//! Protocol parity: the `{text, binary} x {threaded-shim, event-loop}`
//! matrix must produce identical responses for every verb and every
//! error path.
//!
//! Both cores build responses through the shared helpers in
//! `server::mod` and both text decoders share one parser, so parity is
//! by construction — this suite checks the product end-to-end over real
//! sockets: structured results through [`HullClient`] in both
//! encodings, and raw response *bytes* for the deterministic error and
//! pipelining paths.
//!
//! Every assertion is shard-count independent (tier1 re-runs the suite
//! with `ENGINE_SHARDS=4`): session ids are never baked into expected
//! values, and `STATS` is checked for shape, not bytes.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use wagener_hull::coordinator::{BackendKind, BatcherConfig, CoordinatorConfig};
use wagener_hull::engine::{Engine, EngineConfig};
use wagener_hull::geometry::generators::{generate, Distribution};
use wagener_hull::geometry::point::Point;
use wagener_hull::serial::monotone_chain;
use wagener_hull::server::{
    frame, proto, serve_engine, serve_engine_threaded, HullClient, ServerConfig, ServerHandle,
    SessionVerb, WireProto,
};
use wagener_hull::stream::StreamConfig;

fn start_engine(kind: BackendKind) -> Arc<Engine> {
    Arc::new(
        Engine::start(EngineConfig {
            shards: EngineConfig::shards_from_env(1),
            coordinator: CoordinatorConfig {
                backend: kind,
                batcher: BatcherConfig { max_batch: 4, flush_us: 300, queue_cap: 256 },
                self_check: true,
                ..Default::default()
            },
            stream: StreamConfig::default(),
            ..Default::default()
        })
        .unwrap(),
    )
}

fn cfg() -> ServerConfig {
    ServerConfig { addr: "127.0.0.1:0".into(), ..Default::default() }
}

/// Start both connection cores, each on its own (identically
/// configured) engine so per-run state like sid allocation advances in
/// lockstep across the matrix.
fn start_cores(kind: BackendKind) -> Vec<(&'static str, ServerHandle)> {
    vec![
        ("event", serve_engine(start_engine(kind), &cfg()).unwrap()),
        ("threaded", serve_engine_threaded(start_engine(kind), &cfg()).unwrap()),
    ]
}

// ------------------------------------------------- structured matrix

/// Run every verb (happy + error paths) through one client and record a
/// normalized transcript.  Excluded on purpose: sids (allocation
/// advances across runs on a shared engine), `queue_ns`/`exec_ns`
/// (wall-clock), and `STATS` bytes (core-specific gauges) — everything
/// else must be bit-identical across the whole matrix.
fn run_verbs(addr: std::net::SocketAddr, proto: WireProto) -> Vec<String> {
    let mut t: Vec<String> = Vec::new();
    let mut c = HullClient::connect_with(addr, proto).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    assert_eq!(c.wire_proto(), proto);

    c.ping().unwrap();
    t.push("PONG".into());

    let pts = generate(Distribution::Disk, 160, 11);
    let h = c.hull(&pts).unwrap();
    let (u, l) = monotone_chain::full_hull(&pts);
    assert_eq!(h.upper, u);
    assert_eq!(h.lower, l);
    t.push(format!("HULL {:?} {:?} {}", h.upper, h.lower, h.backend));

    // request-level failure: out-of-range coordinate
    let e = c.hull(&[Point::new(5.0, 5.0)]).unwrap_err();
    t.push(format!("HULL-ERR {e}"));
    // request-level failure: empty point set
    let e = c.hull(&[]).unwrap_err();
    t.push(format!("HULL-EMPTY {e}"));

    // session lifecycle (the sid value itself stays out of the transcript)
    let sid = c.session_open().unwrap();
    t.push("SOPEN OK".into());
    let chunk = generate(Distribution::Circle, 100, 23);
    let a1 = c.session_add(sid, &chunk[..50]).unwrap();
    t.push(format!("SADD1 {a1:?}"));
    let a2 = c.session_add(sid, &chunk[50..]).unwrap();
    t.push(format!("SADD2 {a2:?}"));
    let sh = c.session_hull(sid).unwrap();
    t.push(format!("SHULL {} {:?} {:?}", sh.epoch, sh.upper, sh.lower));
    c.session_close(sid).unwrap();
    t.push("SCLOSE OK".into());
    // closed sid: the distinct unknown-session error, connection usable
    let e = c.session_add(sid, &chunk[..1]).unwrap_err();
    t.push(format!("SADD-STALE {e}"));
    let e = c.session_hull(sid).unwrap_err();
    t.push(format!("SHULL-STALE {e}"));
    let e = c.session_close(sid).unwrap_err();
    t.push(format!("SCLOSE-STALE {e}"));

    // STATS: shape only (the event core adds its own "io" gauges)
    let stats = c.stats().unwrap();
    let json = wagener_hull::util::json::parse(&stats).unwrap();
    assert!(json.get("responses").is_some(), "{stats}");
    assert!(json.get("active_connections").is_some(), "{stats}");
    assert!(json.get("open_sessions").is_some(), "{stats}");

    c.ping().unwrap();
    t.push("PONG2".into());
    c.quit().unwrap();
    t
}

#[test]
fn verb_matrix_identical_across_cores_and_protocols() {
    let mut cells: Vec<(String, Vec<String>)> = Vec::new();
    for (core, handle) in start_cores(BackendKind::Native) {
        for proto in [WireProto::Text, WireProto::Binary] {
            cells.push((format!("{core}/{proto:?}"), run_verbs(handle.local_addr, proto)));
        }
        handle.stop();
    }
    let (base_name, base) = cells[0].clone();
    for (name, t) in &cells[1..] {
        assert_eq!(t, &base, "{name} diverges from {base_name}");
    }
}

// ------------------------------------------------- raw byte parity

/// Write `payload`, half-close, read everything the server sends until
/// it closes.  Both cores treat EOF-after-complete-frames as "serve the
/// buffered frames, then close", so this captures a full deterministic
/// exchange.
fn raw_exchange(addr: std::net::SocketAddr, payload: &[u8]) -> Vec<u8> {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    s.write_all(payload).unwrap();
    s.flush().unwrap();
    s.shutdown(std::net::Shutdown::Write).unwrap();
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).unwrap();
    buf
}

fn assert_byte_parity(payloads: &[(&str, Vec<u8>)]) {
    let cores = start_cores(BackendKind::Serial);
    for (what, payload) in payloads {
        let mut replies: Vec<(&'static str, Vec<u8>)> = Vec::new();
        for (core, handle) in &cores {
            replies.push((*core, raw_exchange(handle.local_addr, payload)));
        }
        let (base_core, base) = &replies[0];
        for (core, bytes) in &replies[1..] {
            assert_eq!(
                bytes, base,
                "{what}: {core} bytes diverge from {base_core}\n  {core}: {bytes:?}\n  {base_core}: {base:?}"
            );
        }
    }
    for (_, handle) in cores {
        handle.stop();
    }
}

#[test]
fn text_wire_bytes_identical_across_cores() {
    let oversized_hull = format!("HULL 1 {}\n", proto::MAX_REQUEST_POINTS + 1).into_bytes();
    let oversized_sadd = format!("SADD 9 {}\n", proto::MAX_REQUEST_POINTS + 1).into_bytes();
    assert_byte_parity(&[
        ("unknown command", b"GARBAGE\n".to_vec()),
        ("bad count echoes id", b"HULL 9 zz\n".to_vec()),
        ("bad session count echoes sid", b"SADD 9 zz\n".to_vec()),
        ("bad id: nothing to echo", b"HULL x y\n".to_vec()),
        ("bad sid: nothing to echo", b"SOPEN x\n".to_vec()),
        ("bad point line echoes id", b"HULL 8 1\nnope\n".to_vec()),
        ("oversized HULL trips the DoS guard", oversized_hull),
        ("oversized SADD trips the DoS guard", oversized_sadd),
        ("valid frame before garbage still answers", b"PING\nGARBAGE\n".to_vec()),
        ("pipelined valid frames", b"PING\nSHULL 123456 ignored-operand\nPING\nQUIT\n".to_vec()),
        ("truncated point block closes silently", b"HULL 5 2\n0.1 0.2\n".to_vec()),
        ("empty connection closes silently", Vec::new()),
    ]);
}

/// `[magic, version, verb, id, count]` — a hand-rolled binary request
/// header for frames `encode_request` refuses to produce.
fn bin_header(verb: u8, id: u64, count: u32) -> Vec<u8> {
    let mut b = vec![frame::REQ_MAGIC, frame::VERSION, verb];
    b.extend_from_slice(&id.to_le_bytes());
    b.extend_from_slice(&count.to_le_bytes());
    b
}

#[test]
fn binary_wire_bytes_identical_across_cores() {
    let mut pipelined = Vec::new();
    frame::encode_request(&mut pipelined, &proto::Request::Ping);
    frame::encode_request(&mut pipelined, &proto::Request::Ping);
    frame::encode_request(&mut pipelined, &proto::Request::Quit);

    let mut valid_then_garbage = Vec::new();
    frame::encode_request(&mut valid_then_garbage, &proto::Request::Ping);
    valid_then_garbage.extend_from_slice(&bin_header(200, 77, 0));

    let mut bad_version = bin_header(1, 4, 0);
    bad_version[1] = 9;

    let truncated = bin_header(1, 5, 2); // HULL claiming 2 points, none sent

    assert_byte_parity(&[
        ("unknown verb echoes id", bin_header(200, 77, 0)),
        ("payload on a payload-less verb echoes id", bin_header(7, 5, 3)),
        ("bad version: nothing to echo", bad_version),
        (
            "oversized HULL trips the DoS guard",
            bin_header(1, 1, (proto::MAX_REQUEST_POINTS + 1) as u32),
        ),
        (
            "oversized SADD trips the DoS guard",
            bin_header(3, 9, (proto::MAX_REQUEST_POINTS + 1) as u32),
        ),
        ("valid frame before garbage still answers", valid_then_garbage),
        ("pipelined valid frames", pipelined),
        ("truncated frame closes silently", truncated),
    ]);
}

/// The binary error responses don't just match across cores — they must
/// carry the documented id echo and kind when decoded.
#[test]
fn binary_error_frames_echo_ids_on_both_cores() {
    let cores = start_cores(BackendKind::Serial);
    for (core, handle) in &cores {
        // unknown verb: header parsed, id 77 echoes as MalformedErr
        let bytes = raw_exchange(handle.local_addr, &bin_header(200, 77, 0));
        match frame::decode_response(&bytes).unwrap() {
            proto::Decoded::Frame(proto::Response::MalformedErr { id, .. }, used) => {
                assert_eq!(id, Some(77), "{core}");
                assert_eq!(used, bytes.len(), "{core}: trailing bytes after the error");
            }
            other => panic!("{core}: {other:?}"),
        }
        let over = (proto::MAX_REQUEST_POINTS + 1) as u32;
        // oversized HULL: a HULL-level error on id 1, same as text
        let bytes = raw_exchange(handle.local_addr, &bin_header(1, 1, over));
        match frame::decode_response(&bytes).unwrap() {
            proto::Decoded::Frame(proto::Response::HullErr { id: 1, .. }, _) => {}
            other => panic!("{core}: {other:?}"),
        }
        // oversized SADD: a session error on sid 9 under the SADD verb
        let bytes = raw_exchange(handle.local_addr, &bin_header(3, 9, over));
        match frame::decode_response(&bytes).unwrap() {
            proto::Decoded::Frame(
                proto::Response::SessionErr { verb: SessionVerb::Add, id: 9, .. },
                _,
            ) => {}
            other => panic!("{core}: {other:?}"),
        }
    }
    for (_, handle) in cores {
        handle.stop();
    }
}

/// A text client and a binary client asking the same engine the same
/// question get numerically identical hulls (the encodings carry f64
/// bit patterns either way).
#[test]
fn text_and_binary_hulls_agree_point_for_point() {
    for (_, handle) in start_cores(BackendKind::Native) {
        let pts = generate(Distribution::Bimodal, 300, 99);
        let mut ct = HullClient::connect_with(handle.local_addr, WireProto::Text).unwrap();
        let mut cb = HullClient::connect_with(handle.local_addr, WireProto::Binary).unwrap();
        ct.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
        cb.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
        let ht = ct.hull(&pts).unwrap();
        let hb = cb.hull(&pts).unwrap();
        assert_eq!(ht.upper, hb.upper);
        assert_eq!(ht.lower, hb.lower);
        assert_eq!(ht.backend, hb.backend);
        ct.quit().unwrap();
        cb.quit().unwrap();
        handle.stop();
    }
}
