//! L3↔L2 bridge: load AOT-compiled HLO artifacts and execute them on the
//! PJRT CPU client from the request hot path.  Python never runs here —
//! the artifacts under `artifacts/` were produced once by
//! `python -m compile.aot` (see Makefile target `artifacts`).

pub mod artifacts;
pub mod executor;

pub use artifacts::{ArtifactKind, ArtifactMeta, ArtifactRegistry};
pub use executor::{HullExecutor, RuntimeStats};
