//! The PRAM machine: synchronous steps over flat shared memory.

use std::collections::HashMap;

/// CUDA-style shared-memory serialization model.
#[derive(Clone, Copy, Debug)]
pub struct BankModel {
    /// number of shared-memory banks (32 on every CUDA generation).
    pub banks: usize,
    /// SIMD width — PEs `[w*warp, (w+1)*warp)` form one warp.
    pub warp: usize,
    /// bank index stride in machine words (4-byte words on CUDA; our cells
    /// are one word each).
    pub word_stride: usize,
}

impl Default for BankModel {
    fn default() -> Self {
        BankModel { banks: 32, warp: 32, word_stride: 1 }
    }
}

/// Aggregate counters over the life of the machine.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Counters {
    /// synchronous parallel steps executed (PRAM time).
    pub steps: u64,
    /// total PE activations (PRAM work).
    pub work: u64,
    /// shared-memory cell reads / writes.
    pub reads: u64,
    pub writes: u64,
    /// modeled cycles under the bank model (>= steps; == steps iff
    /// conflict-free).  One step costs `max over warps of (read
    /// serialization + write serialization)`, min 1.
    pub modeled_cycles: u64,
    /// ideal cycles: 1 per step (a conflict-free PRAM).
    pub ideal_cycles: u64,
    /// same-cell writes by two PEs in one step (CREW violations).
    pub write_conflicts: u64,
    /// a cell read and written in the same step (benign under
    /// reads-see-old-memory semantics; counted for diagnostics).
    pub read_write_overlaps: u64,
    /// largest PE count used in any step.
    pub max_pes: u64,
}

impl Counters {
    /// Bank-conflict slowdown factor (modeled / ideal).
    pub fn conflict_factor(&self) -> f64 {
        if self.ideal_cycles == 0 {
            1.0
        } else {
            self.modeled_cycles as f64 / self.ideal_cycles as f64
        }
    }
}

/// Hard errors (write-write conflicts when `strict` is set).
#[derive(Debug, Clone, PartialEq)]
pub struct PramError {
    pub step: u64,
    pub addr: usize,
    pub pes: (usize, usize),
}

impl std::fmt::Display for PramError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "CREW violation at step {}: cell {} written by PEs {} and {}",
            self.step, self.addr, self.pes.0, self.pes.1
        )
    }
}

impl std::error::Error for PramError {}

/// Per-PE execution context handed to the step closure.
pub struct PeCtx<'a> {
    pe: usize,
    mem: &'a [f64],
    regs: &'a mut [f64],
    reads: &'a mut Vec<(usize, usize)>,
    writes: &'a mut Vec<(usize, f64, usize)>,
}

impl<'a> PeCtx<'a> {
    pub fn pe(&self) -> usize {
        self.pe
    }

    /// Read a shared cell (sees the memory state before this step).
    pub fn read(&mut self, addr: usize) -> f64 {
        self.reads.push((addr, self.pe));
        self.mem[addr]
    }

    /// Buffer a shared-cell write (commits at the step barrier).
    pub fn write(&mut self, addr: usize, val: f64) {
        self.writes.push((addr, val, self.pe));
    }

    /// Read a 2-cell point (x at `addr2`, y at `addr2 + 1`).
    pub fn read_pair(&mut self, addr2: usize) -> (f64, f64) {
        (self.read(addr2), self.read(addr2 + 1))
    }

    pub fn write_pair(&mut self, addr2: usize, x: f64, y: f64) {
        self.write(addr2, x);
        self.write(addr2 + 1, y);
    }

    /// Private per-PE register file (not shared memory; not counted).
    pub fn reg(&self, r: usize) -> f64 {
        self.regs[r]
    }

    pub fn set_reg(&mut self, r: usize, v: f64) {
        self.regs[r] = v;
    }
}

/// The machine.
pub struct Pram {
    pub mem: Vec<f64>,
    pub counters: Counters,
    pub bank_model: BankModel,
    /// return Err on write-write conflicts instead of counting.
    pub strict: bool,
    regs: Vec<f64>,
    regs_per_pe: usize,
    reads_buf: Vec<(usize, usize)>,
    writes_buf: Vec<(usize, f64, usize)>,
}

impl Pram {
    /// `cells` words of shared memory; `regs_per_pe` private registers for
    /// up to `max_pes` PEs.
    pub fn new(cells: usize, max_pes: usize, regs_per_pe: usize) -> Pram {
        Pram {
            mem: vec![0.0; cells],
            counters: Counters::default(),
            bank_model: BankModel::default(),
            strict: true,
            regs: vec![0.0; max_pes * regs_per_pe],
            regs_per_pe,
            reads_buf: Vec::new(),
            writes_buf: Vec::new(),
        }
    }

    /// Run one synchronous step with PEs `0..pes`.
    ///
    /// Every PE executes `body(pe, ctx)`; reads observe pre-step memory;
    /// writes commit at the barrier.  Returns the CREW status.
    pub fn step<F>(&mut self, pes: usize, body: F) -> Result<(), PramError>
    where
        F: Fn(usize, &mut PeCtx<'_>),
    {
        self.reads_buf.clear();
        self.writes_buf.clear();
        let rpp = self.regs_per_pe;
        for pe in 0..pes {
            let mut ctx = PeCtx {
                pe,
                mem: &self.mem,
                regs: &mut self.regs[pe * rpp..(pe + 1) * rpp],
                reads: &mut self.reads_buf,
                writes: &mut self.writes_buf,
            };
            body(pe, &mut ctx);
        }
        self.account(pes)
    }

    fn account(&mut self, pes: usize) -> Result<(), PramError> {
        let c = &mut self.counters;
        c.steps += 1;
        c.work += pes as u64;
        c.max_pes = c.max_pes.max(pes as u64);
        c.reads += self.reads_buf.len() as u64;
        c.writes += self.writes_buf.len() as u64;
        c.ideal_cycles += 1;

        // ---- CREW write-conflict detection
        self.writes_buf.sort_unstable_by_key(|&(addr, _, pe)| (addr, pe));
        for w in self.writes_buf.windows(2) {
            if w[0].0 == w[1].0 {
                c.write_conflicts += 1;
                if self.strict {
                    return Err(PramError {
                        step: c.steps,
                        addr: w[0].0,
                        pes: (w[0].2, w[1].2),
                    });
                }
            }
        }
        // read-write overlap diagnostics
        {
            let mut waddrs: Vec<usize> = self.writes_buf.iter().map(|w| w.0).collect();
            waddrs.sort_unstable();
            waddrs.dedup();
            for &(addr, _) in &self.reads_buf {
                if waddrs.binary_search(&addr).is_ok() {
                    c.read_write_overlaps += 1;
                }
            }
        }

        // ---- bank serialization model
        let bm = self.bank_model;
        let mut warp_cost: HashMap<usize, (HashMap<usize, Vec<usize>>, HashMap<usize, Vec<usize>>)> =
            HashMap::new();
        for &(addr, pe) in &self.reads_buf {
            let warp = pe / bm.warp;
            let bank = (addr / bm.word_stride) % bm.banks;
            warp_cost.entry(warp).or_default().0.entry(bank).or_default().push(addr);
        }
        for &(addr, _, pe) in &self.writes_buf {
            let warp = pe / bm.warp;
            let bank = (addr / bm.word_stride) % bm.banks;
            warp_cost.entry(warp).or_default().1.entry(bank).or_default().push(addr);
        }
        let mut step_cycles = 1u64;
        for (_, (rbanks, wbanks)) in warp_cost {
            let mut cyc = 0u64;
            for (_, mut addrs) in rbanks {
                // same-address reads broadcast (CUDA): distinct addresses count
                addrs.sort_unstable();
                addrs.dedup();
                cyc = cyc.max(addrs.len() as u64);
            }
            let mut wcyc = 0u64;
            for (_, mut addrs) in wbanks {
                addrs.sort_unstable();
                addrs.dedup();
                wcyc = wcyc.max(addrs.len() as u64);
            }
            step_cycles = step_cycles.max(cyc + wcyc);
        }
        c.modeled_cycles += step_cycles;

        // commit writes
        for &(addr, val, _) in &self.writes_buf {
            self.mem[addr] = val;
        }
        Ok(())
    }

    /// Convenience: reset counters (memory retained).
    pub fn reset_counters(&mut self) {
        self.counters = Counters::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_commits_writes_at_barrier() {
        let mut m = Pram::new(4, 4, 0);
        m.mem[0] = 1.0;
        m.mem[1] = 2.0;
        // classic swap test: both PEs read old values
        m.step(2, |pe, ctx| {
            let v = ctx.read(1 - pe);
            ctx.write(pe, v);
        })
        .unwrap();
        assert_eq!(m.mem[0], 2.0);
        assert_eq!(m.mem[1], 1.0);
    }

    #[test]
    fn crew_violation_detected() {
        let mut m = Pram::new(2, 4, 0);
        let err = m
            .step(3, |_, ctx| ctx.write(0, 7.0))
            .unwrap_err();
        assert_eq!(err.addr, 0);
        assert_eq!(m.counters.write_conflicts, 1);
    }

    #[test]
    fn non_strict_counts_conflicts() {
        let mut m = Pram::new(2, 4, 0);
        m.strict = false;
        m.step(3, |_, ctx| ctx.write(0, 7.0)).unwrap();
        assert_eq!(m.counters.write_conflicts, 2); // 3 writers -> 2 adjacent pairs
    }

    #[test]
    fn exclusive_writes_pass() {
        let mut m = Pram::new(8, 8, 0);
        m.step(8, |pe, ctx| ctx.write(pe, pe as f64)).unwrap();
        assert_eq!(m.counters.write_conflicts, 0);
        assert_eq!(m.mem[5], 5.0);
    }

    #[test]
    fn work_and_steps_counted() {
        let mut m = Pram::new(8, 8, 0);
        m.step(8, |_, _| {}).unwrap();
        m.step(4, |_, _| {}).unwrap();
        assert_eq!(m.counters.steps, 2);
        assert_eq!(m.counters.work, 12);
        assert_eq!(m.counters.max_pes, 8);
    }

    #[test]
    fn bank_conflicts_modeled() {
        // 32 PEs all hitting bank 0 with distinct addresses: 32-way conflict
        let mut m = Pram::new(32 * 32, 32, 0);
        m.step(32, |pe, ctx| {
            let _ = ctx.read(pe * 32); // all map to bank 0
        })
        .unwrap();
        assert_eq!(m.counters.modeled_cycles, 32);
        assert_eq!(m.counters.ideal_cycles, 1);
        assert!((m.counters.conflict_factor() - 32.0).abs() < 1e-9);

        // stride-1 reads: conflict-free
        let mut m2 = Pram::new(32 * 32, 32, 0);
        m2.step(32, |pe, ctx| {
            let _ = ctx.read(pe);
        })
        .unwrap();
        assert_eq!(m2.counters.modeled_cycles, 1);
    }

    #[test]
    fn broadcast_reads_are_free() {
        // all PEs read the same cell: CUDA broadcast, 1 cycle
        let mut m = Pram::new(4, 32, 0);
        m.step(32, |_, ctx| {
            let _ = ctx.read(0);
        })
        .unwrap();
        assert_eq!(m.counters.modeled_cycles, 1);
    }

    #[test]
    fn read_write_overlap_is_benign_but_counted() {
        let mut m = Pram::new(2, 2, 0);
        m.mem[0] = 5.0;
        m.step(2, |pe, ctx| {
            if pe == 0 {
                let v = ctx.read(0);
                ctx.write(1, v);
            } else {
                ctx.write(0, 9.0);
            }
        })
        .unwrap();
        assert_eq!(m.mem[1], 5.0); // read saw pre-step value
        assert_eq!(m.mem[0], 9.0);
        assert_eq!(m.counters.read_write_overlaps, 1);
    }

    #[test]
    fn registers_are_private_and_persistent() {
        let mut m = Pram::new(1, 4, 2);
        m.step(4, |pe, ctx| ctx.set_reg(0, pe as f64 * 10.0)).unwrap();
        m.step(4, |pe, ctx| {
            assert_eq!(ctx.reg(0), pe as f64 * 10.0);
        })
        .unwrap();
        assert_eq!(m.counters.reads, 0); // registers don't touch shared mem
    }

    #[test]
    fn warps_cost_independently() {
        // warp 0 conflict-free, warp 1 has a 4-way conflict: step = 4 cycles
        let mut m = Pram::new(64 * 33, 64, 0);
        m.step(64, |pe, ctx| {
            if pe < 32 {
                let _ = ctx.read(pe);
            } else {
                let _ = ctx.read((pe % 4) * 32); // 4 distinct addrs, bank 0
            }
        })
        .unwrap();
        assert_eq!(m.counters.modeled_cycles, 4);
    }
}
