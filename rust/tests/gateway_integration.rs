//! HTTP gateway end-to-end: both listeners (TCP event core + HTTP
//! gateway) front *one* shared [`Engine`], so every HTTP exchange must
//! be bit-identical in substance to its TCP equivalent — hulls, session
//! state, epoch time-travel, and error taxonomy.  Pagination is pinned
//! the hardest way: pages fetched through opaque cursors, with the
//! session mutating mid-walk, must reassemble to the exact bytes of a
//! one-shot `SHULL` read.
//!
//! Every assertion is shard-count independent (tier1 re-runs the suite
//! with `ENGINE_SHARDS=4`): sids come from the server, and stats are
//! checked for shape, not values.

use std::sync::Arc;
use std::time::Duration;

use wagener_hull::coordinator::{BackendKind, BatcherConfig, CoordinatorConfig};
use wagener_hull::engine::{Engine, EngineConfig};
use wagener_hull::gateway::client::HttpClient;
use wagener_hull::gateway::{serve_gateway, GatewayConfig, GatewayHandle};
use wagener_hull::geometry::generators::{generate, Distribution};
use wagener_hull::geometry::point::Point;
use wagener_hull::server::{serve_engine, HullClient, ServerConfig, ServerHandle};
use wagener_hull::stream::StreamConfig;
use wagener_hull::util::json::Json;

/// One engine, two listeners.  `merge_threshold: 1` makes every `SADD`
/// absorb immediately, so the epoch in each add reply names a fully
/// materialized ledger entry — the determinism time-travel needs.
struct Stack {
    engine: Arc<Engine>,
    tcp: ServerHandle,
    gw: GatewayHandle,
}

fn start_stack() -> Stack {
    let engine = Arc::new(
        Engine::start(EngineConfig {
            shards: EngineConfig::shards_from_env(1),
            coordinator: CoordinatorConfig {
                backend: BackendKind::Serial,
                batcher: BatcherConfig { max_batch: 4, flush_us: 200, queue_cap: 256 },
                ..Default::default()
            },
            stream: StreamConfig { merge_threshold: 1, ..Default::default() },
            ..Default::default()
        })
        .unwrap(),
    );
    let tcp = serve_engine(
        engine.clone(),
        &ServerConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
    )
    .unwrap();
    let gw = serve_gateway(
        engine.clone(),
        &GatewayConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
    )
    .unwrap();
    Stack { engine, tcp, gw }
}

impl Stack {
    fn http(&self) -> HttpClient {
        HttpClient::connect(self.gw.local_addr()).unwrap()
    }

    fn tcp_client(&self) -> HullClient {
        let mut c = HullClient::connect(self.tcp.local_addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
        c
    }
}

// ----------------------------------------------------------- helpers

/// Points as exact bit patterns — the unit of parity.
fn bits(pts: &[Point]) -> Vec<(u64, u64)> {
    pts.iter().map(|p| (p.x.to_bits(), p.y.to_bits())).collect()
}

/// Decode a JSON `[[x,y],...]` chain back into points.  The gateway
/// prints f64s in shortest-roundtrip form, so parse(print(x)) == x
/// bit-for-bit; any mismatch downstream is a real parity break.
fn json_points(j: &Json, key: &str) -> Vec<Point> {
    j.get(key)
        .and_then(|v| v.as_arr())
        .unwrap_or_else(|| panic!("response wants a {key:?} array: {j}"))
        .iter()
        .map(|pair| {
            let p = pair.as_arr().expect("[x, y] pair");
            Point::new(p[0].as_f64().unwrap(), p[1].as_f64().unwrap())
        })
        .collect()
}

fn err_code(j: &Json) -> String {
    match j.get("error").and_then(|e| e.get("code")) {
        Some(Json::Str(s)) => s.clone(),
        _ => panic!("response wants an error object: {j}"),
    }
}

fn num(j: &Json, key: &str) -> u64 {
    j.get(key).and_then(|v| v.as_f64()).unwrap_or_else(|| panic!("{key} in {j}")) as u64
}

fn points_body(pts: &[Point]) -> String {
    let pairs: Vec<String> = pts.iter().map(|p| format!("[{},{}]", p.x, p.y)).collect();
    format!("{{\"points\":[{}]}}", pairs.join(","))
}

fn le_body(pts: &[Point]) -> Vec<u8> {
    let mut b = Vec::with_capacity(pts.len() * 16);
    for p in pts {
        b.extend_from_slice(&p.x.to_le_bytes());
        b.extend_from_slice(&p.y.to_le_bytes());
    }
    b
}

// ------------------------------------------------------ one-shot hulls

/// The same point set through all four encodings — TCP text, TCP
/// binary, HTTP JSON, HTTP octet-stream — produces bit-identical hulls.
#[test]
fn http_hulls_match_tcp_bit_for_bit() {
    let stack = start_stack();
    let pts = generate(Distribution::Disk, 300, 7);

    let mut tcp = stack.tcp_client();
    let reference = tcp.hull(&pts).unwrap();

    let mut http = stack.http();
    for (what, r) in [
        ("json", http.post_json("/v1/hull?id=7", &points_body(&pts)).unwrap()),
        ("binary", http.post_bytes("/v1/hull?id=7", &le_body(&pts)).unwrap()),
    ] {
        let j = r.json();
        assert_eq!(r.status, 200, "{what}: {j}");
        assert_eq!(num(&j, "id"), 7, "{what}");
        assert_eq!(bits(&json_points(&j, "upper")), bits(&reference.upper), "{what} upper");
        assert_eq!(bits(&json_points(&j, "lower")), bits(&reference.lower), "{what} lower");
        assert_eq!(
            j.get("backend"),
            Some(&Json::Str(reference.backend.clone())),
            "{what} backend"
        );
    }
    tcp.quit().unwrap();
    stack.gw.stop();
    stack.tcp.stop();
}

/// Hull-level failures carry the shared taxonomy: out-of-range
/// coordinates and empty point sets are 400s with stable codes, and the
/// connection stays usable afterwards (keep-alive survives errors).
#[test]
fn hull_errors_map_to_stable_statuses() {
    let stack = start_stack();
    let mut http = stack.http();

    let r = http.post_json("/v1/hull", &points_body(&[Point::new(5.0, 5.0)])).unwrap();
    assert_eq!(r.status, 400, "{}", r.json());
    assert_eq!(err_code(&r.json()), "bad-request");

    let r = http.post_json("/v1/hull", "{\"points\":[]}").unwrap();
    assert_eq!(r.status, 400, "{}", r.json());

    let r = http.post_json("/v1/hull", "points are not json").unwrap();
    assert_eq!(err_code(&r.json()), "bad-json");

    // 15 bytes is not a whole x,y pair
    let r = http.post_bytes("/v1/hull", &[0u8; 15]).unwrap();
    assert_eq!(r.status, 400);
    assert_eq!(err_code(&r.json()), "bad-binary-body");

    // the connection survived four failures: a good request still lands
    let r = http.post_json("/v1/hull", &points_body(&generate(Distribution::Disk, 32, 1))).unwrap();
    assert_eq!(r.status, 200);
    stack.gw.stop();
    stack.tcp.stop();
}

// ------------------------------------------------- shared session state

/// A session opened over HTTP is the same session over TCP: adds from
/// either listener land in one ledger, live hulls agree bit-for-bit,
/// and historical epochs replay identically through both protocols.
#[test]
fn sessions_are_shared_across_listeners_with_epoch_time_travel() {
    let stack = start_stack();
    let mut http = stack.http();
    let mut tcp = stack.tcp_client();

    let r = http.post_json("/v1/sessions", "").unwrap();
    assert_eq!(r.status, 200, "{}", r.json());
    let sid = num(&r.json(), "sid");

    // interleave writers across protocols
    let chunk = generate(Distribution::Circle, 96, 23);
    let r = http
        .post_json(&format!("/v1/sessions/{sid}/points"), &points_body(&chunk[..48]))
        .unwrap();
    assert_eq!(r.status, 200, "{}", r.json());
    let mid_epoch = num(&r.json(), "epoch");
    tcp.session_add(sid, &chunk[48..]).unwrap();

    // live hulls agree
    let reference = tcp.session_hull(sid).unwrap();
    let r = http.get(&format!("/v1/sessions/{sid}/hull")).unwrap();
    let j = r.json();
    assert_eq!(r.status, 200, "{j}");
    assert_eq!(num(&j, "epoch"), reference.epoch);
    assert_eq!(bits(&json_points(&j, "upper")), bits(&reference.upper));
    assert_eq!(bits(&json_points(&j, "lower")), bits(&reference.lower));

    // time-travel: the epoch the HTTP add reported replays identically
    let past = tcp.session_hull_at(sid, mid_epoch).unwrap();
    let r = http.get(&format!("/v1/sessions/{sid}/hull?epoch={mid_epoch}")).unwrap();
    let j = r.json();
    assert_eq!(num(&j, "epoch"), past.epoch);
    assert_eq!(bits(&json_points(&j, "upper")), bits(&past.upper));
    assert_eq!(bits(&json_points(&j, "lower")), bits(&past.lower));

    // epoch 0 is the empty hull on both sides
    let r = http.get(&format!("/v1/sessions/{sid}/hull?epoch=0")).unwrap();
    assert!(json_points(&r.json(), "upper").is_empty());

    // beyond the ledger: unknown-epoch through both protocols
    let r = http.get(&format!("/v1/sessions/{sid}/hull?epoch=999999")).unwrap();
    assert_eq!(r.status, 404);
    assert_eq!(err_code(&r.json()), "unknown-epoch");
    let e = tcp.session_hull_at(sid, 999_999).unwrap_err();
    assert!(e.to_string().contains("unknown-epoch"), "{e}");

    // close over HTTP; the TCP side sees it gone
    let r = http.delete(&format!("/v1/sessions/{sid}")).unwrap();
    assert_eq!(r.status, 200);
    assert!(tcp.session_add(sid, &chunk[..1]).is_err());
    let r = http.get(&format!("/v1/sessions/{sid}/hull")).unwrap();
    assert_eq!(r.status, 404);
    assert_eq!(err_code(&r.json()), "unknown-session");

    tcp.quit().unwrap();
    stack.gw.stop();
    stack.tcp.stop();
}

/// Session restore round-trips through the JSON body form.
#[test]
fn restore_rides_the_json_body() {
    let stack = start_stack();
    // no snapshot store configured in this stack: restore of an unknown
    // sid is still a typed miss, which is what we pin here
    let mut http = stack.http();
    let r = http.post_json("/v1/sessions", "{\"restore\": 424242}").unwrap();
    assert_eq!(r.status, 404, "{}", r.json());
    assert_eq!(err_code(&r.json()), "unknown-session");
    let r = http.post_json("/v1/sessions", "{\"restore\": -3}").unwrap();
    assert_eq!(r.status, 400);
    assert_eq!(err_code(&r.json()), "bad-json");
    stack.gw.stop();
    stack.tcp.stop();
}

// ------------------------------------------------------------ pagination

/// Walk `GET /v1/sessions/{sid}/hull` to exhaustion following
/// `next_cursor`, returning the reassembled chains and every page's
/// reported epoch.
fn paginate(
    http: &mut HttpClient,
    sid: u64,
    first: String,
    limit: usize,
) -> (Vec<Point>, Vec<Point>, Vec<u64>) {
    let (mut upper, mut lower, mut epochs) = (Vec::new(), Vec::new(), Vec::new());
    let mut target = first;
    for _ in 0..10_000 {
        let r = http.get(&target).unwrap();
        let j = r.json();
        assert_eq!(r.status, 200, "{j}");
        let (u, l) = (json_points(&j, "upper"), json_points(&j, "lower"));
        assert!(u.len() + l.len() <= limit, "page overflows limit {limit}: {j}");
        upper.extend(u);
        lower.extend(l);
        epochs.push(num(&j, "epoch"));
        match j.get("next_cursor") {
            Some(Json::Str(c)) => {
                target = format!("/v1/sessions/{sid}/hull?cursor={c}&limit={limit}");
            }
            Some(Json::Null) => return (upper, lower, epochs),
            other => panic!("next_cursor is {other:?}"),
        }
    }
    panic!("pagination never terminated at limit {limit}");
}

/// Pages reassemble bit-identically to a one-shot TCP `SHULL` for every
/// page size — including limit=1 — and keep doing so while the session
/// absorbs new points mid-walk, because the cursor pins its epoch.
#[test]
fn pages_reassemble_bit_identically_under_concurrent_writes() {
    let stack = start_stack();
    let mut http = stack.http();
    let mut tcp = stack.tcp_client();

    let sid = num(&http.post_json("/v1/sessions", "").unwrap().json(), "sid");
    // circle points: every input point is a hull vertex, so the chains
    // are long enough that small limits take many pages
    let pts = generate(Distribution::Circle, 257, 5);
    let r = http.post_bytes(&format!("/v1/sessions/{sid}/points"), &le_body(&pts)).unwrap();
    assert_eq!(r.status, 200, "{}", r.json());

    let reference = tcp.session_hull(sid).unwrap();
    assert!(
        reference.upper.len() + reference.lower.len() > 40,
        "degenerate reference hull ({} + {} points)",
        reference.upper.len(),
        reference.lower.len()
    );

    for limit in [1usize, 2, 3, 7, 64, 4096] {
        let first = format!("/v1/sessions/{sid}/hull?epoch={}&limit={limit}", reference.epoch);
        let (upper, lower, epochs) = paginate(&mut http, sid, first, limit);
        assert_eq!(bits(&upper), bits(&reference.upper), "limit {limit} upper");
        assert_eq!(bits(&lower), bits(&reference.lower), "limit {limit} lower");
        assert!(epochs.iter().all(|e| *e == reference.epoch), "limit {limit}: {epochs:?}");

        // mutate between walks: later reads of the *pinned* epoch must
        // not see the new points
        let more = generate(Distribution::Disk, 16, limit as u64 + 100);
        tcp.session_add(sid, &more).unwrap();
    }

    // and a live (un-pinned) walk now reflects all the mutations
    let live = tcp.session_hull(sid).unwrap();
    let (upper, lower, _) =
        paginate(&mut http, sid, format!("/v1/sessions/{sid}/hull?limit=7"), 7);
    assert_eq!(bits(&upper), bits(&live.upper));
    assert_eq!(bits(&lower), bits(&live.lower));

    tcp.quit().unwrap();
    stack.gw.stop();
    stack.tcp.stop();
}

/// Cursor misuse is a typed 400, never a panic or a silent wrong page.
#[test]
fn cursor_misuse_is_a_typed_400() {
    let stack = start_stack();
    let mut http = stack.http();
    let sid = num(&http.post_json("/v1/sessions", "").unwrap().json(), "sid");
    http.post_json(&format!("/v1/sessions/{sid}/points"), &points_body(&[Point::new(0.0, 0.0)]))
        .unwrap();

    let all_ff = "ff".repeat(19);
    for bad in ["junk", "00", all_ff.as_str()] {
        let r = http.get(&format!("/v1/sessions/{sid}/hull?cursor={bad}")).unwrap();
        assert_eq!(r.status, 400, "cursor {bad:?}");
        assert_eq!(err_code(&r.json()), "bad-cursor");
    }

    // a real cursor with a contradicting ?epoch= is rejected, not raced
    let r = http.get(&format!("/v1/sessions/{sid}/hull?limit=1")).unwrap();
    if let Some(Json::Str(c)) = r.json().get("next_cursor") {
        let r = http.get(&format!("/v1/sessions/{sid}/hull?cursor={c}&epoch=999")).unwrap();
        assert_eq!(r.status, 400);
        assert_eq!(err_code(&r.json()), "bad-cursor");
    }
    stack.gw.stop();
    stack.tcp.stop();
}

// ---------------------------------------------------- routing + errors

#[test]
fn unknown_routes_and_methods_are_typed() {
    let stack = start_stack();
    let mut http = stack.http();

    let r = http.get("/v2/nope").unwrap();
    assert_eq!(r.status, 404);
    assert_eq!(err_code(&r.json()), "unknown-route");

    let r = http.post_json("/healthz", "").unwrap();
    assert_eq!(r.status, 405);
    assert_eq!(err_code(&r.json()), "method-not-allowed");

    let r = http.get("/v1/sessions/notanumber/hull").unwrap();
    assert_eq!(r.status, 400);
    assert_eq!(err_code(&r.json()), "bad-path-parameter");

    let r = http.get("/v1/sessions/1/hull?limit=many").unwrap();
    assert_eq!(r.status, 400);
    assert_eq!(err_code(&r.json()), "bad-query-parameter");
    stack.gw.stop();
    stack.tcp.stop();
}

// -------------------------------------------------- stats + observability

/// Both protocols expose one stats document: the gateway object (with
/// its per-route entries) and the io object appear with identical key
/// sets whether read over `GET /v1/stats` or TCP `STATS`.
#[test]
fn stats_agree_across_protocols_and_probes_answer() {
    let stack = start_stack();
    let mut http = stack.http();
    let mut tcp = stack.tcp_client();

    // generate some traffic so the counters move
    http.post_json("/v1/hull", &points_body(&generate(Distribution::Disk, 32, 3))).unwrap();
    http.get("/v2/nope").unwrap();

    let r = http.get("/healthz").unwrap();
    assert_eq!(r.status, 200);
    assert_eq!(r.json().get("ok"), Some(&Json::Bool(true)));
    assert_eq!(num(&r.json(), "shards"), stack.engine.shard_count() as u64);

    let r = http.get("/readyz").unwrap();
    assert_eq!(r.status, 200, "{}", r.json());
    assert_eq!(r.json().get("ready"), Some(&Json::Bool(true)));

    let r = http.get("/v1/stats").unwrap();
    assert_eq!(r.status, 200);
    let via_http = r.json();
    let via_tcp = wagener_hull::util::json::parse(&tcp.stats().unwrap()).unwrap();

    for doc in [&via_http, &via_tcp] {
        let gw = doc.get("gateway").and_then(|g| g.as_obj()).expect("gateway object");
        assert!(gw.contains_key("accepted"));
        assert!(gw.contains_key("decode_errors"));
        let routes = gw.get("routes").and_then(|r| r.as_obj()).expect("routes object");
        let hull = routes.get("POST /v1/hull").and_then(|r| r.as_obj()).expect("hull route");
        for key in ["requests", "status_2xx", "status_4xx", "status_5xx", "latency"] {
            assert!(hull.contains_key(key), "route metrics want {key}");
        }
        assert!(doc.get("io").is_some(), "stats wants the io object");
    }
    // identical schema through both listeners
    let keys = |j: &Json| -> Vec<String> {
        j.get("gateway").and_then(|g| g.as_obj()).unwrap().keys().cloned().collect()
    };
    assert_eq!(keys(&via_http), keys(&via_tcp));

    // the traffic we generated is visible: ≥1 hull request, ≥1 'other'
    let count = |j: &Json, route: &str| -> u64 {
        j.get("gateway")
            .and_then(|g| g.get("routes"))
            .and_then(|r| r.get(route))
            .and_then(|r| r.get("requests"))
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0) as u64
    };
    assert!(count(&via_tcp, "POST /v1/hull") >= 1);
    assert!(count(&via_tcp, "other") >= 1);

    tcp.quit().unwrap();
    stack.gw.stop();
    stack.tcp.stop();
}
