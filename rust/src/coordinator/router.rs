//! The coordinator: request intake, routing, thread topology, lifecycle.
//!
//! Thread layout (all std threads; this environment vendors no async
//! runtime, and the workload is CPU-bound — see DESIGN.md §Substitutions):
//!
//! ```text
//! callers ──submit()──► [batcher thread] ──batches──► [exec thread]
//!    ▲  (prepare +              │  size-class queues        │ owns the
//!    │   degenerate             ▼  deadline flushing        ▼ backend
//!    │   fast path)      bounded channel             replies + metrics
//!    └──────────────────────── per-request reply channel ◄──┘
//! ```

use std::path::PathBuf;
use std::sync::atomic::AtomicU64;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use super::backend::{exact_full_hull, BackendKind};
use super::batcher::{run_batcher, BatchMsg, BatcherConfig, Item};
use super::metrics::{Metrics, MetricsSnapshot};
use super::request::{prepare, HullRequest, HullResponse, RequestError};
use crate::geometry::hull_check::check_upper_hull;
use crate::geometry::point::Point;
use crate::pram::ExecMode;

/// Coordinator configuration (see config.rs for the TOML form).
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub backend: BackendKind,
    pub artifacts_dir: PathBuf,
    pub batcher: BatcherConfig,
    /// verify every response against the hull checker, and (pjrt backend)
    /// cross-check PJRT results against the PRAM engine on `exec_mode`
    /// (paranoia mode; divergences land in `RuntimeStats::ref_mismatches`).
    pub self_check: bool,
    /// compile all hull artifacts at startup (pjrt backend only).
    pub preload: bool,
    /// PRAM engine tier for the `pram` backend: the serving path defaults
    /// to `Fast`; `Audited` keeps the CREW/bank-model instrument live.
    pub exec_mode: ExecMode,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            backend: BackendKind::Native,
            artifacts_dir: PathBuf::from("artifacts"),
            batcher: BatcherConfig::default(),
            self_check: false,
            preload: false,
            exec_mode: ExecMode::Fast,
        }
    }
}

/// Handle to a running coordinator.
pub struct Coordinator {
    submit_tx: Option<mpsc::SyncSender<Item>>,
    batcher: Option<JoinHandle<()>>,
    exec: Option<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    backend_name: &'static str,
    max_points: usize,
    next_id: AtomicU64,
}

impl Coordinator {
    /// Spawn the batcher + exec threads; fails if the backend cannot be
    /// constructed (e.g. missing artifacts for `pjrt`).
    pub fn start(cfg: CoordinatorConfig) -> Result<Coordinator, String> {
        let metrics = Arc::new(Metrics::default());
        let (submit_tx, submit_rx) = mpsc::sync_channel::<Item>(cfg.batcher.queue_cap);
        let (batch_tx, batch_rx) = mpsc::sync_channel::<BatchMsg>(cfg.batcher.queue_cap.max(1));
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(usize, usize), String>>();

        // --- exec thread: owns the backend (PJRT handles are !Send)
        let exec_metrics = metrics.clone();
        let exec_cfg = cfg.clone();
        let exec = std::thread::Builder::new()
            .name("hull-exec".into())
            .spawn(move || {
                let backend = match exec_cfg.backend.build(
                    &exec_cfg.artifacts_dir,
                    exec_cfg.preload,
                    exec_cfg.exec_mode,
                    exec_cfg.self_check,
                ) {
                    Ok(b) => {
                        let _ = ready_tx.send(Ok((b.max_points(), b.preferred_batch())));
                        b
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(BatchMsg { items }) = batch_rx.recv() {
                    let exec_start = Instant::now();
                    let reqs: Vec<Vec<Point>> =
                        items.iter().map(|i| i.prepared.points.clone()).collect();
                    let result = backend.compute(&reqs);
                    let exec_ns = exec_start.elapsed().as_nanos() as u64;
                    Metrics::inc(&exec_metrics.batches);
                    Metrics::add(&exec_metrics.batched_requests, items.len() as u64);
                    exec_metrics.exec_latency.record_ns(exec_ns);
                    match result {
                        Ok(hulls) => {
                            for (item, (upper, lower)) in items.into_iter().zip(hulls) {
                                let queue_ns =
                                    (exec_start - item.enqueued).as_nanos() as u64;
                                if exec_cfg.self_check {
                                    if let Err(e) =
                                        check_upper_hull(&item.prepared.points, &upper)
                                    {
                                        Metrics::inc(&exec_metrics.errors);
                                        let _ = item.reply.send(Err(RequestError::Backend(
                                            format!("self-check failed: {e}"),
                                        )));
                                        continue;
                                    }
                                }
                                Metrics::inc(&exec_metrics.responses);
                                Metrics::add(
                                    &exec_metrics.hull_points_out,
                                    (upper.len() + lower.len()) as u64,
                                );
                                exec_metrics
                                    .e2e_latency
                                    .record(item.enqueued.elapsed());
                                exec_metrics.queue_latency.record_ns(queue_ns);
                                let _ = item.reply.send(Ok(HullResponse {
                                    id: item.prepared.id,
                                    upper,
                                    lower,
                                    backend: backend.name(),
                                    queue_ns,
                                    exec_ns,
                                }));
                            }
                        }
                        Err(e) => {
                            for item in items {
                                Metrics::inc(&exec_metrics.errors);
                                let _ = item
                                    .reply
                                    .send(Err(RequestError::Backend(e.clone())));
                            }
                        }
                    }
                }
            })
            .map_err(|e| e.to_string())?;

        // wait for backend construction before declaring ready
        let (max_points, pref_batch) = ready_rx
            .recv()
            .map_err(|_| "exec thread died during startup".to_string())??;

        let max_batch = if cfg.batcher.max_batch == 0 {
            pref_batch.max(1)
        } else {
            cfg.batcher.max_batch
        };
        let flush_us = cfg.batcher.flush_us;
        let batcher = std::thread::Builder::new()
            .name("hull-batcher".into())
            .spawn(move || run_batcher(submit_rx, batch_tx, max_batch, flush_us))
            .map_err(|e| e.to_string())?;

        Ok(Coordinator {
            submit_tx: Some(submit_tx),
            batcher: Some(batcher),
            exec: Some(exec),
            metrics,
            backend_name: cfg.backend.name(),
            max_points,
            next_id: AtomicU64::new(1),
        })
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend_name
    }

    pub fn max_points(&self) -> usize {
        self.max_points
    }

    /// Allocate a request id (for callers that don't track their own).
    pub fn next_id(&self) -> u64 {
        self.next_id.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
    }

    /// Submit asynchronously; the returned channel yields the response.
    pub fn submit(
        &self,
        req: HullRequest,
    ) -> mpsc::Receiver<Result<HullResponse, RequestError>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        Metrics::inc(&self.metrics.requests);
        Metrics::add(&self.metrics.points_in, req.points.len() as u64);

        let prepared = match prepare(&req) {
            Ok(p) => p,
            Err(e) => {
                Metrics::inc(&self.metrics.errors);
                let _ = reply_tx.send(Err(e));
                return reply_rx;
            }
        };
        if prepared.points.len() > self.max_points {
            Metrics::inc(&self.metrics.errors);
            let _ = reply_tx.send(Err(RequestError::TooLarge {
                points: prepared.points.len(),
                max: self.max_points,
            }));
            return reply_rx;
        }
        if prepared.degenerate {
            // exact fast path: general position violated; compute inline
            let t0 = Instant::now();
            let (upper, lower) = exact_full_hull(&prepared.points);
            Metrics::inc(&self.metrics.degenerate_fallbacks);
            Metrics::inc(&self.metrics.responses);
            Metrics::add(
                &self.metrics.hull_points_out,
                (upper.len() + lower.len()) as u64,
            );
            let exec_ns = t0.elapsed().as_nanos() as u64;
            self.metrics.e2e_latency.record_ns(exec_ns);
            let _ = reply_tx.send(Ok(HullResponse {
                id: prepared.id,
                upper,
                lower,
                backend: "exact",
                queue_ns: 0,
                exec_ns,
            }));
            return reply_rx;
        }

        let item = Item { prepared, enqueued: Instant::now(), reply: reply_tx.clone() };
        if let Some(tx) = &self.submit_tx {
            if tx.send(item).is_err() {
                Metrics::inc(&self.metrics.errors);
                let _ = reply_tx.send(Err(RequestError::Shutdown));
            }
        } else {
            let _ = reply_tx.send(Err(RequestError::Shutdown));
        }
        reply_rx
    }

    /// Synchronous convenience wrapper.
    pub fn compute(&self, points: Vec<Point>) -> Result<HullResponse, RequestError> {
        let req = HullRequest { id: self.next_id(), points };
        self.submit(req)
            .recv()
            .map_err(|_| RequestError::Shutdown)?
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Graceful shutdown: drain queues, join threads.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.submit_tx.take(); // closes the batcher's input
        if let Some(h) = self.batcher.take() {
            let _ = h.join();
        }
        if let Some(h) = self.exec.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::generators::{generate, Distribution};
    use crate::serial::monotone_chain;

    fn coord(kind: BackendKind) -> Coordinator {
        Coordinator::start(CoordinatorConfig {
            backend: kind,
            batcher: BatcherConfig { max_batch: 4, flush_us: 200, queue_cap: 64 },
            self_check: true,
            ..Default::default()
        })
        .unwrap()
    }

    #[test]
    fn native_roundtrip() {
        let c = coord(BackendKind::Native);
        let pts = generate(Distribution::Disk, 100, 1);
        let resp = c.compute(pts.clone()).unwrap();
        let (u, l) = monotone_chain::full_hull(&pts);
        assert_eq!(resp.upper, u);
        assert_eq!(resp.lower, l);
        assert_eq!(resp.backend, "native");
        c.shutdown();
    }

    #[test]
    fn many_concurrent_requests() {
        let c = Arc::new(coord(BackendKind::Native));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                for k in 0..10u64 {
                    let pts =
                        generate(Distribution::ALL[(k % 7) as usize], 20 + k as usize, t * 100 + k);
                    let resp = c.compute(pts.clone()).unwrap();
                    let (u, _) = monotone_chain::full_hull(&pts);
                    assert_eq!(resp.upper, u);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let snap = c.snapshot().0;
        assert_eq!(snap.get("responses").unwrap().as_usize(), Some(40));
        assert_eq!(snap.get("errors").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn pram_backend_serves_on_the_fast_tier_by_default() {
        let c = coord(BackendKind::Pram); // CoordinatorConfig::default => Fast
        let pts = generate(Distribution::Circle, 200, 8);
        let resp = c.compute(pts.clone()).unwrap();
        let (u, l) = monotone_chain::full_hull(&pts);
        assert_eq!(resp.upper, u);
        assert_eq!(resp.lower, l);
        assert_eq!(resp.backend, "pram-fast");
        c.shutdown();
    }

    #[test]
    fn degenerate_goes_exact() {
        let c = coord(BackendKind::Native);
        let pts = vec![
            Point::new(0.5, 0.1),
            Point::new(0.5, 0.9),
            Point::new(0.1, 0.5),
            Point::new(0.9, 0.5),
        ];
        let resp = c.compute(pts).unwrap();
        assert_eq!(resp.backend, "exact");
        assert_eq!(resp.upper.len(), 3);
        let snap = c.snapshot().0;
        assert_eq!(snap.get("degenerate_fallbacks").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn rejects_invalid() {
        let c = coord(BackendKind::Serial);
        assert!(matches!(c.compute(vec![]), Err(RequestError::Empty)));
        assert!(matches!(
            c.compute(vec![Point::new(7.0, 0.0)]),
            Err(RequestError::OutOfRange(0))
        ));
    }

    #[test]
    fn batching_happens() {
        let c = Arc::new(coord(BackendKind::Native));
        // fire a wave of equal-size requests from multiple threads
        let mut handles = Vec::new();
        for t in 0..8u64 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                let pts = generate(Distribution::UniformSquare, 50, t);
                c.compute(pts).unwrap();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let snap = c.snapshot().0;
        let batches = snap.get("batches").unwrap().as_usize().unwrap();
        assert!(batches < 8, "expected batching, got {batches} batches");
    }

    #[test]
    fn shutdown_then_submit_errors() {
        let mut c = coord(BackendKind::Serial);
        c.shutdown_inner();
        let err = c.compute(generate(Distribution::Disk, 10, 1)).unwrap_err();
        assert_eq!(err, RequestError::Shutdown);
    }
}
