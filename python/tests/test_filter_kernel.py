"""L1 correctness: octagon prefilter + tangent-merge kernels.

The prefilter's oracle is *hull preservation*: whatever the filter drops,
the (f64 monotone-chain) hull of the survivors must equal the hull of the
input, boundary points kept.  The tangent kernel's oracle is ref_stage —
the merged block must be the upper hull of the pair's live corners.
Both kernels are additionally pinned pallas ≡ plain-jnp bit-exact.

Unlike test_kernel.py this module does not use hypothesis (tier1's python
step must run on hosts without it) — randomized sweeps are seeded
pytest parametrizations instead.
"""

from __future__ import annotations

import numpy as np
import pytest

import jax.numpy as jnp

from compile.kernels import filter as filter_kernel
from compile.kernels import ref, tangent, wagener

REMOTE = ref.remote_row()

SEEDS = list(range(12))


def sorted_points(rng: np.random.Generator, m: int) -> np.ndarray:
    pts = rng.random((m, 2)).astype(np.float32)
    return pts[np.argsort(pts[:, 0])]


def make_hood(pts: np.ndarray, n: int) -> np.ndarray:
    """n-slot initial hood: pts live-left-justified, REMOTE padded."""
    hood = np.tile(ref.remote_row(), (n, 1))
    hood[: len(pts)] = pts
    return hood


def disk_points(rng: np.random.Generator, m: int) -> np.ndarray:
    """x-sorted f32 points uniform in a disk inscribed in [0, 1]^2 —
    the dense adversary: almost everything is interior."""
    t = rng.uniform(0, 2 * np.pi, m)
    r = 0.5 * np.sqrt(rng.uniform(0, 1, m))
    pts = np.stack([0.5 + r * np.cos(t), 0.5 + r * np.sin(t)], axis=-1)
    pts = pts.astype(np.float32)
    return pts[np.argsort(pts[:, 0], kind="stable")]


def live(block: np.ndarray) -> np.ndarray:
    return block[ref.is_live(block)]


def full_hull_pts(pts: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """(upper, lower) strict hulls of x-sorted unique-coordinate points."""
    neg = pts.copy()
    neg[:, 1] = -neg[:, 1]
    lo = ref.upper_hull(neg)
    lo[:, 1] = -lo[:, 1]
    return ref.upper_hull(pts), lo


def dedup_xsorted(pts: np.ndarray) -> np.ndarray:
    """Keep max-y per x (hull-equivalent input canonicalization for the
    strict-turn ref.upper_hull, which assumes distinct x)."""
    out: list[np.ndarray] = []
    for p in pts:
        if out and out[-1][0] == p[0]:
            if p[1] > out[-1][1]:
                out[-1] = p
            continue
        out.append(p)
    return np.stack(out)


@pytest.mark.parametrize("dense", [False, True])
@pytest.mark.parametrize("seed", SEEDS)
def test_filter_is_hull_preserving(dense, seed):
    rng = np.random.default_rng(seed)
    n = int(rng.choice([64, 256, 1024]))
    m = int(rng.integers(1, min(301, n + 1)))
    pts = disk_points(rng, m) if dense else sorted_points(rng, m)
    pts = dedup_xsorted(pts)  # unique x: one hull representative per x
    block = make_hood(pts, n)
    out = np.asarray(filter_kernel.pallas_filter(jnp.asarray(block)))
    survivors = live(out)
    # tail is REMOTE, survivors left-justified
    np.testing.assert_array_equal(
        out[len(survivors) :], np.tile(REMOTE, (n - len(survivors), 1))
    )
    # survivors are a subsequence of the input (order + bits preserved)
    i = 0
    for p in map(tuple, pts):
        if i < len(survivors) and p == tuple(survivors[i]):
            i += 1
    assert i == len(survivors), "survivors are not a subsequence"
    # hull preservation: upper+lower hulls unchanged
    for got, want in zip(full_hull_pts(survivors), full_hull_pts(pts)):
        np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("dense", [False, True])
@pytest.mark.parametrize("seed", SEEDS[:6])
def test_pallas_filter_equals_jnp_filter(dense, seed):
    rng = np.random.default_rng(seed)
    m = int(rng.integers(1, 201))
    pts = disk_points(rng, m) if dense else sorted_points(rng, m)
    block = jnp.asarray(make_hood(pts, 256))
    np.testing.assert_array_equal(
        np.asarray(filter_kernel.pallas_filter(block)),
        np.asarray(filter_kernel.jnp_filter(block)),
    )


def test_filter_sheds_dense_interior():
    rng = np.random.default_rng(7)
    block = make_hood(disk_points(rng, 4096), 4096)
    out = np.asarray(filter_kernel.pallas_filter(jnp.asarray(block)))
    assert len(live(out)) < 2048, "dense disk input should shed > half"


def test_filter_passthrough_below_min_points():
    rng = np.random.default_rng(8)
    pts = sorted_points(rng, filter_kernel.PREFILTER_MIN_POINTS - 1)
    block = make_hood(pts, 64)
    out = np.asarray(filter_kernel.pallas_filter(jnp.asarray(block)))
    np.testing.assert_array_equal(out, block)


def test_filter_keeps_octagon_boundary_points():
    # unit square + a point ON the bottom edge (kept) + the center
    # (dropped) + interior fill to clear the min-points gate.
    rng = np.random.default_rng(9)
    fill = np.stack(
        [rng.uniform(0.3, 0.7, 40), rng.uniform(0.3, 0.7, 40)], axis=-1
    )
    pts = np.concatenate(
        [
            np.array([[0, 0], [0, 1], [1, 0], [1, 1], [0.5, 0], [0.5, 0.5]]),
            fill,
        ]
    ).astype(np.float32)
    pts = pts[np.argsort(pts[:, 0], kind="stable")]
    block = make_hood(pts, 64)
    out = np.asarray(filter_kernel.pallas_filter(jnp.asarray(block)))
    kept = {tuple(p) for p in live(out)}
    assert (0.5, 0.0) in kept, "boundary point must be kept"
    assert (0.5, 0.5) not in kept, "center must be dropped"
    for c in [(0, 0), (0, 1), (1, 0), (1, 1)]:
        assert c in kept


def test_filter_all_collinear_passthrough():
    # every point on one line: the octagon is degenerate (< 3 distinct
    # corners) — the filter must pass everything through untouched.
    x = np.linspace(0, 1, 48, dtype=np.float32)
    pts = np.stack([x, x * np.float32(0.5)], axis=-1)
    block = make_hood(pts, 64)
    out = np.asarray(filter_kernel.pallas_filter(jnp.asarray(block)))
    np.testing.assert_array_equal(out, block)


# ---------------------------------------------------------------- tangent


def chain_pair_block(
    rng: np.random.Generator, d: int, lo_x: float, hi_x: float
) -> np.ndarray:
    """A [H(L) | H(R)] block: two x-disjoint upper chains, d slots each."""

    def chain(a: float, b: float) -> np.ndarray:
        m = rng.integers(1, d + 1)
        pts = np.stack(
            [rng.uniform(a, b, m), rng.uniform(0, 1, m)], axis=-1
        ).astype(np.float32)
        pts = dedup_xsorted(pts[np.argsort(pts[:, 0], kind="stable")])
        return ref.upper_hull(pts)

    left = chain(lo_x, (lo_x + hi_x) / 2 - 0.02)
    right = chain((lo_x + hi_x) / 2 + 0.02, hi_x)
    return np.concatenate([ref.pad_block(left, d), ref.pad_block(right, d)])


@pytest.mark.parametrize("d", [4, 8, 16, 64])
@pytest.mark.parametrize("seed", SEEDS[:6])
def test_tangent_merge_matches_ref_stage(d, seed):
    rng = np.random.default_rng(seed)
    blocks = np.stack(
        [chain_pair_block(rng, d, 0.0, 1.0) for _ in range(2)]
    )
    got = np.asarray(tangent.pallas_tangent(jnp.asarray(blocks)))
    for row_got, row_in in zip(got, blocks):
        np.testing.assert_array_equal(row_got, ref.ref_stage(row_in, d))


@pytest.mark.parametrize("seed", SEEDS[:6])
def test_pallas_tangent_equals_jnp_tangent(seed):
    rng = np.random.default_rng(seed)
    blocks = jnp.asarray(
        np.stack([chain_pair_block(rng, 16, 0.0, 1.0) for _ in range(2)])
    )
    np.testing.assert_array_equal(
        np.asarray(tangent.pallas_tangent(blocks)),
        np.asarray(tangent.jnp_tangent(blocks)),
    )


def test_tangent_mirrored_lower_round_trip():
    # The serving contract: row 0 carries the upper-chain pair, row 1 the
    # y-negated lower-chain pair — one upload merges a full hull ⊕ hull.
    # Un-mirroring row 1 of the output must give the merged LOWER hull of
    # the union, computed here directly from the raw point clouds.
    rng = np.random.default_rng(11)
    d = 16

    def cloud(a: float, b: float) -> np.ndarray:
        m = rng.integers(2, d + 1)
        pts = np.stack(
            [rng.uniform(a, b, m), rng.uniform(0, 1, m)], axis=-1
        ).astype(np.float32)
        return dedup_xsorted(pts[np.argsort(pts[:, 0], kind="stable")])

    def neg(p: np.ndarray) -> np.ndarray:
        q = p.copy()
        q[:, 1] = -q[:, 1]
        return q

    a, b = cloud(0.0, 0.48), cloud(0.52, 1.0)
    union = np.concatenate([a, b])
    row0 = np.concatenate(
        [ref.pad_block(ref.upper_hull(a), d), ref.pad_block(ref.upper_hull(b), d)]
    )
    row1 = np.concatenate(
        [
            ref.pad_block(ref.upper_hull(neg(a)), d),
            ref.pad_block(ref.upper_hull(neg(b)), d),
        ]
    )
    got = np.asarray(tangent.pallas_tangent(jnp.asarray(np.stack([row0, row1]))))
    np.testing.assert_array_equal(live(got[0]), ref.upper_hull(union))
    np.testing.assert_array_equal(
        neg(live(got[1])), neg(ref.upper_hull(neg(union)))
    )


def test_tangent_empty_right_half_passthrough():
    d = 8
    rng = np.random.default_rng(12)
    blk = chain_pair_block(rng, d, 0.0, 1.0)
    blk[d:] = REMOTE  # Q half empty: merged hood is H(P) verbatim
    blocks = np.stack([blk, blk])
    got = np.asarray(tangent.pallas_tangent(jnp.asarray(blocks)))
    np.testing.assert_array_equal(got[0], blk)


def test_stage_dims_match_wagener():
    for d in (2, 4, 8, 16, 64):
        assert wagener.stage_dims(d)[0] * wagener.stage_dims(d)[1] == d
