//! The engine: N independent coordinator shards behind one facade.
//!
//! PR 3/PR 4 left two process-wide chokepoints on the serving path: every
//! one-shot request funnels through a single batcher thread + one shared
//! `Mutex<Receiver>` exec channel, and every session op goes through one
//! global registry map lock.  The engine removes both by *partitioning the
//! serving state* — the same divide-and-conquer move the hull pipeline
//! itself makes, lifted one level up:
//!
//! ```text
//! callers ──► [Engine router] ──► shard 0: batcher ─► exec pool ─► metrics
//!                 │                        └ SessionRegistry slice
//!                 ├────────────► shard 1: batcher ─► exec pool ─► metrics
//!                 │                        └ SessionRegistry slice
//!                 └────────────► …  (N fully independent shards)
//! ```
//!
//! * **One-shot requests** route to the cheapest queue (fewest in-flight
//!   requests, round-robin tie-break) — shards share nothing, so N shards
//!   means N batchers and N exec channels with no cross-shard locks.
//! * **Session verbs** route by a stable function of the sid: shard `i`
//!   of `N` allocates sids `≡ i+1 (mod N)` (see
//!   [`SessionRegistry::new_striped`]), and `(sid - 1) % N` sends every
//!   later verb back to the owning shard, so a session is pinned to one
//!   shard — one registry slice, one backend pool, one metrics sink — for
//!   its whole lifetime.  Eviction, capacity and accounting are all
//!   per-shard; the global `max_sessions` cap is split across shards
//!   remainder-aware (`M/N + 1` for the first `M mod N` shards).
//! * **STATS** merges one coherent [`MetricsFrame`] per shard — counters
//!   and gauges sum, histograms merge bucket-wise — and also reports the
//!   raw `per_shard` array.  Each gauge is read once per shard, so the
//!   aggregate can never pair reads from two different moments.
//!
//! A 1-shard engine is bit- and protocol-identical to the pre-engine
//! server: same coordinator, same registry, same wire bytes — the entire
//! pre-existing integration suite runs unmodified against it.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use crate::coordinator::{
    BackendKind, Coordinator, CoordinatorConfig, HullReply, HullRequest, HullResponse,
    IoMetrics, Metrics, MetricsFrame, MetricsSnapshot, RequestError,
};
use crate::geometry::point::Point;
use crate::stream::{
    AddOutcome, SessionError, SessionHullSnapshot, SessionRegistry, StreamConfig,
};
use crate::util::json::Json;

/// Engine configuration (config file: `[engine]`).
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// coordinator-shard count; 0 = auto.  Auto resolves to 1 for the
    /// `pjrt` backend (each shard's workers load the artifact registry —
    /// multiplying loaders must be an explicit choice, the PR 3 worker
    /// rule one level up) and to `clamp(hw_threads / 4, 1, 8)` for host
    /// backends (each shard carries a batcher thread + worker pool, so
    /// shards beyond a fraction of the machine only add switching).
    pub shards: usize,
    /// per-shard coordinator template.  `workers == 0` (auto) splits the
    /// hardware threads across shards (`max(1, hw / shards)` each) instead
    /// of letting every shard claim the whole machine.
    pub coordinator: CoordinatorConfig,
    /// stream knobs; `max_sessions` is the GLOBAL cap, split across
    /// shards remainder-aware.
    pub stream: StreamConfig,
    /// admission ceiling per shard (config: `[engine] max_queued`,
    /// 0 = unbounded): a shard with this many requests in flight stops
    /// admitting; when every healthy shard is at its ceiling new one-shot
    /// requests and `SADD`s answer `overloaded` immediately instead of
    /// queueing (load shedding — see `shed_total`).
    pub max_queued: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            shards: 1,
            coordinator: CoordinatorConfig::default(),
            stream: StreamConfig::default(),
            max_queued: 0,
        }
    }
}

impl EngineConfig {
    /// Shard count for tests/tools honoring the `ENGINE_SHARDS`
    /// environment variable (tier1 exports `ENGINE_SHARDS=4` to run the
    /// server integration suite against a sharded engine).
    pub fn shards_from_env(default: usize) -> usize {
        std::env::var("ENGINE_SHARDS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .unwrap_or(default)
    }

    /// Resolve `shards` (0 = auto; see the field docs for the rule).
    pub fn effective_shards(&self) -> usize {
        if self.shards > 0 {
            self.shards
        } else if self.coordinator.backend == BackendKind::Pjrt {
            1
        } else {
            let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            (hw / 4).clamp(1, 8)
        }
    }
}

/// One shard: a complete coordinator (own batcher, own exec pool, own
/// metrics sink) plus its slice of the session space.
struct Shard {
    coordinator: Arc<Coordinator>,
    registry: Arc<SessionRegistry>,
}

/// Facade over `N` independent coordinator shards.
pub struct Engine {
    shards: Vec<Shard>,
    /// round-robin cursor: rotates the starting shard of the
    /// cheapest-queue scan so equal-load shards alternate.
    rr: AtomicUsize,
    /// the global session cap (sum of the per-shard slices).
    max_sessions_total: usize,
    max_points: usize,
    /// per-shard admission ceiling (0 = unbounded).
    max_queued: usize,
}

impl Engine {
    /// Build and start `N` shards.  Fails if any shard's backend pool
    /// cannot be constructed; already-started shards shut down on drop.
    pub fn start(cfg: EngineConfig) -> Result<Engine, String> {
        let n = cfg.effective_shards();
        let mut shard_cfg = cfg.coordinator.clone();
        if shard_cfg.workers == 0 && n > 1 && shard_cfg.backend != BackendKind::Pjrt {
            // auto workers must split the machine across shards: N shards
            // each auto-sizing to every hardware thread would book N× the
            // cores.  (pjrt auto already resolves to 1 per shard.)
            let hw = std::thread::available_parallelism().map(|h| h.get()).unwrap_or(1);
            shard_cfg.workers = (hw / n).max(1);
        }
        let mut coordinators = Vec::with_capacity(n);
        for _ in 0..n {
            coordinators.push(Arc::new(Coordinator::start(shard_cfg.clone())?));
        }
        let max_points =
            coordinators.iter().map(|c| c.max_points()).min().unwrap_or(usize::MAX);
        // the same brick-proofing rule serve() applies: a threshold above
        // the backend's request cap could never merge
        let stream = cfg.stream.clamp_threshold_to(max_points);
        let shards = coordinators
            .into_iter()
            .enumerate()
            .map(|(i, coordinator)| {
                let slice = StreamConfig {
                    // remainder-aware split: shard i gets M/N, +1 for the
                    // first M mod N shards, so the slices sum to exactly M
                    max_sessions: stream.max_sessions / n
                        + usize::from(i < stream.max_sessions % n),
                    ..stream.clone()
                };
                let registry = Arc::new(SessionRegistry::new_striped(
                    slice,
                    coordinator.metrics.clone(),
                    i as u64 + 1,
                    n as u64,
                ));
                Shard { coordinator, registry }
            })
            .collect();
        Ok(Engine {
            shards,
            rr: AtomicUsize::new(0),
            max_sessions_total: stream.max_sessions,
            max_points,
            max_queued: cfg.max_queued,
        })
    }

    /// Wrap an already-built coordinator + registry as a 1-shard engine —
    /// the compatibility path behind [`crate::server::serve`] /
    /// [`crate::server::serve_with_sessions`], and the reason the whole
    /// pre-engine test suite keeps passing byte-for-byte.
    pub fn single(coordinator: Arc<Coordinator>, registry: Arc<SessionRegistry>) -> Engine {
        let max_points = coordinator.max_points();
        let max_sessions_total = registry.max_sessions();
        Engine {
            shards: vec![Shard { coordinator, registry }],
            rr: AtomicUsize::new(0),
            max_sessions_total,
            max_points,
            max_queued: 0,
        }
    }

    // ------------------------------------------------------------ routing

    /// Admission-controlled shard choice for one-shot work.  Cheapest
    /// queue wins (fewest in-flight requests, round-robin rotated start
    /// so ties alternate), with two rejection layers on top:
    ///
    /// * shards whose circuit breaker is open are skipped — except that
    ///   the first caller after the cooldown is routed in as the
    ///   half-open probe;
    /// * shards at the `max_queued` ceiling are skipped (sibling shards
    ///   absorb the spill); when every healthy shard is at its ceiling
    ///   the request is shed with `overloaded`.
    ///
    /// The in-flight counts are relaxed reads — a stale value only
    /// softens the balance, never correctness.
    fn route_one_shot(&self) -> Result<&Shard, RequestError> {
        let n = self.shards.len();
        let start =
            if n == 1 { 0 } else { self.rr.fetch_add(1, Ordering::Relaxed) % n };
        let mut best: Option<(usize, u64)> = None;
        let mut any_healthy = false;
        for k in 0..n {
            let i = (start + k) % n;
            let c = &self.shards[i].coordinator;
            if c.breaker().blocked() {
                continue;
            }
            if c.breaker().state() == 2 {
                // this caller just flipped the breaker open → half-open:
                // its request IS the probe, ceiling notwithstanding
                return Ok(&self.shards[i]);
            }
            any_healthy = true;
            let load = c.metrics.in_flight();
            if self.max_queued != 0 && load >= self.max_queued as u64 {
                continue; // at ceiling: let a sibling absorb it
            }
            let better = match best {
                None => true,
                Some((_, b)) => load < b,
            };
            if better {
                best = Some((i, load));
            }
        }
        match best {
            Some((i, _)) => Ok(&self.shards[i]),
            None if any_healthy => {
                // every healthy shard is at its ceiling: shed, charged to
                // the scan's starting shard (merged STATS sum per-shard)
                Metrics::inc(&self.shards[start].coordinator.metrics.shed);
                Err(RequestError::Overloaded)
            }
            None => Err(RequestError::Backend("circuit breaker open".into())),
        }
    }

    /// The shard a sid is pinned to for its lifetime: `(sid - 1) % N`
    /// inverts the striped allocation.  Unknown sids (including 0, never
    /// allocated) still land deterministically on some shard, which
    /// answers `unknown-session` exactly like a standalone registry.
    fn shard_for_sid(&self, sid: u64) -> &Shard {
        let n = self.shards.len() as u64;
        &self.shards[(sid.wrapping_sub(1) % n) as usize]
    }

    // ----------------------------------------------------------- one-shot

    /// Submit a one-shot request to the cheapest admitting shard; the
    /// returned channel yields the response (immediately `overloaded`
    /// when every healthy shard is at its ceiling).
    pub fn submit(
        &self,
        req: HullRequest,
    ) -> mpsc::Receiver<Result<HullResponse, RequestError>> {
        let (tx, rx) = mpsc::channel();
        self.submit_with(req, HullReply::Channel(tx));
        rx
    }

    /// Submit a one-shot request with an explicit reply destination
    /// (see [`Coordinator::submit_with`]).  Admission rejections
    /// (`overloaded`, circuit-broken `backend`) answer through `reply`
    /// on the calling thread.
    pub fn submit_with(&self, req: HullRequest, reply: HullReply) {
        match self.route_one_shot() {
            Ok(shard) => shard.coordinator.submit_with(req, reply),
            Err(e) => reply.send(Err(e)),
        }
    }

    /// Non-blocking submit for the event-loop server: `f` runs on
    /// whichever thread completes the request — never parks the caller.
    pub fn submit_into(
        &self,
        req: HullRequest,
        f: impl FnOnce(Result<HullResponse, RequestError>) + Send + 'static,
    ) {
        self.submit_with(req, HullReply::sink(f));
    }

    /// Synchronous one-shot convenience wrapper.
    pub fn compute(&self, points: Vec<Point>) -> Result<HullResponse, RequestError> {
        self.route_one_shot()?.coordinator.compute(points)
    }

    // ----------------------------------------------------------- sessions

    /// `SOPEN`: place the session on the shard with the most free
    /// capacity (ties broken by shard order), falling back through the
    /// rest; only when every shard is full does the global cap error
    /// surface.  The returned sid routes all later verbs to that shard.
    pub fn session_open(&self) -> Result<u64, SessionError> {
        if self.shards.len() == 1 {
            return self.shards[0].registry.open();
        }
        let mut order: Vec<usize> = (0..self.shards.len()).collect();
        order.sort_by_key(|&i| {
            let r = &self.shards[i].registry;
            std::cmp::Reverse(r.max_sessions().saturating_sub(r.open_sessions()))
        });
        for i in order {
            match self.shards[i].registry.open() {
                Ok(sid) => return Ok(sid),
                Err(SessionError::Capacity { .. }) => continue,
                Err(e) => return Err(e),
            }
        }
        Err(SessionError::Capacity { max: self.max_sessions_total })
    }

    /// `SADD` on the owning shard (its registry, its backend pool).
    pub fn session_add(&self, sid: u64, points: &[Point]) -> Result<AddOutcome, SessionError> {
        self.session_add_deadline(sid, points, None)
    }

    /// [`Engine::session_add`] with the request's deadline: an `SADD`
    /// whose budget already expired answers `deadline-exceeded` without
    /// touching the session, and a pinned shard at its admission ceiling
    /// answers `overloaded` (sessions cannot spill to siblings — the sid
    /// owns its shard — so the ceiling sheds instead of rerouting).
    /// Neither rejection counts into `errors`: the request never entered
    /// the coordinator pipeline, so `in_flight` must not be disturbed.
    pub fn session_add_deadline(
        &self,
        sid: u64,
        points: &[Point],
        deadline: Option<Instant>,
    ) -> Result<AddOutcome, SessionError> {
        let shard = self.shard_for_sid(sid);
        if deadline.is_some_and(|d| Instant::now() >= d) {
            Metrics::inc(&shard.coordinator.metrics.deadline_exceeded);
            return Err(SessionError::Request(RequestError::DeadlineExceeded));
        }
        if self.max_queued != 0
            && shard.coordinator.metrics.in_flight() >= self.max_queued as u64
        {
            Metrics::inc(&shard.coordinator.metrics.shed);
            return Err(SessionError::Request(RequestError::Overloaded));
        }
        shard.registry.add(sid, points, &*shard.coordinator)
    }

    /// `SHULL` on the owning shard (flushes pending first).
    pub fn session_hull(&self, sid: u64) -> Result<SessionHullSnapshot, SessionError> {
        let shard = self.shard_for_sid(sid);
        shard.registry.hull(sid, &*shard.coordinator)
    }

    /// `SCLOSE` on the owning shard.
    pub fn session_close(&self, sid: u64) -> Result<(), SessionError> {
        self.shard_for_sid(sid).registry.close(sid)
    }

    /// Open sessions across every shard.
    pub fn open_sessions(&self) -> usize {
        self.shards.iter().map(|s| s.registry.open_sessions()).sum()
    }

    /// Run one eviction sweep on every shard (tests; each shard's own
    /// sweeper thread does this on its interval).
    pub fn sweep_now(&self) {
        for s in &self.shards {
            s.registry.sweep_now();
        }
    }

    // ------------------------------------------------------------ metrics

    /// Merged metrics: one coherent [`MetricsFrame`] per shard, summed
    /// once (counters and gauges sum, histograms merge bucket-wise), plus
    /// the raw `per_shard` array and the shard count.  For shards = 1 the
    /// top-level fields equal the lone coordinator's own snapshot.
    pub fn snapshot(&self) -> MetricsSnapshot {
        self.stats(None)
    }

    /// [`Engine::snapshot`] with the server's connection gauge spliced in
    /// (`active_connections` is engine-global — connections are not
    /// sharded — and read exactly once).
    pub fn stats(&self, active_connections: Option<u64>) -> MetricsSnapshot {
        self.stats_io(active_connections, None)
    }

    /// [`Engine::stats`] with the event-loop server's I/O gauges spliced
    /// in under the `io` key (per-loop connection counts, bytes in/out,
    /// frame counters, decode latency, backpressure stalls).
    pub fn stats_io(
        &self,
        active_connections: Option<u64>,
        io: Option<&IoMetrics>,
    ) -> MetricsSnapshot {
        let frames: Vec<MetricsFrame> =
            self.shards.iter().map(|s| s.coordinator.metrics.frame()).collect();
        let mut merged = MetricsFrame::default();
        for f in &frames {
            merged.merge(f);
        }
        let Json::Obj(mut obj) = merged.to_json() else { unreachable!("frame json is an object") };
        obj.insert("shards".into(), Json::Num(self.shards.len() as f64));
        obj.insert(
            "per_shard".into(),
            Json::Arr(frames.iter().map(MetricsFrame::to_json).collect()),
        );
        if let Some(active) = active_connections {
            obj.insert("active_connections".into(), Json::Num(active as f64));
        }
        if let Some(io) = io {
            obj.insert("io".into(), io.to_json());
        }
        MetricsSnapshot(Json::Obj(obj))
    }

    // ---------------------------------------------------------- topology

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Shard `i`'s coordinator (tests, benches, affinity checks).
    pub fn shard_coordinator(&self, i: usize) -> &Arc<Coordinator> {
        &self.shards[i].coordinator
    }

    /// Shard `i`'s registry slice (tests, benches, affinity checks).
    pub fn shard_registry(&self, i: usize) -> &Arc<SessionRegistry> {
        &self.shards[i].registry
    }

    pub fn backend_name(&self) -> &'static str {
        self.shards[0].coordinator.backend_name()
    }

    /// The per-request point cap (min across shards; they are identical
    /// when built by [`Engine::start`]).
    pub fn max_points(&self) -> usize {
        self.max_points
    }

    /// Global session cap (sum of the per-shard slices).
    pub fn max_sessions(&self) -> usize {
        self.max_sessions_total
    }

    /// Effective (possibly clamped) merge threshold.
    pub fn merge_threshold(&self) -> usize {
        self.shards[0].registry.merge_threshold()
    }

    /// Exec workers per shard.
    pub fn workers_per_shard(&self) -> usize {
        self.shards[0].coordinator.workers()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::generators::{generate, Distribution};

    fn engine(shards: usize, max_sessions: usize) -> Engine {
        engine_queued(shards, max_sessions, 0)
    }

    fn engine_queued(shards: usize, max_sessions: usize, max_queued: usize) -> Engine {
        Engine::start(EngineConfig {
            shards,
            coordinator: CoordinatorConfig {
                backend: BackendKind::Serial,
                workers: 1,
                ..Default::default()
            },
            stream: StreamConfig { max_sessions, idle_ttl_ms: 0, ..Default::default() },
            max_queued,
        })
        .unwrap()
    }

    #[test]
    fn capacity_splits_remainder_aware() {
        let e = engine(4, 10); // 10 = 3 + 3 + 2 + 2
        let per: Vec<usize> = (0..4).map(|i| e.shard_registry(i).max_sessions()).collect();
        assert_eq!(per, vec![3, 3, 2, 2]);
        assert_eq!(per.iter().sum::<usize>(), 10);
        assert_eq!(e.max_sessions(), 10);
    }

    #[test]
    fn global_cap_enforced_across_shards() {
        let e = engine(4, 2); // shards 2 and 3 get zero capacity
        let a = e.session_open().unwrap();
        let b = e.session_open().unwrap();
        let err = e.session_open().unwrap_err();
        assert_eq!(err, SessionError::Capacity { max: 2 });
        assert_eq!(err.to_string(), "session capacity 2 reached");
        e.session_close(a).unwrap();
        e.session_open().unwrap();
        let _ = b;
    }

    #[test]
    fn sids_route_back_to_their_allocating_shard() {
        let e = engine(4, 100);
        let mut owned = [0usize; 4];
        for _ in 0..12 {
            let before: Vec<usize> =
                (0..4).map(|i| e.shard_registry(i).open_sessions()).collect();
            let sid = e.session_open().unwrap();
            let owner = ((sid - 1) % 4) as usize;
            owned[owner] += 1;
            // exactly the sid-residue shard gained a session
            for (i, b) in before.iter().enumerate() {
                let now = e.shard_registry(i).open_sessions();
                assert_eq!(now, b + usize::from(i == owner), "sid {sid} shard {i}");
            }
            e.session_add(sid, &[crate::geometry::point::Point::new(0.25, 0.75)])
                .unwrap();
        }
        assert_eq!(e.open_sessions(), 12);
        // balanced placement spreads the 12 sessions across all 4 shards
        assert_eq!(owned, [3, 3, 3, 3]);
    }

    #[test]
    fn one_shot_routing_spreads_and_answers_exactly() {
        let e = engine(3, 8);
        for k in 0..9u64 {
            let pts = generate(Distribution::ALL[(k % 7) as usize], 40 + k as usize, k);
            let resp = e.compute(pts.clone()).unwrap();
            let (u, l) = crate::serial::monotone_chain::full_hull(&pts);
            assert_eq!(resp.upper, u);
            assert_eq!(resp.lower, l);
        }
        // merged totals account for every request exactly once
        let snap = e.snapshot().0;
        assert_eq!(snap.get("responses").unwrap().as_usize(), Some(9));
        assert_eq!(snap.get("errors").unwrap().as_usize(), Some(0));
        let per = snap.get("per_shard").unwrap().as_arr().unwrap();
        assert_eq!(per.len(), 3);
        let spread: usize = per
            .iter()
            .map(|s| s.get("responses").unwrap().as_usize().unwrap())
            .sum();
        assert_eq!(spread, 9);
        assert_eq!(snap.get("shards").unwrap().as_usize(), Some(3));
    }

    #[test]
    fn single_wraps_existing_parts_unchanged() {
        let coord = Arc::new(
            Coordinator::start(CoordinatorConfig {
                backend: BackendKind::Serial,
                workers: 1,
                ..Default::default()
            })
            .unwrap(),
        );
        let reg = Arc::new(SessionRegistry::new(
            StreamConfig { max_sessions: 5, idle_ttl_ms: 0, ..Default::default() },
            coord.metrics.clone(),
        ));
        let e = Engine::single(coord, reg);
        assert_eq!(e.shard_count(), 1);
        assert_eq!(e.max_sessions(), 5);
        let sid = e.session_open().unwrap();
        assert_eq!(sid, 1); // stride-1 allocation, exactly the old registry
        e.session_close(sid).unwrap();
    }

    // ------------------------------------------------ admission control

    /// Simulate load by bumping the raw `requests` counter (in_flight =
    /// requests − responses − errors, all relaxed atomics) — fully
    /// deterministic, no racing against real workers.
    fn fake_in_flight(e: &Engine, shard: usize, n: u64) {
        Metrics::add(&e.shard_coordinator(shard).metrics.requests, n);
    }

    fn drain_fake(e: &Engine, shard: usize, n: u64) {
        Metrics::add(&e.shard_coordinator(shard).metrics.responses, n);
    }

    #[test]
    fn overload_sheds_with_typed_error() {
        let e = engine_queued(1, 4, 2);
        fake_in_flight(&e, 0, 2); // at the ceiling
        let pts = generate(Distribution::Disk, 40, 1);
        let err = e.compute(pts.clone()).unwrap_err();
        assert_eq!(err, RequestError::Overloaded);
        assert_eq!(err.to_string(), "overloaded");
        let snap = e.snapshot().0;
        assert_eq!(snap.get("shed_total").unwrap().as_usize(), Some(1));
        // shed requests never entered the pipeline: no error counted
        assert_eq!(snap.get("errors").unwrap().as_usize(), Some(0));
        drain_fake(&e, 0, 2); // load drains: admission resumes
        e.compute(pts).unwrap();
    }

    #[test]
    fn ceiling_spills_to_sibling_shard_first() {
        let e = engine_queued(2, 4, 1);
        fake_in_flight(&e, 0, 1); // shard 0 full, shard 1 idle
        for k in 0..4u64 {
            e.compute(generate(Distribution::Disk, 30 + k as usize, k)).unwrap();
        }
        let shard1 = e.shard_coordinator(1).metrics.frame();
        assert_eq!(shard1.responses, 4, "all traffic must spill to the idle sibling");
        assert_eq!(e.snapshot().0.get("shed_total").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn tripped_breaker_diverts_then_recovers_via_probe() {
        let e = engine_queued(2, 4, 0);
        // trip shard 0's breaker (3 consecutive batch failures)
        for _ in 0..3 {
            e.shard_coordinator(0).breaker().on_failure();
        }
        assert_eq!(e.shard_coordinator(0).breaker().state(), 1);
        for k in 0..4u64 {
            e.compute(generate(Distribution::Disk, 25 + k as usize, k)).unwrap();
        }
        assert_eq!(
            e.shard_coordinator(1).metrics.frame().responses,
            4,
            "open breaker must divert everything to the healthy shard"
        );
        // cooldown default is 1s — too long for a test; force-expire by
        // the only supported path: a successful probe closes the breaker
        e.shard_coordinator(0).breaker().on_success();
        assert_eq!(e.shard_coordinator(0).breaker().state(), 0);
    }

    #[test]
    fn all_shards_broken_answers_backend_error() {
        let e = engine_queued(1, 4, 0);
        for _ in 0..3 {
            e.shard_coordinator(0).breaker().on_failure();
        }
        let err = e.compute(generate(Distribution::Disk, 30, 2)).unwrap_err();
        assert!(matches!(err, RequestError::Backend(_)), "got {err:?}");
    }

    #[test]
    fn session_add_sheds_and_honors_deadline() {
        let e = engine_queued(1, 4, 1);
        let sid = e.session_open().unwrap();
        let pts = [crate::geometry::point::Point::new(0.25, 0.75)];
        // expired budget: typed deadline-exceeded, session untouched
        let err = e
            .session_add_deadline(sid, &pts, Some(Instant::now()))
            .unwrap_err();
        assert_eq!(err.to_string(), "deadline-exceeded");
        // shard at ceiling: typed overloaded
        fake_in_flight(&e, 0, 1);
        let err = e.session_add_deadline(sid, &pts, None).unwrap_err();
        assert_eq!(err.to_string(), "overloaded");
        assert_eq!(e.snapshot().0.get("shed_total").unwrap().as_usize(), Some(1));
        // load drains: the add lands
        drain_fake(&e, 0, 1);
        e.session_add(sid, &pts).unwrap();
        e.session_close(sid).unwrap();
    }

    #[test]
    fn effective_shards_auto_rules() {
        let pjrt = EngineConfig {
            shards: 0,
            coordinator: CoordinatorConfig {
                backend: BackendKind::Pjrt,
                ..Default::default()
            },
            ..Default::default()
        };
        assert_eq!(pjrt.effective_shards(), 1, "pjrt auto-resolves to one shard");
        let host = EngineConfig { shards: 0, ..Default::default() };
        let n = host.effective_shards();
        assert!((1..=8).contains(&n), "host auto in [1, 8]: {n}");
        let explicit = EngineConfig { shards: 6, ..Default::default() };
        assert_eq!(explicit.effective_shards(), 6);
    }
}
