//! Serial hull baselines — the "another serial program (not described
//! here)" the paper compares against in its Conclusions, plus classic
//! alternatives so E4 can show where each baseline sits.

pub mod gift_wrapping;
pub mod graham;
pub mod hood;
pub mod monotone_chain;
pub mod quickhull;

pub use monotone_chain::{full_hull, lower_hull, upper_hull};
