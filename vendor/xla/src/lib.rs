//! Offline stub of the `xla` (xla_extension 0.5.1) PJRT bindings.
//!
//! The native XLA/PJRT toolchain is not present in this build
//! environment, so this crate mirrors the API surface the repository
//! uses and fails — cleanly, at *client construction* — when the pjrt
//! backend is requested.  Everything downstream (coordinator, server,
//! tests) already treats "backend failed to build" as a first-class
//! outcome, and the artifact-dependent test suites skip when
//! `HullExecutor::new` errors.  Swap the real `xla` crate back into the
//! root Cargo.toml to enable the pjrt path; no call site changes.

use std::fmt;
use std::path::Path;

/// The stub's only error: "runtime unavailable" (plus a hint).
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn unavailable() -> Error {
        Error(
            "XLA/PJRT runtime unavailable: built against the vendored stub \
             (vendor/xla); install the native xla_extension crate to enable \
             the pjrt backend"
                .to_string(),
        )
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable())
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Err(Error::unavailable())
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable())
    }
}

/// Device buffer (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable())
    }
}

/// Host literal (stub: shape-less placeholder).
#[derive(Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1(_values: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable())
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(Error::unavailable())
    }

    pub fn to_tuple2(&self) -> Result<(Literal, Literal)> {
        Err(Error::unavailable())
    }
}
