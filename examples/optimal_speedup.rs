//! Experiment E5: the paper's §3 optimal-speedup sketch, measured.
//!
//! Compares total *work* (the PRAM currency) of the standard Wagener
//! pipeline against the strip + Overmars–van-Leeuwen variant:
//!   standard:  Θ(n log n) PE-operations (measured from the simulator)
//!   optimal:   Θ(n) strip work + polylog tangent work per merge
//!
//! ```bash
//! cargo run --release --example optimal_speedup
//! ```

use wagener_hull::geometry::generators::{generate, Distribution};
use wagener_hull::ovl;
use wagener_hull::serial::monotone_chain;
use wagener_hull::wagener;

fn main() {
    println!("== E5: standard Wagener vs optimal-speedup variant (paper §3) ==");
    println!("workload: parabola (every point on the hull — worst case for merges)\n");
    println!(
        "{:>7} | {:>12} {:>9} | {:>10} {:>12} {:>10} | {:>7}",
        "n", "std-work", "n·log2 n", "strip-work", "tangent-evals", "opt-total", "ratio"
    );
    for &n in &[256usize, 1024, 4096, 16384] {
        let pts = generate(Distribution::Parabola, n, 5);

        // standard pipeline work: PE activations on the PRAM simulator
        // (non-strict: large dense curves can carry residual collinear
        // triples; the work counters are what we need here)
        let run = wagener::pram_exec::run_pipeline_with(&pts, n, false).unwrap();
        let std_work = run.counters.work;

        // optimal variant: strips of log^2 n + tree merges
        let opt = ovl::optimal_upper_hull(&pts, 0);
        assert_eq!(opt.hull, monotone_chain::upper_hull(&pts));
        let nlogn = n as f64 * (n as f64).log2();

        println!(
            "{:>7} | {:>12} {:>9.0} | {:>10} {:>13} {:>10} | {:>6.1}x",
            n,
            std_work,
            nlogn,
            opt.stats.strip_work,
            opt.stats.tangent_predicate_evals,
            opt.stats.total(),
            std_work as f64 / opt.stats.total() as f64,
        );
    }

    println!("\nstrip-length ablation at n = 16384 (paper picks log²n):");
    let n = 16384;
    let pts = generate(Distribution::Parabola, n, 5);
    println!("{:>10} {:>10} {:>14} {:>12}", "strip", "strips", "tangent-evals", "total-work");
    for strip in [16usize, 64, ovl::optimal::default_strip_len(n), 1024, 4096] {
        let opt = ovl::optimal_upper_hull(&pts, strip);
        println!(
            "{:>10} {:>10} {:>14} {:>12}",
            strip,
            opt.stats.strips,
            opt.stats.tangent_predicate_evals,
            opt.stats.total()
        );
    }
    println!(
        "\nthe work ratio grows ≈ log n, matching the paper's claim that the\n\
         strip variant removes the log-factor of work overhead."
    );
}
