//! Network front-end: two wire protocols (line-oriented text and
//! length-prefixed binary, auto-detected per connection on the first
//! byte), two connection cores (the readiness-driven event loop on unix,
//! a thread-per-connection compatibility shim everywhere), and a blocking
//! client used by the examples, benches, CLI and integration tests.
//!
//! Both cores funnel every verb through the response builders at the
//! bottom of this module, so the `{text,binary} x {threaded,event-loop}`
//! matrix produces identical responses by construction — the protocol
//! parity suite (`rust/tests/proto_parity.rs`) checks the product.

pub mod client;
#[cfg(unix)]
pub(crate) mod event_loop;
pub mod frame;
pub mod proto;
#[cfg(unix)]
pub(crate) mod sys;
pub mod tcp;

pub use client::{HullClient, SessionAddReply, SessionHullReply, WireProto};
pub use proto::{Request, Response, SessionVerb};
#[cfg(unix)]
pub use sys::{nofile_limit, raise_nofile_limit};

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::{Coordinator, HullResponse, RequestError};
use crate::engine::Engine;
use crate::geometry::point::Point;
use crate::stream::{SessionRegistry, StreamConfig};

use proto::ProtoError;

/// Server knobs (config file: `[server]`).
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// bind address, e.g. "127.0.0.1:7878"; port 0 picks a free port.
    pub addr: String,
    /// I/O event-loop threads for the readiness-driven core
    /// (0 = auto: `clamp(cores / 4, 1, 4)`).  Ignored by the threaded
    /// compatibility shim, which spawns one handler thread per
    /// connection regardless.
    pub io_threads: usize,
    /// Default per-request deadline budget in milliseconds, stamped at
    /// frame arrival (0 = no default).  A client `TMO=`/frame deadline
    /// can only tighten this, never extend it.
    pub request_timeout_ms: u64,
    /// Disconnect a connection after this many *consecutive* recoverable
    /// protocol errors (reset by any well-formed frame).  Binary decode
    /// failures stay fatal immediately — framing is lost.  0 disables
    /// the guard.
    pub max_proto_errors: u32,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7878".into(),
            io_threads: 0,
            request_timeout_ms: 0,
            max_proto_errors: 8,
        }
    }
}

/// Handle to a running server (shutdown on drop), wrapping whichever
/// connection core is driving the listener.
pub struct ServerHandle {
    pub local_addr: std::net::SocketAddr,
    core: HandleCore,
}

enum HandleCore {
    Threaded(tcp::ThreadedHandle),
    #[cfg(unix)]
    Event(event_loop::EventHandle),
}

impl ServerHandle {
    /// Currently open connections (gauge, not a lifetime total).
    pub fn active_connections(&self) -> u64 {
        match &self.core {
            HandleCore::Threaded(h) => h.active_connections(),
            #[cfg(unix)]
            HandleCore::Event(h) => h.active_connections(),
        }
    }

    /// The engine this server serves (shards, registries, metrics).
    pub fn engine(&self) -> &Arc<Engine> {
        match &self.core {
            HandleCore::Threaded(h) => h.engine(),
            #[cfg(unix)]
            HandleCore::Event(h) => h.engine(),
        }
    }

    /// Shard 0's session registry — meaningful only for 1-shard engines
    /// (the [`serve`] / [`serve_with_sessions`] compatibility paths).
    /// Sharded callers should use [`ServerHandle::engine`] and address
    /// shards explicitly (`sweep_now` there sweeps every shard).
    pub fn sessions(&self) -> &Arc<SessionRegistry> {
        self.engine().shard_registry(0)
    }

    /// Stop accepting, drain in-flight work, join every server thread.
    /// After this returns nothing races an engine shutdown that follows.
    pub fn stop(self) {
        // Drop runs the core-specific shutdown.
    }
}

/// Deprecated thin wrapper: start serving one `coordinator` on
/// `cfg.addr`.  Streaming sessions get a default-configured registry
/// sharing the coordinator's metrics.  New code should build an
/// [`Engine`] and call [`serve_engine`]; this wraps the coordinator as a
/// 1-shard engine, which is bit- and protocol-identical.
pub fn serve(coordinator: Arc<Coordinator>, cfg: &ServerConfig) -> std::io::Result<ServerHandle> {
    let stream_cfg = StreamConfig::default().clamp_threshold_to(coordinator.max_points());
    let sessions = Arc::new(SessionRegistry::new(stream_cfg, coordinator.metrics.clone()));
    serve_with_sessions(coordinator, sessions, cfg)
}

/// Deprecated thin wrapper: [`serve`] with an explicitly configured
/// session registry (clamp the threshold with
/// [`StreamConfig::clamp_threshold_to`] — a threshold above the backend's
/// request cap can never merge).  New code should build an [`Engine`] and
/// call [`serve_engine`].
pub fn serve_with_sessions(
    coordinator: Arc<Coordinator>,
    sessions: Arc<SessionRegistry>,
    cfg: &ServerConfig,
) -> std::io::Result<ServerHandle> {
    serve_engine(Arc::new(Engine::single(coordinator, sessions)), cfg)
}

/// Start serving `engine` on `cfg.addr` (non-blocking; returns a handle).
/// One-shot requests route to the cheapest shard; session verbs follow
/// their sid's shard; `STATS` returns the merged aggregate plus a
/// `per_shard` array and the `active_connections` gauge.
///
/// On unix this runs the readiness-driven event loop (`cfg.io_threads`
/// loops multiplexing every connection); elsewhere it falls back to the
/// thread-per-connection shim.
pub fn serve_engine(engine: Arc<Engine>, cfg: &ServerConfig) -> std::io::Result<ServerHandle> {
    #[cfg(unix)]
    {
        let h = event_loop::serve_event(engine, cfg)?;
        Ok(ServerHandle { local_addr: h.local_addr, core: HandleCore::Event(h) })
    }
    #[cfg(not(unix))]
    {
        serve_engine_threaded(engine, cfg)
    }
}

/// [`serve_engine`] on the thread-per-connection compatibility shim —
/// the reference core the parity suite measures the event loop against.
pub fn serve_engine_threaded(
    engine: Arc<Engine>,
    cfg: &ServerConfig,
) -> std::io::Result<ServerHandle> {
    let h = tcp::serve_threaded(engine, cfg)?;
    Ok(ServerHandle { local_addr: h.local_addr, core: HandleCore::Threaded(h) })
}

// ---------------------------------------------------------------- parity
// Request -> Response mapping shared verbatim by both connection cores.

/// Effective deadline for a frame that arrived now: the client's
/// `TMO=`/frame budget caps the server default (a client can tighten the
/// server's ceiling but never extend it).  `None` when neither side set
/// one.
pub(crate) fn request_deadline(server_timeout_ms: u64, tmo_ms: Option<u32>) -> Option<Instant> {
    let server = (server_timeout_ms != 0).then_some(server_timeout_ms);
    let client = tmo_ms.map(u64::from);
    let budget_ms = match (server, client) {
        (Some(s), Some(c)) => Some(s.min(c)),
        (s, c) => s.or(c),
    };
    budget_ms.map(|ms| Instant::now() + Duration::from_millis(ms))
}

/// Map a decode failure to its error response: echo the failed frame's
/// id when the header parsed, so id-correlating clients can still match
/// the failure (session frames echo under their own verb).
pub(crate) fn proto_error_response(e: &ProtoError) -> Response {
    match e {
        ProtoError::TooManyPoints { id, session: false, .. } => {
            Response::HullErr { id: *id, message: e.to_string() }
        }
        ProtoError::TooManyPoints { id, session: true, .. } => {
            Response::SessionErr { verb: SessionVerb::Add, id: *id, message: e.to_string() }
        }
        _ => Response::MalformedErr { id: e.frame_id(), message: e.to_string() },
    }
}

pub(crate) fn hull_response(id: u64, result: Result<HullResponse, RequestError>) -> Response {
    match result {
        Ok(h) => Response::Hull {
            id,
            upper: h.upper,
            lower: h.lower,
            backend: h.backend.to_string(),
            queue_ns: h.queue_ns,
            exec_ns: h.exec_ns,
        },
        Err(e) => Response::HullErr { id, message: e.to_string() },
    }
}

pub(crate) fn session_open_response(
    engine: &Engine,
    id: u64,
    restore: Option<u64>,
) -> Response {
    let opened = match restore {
        None => engine.session_open(),
        Some(sid) => engine.session_restore(sid),
    };
    match opened {
        Ok(sid) => Response::SessionOpened { id, sid },
        Err(e) => Response::SessionErr { verb: SessionVerb::Open, id, message: e.to_string() },
    }
}

pub(crate) fn session_add_response(
    engine: &Engine,
    sid: u64,
    points: &[Point],
    deadline: Option<Instant>,
) -> Response {
    match engine.session_add_deadline(sid, points, deadline) {
        Ok(o) => Response::SessionAdded {
            sid,
            absorbed: o.absorbed,
            pending: o.pending as u64,
            epoch: o.epoch,
        },
        Err(e) => Response::SessionErr { verb: SessionVerb::Add, id: sid, message: e.to_string() },
    }
}

pub(crate) fn session_hull_response(engine: &Engine, sid: u64, epoch: Option<u64>) -> Response {
    match engine.session_hull_at(sid, epoch) {
        Ok(s) => Response::SessionHull { sid, epoch: s.epoch, upper: s.upper, lower: s.lower },
        Err(e) => Response::SessionErr { verb: SessionVerb::Hull, id: sid, message: e.to_string() },
    }
}

pub(crate) fn session_close_response(engine: &Engine, sid: u64) -> Response {
    match engine.session_close(sid) {
        Ok(()) => Response::SessionClosed { sid },
        Err(e) => {
            Response::SessionErr { verb: SessionVerb::Close, id: sid, message: e.to_string() }
        }
    }
}
