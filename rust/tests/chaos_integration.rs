//! Chaos acceptance gates: the deterministic fault-injection harness
//! driven end-to-end through the engine.
//!
//! A seeded [`FaultPlan`] is injected into every exec worker of every
//! shard (the `Arc` cursor is shared, so one global step index spans the
//! whole engine).  Requests are submitted strictly sequentially — one in
//! flight at a time — which pins the dispatch order and makes the whole
//! run a pure function of the seed:
//!
//! * same seed → bit-for-bit identical outcome sequence (hulls AND typed
//!   errors), at 1 shard and at 4;
//! * whenever a result IS returned it is bit-identical to the no-fault
//!   oracle (the serial monotone chain for one-shots, a fault-free twin
//!   engine for sessions);
//! * every request resolves within [`RESOLVE_BUDGET`] with a typed
//!   outcome — success, `deadline-exceeded`, `overloaded`, or a
//!   `backend` error — never a hang;
//! * the books stay balanced: per-shard `requests == responses + errors`
//!   (so `in_flight` cannot underflow) and the session ledger
//!   `inserted == absorbed + pending + hull_points` stays exact.
//!
//! `ENGINE_SHARDS=4` reruns the env-driven tests against a sharded
//! engine (tier1 does both passes).

use std::sync::Arc;
use std::time::{Duration, Instant};

use wagener_hull::coordinator::{
    BackendKind, BatcherConfig, CoordinatorConfig, HullRequest, RequestError,
};
use wagener_hull::engine::{Engine, EngineConfig};
use wagener_hull::fault::{FaultAction, FaultPlan};
use wagener_hull::geometry::generators::{generate, Distribution};
use wagener_hull::geometry::point::{sort_by_x, Point};
use wagener_hull::serial::monotone_chain;
use wagener_hull::stream::StreamConfig;

/// Every submitted request must resolve within this bound or the suite
/// fails — the "no request hangs under faults" gate.
const RESOLVE_BUDGET: Duration = Duration::from_secs(30);

/// What one request produced: the hull chains, or the typed error text.
type Outcome = Result<(Vec<Point>, Vec<Point>), String>;

fn chaos_engine(shards: usize, plan: Arc<FaultPlan>, cooldown_ms: u64) -> Engine {
    Engine::start(EngineConfig {
        shards,
        coordinator: CoordinatorConfig {
            backend: BackendKind::Native,
            workers: 2, // failover needs a second worker to retry on
            // one request per batch: the dispatch (= fault-plan step)
            // sequence is then exactly the request sequence
            batcher: BatcherConfig { max_batch: 1, flush_us: 100, queue_cap: 64 },
            breaker_cooldown_ms: cooldown_ms,
            fault_plan: Some(plan),
            ..Default::default()
        },
        stream: StreamConfig { idle_ttl_ms: 0, merge_threshold: 48, ..Default::default() },
        max_queued: 0,
        ..Default::default()
    })
    .unwrap()
}

fn workload(n: usize) -> Vec<Vec<Point>> {
    (0..n)
        .map(|k| {
            let dist = Distribution::ALL[k % Distribution::ALL.len()];
            generate(dist, 16 + 7 * k, k as u64)
        })
        .collect()
}

/// Submit the inputs one at a time (strictly sequential — the property
/// that makes a faulted run deterministic) and collect every outcome.
fn run_schedule(e: &Engine, inputs: &[Vec<Point>]) -> Vec<Outcome> {
    inputs
        .iter()
        .enumerate()
        .map(|(k, pts)| {
            let rx = e.submit(HullRequest::new(k as u64 + 1, pts.clone()));
            let result = rx
                .recv_timeout(RESOLVE_BUDGET)
                .unwrap_or_else(|_| panic!("request {k} did not resolve within budget"));
            result.map(|r| (r.upper, r.lower)).map_err(|e| e.to_string())
        })
        .collect()
}

/// The full typed-error vocabulary a chaos run may answer with.
fn typed_error(msg: &str) -> bool {
    msg == "deadline-exceeded"
        || msg == "overloaded"
        || msg == "unknown-session"
        || msg.starts_with("backend failure:")
}

/// Per-shard ledger balance: every request that entered the pipeline was
/// answered exactly once, so the derived `in_flight` gauge is zero (and
/// by construction can never have gone negative).
fn assert_books_balanced(e: &Engine) {
    for i in 0..e.shard_count() {
        let f = e.shard_coordinator(i).metrics.frame();
        assert_eq!(
            f.requests,
            f.responses + f.errors,
            "shard {i}: requests {} != responses {} + errors {}",
            f.requests,
            f.responses,
            f.errors
        );
        assert_eq!(f.in_flight(), 0, "shard {i}: in-flight gauge did not drain");
    }
}

fn unique_vertices(upper: &[Point], lower: &[Point]) -> usize {
    let mut all: Vec<Point> = upper.iter().chain(lower.iter()).copied().collect();
    sort_by_x(&mut all);
    all.dedup();
    all.len()
}

/// THE determinism gate: the same seeded plan replayed against the same
/// inputs produces a bit-for-bit identical outcome sequence, every
/// returned hull is bit-identical to the no-fault serial oracle, and
/// every error is typed.  Runs at `ENGINE_SHARDS` shards (default 1).
#[test]
fn same_seed_same_outcomes_and_hulls_match_the_no_fault_oracle() {
    let shards = EngineConfig::shards_from_env(1);
    let inputs = workload(40);
    let menu = [
        FaultAction::Error,
        FaultAction::Panic,
        FaultAction::Delay(Duration::from_millis(1)),
    ];
    let mut runs = Vec::new();
    for _ in 0..2 {
        let plan = FaultPlan::seeded(0xC0FFEE, 4096, 20, &menu);
        assert!(plan.planned() > 0, "a 20% plan over 4096 steps must schedule faults");
        // hour-long cooldown: a tripped breaker stays tripped for the
        // whole run, so no wall-clock race can change the outcome
        let e = chaos_engine(shards, plan.clone(), 3_600_000);
        let outcomes = run_schedule(&e, &inputs);
        assert!(plan.taken() > 0, "the plan cursor must have been consumed");
        assert_books_balanced(&e);
        runs.push(outcomes);
    }
    assert_eq!(runs[0], runs[1], "same seed diverged between two runs");
    let mut ok = 0usize;
    for (k, outcome) in runs[0].iter().enumerate() {
        match outcome {
            Ok((upper, lower)) => {
                ok += 1;
                let (u, l) = monotone_chain::full_hull(&inputs[k]);
                assert_eq!(*upper, u, "request {k}: upper diverged from oracle");
                assert_eq!(*lower, l, "request {k}: lower diverged from oracle");
            }
            Err(msg) => assert!(typed_error(msg), "request {k}: untyped error {msg:?}"),
        }
    }
    assert!(ok > 0, "a 20% fault rate must let most requests through");
}

/// The same determinism property pinned at 4 shards: the plan cursor is
/// shared across all four coordinators, so sequential submission keeps
/// the global dispatch order — and therefore every outcome — fixed.
#[test]
fn four_shard_chaos_is_equally_deterministic() {
    let inputs = workload(28);
    let menu = [FaultAction::Panic, FaultAction::Error];
    let mut runs = Vec::new();
    for _ in 0..2 {
        let plan = FaultPlan::seeded(0xBADD_CAFE, 4096, 20, &menu);
        let e = chaos_engine(4, plan, 3_600_000);
        let outcomes = run_schedule(&e, &inputs);
        assert_books_balanced(&e);
        runs.push(outcomes);
    }
    assert_eq!(runs[0], runs[1], "4-shard run diverged between replays");
    for (k, outcome) in runs[0].iter().enumerate() {
        if let Ok((upper, lower)) = outcome {
            let (u, l) = monotone_chain::full_hull(&inputs[k]);
            assert_eq!((upper, lower), (&u, &l), "request {k} diverged from oracle");
        }
    }
}

/// Expired budgets answer the typed `deadline-exceeded` error (counted
/// in `deadline_exceeded_total` AND in `errors`, so the in-flight gauge
/// drains exactly) while unexpired requests on the same connection keep
/// computing oracle-identical hulls.
#[test]
fn expired_deadlines_answer_typed_error_without_unbalancing_the_books() {
    let shards = EngineConfig::shards_from_env(1);
    let e = chaos_engine(shards, FaultPlan::from_steps(&[]), 0);
    let inputs = workload(12);
    let mut expired = 0u64;
    for (k, pts) in inputs.iter().enumerate() {
        // every third request arrives already out of budget
        let deadline = (k % 3 == 0).then(Instant::now);
        let rx = e.submit(HullRequest::new(k as u64 + 1, pts.clone()).with_deadline(deadline));
        let outcome = rx.recv_timeout(RESOLVE_BUDGET).expect("request must resolve");
        if k % 3 == 0 {
            expired += 1;
            assert_eq!(outcome.unwrap_err().to_string(), "deadline-exceeded", "request {k}");
        } else {
            let resp = outcome.unwrap_or_else(|e| panic!("request {k}: {e}"));
            let (u, l) = monotone_chain::full_hull(pts);
            assert_eq!((resp.upper, resp.lower), (u, l), "request {k}");
        }
    }
    let snap = e.snapshot().0;
    assert_eq!(
        snap.get("deadline_exceeded_total").unwrap().as_usize(),
        Some(expired as usize)
    );
    assert_books_balanced(&e);
}

/// Breaker lifecycle under an explicit panic storm: both attempts of the
/// first two requests fault (exhausting the bounded retry), the third
/// consecutive batch failure trips the breaker open, an open breaker
/// rejects at admission WITHOUT consuming a dispatch, and after the
/// cooldown the next request becomes the half-open probe that closes it.
#[test]
fn panic_storm_trips_the_breaker_and_a_probe_recovers_it() {
    let plan = FaultPlan::from_steps(&[
        (0, FaultAction::Panic),
        (1, FaultAction::Panic),
        (2, FaultAction::Error),
        (3, FaultAction::Panic),
    ]);
    let e = chaos_engine(1, plan.clone(), 1000);
    let pts = generate(Distribution::Circle, 64, 11);
    for k in 0..2 {
        let err = e.compute(pts.clone()).unwrap_err();
        assert!(matches!(err, RequestError::Backend(_)), "request {k}: got {err:?}");
    }
    assert_eq!(plan.taken(), 4, "2 requests x (dispatch + failover retry)");
    assert_eq!(e.shard_coordinator(0).breaker().state(), 1, "3rd failure must trip");
    // open breaker: rejected at admission, no plan step consumed
    let err = e.compute(pts.clone()).unwrap_err();
    assert!(matches!(err, RequestError::Backend(_)), "got {err:?}");
    assert_eq!(plan.taken(), 4, "breaker-open rejection must not dispatch");
    // cooldown elapses: the next request IS the half-open probe; the
    // plan is exhausted so it succeeds and closes the breaker
    std::thread::sleep(Duration::from_millis(1200));
    let resp = e.compute(pts.clone()).unwrap();
    let (u, l) = monotone_chain::full_hull(&pts);
    assert_eq!((resp.upper, resp.lower), (u, l));
    assert_eq!(e.shard_coordinator(0).breaker().state(), 0, "probe must close it");
    let snap = e.snapshot().0;
    assert_eq!(snap.get("retries_total").unwrap().as_usize(), Some(2));
    assert_eq!(snap.get("breaker_state").unwrap().as_usize(), Some(0));
    assert_books_balanced(&e);
}

/// Sessions under pure Delay chaos (perturbation without failure): every
/// add outcome, epoch and hull must be bit-identical to a fault-free
/// twin engine fed the same schedule, and the global session ledger
/// `inserted == absorbed + pending + hull_points` must be exact on both.
#[test]
fn delay_chaos_keeps_sessions_bit_identical_to_the_no_fault_run() {
    let shards = EngineConfig::shards_from_env(1);
    let delayed = chaos_engine(
        shards,
        FaultPlan::seeded(7, 4096, 30, &[FaultAction::Delay(Duration::from_micros(300))]),
        0,
    );
    let control = chaos_engine(shards, FaultPlan::from_steps(&[]), 0);
    let n_sessions = 3usize;
    let sids_d: Vec<u64> = (0..n_sessions).map(|_| delayed.session_open().unwrap()).collect();
    let sids_c: Vec<u64> = (0..n_sessions).map(|_| control.session_open().unwrap()).collect();
    let mut fed = vec![0usize; n_sessions];
    for step in 0..24usize {
        let dist = Distribution::ALL[step % Distribution::ALL.len()];
        let pts = generate(dist, 20 + 3 * step, step as u64 + 100);
        if step % 4 == 3 {
            // interleaved one-shot stirring the same exec pools
            let a = delayed.compute(pts.clone()).unwrap();
            let b = control.compute(pts).unwrap();
            assert_eq!((a.upper, a.lower), (b.upper, b.lower), "one-shot {step} diverged");
        } else {
            let k = step % n_sessions;
            let a = delayed.session_add(sids_d[k], &pts).unwrap();
            let b = control.session_add(sids_c[k], &pts).unwrap();
            assert_eq!(a, b, "session {k} step {step}: add outcome diverged");
            fed[k] += pts.len();
        }
    }
    let mut hull_points = 0usize;
    for k in 0..n_sessions {
        let a = delayed.session_hull(sids_d[k]).unwrap();
        let b = control.session_hull(sids_c[k]).unwrap();
        assert_eq!(a.epoch, b.epoch, "session {k}: epoch diverged");
        assert_eq!(a.upper, b.upper, "session {k}: upper diverged");
        assert_eq!(a.lower, b.lower, "session {k}: lower diverged");
        hull_points += unique_vertices(&a.upper, &a.lower);
    }
    // exact accounting on the delayed engine's merged metrics: every
    // point ever inserted is absorbed, pending, or a hull vertex
    let inserted: usize = fed.iter().sum();
    let m = delayed.snapshot().0;
    let absorbed = m.get("absorbed_points_total").unwrap().as_usize().unwrap();
    let pending = m.get("pending_points_total").unwrap().as_usize().unwrap();
    assert_eq!(pending, 0, "SHULL must have flushed every pending point");
    assert_eq!(absorbed + pending + hull_points, inserted, "session ledger drifted");
    for k in 0..n_sessions {
        delayed.session_close(sids_d[k]).unwrap();
        control.session_close(sids_c[k]).unwrap();
    }
    assert_eq!(delayed.open_sessions(), 0);
    assert_books_balanced(&delayed);
    assert_books_balanced(&control);
}

/// Mixed chaos (errors, panics, delays, expired deadlines, a breaker
/// that may cycle) over interleaved one-shots and session traffic: every
/// request resolves with a typed outcome within budget and no gauge ever
/// underflows — the ledgers drain to zero once the sessions close.
#[test]
fn mixed_chaos_never_underflows_gauges_and_resolves_everything() {
    let shards = EngineConfig::shards_from_env(1);
    let menu = [
        FaultAction::Error,
        FaultAction::Delay(Duration::from_micros(300)),
        FaultAction::Panic,
    ];
    let plan = FaultPlan::seeded(99, 4096, 25, &menu);
    let e = chaos_engine(shards, plan, 30);
    let sid = e.session_open().unwrap();
    let mut attempted = 0usize;
    let (mut ok, mut failed) = (0usize, 0usize);
    for step in 0..36usize {
        let dist = Distribution::ALL[step % Distribution::ALL.len()];
        let pts = generate(dist, 24 + 5 * step, step as u64 + 500);
        let outcome: Result<(), String> = match step % 3 {
            0 => {
                // one-shot, occasionally with an already-expired budget
                let deadline = (step % 9 == 0 && step > 0).then(Instant::now);
                let rx =
                    e.submit(HullRequest::new(step as u64 + 1, pts.clone()).with_deadline(deadline));
                rx.recv_timeout(RESOLVE_BUDGET)
                    .expect("one-shot must resolve within budget")
                    .map(|resp| {
                        let (u, l) = monotone_chain::full_hull(&pts);
                        assert_eq!((resp.upper, resp.lower), (u, l), "step {step}");
                    })
                    .map_err(|e| e.to_string())
            }
            _ => {
                // a failed add may still have pended points before the
                // merge faulted, so the gauge bound counts every attempt
                attempted += pts.len();
                e.session_add(sid, &pts).map(|_| ()).map_err(|e| e.to_string())
            }
        };
        match outcome {
            Ok(()) => ok += 1,
            Err(msg) => {
                failed += 1;
                assert!(typed_error(&msg), "step {step}: untyped error {msg:?}");
            }
        }
        if (0..e.shard_count()).any(|i| e.shard_coordinator(i).breaker().state() != 0) {
            // give a tripped breaker its cooldown so later steps probe it
            std::thread::sleep(Duration::from_millis(40));
        }
    }
    assert_eq!(ok + failed, 36, "every step must resolve one way or the other");
    // the pending gauge is bounded by what was ever offered — an
    // underflow would read as an astronomically large value here
    let m = e.snapshot().0;
    let pending = m.get("pending_points_total").unwrap().as_usize().unwrap();
    assert!(pending <= attempted, "pending {pending} > attempted {attempted}: underflow");
    assert_eq!(m.get("open_sessions").unwrap().as_usize(), Some(1));
    // closing the session must release its share of the gauges exactly
    e.session_close(sid).unwrap();
    let m = e.snapshot().0;
    assert_eq!(m.get("open_sessions").unwrap().as_usize(), Some(0));
    assert_eq!(m.get("pending_points_total").unwrap().as_usize(), Some(0));
    assert_books_balanced(&e);
}
