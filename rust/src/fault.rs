//! Deterministic fault injection for the serving stack.
//!
//! A [`FaultPlan`] is a seeded, step-indexed schedule of faults: every
//! dispatch site that carries a plan calls [`FaultPlan::next`] exactly
//! once per attempt, and the plan answers "inject nothing" or one of the
//! three [`FaultAction`]s for that global step index.  Because the index
//! is a single shared counter and the schedule is fixed up front, a run
//! with a given plan is reproducible: the chaos suite
//! (`rust/tests/chaos_integration.rs`) replays the same plan against the
//! same inputs and asserts identical outcomes.
//!
//! Two injection points consume plans:
//!
//! * coordinator exec workers (`CoordinatorConfig::fault_plan`) — the
//!   action fires inside the worker's `catch_unwind`, exercising the
//!   panic-containment, bounded-retry and circuit-breaker paths;
//! * the session merge path, via [`FaultyService`] wrapping any
//!   [`HullService`] handed to the registry.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::RequestError;
use crate::geometry::point::Point;
use crate::stream::HullService;

/// One injected fault.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// Panic at the dispatch site (workers contain it via `catch_unwind`;
    /// session callers see the unwind).
    Panic,
    /// Fail the dispatch with a typed `backend` error without computing.
    Error,
    /// Sleep before computing — deadline pressure without failure.
    Delay(Duration),
}

/// A fixed schedule mapping dispatch indices to faults, consumed through
/// one shared step counter (clones of the `Arc` share the cursor, so a
/// plan spans every worker of a coordinator).
#[derive(Debug)]
pub struct FaultPlan {
    /// `(step index, action)`, unordered; tiny, scanned linearly.
    steps: Vec<(u64, FaultAction)>,
    cursor: AtomicU64,
}

impl FaultPlan {
    /// Plan from explicit `(dispatch index, action)` pairs.
    pub fn from_steps(steps: &[(u64, FaultAction)]) -> Arc<FaultPlan> {
        Arc::new(FaultPlan { steps: steps.to_vec(), cursor: AtomicU64::new(0) })
    }

    /// Seeded pseudo-random plan over the first `horizon` dispatches:
    /// each step independently faults with probability `percent`/100,
    /// cycling through `menu` for the action.  Same seed, same plan.
    pub fn seeded(seed: u64, horizon: u64, percent: u64, menu: &[FaultAction]) -> Arc<FaultPlan> {
        let mut steps = Vec::new();
        if !menu.is_empty() {
            let mut pick = 0usize;
            for step in 0..horizon {
                if splitmix64(seed.wrapping_add(step)) % 100 < percent {
                    steps.push((step, menu[pick % menu.len()]));
                    pick += 1;
                }
            }
        }
        Arc::new(FaultPlan { steps, cursor: AtomicU64::new(0) })
    }

    /// Claim the next dispatch index and return its scheduled action, if
    /// any.  Exactly one call per dispatch attempt.
    pub fn next(&self) -> Option<FaultAction> {
        let step = self.cursor.fetch_add(1, Ordering::Relaxed);
        self.steps.iter().find(|(s, _)| *s == step).map(|(_, a)| *a)
    }

    /// Dispatches claimed so far (assertions; monotone).
    pub fn taken(&self) -> u64 {
        self.cursor.load(Ordering::Relaxed)
    }

    /// Scheduled fault count.
    pub fn planned(&self) -> usize {
        self.steps.len()
    }
}

/// splitmix64 — the crate's stock no-dependency mixer.
fn splitmix64(seed: u64) -> u64 {
    let mut z = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// [`HullService`] adapter injecting a plan into the session merge path:
/// `Panic` unwinds out of the merge (the registry's poison-tolerant locks
/// keep the session usable), `Error` surfaces as a `backend` session
/// error, `Delay` stalls the merge.
pub struct FaultyService<S> {
    inner: S,
    plan: Arc<FaultPlan>,
}

impl<S> FaultyService<S> {
    pub fn new(inner: S, plan: Arc<FaultPlan>) -> FaultyService<S> {
        FaultyService { inner, plan }
    }
}

impl<S: HullService> HullService for FaultyService<S> {
    fn full_hull(&self, points: Vec<Point>) -> Result<(Vec<Point>, Vec<Point>), RequestError> {
        match self.plan.next() {
            Some(FaultAction::Panic) => panic!("fault-plan: injected panic"),
            Some(FaultAction::Error) => {
                return Err(RequestError::Backend("fault-plan: injected error".into()))
            }
            Some(FaultAction::Delay(d)) => std::thread::sleep(d),
            None => {}
        }
        self.inner.full_hull(points)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn explicit_steps_fire_at_their_index() {
        let plan = FaultPlan::from_steps(&[(1, FaultAction::Panic), (3, FaultAction::Error)]);
        assert_eq!(plan.next(), None); // step 0
        assert_eq!(plan.next(), Some(FaultAction::Panic)); // step 1
        assert_eq!(plan.next(), None); // step 2
        assert_eq!(plan.next(), Some(FaultAction::Error)); // step 3
        assert_eq!(plan.next(), None); // past the horizon
        assert_eq!(plan.taken(), 5);
        assert_eq!(plan.planned(), 2);
    }

    #[test]
    fn seeded_plans_are_reproducible_and_seed_sensitive() {
        let a = FaultPlan::seeded(42, 256, 25, &[FaultAction::Panic, FaultAction::Error]);
        let b = FaultPlan::seeded(42, 256, 25, &[FaultAction::Panic, FaultAction::Error]);
        let c = FaultPlan::seeded(43, 256, 25, &[FaultAction::Panic, FaultAction::Error]);
        assert_eq!(a.steps, b.steps, "same seed, same schedule");
        assert_ne!(a.steps, c.steps, "different seed, different schedule");
        assert!(a.planned() > 0, "25% of 256 steps should schedule faults");
        // ~25% hit rate, loosely bounded
        assert!(a.planned() < 128, "got {}", a.planned());
    }

    #[test]
    fn faulty_service_maps_actions() {
        struct Ok2;
        impl HullService for Ok2 {
            fn full_hull(
                &self,
                points: Vec<Point>,
            ) -> Result<(Vec<Point>, Vec<Point>), RequestError> {
                Ok((points.clone(), points))
            }
        }
        let plan = FaultPlan::from_steps(&[(0, FaultAction::Error)]);
        let svc = FaultyService::new(Ok2, plan);
        let err = svc.full_hull(vec![Point::new(0.0, 0.0)]).unwrap_err();
        assert!(matches!(err, RequestError::Backend(_)));
        // step 1 has no fault: passes through
        let (u, _) = svc.full_hull(vec![Point::new(0.5, 0.5)]).unwrap();
        assert_eq!(u.len(), 1);
    }
}
