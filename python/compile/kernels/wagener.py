"""L1: Wagener match-and-merge as a Pallas kernel (+ plain-jnp twin).

One kernel invocation executes one *stage* of Wagener's pipeline: every
pair of adjacent d-slot hoods is merged into a 2d-slot hood.  The pallas
grid has one program per merge pair — the analogue of the paper's CUDA
thread block — and the 2d-point window lives in the program's local memory
(VMEM on a real TPU; the paper's ``__shared__`` scratch).  Inside a program
the six ``mam`` phases of the paper become fixed-shape vector ops over the
d1 x d2 sample lattice (the paper's thread lattice), so the whole kernel is
branch-free: every CUDA thread conditional is a ``jnp.where`` select, which
is exactly the divergence-free style the paper says it aspires to.

Hardware adaptation (DESIGN.md §2): the paper tiles work into CUDA thread
blocks with shared-memory ``scratch``; here BlockSpec expresses the same
HBM->VMEM schedule, and the intra-block thread lattice becomes vector
lanes.  Memory-bank conflicts have no analogue on the vector unit — the
serialization cost the paper observed is modelled in the rust PRAM
simulator instead.

Kernels MUST be lowered with interpret=True: real-TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute.

Index conventions inside a block (size 2d, block-relative):
  P half = slots [0, d), Q half = slots [d, 2d).
Phases (paper chunk names; scratch cells shown for fidelity):
  mam1  scratch[x]   = max sample j_y = d + d1*y with g(i_x, j_y) <= EQ
  mam2  scratch[d+x] = unique j in [scratch[x], +d1) with g(i_x, j) == EQ
  mam3  scratch[0]   = k0 = max sample i_x = d2*x with f(i_x, scratch[d+x]) <= EQ
  mam4  scratch[d+y] = max sample j_x = d + d2*x with g(k0+y, j_x) <= EQ
  mam5  (p*, q*)     = unique pair with g == f == EQ
  mam6  newhood      = hood[0..p*] ++ hood[q*..2d) ++ REMOTE...
mam6 fixes the paper's stale-corner bug (DESIGN.md §1.1) by REMOTE-filling
every lower-half slot past p* before the shift-copy.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

REMOTE_X = 10.0
REMOTE_Y = 0.0
LIVE_X_MAX = 1.0

LOW, EQUAL, HIGH = 0, 1, 2

# Orientation determinants are computed in float64 (see ref.py docstring).
# Requires jax_enable_x64; enable_x64() is called by model/aot/tests.
DET_DTYPE = jnp.float64


def enable_x64() -> None:
    jax.config.update("jax_enable_x64", True)


def stage_dims(d: int) -> tuple[int, int]:
    """The paper's thread-block shape for hood size d: d1 = 2^ceil(r/2),
    d2 = 2^floor(r/2) with d = 2^r, so d1*d2 == d and d2 <= d1 <= 2*d2."""
    r = d.bit_length() - 1
    assert 1 << r == d and r >= 1, f"d must be a power of two >= 2, got {d}"
    d1 = 1 << ((r + 1) // 2)
    d2 = 1 << (r // 2)
    return d1, d2


def _live(pts: jnp.ndarray) -> jnp.ndarray:
    return pts[..., 0] <= LIVE_X_MAX


def _left_of(p: jnp.ndarray, q: jnp.ndarray, r: jnp.ndarray) -> jnp.ndarray:
    """r strictly left of directed segment p->q (broadcasting, f64 det)."""
    p = p.astype(DET_DTYPE)
    q = q.astype(DET_DTYPE)
    r = r.astype(DET_DTYPE)
    det = (q[..., 0] - p[..., 0]) * (r[..., 1] - p[..., 1]) - (
        q[..., 1] - p[..., 1]
    ) * (r[..., 0] - p[..., 0])
    return det > 0.0


def _gather(blk: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """blk[(idx clamped), :] for integer index arrays of any shape."""
    idx = jnp.clip(idx, 0, blk.shape[0] - 1)
    return jnp.take(blk, idx, axis=0)


def _neighbors(
    blk: jnp.ndarray, idx: jnp.ndarray, lo: int, hi: int
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(pt, next, prev) for corners at ``idx`` of the hood stored in
    blk[lo:hi] (live-left-justified).  Where the neighbor does not exist
    (block edge or REMOTE slot) it is the synthetic point directly below
    ``pt`` — the paper's ``q_next.y -= atend`` trick, which keeps every
    phase branch-free."""
    pt = _gather(blk, idx)
    nxt_raw = _gather(blk, idx + 1)
    prv_raw = _gather(blk, idx - 1)
    # synthetic point directly below pt (avoid array-literal constants,
    # which pallas kernels may not capture)
    below = jnp.stack([pt[..., 0], pt[..., 1] - 1.0], axis=-1)
    at_end = (idx + 1 >= hi) | ~_live(nxt_raw)
    at_start = idx <= lo
    nxt = jnp.where(at_end[..., None], below, nxt_raw)
    prv = jnp.where(at_start[..., None], below, prv_raw)
    return pt, nxt, prv


def _g(blk: jnp.ndarray, i: jnp.ndarray, j: jnp.ndarray, d: int) -> jnp.ndarray:
    """Paper's g(i, j): position of corner q = blk[j] of H(Q) relative to
    the corner supporting the tangent from p = blk[i] (i in the P half).
    Along H(Q) left-to-right the value sequence is LOW* EQUAL HIGH*.
    REMOTE p or q => HIGH."""
    i, j = jnp.broadcast_arrays(i, j)
    p = _gather(blk, i)
    q, q_next, q_prev = _neighbors(blk, j, d, 2 * d)
    low = _left_of(p, q, q_next)
    high = _left_of(p, q, q_prev)
    code = jnp.where(low, LOW, jnp.where(high, HIGH, EQUAL))
    remote = ~_live(p) | ~_live(q)
    return jnp.where(remote, HIGH, code)


def _f(blk: jnp.ndarray, i: jnp.ndarray, j: jnp.ndarray, d: int) -> jnp.ndarray:
    """Paper's f(i, j): position of corner p = blk[i] of H(P) relative to
    the corner supporting the tangent from q = blk[j] (j in the Q half).
    Along H(P) left-to-right: LOW* EQUAL HIGH*.  REMOTE p or q => HIGH."""
    i, j = jnp.broadcast_arrays(i, j)
    q = _gather(blk, j)
    p, p_next, p_prev = _neighbors(blk, i, 0, d)
    low = _left_of(p, q, p_next)
    high = _left_of(p, q, p_prev)
    code = jnp.where(low, LOW, jnp.where(high, HIGH, EQUAL))
    remote = ~_live(p) | ~_live(q)
    return jnp.where(remote, HIGH, code)


def _max_index_leq_equal(codes: jnp.ndarray, axis: int) -> jnp.ndarray:
    """Largest index along ``axis`` whose code is <= EQUAL, or 0 if none.

    This is the paper's "g(..) <= EQUAL && next is HIGH-or-absent" thread
    race, resolved as a reduction (codes are LOW* EQUAL HIGH* monotone, so
    the max qualifying index is exactly the paper's unique writer)."""
    k = codes.shape[axis]
    idx = jnp.arange(k)
    shape = [1] * codes.ndim
    shape[axis] = k
    idx = idx.reshape(shape)
    cand = jnp.where(codes <= EQUAL, idx, -1)
    return jnp.maximum(jnp.max(cand, axis=axis), 0)


def merge_block(blk: jnp.ndarray, d1: int, d2: int) -> jnp.ndarray:
    """Merge one 2d-slot block: H(P) ++ H(Q) -> H(P u Q), REMOTE-padded.

    Pure function of the block; shared verbatim by the pallas kernel body
    and the plain-jnp twin so both lower from one source of truth."""
    d = d1 * d2
    assert blk.shape == (2 * d, 2), blk.shape

    # mam1: for each P sample i_x (stride d2), bracket the tangent corner on
    # H(Q) between Q samples j_y (stride d1).
    i_x = jnp.arange(d1) * d2                       # (d1,)
    j_y = d + jnp.arange(d2) * d1                   # (d2,)
    g1 = _g(blk, i_x[:, None], j_y[None, :], d)     # (d1, d2)
    qsamp = d + _max_index_leq_equal(g1, axis=1) * d1   # (d1,)

    # mam2: refine within the bracket [qsamp, qsamp + d1): the unique EQUAL.
    t1 = jnp.arange(d1)                             # (d1,)
    g2 = _g(blk, i_x[:, None], qsamp[:, None] + t1[None, :], d)  # (d1, d1)
    qexact = qsamp + jnp.argmax(g2 == EQUAL, axis=1)             # (d1,)

    # mam3: k0 = max P sample with f(i_x, tangent(i_x)) <= EQUAL;
    # the tangent corner p* lies in [k0, k0 + d2).
    f3 = _f(blk, i_x, qexact, d)                    # (d1,)
    k0 = _max_index_leq_equal(f3, axis=0) * d2      # scalar

    # mam4: for each exact candidate i = k0 + y, re-bracket on H(Q) with the
    # finer sample stride d2 (d1 samples).
    yy = jnp.arange(d2)                             # (d2,)
    j_x = d + jnp.arange(d1) * d2                   # (d1,)
    g4 = _g(blk, (k0 + yy)[:, None], j_x[None, :], d)            # (d2, d1)
    qs2 = d + _max_index_leq_equal(g4, axis=1) * d2              # (d2,)

    # mam5: the unique pair with g == f == EQUAL is the common tangent.
    t2 = jnp.arange(d2)                             # (d2,)
    ii = (k0 + yy)[:, None]                         # (d2, 1)
    jj = qs2[:, None] + t2[None, :]                 # (d2, d2)
    hit = (_g(blk, ii, jj, d) == EQUAL) & (_f(blk, ii, jj, d) == EQUAL)
    flat = jnp.argmax(hit.reshape(-1))
    pidx = k0 + flat // d2
    qidx = jnp.take(qs2, flat // d2) + flat % d2

    # mam6: newhood = blk[0..pidx] ++ blk[qidx..2d) ++ REMOTE...
    # (REMOTE-fill past pidx *before* the shift-copy — paper-bug fix.)
    shift = qidx - pidx - 1
    t = jnp.arange(2 * d)
    src = jnp.where(t <= pidx, t, t + shift)
    gathered = _gather(blk, src)
    in_range = src < 2 * d
    out = jnp.stack(
        [
            jnp.where(in_range, gathered[:, 0], REMOTE_X),
            jnp.where(in_range, gathered[:, 1], REMOTE_Y),
        ],
        axis=-1,
    )

    # Degenerate pair: Q half entirely REMOTE (input padding) — the merged
    # hood is just H(P).  (P empty implies Q empty, since live data is
    # globally left-justified.)
    q_empty = ~_live(blk[d])
    return jnp.where(q_empty, blk, out)


def _stage_kernel(hood_ref, out_ref, *, d1: int, d2: int):
    """Pallas body: one program = one merge pair (the CUDA thread block)."""
    out_ref[...] = merge_block(hood_ref[...], d1, d2)


@functools.partial(jax.jit, static_argnums=(1,))
def pallas_stage(hood: jnp.ndarray, d: int) -> jnp.ndarray:
    """One Wagener stage over the whole hood array via pallas_call.

    hood: (n, 2) float32, n % 2d == 0.  Grid = n/(2d) programs; BlockSpec
    carves the 2d-slot window each program owns (HBM->VMEM schedule)."""
    n = hood.shape[0]
    d1, d2 = stage_dims(d)
    assert n % (2 * d) == 0, (n, d)
    grid = (n // (2 * d),)
    spec = pl.BlockSpec((2 * d, 2), lambda b: (b, 0))
    return pl.pallas_call(
        functools.partial(_stage_kernel, d1=d1, d2=d2),
        grid=grid,
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(hood.shape, hood.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(hood)


@functools.partial(jax.jit, static_argnums=(1,))
def jnp_stage(hood: jnp.ndarray, d: int) -> jnp.ndarray:
    """Plain-jnp twin of pallas_stage (vmap over merge pairs).

    Kept as (a) an ablation target for the AOT report and (b) a second
    implementation path for differential testing."""
    n = hood.shape[0]
    d1, d2 = stage_dims(d)
    assert n % (2 * d) == 0, (n, d)
    blocks = hood.reshape(n // (2 * d), 2 * d, 2)
    merged = jax.vmap(lambda b: merge_block(b, d1, d2))(blocks)
    return merged.reshape(n, 2)
