//! Minimal blocking HTTP/1.1 client for the gateway — the HTTP-side
//! sibling of [`crate::server::HullClient`], used by the parity suite
//! and benches.  Keep-alive by default: one connection serves many
//! requests; responses are framed by `Content-Length` (the only framing
//! the gateway emits).

use std::io::{self, ErrorKind, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};

use crate::util::json::{self, Json};

/// One decoded response.
#[derive(Debug)]
pub struct HttpResult {
    pub status: u16,
    /// Headers with ascii-lowercased names.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpResult {
    /// Parse the body as JSON (panics on non-JSON — test/bench helper).
    pub fn json(&self) -> Json {
        let text = std::str::from_utf8(&self.body).expect("response body is utf-8");
        json::parse(text).expect("response body is JSON")
    }
}

pub struct HttpClient {
    stream: TcpStream,
    /// Unconsumed bytes past the previous response (keep-alive).
    rbuf: Vec<u8>,
}

impl HttpClient {
    pub fn connect<A: ToSocketAddrs>(addr: A) -> io::Result<HttpClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(HttpClient { stream, rbuf: Vec::new() })
    }

    /// Send one request and read its response.  `content_type` is only
    /// emitted when a body is present.
    pub fn request(
        &mut self,
        method: &str,
        target: &str,
        content_type: &str,
        body: &[u8],
    ) -> io::Result<HttpResult> {
        let mut wire = format!("{method} {target} HTTP/1.1\r\nhost: gw\r\n").into_bytes();
        if !body.is_empty() {
            wire.extend_from_slice(format!("content-type: {content_type}\r\n").as_bytes());
        }
        wire.extend_from_slice(format!("content-length: {}\r\n\r\n", body.len()).as_bytes());
        wire.extend_from_slice(body);
        self.stream.write_all(&wire)?;
        self.read_response()
    }

    pub fn get(&mut self, target: &str) -> io::Result<HttpResult> {
        self.request("GET", target, "", &[])
    }

    pub fn delete(&mut self, target: &str) -> io::Result<HttpResult> {
        self.request("DELETE", target, "", &[])
    }

    pub fn post_json(&mut self, target: &str, body: &str) -> io::Result<HttpResult> {
        self.request("POST", target, "application/json", body.as_bytes())
    }

    /// POST raw little-endian `f64` pairs (the binary hull body).
    pub fn post_bytes(&mut self, target: &str, body: &[u8]) -> io::Result<HttpResult> {
        self.request("POST", target, "application/octet-stream", body)
    }

    fn fill(&mut self) -> io::Result<()> {
        let mut chunk = [0u8; 16 * 1024];
        loop {
            match self.stream.read(&mut chunk) {
                Ok(0) => {
                    return Err(io::Error::new(
                        ErrorKind::UnexpectedEof,
                        "connection closed mid-response",
                    ))
                }
                Ok(n) => {
                    self.rbuf.extend_from_slice(&chunk[..n]);
                    return Ok(());
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
    }

    fn read_response(&mut self) -> io::Result<HttpResult> {
        let bad = |d: &str| io::Error::new(ErrorKind::InvalidData, format!("bad response: {d}"));
        // head
        let head_len = loop {
            if let Some(i) = find_blank_line(&self.rbuf) {
                break i;
            }
            self.fill()?;
        };
        let head = std::str::from_utf8(&self.rbuf[..head_len])
            .map_err(|_| bad("head is not utf-8"))?
            .to_string();
        let mut lines = head.split('\n').map(|l| l.strip_suffix('\r').unwrap_or(l));
        let status_line = lines.next().unwrap_or("");
        let status: u16 = status_line
            .split(' ')
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| bad("status line"))?;
        let mut headers = Vec::new();
        for line in lines {
            if line.is_empty() {
                continue;
            }
            let (name, value) = line.split_once(':').ok_or_else(|| bad("header line"))?;
            headers.push((name.to_ascii_lowercase(), value.trim().to_string()));
        }
        let len: usize = headers
            .iter()
            .find(|(n, _)| n == "content-length")
            .and_then(|(_, v)| v.parse().ok())
            .ok_or_else(|| bad("missing content-length"))?;
        // body
        while self.rbuf.len() < head_len + len {
            self.fill()?;
        }
        let body = self.rbuf[head_len..head_len + len].to_vec();
        self.rbuf.drain(..head_len + len);
        Ok(HttpResult { status, headers, body })
    }
}

/// Index just past the first blank line (`\r\n\r\n` or `\n\n`).
fn find_blank_line(buf: &[u8]) -> Option<usize> {
    let mut i = 0;
    while i < buf.len() {
        if buf[i] == b'\n' {
            let rest = &buf[i + 1..];
            if rest.first() == Some(&b'\n') {
                return Some(i + 2);
            }
            if rest.len() >= 2 && rest[0] == b'\r' && rest[1] == b'\n' {
                return Some(i + 3);
            }
        }
        i += 1;
    }
    None
}
