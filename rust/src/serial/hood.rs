//! Hood-array utilities and the per-stage serial oracle.
//!
//! A *hood array* is the paper's central data structure (Figure 1): n
//! slots split into blocks of d, each block holding the upper-hull corners
//! of its d input points, left-justified and REMOTE-padded.

use crate::geometry::point::{live_prefix, Point, REMOTE};
use crate::serial::monotone_chain;

/// Check the paper's block invariant INV(d): every d-block is a valid
/// hood (live prefix strictly x-increasing, convex, then REMOTE).
pub fn check_block_invariant(hood: &[Point], d: usize) -> Result<(), String> {
    use crate::geometry::predicates::{orient2d, Orientation};
    if hood.len() % d != 0 {
        return Err(format!("hood len {} not a multiple of d={d}", hood.len()));
    }
    for (b, blk) in hood.chunks(d).enumerate() {
        let live = live_prefix(blk);
        for (i, p) in blk.iter().enumerate() {
            if i < live.len() && !p.is_live() {
                return Err(format!("block {b}: dead slot {i} inside live prefix"));
            }
            if i >= live.len() && p.is_live() {
                return Err(format!("block {b}: live slot {i} after dead slot"));
            }
        }
        for w in live.windows(2) {
            if w[0].x >= w[1].x {
                return Err(format!("block {b}: x-order violated"));
            }
        }
        for w in live.windows(3) {
            if orient2d(w[0], w[2], w[1]) != Orientation::Left {
                return Err(format!("block {b}: not strictly convex"));
            }
        }
    }
    Ok(())
}

/// Serial oracle for one merge stage (ref_stage in the python twin):
/// recompute each 2d-block's hood from its live corners by monotone chain.
pub fn oracle_stage(hood: &[Point], d: usize) -> Vec<Point> {
    assert_eq!(hood.len() % (2 * d), 0);
    let mut out = Vec::with_capacity(hood.len());
    for blk in hood.chunks(2 * d) {
        // live corners sit in the live prefixes of the two d-halves (not
        // one contiguous prefix of the 2d block); both are x-sorted and
        // P's x-range precedes Q's, so a flat filter stays sorted.
        let live: Vec<Point> = blk.iter().copied().filter(|p| p.is_live()).collect();
        let merged = monotone_chain::upper_hull(&live);
        out.extend_from_slice(&merged);
        out.resize(out.len() + 2 * d - merged.len(), REMOTE);
    }
    out
}

/// Hood of the whole array (n-slot block) via the serial baseline.
pub fn oracle_hood(points: &[Point], slots: usize) -> Vec<Point> {
    let hull = monotone_chain::upper_hull(points);
    let mut out = hull;
    assert!(out.len() <= slots);
    out.resize(slots, REMOTE);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::generators::{generate, Distribution};
    use crate::geometry::point::pad_to_hood;

    #[test]
    fn oracle_stage_preserves_invariant() {
        let pts = generate(Distribution::UniformSquare, 64, 21);
        let mut hood = pad_to_hood(&pts, 64);
        let mut d = 2;
        while d < 64 {
            hood = oracle_stage(&hood, d);
            check_block_invariant(&hood, 2 * d).unwrap();
            d *= 2;
        }
        let live = live_prefix(&hood).to_vec();
        assert_eq!(live, monotone_chain::upper_hull(&pts));
    }

    #[test]
    fn invariant_rejects_bad_blocks() {
        // live after dead
        let hood = vec![REMOTE, Point::new(0.5, 0.5)];
        assert!(check_block_invariant(&hood, 2).is_err());
        // x-order violated
        let hood = vec![Point::new(0.5, 0.5), Point::new(0.2, 0.2)];
        assert!(check_block_invariant(&hood, 2).is_err());
        // concave triple
        let hood = vec![
            Point::new(0.1, 0.5),
            Point::new(0.5, 0.1),
            Point::new(0.9, 0.5),
            REMOTE,
        ];
        assert!(check_block_invariant(&hood, 4).is_err());
    }

    #[test]
    fn invariant_accepts_oracle_blocks() {
        let pts = generate(Distribution::Circle, 32, 2);
        let hood = pad_to_hood(&pts, 32);
        check_block_invariant(&hood, 1).unwrap();
        let out = oracle_stage(&hood, 1);
        check_block_invariant(&out, 2).unwrap();
    }
}
