//! Content-addressed session snapshot store.
//!
//! A streaming session checkpoints as:
//!
//!   * **chunks** — packed little-endian `f64` pairs (16 bytes/point),
//!     named by the sha256 of their bytes.  Identical chains across
//!     epochs or sessions dedup to one chunk.
//!   * a **manifest** — one versioned JSON document per sid listing
//!     `{epoch, hull_chunks, pending_chunks, ledger, checksums}` plus the
//!     scalar counters needed to restore accounting bit-identically.
//!
//! Chunks are written before the manifest that references them, so a
//! crash can orphan chunks but never commit a manifest with dangling
//! references.  Every chunk read is re-hashed; any mismatch, truncation,
//! or malformed manifest surfaces as the typed [`StoreError::Corrupt`]
//! ("snapshot-corrupt" on the wire), never a panic or a wrong hull.
//!
//! Two impls: [`MemStore`] (tests, rebalance transfers) and [`FsStore`]
//! (`[store] dir`; atomic temp-file + rename commits).

mod fs;
mod mem;
pub mod sha256;

use std::collections::BTreeMap;
use std::fmt;

use crate::geometry::point::Point;
use crate::util::json::{self, Json};

pub use fs::FsStore;
pub use mem::MemStore;

/// Manifest schema version written by this build.
pub const MANIFEST_VERSION: u64 = 1;

/// Pending points are split into blocks of this many points so an
/// unmerged tail rewrites only its last partial chunk per checkpoint.
pub const PENDING_CHUNK_POINTS: usize = 4096;

/// sha256 content id of a chunk.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ChunkId(pub [u8; 32]);

impl ChunkId {
    pub fn of(data: &[u8]) -> ChunkId {
        ChunkId(sha256::sha256(data))
    }

    pub fn to_hex(&self) -> String {
        self.0.iter().map(|b| format!("{b:02x}")).collect()
    }

    pub fn from_hex(s: &str) -> Option<ChunkId> {
        if s.len() != 64 || !s.bytes().all(|b| b.is_ascii_hexdigit()) {
            return None;
        }
        let mut out = [0u8; 32];
        for (i, byte) in out.iter_mut().enumerate() {
            *byte = u8::from_str_radix(&s[2 * i..2 * i + 2], 16).ok()?;
        }
        Some(ChunkId(out))
    }
}

impl fmt::Debug for ChunkId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "ChunkId({})", self.to_hex())
    }
}

impl fmt::Display for ChunkId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_hex())
    }
}

/// Store failures.  `Corrupt` is the typed durability error: its wire
/// form always starts with the machine-parseable token `snapshot-corrupt`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StoreError {
    /// Chunk bytes, manifest structure, or checksums fail verification.
    Corrupt(String),
    /// Underlying I/O failed (disk full, permissions, ...).
    Io(String),
}

impl fmt::Display for StoreError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StoreError::Corrupt(d) => {
                write!(f, "{}: {d}", crate::errors::TypedError::SnapshotCorrupt.wire_token())
            }
            StoreError::Io(d) => {
                write!(f, "{}: {d}", crate::errors::TypedError::SnapshotIo.wire_token())
            }
        }
    }
}

impl std::error::Error for StoreError {}

/// Backing storage for chunks + manifests.  Implementations must make
/// `put_manifest` atomic (readers see the old or the new manifest, never
/// a torn one) and `get_chunk` verifying (re-hash on read).
pub trait SnapshotStore: Send + Sync {
    /// Store `data` under its content id.  Returns the id and whether
    /// the chunk was newly written (false = dedup hit).
    fn put_chunk(&self, data: &[u8]) -> Result<(ChunkId, bool), StoreError>;

    /// Fetch a chunk and verify its hash; a missing or mutated chunk is
    /// `Corrupt`.
    fn get_chunk(&self, id: ChunkId) -> Result<Vec<u8>, StoreError>;

    /// Atomically install `text` as the manifest for `sid`.
    fn put_manifest(&self, sid: u64, text: &str) -> Result<(), StoreError>;

    /// The manifest for `sid`, or `None` if it was never snapshotted.
    fn get_manifest(&self, sid: u64) -> Result<Option<String>, StoreError>;

    /// Every sid with a committed manifest.
    fn list_sids(&self) -> Result<Vec<u64>, StoreError>;
}

// ---------------------------------------------------------- point codec

/// Pack points as little-endian f64 pairs (16 bytes/point); the inverse
/// of [`decode_points`].  Bit-exact: f64 -> bytes -> f64 is the identity.
pub fn encode_points(pts: &[Point]) -> Vec<u8> {
    let mut out = Vec::with_capacity(pts.len() * 16);
    for p in pts {
        out.extend_from_slice(&p.x.to_le_bytes());
        out.extend_from_slice(&p.y.to_le_bytes());
    }
    out
}

/// Unpack a point chunk; a length that is not a multiple of 16 means the
/// chunk was truncated or spliced.
pub fn decode_points(bytes: &[u8]) -> Result<Vec<Point>, StoreError> {
    if bytes.len() % 16 != 0 {
        return Err(StoreError::Corrupt(format!(
            "point chunk length {} not a multiple of 16",
            bytes.len()
        )));
    }
    let mut out = Vec::with_capacity(bytes.len() / 16);
    for pair in bytes.chunks_exact(16) {
        let x = f64::from_le_bytes(pair[..8].try_into().unwrap());
        let y = f64::from_le_bytes(pair[8..].try_into().unwrap());
        out.push(Point::new(x, y));
    }
    Ok(out)
}

// ------------------------------------------------------- session state

/// One epoch's delta record: the pending survivors consumed by the merge
/// plus the resulting canonical chains.  `ledger[e-1]` reconstructs the
/// hull as of epoch `e`.
#[derive(Clone, Debug, PartialEq)]
pub struct LedgerEntry {
    pub survivors: Vec<Point>,
    pub upper: Vec<Point>,
    pub lower: Vec<Point>,
}

/// The complete logical state of a session — everything a restore needs
/// to be bit-identical to the uninterrupted original, including the
/// epoch ledger that serves `SHULL <sid> <epoch>` time travel.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct SessionState {
    pub epoch: u64,
    pub merge_threshold: usize,
    pub inserted: u64,
    pub absorbed: u64,
    pub upper: Vec<Point>,
    pub lower: Vec<Point>,
    pub pending: Vec<Point>,
    pub ledger: Vec<LedgerEntry>,
}

/// Byte accounting for one checkpoint (feeds `snapshot_bytes_total`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WriteReport {
    /// Bytes physically written: new chunks + the manifest.  Dedup'd
    /// chunks cost nothing.
    pub bytes_written: u64,
}

// ------------------------------------------------------ snapshot write

struct ChunkWriter<'a> {
    store: &'a dyn SnapshotStore,
    checksums: BTreeMap<String, Json>,
    bytes_written: u64,
}

impl<'a> ChunkWriter<'a> {
    fn put(&mut self, pts: &[Point]) -> Result<String, StoreError> {
        let data = encode_points(pts);
        let (id, wrote) = self.store.put_chunk(&data)?;
        if wrote {
            self.bytes_written += data.len() as u64;
        }
        let hex = id.to_hex();
        self.checksums.insert(hex.clone(), Json::Num(data.len() as f64));
        Ok(hex)
    }
}

/// Checkpoint `state` for `sid`: chunks first, manifest last (commit
/// point).  Returns byte accounting for metrics.
pub fn write_snapshot(
    store: &dyn SnapshotStore,
    sid: u64,
    state: &SessionState,
) -> Result<WriteReport, StoreError> {
    let mut w = ChunkWriter { store, checksums: BTreeMap::new(), bytes_written: 0 };

    let upper = w.put(&state.upper)?;
    let lower = w.put(&state.lower)?;
    let mut pending = Vec::new();
    for block in state.pending.chunks(PENDING_CHUNK_POINTS.max(1)) {
        pending.push(Json::Str(w.put(block)?));
    }
    let mut ledger = Vec::with_capacity(state.ledger.len());
    for entry in &state.ledger {
        let survivors = w.put(&entry.survivors)?;
        let e_upper = w.put(&entry.upper)?;
        let e_lower = w.put(&entry.lower)?;
        ledger.push(Json::obj(vec![
            ("survivors", Json::Str(survivors)),
            ("upper", Json::Str(e_upper)),
            ("lower", Json::Str(e_lower)),
        ]));
    }

    let manifest = Json::obj(vec![
        ("version", Json::Num(MANIFEST_VERSION as f64)),
        ("sid", Json::Num(sid as f64)),
        ("epoch", Json::Num(state.epoch as f64)),
        ("merge_threshold", Json::Num(state.merge_threshold as f64)),
        ("inserted", Json::Num(state.inserted as f64)),
        ("absorbed", Json::Num(state.absorbed as f64)),
        (
            "hull_chunks",
            Json::obj(vec![("upper", Json::Str(upper)), ("lower", Json::Str(lower))]),
        ),
        ("pending_chunks", Json::Arr(pending)),
        ("ledger", Json::Arr(ledger)),
        ("checksums", Json::Obj(w.checksums.clone())),
    ]);
    let text = manifest.to_string();
    store.put_manifest(sid, &text)?;
    Ok(WriteReport { bytes_written: w.bytes_written + text.len() as u64 })
}

// ------------------------------------------------------- snapshot read

struct ChunkReader<'a> {
    store: &'a dyn SnapshotStore,
    checksums: &'a BTreeMap<String, Json>,
}

impl<'a> ChunkReader<'a> {
    fn get(&self, hex: &str) -> Result<Vec<Point>, StoreError> {
        let id = ChunkId::from_hex(hex)
            .ok_or_else(|| StoreError::Corrupt(format!("bad chunk id {hex:?}")))?;
        let want_len = self
            .checksums
            .get(hex)
            .and_then(Json::as_f64)
            .ok_or_else(|| StoreError::Corrupt(format!("chunk {hex} missing from checksums")))?;
        let data = self.store.get_chunk(id)?;
        if data.len() as f64 != want_len {
            return Err(StoreError::Corrupt(format!(
                "chunk {hex}: manifest says {want_len} bytes, store has {}",
                data.len()
            )));
        }
        decode_points(&data)
    }
}

fn field<'a>(m: &'a Json, key: &str) -> Result<&'a Json, StoreError> {
    m.get(key)
        .ok_or_else(|| StoreError::Corrupt(format!("manifest missing {key:?}")))
}

fn field_u64(m: &Json, key: &str) -> Result<u64, StoreError> {
    let v = field(m, key)?
        .as_f64()
        .ok_or_else(|| StoreError::Corrupt(format!("manifest {key:?} not a number")))?;
    if v < 0.0 || v.fract() != 0.0 {
        return Err(StoreError::Corrupt(format!("manifest {key:?} not a non-negative integer")));
    }
    Ok(v as u64)
}

fn field_str<'a>(m: &'a Json, key: &str) -> Result<&'a str, StoreError> {
    field(m, key)?
        .as_str()
        .ok_or_else(|| StoreError::Corrupt(format!("manifest {key:?} not a string")))
}

/// Load the snapshot for `sid`; `None` when no manifest exists.  Every
/// structural or integrity failure is `Corrupt` — restore either yields
/// the exact checkpointed state or a typed error.
pub fn read_snapshot(
    store: &dyn SnapshotStore,
    sid: u64,
) -> Result<Option<SessionState>, StoreError> {
    let Some(text) = store.get_manifest(sid)? else {
        return Ok(None);
    };
    let manifest = json::parse(&text)
        .map_err(|e| StoreError::Corrupt(format!("manifest for sid {sid}: {e}")))?;

    let version = field_u64(&manifest, "version")?;
    if version != MANIFEST_VERSION {
        return Err(StoreError::Corrupt(format!(
            "manifest version {version} (this build reads {MANIFEST_VERSION})"
        )));
    }
    let checksums = field(&manifest, "checksums")?
        .as_obj()
        .ok_or_else(|| StoreError::Corrupt("manifest checksums not an object".into()))?;
    let r = ChunkReader { store, checksums };

    let hulls = field(&manifest, "hull_chunks")?;
    let upper = r.get(field_str(hulls, "upper")?)?;
    let lower = r.get(field_str(hulls, "lower")?)?;

    let mut pending = Vec::new();
    let pending_chunks = field(&manifest, "pending_chunks")?
        .as_arr()
        .ok_or_else(|| StoreError::Corrupt("pending_chunks not an array".into()))?;
    for c in pending_chunks {
        let hex = c
            .as_str()
            .ok_or_else(|| StoreError::Corrupt("pending chunk id not a string".into()))?;
        pending.extend(r.get(hex)?);
    }

    let epoch = field_u64(&manifest, "epoch")?;
    let ledger_arr = field(&manifest, "ledger")?
        .as_arr()
        .ok_or_else(|| StoreError::Corrupt("ledger not an array".into()))?;
    if ledger_arr.len() as u64 != epoch {
        return Err(StoreError::Corrupt(format!(
            "ledger has {} entries but epoch is {epoch}",
            ledger_arr.len()
        )));
    }
    let mut ledger = Vec::with_capacity(ledger_arr.len());
    for entry in ledger_arr {
        ledger.push(LedgerEntry {
            survivors: r.get(field_str(entry, "survivors")?)?,
            upper: r.get(field_str(entry, "upper")?)?,
            lower: r.get(field_str(entry, "lower")?)?,
        });
    }

    Ok(Some(SessionState {
        epoch,
        merge_threshold: field_u64(&manifest, "merge_threshold")?.max(1) as usize,
        inserted: field_u64(&manifest, "inserted")?,
        absorbed: field_u64(&manifest, "absorbed")?,
        upper,
        lower,
        pending,
        ledger,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pts(v: &[(f64, f64)]) -> Vec<Point> {
        v.iter().map(|&(x, y)| Point::new(x, y)).collect()
    }

    fn sample_state() -> SessionState {
        let upper = pts(&[(0.0, 0.0), (0.5, 0.9), (1.0, 0.1)]);
        let lower = pts(&[(0.0, 0.0), (0.4, -0.5), (1.0, 0.1)]);
        SessionState {
            epoch: 2,
            merge_threshold: 64,
            inserted: 41,
            absorbed: 30,
            upper: upper.clone(),
            lower: lower.clone(),
            pending: pts(&[(0.25, 0.25), (0.125, -0.0625)]),
            ledger: vec![
                LedgerEntry {
                    survivors: pts(&[(0.0, 0.0), (1.0, 0.1)]),
                    upper: pts(&[(0.0, 0.0), (1.0, 0.1)]),
                    lower: pts(&[(0.0, 0.0), (1.0, 0.1)]),
                },
                LedgerEntry { survivors: pts(&[(0.5, 0.9), (0.4, -0.5)]), upper, lower },
            ],
        }
    }

    #[test]
    fn chunk_id_hex_roundtrip() {
        let id = ChunkId::of(b"abc");
        assert_eq!(
            id.to_hex(),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(ChunkId::from_hex(&id.to_hex()), Some(id));
        assert_eq!(ChunkId::from_hex("zz"), None);
        assert_eq!(ChunkId::from_hex(&"a".repeat(63)), None);
    }

    #[test]
    fn point_codec_is_bit_exact() {
        let p = pts(&[(0.1, -0.7), (f64::MIN_POSITIVE, -0.0), (1.0, 1e-300)]);
        let enc = encode_points(&p);
        assert_eq!(enc.len(), 48);
        let dec = decode_points(&enc).unwrap();
        for (a, b) in p.iter().zip(&dec) {
            assert_eq!(a.x.to_bits(), b.x.to_bits());
            assert_eq!(a.y.to_bits(), b.y.to_bits());
        }
        assert!(matches!(decode_points(&enc[..15]), Err(StoreError::Corrupt(_))));
    }

    #[test]
    fn roundtrip_through_mem_store() {
        let store = MemStore::new();
        let state = sample_state();
        let report = write_snapshot(&store, 7, &state).unwrap();
        assert!(report.bytes_written > 0);
        let back = read_snapshot(&store, 7).unwrap().unwrap();
        assert_eq!(back, state);
        assert_eq!(read_snapshot(&store, 8).unwrap(), None);
        assert_eq!(store.list_sids().unwrap(), vec![7]);
    }

    #[test]
    fn rewrite_dedups_unchanged_chunks() {
        let store = MemStore::new();
        let state = sample_state();
        let first = write_snapshot(&store, 1, &state).unwrap();
        // identical state again: every chunk dedups, only the manifest is written
        let second = write_snapshot(&store, 1, &state).unwrap();
        assert!(second.bytes_written < first.bytes_written);
        let manifest_len = store.get_manifest(1).unwrap().unwrap().len() as u64;
        assert_eq!(second.bytes_written, manifest_len);
    }

    #[test]
    fn empty_session_roundtrips() {
        let store = MemStore::new();
        let state = SessionState { merge_threshold: 4, ..SessionState::default() };
        write_snapshot(&store, 3, &state).unwrap();
        let back = read_snapshot(&store, 3).unwrap().unwrap();
        assert_eq!(back.epoch, 0);
        assert!(back.upper.is_empty() && back.pending.is_empty() && back.ledger.is_empty());
    }

    #[test]
    fn bit_flipped_chunk_is_typed_corrupt() {
        let store = MemStore::new();
        write_snapshot(&store, 9, &sample_state()).unwrap();
        for id in store.chunk_ids() {
            let tampered = store.tamper_chunk(id, |data| {
                if data.is_empty() {
                    data.push(0);
                } else {
                    data[0] ^= 0x01;
                }
            });
            assert!(tampered);
            let err = read_snapshot(&store, 9).unwrap_err();
            assert!(err.to_string().starts_with("snapshot-corrupt"), "{err}");
            // restore the original bytes for the next iteration
            store.tamper_chunk(id, |data| {
                if data.len() == 1 && data[0] == 0 {
                    data.clear();
                } else {
                    data[0] ^= 0x01;
                }
            });
        }
        assert!(read_snapshot(&store, 9).is_ok());
    }

    #[test]
    fn truncated_chunk_is_typed_corrupt() {
        let store = MemStore::new();
        write_snapshot(&store, 4, &sample_state()).unwrap();
        let victim = store
            .chunk_ids()
            .into_iter()
            .find(|id| store.get_chunk(*id).map(|d| d.len() >= 16).unwrap_or(false))
            .unwrap();
        store.tamper_chunk(victim, |data| data.truncate(data.len() - 7));
        let err = read_snapshot(&store, 4).unwrap_err();
        assert!(err.to_string().starts_with("snapshot-corrupt"), "{err}");
    }

    #[test]
    fn malformed_manifests_are_typed_corrupt_never_panic() {
        let store = MemStore::new();
        write_snapshot(&store, 2, &sample_state()).unwrap();
        let good = store.get_manifest(2).unwrap().unwrap();
        let bad_cases: Vec<String> = vec![
            "not json at all".into(),
            "{}".into(),
            good.replace("\"version\": 1", "\"version\": 99"),
            good.replace("\"epoch\": 2", "\"epoch\": 7"),           // ledger length mismatch
            good.replace("\"inserted\": 41", "\"inserted\": -1"),
            good.replace("\"inserted\": 41", "\"inserted\": 1.5"),
            {
                // swap one checksum's length so verification trips
                let idx = good.find(": 48").unwrap();
                format!("{}: 47{}", &good[..idx], &good[idx + 4..])
            },
        ];
        for bad in bad_cases {
            store.put_manifest(2, &bad).unwrap();
            match read_snapshot(&store, 2) {
                Err(e) => assert!(e.to_string().starts_with("snapshot-corrupt"), "{e}: {bad}"),
                Ok(v) => panic!("accepted malformed manifest {bad:?} -> {v:?}"),
            }
        }
        store.put_manifest(2, &good).unwrap();
        assert_eq!(read_snapshot(&store, 2).unwrap().unwrap(), sample_state());
    }

    #[test]
    fn manifest_references_only_checksummed_chunks() {
        let store = MemStore::new();
        let state = sample_state();
        write_snapshot(&store, 5, &state).unwrap();
        let manifest = json::parse(&store.get_manifest(5).unwrap().unwrap()).unwrap();
        let checksums = manifest.get("checksums").unwrap().as_obj().unwrap();
        // every chunk the store holds for this write is accounted for
        for id in store.chunk_ids() {
            let data = store.get_chunk(id).unwrap();
            let want = checksums.get(&id.to_hex()).and_then(Json::as_f64).unwrap();
            assert_eq!(want, data.len() as f64);
        }
        assert_eq!(manifest.get("version").unwrap().as_f64(), Some(1.0));
    }
}
