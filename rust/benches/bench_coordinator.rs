//! E6 — coordinator overhead and batching policy: throughput/latency of
//! the serving layer itself (native backend so the backend cost is tiny
//! and the router/batcher dominate), swept over batch size and flush
//! deadline.
//!
//! Run: `cargo bench --bench bench_coordinator`

use std::sync::Arc;

use wagener_hull::benchkit::{Bencher, Report};
use wagener_hull::coordinator::{
    BackendKind, BatcherConfig, Coordinator, CoordinatorConfig, HullRequest,
};
use wagener_hull::geometry::generators::{generate, Distribution};

fn coord(max_batch: usize, flush_us: u64) -> Arc<Coordinator> {
    Arc::new(
        Coordinator::start(CoordinatorConfig {
            backend: BackendKind::Native,
            batcher: BatcherConfig { max_batch, flush_us, queue_cap: 4096 },
            self_check: false,
            ..Default::default()
        })
        .unwrap(),
    )
}

fn main() {
    let b = Bencher::default();
    let pts = generate(Distribution::Disk, 200, 5);

    // direct backend call = the floor (no batcher, no channels)
    let mut report = Report::new("E6: coordinator overhead (native backend, 200-pt reqs)");
    report.add(b.run("floor/native_full_hull", || {
        wagener_hull::wagener::full_hull(std::hint::black_box(&pts))
    }));

    for (mb, flush) in [(1usize, 50u64), (4, 200), (8, 200), (8, 1000)] {
        let c = coord(mb, flush);
        let pts2 = pts.clone();
        report.add(b.run(&format!("coordinator/batch{mb}_flush{flush}us"), move || {
            c.compute(pts2.clone()).unwrap()
        }));
    }
    report.finish();

    // concurrent wave throughput at different batching policies
    let mut report = Report::new("E6b: wave throughput (8 threads x 25 reqs)");
    for (mb, flush) in [(1usize, 100u64), (8, 400), (16, 800)] {
        let c = coord(mb, flush);
        report.add(b.run_batched(
            &format!("wave/batch{mb}_flush{flush}us"),
            200,
            || {
                let mut handles = Vec::new();
                for t in 0..8u64 {
                    let c = c.clone();
                    handles.push(std::thread::spawn(move || {
                        let pts = generate(Distribution::Disk, 150, t);
                        let waits: Vec<_> = (0..25)
                            .map(|_| {
                                c.submit(HullRequest {
                                    id: c.next_id(),
                                    points: pts.clone(),
                                })
                            })
                            .collect();
                        for w in waits {
                            w.recv().unwrap().unwrap();
                        }
                    }));
                }
                for h in handles {
                    h.join().unwrap();
                }
            },
        ));
        let snap = c.snapshot().0;
        report.note(format!(
            "batch{mb}_flush{flush}: mean_batch_size={}",
            snap.get("mean_batch_size").unwrap()
        ));
    }
    report.finish();
}
