//! L3 coordinator: the serving framework around the hull pipelines.
//!
//! Shaped like a vLLM-style router: requests enter through
//! [`Coordinator::submit`], are preprocessed (f32 quantization, sort,
//! general-position screening), routed into per-size-class queues, batched
//! by the dynamic batcher (flush on batch-full or deadline), executed on
//! the configured backend (PJRT artifacts by default — python never runs
//! here), and returned with queue/execute timings.
//!
//! Degenerate inputs (duplicate points / duplicate x-coordinates violate
//! the paper's general-position assumption) short-circuit to an exact
//! serial fallback instead of poisoning the Wagener fast path.

pub mod backend;
pub mod batcher;
pub mod metrics;
pub mod request;
pub mod router;

pub use backend::{BackendKind, HullBackend};
pub use batcher::BatcherConfig;
pub use metrics::{
    GatewayMetrics, GatewayRoute, GatewayRouteMetrics, Histogram, HistogramSnapshot,
    IoLoopMetrics, IoMetrics, Metrics, MetricsFrame, MetricsSnapshot,
};
pub use request::{HullReply, HullRequest, HullResponse, RequestError};
pub use router::{Breaker, Coordinator, CoordinatorConfig, PrefilterMode};
