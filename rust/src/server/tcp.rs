//! Threaded TCP server: one accept loop, one handler thread per
//! connection, all sharing the coordinator (thread-based substitute for
//! the usual async runtime; connections are long-lived and few, work is
//! CPU-bound, so thread-per-connection is the right shape here).

use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::coordinator::{Coordinator, HullRequest};
use crate::log_info;

use super::proto::{self, ProtoError, Request, Response};

/// Server knobs (config file: `[server]`).
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// bind address, e.g. "127.0.0.1:7878"; port 0 picks a free port.
    pub addr: String,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { addr: "127.0.0.1:7878".into() }
    }
}

/// Handle to a running server (shutdown on drop).
pub struct ServerHandle {
    pub local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    pub connections: Arc<AtomicU64>,
}

impl ServerHandle {
    pub fn stop(mut self) {
        self.stop_inner();
    }

    fn stop_inner(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // poke the accept loop awake
        let _ = TcpStream::connect(self.local_addr);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop_inner();
    }
}

/// Start serving `coordinator` on `cfg.addr` (non-blocking; returns a
/// handle).  The coordinator must outlive the handle (Arc).
pub fn serve(coordinator: Arc<Coordinator>, cfg: &ServerConfig) -> std::io::Result<ServerHandle> {
    let listener = TcpListener::bind(&cfg.addr)?;
    let local_addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let connections = Arc::new(AtomicU64::new(0));
    log_info!("serving on {local_addr} (backend={})", coordinator.backend_name());

    let stop2 = stop.clone();
    let conns2 = connections.clone();
    let accept_thread = std::thread::Builder::new()
        .name("hull-accept".into())
        .spawn(move || {
            for stream in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                match stream {
                    Ok(s) => {
                        conns2.fetch_add(1, Ordering::Relaxed);
                        let coord = coordinator.clone();
                        let _ = std::thread::Builder::new()
                            .name("hull-conn".into())
                            .spawn(move || handle_connection(s, coord));
                    }
                    Err(e) => {
                        log_info!("accept error: {e}");
                    }
                }
            }
        })?;

    Ok(ServerHandle { local_addr, stop, accept_thread: Some(accept_thread), connections })
}

fn handle_connection(stream: TcpStream, coord: Arc<Coordinator>) {
    let peer = stream.peer_addr().ok();
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = BufWriter::new(stream);
    loop {
        let req = match proto::read_request(&mut reader) {
            Ok(r) => r,
            Err(ProtoError::Eof) => break,
            Err(e) => {
                let _ = proto::write_response(
                    &mut writer,
                    &Response::HullErr { id: 0, message: e.to_string() },
                );
                break;
            }
        };
        match req {
            Request::Quit => break,
            Request::Ping => {
                if proto::write_response(&mut writer, &Response::Pong).is_err() {
                    break;
                }
            }
            Request::Stats => {
                let snap = coord.snapshot().0.to_string();
                if proto::write_response(&mut writer, &Response::Stats(snap)).is_err() {
                    break;
                }
            }
            Request::Hull { id, points } => {
                let reply = coord.submit(HullRequest { id, points });
                let resp = match reply.recv() {
                    Ok(Ok(h)) => Response::Hull {
                        id,
                        upper: h.upper,
                        lower: h.lower,
                        backend: h.backend.to_string(),
                        queue_ns: h.queue_ns,
                        exec_ns: h.exec_ns,
                    },
                    Ok(Err(e)) => Response::HullErr { id, message: e.to_string() },
                    Err(_) => Response::HullErr { id, message: "coordinator gone".into() },
                };
                if proto::write_response(&mut writer, &resp).is_err() {
                    break;
                }
            }
        }
    }
    let _ = peer;
}
