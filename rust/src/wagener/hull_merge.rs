//! `merge_hulls` — the paper's common-tangent machinery applied to two
//! *precomputed* convex hulls (hull ⊕ hull inputs, not leaf merges).
//!
//! The streaming-session subsystem re-hulls `current hull ∪ pending` on
//! every merge; re-sorting the union and running a full pipeline would
//! throw away the structure both sides already have.  Instead:
//!
//! * **x-disjoint chains** (one hull entirely left of the other): the
//!   block-pair tangent search from `merge.rs` (`find_tangent`, the
//!   paper's mam1..mam5 sampled phases) locates the common tangent in
//!   O(√h · …) predicate evaluations, and the merged chain is a pair of
//!   slice copies.  This is exactly the [H(P) | H(Q)] merge the paper
//!   runs at every pipeline stage, now exposed as a standalone entry
//!   point.
//! * **x-overlapping chains** (the common streaming case): the two
//!   vertex sequences are interleaved by a linear two-pointer merge
//!   (both are already x-sorted — nothing is re-sorted), x-classes are
//!   collapsed to their extreme-y representative, and one strict-turn
//!   scan over the ≤ h₁+h₂ vertices rebuilds the chain.
//!
//! Both paths finish with (or consist of) a strict-turn monotone scan,
//! so the output is *canonical*: bit-identical to the chain a one-shot
//! hull of the union of the two vertex sets would produce, including
//! under cross-hull collinearity and duplicate x (exact predicates
//! throughout).  Correctness does not depend on which touch corner the
//! sampled phases return when the tangent passes through a collinear
//! run: every mutually-supporting pair lies on the same support line
//! (convexity makes local support global), and the trailing scan drops
//! the collinear middles.

use super::merge::find_tangent;
use super::stage::stage_dims;
use crate::geometry::point::{dedup_x, pad_to_hood, Point};
use crate::serial::monotone_chain;

/// Which strategy merged a chain pair (exposed for tests, the CLI, and
/// benches — the tangent path is the one the paper's machinery serves).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MergePath {
    /// One side empty: the other chain verbatim.
    Trivial,
    /// x-disjoint chains: sampled common-tangent search (mam1..mam5).
    Tangent,
    /// x-overlapping chains: linear interleave + strict-turn rescan.
    Interleave,
}

impl MergePath {
    pub fn name(&self) -> &'static str {
        match self {
            MergePath::Trivial => "trivial",
            MergePath::Tangent => "tangent",
            MergePath::Interleave => "interleave",
        }
    }
}

/// Merge two *upper-hull* chains (each canonical: x-strictly-increasing,
/// strict turns only, as every backend produces).  Returns the canonical
/// upper chain of the union of the two vertex sets and the path taken.
pub fn merge_upper_hulls(a: &[Point], b: &[Point]) -> (Vec<Point>, MergePath) {
    if a.is_empty() {
        return (b.to_vec(), MergePath::Trivial);
    }
    if b.is_empty() {
        return (a.to_vec(), MergePath::Trivial);
    }
    // strict inequality: a shared boundary x needs the dedup of the
    // interleave path, not the tangent's general-position block
    let (l, r) = if a[a.len() - 1].x < b[0].x {
        (a, b)
    } else if b[b.len() - 1].x < a[0].x {
        (b, a)
    } else {
        return (interleave_upper(a, b), MergePath::Interleave);
    };
    (tangent_merge_upper(l, r), MergePath::Tangent)
}

/// Merge two *lower-hull* chains.  Mirrors y and reuses the upper
/// machinery: negation is exact in f64, so the result stays canonical.
pub fn merge_lower_hulls(a: &[Point], b: &[Point]) -> (Vec<Point>, MergePath) {
    fn mirror(chain: &[Point]) -> Vec<Point> {
        chain.iter().map(|p| Point::new(p.x, -p.y)).collect()
    }
    let (merged, path) = merge_upper_hulls(&mirror(a), &mirror(b));
    (mirror(&merged), path)
}

/// Merge two full hulls, each given as `(upper, lower)` chains.  The two
/// chains of one hull share their x-range, so upper and lower always take
/// the same path; it is returned once.
pub fn merge_hulls(
    a: (&[Point], &[Point]),
    b: (&[Point], &[Point]),
) -> ((Vec<Point>, Vec<Point>), MergePath) {
    let (upper, path) = merge_upper_hulls(a.0, b.0);
    let (lower, _) = merge_lower_hulls(a.1, b.1);
    ((upper, lower), path)
}

/// x-disjoint case: the paper's sampled tangent phases over a block pair
/// [H(L) | H(R)], then two slice copies and a canonicalizing scan.
fn tangent_merge_upper(l: &[Point], r: &[Point]) -> Vec<Point> {
    let d = l.len().max(r.len()).next_power_of_two().max(2);
    let (d1, d2) = stage_dims(d);
    let mut blk = pad_to_hood(l, d);
    blk.extend(pad_to_hood(r, d));
    let t = find_tangent(&blk, d1, d2);
    // mam6 without the REMOTE fill: the chain is materialized compactly
    let mut chain = Vec::with_capacity(t.pidx + 1 + (2 * d - t.qidx));
    chain.extend_from_slice(&l[..=t.pidx]);
    chain.extend_from_slice(&r[t.qidx - d..]);
    // the tangent can pass through corners of BOTH chains (cross-hull
    // collinearity); the strict-turn rescan of the ≤ h₁+h₂ survivors
    // drops the middles, making the output canonical
    monotone_chain::upper_hull(&chain)
}

/// x-overlapping case: linear interleave of two x-sorted chains (no
/// re-sort), extreme-y per x-class, strict-turn scan.
fn interleave_upper(a: &[Point], b: &[Point]) -> Vec<Point> {
    let mut merged = Vec::with_capacity(a.len() + b.len());
    let (mut i, mut j) = (0usize, 0usize);
    while i < a.len() && j < b.len() {
        let take_a =
            a[i].x < b[j].x || (a[i].x == b[j].x && a[i].y <= b[j].y);
        if take_a {
            merged.push(a[i]);
            i += 1;
        } else {
            merged.push(b[j]);
            j += 1;
        }
    }
    merged.extend_from_slice(&a[i..]);
    merged.extend_from_slice(&b[j..]);
    // duplicate x across the chains: only the max-y representative can
    // sit on the upper chain (same rule as the exact degenerate path)
    let merged = dedup_x(&merged, true);
    monotone_chain::upper_hull(&merged)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::canonical_full_hull as oracle;
    use crate::geometry::generators::{generate, squeeze_x, Distribution};
    use crate::util::rng::Rng;

    #[test]
    fn empty_sides_are_trivial() {
        let pts = generate(Distribution::Disk, 40, 3);
        let (u, l) = oracle(&pts);
        let ((mu, ml), path) = merge_hulls((&u, &l), (&[], &[]));
        assert_eq!(path, MergePath::Trivial);
        assert_eq!((mu, ml), (u.clone(), l.clone()));
        let ((mu, ml), path) = merge_hulls((&[], &[]), (&u, &l));
        assert_eq!(path, MergePath::Trivial);
        assert_eq!((mu, ml), (u, l));
    }

    #[test]
    fn disjoint_pairs_take_the_tangent_path_and_match_oracle() {
        let mut rng = Rng::new(71);
        for case in 0..200 {
            let da = Distribution::ALL[case % 7];
            let db = Distribution::ALL[(case + 3) % 7];
            let a = squeeze_x(&generate(da, rng.range_usize(1, 200), rng.next_u64()), 0.0, 0.47);
            let b = squeeze_x(&generate(db, rng.range_usize(1, 200), rng.next_u64()), 0.53, 1.0);
            let (au, al) = oracle(&a);
            let (bu, bl) = oracle(&b);
            let ((mu, ml), path) = merge_hulls((&au, &al), (&bu, &bl));
            assert_eq!(path, MergePath::Tangent, "case {case}");
            let union: Vec<Point> = a.iter().chain(b.iter()).copied().collect();
            let (wu, wl) = oracle(&union);
            assert_eq!(mu, wu, "case {case} upper ({} ∪ {})", da.name(), db.name());
            assert_eq!(ml, wl, "case {case} lower ({} ∪ {})", da.name(), db.name());
        }
    }

    #[test]
    fn overlapping_pairs_interleave_and_match_oracle() {
        let mut rng = Rng::new(73);
        for case in 0..200 {
            let da = Distribution::ALL[case % 7];
            let db = Distribution::ALL[(case + 5) % 7];
            let a = generate(da, rng.range_usize(1, 300), rng.next_u64());
            let b = generate(db, rng.range_usize(1, 300), rng.next_u64());
            let (au, al) = oracle(&a);
            let (bu, bl) = oracle(&b);
            let ((mu, ml), _path) = merge_hulls((&au, &al), (&bu, &bl));
            let union: Vec<Point> = a.iter().chain(b.iter()).copied().collect();
            let (wu, wl) = oracle(&union);
            assert_eq!(mu, wu, "case {case} upper ({} ∪ {})", da.name(), db.name());
            assert_eq!(ml, wl, "case {case} lower ({} ∪ {})", da.name(), db.name());
        }
    }

    #[test]
    fn duplicate_x_across_hulls_is_exact() {
        // both hulls own vertices at x = 0.5 with different y: the merged
        // chain must keep only the extreme-y representative, exactly like
        // the one-shot degenerate path
        let a = vec![
            Point::new(0.1, 0.4),
            Point::new(0.5, 0.9),
            Point::new(0.5, 0.1),
            Point::new(0.8, 0.4),
        ];
        let b = vec![
            Point::new(0.3, 0.3),
            Point::new(0.5, 0.95),
            Point::new(0.5, 0.05),
            Point::new(0.9, 0.5),
        ];
        let (au, al) = oracle(&a);
        let (bu, bl) = oracle(&b);
        let ((mu, ml), path) = merge_hulls((&au, &al), (&bu, &bl));
        assert_eq!(path, MergePath::Interleave);
        let union: Vec<Point> = a.iter().chain(b.iter()).copied().collect();
        let (wu, wl) = oracle(&union);
        assert_eq!(mu, wu);
        assert_eq!(ml, wl);
    }

    #[test]
    fn cross_hull_collinearity_is_canonicalized() {
        // the common tangent passes through two corners of EACH chain:
        // only the outermost pair survives (collinear middles dropped),
        // matching the strict-turn oracle bit-for-bit
        // exact collinearity on dyadic coordinates:
        let a = vec![
            Point::new(0.0, 0.25),
            Point::new(0.125, 0.375),
            Point::new(0.25, 0.5),
            Point::new(0.3125, 0.0625),
        ];
        let b = vec![
            Point::new(0.5, 0.75),
            Point::new(0.625, 0.875),
            Point::new(0.75, 0.5),
        ];
        // (0.125,0.375),(0.25,0.5),(0.5,0.75),(0.625,0.875) all on y = x + 0.25
        let (au, al) = oracle(&a);
        let (bu, bl) = oracle(&b);
        let ((mu, ml), path) = merge_hulls((&au, &al), (&bu, &bl));
        assert_eq!(path, MergePath::Tangent);
        let union: Vec<Point> = a.iter().chain(b.iter()).copied().collect();
        let (wu, wl) = oracle(&union);
        assert_eq!(mu, wu, "collinear tangent upper");
        assert_eq!(ml, wl, "collinear tangent lower");
    }

    #[test]
    fn single_point_hulls_merge() {
        let a = vec![Point::new(0.2, 0.3)];
        let b = vec![Point::new(0.7, 0.6)];
        let ((mu, ml), path) = merge_hulls((&a, &a), (&b, &b));
        assert_eq!(path, MergePath::Tangent);
        assert_eq!(mu, vec![a[0], b[0]]);
        assert_eq!(ml, vec![a[0], b[0]]);
    }

    #[test]
    fn one_hull_swallowing_the_other() {
        // b strictly inside a: the merge must return a unchanged
        let a = generate(Distribution::Circle, 64, 9);
        let mut b = squeeze_x(&generate(Distribution::Disk, 64, 10), 0.4, 0.6);
        for p in b.iter_mut() {
            *p = Point::new(p.x, 0.4 + p.y * 0.2).quantize_f32();
        }
        let (au, al) = oracle(&a);
        let (bu, bl) = oracle(&b);
        let ((mu, ml), _) = merge_hulls((&au, &al), (&bu, &bl));
        let union: Vec<Point> = a.iter().chain(b.iter()).copied().collect();
        let (wu, wl) = oracle(&union);
        assert_eq!(mu, wu);
        assert_eq!(ml, wl);
    }
}
