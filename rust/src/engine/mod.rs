//! The engine: N independent coordinator shards behind one facade.
//!
//! PR 3/PR 4 left two process-wide chokepoints on the serving path: every
//! one-shot request funnels through a single batcher thread + one shared
//! `Mutex<Receiver>` exec channel, and every session op goes through one
//! global registry map lock.  The engine removes both by *partitioning the
//! serving state* — the same divide-and-conquer move the hull pipeline
//! itself makes, lifted one level up:
//!
//! ```text
//! callers ──► [Engine router] ──► shard 0: batcher ─► exec pool ─► metrics
//!                 │                        └ SessionRegistry slice
//!                 ├────────────► shard 1: batcher ─► exec pool ─► metrics
//!                 │                        └ SessionRegistry slice
//!                 └────────────► …  (N fully independent shards)
//! ```
//!
//! * **One-shot requests** route to the cheapest queue (fewest in-flight
//!   requests, round-robin tie-break) — shards share nothing, so N shards
//!   means N batchers and N exec channels with no cross-shard locks.
//! * **Session verbs** route by a stable function of the sid: shard `i`
//!   of `N` allocates sids `≡ i+1 (mod N)` (see
//!   [`SessionRegistry::new_striped`]), and `(sid - 1) % N` sends every
//!   later verb back to the owning shard, so a session is pinned to one
//!   shard — one registry slice, one backend pool, one metrics sink — for
//!   its whole lifetime.  Eviction, capacity and accounting are all
//!   per-shard; the global `max_sessions` cap is split across shards
//!   remainder-aware (`M/N + 1` for the first `M mod N` shards).
//! * **STATS** merges one coherent [`MetricsFrame`] per shard — counters
//!   and gauges sum, histograms merge bucket-wise — and also reports the
//!   raw `per_shard` array.  Each gauge is read once per shard, so the
//!   aggregate can never pair reads from two different moments.
//!
//! A 1-shard engine is bit- and protocol-identical to the pre-engine
//! server: same coordinator, same registry, same wire bytes — the entire
//! pre-existing integration suite runs unmodified against it.

pub mod placement;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, OnceLock, PoisonError, RwLock};
use std::time::Instant;

use crate::coordinator::{
    BackendKind, Coordinator, CoordinatorConfig, GatewayMetrics, HullReply, HullRequest,
    HullResponse, IoMetrics, Metrics, MetricsFrame, MetricsSnapshot, RequestError,
};
use crate::geometry::point::Point;
use crate::log_warn;
use crate::store::{self, SessionState, SnapshotStore};
use crate::stream::{
    AddOutcome, SessionError, SessionHullSnapshot, SessionRegistry, StreamConfig,
};
use crate::util::json::Json;

pub use placement::{Placement, PlacementKind, Ring, Stripe};

/// Engine configuration (config file: `[engine]`).
#[derive(Clone)]
pub struct EngineConfig {
    /// coordinator-shard count; 0 = auto.  Auto resolves to 1 for the
    /// `pjrt` backend (each shard's workers load the artifact registry —
    /// multiplying loaders must be an explicit choice, the PR 3 worker
    /// rule one level up) and to `clamp(hw_threads / 4, 1, 8)` for host
    /// backends (each shard carries a batcher thread + worker pool, so
    /// shards beyond a fraction of the machine only add switching).
    pub shards: usize,
    /// per-shard coordinator template.  `workers == 0` (auto) splits the
    /// hardware threads across shards (`max(1, hw / shards)` each) instead
    /// of letting every shard claim the whole machine.
    pub coordinator: CoordinatorConfig,
    /// stream knobs; `max_sessions` is the GLOBAL cap, split across
    /// shards remainder-aware.
    pub stream: StreamConfig,
    /// admission ceiling per shard (config: `[engine] max_queued`,
    /// 0 = unbounded): a shard with this many requests in flight stops
    /// admitting; when every healthy shard is at its ceiling new one-shot
    /// requests and `SADD`s answer `overloaded` immediately instead of
    /// queueing (load shedding — see `shed_total`).
    pub max_queued: usize,
    /// sid → shard routing policy (config: `[engine] placement`); see
    /// [`placement`] for the two implementations.
    pub placement: PlacementKind,
    /// snapshot store (config: `[store] dir`): sessions checkpoint on
    /// merge/close/evict/shutdown, `SOPEN <sid>` restores, and rebalance
    /// has a durable fallback.  `None` = sessions are memory-only
    /// (pre-PR 8 behaviour).
    pub store: Option<Arc<dyn SnapshotStore>>,
}

impl std::fmt::Debug for EngineConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("EngineConfig")
            .field("shards", &self.shards)
            .field("coordinator", &self.coordinator)
            .field("stream", &self.stream)
            .field("max_queued", &self.max_queued)
            .field("placement", &self.placement)
            .field("store", &self.store.is_some())
            .finish()
    }
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            shards: 1,
            coordinator: CoordinatorConfig::default(),
            stream: StreamConfig::default(),
            max_queued: 0,
            placement: PlacementKind::Stripe,
            store: None,
        }
    }
}

impl EngineConfig {
    /// Shard count for tests/tools honoring the `ENGINE_SHARDS`
    /// environment variable (tier1 exports `ENGINE_SHARDS=4` to run the
    /// server integration suite against a sharded engine).
    pub fn shards_from_env(default: usize) -> usize {
        std::env::var("ENGINE_SHARDS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .unwrap_or(default)
    }

    /// Resolve `shards` (0 = auto; see the field docs for the rule).
    pub fn effective_shards(&self) -> usize {
        if self.shards > 0 {
            self.shards
        } else if self.coordinator.backend == BackendKind::Pjrt {
            1
        } else {
            let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            (hw / 4).clamp(1, 8)
        }
    }
}

/// One shard: a complete coordinator (own batcher, own exec pool, own
/// metrics sink) plus its slice of the session space.
struct Shard {
    coordinator: Arc<Coordinator>,
    registry: Arc<SessionRegistry>,
}

/// Facade over `N` independent coordinator shards.
pub struct Engine {
    shards: Vec<Shard>,
    /// round-robin cursor: rotates the starting shard of the
    /// cheapest-queue scan so equal-load shards alternate.
    rr: AtomicUsize,
    /// the global session cap (sum of the per-shard slices).
    max_sessions_total: usize,
    max_points: usize,
    /// per-shard admission ceiling (0 = unbounded).
    max_queued: usize,
    /// sid → shard routing policy (pure function of the sid).
    placement: Box<dyn Placement>,
    /// sessions routed away from their designated shard (capacity spill
    /// at open, explicit [`Engine::rebalance`]).  `rebalance` holds the
    /// WRITE lock across the whole detach + install move, so any op that
    /// reads the routing mid-move blocks until the session has landed.
    overrides: RwLock<HashMap<u64, usize>>,
    /// engine-global sid allocator for [`PlacementKind::Ring`] (stripe
    /// placement keeps the per-registry striped allocators): hands out
    /// 1, 2, 3, … — the exact sequence a 1-shard engine produces, which
    /// is what the shards=1 vs shards=N parity gates compare against.
    next_sid: AtomicU64,
    /// snapshot store for `SOPEN <sid>` restores + rebalance fallback
    /// (the per-shard registries hold their own clones for checkpoints).
    store: Option<Arc<dyn SnapshotStore>>,
    /// the HTTP gateway's metrics sink, registered once at gateway start;
    /// STATS serializes a zeroed stand-in until (or unless) one exists,
    /// so the `gateway` key is schema-stable across deployments.
    gateway_metrics: OnceLock<Arc<GatewayMetrics>>,
}

fn read_lock<T>(l: &RwLock<T>) -> std::sync::RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(PoisonError::into_inner)
}

fn write_lock<T>(l: &RwLock<T>) -> std::sync::RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(PoisonError::into_inner)
}

impl Engine {
    /// Build and start `N` shards.  Fails if any shard's backend pool
    /// cannot be constructed; already-started shards shut down on drop.
    pub fn start(cfg: EngineConfig) -> Result<Engine, String> {
        let n = cfg.effective_shards();
        let mut shard_cfg = cfg.coordinator.clone();
        if shard_cfg.workers == 0 && n > 1 && shard_cfg.backend != BackendKind::Pjrt {
            // auto workers must split the machine across shards: N shards
            // each auto-sizing to every hardware thread would book N× the
            // cores.  (pjrt auto already resolves to 1 per shard.)
            let hw = std::thread::available_parallelism().map(|h| h.get()).unwrap_or(1);
            shard_cfg.workers = (hw / n).max(1);
        }
        let mut coordinators = Vec::with_capacity(n);
        for _ in 0..n {
            coordinators.push(Arc::new(Coordinator::start(shard_cfg.clone())?));
        }
        let max_points =
            coordinators.iter().map(|c| c.max_points()).min().unwrap_or(usize::MAX);
        // the same brick-proofing rule serve() applies: a threshold above
        // the backend's request cap could never merge
        let stream = cfg.stream.clamp_threshold_to(max_points);
        let shards = coordinators
            .into_iter()
            .enumerate()
            .map(|(i, coordinator)| {
                let slice = StreamConfig {
                    // remainder-aware split: shard i gets M/N, +1 for the
                    // first M mod N shards, so the slices sum to exactly M
                    max_sessions: stream.max_sessions / n
                        + usize::from(i < stream.max_sessions % n),
                    ..stream.clone()
                };
                let registry = Arc::new(SessionRegistry::new_striped_with_store(
                    slice,
                    coordinator.metrics.clone(),
                    i as u64 + 1,
                    n as u64,
                    cfg.store.clone(),
                ));
                Shard { coordinator, registry }
            })
            .collect();
        Ok(Engine {
            shards,
            rr: AtomicUsize::new(0),
            max_sessions_total: stream.max_sessions,
            max_points,
            max_queued: cfg.max_queued,
            placement: cfg.placement.build(n),
            overrides: RwLock::new(HashMap::new()),
            next_sid: AtomicU64::new(1),
            store: cfg.store,
            gateway_metrics: OnceLock::new(),
        })
    }

    /// Wrap an already-built coordinator + registry as a 1-shard engine —
    /// the compatibility path behind [`crate::server::serve`] /
    /// [`crate::server::serve_with_sessions`], and the reason the whole
    /// pre-engine test suite keeps passing byte-for-byte.
    pub fn single(coordinator: Arc<Coordinator>, registry: Arc<SessionRegistry>) -> Engine {
        let max_points = coordinator.max_points();
        let max_sessions_total = registry.max_sessions();
        let store = registry.store();
        Engine {
            shards: vec![Shard { coordinator, registry }],
            rr: AtomicUsize::new(0),
            max_sessions_total,
            max_points,
            max_queued: 0,
            placement: PlacementKind::Stripe.build(1),
            overrides: RwLock::new(HashMap::new()),
            next_sid: AtomicU64::new(1),
            store,
            gateway_metrics: OnceLock::new(),
        }
    }

    // ------------------------------------------------------------ routing

    /// Admission-controlled shard choice for one-shot work.  Cheapest
    /// queue wins (fewest in-flight requests, round-robin rotated start
    /// so ties alternate), with two rejection layers on top:
    ///
    /// * shards whose circuit breaker is open are skipped — except that
    ///   the first caller after the cooldown is routed in as the
    ///   half-open probe;
    /// * shards at the `max_queued` ceiling are skipped (sibling shards
    ///   absorb the spill); when every healthy shard is at its ceiling
    ///   the request is shed with `overloaded`.
    ///
    /// The in-flight counts are relaxed reads — a stale value only
    /// softens the balance, never correctness.
    fn route_one_shot(&self) -> Result<&Shard, RequestError> {
        let n = self.shards.len();
        let start =
            if n == 1 { 0 } else { self.rr.fetch_add(1, Ordering::Relaxed) % n };
        let mut best: Option<(usize, u64)> = None;
        let mut any_healthy = false;
        for k in 0..n {
            let i = (start + k) % n;
            let c = &self.shards[i].coordinator;
            if c.breaker().blocked() {
                continue;
            }
            if c.breaker().state() == 2 {
                // this caller just flipped the breaker open → half-open:
                // its request IS the probe, ceiling notwithstanding
                return Ok(&self.shards[i]);
            }
            any_healthy = true;
            let load = c.metrics.in_flight();
            if self.max_queued != 0 && load >= self.max_queued as u64 {
                continue; // at ceiling: let a sibling absorb it
            }
            let better = match best {
                None => true,
                Some((_, b)) => load < b,
            };
            if better {
                best = Some((i, load));
            }
        }
        match best {
            Some((i, _)) => Ok(&self.shards[i]),
            None if any_healthy => {
                // every healthy shard is at its ceiling: shed, charged to
                // the scan's starting shard (merged STATS sum per-shard)
                Metrics::inc(&self.shards[start].coordinator.metrics.shed);
                Err(RequestError::Overloaded)
            }
            None => Err(RequestError::Backend("circuit breaker open".into())),
        }
    }

    /// The shard a sid routes to *right now*: the rebalance override map
    /// first (read lock — blocks while a rebalance is mid-move), then the
    /// placement function.  Unknown sids (including 0, never allocated)
    /// still land deterministically on some shard, which answers
    /// `unknown-session` exactly like a standalone registry.
    fn shard_index_for_sid(&self, sid: u64) -> usize {
        if self.shards.len() == 1 {
            return 0;
        }
        if let Some(&i) = read_lock(&self.overrides).get(&sid) {
            return i;
        }
        self.placement.shard_for(sid)
    }

    /// Run a session op against the sid's current shard, retrying when
    /// the routing changed underneath it.  A rebalance detaches the
    /// session (ops racing in see `unknown-session` from the donor shard)
    /// while holding the override write lock; re-reading the routing
    /// blocks on that lock until the move lands, and the op retries only
    /// if the answer actually changed — a genuinely unknown sid still
    /// errors on the first pass.
    fn with_routing<T>(
        &self,
        sid: u64,
        mut op: impl FnMut(&Shard) -> Result<T, SessionError>,
    ) -> Result<T, SessionError> {
        let mut idx = self.shard_index_for_sid(sid);
        loop {
            match op(&self.shards[idx]) {
                Err(SessionError::UnknownSession) => {
                    let now = self.shard_index_for_sid(sid);
                    if now == idx {
                        return Err(SessionError::UnknownSession);
                    }
                    idx = now;
                }
                r => return r,
            }
        }
    }

    // ----------------------------------------------------------- one-shot

    /// Submit a one-shot request to the cheapest admitting shard; the
    /// returned channel yields the response (immediately `overloaded`
    /// when every healthy shard is at its ceiling).
    pub fn submit(
        &self,
        req: HullRequest,
    ) -> mpsc::Receiver<Result<HullResponse, RequestError>> {
        let (tx, rx) = mpsc::channel();
        self.submit_with(req, HullReply::Channel(tx));
        rx
    }

    /// Submit a one-shot request with an explicit reply destination
    /// (see [`Coordinator::submit_with`]).  Admission rejections
    /// (`overloaded`, circuit-broken `backend`) answer through `reply`
    /// on the calling thread.
    pub fn submit_with(&self, req: HullRequest, reply: HullReply) {
        match self.route_one_shot() {
            Ok(shard) => shard.coordinator.submit_with(req, reply),
            Err(e) => reply.send(Err(e)),
        }
    }

    /// Non-blocking submit for the event-loop server: `f` runs on
    /// whichever thread completes the request — never parks the caller.
    pub fn submit_into(
        &self,
        req: HullRequest,
        f: impl FnOnce(Result<HullResponse, RequestError>) + Send + 'static,
    ) {
        self.submit_with(req, HullReply::sink(f));
    }

    /// Synchronous one-shot convenience wrapper.
    pub fn compute(&self, points: Vec<Point>) -> Result<HullResponse, RequestError> {
        self.route_one_shot()?.coordinator.compute(points)
    }

    // ----------------------------------------------------------- sessions

    /// `SOPEN`: open a fresh session.
    ///
    /// * **Stripe** — place on the shard with the most free capacity
    ///   (ties broken by shard order), falling back through the rest;
    ///   the shard's striped allocator picks the sid.  PR 5 behaviour,
    ///   unchanged.
    /// * **Ring** — allocate the next engine-global sid (1, 2, 3, …) and
    ///   install it on its ring-designated shard, spilling clockwise to
    ///   ring successors when that shard is full (recorded as a routing
    ///   override so later verbs find it).
    ///
    /// Only when every shard is full does the global cap error surface.
    pub fn session_open(&self) -> Result<u64, SessionError> {
        if self.placement.kind() == PlacementKind::Ring {
            return self.session_open_ring();
        }
        if self.shards.len() == 1 {
            return self.shards[0].registry.open();
        }
        let mut order: Vec<usize> = (0..self.shards.len()).collect();
        order.sort_by_key(|&i| {
            let r = &self.shards[i].registry;
            std::cmp::Reverse(r.max_sessions().saturating_sub(r.open_sessions()))
        });
        for i in order {
            match self.shards[i].registry.open() {
                Ok(sid) => return Ok(sid),
                Err(SessionError::Capacity { .. }) => continue,
                Err(e) => return Err(e),
            }
        }
        Err(SessionError::Capacity { max: self.max_sessions_total })
    }

    fn session_open_ring(&self) -> Result<u64, SessionError> {
        let sid = self.next_sid.fetch_add(1, Ordering::Relaxed);
        let fresh = SessionState {
            merge_threshold: self.shards[0].registry.merge_threshold(),
            ..SessionState::default()
        };
        let order = self.placement.order_for(sid);
        let designated = order[0];
        for &i in &order {
            match self.shards[i].registry.install(sid, fresh.clone()) {
                Ok(()) => {
                    if i != designated {
                        write_lock(&self.overrides).insert(sid, i);
                    }
                    return Ok(sid);
                }
                Err(SessionError::Capacity { .. }) => continue,
                Err(e) => return Err(e),
            }
        }
        Err(SessionError::Capacity { max: self.max_sessions_total })
    }

    /// `SOPEN <sid>`: restore a snapshotted session at its original sid —
    /// the durable-session half of open.  Answers `unknown-session` when
    /// no store is configured or the store has no manifest for the sid,
    /// `session already open` when it is currently live, and the typed
    /// `snapshot-corrupt` / `snapshot-io` errors when the stored bytes
    /// fail verification.  The restored hull, pending buffer, epoch and
    /// ledger are bit-identical to the last checkpoint.
    pub fn session_restore(&self, sid: u64) -> Result<u64, SessionError> {
        let Some(st) = &self.store else {
            return Err(SessionError::UnknownSession);
        };
        if sid == 0 {
            return Err(SessionError::UnknownSession);
        }
        let idx = self.shard_index_for_sid(sid);
        let shard = &self.shards[idx];
        let state = store::read_snapshot(st.as_ref(), sid)
            .map_err(SessionError::Snapshot)?
            .ok_or(SessionError::UnknownSession)?;
        shard.registry.install(sid, state)?;
        // a restored sid must never be re-issued by a later fresh open
        self.next_sid.fetch_max(sid + 1, Ordering::Relaxed);
        Metrics::inc(&shard.coordinator.metrics.restores);
        Ok(sid)
    }

    /// Move a live session to another shard: detach from its current
    /// home, install on `target`, and record the routing override (or
    /// clear it when the move lands the session back on its designated
    /// shard).  The override write lock is held across the whole move, so
    /// concurrent verbs for the sid block in [`Engine::with_routing`]'s
    /// re-route read rather than observing the gap; nothing about the
    /// session's hull, epoch, or accounting changes — the PR 5 parity
    /// gates hold across an arbitrary interleaving of rebalances.
    pub fn rebalance(&self, sid: u64, target: usize) -> Result<(), SessionError> {
        assert!(target < self.shards.len(), "rebalance target out of range");
        let mut ov = write_lock(&self.overrides);
        let src = ov.get(&sid).copied().unwrap_or_else(|| self.placement.shard_for(sid));
        if src == target {
            return Ok(());
        }
        let state = self.shards[src].registry.detach(sid)?;
        if let Err(e) = self.shards[target].registry.install(sid, state.clone()) {
            // the move failed; the session must survive.  Its old slot
            // can have been claimed by a racing open, so fall back to the
            // durable store if re-install also refuses.
            if self.shards[src].registry.install(sid, state.clone()).is_err() {
                match &self.store {
                    Some(st) => {
                        if let Err(e2) = store::write_snapshot(st.as_ref(), sid, &state) {
                            log_warn!("session {sid}: lost in failed rebalance: {e2}");
                        }
                    }
                    None => log_warn!("session {sid}: lost in failed rebalance (no store)"),
                }
            }
            return Err(e);
        }
        if self.placement.shard_for(sid) == target {
            ov.remove(&sid);
        } else {
            ov.insert(sid, target);
        }
        Ok(())
    }

    /// `SADD` on the owning shard (its registry, its backend pool).
    pub fn session_add(&self, sid: u64, points: &[Point]) -> Result<AddOutcome, SessionError> {
        self.session_add_deadline(sid, points, None)
    }

    /// [`Engine::session_add`] with the request's deadline: an `SADD`
    /// whose budget already expired answers `deadline-exceeded` without
    /// touching the session, and a pinned shard at its admission ceiling
    /// answers `overloaded` (sessions cannot spill to siblings — the sid
    /// owns its shard — so the ceiling sheds instead of rerouting).
    /// Neither rejection counts into `errors`: the request never entered
    /// the coordinator pipeline, so `in_flight` must not be disturbed.
    pub fn session_add_deadline(
        &self,
        sid: u64,
        points: &[Point],
        deadline: Option<Instant>,
    ) -> Result<AddOutcome, SessionError> {
        self.with_routing(sid, |shard| {
            if deadline.is_some_and(|d| Instant::now() >= d) {
                Metrics::inc(&shard.coordinator.metrics.deadline_exceeded);
                return Err(SessionError::Request(RequestError::DeadlineExceeded));
            }
            if self.max_queued != 0
                && shard.coordinator.metrics.in_flight() >= self.max_queued as u64
            {
                Metrics::inc(&shard.coordinator.metrics.shed);
                return Err(SessionError::Request(RequestError::Overloaded));
            }
            shard.registry.add(sid, points, &*shard.coordinator)
        })
    }

    /// `SHULL` on the owning shard (flushes pending first).
    pub fn session_hull(&self, sid: u64) -> Result<SessionHullSnapshot, SessionError> {
        self.session_hull_at(sid, None)
    }

    /// `SHULL <sid> [<epoch>]`: the live hull (flushing pending) when
    /// `epoch` is `None`, or the immutable historical hull as of the
    /// requested epoch from the session's ledger (no flush — a past
    /// epoch cannot change).  Epoch 0 is the empty hull every session
    /// starts from; an epoch beyond the session's current one answers
    /// `unknown-epoch`.
    pub fn session_hull_at(
        &self,
        sid: u64,
        epoch: Option<u64>,
    ) -> Result<SessionHullSnapshot, SessionError> {
        self.with_routing(sid, |shard| match epoch {
            None => shard.registry.hull(sid, &*shard.coordinator),
            Some(e) => shard.registry.hull_at(sid, e),
        })
    }

    /// `SCLOSE` on the owning shard: flushes (the final merge), writes a
    /// last checkpoint when a store is configured, then unregisters.
    pub fn session_close(&self, sid: u64) -> Result<(), SessionError> {
        self.with_routing(sid, |shard| shard.registry.close(sid, &*shard.coordinator))
    }

    /// Open sessions across every shard.
    pub fn open_sessions(&self) -> usize {
        self.shards.iter().map(|s| s.registry.open_sessions()).sum()
    }

    /// Run one eviction sweep on every shard (tests; each shard's own
    /// sweeper thread does this on its interval).
    pub fn sweep_now(&self) {
        for s in &self.shards {
            s.registry.sweep_now();
        }
    }

    // ------------------------------------------------------------ metrics

    /// Merged metrics: one coherent [`MetricsFrame`] per shard, summed
    /// once (counters and gauges sum, histograms merge bucket-wise), plus
    /// the raw `per_shard` array and the shard count.  For shards = 1 the
    /// top-level fields equal the lone coordinator's own snapshot.
    pub fn snapshot(&self) -> MetricsSnapshot {
        self.stats(None)
    }

    /// [`Engine::snapshot`] with the server's connection gauge spliced in
    /// (`active_connections` is engine-global — connections are not
    /// sharded — and read exactly once).
    pub fn stats(&self, active_connections: Option<u64>) -> MetricsSnapshot {
        self.stats_io(active_connections, None)
    }

    /// [`Engine::stats`] with the event-loop server's I/O gauges spliced
    /// in under the `io` key (per-loop connection counts, bytes in/out,
    /// frame counters, decode latency, backpressure stalls).
    pub fn stats_io(
        &self,
        active_connections: Option<u64>,
        io: Option<&IoMetrics>,
    ) -> MetricsSnapshot {
        let frames: Vec<MetricsFrame> =
            self.shards.iter().map(|s| s.coordinator.metrics.frame()).collect();
        let mut merged = MetricsFrame::default();
        for f in &frames {
            merged.merge(f);
        }
        let Json::Obj(mut obj) = merged.to_json() else { unreachable!("frame json is an object") };
        obj.insert("shards".into(), Json::Num(self.shards.len() as f64));
        obj.insert(
            "per_shard".into(),
            Json::Arr(frames.iter().map(MetricsFrame::to_json).collect()),
        );
        if let Some(active) = active_connections {
            obj.insert("active_connections".into(), Json::Num(active as f64));
        }
        // schema normalization: `io` and `gateway` are always present so
        // STATS serializes one stable shape regardless of connection core
        // (the threaded shim has no event-loop gauges) or whether an HTTP
        // gateway is running — absent subsystems report zeroes
        obj.insert(
            "io".into(),
            match io {
                Some(io) => io.to_json(),
                None => IoMetrics::new(0).to_json(),
            },
        );
        obj.insert(
            "gateway".into(),
            match self.gateway_metrics.get() {
                Some(gw) => gw.to_json(),
                None => GatewayMetrics::default().to_json(),
            },
        );
        MetricsSnapshot(Json::Obj(obj))
    }

    /// Register the HTTP gateway's metrics sink (once; later calls keep
    /// the first).  Returns the registered sink so gateway start-up can
    /// share one `Arc` between its loops and STATS.
    pub fn register_gateway_metrics(&self) -> Arc<GatewayMetrics> {
        self.gateway_metrics.get_or_init(|| Arc::new(GatewayMetrics::default())).clone()
    }

    // ---------------------------------------------------------- topology

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The routing policy in force.
    pub fn placement_kind(&self) -> PlacementKind {
        self.placement.kind()
    }

    /// The sid's current shard index (overrides included) — tests,
    /// affinity checks, rebalance tooling.
    pub fn shard_of(&self, sid: u64) -> usize {
        self.shard_index_for_sid(sid)
    }

    /// Shard `i`'s coordinator (tests, benches, affinity checks).
    pub fn shard_coordinator(&self, i: usize) -> &Arc<Coordinator> {
        &self.shards[i].coordinator
    }

    /// Shard `i`'s registry slice (tests, benches, affinity checks).
    pub fn shard_registry(&self, i: usize) -> &Arc<SessionRegistry> {
        &self.shards[i].registry
    }

    pub fn backend_name(&self) -> &'static str {
        self.shards[0].coordinator.backend_name()
    }

    /// The per-request point cap (min across shards; they are identical
    /// when built by [`Engine::start`]).
    pub fn max_points(&self) -> usize {
        self.max_points
    }

    /// Global session cap (sum of the per-shard slices).
    pub fn max_sessions(&self) -> usize {
        self.max_sessions_total
    }

    /// Effective (possibly clamped) merge threshold.
    pub fn merge_threshold(&self) -> usize {
        self.shards[0].registry.merge_threshold()
    }

    /// Exec workers per shard.
    pub fn workers_per_shard(&self) -> usize {
        self.shards[0].coordinator.workers()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::generators::{generate, Distribution};

    fn engine(shards: usize, max_sessions: usize) -> Engine {
        engine_queued(shards, max_sessions, 0)
    }

    fn engine_queued(shards: usize, max_sessions: usize, max_queued: usize) -> Engine {
        Engine::start(EngineConfig {
            shards,
            coordinator: CoordinatorConfig {
                backend: BackendKind::Serial,
                workers: 1,
                ..Default::default()
            },
            stream: StreamConfig { max_sessions, idle_ttl_ms: 0, ..Default::default() },
            max_queued,
            ..Default::default()
        })
        .unwrap()
    }

    fn engine_placed(
        shards: usize,
        max_sessions: usize,
        placement: PlacementKind,
        store: Option<Arc<dyn crate::store::SnapshotStore>>,
    ) -> Engine {
        Engine::start(EngineConfig {
            shards,
            coordinator: CoordinatorConfig {
                backend: BackendKind::Serial,
                workers: 1,
                ..Default::default()
            },
            stream: StreamConfig { max_sessions, idle_ttl_ms: 0, ..Default::default() },
            max_queued: 0,
            placement,
            store,
        })
        .unwrap()
    }

    #[test]
    fn capacity_splits_remainder_aware() {
        let e = engine(4, 10); // 10 = 3 + 3 + 2 + 2
        let per: Vec<usize> = (0..4).map(|i| e.shard_registry(i).max_sessions()).collect();
        assert_eq!(per, vec![3, 3, 2, 2]);
        assert_eq!(per.iter().sum::<usize>(), 10);
        assert_eq!(e.max_sessions(), 10);
    }

    #[test]
    fn global_cap_enforced_across_shards() {
        let e = engine(4, 2); // shards 2 and 3 get zero capacity
        let a = e.session_open().unwrap();
        let b = e.session_open().unwrap();
        let err = e.session_open().unwrap_err();
        assert_eq!(err, SessionError::Capacity { max: 2 });
        assert_eq!(err.to_string(), "session capacity 2 reached");
        e.session_close(a).unwrap();
        e.session_open().unwrap();
        let _ = b;
    }

    #[test]
    fn sids_route_back_to_their_allocating_shard() {
        let e = engine(4, 100);
        let mut owned = [0usize; 4];
        for _ in 0..12 {
            let before: Vec<usize> =
                (0..4).map(|i| e.shard_registry(i).open_sessions()).collect();
            let sid = e.session_open().unwrap();
            let owner = ((sid - 1) % 4) as usize;
            owned[owner] += 1;
            // exactly the sid-residue shard gained a session
            for (i, b) in before.iter().enumerate() {
                let now = e.shard_registry(i).open_sessions();
                assert_eq!(now, b + usize::from(i == owner), "sid {sid} shard {i}");
            }
            e.session_add(sid, &[crate::geometry::point::Point::new(0.25, 0.75)])
                .unwrap();
        }
        assert_eq!(e.open_sessions(), 12);
        // balanced placement spreads the 12 sessions across all 4 shards
        assert_eq!(owned, [3, 3, 3, 3]);
    }

    #[test]
    fn one_shot_routing_spreads_and_answers_exactly() {
        let e = engine(3, 8);
        for k in 0..9u64 {
            let pts = generate(Distribution::ALL[(k % 7) as usize], 40 + k as usize, k);
            let resp = e.compute(pts.clone()).unwrap();
            let (u, l) = crate::serial::monotone_chain::full_hull(&pts);
            assert_eq!(resp.upper, u);
            assert_eq!(resp.lower, l);
        }
        // merged totals account for every request exactly once
        let snap = e.snapshot().0;
        assert_eq!(snap.get("responses").unwrap().as_usize(), Some(9));
        assert_eq!(snap.get("errors").unwrap().as_usize(), Some(0));
        let per = snap.get("per_shard").unwrap().as_arr().unwrap();
        assert_eq!(per.len(), 3);
        let spread: usize = per
            .iter()
            .map(|s| s.get("responses").unwrap().as_usize().unwrap())
            .sum();
        assert_eq!(spread, 9);
        assert_eq!(snap.get("shards").unwrap().as_usize(), Some(3));
    }

    #[test]
    fn single_wraps_existing_parts_unchanged() {
        let coord = Arc::new(
            Coordinator::start(CoordinatorConfig {
                backend: BackendKind::Serial,
                workers: 1,
                ..Default::default()
            })
            .unwrap(),
        );
        let reg = Arc::new(SessionRegistry::new(
            StreamConfig { max_sessions: 5, idle_ttl_ms: 0, ..Default::default() },
            coord.metrics.clone(),
        ));
        let e = Engine::single(coord, reg);
        assert_eq!(e.shard_count(), 1);
        assert_eq!(e.max_sessions(), 5);
        let sid = e.session_open().unwrap();
        assert_eq!(sid, 1); // stride-1 allocation, exactly the old registry
        e.session_close(sid).unwrap();
    }

    // ------------------------------------------------ admission control

    /// Simulate load by bumping the raw `requests` counter (in_flight =
    /// requests − responses − errors, all relaxed atomics) — fully
    /// deterministic, no racing against real workers.
    fn fake_in_flight(e: &Engine, shard: usize, n: u64) {
        Metrics::add(&e.shard_coordinator(shard).metrics.requests, n);
    }

    fn drain_fake(e: &Engine, shard: usize, n: u64) {
        Metrics::add(&e.shard_coordinator(shard).metrics.responses, n);
    }

    #[test]
    fn overload_sheds_with_typed_error() {
        let e = engine_queued(1, 4, 2);
        fake_in_flight(&e, 0, 2); // at the ceiling
        let pts = generate(Distribution::Disk, 40, 1);
        let err = e.compute(pts.clone()).unwrap_err();
        assert_eq!(err, RequestError::Overloaded);
        assert_eq!(err.to_string(), "overloaded");
        let snap = e.snapshot().0;
        assert_eq!(snap.get("shed_total").unwrap().as_usize(), Some(1));
        // shed requests never entered the pipeline: no error counted
        assert_eq!(snap.get("errors").unwrap().as_usize(), Some(0));
        drain_fake(&e, 0, 2); // load drains: admission resumes
        e.compute(pts).unwrap();
    }

    #[test]
    fn ceiling_spills_to_sibling_shard_first() {
        let e = engine_queued(2, 4, 1);
        fake_in_flight(&e, 0, 1); // shard 0 full, shard 1 idle
        for k in 0..4u64 {
            e.compute(generate(Distribution::Disk, 30 + k as usize, k)).unwrap();
        }
        let shard1 = e.shard_coordinator(1).metrics.frame();
        assert_eq!(shard1.responses, 4, "all traffic must spill to the idle sibling");
        assert_eq!(e.snapshot().0.get("shed_total").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn tripped_breaker_diverts_then_recovers_via_probe() {
        let e = engine_queued(2, 4, 0);
        // trip shard 0's breaker (3 consecutive batch failures)
        for _ in 0..3 {
            e.shard_coordinator(0).breaker().on_failure();
        }
        assert_eq!(e.shard_coordinator(0).breaker().state(), 1);
        for k in 0..4u64 {
            e.compute(generate(Distribution::Disk, 25 + k as usize, k)).unwrap();
        }
        assert_eq!(
            e.shard_coordinator(1).metrics.frame().responses,
            4,
            "open breaker must divert everything to the healthy shard"
        );
        // cooldown default is 1s — too long for a test; force-expire by
        // the only supported path: a successful probe closes the breaker
        e.shard_coordinator(0).breaker().on_success();
        assert_eq!(e.shard_coordinator(0).breaker().state(), 0);
    }

    #[test]
    fn all_shards_broken_answers_backend_error() {
        let e = engine_queued(1, 4, 0);
        for _ in 0..3 {
            e.shard_coordinator(0).breaker().on_failure();
        }
        let err = e.compute(generate(Distribution::Disk, 30, 2)).unwrap_err();
        assert!(matches!(err, RequestError::Backend(_)), "got {err:?}");
    }

    #[test]
    fn session_add_sheds_and_honors_deadline() {
        let e = engine_queued(1, 4, 1);
        let sid = e.session_open().unwrap();
        let pts = [crate::geometry::point::Point::new(0.25, 0.75)];
        // expired budget: typed deadline-exceeded, session untouched
        let err = e
            .session_add_deadline(sid, &pts, Some(Instant::now()))
            .unwrap_err();
        assert_eq!(err.to_string(), "deadline-exceeded");
        // shard at ceiling: typed overloaded
        fake_in_flight(&e, 0, 1);
        let err = e.session_add_deadline(sid, &pts, None).unwrap_err();
        assert_eq!(err.to_string(), "overloaded");
        assert_eq!(e.snapshot().0.get("shed_total").unwrap().as_usize(), Some(1));
        // load drains: the add lands
        drain_fake(&e, 0, 1);
        e.session_add(sid, &pts).unwrap();
        e.session_close(sid).unwrap();
    }

    // ------------------------------------- placement, rebalance, restore

    #[test]
    fn ring_allocates_sequential_sids_and_routes_back() {
        let e = engine_placed(4, 100, PlacementKind::Ring, None);
        assert_eq!(e.placement_kind(), PlacementKind::Ring);
        for expect in 1..=12u64 {
            let sid = e.session_open().unwrap();
            assert_eq!(sid, expect, "ring sids are the global 1,2,3,… sequence");
            let owner = e.shard_of(sid);
            assert_eq!(
                e.shard_registry(owner).open_sessions()
                    + (0..4)
                        .filter(|&i| i != owner)
                        .map(|i| e.shard_registry(i).open_sessions())
                        .sum::<usize>(),
                expect as usize
            );
            e.session_add(sid, &[crate::geometry::point::Point::new(0.25, 0.75)])
                .unwrap();
        }
        assert_eq!(e.open_sessions(), 12);
        // routing really is the ring function: each sid's verbs landed on
        // the shard the ring designates
        for sid in 1..=12u64 {
            let snap = e.session_hull(sid).unwrap();
            assert_eq!(snap.epoch, 1, "sid {sid} flushed exactly once");
            e.session_close(sid).unwrap();
        }
        assert_eq!(e.open_sessions(), 0);
    }

    #[test]
    fn ring_spills_to_successor_when_designated_shard_is_full() {
        // 2 shards, global cap 2 → 1 slot each.  Opening 2 sessions must
        // succeed regardless of which shards the ring designates; at
        // least one lives off its designated shard iff both hash to the
        // same shard — and verbs still find every session.
        let e = engine_placed(2, 2, PlacementKind::Ring, None);
        let a = e.session_open().unwrap();
        let b = e.session_open().unwrap();
        assert_eq!(e.open_sessions(), 2);
        assert_ne!(e.shard_of(a), e.shard_of(b), "1-slot shards force a spread");
        for sid in [a, b] {
            e.session_add(sid, &[crate::geometry::point::Point::new(0.5, 0.25)])
                .unwrap();
            e.session_close(sid).unwrap();
        }
        let err = {
            let c = e.session_open().unwrap();
            let d = e.session_open().unwrap();
            let err = e.session_open().unwrap_err();
            let _ = (c, d);
            err
        };
        assert_eq!(err, SessionError::Capacity { max: 2 });
    }

    #[test]
    fn rebalance_is_invisible_to_the_session() {
        let e = engine(2, 10);
        let sid = e.session_open().unwrap();
        let pts = generate(Distribution::Circle, 300, 42);
        let (first, rest) = pts.split_at(130);
        e.session_add(sid, first).unwrap();
        let home = e.shard_of(sid);
        let away = 1 - home;
        e.rebalance(sid, away).unwrap();
        assert_eq!(e.shard_of(sid), away);
        // gauges moved with the session
        assert_eq!(e.shard_registry(away).open_sessions(), 1);
        assert_eq!(e.shard_registry(home).open_sessions(), 0);
        e.session_add(sid, rest).unwrap();
        let snap = e.session_hull(sid).unwrap();
        let (u, l) = crate::serial::monotone_chain::full_hull(&pts);
        assert_eq!(snap.upper, u);
        assert_eq!(snap.lower, l);
        // moving back to the designated shard clears the override
        e.rebalance(sid, home).unwrap();
        assert!(read_lock(&e.overrides).is_empty());
        e.session_close(sid).unwrap();
        assert_eq!(e.session_hull(sid).unwrap_err(), SessionError::UnknownSession);
    }

    #[test]
    fn rebalance_of_unknown_sid_and_same_shard_are_exact() {
        let e = engine(2, 10);
        assert_eq!(e.rebalance(999, 1).unwrap_err(), SessionError::UnknownSession);
        let sid = e.session_open().unwrap();
        let here = e.shard_of(sid);
        e.rebalance(sid, here).unwrap(); // no-op, not an error
        assert_eq!(e.shard_of(sid), here);
    }

    #[test]
    fn restore_after_engine_restart_is_bit_identical() {
        use crate::store::MemStore;
        let store: Arc<MemStore> = Arc::new(MemStore::new());
        let pts = generate(Distribution::Disk, 400, 7);
        let (before, after) = pts.split_at(250);
        let (sid, hull_mid) = {
            let e = engine_placed(2, 10, PlacementKind::Stripe, Some(store.clone()));
            let sid = e.session_open().unwrap();
            e.session_add(sid, before).unwrap();
            let snap = e.session_hull(sid).unwrap();
            (sid, snap)
            // engine drops here: clean-shutdown checkpoint
        };
        let e = engine_placed(2, 10, PlacementKind::Stripe, Some(store.clone()));
        assert_eq!(e.open_sessions(), 0);
        assert_eq!(e.session_restore(sid).unwrap(), sid);
        let snap = e.session_hull(sid).unwrap();
        assert_eq!(snap.epoch, hull_mid.epoch);
        assert_eq!(snap.upper, hull_mid.upper);
        assert_eq!(snap.lower, hull_mid.lower);
        // the continued session converges on the same hull as one that
        // never restarted
        e.session_add(sid, after).unwrap();
        let fin = e.session_hull(sid).unwrap();
        let (u, l) = crate::serial::monotone_chain::full_hull(&pts);
        assert_eq!(fin.upper, u);
        assert_eq!(fin.lower, l);
        // restoring a live session is a typed error, not a duplicate
        assert_eq!(e.session_restore(sid).unwrap_err(), SessionError::AlreadyOpen);
        // restored sids are fenced off from fresh allocation
        let fresh = e.session_open().unwrap();
        assert_ne!(fresh, sid);
        let snap = e.snapshot().0;
        assert_eq!(snap.get("restores_total").unwrap().as_usize(), Some(1));
        assert!(snap.get("snapshots_written_total").unwrap().as_usize().unwrap() >= 1);
    }

    #[test]
    fn restore_without_store_or_snapshot_is_unknown_session() {
        let e = engine(2, 10);
        assert_eq!(e.session_restore(42).unwrap_err(), SessionError::UnknownSession);
        let store: Arc<crate::store::MemStore> = Arc::new(crate::store::MemStore::new());
        let e = engine_placed(2, 10, PlacementKind::Stripe, Some(store));
        assert_eq!(e.session_restore(42).unwrap_err(), SessionError::UnknownSession);
        assert_eq!(e.session_restore(0).unwrap_err(), SessionError::UnknownSession);
    }

    #[test]
    fn effective_shards_auto_rules() {
        let pjrt = EngineConfig {
            shards: 0,
            coordinator: CoordinatorConfig {
                backend: BackendKind::Pjrt,
                ..Default::default()
            },
            ..Default::default()
        };
        assert_eq!(pjrt.effective_shards(), 1, "pjrt auto-resolves to one shard");
        let host = EngineConfig { shards: 0, ..Default::default() };
        let n = host.effective_shards();
        assert!((1..=8).contains(&n), "host auto in [1, 8]: {n}");
        let explicit = EngineConfig { shards: 6, ..Default::default() };
        assert_eq!(explicit.effective_shards(), 6);
    }
}
