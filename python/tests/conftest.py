"""Shared fixtures: enable x64 before any jax computation traces."""

from compile.kernels import wagener

wagener.enable_x64()
