//! Wire protocol: line-oriented text, extending the paper's point-file
//! format ("count, then `x y` lines") with request framing.
//!
//! ```text
//! client -> server
//!   HULL <id> <m> [TMO=<ms>]\n  then m lines "x y"   full hull request
//!   SOPEN <id>\n                            open a streaming session
//!   SOPEN <id> <sid>\n                      restore a snapshotted session
//!   SADD <sid> <m> [TMO=<ms>]\n  then m lines "x y"  insert into a session
//!   SHULL <sid>\n                           authoritative session hull
//!   SHULL <sid> <epoch>\n                   historical hull at <epoch>
//!   SCLOSE <sid>\n                          close a session
//!   STATS\n                                 metrics snapshot (JSON line)
//!   PING\n                                  liveness
//!   QUIT\n                                  close connection
//!
//! server -> client
//!   HULL <id> OK <k_up> <k_lo> <backend> <queue_ns> <exec_ns>\n
//!     then k_up lines, then k_lo lines, then END\n
//!   HULL <id> ERR <message...>\n            request-level failure
//!   SOPEN <id> OK <sid>\n                   session token
//!   SADD <sid> OK <absorbed> <pending> <epoch>\n
//!   SHULL <sid> OK <epoch> <k_up> <k_lo>\n
//!     then k_up lines, then k_lo lines, then END\n
//!   SCLOSE <sid> OK\n
//!   SOPEN|SADD|SHULL|SCLOSE <sid> ERR <message...>\n
//!                                           session-level failure (the
//!                                           sid — the id for SOPEN — is
//!                                           echoed, same rule as HULL)
//!   ERR <id|-> <message...>\n               malformed frame (id echoed
//!                                           when the header parsed)
//!   STATS <json>\n       PONG\n
//! ```
//!
//! The `STATS` JSON object is the engine-merged aggregate (counters and
//! gauges summed from one coherent per-shard snapshot each, histograms
//! merged bucket-wise) extended with `shards` (coordinator-shard count),
//! `per_shard` (the raw per-shard snapshot array) and
//! `active_connections` (the server's connection gauge).
//!
//! The optional `TMO=<ms>` header token is a per-request deadline
//! override in milliseconds from arrival (caps the server's configured
//! `request_timeout_ms`); an expired request answers the typed error
//! `deadline-exceeded`.  Unrecognized trailing header tokens are ignored
//! — old servers serve new clients, minus the deadline.
//!
//! The optional second operand of `SOPEN` / `SHULL` is the durable-session
//! extension (PR 8): `SOPEN <id> <sid>` restores the snapshotted session
//! `<sid>` (errors `unknown-session` when nothing is stored under it,
//! `session already open` when it is live, or the typed
//! `snapshot-corrupt` / `snapshot-io` on bad bytes), and `SHULL <sid>
//! <epoch>` reads the immutable historical hull as of `<epoch>` from the
//! session's ledger without flushing (epoch 0 is the empty hull; a future
//! epoch errors `unknown-epoch`).  Unlike unknown header *tokens*, a
//! malformed second operand is rejected — silently ignoring it would
//! serve the live hull where history was asked for.

use std::io::{BufRead, Write};

use crate::geometry::point::Point;

/// A parsed client request.  `tmo_ms` is the optional per-request
/// deadline budget (text: `TMO=<ms>` header token; binary: the deadline
/// header extension behind the verb flag bit).
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Hull { id: u64, points: Vec<Point>, tmo_ms: Option<u32> },
    /// `restore` names a snapshotted sid to bring back; `None` opens a
    /// fresh session.
    SessionOpen { id: u64, restore: Option<u64> },
    SessionAdd { sid: u64, points: Vec<Point>, tmo_ms: Option<u32> },
    /// `epoch` selects a historical hull from the session's ledger;
    /// `None` is the live (flushing) read.
    SessionHull { sid: u64, epoch: Option<u64> },
    SessionClose { sid: u64 },
    Stats,
    Ping,
    Quit,
}

/// Which session verb a [`Response::SessionErr`] belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SessionVerb {
    Open,
    Add,
    Hull,
    Close,
}

impl SessionVerb {
    pub fn word(&self) -> &'static str {
        match self {
            SessionVerb::Open => "SOPEN",
            SessionVerb::Add => "SADD",
            SessionVerb::Hull => "SHULL",
            SessionVerb::Close => "SCLOSE",
        }
    }
}

/// A server reply (structured; formatting lives in write_response).
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    Hull {
        id: u64,
        upper: Vec<Point>,
        lower: Vec<Point>,
        backend: String,
        queue_ns: u64,
        exec_ns: u64,
    },
    HullErr { id: u64, message: String },
    /// Frame-level failure: the request never parsed.  `id` is echoed
    /// when the frame header got far enough to recover it, so clients
    /// correlating replies by request id can still match the failure.
    MalformedErr { id: Option<u64>, message: String },
    /// `SOPEN` accepted: the session token to use with the other verbs.
    SessionOpened { id: u64, sid: u64 },
    /// `SADD` accepted: lifetime absorbed count, current pending count,
    /// current epoch.
    SessionAdded { sid: u64, absorbed: u64, pending: u64, epoch: u64 },
    /// `SHULL` reply: the authoritative hull (pending flushed) and the
    /// epoch that produced it.
    SessionHull { sid: u64, epoch: u64, upper: Vec<Point>, lower: Vec<Point> },
    /// `SCLOSE` accepted.
    SessionClosed { sid: u64 },
    /// Session-level failure; the sid (request id for `SOPEN`) is echoed
    /// under the same rules as `HULL <id> ERR`.
    SessionErr { verb: SessionVerb, id: u64, message: String },
    Stats(String),
    Pong,
}

/// Protocol violations (distinct from request-level errors).
#[derive(Debug, PartialEq)]
pub enum ProtoError {
    Eof,
    /// The frame could not be parsed; `id` is present when the header
    /// parsed far enough to recover the request id.
    Malformed { id: Option<u64>, detail: String },
    /// DoS guard tripped; the header (and thus the id) did parse.
    /// `session` distinguishes an `SADD` frame (the error must echo as
    /// `SADD <sid> ERR …`, not `HULL <id> ERR …`).
    TooManyPoints { id: u64, points: usize, session: bool },
}

impl ProtoError {
    fn malformed(detail: impl Into<String>) -> ProtoError {
        ProtoError::Malformed { id: None, detail: detail.into() }
    }

    /// Attach a frame id to a mid-frame parse failure (Eof passes through).
    fn with_id(self, frame_id: u64) -> ProtoError {
        match self {
            ProtoError::Malformed { id: None, detail } => {
                ProtoError::Malformed { id: Some(frame_id), detail }
            }
            other => other,
        }
    }

    /// The failed frame's id, when it was recoverable.
    pub fn frame_id(&self) -> Option<u64> {
        match self {
            ProtoError::Eof => None,
            ProtoError::Malformed { id, .. } => *id,
            ProtoError::TooManyPoints { id, .. } => Some(*id),
        }
    }
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtoError::Eof => write!(f, "connection closed"),
            ProtoError::Malformed { detail, .. } => write!(f, "malformed request: {detail}"),
            ProtoError::TooManyPoints { points, .. } => {
                write!(f, "request of {points} points over limit")
            }
        }
    }
}

/// Hard cap on request size (DoS guard; far above the largest artifact).
pub const MAX_REQUEST_POINTS: usize = 1 << 22;

/// Longest text line the incremental decoder will buffer before declaring
/// the frame malformed.  A valid line is two f64 tokens (< 64 bytes); the
/// guard only exists so an unterminated garbage stream cannot grow a
/// connection's read buffer without bound.
pub const MAX_TEXT_LINE: usize = 64 * 1024;

/// Result of one incremental decode attempt over a byte buffer.
#[derive(Debug, PartialEq)]
pub enum Decoded<T> {
    /// A complete frame, plus the number of bytes it consumed from the
    /// front of the buffer.
    Frame(T, usize),
    /// Incomplete: the decoder needs at least this many TOTAL buffered
    /// bytes before it can make progress (for the text protocol this is
    /// simply `buf.len() + 1` — "any more input might finish the line").
    Need(usize),
}

/// Incrementally decode one text-protocol request from the front of
/// `buf` (the event-loop counterpart of [`read_request`]).
///
/// Parity with the blocking reader is by construction, not by a parallel
/// implementation: this function only finds the frame's extent (header
/// line + point lines for `HULL`/`SADD`), then delegates the actual parse
/// to [`read_request`] over exactly those bytes, so every accept/reject
/// decision and every error (id echo included) is bit-identical to the
/// threaded path.  When the extent itself cannot be determined — a
/// malformed header or an oversized count — delegation over the header
/// line alone reproduces the exact error the blocking reader would raise.
pub fn decode_text_request(buf: &[u8]) -> Result<Decoded<Request>, ProtoError> {
    decode_text_request_resync(buf).map_err(|(e, _)| e)
}

/// [`decode_text_request`], but a parse failure also reports how many
/// bytes the blocking reader would have consumed before erroring — the
/// prefix an event-loop connection discards to resynchronize on the next
/// line and keep serving (text framing is line-oriented, so one bad
/// frame need not end the connection).  `0` means framing is genuinely
/// lost (an unterminated over-limit line): the caller must disconnect.
pub fn decode_text_request_resync(
    buf: &[u8],
) -> Result<Decoded<Request>, (ProtoError, usize)> {
    let Some(eol) = buf.iter().position(|&b| b == b'\n') else {
        if buf.len() >= MAX_TEXT_LINE {
            return Err((ProtoError::malformed("header line over limit without newline"), 0));
        }
        return Ok(Decoded::Need(buf.len() + 1));
    };
    let header_end = eol + 1;
    let header = String::from_utf8_lossy(&buf[..eol]);
    let mut it = header.split_whitespace();
    let verb = it.next().unwrap_or("");
    let (frame_id, extra_lines) = match verb {
        "HULL" | "SADD" => {
            let id: Option<u64> = it.next().and_then(|s| s.parse().ok());
            let m: Option<usize> = it.next().and_then(|s| s.parse().ok());
            match (id, m) {
                (Some(id), Some(m)) if m <= MAX_REQUEST_POINTS => (Some(id), m),
                // bad header, or the DoS guard will trip: read_request
                // over the header line alone raises the identical error
                (id, _) => (id, 0),
            }
        }
        _ => (None, 0),
    };
    let mut end = header_end;
    for _ in 0..extra_lines {
        match buf[end..].iter().position(|&b| b == b'\n') {
            Some(p) if p < MAX_TEXT_LINE => end += p + 1,
            Some(_) => {
                let e = ProtoError::malformed("point line over limit");
                return Err((
                    match frame_id {
                        Some(id) => e.with_id(id),
                        None => e,
                    },
                    0,
                ));
            }
            None => {
                if buf.len() - end >= MAX_TEXT_LINE {
                    let e = ProtoError::malformed("point line over limit without newline");
                    return Err((
                        match frame_id {
                            Some(id) => e.with_id(id),
                            None => e,
                        },
                        0,
                    ));
                }
                return Ok(Decoded::Need(buf.len() + 1));
            }
        }
    }
    // delegate the parse to the blocking reader over exactly the frame's
    // bytes; on failure the advanced slice reveals how many bytes it
    // consumed (header + point lines up to the bad one) — the resync
    // prefix
    let mut frame_bytes = &buf[..end];
    match read_request(&mut frame_bytes) {
        Ok(req) => Ok(Decoded::Frame(req, end)),
        Err(e) => Err((e, end - frame_bytes.len())),
    }
}

fn read_line<R: BufRead>(r: &mut R) -> Result<String, ProtoError> {
    let mut line = String::new();
    let n = r
        .read_line(&mut line)
        .map_err(|e| ProtoError::malformed(e.to_string()))?;
    if n == 0 {
        return Err(ProtoError::Eof);
    }
    Ok(line.trim_end().to_string())
}

/// Read the `<id> <m> [TMO=<ms>]` header tail + the m-line point block
/// shared by `HULL` and `SADD` frames.  Trailing header tokens other
/// than `TMO=` are ignored (forward compatibility).
fn read_point_block<R: BufRead>(
    r: &mut R,
    it: &mut std::str::SplitWhitespace<'_>,
    verb: &str,
    session: bool,
) -> Result<(u64, Vec<Point>, Option<u32>), ProtoError> {
    let id: Option<u64> = it.next().and_then(|s| s.parse().ok());
    let m: Option<usize> = it.next().and_then(|s| s.parse().ok());
    let (Some(id), Some(m)) = (id, m) else {
        return Err(ProtoError::Malformed {
            id,
            detail: format!("{verb} needs <id> <m>"),
        });
    };
    if m > MAX_REQUEST_POINTS {
        return Err(ProtoError::TooManyPoints { id, points: m, session });
    }
    let mut tmo_ms: Option<u32> = None;
    for tok in it.by_ref() {
        if let Some(ms) = tok.strip_prefix("TMO=").and_then(|v| v.parse::<u32>().ok()) {
            tmo_ms = Some(ms);
        }
    }
    let mut points = Vec::with_capacity(m);
    for k in 0..m {
        let pl = read_line(r).map_err(|e| e.with_id(id))?;
        let mut c = pl.split_whitespace();
        let (x, y) = match (c.next(), c.next()) {
            (Some(a), Some(b)) => (
                a.parse::<f64>().map_err(|_| {
                    ProtoError::malformed(format!("point {k}: {pl:?}")).with_id(id)
                })?,
                b.parse::<f64>().map_err(|_| {
                    ProtoError::malformed(format!("point {k}: {pl:?}")).with_id(id)
                })?,
            ),
            _ => {
                return Err(ProtoError::malformed(format!("point {k}: {pl:?}")).with_id(id))
            }
        };
        points.push(Point::new(x, y));
    }
    Ok((id, points, tmo_ms))
}

/// Parse the first numeric operand of SOPEN (`<id>`) / SHULL / SCLOSE
/// (`<sid>`).
fn parse_sid(it: &mut std::str::SplitWhitespace<'_>, verb: &str) -> Result<u64, ProtoError> {
    it.next()
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| ProtoError::malformed(format!("{verb} needs a numeric id")))
}

/// Parse the optional second numeric operand of SOPEN (`<sid>` to
/// restore) / SHULL (`<epoch>`).  Present-but-unparseable is malformed —
/// it selects *which* result the client gets, so it must never be
/// silently dropped — and the already-parsed first operand is echoed.
fn parse_opt_operand(
    it: &mut std::str::SplitWhitespace<'_>,
    first: u64,
    verb: &str,
    what: &str,
) -> Result<Option<u64>, ProtoError> {
    match it.next() {
        None => Ok(None),
        Some(tok) => tok.parse().map(Some).map_err(|_| ProtoError::Malformed {
            id: Some(first),
            detail: format!("{verb}: bad {what} {tok:?}"),
        }),
    }
}

/// Read one request off the stream.
pub fn read_request<R: BufRead>(r: &mut R) -> Result<Request, ProtoError> {
    let line = read_line(r)?;
    let mut it = line.split_whitespace();
    match it.next() {
        Some("HULL") => {
            let (id, points, tmo_ms) = read_point_block(r, &mut it, "HULL", false)?;
            Ok(Request::Hull { id, points, tmo_ms })
        }
        Some("SOPEN") => {
            let id = parse_sid(&mut it, "SOPEN")?;
            let restore = parse_opt_operand(&mut it, id, "SOPEN", "restore sid")?;
            Ok(Request::SessionOpen { id, restore })
        }
        Some("SADD") => {
            let (sid, points, tmo_ms) = read_point_block(r, &mut it, "SADD", true)?;
            Ok(Request::SessionAdd { sid, points, tmo_ms })
        }
        Some("SHULL") => {
            let sid = parse_sid(&mut it, "SHULL")?;
            let epoch = parse_opt_operand(&mut it, sid, "SHULL", "epoch")?;
            Ok(Request::SessionHull { sid, epoch })
        }
        Some("SCLOSE") => Ok(Request::SessionClose { sid: parse_sid(&mut it, "SCLOSE")? }),
        Some("STATS") => Ok(Request::Stats),
        Some("PING") => Ok(Request::Ping),
        Some("QUIT") => Ok(Request::Quit),
        other => Err(ProtoError::malformed(format!("unknown command {other:?}"))),
    }
}

/// Serialize a request (client side).
pub fn write_request<W: Write>(w: &mut W, req: &Request) -> std::io::Result<()> {
    match req {
        Request::Hull { id, points, tmo_ms } => {
            match tmo_ms {
                Some(ms) => writeln!(w, "HULL {id} {} TMO={ms}", points.len())?,
                None => writeln!(w, "HULL {id} {}", points.len())?,
            }
            for p in points {
                writeln!(w, "{} {}", p.x, p.y)?;
            }
        }
        Request::SessionOpen { id, restore } => match restore {
            Some(sid) => writeln!(w, "SOPEN {id} {sid}")?,
            None => writeln!(w, "SOPEN {id}")?,
        },
        Request::SessionAdd { sid, points, tmo_ms } => {
            match tmo_ms {
                Some(ms) => writeln!(w, "SADD {sid} {} TMO={ms}", points.len())?,
                None => writeln!(w, "SADD {sid} {}", points.len())?,
            }
            for p in points {
                writeln!(w, "{} {}", p.x, p.y)?;
            }
        }
        Request::SessionHull { sid, epoch } => match epoch {
            Some(e) => writeln!(w, "SHULL {sid} {e}")?,
            None => writeln!(w, "SHULL {sid}")?,
        },
        Request::SessionClose { sid } => writeln!(w, "SCLOSE {sid}")?,
        Request::Stats => writeln!(w, "STATS")?,
        Request::Ping => writeln!(w, "PING")?,
        Request::Quit => writeln!(w, "QUIT")?,
    }
    w.flush()
}

/// Serialize a response (server side).
pub fn write_response<W: Write>(w: &mut W, resp: &Response) -> std::io::Result<()> {
    match resp {
        Response::Hull { id, upper, lower, backend, queue_ns, exec_ns } => {
            writeln!(
                w,
                "HULL {id} OK {} {} {backend} {queue_ns} {exec_ns}",
                upper.len(),
                lower.len()
            )?;
            for p in upper.iter().chain(lower.iter()) {
                writeln!(w, "{} {}", p.x, p.y)?;
            }
            writeln!(w, "END")?;
        }
        Response::HullErr { id, message } => {
            writeln!(w, "HULL {id} ERR {message}")?;
        }
        Response::MalformedErr { id, message } => match id {
            Some(id) => writeln!(w, "ERR {id} {message}")?,
            None => writeln!(w, "ERR - {message}")?,
        },
        Response::SessionOpened { id, sid } => writeln!(w, "SOPEN {id} OK {sid}")?,
        Response::SessionAdded { sid, absorbed, pending, epoch } => {
            writeln!(w, "SADD {sid} OK {absorbed} {pending} {epoch}")?;
        }
        Response::SessionHull { sid, epoch, upper, lower } => {
            writeln!(w, "SHULL {sid} OK {epoch} {} {}", upper.len(), lower.len())?;
            for p in upper.iter().chain(lower.iter()) {
                writeln!(w, "{} {}", p.x, p.y)?;
            }
            writeln!(w, "END")?;
        }
        Response::SessionClosed { sid } => writeln!(w, "SCLOSE {sid} OK")?,
        Response::SessionErr { verb, id, message } => {
            writeln!(w, "{} {id} ERR {message}", verb.word())?;
        }
        Response::Stats(json) => writeln!(w, "STATS {json}")?,
        Response::Pong => writeln!(w, "PONG")?,
    }
    w.flush()
}

/// Read one response off the stream (client side).
pub fn read_response<R: BufRead>(r: &mut R) -> Result<Response, ProtoError> {
    let line = read_line(r)?;
    if let Some(rest) = line.strip_prefix("STATS ") {
        return Ok(Response::Stats(rest.to_string()));
    }
    if line == "PONG" {
        return Ok(Response::Pong);
    }
    if let Some(rest) = line.strip_prefix("ERR ") {
        let mut it = rest.splitn(2, ' ');
        let id_tok = it.next().unwrap_or("-");
        let id = if id_tok == "-" { None } else { id_tok.parse().ok() };
        return Ok(Response::MalformedErr {
            id,
            message: it.next().unwrap_or("").to_string(),
        });
    }
    let mut it = line.split_whitespace();
    let verb = it.next().unwrap_or("");
    if !matches!(verb, "HULL" | "SOPEN" | "SADD" | "SHULL" | "SCLOSE") {
        return Err(ProtoError::malformed(line));
    }
    let id: u64 = it
        .next()
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| ProtoError::malformed(line.clone()))?;
    let status = it.next();
    match (verb, status) {
        ("HULL", Some("OK")) => {
            let k_up = next_num(&mut it, verb, "k_up")? as usize;
            let k_lo = next_num(&mut it, verb, "k_lo")? as usize;
            let backend = it.next().unwrap_or("?").to_string();
            let queue_ns: u64 = it.next().and_then(|s| s.parse().ok()).unwrap_or(0);
            let exec_ns: u64 = it.next().and_then(|s| s.parse().ok()).unwrap_or(0);
            let (upper, lower) = read_chains(r, k_up, k_lo)?;
            Ok(Response::Hull { id, upper, lower, backend, queue_ns, exec_ns })
        }
        ("SOPEN", Some("OK")) => {
            Ok(Response::SessionOpened { id, sid: next_num(&mut it, verb, "sid")? })
        }
        ("SADD", Some("OK")) => Ok(Response::SessionAdded {
            sid: id,
            absorbed: next_num(&mut it, verb, "absorbed")?,
            pending: next_num(&mut it, verb, "pending")?,
            epoch: next_num(&mut it, verb, "epoch")?,
        }),
        ("SHULL", Some("OK")) => {
            let epoch = next_num(&mut it, verb, "epoch")?;
            let k_up = next_num(&mut it, verb, "k_up")? as usize;
            let k_lo = next_num(&mut it, verb, "k_lo")? as usize;
            let (upper, lower) = read_chains(r, k_up, k_lo)?;
            Ok(Response::SessionHull { sid: id, epoch, upper, lower })
        }
        ("SCLOSE", Some("OK")) => Ok(Response::SessionClosed { sid: id }),
        ("HULL", Some("ERR")) => {
            let msg: Vec<&str> = it.collect();
            Ok(Response::HullErr { id, message: msg.join(" ") })
        }
        (_, Some("ERR")) => {
            let sverb = match verb {
                "SOPEN" => SessionVerb::Open,
                "SADD" => SessionVerb::Add,
                "SHULL" => SessionVerb::Hull,
                _ => SessionVerb::Close,
            };
            let msg: Vec<&str> = it.collect();
            Ok(Response::SessionErr { verb: sverb, id, message: msg.join(" ") })
        }
        _ => Err(ProtoError::malformed(line)),
    }
}

/// Parse the next whitespace token of a response header as a number.
fn next_num(
    it: &mut std::str::SplitWhitespace<'_>,
    verb: &str,
    what: &str,
) -> Result<u64, ProtoError> {
    it.next()
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| ProtoError::malformed(format!("{verb}: bad {what}")))
}

/// Read `k_up + k_lo` point lines followed by `END` (HULL / SHULL OK
/// payload).
fn read_chains<R: BufRead>(
    r: &mut R,
    k_up: usize,
    k_lo: usize,
) -> Result<(Vec<Point>, Vec<Point>), ProtoError> {
    let mut pts = Vec::with_capacity(k_up + k_lo);
    for _ in 0..k_up + k_lo {
        let pl = read_line(r)?;
        let mut c = pl.split_whitespace();
        let x: f64 = c
            .next()
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| ProtoError::malformed(pl.clone()))?;
        let y: f64 = c
            .next()
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| ProtoError::malformed(pl.clone()))?;
        pts.push(Point::new(x, y));
    }
    let end = read_line(r)?;
    if end != "END" {
        return Err(ProtoError::malformed(format!("expected END, got {end:?}")));
    }
    let lower = pts.split_off(k_up);
    Ok((pts, lower))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    fn roundtrip_req(req: Request) -> Request {
        let mut buf = Vec::new();
        write_request(&mut buf, &req).unwrap();
        read_request(&mut BufReader::new(&buf[..])).unwrap()
    }

    fn roundtrip_resp(resp: Response) -> Response {
        let mut buf = Vec::new();
        write_response(&mut buf, &resp).unwrap();
        read_response(&mut BufReader::new(&buf[..])).unwrap()
    }

    #[test]
    fn request_roundtrips() {
        let req = Request::Hull {
            id: 42,
            points: vec![Point::new(0.125, 0.25), Point::new(0.5, 0.75)],
            tmo_ms: None,
        };
        assert_eq!(roundtrip_req(req.clone()), req);
        assert_eq!(roundtrip_req(Request::Stats), Request::Stats);
        assert_eq!(roundtrip_req(Request::Ping), Request::Ping);
        assert_eq!(roundtrip_req(Request::Quit), Request::Quit);
    }

    #[test]
    fn deadline_token_roundtrips_and_parses() {
        // explicit deadline survives a write/read roundtrip on both verbs
        let hull = Request::Hull { id: 5, points: vec![Point::new(0.5, 0.5)], tmo_ms: Some(250) };
        assert_eq!(roundtrip_req(hull.clone()), hull);
        let sadd =
            Request::SessionAdd { sid: 9, points: vec![Point::new(0.1, 0.2)], tmo_ms: Some(40) };
        assert_eq!(roundtrip_req(sadd.clone()), sadd);
        // wire form is the documented TMO= token
        let mut buf = Vec::new();
        write_request(&mut buf, &hull).unwrap();
        assert!(buf.starts_with(b"HULL 5 1 TMO=250\n"), "{:?}", String::from_utf8_lossy(&buf));
        // hand-written frame parses
        let req = read_request(&mut BufReader::new(&b"HULL 7 1 TMO=125\n0.5 0.5\n"[..])).unwrap();
        assert_eq!(req, Request::Hull { id: 7, points: vec![Point::new(0.5, 0.5)], tmo_ms: Some(125) });
        // unknown / malformed trailing tokens are ignored, not fatal
        for frame in
            [&b"HULL 7 0 FUTURE=1\n"[..], &b"HULL 7 0 TMO=abc\n"[..], &b"HULL 7 0 TMO=\n"[..]]
        {
            let req = read_request(&mut BufReader::new(frame)).unwrap();
            assert_eq!(req, Request::Hull { id: 7, points: vec![], tmo_ms: None }, "{frame:?}");
        }
        // the incremental decoder agrees bit-for-bit
        assert_incremental_matches(b"HULL 7 1 TMO=125\n0.5 0.5\n");
        assert_incremental_matches(b"SADD 9 1 TMO=40\n0.1 0.2\n");
    }

    #[test]
    fn response_roundtrips() {
        let resp = Response::Hull {
            id: 7,
            upper: vec![Point::new(0.0, 0.0), Point::new(1.0, 1.0)],
            lower: vec![Point::new(0.0, 0.0), Point::new(0.5, 0.0), Point::new(1.0, 1.0)],
            backend: "pjrt".into(),
            queue_ns: 123,
            exec_ns: 456,
        };
        assert_eq!(roundtrip_resp(resp.clone()), resp);
        let err = Response::HullErr { id: 9, message: "empty point set".into() };
        assert_eq!(roundtrip_resp(err.clone()), err);
        assert_eq!(roundtrip_resp(Response::Pong), Response::Pong);
        for id in [Some(31u64), None] {
            let merr = Response::MalformedErr { id, message: "bad frame".into() };
            assert_eq!(roundtrip_resp(merr.clone()), merr);
        }
    }

    #[test]
    fn malformed_rejected() {
        for bad in ["BOGUS\n", "HULL x y\n", "HULL 1 2\n0.5\n0.5 0.5\n", ""] {
            let r = read_request(&mut BufReader::new(bad.as_bytes()));
            assert!(r.is_err(), "{bad:?}");
        }
    }

    #[test]
    fn malformed_frames_echo_the_id_when_parseable() {
        // bad count token: id parsed, count didn't
        let e = read_request(&mut BufReader::new(&b"HULL 7 abc\n"[..])).unwrap_err();
        assert_eq!(e.frame_id(), Some(7));
        // bad point line: header fully parsed
        let e = read_request(&mut BufReader::new(&b"HULL 8 1\nnope\n"[..])).unwrap_err();
        assert_eq!(e.frame_id(), Some(8));
        // bad id token: nothing to echo
        let e = read_request(&mut BufReader::new(&b"HULL x 2\n"[..])).unwrap_err();
        assert_eq!(e.frame_id(), None);
        // unknown command: nothing to echo
        let e = read_request(&mut BufReader::new(&b"BOGUS\n"[..])).unwrap_err();
        assert_eq!(e.frame_id(), None);
    }

    #[test]
    fn oversized_rejected() {
        let line = format!("HULL 1 {}\n", MAX_REQUEST_POINTS + 1);
        assert_eq!(
            read_request(&mut BufReader::new(line.as_bytes())),
            Err(ProtoError::TooManyPoints {
                id: 1,
                points: MAX_REQUEST_POINTS + 1,
                session: false
            })
        );
        let line = format!("SADD 9 {}\n", MAX_REQUEST_POINTS + 1);
        assert_eq!(
            read_request(&mut BufReader::new(line.as_bytes())),
            Err(ProtoError::TooManyPoints {
                id: 9,
                points: MAX_REQUEST_POINTS + 1,
                session: true
            })
        );
    }

    // ------------------------------------------------- session verbs

    #[test]
    fn session_requests_roundtrip() {
        for req in [
            Request::SessionOpen { id: 3, restore: None },
            Request::SessionOpen { id: 4, restore: Some(99) },
            Request::SessionAdd {
                sid: 17,
                points: vec![Point::new(0.125, 0.25), Point::new(0.5, 0.75)],
                tmo_ms: None,
            },
            Request::SessionAdd { sid: 18, points: vec![], tmo_ms: None },
            Request::SessionHull { sid: 17, epoch: None },
            Request::SessionHull { sid: 17, epoch: Some(0) },
            Request::SessionHull { sid: 17, epoch: Some(3) },
            Request::SessionClose { sid: 17 },
        ] {
            assert_eq!(roundtrip_req(req.clone()), req);
        }
    }

    #[test]
    fn optional_second_operand_parses_strictly() {
        // wire form of the extended verbs
        let mut buf = Vec::new();
        write_request(&mut buf, &Request::SessionHull { sid: 7, epoch: Some(2) }).unwrap();
        assert_eq!(buf, b"SHULL 7 2\n");
        let mut buf = Vec::new();
        write_request(&mut buf, &Request::SessionOpen { id: 1, restore: Some(42) }).unwrap();
        assert_eq!(buf, b"SOPEN 1 42\n");
        // a present-but-garbage operand is malformed, echoing the first
        // operand — NOT silently treated as a live read / fresh open
        for bad in ["SHULL 7 abc\n", "SHULL 7 -1\n", "SOPEN 1 x\n"] {
            let e = read_request(&mut BufReader::new(bad.as_bytes())).unwrap_err();
            assert!(
                matches!(e, ProtoError::Malformed { id: Some(_), .. }),
                "{bad:?} -> {e:?}"
            );
            // the incremental decoder rejects identically
            assert_incremental_matches(bad.as_bytes());
        }
        assert_incremental_matches(b"SHULL 7 2\n");
        assert_incremental_matches(b"SOPEN 1 42\n");
    }

    #[test]
    fn session_responses_roundtrip() {
        for resp in [
            Response::SessionOpened { id: 3, sid: 42 },
            Response::SessionAdded { sid: 42, absorbed: 7, pending: 11, epoch: 2 },
            Response::SessionHull {
                sid: 42,
                epoch: 5,
                upper: vec![Point::new(0.0, 0.0), Point::new(1.0, 1.0)],
                lower: vec![Point::new(0.0, 0.0), Point::new(0.5, 0.0), Point::new(1.0, 1.0)],
            },
            Response::SessionHull { sid: 1, epoch: 0, upper: vec![], lower: vec![] },
            Response::SessionClosed { sid: 42 },
            Response::SessionErr {
                verb: SessionVerb::Add,
                id: 42,
                message: "unknown-session".into(),
            },
            Response::SessionErr {
                verb: SessionVerb::Open,
                id: 9,
                message: "session capacity 8 reached".into(),
            },
            Response::SessionErr { verb: SessionVerb::Hull, id: 2, message: "x".into() },
            Response::SessionErr { verb: SessionVerb::Close, id: 2, message: "x".into() },
        ] {
            assert_eq!(roundtrip_resp(resp.clone()), resp);
        }
    }

    #[test]
    fn malformed_session_frames_echo_the_sid_when_parseable() {
        // bad count token: sid parsed, count didn't
        let e = read_request(&mut BufReader::new(&b"SADD 7 abc\n"[..])).unwrap_err();
        assert_eq!(e.frame_id(), Some(7));
        // bad point line: header fully parsed
        let e = read_request(&mut BufReader::new(&b"SADD 8 1\nnope\n"[..])).unwrap_err();
        assert_eq!(e.frame_id(), Some(8));
        // truncated point block: EOF passes through (no reply possible)
        let e = read_request(&mut BufReader::new(&b"SADD 8 2\n0.1 0.2\n"[..])).unwrap_err();
        assert_eq!(e, ProtoError::Eof);
        // bad sid token: nothing to echo
        for bad in ["SADD x 2\n", "SOPEN x\n", "SHULL nope\n", "SCLOSE\n", "SOPEN\n"] {
            let e = read_request(&mut BufReader::new(bad.as_bytes())).unwrap_err();
            assert_eq!(e.frame_id(), None, "{bad:?}");
        }
    }

    // -------------------------------------------- incremental decoder

    /// Every complete frame must decode identically through the
    /// incremental path and the blocking reader, consuming exactly the
    /// bytes it wrote.
    fn assert_incremental_matches(bytes: &[u8]) {
        let blocking = read_request(&mut BufReader::new(bytes));
        match decode_text_request(bytes) {
            Ok(Decoded::Frame(req, used)) => {
                assert_eq!(used, bytes.len());
                assert_eq!(Ok(req), blocking);
            }
            Ok(Decoded::Need(n)) => panic!("complete frame reported Need({n})"),
            Err(e) => assert_eq!(Err(e), blocking),
        }
    }

    #[test]
    fn incremental_text_decode_matches_blocking_reader() {
        let frames: &[&[u8]] = &[
            b"HULL 42 2\n0.125 0.25\n0.5 0.75\n",
            b"HULL 1 0\n",
            b"SOPEN 3\n",
            b"SADD 17 1\n0.5 0.5\n",
            b"SADD 18 0\n",
            b"SHULL 17\n",
            b"SCLOSE 17\n",
            b"STATS\n",
            b"PING\n",
            b"QUIT\n",
            // malformed frames must fail identically (same id echo)
            b"BOGUS\n",
            b"HULL x y\n",
            b"HULL 7 abc\n",
            b"HULL 8 1\nnope\n",
            b"SADD 7 abc\n",
            b"SOPEN x\n",
        ];
        for f in frames {
            assert_incremental_matches(f);
        }
    }

    #[test]
    fn incremental_text_decode_is_exactly_framed() {
        let bytes = b"HULL 5 2\n0.1 0.2\n0.3 0.4\nPING\n";
        // prefixes are incomplete, never errors
        for cut in 0..bytes.len() - 6 {
            match decode_text_request(&bytes[..cut]).unwrap() {
                Decoded::Need(n) => assert_eq!(n, cut + 1),
                Decoded::Frame(req, used) => panic!("early frame {req:?} at {used}"),
            }
        }
        // the full buffer yields the HULL frame and leaves PING unread
        match decode_text_request(bytes).unwrap() {
            Decoded::Frame(Request::Hull { id: 5, points, .. }, used) => {
                assert_eq!(points.len(), 2);
                assert_eq!(&bytes[used..], b"PING\n");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn incremental_text_decode_oversized_needs_no_payload() {
        // the DoS guard must fire from the header line alone
        let line = format!("HULL 1 {}\n", MAX_REQUEST_POINTS + 1);
        assert_eq!(
            decode_text_request(line.as_bytes()),
            Err(ProtoError::TooManyPoints {
                id: 1,
                points: MAX_REQUEST_POINTS + 1,
                session: false
            })
        );
        let line = format!("SADD 9 {}\n", MAX_REQUEST_POINTS + 1);
        assert_eq!(
            decode_text_request(line.as_bytes()),
            Err(ProtoError::TooManyPoints {
                id: 9,
                points: MAX_REQUEST_POINTS + 1,
                session: true
            })
        );
    }

    #[test]
    fn resync_extent_matches_blocking_consumption() {
        // bad header: the header line is the whole resync prefix
        let (e, used) = decode_text_request_resync(b"BOGUS\nPING\n").unwrap_err();
        assert_eq!(e.frame_id(), None);
        assert_eq!(used, 6);
        // bad count: header line only
        let (e, used) = decode_text_request_resync(b"HULL 7 abc\nPING\n").unwrap_err();
        assert_eq!(e.frame_id(), Some(7));
        assert_eq!(used, 11);
        // bad first point of two: header + the bad line, the second point
        // line is left to be (mis)read as the next frame — exactly what
        // the blocking reader consumes
        let bytes = b"HULL 1 2\n0.5\n0.5 0.5\n";
        let (e, used) = decode_text_request_resync(bytes).unwrap_err();
        assert_eq!(e.frame_id(), Some(1));
        assert_eq!(&bytes[used..], b"0.5 0.5\n");
        // unterminated over-limit garbage: resync impossible
        let junk = vec![b'A'; MAX_TEXT_LINE];
        let (_, used) = decode_text_request_resync(&junk).unwrap_err();
        assert_eq!(used, 0);
    }

    #[test]
    fn incremental_text_decode_bounds_unterminated_lines() {
        // an endless header line must be rejected, not buffered forever
        let junk = vec![b'A'; MAX_TEXT_LINE];
        assert!(decode_text_request(&junk).is_err());
        // an endless point line too, echoing the parsed id
        let mut buf = b"HULL 3 1\n".to_vec();
        buf.resize(buf.len() + MAX_TEXT_LINE, b'7');
        assert_eq!(decode_text_request(&buf).unwrap_err().frame_id(), Some(3));
    }

    #[test]
    fn f64_precision_survives() {
        let p = Point::new(0.1234567890123, 0.000001);
        let req = Request::Hull { id: 1, points: vec![p], tmo_ms: None };
        match roundtrip_req(req) {
            Request::Hull { points, .. } => assert_eq!(points[0], p),
            _ => panic!(),
        }
    }
}
