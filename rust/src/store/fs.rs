//! Filesystem [`SnapshotStore`] (`[store] dir` / `serve --store-dir`).
//!
//! Layout:
//!
//! ```text
//! <dir>/chunks/<sha256-hex>     packed LE f64 pairs
//! <dir>/sessions/<sid>.json     manifest (the commit point)
//! ```
//!
//! Every write lands in a unique temp file in the destination directory
//! and is `rename(2)`d into place, so readers never observe a torn chunk
//! or manifest and a crashed writer leaves only `.tmp-*` litter (swept on
//! open).  Chunks are immutable once placed; a name collision means the
//! bytes already exist and the write is skipped (dedup).

use std::fs;
use std::io::{ErrorKind, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use super::{ChunkId, SnapshotStore, StoreError};

pub struct FsStore {
    chunks: PathBuf,
    sessions: PathBuf,
    tmp_seq: AtomicU64,
}

fn io_err(what: &str, path: &Path, e: std::io::Error) -> StoreError {
    StoreError::Io(format!("{what} {}: {e}", path.display()))
}

impl FsStore {
    /// Open (creating if needed) a store rooted at `dir`; sweeps temp
    /// litter left by a crashed writer.
    pub fn open(dir: impl Into<PathBuf>) -> Result<FsStore, StoreError> {
        let root: PathBuf = dir.into();
        let chunks = root.join("chunks");
        let sessions = root.join("sessions");
        for d in [&chunks, &sessions] {
            fs::create_dir_all(d).map_err(|e| io_err("creating", d, e))?;
            if let Ok(entries) = fs::read_dir(d) {
                for entry in entries.flatten() {
                    if entry.file_name().to_string_lossy().starts_with(".tmp-") {
                        let _ = fs::remove_file(entry.path());
                    }
                }
            }
        }
        Ok(FsStore { chunks, sessions, tmp_seq: AtomicU64::new(0) })
    }

    /// Temp-write `data` next to `dest`, then rename into place.
    fn commit(&self, dir: &Path, dest: &Path, data: &[u8]) -> Result<(), StoreError> {
        let tmp = dir.join(format!(
            ".tmp-{}-{}",
            std::process::id(),
            self.tmp_seq.fetch_add(1, Ordering::Relaxed)
        ));
        let write = (|| -> std::io::Result<()> {
            let mut f = fs::File::create(&tmp)?;
            f.write_all(data)?;
            f.sync_all()
        })();
        if let Err(e) = write {
            let _ = fs::remove_file(&tmp);
            return Err(io_err("writing", &tmp, e));
        }
        fs::rename(&tmp, dest).map_err(|e| {
            let _ = fs::remove_file(&tmp);
            io_err("committing", dest, e)
        })
    }

    fn manifest_path(&self, sid: u64) -> PathBuf {
        self.sessions.join(format!("{sid}.json"))
    }
}

impl SnapshotStore for FsStore {
    fn put_chunk(&self, data: &[u8]) -> Result<(ChunkId, bool), StoreError> {
        let id = ChunkId::of(data);
        let dest = self.chunks.join(id.to_hex());
        if dest.exists() {
            return Ok((id, false));
        }
        self.commit(&self.chunks, &dest, data)?;
        Ok((id, true))
    }

    fn get_chunk(&self, id: ChunkId) -> Result<Vec<u8>, StoreError> {
        let path = self.chunks.join(id.to_hex());
        let data = match fs::read(&path) {
            Ok(d) => d,
            Err(e) if e.kind() == ErrorKind::NotFound => {
                return Err(StoreError::Corrupt(format!("missing chunk {id}")))
            }
            Err(e) => return Err(io_err("reading", &path, e)),
        };
        if ChunkId::of(&data) != id {
            return Err(StoreError::Corrupt(format!("chunk {id} fails hash verification")));
        }
        Ok(data)
    }

    fn put_manifest(&self, sid: u64, text: &str) -> Result<(), StoreError> {
        self.commit(&self.sessions, &self.manifest_path(sid), text.as_bytes())
    }

    fn get_manifest(&self, sid: u64) -> Result<Option<String>, StoreError> {
        let path = self.manifest_path(sid);
        match fs::read_to_string(&path) {
            Ok(s) => Ok(Some(s)),
            Err(e) if e.kind() == ErrorKind::NotFound => Ok(None),
            Err(e) => Err(io_err("reading", &path, e)),
        }
    }

    fn list_sids(&self) -> Result<Vec<u64>, StoreError> {
        let entries =
            fs::read_dir(&self.sessions).map_err(|e| io_err("listing", &self.sessions, e))?;
        let mut sids = Vec::new();
        for entry in entries {
            let entry = entry.map_err(|e| io_err("listing", &self.sessions, e))?;
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if let Some(stem) = name.strip_suffix(".json") {
                if let Ok(sid) = stem.parse::<u64>() {
                    sids.push(sid);
                }
            }
        }
        sids.sort_unstable();
        Ok(sids)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::{read_snapshot, write_snapshot, SessionState};
    use std::sync::atomic::AtomicU32;

    static DIR_SEQ: AtomicU32 = AtomicU32::new(0);

    /// Unique scratch dir, removed on drop.
    pub(crate) struct TempDir(pub PathBuf);

    impl TempDir {
        pub(crate) fn new(tag: &str) -> TempDir {
            let dir = std::env::temp_dir().join(format!(
                "wagener-{tag}-{}-{}",
                std::process::id(),
                DIR_SEQ.fetch_add(1, Ordering::Relaxed)
            ));
            let _ = fs::remove_dir_all(&dir);
            TempDir(dir)
        }
    }

    impl Drop for TempDir {
        fn drop(&mut self) {
            let _ = fs::remove_dir_all(&self.0);
        }
    }

    use crate::geometry::point::Point;

    fn state() -> SessionState {
        SessionState {
            epoch: 0,
            merge_threshold: 8,
            inserted: 2,
            absorbed: 0,
            upper: vec![],
            lower: vec![],
            pending: vec![Point::new(0.25, 0.5), Point::new(0.75, -0.5)],
            ledger: vec![],
        }
    }

    #[test]
    fn fs_roundtrip_and_dedup() {
        let tmp = TempDir::new("fsstore");
        let store = FsStore::open(&tmp.0).unwrap();
        let (id, wrote) = store.put_chunk(b"hello world").unwrap();
        assert!(wrote);
        let (id2, wrote2) = store.put_chunk(b"hello world").unwrap();
        assert_eq!(id, id2);
        assert!(!wrote2);
        assert_eq!(store.get_chunk(id).unwrap(), b"hello world");

        write_snapshot(&store, 12, &state()).unwrap();
        // reopening (a "restart") sees the same bytes
        let reopened = FsStore::open(&tmp.0).unwrap();
        assert_eq!(read_snapshot(&reopened, 12).unwrap().unwrap(), state());
        assert_eq!(reopened.list_sids().unwrap(), vec![12]);
        assert_eq!(reopened.get_manifest(99).unwrap(), None);
    }

    #[test]
    fn manifest_replace_is_atomic_overwrite() {
        let tmp = TempDir::new("fsstore-manifest");
        let store = FsStore::open(&tmp.0).unwrap();
        store.put_manifest(1, "first").unwrap();
        store.put_manifest(1, "second").unwrap();
        assert_eq!(store.get_manifest(1).unwrap().as_deref(), Some("second"));
        // no temp litter survives a normal write sequence
        let leftovers: Vec<_> = fs::read_dir(tmp.0.join("sessions"))
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().starts_with(".tmp-"))
            .collect();
        assert!(leftovers.is_empty());
    }

    #[test]
    fn on_disk_corruption_is_typed() {
        let tmp = TempDir::new("fsstore-corrupt");
        let store = FsStore::open(&tmp.0).unwrap();
        write_snapshot(&store, 5, &state()).unwrap();

        // flip one byte in every chunk file and expect snapshot-corrupt
        for entry in fs::read_dir(tmp.0.join("chunks")).unwrap().flatten() {
            let path = entry.path();
            let mut data = fs::read(&path).unwrap();
            if data.is_empty() {
                continue;
            }
            data[0] ^= 0x40;
            fs::write(&path, &data).unwrap();
            let err = read_snapshot(&store, 5).unwrap_err();
            assert!(err.to_string().starts_with("snapshot-corrupt"), "{err}");
            data[0] ^= 0x40;
            fs::write(&path, &data).unwrap();
        }

        // truncate a chunk file (torn write simulation)
        let victim = fs::read_dir(tmp.0.join("chunks"))
            .unwrap()
            .flatten()
            .map(|e| e.path())
            .find(|p| fs::metadata(p).map(|m| m.len() >= 16).unwrap_or(false))
            .unwrap();
        let data = fs::read(&victim).unwrap();
        fs::write(&victim, &data[..data.len() - 3]).unwrap();
        let err = read_snapshot(&store, 5).unwrap_err();
        assert!(err.to_string().starts_with("snapshot-corrupt"), "{err}");

        // deleting the chunk is also corruption, not a panic
        fs::remove_file(&victim).unwrap();
        let err = read_snapshot(&store, 5).unwrap_err();
        assert!(err.to_string().starts_with("snapshot-corrupt"), "{err}");
    }

    #[test]
    fn open_sweeps_tmp_litter() {
        let tmp = TempDir::new("fsstore-litter");
        let store = FsStore::open(&tmp.0).unwrap();
        drop(store);
        fs::write(tmp.0.join("chunks").join(".tmp-999-0"), b"half a chunk").unwrap();
        let _ = FsStore::open(&tmp.0).unwrap();
        assert!(!tmp.0.join("chunks").join(".tmp-999-0").exists());
    }
}
