//! E5 — work-optimal variant (paper §3): wall time and work counters of
//! the strip + Overmars–van-Leeuwen pipeline vs the standard one, plus a
//! strip-length ablation (the paper picks log²n).
//!
//! Run: `cargo bench --bench bench_optimal`

use wagener_hull::benchkit::{black_box, Bencher, Report};
use wagener_hull::geometry::generators::{generate, Distribution};
use wagener_hull::ovl::{self, optimal::default_strip_len};
use wagener_hull::wagener;

fn main() {
    let b = Bencher::default();

    let mut report = Report::new("E5: optimal-speedup variant (circle: large hulls)");
    for &n in &[1024usize, 4096, 16384] {
        let pts = generate(Distribution::Circle, n, 13);
        report.add(b.run(&format!("wagener_native/n{n}"), || {
            black_box(wagener::upper_hull(black_box(&pts)))
        }));
        report.add(b.run(&format!("ovl_optimal/n{n}"), || {
            black_box(ovl::optimal_upper_hull(black_box(&pts), 0).hull)
        }));
        let opt = ovl::optimal_upper_hull(&pts, 0);
        let run = wagener::pram_exec::run_pipeline_with(&pts, n, false).unwrap();
        report.note(format!(
            "n={n}: std_work={} opt_work={} (strip={} tangent_evals={}) ratio={:.1}",
            run.counters.work,
            opt.stats.total(),
            opt.stats.strip_work,
            opt.stats.tangent_predicate_evals,
            run.counters.work as f64 / opt.stats.total() as f64
        ));
    }
    report.finish();

    let mut report = Report::new("E5b: strip-length ablation, n = 16384 circle");
    let n = 16384;
    let pts = generate(Distribution::Circle, n, 13);
    for strip in [16usize, 64, default_strip_len(n), 1024, 4096] {
        report.add(b.run(&format!("ovl/strip{strip}"), || {
            black_box(ovl::optimal_upper_hull(black_box(&pts), strip).hull)
        }));
        let opt = ovl::optimal_upper_hull(&pts, strip);
        report.note(format!(
            "strip={strip}: strips={} evals={} total_work={}",
            opt.stats.strips,
            opt.stats.tangent_predicate_evals,
            opt.stats.total()
        ));
    }
    report.finish();
}
