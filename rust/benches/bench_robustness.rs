//! E11 — robustness under overload: latency and shed rate at ~2× the
//! measured capacity, with admission control off (`max_queued = 0`,
//! the queue absorbs everything) vs on (a bounded in-flight ceiling
//! sheds the excess as typed `overloaded` rejections).
//!
//! Per mode the report carries a closed-loop baseline row (which also
//! calibrates the service time used to pace the overload), an open-loop
//! burst row at 2× capacity (wall time per request, pacing + drain),
//! and notes with the shed rate and the sojourn p50/p95/max of the
//! requests that were actually served.  The headline contrast: without
//! shedding every request is eventually served but sojourn latency
//! balloons with queue depth; with shedding the served requests keep
//! near-baseline sojourns and the excess fails fast.
//!
//! Run: `cargo bench --bench bench_robustness` (tier1.sh feeds
//! BENCH_robustness.json via WAGENER_BENCH_JSON; WAGENER_BENCH_FAST=1
//! shrinks the burst).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use wagener_hull::benchkit::{Bencher, Report};
use wagener_hull::coordinator::{
    BackendKind, BatcherConfig, CoordinatorConfig, HullRequest, RequestError,
};
use wagener_hull::engine::{Engine, EngineConfig};
use wagener_hull::geometry::generators::{generate, Distribution};
use wagener_hull::geometry::point::Point;
use wagener_hull::stream::StreamConfig;

fn start_engine(max_queued: usize) -> Arc<Engine> {
    Arc::new(
        Engine::start(EngineConfig {
            shards: 1,
            coordinator: CoordinatorConfig {
                backend: BackendKind::Serial,
                workers: 1,
                batcher: BatcherConfig { max_batch: 1, flush_us: 100, queue_cap: 8192 },
                self_check: false,
                ..Default::default()
            },
            stream: StreamConfig::default(),
            max_queued,
            ..Default::default()
        })
        .unwrap(),
    )
}

struct BurstTally {
    ok: AtomicUsize,
    shed: AtomicUsize,
    other: AtomicUsize,
    done: AtomicUsize,
    /// sojourn (submit → completion) of every SERVED request, in ns
    sojourn_ns: Mutex<Vec<f64>>,
}

impl BurstTally {
    fn new() -> Self {
        BurstTally {
            ok: AtomicUsize::new(0),
            shed: AtomicUsize::new(0),
            other: AtomicUsize::new(0),
            done: AtomicUsize::new(0),
            sojourn_ns: Mutex::new(Vec::new()),
        }
    }
}

/// Submit `burst` copies of `pts` open-loop at one request per
/// `interval` (≈ 2× capacity when `interval` is half the service time),
/// then wait for every one to resolve — shed requests fail fast, queued
/// ones drain at the backend's pace.
fn run_burst(
    e: &Arc<Engine>,
    pts: &[Point],
    burst: usize,
    interval: Duration,
    tally: &Arc<BurstTally>,
) {
    // the tally accumulates across repeated bench iterations; this burst
    // is drained once `done` has advanced by exactly `burst`
    let done0 = tally.done.load(Ordering::Acquire);
    let t0 = Instant::now();
    for k in 0..burst {
        // open-loop pacing against the global clock (sleep drift does not
        // accumulate: each slot is an absolute offset from the start)
        let due = interval * k as u32;
        let now = t0.elapsed();
        if now < due {
            std::thread::sleep(due - now);
        }
        let submitted = Instant::now();
        let tally = tally.clone();
        e.submit_into(HullRequest::new(k as u64 + 1, pts.to_vec()), move |res| {
            match res {
                Ok(_) => {
                    let ns = submitted.elapsed().as_nanos() as f64;
                    tally.sojourn_ns.lock().unwrap().push(ns);
                    tally.ok.fetch_add(1, Ordering::Relaxed);
                }
                Err(RequestError::Overloaded) => {
                    tally.shed.fetch_add(1, Ordering::Relaxed);
                }
                Err(_) => {
                    tally.other.fetch_add(1, Ordering::Relaxed);
                }
            }
            tally.done.fetch_add(1, Ordering::Relaxed);
        });
    }
    let deadline = Instant::now() + Duration::from_secs(60);
    while tally.done.load(Ordering::Acquire) < done0 + burst {
        assert!(Instant::now() < deadline, "burst did not drain within 60s");
        std::thread::sleep(Duration::from_micros(200));
    }
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[((sorted.len() as f64 * p) as usize).min(sorted.len() - 1)]
}

fn main() {
    let b = Bencher::default();
    let fast = std::env::var("WAGENER_BENCH_FAST").is_ok();
    let burst: usize = if fast { 200 } else { 800 };
    let pts = generate(Distribution::Disk, 8192, 42);

    let mut report = Report::new(&format!(
        "E11: overload robustness — {burst}-request bursts at 2x capacity, shedding off vs on"
    ));

    // (label, max_queued): 0 = unbounded queue, bounded = shed the excess
    for &(label, max_queued) in &[("shed_off", 0usize), ("shed_on", 64usize)] {
        let e = start_engine(max_queued);

        // closed-loop baseline: one request in flight, no queueing — this
        // row is also the capacity calibration for the burst pacing
        let baseline = b.run(&format!("robustness/{label}/closed_loop_rtt"), || {
            e.compute(pts.clone()).unwrap().upper.len()
        });
        let service = Duration::from_nanos(baseline.mean_ns.max(1.0) as u64);
        report.add(baseline);

        // open-loop burst at 2× capacity: one submit per service/2
        let interval = service / 2;
        let tally = Arc::new(BurstTally::new());
        report.add(b.run_batched(
            &format!("robustness/{label}/overload_2x_wall_per_req"),
            burst,
            || run_burst(&e, &pts, burst, interval, &tally),
        ));

        let ok = tally.ok.load(Ordering::Acquire);
        let shed = tally.shed.load(Ordering::Acquire);
        let other = tally.other.load(Ordering::Acquire);
        let total = ok + shed + other;
        let mut sojourns = tally.sojourn_ns.lock().unwrap().clone();
        sojourns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        report.note(format!(
            "{label} (max_queued={max_queued}): served {ok}/{total}, shed {shed} \
             ({:.1}%), other {other}; served sojourn p50 {:.0} µs, p95 {:.0} µs, \
             max {:.0} µs (closed-loop {:.0} µs)",
            100.0 * shed as f64 / total.max(1) as f64,
            percentile(&sojourns, 0.50) / 1e3,
            percentile(&sojourns, 0.95) / 1e3,
            sojourns.last().copied().unwrap_or(0.0) / 1e3,
            service.as_nanos() as f64 / 1e3,
        ));
        let snap = e.snapshot().0;
        report.note(format!(
            "{label}: engine shed_total={} deadline_exceeded_total={} retries_total={}",
            snap.get("shed_total").and_then(|v| v.as_usize()).unwrap_or(0),
            snap.get("deadline_exceeded_total").and_then(|v| v.as_usize()).unwrap_or(0),
            snap.get("retries_total").and_then(|v| v.as_usize()).unwrap_or(0),
        ));
    }
    report.finish();
}
