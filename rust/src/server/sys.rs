//! Minimal readiness-notification syscalls for the event loop: an
//! `epoll(7)` poller on Linux with a `poll(2)` fallback on other unixes,
//! a self-pipe waker, and NOFILE rlimit helpers — all via raw `extern
//! "C"` declarations against the libc that `std` already links, so no
//! external crate (and no async runtime) is needed.
//!
//! Scope is deliberately tiny: level-triggered readiness on sockets plus
//! a cross-thread wake primitive.  Everything else (non-blocking mode,
//! accept, read/write) goes through `std::net`.

use std::io;
use std::os::raw::{c_int, c_void};
use std::os::unix::io::RawFd;

/// Interest in readability.
pub const EV_READ: u32 = 0b01;
/// Interest in writability.
pub const EV_WRITE: u32 = 0b10;

/// One readiness event.  Error/hangup conditions are folded into the
/// readiness flags (mio-style): a dead socket reports readable, the next
/// `read` returns 0 or an error, and the connection closes through the
/// normal path.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
}

fn last_errno() -> io::Error {
    io::Error::last_os_error()
}

fn cvt(ret: c_int) -> io::Result<c_int> {
    if ret < 0 {
        Err(last_errno())
    } else {
        Ok(ret)
    }
}

// ---------------------------------------------------------------- linux

#[cfg(target_os = "linux")]
mod imp {
    use super::*;

    // The kernel packs epoll_event on x86_64 (12 bytes); other arches use
    // natural alignment.  Getting this wrong corrupts every second event.
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    const EPOLL_CTL_ADD: c_int = 1;
    const EPOLL_CTL_DEL: c_int = 2;
    const EPOLL_CTL_MOD: c_int = 3;
    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLLRDHUP: u32 = 0x2000;
    const EPOLL_CLOEXEC: c_int = 0x80000;

    extern "C" {
        fn epoll_create1(flags: c_int) -> c_int;
        fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout: c_int,
        ) -> c_int;
        fn close(fd: c_int) -> c_int;
    }

    const MAX_EVENTS: usize = 1024;

    /// Level-triggered epoll instance.
    pub struct Poller {
        epfd: c_int,
        buf: Vec<EpollEvent>,
    }

    fn mask(interest: u32) -> u32 {
        let mut m = 0u32;
        if interest & EV_READ != 0 {
            m |= EPOLLIN | EPOLLRDHUP;
        }
        if interest & EV_WRITE != 0 {
            m |= EPOLLOUT;
        }
        m
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            let epfd = cvt(unsafe { epoll_create1(EPOLL_CLOEXEC) })?;
            Ok(Poller { epfd, buf: vec![EpollEvent { events: 0, data: 0 }; MAX_EVENTS] })
        }

        fn ctl(&mut self, op: c_int, fd: RawFd, token: u64, interest: u32) -> io::Result<()> {
            let mut ev = EpollEvent { events: mask(interest), data: token };
            cvt(unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) })?;
            Ok(())
        }

        pub fn add(&mut self, fd: RawFd, token: u64, interest: u32) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, token, interest)
        }

        pub fn modify(&mut self, fd: RawFd, token: u64, interest: u32) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, token, interest)
        }

        pub fn delete(&mut self, fd: RawFd) -> io::Result<()> {
            let mut ev = EpollEvent { events: 0, data: 0 };
            cvt(unsafe { epoll_ctl(self.epfd, EPOLL_CTL_DEL, fd, &mut ev) })?;
            Ok(())
        }

        /// Wait for readiness; `timeout_ms < 0` blocks indefinitely.
        /// EINTR returns an empty event set instead of an error.
        pub fn wait(&mut self, out: &mut Vec<Event>, timeout_ms: i32) -> io::Result<()> {
            out.clear();
            let n = unsafe {
                epoll_wait(self.epfd, self.buf.as_mut_ptr(), MAX_EVENTS as c_int, timeout_ms)
            };
            if n < 0 {
                let e = last_errno();
                if e.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(e);
            }
            for ev in self.buf.iter().take(n as usize).copied() {
                let bits = { ev.events };
                let token = { ev.data };
                out.push(Event {
                    token,
                    readable: bits & (EPOLLIN | EPOLLRDHUP | EPOLLERR | EPOLLHUP) != 0,
                    writable: bits & (EPOLLOUT | EPOLLERR | EPOLLHUP) != 0,
                });
            }
            Ok(())
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            unsafe { close(self.epfd) };
        }
    }
}

// ------------------------------------------------------ other unixes

#[cfg(all(unix, not(target_os = "linux")))]
mod imp {
    use super::*;

    #[repr(C)]
    #[derive(Clone, Copy)]
    struct PollFd {
        fd: c_int,
        events: i16,
        revents: i16,
    }

    const POLLIN: i16 = 0x001;
    const POLLOUT: i16 = 0x004;
    const POLLERR: i16 = 0x008;
    const POLLHUP: i16 = 0x010;

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: std::os::raw::c_uint, timeout: c_int) -> c_int;
    }

    /// `poll(2)`-backed poller: O(n) per wait, fine as a portability
    /// fallback (the Linux build — every deployment target — uses epoll).
    pub struct Poller {
        interests: Vec<(RawFd, u64, u32)>,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            Ok(Poller { interests: Vec::new() })
        }

        pub fn add(&mut self, fd: RawFd, token: u64, interest: u32) -> io::Result<()> {
            self.interests.push((fd, token, interest));
            Ok(())
        }

        pub fn modify(&mut self, fd: RawFd, token: u64, interest: u32) -> io::Result<()> {
            match self.interests.iter_mut().find(|(f, _, _)| *f == fd) {
                Some(slot) => {
                    *slot = (fd, token, interest);
                    Ok(())
                }
                None => Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered")),
            }
        }

        pub fn delete(&mut self, fd: RawFd) -> io::Result<()> {
            self.interests.retain(|(f, _, _)| *f != fd);
            Ok(())
        }

        pub fn wait(&mut self, out: &mut Vec<Event>, timeout_ms: i32) -> io::Result<()> {
            out.clear();
            let mut fds: Vec<PollFd> = self
                .interests
                .iter()
                .map(|&(fd, _, interest)| {
                    let mut events = 0i16;
                    if interest & EV_READ != 0 {
                        events |= POLLIN;
                    }
                    if interest & EV_WRITE != 0 {
                        events |= POLLOUT;
                    }
                    PollFd { fd, events, revents: 0 }
                })
                .collect();
            let n = unsafe {
                poll(fds.as_mut_ptr(), fds.len() as std::os::raw::c_uint, timeout_ms)
            };
            if n < 0 {
                let e = last_errno();
                if e.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(e);
            }
            for (pfd, &(_, token, _)) in fds.iter().zip(self.interests.iter()) {
                let r = pfd.revents;
                if r == 0 {
                    continue;
                }
                out.push(Event {
                    token,
                    readable: r & (POLLIN | POLLERR | POLLHUP) != 0,
                    writable: r & (POLLOUT | POLLERR | POLLHUP) != 0,
                });
            }
            Ok(())
        }
    }
}

pub use imp::Poller;

// ----------------------------------------------------------- self-pipe

extern "C" {
    fn pipe(fds: *mut c_int) -> c_int;
    fn fcntl(fd: c_int, cmd: c_int, arg: c_int) -> c_int;
    fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
    fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
}

mod fd_close {
    use super::c_int;
    extern "C" {
        pub fn close(fd: c_int) -> c_int;
    }
}

const F_GETFL: c_int = 3;
const F_SETFL: c_int = 4;
const F_SETFD: c_int = 2;
const FD_CLOEXEC: c_int = 1;
#[cfg(target_os = "linux")]
const O_NONBLOCK: c_int = 0o4000;
#[cfg(all(unix, not(target_os = "linux")))]
const O_NONBLOCK: c_int = 0x0004;

fn set_nonblocking_cloexec(fd: RawFd) -> io::Result<()> {
    let flags = cvt(unsafe { fcntl(fd, F_GETFL, 0) })?;
    cvt(unsafe { fcntl(fd, F_SETFL, flags | O_NONBLOCK) })?;
    cvt(unsafe { fcntl(fd, F_SETFD, FD_CLOEXEC) })?;
    Ok(())
}

/// A self-pipe wake primitive: any thread calls [`Waker::wake`], the
/// owning event loop sees the read end become readable and calls
/// [`Waker::drain`].  Both ends are non-blocking, so a full pipe makes
/// `wake` a no-op (the loop is already pending wake-up) and a wake after
/// the loop closed its end fails harmlessly (Rust ignores `SIGPIPE`).
pub struct Waker {
    read_fd: RawFd,
    write_fd: RawFd,
}

// Raw fds are plain integers; writes to a pipe are atomic at this size.
unsafe impl Send for Waker {}
unsafe impl Sync for Waker {}

impl Waker {
    pub fn new() -> io::Result<Waker> {
        let mut fds = [0 as c_int; 2];
        cvt(unsafe { pipe(fds.as_mut_ptr()) })?;
        let (r, w) = (fds[0], fds[1]);
        for fd in [r, w] {
            if let Err(e) = set_nonblocking_cloexec(fd) {
                unsafe {
                    fd_close::close(r);
                    fd_close::close(w);
                }
                return Err(e);
            }
        }
        Ok(Waker { read_fd: r, write_fd: w })
    }

    /// The fd to register with [`Poller::add`] under `EV_READ`.
    pub fn fd(&self) -> RawFd {
        self.read_fd
    }

    /// Make the owning loop's next `wait` return immediately.
    pub fn wake(&self) {
        let byte = 1u8;
        unsafe { write(self.write_fd, &byte as *const u8 as *const c_void, 1) };
    }

    /// Swallow all queued wake bytes (call when the read end polls ready).
    pub fn drain(&self) {
        let mut buf = [0u8; 64];
        loop {
            let n = unsafe { read(self.read_fd, buf.as_mut_ptr() as *mut c_void, buf.len()) };
            if n <= 0 || (n as usize) < buf.len() {
                break;
            }
        }
    }
}

impl Drop for Waker {
    fn drop(&mut self) {
        unsafe {
            fd_close::close(self.read_fd);
            fd_close::close(self.write_fd);
        }
    }
}

// ------------------------------------------------------------- rlimits

#[repr(C)]
struct Rlimit {
    cur: u64,
    max: u64,
}

#[cfg(target_os = "linux")]
const RLIMIT_NOFILE: c_int = 7;
#[cfg(all(unix, not(target_os = "linux")))]
const RLIMIT_NOFILE: c_int = 8;

extern "C" {
    fn getrlimit(resource: c_int, rlim: *mut Rlimit) -> c_int;
    fn setrlimit(resource: c_int, rlim: *const Rlimit) -> c_int;
}

/// Current `(soft, hard)` open-file limit.
pub fn nofile_limit() -> io::Result<(u64, u64)> {
    let mut r = Rlimit { cur: 0, max: 0 };
    cvt(unsafe { getrlimit(RLIMIT_NOFILE, &mut r) })?;
    Ok((r.cur, r.max))
}

/// Best-effort raise of the soft NOFILE limit toward `want` (capped at
/// the hard limit); returns the effective soft limit afterwards.  Used by
/// the 10k-connection integration test, which skips when the box refuses.
pub fn raise_nofile_limit(want: u64) -> u64 {
    let Ok((cur, max)) = nofile_limit() else { return 0 };
    if cur >= want {
        return cur;
    }
    let target = want.min(max);
    let r = Rlimit { cur: target, max };
    if unsafe { setrlimit(RLIMIT_NOFILE, &r) } == 0 {
        target
    } else {
        cur
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read as _, Write as _};
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;

    #[test]
    fn waker_wakes_and_drains() {
        let mut poller = Poller::new().unwrap();
        let waker = Waker::new().unwrap();
        poller.add(waker.fd(), 1, EV_READ).unwrap();
        let mut events = Vec::new();

        // no wake: times out empty
        poller.wait(&mut events, 0).unwrap();
        assert!(events.is_empty());

        waker.wake();
        waker.wake(); // coalesces
        poller.wait(&mut events, 1000).unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].token, 1);
        assert!(events[0].readable);
        waker.drain();

        // drained: quiet again
        poller.wait(&mut events, 0).unwrap();
        assert!(events.is_empty());
    }

    #[test]
    fn waker_wakes_from_another_thread() {
        let mut poller = Poller::new().unwrap();
        let waker = std::sync::Arc::new(Waker::new().unwrap());
        poller.add(waker.fd(), 7, EV_READ).unwrap();
        let w2 = waker.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(30));
            w2.wake();
        });
        let mut events = Vec::new();
        poller.wait(&mut events, 5000).unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].token, 7);
        h.join().unwrap();
    }

    #[test]
    fn socket_readiness_roundtrip() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();

        let mut poller = Poller::new().unwrap();
        poller.add(server.as_raw_fd(), 42, EV_READ).unwrap();
        let mut events = Vec::new();

        poller.wait(&mut events, 0).unwrap();
        assert!(events.is_empty(), "no data yet");

        client.write_all(b"hi").unwrap();
        client.flush().unwrap();
        poller.wait(&mut events, 2000).unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].token, 42);
        assert!(events[0].readable);

        // level-triggered: still readable until consumed
        poller.wait(&mut events, 100).unwrap();
        assert_eq!(events.len(), 1);
        let mut s = server;
        let mut buf = [0u8; 8];
        assert_eq!(s.read(&mut buf).unwrap(), 2);

        // modify to write interest: an idle socket is instantly writable
        poller.modify(s.as_raw_fd(), 43, EV_WRITE).unwrap();
        poller.wait(&mut events, 1000).unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].token, 43);
        assert!(events[0].writable);

        poller.delete(s.as_raw_fd()).unwrap();
        poller.wait(&mut events, 0).unwrap();
        assert!(events.is_empty());
    }

    #[test]
    fn peer_close_reports_readable() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();

        let mut poller = Poller::new().unwrap();
        poller.add(server.as_raw_fd(), 9, EV_READ).unwrap();
        drop(client);
        let mut events = Vec::new();
        poller.wait(&mut events, 2000).unwrap();
        assert_eq!(events.len(), 1);
        assert!(events[0].readable, "hangup folds into readability");
    }

    #[test]
    fn nofile_limits_query() {
        let (cur, max) = nofile_limit().unwrap();
        assert!(cur > 0 && max >= cur);
        // raising toward the current value is a no-op that reports cur
        assert_eq!(raise_nofile_limit(cur), cur);
    }
}
