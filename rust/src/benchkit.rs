//! Built-in micro-benchmark harness (substitute for `criterion`, which is
//! not vendored in this offline environment).
//!
//! Time-targeted sampling with warmup, robust stats (median/p95), and
//! paper-style table output.  Every `cargo bench` target is a
//! `harness = false` binary built on this module, so `cargo bench` works
//! with no external dev-dependencies.

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

pub use std::hint::black_box;

/// Robust summary of per-iteration wall times.
#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    pub stddev_ns: f64,
}

impl Stats {
    pub fn mean(&self) -> Duration {
        Duration::from_nanos(self.mean_ns as u64)
    }
    /// items processed per second given `items` of work per iteration.
    pub fn throughput(&self, items: usize) -> f64 {
        items as f64 / (self.mean_ns * 1e-9)
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

impl std::fmt::Display for Stats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} {:>12} {:>12} {:>12}  x{}",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.p95_ns),
            self.iters
        )
    }
}

/// Benchmark runner configuration.
#[derive(Clone, Debug)]
pub struct Bencher {
    pub warmup: Duration,
    pub target: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        // Overridable for CI smoke runs: WAGENER_BENCH_FAST=1.
        let fast = std::env::var("WAGENER_BENCH_FAST").is_ok();
        Bencher {
            warmup: Duration::from_millis(if fast { 20 } else { 200 }),
            target: Duration::from_millis(if fast { 100 } else { 1000 }),
            min_iters: 5,
            max_iters: 1_000_000,
        }
    }
}

impl Bencher {
    /// Measure `f`, returning robust stats. `f` should consume its output
    /// via `black_box` internally or return a value (which we black_box).
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> Stats {
        // Warmup + pilot estimate.
        let warm_start = Instant::now();
        let mut pilot_iters = 0usize;
        while warm_start.elapsed() < self.warmup || pilot_iters < 2 {
            std_black_box(f());
            pilot_iters += 1;
            if pilot_iters >= self.max_iters {
                break;
            }
        }
        let per_iter = warm_start.elapsed().as_secs_f64() / pilot_iters as f64;
        let planned = ((self.target.as_secs_f64() / per_iter.max(1e-9)) as usize)
            .clamp(self.min_iters, self.max_iters);

        let mut samples = Vec::with_capacity(planned);
        for _ in 0..planned {
            let t = Instant::now();
            std_black_box(f());
            samples.push(t.elapsed().as_nanos() as f64);
        }
        Self::stats_from(name, &mut samples)
    }

    /// Measure a batch-style closure that runs `k` logical operations per
    /// call; stats are per logical operation.
    pub fn run_batched<T, F: FnMut() -> T>(&self, name: &str, k: usize, f: F) -> Stats {
        let mut s = self.run(name, f);
        let k = k.max(1) as f64;
        s.mean_ns /= k;
        s.median_ns /= k;
        s.p95_ns /= k;
        s.min_ns /= k;
        s.stddev_ns /= k;
        s
    }

    fn stats_from(name: &str, samples: &mut [f64]) -> Stats {
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var =
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Stats {
            name: name.to_string(),
            iters: n,
            mean_ns: mean,
            median_ns: samples[n / 2],
            p95_ns: samples[(n as f64 * 0.95) as usize % n.max(1)],
            min_ns: samples[0],
            stddev_ns: var.sqrt(),
        }
    }
}

/// Collects rows and prints a paper-style table; also emits a machine-
/// readable JSON block consumed by scripts/experiments.
pub struct Report {
    title: String,
    rows: Vec<Stats>,
    notes: Vec<String>,
}

impl Report {
    pub fn new(title: &str) -> Self {
        println!("\n=== {title} ===");
        println!(
            "{:<44} {:>12} {:>12} {:>12}  iters",
            "benchmark", "median", "mean", "p95"
        );
        Report {
            title: title.to_string(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn add(&mut self, s: Stats) {
        println!("{s}");
        self.rows.push(s);
    }

    pub fn note(&mut self, msg: impl Into<String>) {
        let msg = msg.into();
        println!("  # {msg}");
        self.notes.push(msg);
    }

    /// Emit the JSON trailer (one line, greppable as BENCH_JSON).  When
    /// `WAGENER_BENCH_JSON=<path>` is set, the same document is appended
    /// to that file (one JSON object per line) — how `scripts/tier1.sh`
    /// builds BENCH_pram.json as the cross-PR perf trajectory.
    pub fn finish(self) {
        use crate::util::json::Json;
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("name", Json::Str(s.name.clone())),
                    ("median_ns", Json::Num(s.median_ns)),
                    ("mean_ns", Json::Num(s.mean_ns)),
                    ("p95_ns", Json::Num(s.p95_ns)),
                    ("iters", Json::Num(s.iters as f64)),
                ])
            })
            .collect();
        let doc = Json::obj(vec![
            ("title", Json::Str(self.title)),
            ("rows", Json::Arr(rows)),
            (
                "notes",
                Json::Arr(self.notes.into_iter().map(Json::Str).collect()),
            ),
        ]);
        println!("BENCH_JSON {doc}");
        if let Ok(path) = std::env::var("WAGENER_BENCH_JSON") {
            if !path.is_empty() {
                use std::io::Write;
                let sink = std::fs::OpenOptions::new().create(true).append(true).open(&path);
                match sink.and_then(|mut f| writeln!(f, "{doc}")) {
                    Ok(()) => {}
                    Err(e) => eprintln!("benchkit: cannot append to {path}: {e}"),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_produces_sane_stats() {
        let b = Bencher {
            warmup: Duration::from_millis(1),
            target: Duration::from_millis(10),
            min_iters: 5,
            max_iters: 10_000,
        };
        let s = b.run("spin", || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(black_box(i));
            }
            acc
        });
        assert!(s.iters >= 5);
        assert!(s.median_ns > 0.0);
        assert!(s.min_ns <= s.median_ns);
        assert!(s.median_ns <= s.p95_ns * 1.0001);
    }

    #[test]
    fn batched_divides() {
        let b = Bencher {
            warmup: Duration::from_millis(1),
            target: Duration::from_millis(5),
            min_iters: 5,
            max_iters: 10_000,
        };
        let s1 = b.run("one", || std::thread::yield_now());
        let s10 = b.run_batched("ten", 10, || {
            for _ in 0..10 {
                std::thread::yield_now();
            }
        });
        // per-op cost of the batched version should be within ~10x of single
        assert!(s10.mean_ns < s1.mean_ns * 10.0 + 1e5);
    }

    #[test]
    fn throughput_math() {
        let s = Stats {
            name: "t".into(),
            iters: 1,
            mean_ns: 1e9,
            median_ns: 1e9,
            p95_ns: 1e9,
            min_ns: 1e9,
            stddev_ns: 0.0,
        };
        assert!((s.throughput(1000) - 1000.0).abs() < 1e-6);
    }
}
