//! Hull verification: independent validity checks used by tests, the
//! examples and the coordinator's (optional) self-check mode.

use super::point::Point;
use super::predicates::{orient2d, Orientation};

/// Why a candidate upper hull was rejected.
#[derive(Clone, Debug, PartialEq)]
pub enum HullError {
    Empty,
    NotSortedByX(usize),
    NotStrictlyConvex(usize),
    NotFromInput(usize),
    PointAbove(usize),
    MissingExtreme(&'static str),
}

impl std::fmt::Display for HullError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HullError::Empty => write!(f, "hull is empty"),
            HullError::NotSortedByX(i) => write!(f, "hull x-order violated at {i}"),
            HullError::NotStrictlyConvex(i) => write!(f, "hull not strictly convex at {i}"),
            HullError::NotFromInput(i) => write!(f, "hull corner {i} not an input point"),
            HullError::PointAbove(i) => write!(f, "input point {i} above the hull"),
            HullError::MissingExtreme(w) => write!(f, "{w} extreme point missing"),
        }
    }
}

impl std::error::Error for HullError {}

/// Validate `hull` as THE upper hull of `points` (both x-sorted).
///
/// Checks: non-empty, strictly increasing x, strictly convex (every
/// interior corner strictly above its neighbors' chord), corners are input
/// points, extremes present, and no input point strictly above any hull
/// edge.  O(n log h).
pub fn check_upper_hull(points: &[Point], hull: &[Point]) -> Result<(), HullError> {
    if hull.is_empty() || points.is_empty() {
        return Err(HullError::Empty);
    }
    for i in 1..hull.len() {
        if hull[i - 1].x >= hull[i].x {
            return Err(HullError::NotSortedByX(i));
        }
    }
    for i in 1..hull.len().saturating_sub(1) {
        // corner strictly above chord (prev -> next)
        if orient2d(hull[i - 1], hull[i + 1], hull[i]) != Orientation::Left {
            return Err(HullError::NotStrictlyConvex(i));
        }
    }
    for (i, h) in hull.iter().enumerate() {
        if !points.iter().any(|p| p == h) {
            return Err(HullError::NotFromInput(i));
        }
    }
    let first = points.first().unwrap();
    let last = points.last().unwrap();
    if hull.first().unwrap() != first {
        return Err(HullError::MissingExtreme("leftmost"));
    }
    if hull.last().unwrap() != last {
        return Err(HullError::MissingExtreme("rightmost"));
    }
    // every input point at-or-below the chain
    for (i, p) in points.iter().enumerate() {
        if hull.iter().any(|h| h == p) {
            continue;
        }
        let seg = hull.partition_point(|h| h.x <= p.x);
        // p.x lies in [hull[seg-1].x, hull[seg].x)
        if seg == 0 || seg >= hull.len() + 1 {
            return Err(HullError::PointAbove(i));
        }
        let (a, b) = if seg == hull.len() {
            (hull[seg - 2], hull[seg - 1])
        } else {
            (hull[seg - 1], hull[seg])
        };
        if orient2d(a, b, *p) == Orientation::Left {
            return Err(HullError::PointAbove(i));
        }
    }
    Ok(())
}

/// Brute-force upper hull by definition: a point is a corner iff it is not
/// strictly below any segment between two other points and not dominated.
/// O(n^3); test-oracle only.  Input x-sorted, distinct x, general position.
pub fn brute_force_upper_hull(points: &[Point]) -> Vec<Point> {
    let n = points.len();
    if n <= 2 {
        return points.to_vec();
    }
    let mut hull = Vec::new();
    'cand: for (k, &r) in points.iter().enumerate() {
        for i in 0..n {
            for j in (i + 1)..n {
                if i == k || j == k {
                    continue;
                }
                // r strictly below segment points[i] -> points[j]?
                let (a, b) = (points[i], points[j]);
                if a.x < r.x && r.x < b.x && orient2d(a, b, r) == Orientation::Right {
                    continue 'cand;
                }
            }
        }
        hull.push(r);
        let _ = k;
    }
    hull
}

/// Signed doubled area of a closed polygon (CCW positive).
pub fn polygon_area2(poly: &[Point]) -> f64 {
    let n = poly.len();
    let mut s = 0.0;
    for i in 0..n {
        let a = poly[i];
        let b = poly[(i + 1) % n];
        s += a.x * b.y - b.x * a.y;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::point::sort_by_x;
    use crate::util::rng::Rng;

    fn pts(v: &[(f64, f64)]) -> Vec<Point> {
        v.iter().map(|&(x, y)| Point::new(x, y)).collect()
    }

    #[test]
    fn accepts_valid_hull() {
        let points = pts(&[(0.0, 0.0), (0.25, 0.9), (0.5, 0.1), (1.0, 0.2)]);
        let hull = pts(&[(0.0, 0.0), (0.25, 0.9), (1.0, 0.2)]);
        check_upper_hull(&points, &hull).unwrap();
    }

    #[test]
    fn rejects_missing_corner() {
        let points = pts(&[(0.0, 0.0), (0.25, 0.9), (0.5, 0.1), (1.0, 0.2)]);
        let hull = pts(&[(0.0, 0.0), (1.0, 0.2)]); // 0.25-peak left out
        assert!(matches!(
            check_upper_hull(&points, &hull),
            Err(HullError::PointAbove(_))
        ));
    }

    #[test]
    fn rejects_concave_chain() {
        let points = pts(&[(0.0, 0.5), (0.5, 0.0), (1.0, 0.5)]);
        let hull = points.clone(); // dip is not a hull corner
        assert!(matches!(
            check_upper_hull(&points, &hull),
            Err(HullError::NotStrictlyConvex(1))
        ));
    }

    #[test]
    fn rejects_foreign_corner() {
        let points = pts(&[(0.0, 0.0), (1.0, 0.0)]);
        let hull = pts(&[(0.0, 0.0), (0.5, 2.0), (1.0, 0.0)]);
        assert!(matches!(
            check_upper_hull(&points, &hull),
            Err(HullError::NotFromInput(1))
        ));
    }

    #[test]
    fn rejects_missing_extremes() {
        let points = pts(&[(0.0, 0.0), (0.5, 1.0), (1.0, 0.0)]);
        let hull = pts(&[(0.5, 1.0), (1.0, 0.0)]);
        assert_eq!(
            check_upper_hull(&points, &hull),
            Err(HullError::MissingExtreme("leftmost"))
        );
    }

    #[test]
    fn brute_force_matches_known() {
        let points = pts(&[(0.0, 0.0), (0.2, 0.5), (0.4, 0.3), (0.6, 0.8), (1.0, 0.1)]);
        let hull = brute_force_upper_hull(&points);
        assert_eq!(hull, pts(&[(0.0, 0.0), (0.2, 0.5), (0.6, 0.8), (1.0, 0.1)]));
        check_upper_hull(&points, &hull).unwrap();
    }

    #[test]
    fn brute_force_validates_on_random() {
        let mut rng = Rng::new(17);
        for _ in 0..50 {
            let n = rng.range_usize(3, 24);
            let mut p: Vec<Point> =
                (0..n).map(|_| Point::new(rng.f64(), rng.f64())).collect();
            sort_by_x(&mut p);
            p.dedup_by(|a, b| a.x == b.x);
            let hull = brute_force_upper_hull(&p);
            check_upper_hull(&p, &hull).unwrap();
        }
    }

    #[test]
    fn area_sign() {
        let sq = pts(&[(0., 0.), (1., 0.), (1., 1.), (0., 1.)]);
        assert!((polygon_area2(&sq) - 2.0).abs() < 1e-12);
        let cw: Vec<Point> = sq.into_iter().rev().collect();
        assert!((polygon_area2(&cw) + 2.0).abs() < 1e-12);
    }
}
