//! Deterministic protocol fuzzing: seeded random inputs through every
//! decoder (text request, binary request, binary response), run as a
//! normal `#[test]` with a bounded iteration budget so it rides in the
//! tier-1 suite (no external fuzzer, no wall-clock dependence).
//!
//! Properties checked on every input:
//!   * no panic (the driver is this test completing);
//!   * no unbounded buffering: `Decoded::Need(n)` always makes progress
//!     (`n > buf.len()`) and never exceeds the protocol's hard caps, so
//!     a hostile frame cannot talk a connection into a huge allocation;
//!   * id echo: any rejection whose header parsed far enough to carry a
//!     request id reports it through [`ProtoError::frame_id`] — the rule
//!     clients rely on to correlate failures;
//!   * valid frames survive encode -> decode bit-exactly, and every
//!     strict prefix of a valid frame is `Need`, never an error.

use wagener_hull::gateway::cursor;
use wagener_hull::gateway::http::{self, HttpError, MAX_HEAD_BYTES};
use wagener_hull::geometry::point::Point;
use wagener_hull::server::proto::{
    self, Decoded, ProtoError, Request, MAX_REQUEST_POINTS, MAX_TEXT_LINE,
};
use wagener_hull::server::{frame, Response, SessionVerb};
use wagener_hull::util::rng::Rng;

const REQ_HEADER: usize = 15;
const RESP_HEADER: usize = 16;
/// Largest total-bytes value a request decoder may ever ask for.
const REQ_NEED_CEIL: usize = REQ_HEADER + MAX_REQUEST_POINTS * 16;
/// Mirrors `frame::MAX_RESPONSE_PAYLOAD` (private) plus header slack.
const RESP_NEED_CEIL: usize = RESP_HEADER + MAX_REQUEST_POINTS * 32 + (1 << 20);

/// The id a *binary* request rejection must echo: present whenever the
/// fixed header is complete with the right magic and version.
fn expected_binary_id(buf: &[u8]) -> Option<u64> {
    if buf.len() >= REQ_HEADER && buf[0] == frame::REQ_MAGIC && buf[1] == frame::VERSION {
        Some(u64::from_le_bytes(buf[3..11].try_into().unwrap()))
    } else {
        None
    }
}

/// The id a *text* rejection must echo: a complete `HULL`/`SADD` header
/// line whose id token parses.  (Other verbs never fail once their sid
/// parses, so the property is only meaningful for the point-block verbs.)
fn expected_text_id(buf: &[u8]) -> Option<u64> {
    let eol = buf.iter().position(|&b| b == b'\n')?;
    let line = std::str::from_utf8(&buf[..eol]).ok()?;
    let mut it = line.split_whitespace();
    if !matches!(it.next(), Some("HULL") | Some("SADD")) {
        return None;
    }
    it.next()?.parse().ok()
}

fn check_binary_request(buf: &[u8]) {
    match frame::decode_request(buf) {
        Ok(Decoded::Need(n)) => {
            assert!(n > buf.len(), "Need({n}) makes no progress at len {}", buf.len());
            assert!(n <= REQ_NEED_CEIL, "Need({n}) over the request cap");
        }
        Ok(Decoded::Frame(_, used)) => {
            assert!(used <= buf.len() && used >= REQ_HEADER, "used {used} of {}", buf.len());
        }
        Err(e) => {
            if let Some(id) = expected_binary_id(buf) {
                assert_eq!(e.frame_id(), Some(id), "lost the id echo: {e}");
            }
        }
    }
}

fn check_text_request(buf: &[u8]) {
    match proto::decode_text_request(buf) {
        Ok(Decoded::Need(n)) => {
            // the text decoder can only ask for "one more byte"
            assert_eq!(n, buf.len() + 1);
            assert!(n <= REQ_NEED_CEIL.max(MAX_TEXT_LINE * 2));
        }
        Ok(Decoded::Frame(_, used)) => assert!(used <= buf.len() && used > 0),
        Err(e) => {
            if !matches!(e, ProtoError::Eof) {
                if let Some(id) = expected_text_id(buf) {
                    assert_eq!(e.frame_id(), Some(id), "lost the id echo: {e} in {buf:?}");
                }
            }
        }
    }
}

fn check_binary_response(buf: &[u8]) {
    match frame::decode_response(buf) {
        Ok(Decoded::Need(n)) => {
            assert!(n > buf.len());
            assert!(n <= RESP_NEED_CEIL, "Need({n}) over the response cap");
        }
        Ok(Decoded::Frame(_, used)) => assert!(used <= buf.len() && used >= RESP_HEADER),
        Err(_) => {} // client-side: any rejection just drops the connection
    }
}

fn random_bytes(rng: &mut Rng, max_len: usize) -> Vec<u8> {
    let len = rng.range_usize(0, max_len + 1);
    (0..len).map(|_| rng.next_u64() as u8).collect()
}

fn random_points(rng: &mut Rng, max: usize) -> Vec<Point> {
    let n = rng.range_usize(0, max + 1);
    (0..n).map(|_| Point::new(rng.f64(), rng.f64())).collect()
}

fn random_request(rng: &mut Rng) -> Request {
    match rng.below(8) {
        0 => Request::Hull { id: rng.next_u64(), points: random_points(rng, 8), tmo_ms: None },
        1 => Request::SessionOpen {
            id: rng.next_u64(),
            restore: rng.chance(0.5).then(|| rng.next_u64()),
        },
        2 => Request::SessionAdd { sid: rng.next_u64(), points: random_points(rng, 8), tmo_ms: None },
        3 => Request::SessionHull {
            sid: rng.next_u64(),
            epoch: rng.chance(0.5).then(|| rng.next_u64()),
        },
        4 => Request::SessionClose { sid: rng.next_u64() },
        5 => Request::Stats,
        6 => Request::Ping,
        _ => Request::Quit,
    }
}

fn random_response(rng: &mut Rng) -> Response {
    match rng.below(10) {
        0 => Response::Hull {
            id: rng.next_u64(),
            upper: random_points(rng, 6),
            lower: random_points(rng, 6),
            backend: "native".into(),
            queue_ns: rng.next_u64(),
            exec_ns: rng.next_u64(),
        },
        1 => Response::HullErr { id: rng.next_u64(), message: "e".repeat(rng.range_usize(0, 40)) },
        2 => Response::MalformedErr {
            id: rng.chance(0.5).then(|| rng.next_u64()),
            message: "m".repeat(rng.range_usize(0, 40)),
        },
        3 => Response::SessionOpened { id: rng.next_u64(), sid: rng.next_u64() },
        4 => Response::SessionAdded {
            sid: rng.next_u64(),
            absorbed: rng.next_u64(),
            pending: rng.next_u64(),
            epoch: rng.next_u64(),
        },
        5 => Response::SessionHull {
            sid: rng.next_u64(),
            epoch: rng.next_u64(),
            upper: random_points(rng, 6),
            lower: random_points(rng, 6),
        },
        6 => Response::SessionClosed { sid: rng.next_u64() },
        7 => Response::SessionErr {
            verb: [SessionVerb::Open, SessionVerb::Add, SessionVerb::Hull, SessionVerb::Close]
                [rng.range_usize(0, 4)],
            id: rng.next_u64(),
            message: "x".repeat(rng.range_usize(0, 40)),
        },
        8 => Response::Stats("{\"requests\":1}".into()),
        _ => Response::Pong,
    }
}

// ------------------------------------------------------- random inputs

#[test]
fn random_bytes_never_panic_or_overcommit() {
    let mut rng = Rng::new(0xF0CC_0001);
    for i in 0..6000u32 {
        // mostly short, occasionally kilobytes (line-length guard paths)
        let max = if i % 50 == 0 { 4096 } else { 64 };
        let buf = random_bytes(&mut rng, max);
        check_binary_request(&buf);
        check_text_request(&buf);
        check_binary_response(&buf);
    }
}

/// Random inputs that *start like* real frames reach much deeper parser
/// states than raw noise: seed the prefix, randomize the rest.
#[test]
fn magic_prefixed_bytes_never_panic_and_echo_ids() {
    let mut rng = Rng::new(0xF0CC_0002);
    for _ in 0..6000u32 {
        let mut buf = vec![frame::REQ_MAGIC];
        if rng.chance(0.8) {
            buf.push(frame::VERSION);
        }
        buf.extend(random_bytes(&mut rng, 48));
        check_binary_request(&buf);
        let mut rbuf = vec![frame::RESP_MAGIC];
        if rng.chance(0.8) {
            rbuf.push(frame::VERSION);
        }
        rbuf.extend(random_bytes(&mut rng, 48));
        check_binary_response(&rbuf);
    }
}

/// Token soup: structurally plausible text frames (real verbs, junk
/// operands, stray point lines) exercise every branch of the header and
/// point-block parsers.
#[test]
fn text_token_soup_never_panics_and_echoes_ids() {
    const VERBS: &[&str] =
        &["HULL", "SADD", "SOPEN", "SHULL", "SCLOSE", "STATS", "PING", "QUIT", "BOGUS", ""];
    const OPERANDS: &[&str] =
        &["0", "1", "7", "42", "-1", "zz", "1e9", "0.5", "99999999999999999999", ""];
    const POINT_LINES: &[&str] = &["0.1 0.2", "0.5", "x y", "0.3 0.4 0.5", "", "NaN inf"];
    let mut rng = Rng::new(0xF0CC_0003);
    for _ in 0..8000u32 {
        let mut s = String::new();
        s.push_str(VERBS[rng.range_usize(0, VERBS.len())]);
        for _ in 0..rng.range_usize(0, 4) {
            s.push(' ');
            s.push_str(OPERANDS[rng.range_usize(0, OPERANDS.len())]);
        }
        s.push('\n');
        for _ in 0..rng.range_usize(0, 4) {
            s.push_str(POINT_LINES[rng.range_usize(0, POINT_LINES.len())]);
            s.push('\n');
        }
        let mut buf = s.into_bytes();
        if rng.chance(0.2) {
            // occasionally cut mid-line so Need paths run too
            buf.truncate(rng.range_usize(0, buf.len() + 1));
        }
        check_text_request(&buf);
    }
}

// ------------------------------------------- corpus: valid + mutated

#[test]
fn valid_frames_roundtrip_and_prefixes_are_need() {
    let mut rng = Rng::new(0xF0CC_0004);
    for _ in 0..1500u32 {
        let req = random_request(&mut rng);

        let mut bin = Vec::new();
        frame::encode_request(&mut bin, &req);
        match frame::decode_request(&bin) {
            Ok(Decoded::Frame(got, used)) => {
                assert_eq!(got, req);
                assert_eq!(used, bin.len());
            }
            other => panic!("valid binary frame: {other:?}"),
        }

        let mut txt = Vec::new();
        proto::write_request(&mut txt, &req).unwrap();
        match proto::decode_text_request(&txt) {
            Ok(Decoded::Frame(got, used)) => {
                assert_eq!(got, req);
                assert_eq!(used, txt.len());
            }
            other => panic!("valid text frame: {other:?}"),
        }

        // strict prefixes: always Need, never an error or a phantom frame
        for (is_bin, buf) in [(true, &bin), (false, &txt)] {
            for _ in 0..3 {
                let cut = rng.range_usize(0, buf.len());
                let decoded = if is_bin {
                    frame::decode_request(&buf[..cut])
                } else {
                    proto::decode_text_request(&buf[..cut])
                };
                match decoded {
                    Ok(Decoded::Need(n)) => assert!(n > cut),
                    Ok(Decoded::Frame(..)) => panic!("phantom frame in a {cut}-byte prefix"),
                    Err(e) => panic!("prefix of a valid frame errored: {e}"),
                }
            }
        }

        let resp = random_response(&mut rng);
        let mut rbin = Vec::new();
        frame::encode_response(&mut rbin, &resp);
        match frame::decode_response(&rbin) {
            Ok(Decoded::Frame(got, used)) => {
                assert_eq!(got, resp);
                assert_eq!(used, rbin.len());
            }
            other => panic!("valid response frame: {other:?}"),
        }
        for _ in 0..3 {
            let cut = rng.range_usize(0, rbin.len());
            match frame::decode_response(&rbin[..cut]) {
                Ok(Decoded::Need(n)) => assert!(n > cut),
                Ok(Decoded::Frame(..)) => panic!("phantom response in a {cut}-byte prefix"),
                Err(e) => panic!("prefix of a valid response errored: {e}"),
            }
        }
    }
}

#[test]
fn mutated_frames_never_panic_and_keep_the_id_echo() {
    let mut rng = Rng::new(0xF0CC_0005);
    for _ in 0..3000u32 {
        let req = random_request(&mut rng);
        let mut bin = Vec::new();
        frame::encode_request(&mut bin, &req);
        let mut txt = Vec::new();
        proto::write_request(&mut txt, &req).unwrap();
        for buf in [&mut bin, &mut txt] {
            for _ in 0..rng.range_usize(1, 5) {
                let at = rng.range_usize(0, buf.len());
                buf[at] = rng.next_u64() as u8;
            }
            if rng.chance(0.3) {
                buf.truncate(rng.range_usize(0, buf.len() + 1));
            }
        }
        // the expected ids are recomputed from the MUTATED bytes, so the
        // echo property is checked against what actually hit the wire
        check_binary_request(&bin);
        check_text_request(&txt);

        let resp = random_response(&mut rng);
        let mut rbin = Vec::new();
        frame::encode_response(&mut rbin, &resp);
        for _ in 0..rng.range_usize(1, 5) {
            let at = rng.range_usize(0, rbin.len());
            rbin[at] = rng.next_u64() as u8;
        }
        check_binary_response(&rbin);
    }
}

/// The DoS guard is total: EVERY count over the cap is rejected from
/// the header alone with the id echoed, on both wire formats.
#[test]
fn oversized_counts_always_reject_before_payload() {
    let mut rng = Rng::new(0xF0CC_0006);
    for _ in 0..500u32 {
        let id = rng.next_u64();
        let span = u32::MAX as u64 - MAX_REQUEST_POINTS as u64 - 1;
        let over = (MAX_REQUEST_POINTS as u64 + 1 + rng.below(span)) as u32;
        for verb in [1u8, 3] {
            // header only — no payload bytes exist to buffer
            let mut buf = vec![frame::REQ_MAGIC, frame::VERSION, verb];
            buf.extend_from_slice(&id.to_le_bytes());
            buf.extend_from_slice(&over.to_le_bytes());
            let e = frame::decode_request(&buf).unwrap_err();
            assert_eq!(e.frame_id(), Some(id), "binary verb {verb} count {over}");
        }
        for verb in ["HULL", "SADD"] {
            let line = format!("{verb} {id} {over}\n");
            let e = proto::decode_text_request(line.as_bytes()).unwrap_err();
            assert_eq!(e.frame_id(), Some(id), "text {verb} count {over}");
        }
    }
}

// ------------------------------------------------------ HTTP gateway

/// The gateway decoder's bounded-progress contract: `Need(n)` moves
/// forward and never asks past the head cap + body cap (plus buffered
/// chunk-framing overhead).  Errors are fatal by design — the gateway
/// answers once and closes — so any `Err` is acceptable here; the only
/// failures are panics and contract breaches.
fn check_http_request(buf: &[u8], max_body: usize) {
    match http::decode_request(buf, max_body) {
        Ok(Decoded::Need(n)) => {
            assert!(n > buf.len(), "Need({n}) makes no progress at len {}", buf.len());
            assert!(
                n <= buf.len().max(MAX_HEAD_BYTES) + max_body + 2,
                "Need({n}) over the cap (len {}, max_body {max_body})",
                buf.len()
            );
        }
        Ok(Decoded::Frame(_, used)) => {
            assert!(used <= buf.len() && used > 0, "used {used} of {}", buf.len());
        }
        Err(_) => {}
    }
}

#[test]
fn http_random_bytes_never_panic_or_overcommit() {
    let mut rng = Rng::new(0xF0CC_0007);
    for i in 0..6000u32 {
        let max = if i % 50 == 0 { 4096 } else { 96 };
        let buf = random_bytes(&mut rng, max);
        for max_body in [0usize, 100, 1 << 20] {
            check_http_request(&buf, max_body);
        }
    }
}

/// Header soup: structurally plausible requests (real methods and
/// targets, adversarial framing headers) reach the body-framing logic
/// that raw noise almost never does.
#[test]
fn http_header_soup_never_panics() {
    const METHODS: &[&str] = &["GET", "POST", "DELETE", "PATCH", "get", ""];
    const TARGETS: &[&str] =
        &["/", "/v1/hull", "/v1/sessions/7/hull?epoch=3&limit=2", "nope", "/%zz%41+x", "/?a&b="];
    const VERSIONS: &[&str] = &["HTTP/1.1", "HTTP/1.0", "HTTP/2", "http/1.1", ""];
    const HEADERS: &[&str] = &[
        "host: x",
        "content-length: 5",
        "content-length: 5\r\ncontent-length: 5",
        "content-length: 5\r\ncontent-length: 6",
        "content-length: zz",
        "content-length: 99999999999999999999",
        "transfer-encoding: chunked",
        "transfer-encoding: chunked\r\ncontent-length: 3",
        "transfer-encoding: gzip",
        " folded: 1",
        "no-colon",
        "bad name: 1",
        "connection: close",
        "connection: keep-alive",
        ": empty",
    ];
    let mut rng = Rng::new(0xF0CC_0008);
    for _ in 0..8000u32 {
        let mut s = format!(
            "{} {} {}\r\n",
            METHODS[rng.range_usize(0, METHODS.len())],
            TARGETS[rng.range_usize(0, TARGETS.len())],
            VERSIONS[rng.range_usize(0, VERSIONS.len())],
        );
        for _ in 0..rng.range_usize(0, 4) {
            s.push_str(HEADERS[rng.range_usize(0, HEADERS.len())]);
            s.push_str("\r\n");
        }
        s.push_str("\r\n");
        let mut buf = s.into_bytes();
        buf.extend(random_bytes(&mut rng, 32));
        if rng.chance(0.2) {
            buf.truncate(rng.range_usize(0, buf.len() + 1));
        }
        check_http_request(&buf, 1 << 20);
    }
}

/// A generated *valid* request decodes whole (`used` == wire length,
/// body reassembled exactly), and every strict prefix is `Need` — never
/// a phantom frame, never an error.
#[test]
fn http_valid_requests_roundtrip_and_prefixes_are_need() {
    let mut rng = Rng::new(0xF0CC_0009);
    for _ in 0..1200u32 {
        let method = ["GET", "POST", "DELETE"][rng.range_usize(0, 3)];
        let target = [
            "/v1/hull".to_string(),
            format!("/v1/sessions/{}/hull?epoch={}&limit=7", rng.below(100), rng.below(9)),
            "/v1/stats".to_string(),
        ][rng.range_usize(0, 3)]
            .clone();
        let mut wire = format!("{method} {target} HTTP/1.1\r\nhost: fuzz\r\n").into_bytes();
        let mut body = Vec::new();
        match rng.below(3) {
            0 => {
                // no framing headers: the body is empty by definition
                wire.extend_from_slice(b"\r\n");
            }
            1 => {
                body = random_bytes(&mut rng, 64);
                wire.extend_from_slice(
                    format!("content-length: {}\r\n\r\n", body.len()).as_bytes(),
                );
                wire.extend_from_slice(&body);
            }
            _ => {
                wire.extend_from_slice(b"transfer-encoding: chunked\r\n\r\n");
                for _ in 0..rng.range_usize(0, 4) {
                    let chunk = random_bytes(&mut rng, 32);
                    if chunk.is_empty() {
                        continue; // a zero chunk would terminate early
                    }
                    wire.extend_from_slice(format!("{:x}\r\n", chunk.len()).as_bytes());
                    wire.extend_from_slice(&chunk);
                    wire.extend_from_slice(b"\r\n");
                    body.extend_from_slice(&chunk);
                }
                wire.extend_from_slice(b"0\r\n\r\n");
            }
        }
        match http::decode_request(&wire, 1 << 20) {
            Ok(Decoded::Frame(r, used)) => {
                assert_eq!(used, wire.len());
                assert_eq!(r.body, body);
                assert!(r.keep_alive);
            }
            other => panic!("valid request: {other:?}"),
        }
        for _ in 0..4 {
            let cut = rng.range_usize(0, wire.len());
            match http::decode_request(&wire[..cut], 1 << 20) {
                Ok(Decoded::Need(n)) => assert!(n > cut),
                Ok(Decoded::Frame(..)) => panic!("phantom frame in a {cut}-byte prefix"),
                Err(e) => panic!("prefix of a valid request errored: {e}"),
            }
        }
    }
}

/// The body cap rejects from the *header alone* — a hostile
/// `Content-Length` can never talk the loop into buffering toward a
/// huge target (fatal 413, not `Need`).
#[test]
fn http_oversized_content_length_is_fatal_not_need() {
    let mut rng = Rng::new(0xF0CC_000A);
    for _ in 0..500u32 {
        let max_body = rng.range_usize(0, 1 << 16);
        let declared = max_body as u64 + 1 + rng.below(1 << 32);
        let wire = format!("POST /v1/hull HTTP/1.1\r\ncontent-length: {declared}\r\n\r\n");
        match http::decode_request(wire.as_bytes(), max_body) {
            Err(e @ HttpError::BodyTooLarge { max }) => {
                assert_eq!(max, max_body);
                assert_eq!(e.status(), 413);
            }
            other => panic!("declared {declared} vs cap {max_body}: {other:?}"),
        }
    }
}

/// Every classic smuggling vector is fatal with the one stable code, no
/// matter what else rides in the request.
#[test]
fn http_smuggling_vectors_are_always_fatal() {
    let mut rng = Rng::new(0xF0CC_000B);
    for _ in 0..500u32 {
        let a = rng.below(1 << 20);
        let b = a + 1 + rng.below(1 << 10);
        let vectors = [
            format!("content-length: {a}\r\ntransfer-encoding: chunked\r\n"),
            format!("transfer-encoding: chunked\r\ncontent-length: {a}\r\n"),
            format!("content-length: {a}\r\ncontent-length: {b}\r\n"),
            "x: 1\r\n folded-continuation\r\n".to_string(),
        ];
        for v in &vectors {
            let wire = format!("POST /v1/hull HTTP/1.1\r\n{v}\r\n");
            match http::decode_request(wire.as_bytes(), 1 << 24) {
                Err(e @ HttpError::Smuggling(_)) => {
                    assert_eq!(e.status(), 400);
                    assert_eq!(e.code(), "ambiguous-framing");
                }
                other => panic!("smuggling vector {v:?}: {other:?}"),
            }
        }
        // the benign cousin — identical duplicate lengths — still frames
        let wire = b"POST /x HTTP/1.1\r\ncontent-length: 2\r\ncontent-length: 2\r\n\r\nok";
        assert!(matches!(http::decode_request(wire, 1 << 24), Ok(Decoded::Frame(..))));
    }
}

// -------------------------------------------------- pagination cursors

/// Cursor wire form: encode/decode is the identity, decode is canonical
/// (anything it accepts re-encodes to the same string), and random or
/// tampered strings never panic.
#[test]
fn cursor_codec_roundtrips_and_rejects_garbage() {
    let mut rng = Rng::new(0xF0CC_000C);
    for _ in 0..4000u32 {
        let c = cursor::Cursor {
            epoch: rng.next_u64(),
            chain: rng.below(2) as u8,
            offset: rng.next_u64(),
        };
        let wire = cursor::encode(&c);
        assert_eq!(wire.len(), 38);
        assert_eq!(cursor::decode(&wire), Some(c));

        // single hex-digit tamper: the checksum (or version/chain gate)
        // catches every one
        let at = rng.range_usize(0, wire.len());
        let mut bytes = wire.clone().into_bytes();
        let old = bytes[at];
        let replacement = b"0123456789abcdef"[rng.range_usize(0, 16)];
        if replacement != old {
            bytes[at] = replacement;
            let tampered = String::from_utf8(bytes).unwrap();
            assert_eq!(cursor::decode(&tampered), None, "tamper at {at} survived: {tampered}");
        }

        // random lowercase-hex of the right length: decode is canonical
        let junk: String =
            (0..38).map(|_| b"0123456789abcdef"[rng.range_usize(0, 16)] as char).collect();
        if let Some(got) = cursor::decode(&junk) {
            assert_eq!(cursor::encode(&got), junk, "non-canonical accept: {junk}");
        }

        // arbitrary garbage strings: never panic, never decode
        let garbage: String = (0..rng.range_usize(0, 48))
            .map(|_| (rng.below(94) as u8 + b'!') as char)
            .collect();
        if garbage.len() != 38 || !garbage.bytes().all(|b| b.is_ascii_hexdigit()) {
            assert_eq!(cursor::decode(&garbage), None);
        }
    }
}
