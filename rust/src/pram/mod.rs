//! CREW-PRAM simulator with CUDA-style cost accounting.
//!
//! The paper's machine model is Wagener's CREW PRAM, realised on a CUDA
//! chip whose shared-memory *bank conflicts* made the parallel program
//! "slow by comparison with another serial program" (paper Conclusions).
//! This substrate makes both halves of that statement measurable:
//!
//! * a synchronous shared-memory machine with per-step write-conflict
//!   (CREW) checking — a correctness tool: the Wagener phases must be
//!   exclusive-write, and tests assert zero violations;
//! * a cost model counting PRAM steps, work (PE-operations), and modeled
//!   cycles under a 32-bank / 32-lane-warp serialization model — the
//!   quantity behind experiment E4.
//!
//! # Execution tiers
//!
//! One `Pram::step` API, two engines, selected by [`ExecMode`]:
//!
//! * **`ExecMode::Audited`** (default) — the instrument.  Every shared
//!   access is logged as a transaction, CREW write-exclusivity is checked
//!   per step, and the bank model charges `max over warps of (read
//!   serialization + write serialization)` cycles per step.  The engine
//!   pays *zero steady-state allocation* for that fidelity: transaction
//!   logs are reused `Vec`s, per-warp bank counters are fixed
//!   `[u32; 32]` arrays (banks is always 32 on CUDA), and all per-step
//!   set-membership questions (which cells were written this step? which
//!   addresses has this warp already touched?) are answered by
//!   epoch-stamped shadow arrays — bump one counter and every stamp is
//!   invalidated in O(1), no clearing, no sorting, no hashing.
//!   Use it for experiments: the counters are deterministic and
//!   bit-stable run-to-run.
//!
//! * **`ExecMode::Fast`** — the serving engine.  No read logging, no
//!   conflict detection, no bank model; a step only buffers writes (the
//!   barrier semantics stay exact) and maintains `steps` / `work` /
//!   `max_pes` plus a conflict-free cycle floor.  Large launches dispatch
//!   PEs across scoped worker threads (`std::thread::scope`, per step) —
//!   contiguous PE ranges per worker, private register windows, and
//!   per-worker write buffers merged in PE order at the barrier, so
//!   results are bit-identical to serial dispatch (and to the audited
//!   tier) on any CREW-clean program.  The coordinator/server `pram`
//!   backend runs this tier by default; property tests pin the
//!   fast == audited equivalence across generators and sizes.
//!
//! What the audited counters mean: `reads`/`writes` count *transactions*
//! (a `read_pair`/`write_pair` float2 access is one coalesced
//! transaction at word-stride 2, as on the paper's hardware);
//! `write_conflicts` counts conflicting *cells* once per (step, cell);
//! `modeled_cycles / ideal_cycles` is the bank-serialization factor the
//! paper blames for losing to the serial program.

pub mod machine;

pub use machine::{BankModel, Counters, ExecMode, PeCtx, Pram, PramError, MAX_BANKS};
