#!/usr/bin/env python3
"""Differential simulation of rust/src/store/mod.rs (manifest v1).

Transliterates the point codec and the snapshot write/read paths, then
property-tests them: bit-exact round trips over random session states,
dedup byte accounting, and a corruption corpus where every mutation of
chunks or manifest must surface as a typed `snapshot-corrupt` — never a
silent mis-restore.
"""

import hashlib
import json
import random
import struct
import sys

MANIFEST_VERSION = 1
PENDING_CHUNK_POINTS = 4096


class Corrupt(Exception):
    """Mirror of StoreError::Corrupt (wire prefix `snapshot-corrupt`)."""


# ---------------------------------------------------------- point codec
# encode_points / decode_points: LE f64 pairs, 16 bytes per point.

def encode_points(pts):
    return b"".join(struct.pack("<dd", x, y) for x, y in pts)


def decode_points(data):
    if len(data) % 16 != 0:
        raise Corrupt(f"point chunk length {len(data)} not a multiple of 16")
    return [struct.unpack_from("<dd", data, off) for off in range(0, len(data), 16)]


# --------------------------------------------------------- MemStore twin

class MemStore:
    """Mirror of store::MemStore: content-addressed chunks + manifests.

    get_chunk re-hashes on read, exactly like the Rust impls, so any
    byte-level tamper surfaces as Corrupt.
    """

    def __init__(self):
        self.chunks = {}
        self.manifests = {}

    def put_chunk(self, data):
        cid = hashlib.sha256(data).hexdigest()
        wrote = cid not in self.chunks
        self.chunks[cid] = bytes(data)
        return cid, wrote

    def get_chunk(self, cid):
        data = self.chunks.get(cid)
        if data is None:
            raise Corrupt(f"chunk {cid} missing")
        if hashlib.sha256(data).hexdigest() != cid:
            raise Corrupt(f"chunk {cid} fails verification")
        return data

    def put_manifest(self, sid, text):
        self.manifests[sid] = text

    def get_manifest(self, sid):
        return self.manifests.get(sid)


# ------------------------------------------------------- write_snapshot

def write_snapshot(store, sid, state):
    """Returns bytes_written (new chunks + manifest), like WriteReport."""
    checksums = {}
    bytes_written = 0

    def put(pts):
        nonlocal bytes_written
        data = encode_points(pts)
        cid, wrote = store.put_chunk(data)
        if wrote:
            bytes_written += len(data)
        checksums[cid] = len(data)
        return cid

    upper = put(state["upper"])
    lower = put(state["lower"])
    pending = [
        put(state["pending"][i : i + PENDING_CHUNK_POINTS])
        for i in range(0, len(state["pending"]), PENDING_CHUNK_POINTS)
    ]
    ledger = [
        {
            "survivors": put(e["survivors"]),
            "upper": put(e["upper"]),
            "lower": put(e["lower"]),
        }
        for e in state["ledger"]
    ]
    manifest = {
        "version": MANIFEST_VERSION,
        "sid": sid,
        "epoch": state["epoch"],
        "merge_threshold": state["merge_threshold"],
        "inserted": state["inserted"],
        "absorbed": state["absorbed"],
        "hull_chunks": {"upper": upper, "lower": lower},
        "pending_chunks": pending,
        "ledger": ledger,
        "checksums": checksums,
    }
    text = json.dumps(manifest)
    store.put_manifest(sid, text)
    return bytes_written + len(text)


# -------------------------------------------------------- read_snapshot

def _field(m, key):
    if not isinstance(m, dict) or key not in m:
        raise Corrupt(f"manifest missing {key!r}")
    return m[key]


def _field_u64(m, key):
    v = _field(m, key)
    if not isinstance(v, (int, float)) or isinstance(v, bool):
        raise Corrupt(f"manifest {key!r} not a number")
    if v < 0 or float(v) != int(v):
        raise Corrupt(f"manifest {key!r} not a non-negative integer")
    return int(v)


def _get_chunk(store, checksums, cid):
    if not isinstance(cid, str):
        raise Corrupt("chunk id not a string")
    want = checksums.get(cid)
    if not isinstance(want, (int, float)):
        raise Corrupt(f"chunk {cid} missing from checksums")
    data = store.get_chunk(cid)
    if len(data) != want:
        raise Corrupt(f"chunk {cid}: manifest says {want} bytes, store has {len(data)}")
    return decode_points(data)


def read_snapshot(store, sid):
    text = store.get_manifest(sid)
    if text is None:
        return None
    try:
        manifest = json.loads(text)
    except ValueError as e:
        raise Corrupt(f"manifest for sid {sid}: {e}") from None

    version = _field_u64(manifest, "version")
    if version != MANIFEST_VERSION:
        raise Corrupt(f"manifest version {version} (this build reads {MANIFEST_VERSION})")
    checksums = _field(manifest, "checksums")
    if not isinstance(checksums, dict):
        raise Corrupt("manifest checksums not an object")

    hulls = _field(manifest, "hull_chunks")
    upper = _get_chunk(store, checksums, _field(hulls, "upper"))
    lower = _get_chunk(store, checksums, _field(hulls, "lower"))

    pending_chunks = _field(manifest, "pending_chunks")
    if not isinstance(pending_chunks, list):
        raise Corrupt("pending_chunks not an array")
    pending = []
    for cid in pending_chunks:
        pending.extend(_get_chunk(store, checksums, cid))

    epoch = _field_u64(manifest, "epoch")
    ledger_arr = _field(manifest, "ledger")
    if not isinstance(ledger_arr, list):
        raise Corrupt("ledger not an array")
    if len(ledger_arr) != epoch:
        raise Corrupt(f"ledger has {len(ledger_arr)} entries but epoch is {epoch}")
    ledger = [
        {
            "survivors": _get_chunk(store, checksums, _field(e, "survivors")),
            "upper": _get_chunk(store, checksums, _field(e, "upper")),
            "lower": _get_chunk(store, checksums, _field(e, "lower")),
        }
        for e in ledger_arr
    ]
    return {
        "epoch": epoch,
        "merge_threshold": max(_field_u64(manifest, "merge_threshold"), 1),
        "inserted": _field_u64(manifest, "inserted"),
        "absorbed": _field_u64(manifest, "absorbed"),
        "upper": upper,
        "lower": lower,
        "pending": pending,
        "ledger": ledger,
    }


# ------------------------------------------------------------ generators

def rand_coord(rng):
    """Adversarial f64s: plain uniforms plus signed zeros, denormals,
    huge magnitudes and exact dyadics — everything but NaN (points are
    validated non-NaN upstream in coordinator::request)."""
    k = rng.randrange(8)
    if k == 0:
        return -0.0
    if k == 1:
        return rng.choice([5e-324, -5e-324, 2.2250738585072014e-308])
    if k == 2:
        return rng.choice([1e300, -1e300, 1.7976931348623157e308])
    if k == 3:
        return rng.randrange(-1000, 1000) / 2 ** rng.randrange(0, 40)
    return rng.uniform(-1e6, 1e6)


def rand_points(rng, n):
    return [(rand_coord(rng), rand_coord(rng)) for _ in range(n)]


def rand_state(rng):
    epoch = rng.randrange(0, 6)
    hull = rand_points(rng, rng.randrange(0, 40))
    return {
        "epoch": epoch,
        "merge_threshold": rng.randrange(1, 5000),
        "inserted": rng.randrange(0, 2**48),
        "absorbed": rng.randrange(0, 2**48),
        "upper": hull,
        "lower": list(reversed(hull)) if rng.random() < 0.5 else rand_points(rng, 7),
        # cross the PENDING_CHUNK_POINTS boundary sometimes
        "pending": rand_points(
            rng, rng.choice([0, 1, 17, PENDING_CHUNK_POINTS - 1, PENDING_CHUNK_POINTS + 3])
        ),
        "ledger": [
            {
                "survivors": rand_points(rng, rng.randrange(0, 12)),
                "upper": rand_points(rng, rng.randrange(0, 12)),
                "lower": rand_points(rng, rng.randrange(0, 12)),
            }
            for _ in range(epoch)
        ],
    }


def bits(pts):
    """Bit-exact view of a point list (distinguishes -0.0 from 0.0)."""
    return [struct.pack("<dd", x, y) for x, y in pts]


def states_bit_equal(a, b):
    if (a["epoch"], a["merge_threshold"], a["inserted"], a["absorbed"]) != (
        b["epoch"],
        b["merge_threshold"],
        b["inserted"],
        b["absorbed"],
    ):
        return False
    for key in ("upper", "lower", "pending"):
        if bits(a[key]) != bits(b[key]):
            return False
    if len(a["ledger"]) != len(b["ledger"]):
        return False
    for ea, eb in zip(a["ledger"], b["ledger"]):
        for key in ("survivors", "upper", "lower"):
            if bits(ea[key]) != bits(eb[key]):
                return False
    return True


# ------------------------------------------------------------ properties

def check(cond, msg):
    if not cond:
        print(f"FAIL: {msg}", file=sys.stderr)
        sys.exit(1)


def expect_corrupt(fn, msg):
    try:
        fn()
    except Corrupt:
        return
    print(f"FAIL: {msg} (no Corrupt raised)", file=sys.stderr)
    sys.exit(1)


def main():
    rng = random.Random(0x5EED_1203_5004)

    # anchor: the sim's hash is the same sha256 the Rust store names
    # chunks with (vector from store::tests::chunk_id_hex_roundtrip)
    check(
        hashlib.sha256(b"abc").hexdigest()
        == "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad",
        "sha256 anchor vector",
    )

    # codec: encode/decode is the bit-exact identity, incl. -0.0/denormals
    for _ in range(2000):
        pts = rand_points(rng, rng.randrange(0, 64))
        check(bits(decode_points(encode_points(pts))) == bits(pts), "codec round trip")
    expect_corrupt(lambda: decode_points(b"\x00" * 15), "truncated chunk decodes")

    # round trip: write → read is bit-exact for random session states
    n_roundtrip = 1500
    for i in range(n_roundtrip):
        state = rand_state(rng)
        store = MemStore()
        sid = rng.randrange(1, 2**32)
        write_snapshot(store, sid, state)
        back = read_snapshot(store, sid)
        check(back is not None, "manifest vanished")
        check(states_bit_equal(state, back), f"round trip case {i} diverged")
        check(read_snapshot(store, sid + 1) is None, "phantom manifest for other sid")

    # dedup accounting: re-checkpointing an unchanged state writes only
    # the manifest; shared chunks across sids cost nothing
    for _ in range(300):
        state = rand_state(rng)
        store = MemStore()
        write_snapshot(store, 1, state)
        manifest_len = len(store.get_manifest(1))
        again = write_snapshot(store, 1, state)
        check(again == manifest_len, f"warm rewrite wrote {again} != manifest {manifest_len}")
        other = write_snapshot(store, 2, state)
        check(other == len(store.get_manifest(2)), "cross-sid dedup missed")

    # corruption corpus: every chunk bit-flip, truncation or removal and
    # every manifest scribble must raise Corrupt — never return a state
    n_corrupt = 0
    for i in range(250):
        state = rand_state(rng)
        # guarantee at least one non-empty chunk
        if not state["upper"]:
            state["upper"] = rand_points(rng, 3)
        store = MemStore()
        write_snapshot(store, 7, state)
        for cid in list(store.chunks):
            data = store.chunks[cid]
            if data:
                flipped = bytearray(data)
                flipped[rng.randrange(len(flipped))] ^= 1 << rng.randrange(8)
                store.chunks[cid] = bytes(flipped)
                expect_corrupt(lambda: read_snapshot(store, 7), f"bit flip in {cid}")
                store.chunks[cid] = data
                n_corrupt += 1
            # truncation: drop the last byte (hash mismatch on read)
            if data:
                store.chunks[cid] = data[:-1]
                expect_corrupt(lambda: read_snapshot(store, 7), f"truncated {cid}")
                store.chunks[cid] = data
                n_corrupt += 1
            # removal: dangling manifest reference
            del store.chunks[cid]
            expect_corrupt(lambda: read_snapshot(store, 7), f"missing {cid}")
            store.chunks[cid] = data
            n_corrupt += 1
        # clean again after un-tampering
        check(states_bit_equal(state, read_snapshot(store, 7)), "state sticky-corrupt")

        good = store.manifests[7]
        for scribble in [
            "}{ not json",
            good.replace('"version": 1', '"version": 2', 1),
            good.replace('"epoch"', '"epch"', 1),
            good.replace('"checksums"', '"chksums"', 1),
            json.dumps({**json.loads(good), "ledger": []})
            if state["epoch"] > 0
            else "}{",
        ]:
            store.manifests[7] = scribble
            expect_corrupt(lambda: read_snapshot(store, 7), "manifest scribble")
            n_corrupt += 1
        store.manifests[7] = good

    print(
        f"sim_store OK: codec 2000, round-trip {n_roundtrip}, dedup 300x2, "
        f"corruption corpus {n_corrupt} mutations — all detected, zero mis-restores"
    )


if __name__ == "__main__":
    main()
