//! Event-loop connection core end-to-end: multiplexing scale, shutdown
//! semantics, pipelining/ordering, half-close, and the threaded shim's
//! compatibility guarantees.  Runs with `ENGINE_SHARDS=1` and `=4` in
//! tier1 like the rest of the server suites.

use std::io::{BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use wagener_hull::coordinator::{BackendKind, BatcherConfig, CoordinatorConfig};
use wagener_hull::engine::{Engine, EngineConfig};
use wagener_hull::geometry::generators::{generate, Distribution};
use wagener_hull::serial::monotone_chain;
use wagener_hull::server::{
    frame, proto, serve_engine, serve_engine_threaded, HullClient, Request, Response, ServerConfig,
    ServerHandle, WireProto,
};
use wagener_hull::stream::StreamConfig;

fn start_engine(kind: BackendKind) -> Arc<Engine> {
    Arc::new(
        Engine::start(EngineConfig {
            shards: EngineConfig::shards_from_env(1),
            coordinator: CoordinatorConfig {
                backend: kind,
                batcher: BatcherConfig { max_batch: 4, flush_us: 300, queue_cap: 256 },
                self_check: true,
                ..Default::default()
            },
            stream: StreamConfig::default(),
            ..Default::default()
        })
        .unwrap(),
    )
}

fn start_event(kind: BackendKind, io_threads: usize) -> ServerHandle {
    serve_engine(
        start_engine(kind),
        &ServerConfig { addr: "127.0.0.1:0".into(), io_threads, ..Default::default() },
    )
    .unwrap()
}

fn wait_gauge(handle: &ServerHandle, want: u64, within: Duration) {
    let t0 = Instant::now();
    while handle.active_connections() != want {
        assert!(
            t0.elapsed() < within,
            "gauge stuck at {} (want {want})",
            handle.active_connections()
        );
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// The full streaming-session lifecycle over the event core in binary
/// framing, verified against the serial oracle.
#[test]
fn binary_session_lifecycle_over_event_core() {
    let handle = start_event(BackendKind::Native, 2);
    let mut c = HullClient::connect_with(handle.local_addr, WireProto::Binary).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(20))).unwrap();

    let sid = c.session_open().unwrap();
    let pts = generate(Distribution::Disk, 400, 17);
    let mut last_epoch = 0;
    for chunk in pts.chunks(100) {
        let ack = c.session_add(sid, chunk).unwrap();
        assert!(ack.epoch >= last_epoch);
        last_epoch = ack.epoch;
    }
    let hull = c.session_hull(sid).unwrap();
    let (u, l) = monotone_chain::full_hull(&pts);
    assert_eq!(hull.upper, u);
    assert_eq!(hull.lower, l);

    // one-shot on the same connection agrees bit-for-bit
    let oneshot = c.hull(&pts).unwrap();
    assert_eq!(oneshot.upper, hull.upper);
    assert_eq!(oneshot.lower, hull.lower);

    c.session_close(sid).unwrap();
    let err = c.session_hull(sid).unwrap_err();
    assert!(err.to_string().contains("unknown-session"), "{err}");
    c.ping().unwrap();
    c.quit().unwrap();
    handle.stop();
}

/// The acceptance bar for the tentpole: ≥10k mostly-idle connections
/// multiplexed onto 4 I/O threads, with the server still answering
/// through the crowd.  Skips (loudly) when the fd limit cannot be
/// raised far enough — CI containers usually allow it, laptops vary.
#[cfg(unix)]
#[test]
fn idle_connection_fleet_multiplexes_on_four_loops() {
    use wagener_hull::server::{nofile_limit, raise_nofile_limit};

    const FLEET: usize = 10_000;
    // client fd + server fd per connection, plus generous slack
    let want = (FLEET as u64) * 2 + 1_000;
    let got = raise_nofile_limit(want);
    if got < want {
        let limits = nofile_limit().ok();
        eprintln!(
            "SKIP idle_connection_fleet: fd limit {got} < {want} (rlimit {limits:?}) — \
             raise `ulimit -n` to run the 10k-connection test"
        );
        return;
    }

    let handle = start_event(BackendKind::Serial, 4);
    let mut conns: Vec<TcpStream> = Vec::with_capacity(FLEET);
    for i in 0..FLEET {
        // a brief retry absorbs transient accept-backlog pressure while
        // the loops adopt the burst
        let mut attempt = 0;
        let s = loop {
            match TcpStream::connect(handle.local_addr) {
                Ok(s) => break s,
                Err(_) if attempt < 5 => {
                    attempt += 1;
                    std::thread::sleep(Duration::from_millis(20 * attempt));
                }
                Err(e) => panic!("connect {i}/{FLEET} failed: {e}"),
            }
        };
        conns.push(s);
    }
    wait_gauge(&handle, FLEET as u64, Duration::from_secs(60));

    // the loops must still serve while holding the whole fleet: ping
    // through a sample of the idle crowd
    for s in conns.iter_mut().step_by(1000) {
        s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
        s.write_all(b"PING\n").unwrap();
        let mut buf = [0u8; 5];
        s.read_exact(&mut buf).unwrap();
        assert_eq!(&buf, b"PONG\n");
    }
    // and a fresh request still gets in and out
    let mut c = HullClient::connect_with(handle.local_addr, WireProto::Binary).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    c.ping().unwrap();
    c.quit().unwrap();

    drop(conns);
    wait_gauge(&handle, 0, Duration::from_secs(60));
    handle.stop();
}

/// Regression for the shutdown waker: `stop` must return promptly even
/// when the server is bound to a wildcard address (the old threaded
/// core poked itself awake by connecting to its own `local_addr`, which
/// is unroutable for `0.0.0.0`), on BOTH cores.
#[test]
fn stop_returns_promptly_on_wildcard_bind() {
    let cfg = ServerConfig { addr: "0.0.0.0:0".into(), ..Default::default() };
    let cores: Vec<(&str, ServerHandle)> = vec![
        ("event", serve_engine(start_engine(BackendKind::Serial), &cfg).unwrap()),
        ("threaded", serve_engine_threaded(start_engine(BackendKind::Serial), &cfg).unwrap()),
    ];
    for (core, handle) in cores {
        let port = handle.local_addr.port();
        let mut c = HullClient::connect(("127.0.0.1", port)).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
        c.ping().unwrap();

        let (tx, rx) = std::sync::mpsc::channel();
        let stopper = std::thread::spawn(move || {
            handle.stop();
            let _ = tx.send(());
        });
        rx.recv_timeout(Duration::from_secs(10))
            .unwrap_or_else(|_| panic!("{core} core: stop() hung on a wildcard bind"));
        stopper.join().unwrap();
        drop(c);
    }
}

/// Pipelined binary requests on one connection come back complete and
/// in request order (the `busy` flag serializes decode past a
/// dispatched request, exactly like the one-at-a-time threaded shim).
#[test]
fn pipelined_binary_requests_answered_in_order() {
    let handle = start_event(BackendKind::Native, 1);
    let mut s = TcpStream::connect(handle.local_addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(60))).unwrap();

    const N: u64 = 200;
    let mut batch = Vec::new();
    for id in 1..=N {
        let points = generate(Distribution::Disk, 30 + (id % 7) as usize, id);
        frame::encode_request(&mut batch, &Request::Hull { id, points, tmo_ms: None });
    }
    frame::encode_request(&mut batch, &Request::Ping);
    s.write_all(&batch).unwrap();
    s.flush().unwrap();

    let mut r = BufReader::new(s);
    for want in 1..=N {
        match frame::read_response(&mut r).unwrap() {
            Response::Hull { id, upper, lower, .. } => {
                assert_eq!(id, want, "responses out of order");
                assert!(!upper.is_empty() && !lower.is_empty());
            }
            other => panic!("request {want}: {other:?}"),
        }
    }
    assert_eq!(frame::read_response(&mut r).unwrap(), Response::Pong);
    handle.stop();
}

/// A peer that sends its frames and half-closes still gets every
/// buffered response before the server closes its side.
#[test]
fn half_close_still_serves_buffered_frames() {
    let handle = start_event(BackendKind::Serial, 1);
    let mut s = TcpStream::connect(handle.local_addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    s.write_all(b"PING\nPING\nPING\n").unwrap();
    s.flush().unwrap();
    s.shutdown(std::net::Shutdown::Write).unwrap();
    let mut all = Vec::new();
    s.read_to_end(&mut all).unwrap();
    assert_eq!(&all, b"PONG\nPONG\nPONG\n");
    handle.stop();
}

/// The event core's `STATS` carries the I/O gauges (frame counters per
/// protocol, open connections, decode latency) under the `io` key.
#[cfg(unix)]
#[test]
fn event_core_stats_reports_io_gauges() {
    let handle = start_event(BackendKind::Serial, 2);
    let mut ct = HullClient::connect_with(handle.local_addr, WireProto::Text).unwrap();
    let mut cb = HullClient::connect_with(handle.local_addr, WireProto::Binary).unwrap();
    ct.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    cb.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    ct.ping().unwrap();
    cb.ping().unwrap();

    let stats = cb.stats().unwrap();
    let json = wagener_hull::util::json::parse(&stats).unwrap();
    let io = json.get("io").expect("event-core STATS carries an io object");
    assert!(io.get("frames_text").unwrap().as_usize().unwrap() >= 1, "{stats}");
    assert!(io.get("frames_binary").unwrap().as_usize().unwrap() >= 2, "{stats}");
    assert!(io.get("open_connections").unwrap().as_usize().unwrap() >= 2, "{stats}");
    assert!(io.get("decode_latency").is_some(), "{stats}");
    assert_eq!(json.get("active_connections").unwrap().as_usize(), Some(2), "{stats}");

    ct.quit().unwrap();
    cb.quit().unwrap();
    handle.stop();
}

/// The threaded compatibility shim keeps its old contract — and now
/// speaks binary too: gauge tracking, binary round-trips, and a stop
/// that joins every handler thread.
#[test]
fn threaded_shim_serves_binary_and_joins_on_stop() {
    let handle = serve_engine_threaded(
        start_engine(BackendKind::Native),
        &ServerConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
    )
    .unwrap();
    let mut c = HullClient::connect_with(handle.local_addr, WireProto::Binary).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    let pts = generate(Distribution::Circle, 120, 3);
    let hull = c.hull(&pts).unwrap();
    let (u, l) = monotone_chain::full_hull(&pts);
    assert_eq!(hull.upper, u);
    assert_eq!(hull.lower, l);
    wait_gauge(&handle, 1, Duration::from_secs(5));
    // stop with the connection still open: the shim must shut the
    // socket down and join the handler rather than hang
    handle.stop();
    drop(c);
}

/// Backpressure lifecycle: a client that pipelines big hull requests
/// without reading drives the write buffer past the 1 MiB high-water
/// (reads pause, `backpressure_stalls` increments), draining below the
/// low-water resumes reads, every response still arrives complete and in
/// order, and the stall is counted exactly once.
#[test]
fn backpressure_pause_resumes_after_drain_and_stalls_once() {
    // 48 requests of 32k circle points (~524 KiB of response each, ~25 MiB
    // total) overwhelm whatever the loopback kernel buffers absorb, so the
    // write buffer must cross the high-water.  Decode is serialized behind
    // the in-flight request, so the buffer grows one response at a time:
    // once the drain starts, a fresh response lands in kernel space ahead
    // of an actively reading client and the stall cannot re-fire.
    const N: u64 = 48;
    const PTS: usize = 1 << 15;
    let handle = start_event(BackendKind::Native, 1);
    let addr = handle.local_addr;
    let s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(60))).unwrap();

    // writer thread: the firehose must keep pushing while the test
    // thread watches the gauge (our own writes block once the server
    // pauses reads and the kernel buffers fill)
    let writer = {
        let mut s = s.try_clone().unwrap();
        std::thread::spawn(move || {
            for id in 1..=N {
                let points = generate(Distribution::Circle, PTS, id);
                let mut buf = Vec::new();
                frame::encode_request(&mut buf, &Request::Hull { id, points, tmo_ms: None });
                s.write_all(&buf).unwrap();
            }
            s.flush().unwrap();
        })
    };

    // watch the stall fire through a second connection's STATS
    let mut stats_c = HullClient::connect_with(addr, WireProto::Binary).unwrap();
    stats_c.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    let stalls = |c: &mut HullClient| -> usize {
        let json = wagener_hull::util::json::parse(&c.stats().unwrap()).unwrap();
        json.get("io").unwrap().get("backpressure_stalls").unwrap().as_usize().unwrap()
    };
    let t0 = Instant::now();
    while stalls(&mut stats_c) == 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(60),
            "write buffer never crossed the high-water mark"
        );
        std::thread::sleep(Duration::from_millis(10));
    }

    // drain: every pipelined response still arrives, complete, in order
    let mut r = BufReader::new(s.try_clone().unwrap());
    for want in 1..=N {
        match frame::read_response(&mut r).unwrap() {
            Response::Hull { id, upper, lower, .. } => {
                assert_eq!(id, want, "responses out of order across the stall");
                let (u, l) = monotone_chain::full_hull(&generate(Distribution::Circle, PTS, want));
                assert_eq!((upper, lower), (u, l), "response {want} corrupted across the stall");
            }
            other => panic!("request {want}: {other:?}"),
        }
    }
    writer.join().unwrap();

    // reads resumed: the same connection answers fresh frames
    let mut ping = Vec::new();
    frame::encode_request(&mut ping, &Request::Ping);
    let mut s2 = s.try_clone().unwrap();
    s2.write_all(&ping).unwrap();
    s2.flush().unwrap();
    assert_eq!(frame::read_response(&mut r).unwrap(), Response::Pong);

    assert_eq!(stalls(&mut stats_c), 1, "stall must be counted exactly once");
    stats_c.quit().unwrap();
    handle.stop();
}

/// The abuse guard, on BOTH cores: recoverable text protocol errors are
/// answered and the connection lives on, a good frame resets the
/// counter, and the configured burst of consecutive errors disconnects.
#[test]
fn text_proto_error_storm_disconnects_after_the_configured_limit() {
    use std::io::BufRead;

    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        max_proto_errors: 3,
        ..Default::default()
    };
    let cores: Vec<(&str, ServerHandle)> = vec![
        ("event", serve_engine(start_engine(BackendKind::Serial), &cfg).unwrap()),
        ("threaded", serve_engine_threaded(start_engine(BackendKind::Serial), &cfg).unwrap()),
    ];
    for (core, handle) in cores {
        let mut s = TcpStream::connect(handle.local_addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        let mut read_line = |r: &mut BufReader<TcpStream>| {
            line.clear();
            r.read_line(&mut line).unwrap();
            line.clone()
        };

        // two bad frames: answered, connection stays up (limit is 3)
        for k in 0..2 {
            s.write_all(b"BOGUS\n").unwrap();
            let reply = read_line(&mut r);
            assert!(reply.starts_with("ERR"), "{core} error {k}: {reply:?}");
        }
        // a recoverable mid-stream error resyncs at line granularity on
        // the event core too, and the good frame resets the counter
        s.write_all(b"HULL 1 abc\nPING\n").unwrap();
        assert!(read_line(&mut r).starts_with("ERR"), "{core}: bad HULL header");
        assert_eq!(read_line(&mut r), "PONG\n", "{core}: resync lost framing");

        // three consecutive errors: each answered, then disconnected
        s.write_all(b"BOGUS\nBOGUS\nBOGUS\n").unwrap();
        for k in 0..3 {
            let reply = read_line(&mut r);
            assert!(reply.starts_with("ERR"), "{core} storm {k}: {reply:?}");
        }
        assert_eq!(read_line(&mut r), "", "{core}: must disconnect at the limit");
        handle.stop();
    }
}

/// Binary framing stays fatal on the first protocol error regardless of
/// `max_proto_errors`: a corrupt frame is answered, then the connection
/// closes (resync inside a length-prefixed stream is hopeless).
#[test]
fn binary_proto_error_is_fatal_on_first_strike() {
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        max_proto_errors: 8,
        ..Default::default()
    };
    let cores: Vec<(&str, ServerHandle)> = vec![
        ("event", serve_engine(start_engine(BackendKind::Serial), &cfg).unwrap()),
        ("threaded", serve_engine_threaded(start_engine(BackendKind::Serial), &cfg).unwrap()),
    ];
    for (core, handle) in cores {
        let mut s = TcpStream::connect(handle.local_addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
        // valid magic + version, unknown verb 9: parseable header, bad frame
        s.write_all(&[frame::REQ_MAGIC, frame::VERSION, 9, 7, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0])
            .unwrap();
        s.flush().unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        match frame::read_response(&mut r) {
            Ok(Response::MalformedErr { .. }) => {}
            other => panic!("{core}: wanted a malformed-frame error, got {other:?}"),
        }
        let mut rest = Vec::new();
        r.read_to_end(&mut rest).unwrap();
        assert!(rest.is_empty(), "{core}: binary error must close the connection");
        handle.stop();
    }
}

/// `proto` re-export sanity: the text decoder the event loop uses is
/// reachable for downstream callers building their own tooling.
#[test]
fn exported_decoders_are_usable_standalone() {
    match proto::decode_text_request(b"PING\n").unwrap() {
        proto::Decoded::Frame(Request::Ping, 5) => {}
        other => panic!("{other:?}"),
    }
    let mut buf = Vec::new();
    frame::encode_request(&mut buf, &Request::Quit);
    match frame::decode_request(&buf).unwrap() {
        proto::Decoded::Frame(Request::Quit, n) => assert_eq!(n, buf.len()),
        other => panic!("{other:?}"),
    }
}
