"""L2: the full Wagener hood pipeline as a JAX computation.

The model composes ``log2(n) - 1`` merge stages (paper §2: the hood is
built in s-1 stages, d = 2, 4, ..., n/2).  Stage shapes differ, so the
pipeline is unrolled at trace time — every stage is a pallas_call whose
grid/BlockSpec mirror the paper's kernel-launch geometry for that d.

Exported entry points (all pure, all AOT-lowerable):
  * ``upper_hood(points)``     — (n,2) -> (n,2) hood block
  * ``full_hull(points)``      — (n,2) -> (upper (n,2), lower (n,2))
  * ``batched_full_hull(pts)`` — (b,n,2) -> ((b,n,2), (b,n,2))
  * ``prefilter(points)``      — (n,2) -> (n,2) octagon-filtered block
  * ``tangent_merge(blocks)``  — (b,2d,2) -> (b,2d,2) merged block pairs

Inputs are x-sorted float32 points, live-left-justified, REMOTE-padded to a
power-of-two length (the rust coordinator's batcher guarantees this).
Python runs only at build time: these functions are lowered to HLO text by
``compile.aot`` and executed from rust via PJRT.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import filter as filter_kernel
from .kernels import tangent as tangent_kernel
from .kernels import wagener
from .kernels.wagener import enable_x64  # re-export for aot/tests

__all__ = [
    "upper_hood",
    "full_hull",
    "batched_full_hull",
    "upper_hood_jnp",
    "prefilter",
    "prefilter_jnp",
    "tangent_merge",
    "tangent_merge_jnp",
    "enable_x64",
]


def _pipeline(points: jnp.ndarray, stage_fn) -> jnp.ndarray:
    n = points.shape[0]
    assert n >= 2 and (n & (n - 1)) == 0, f"n must be a power of two, got {n}"
    hood = points
    d = 2
    while d < n:
        hood = stage_fn(hood, d)
        d *= 2
    return hood


def upper_hood(points: jnp.ndarray) -> jnp.ndarray:
    """Upper hull of x-sorted points as an n-slot hood block (pallas path)."""
    return _pipeline(points, wagener.pallas_stage)


def upper_hood_jnp(points: jnp.ndarray) -> jnp.ndarray:
    """Plain-jnp twin of :func:`upper_hood` (ablation / differential test)."""
    return _pipeline(points, wagener.jnp_stage)


def _negate_live_y(hood: jnp.ndarray) -> jnp.ndarray:
    live = hood[:, 0] <= wagener.LIVE_X_MAX
    return jnp.stack(
        [hood[:, 0], jnp.where(live, -hood[:, 1], hood[:, 1])], axis=-1
    )


def full_hull(points: jnp.ndarray):
    """(upper hood, lower hood) of x-sorted points.

    The lower hull is the upper hull of y-negated points (REMOTE slots keep
    y = 0 so the liveness convention survives the round trip).
    """
    upper = upper_hood(points)
    lower = _negate_live_y(upper_hood(_negate_live_y(points)))
    return upper, lower


def batched_full_hull(points: jnp.ndarray):
    """vmap of :func:`full_hull` over a leading batch axis (b, n, 2)."""
    return jax.vmap(full_hull)(points)


def prefilter(points: jnp.ndarray) -> jnp.ndarray:
    """Octagon interior-point prefilter of an (n, 2) block (pallas path).

    Drops points strictly inside the 8-extremes octagon, left-justifies
    the survivors (input order preserved) and REMOTE-pads the tail — the
    on-device shrink that runs *before* the hull pipeline on dense
    inputs.  Hull-preserving: boundary points are kept.
    """
    return filter_kernel.pallas_filter(points)


def prefilter_jnp(points: jnp.ndarray) -> jnp.ndarray:
    """Plain-jnp twin of :func:`prefilter` (differential test target)."""
    return filter_kernel.jnp_filter(points)


def tangent_merge(blocks: jnp.ndarray) -> jnp.ndarray:
    """Sampled common-tangent merge of (b, 2d, 2) block pairs (pallas).

    Each row is a padded ``[H(L) | H(R)]`` pair; the serving artifact
    uses b = 2 (upper pair + y-negated lower pair), so one streaming
    session merge costs exactly one upload.
    """
    return tangent_kernel.pallas_tangent(blocks)


def tangent_merge_jnp(blocks: jnp.ndarray) -> jnp.ndarray:
    """Plain-jnp twin of :func:`tangent_merge`."""
    return tangent_kernel.jnp_tangent(blocks)
