//! Robust orientation predicate (Shewchuk-style adaptive `orient2d`).
//!
//! The paper waves floating-point error away ("it's a problem, but it's
//! not our problem").  A production library cannot: one misclassified
//! LOW/EQUAL/HIGH flips a tangent and corrupts every later stage.  This is
//! the standard adaptive-precision scheme: a fast f64 evaluation with a
//! forward error bound, escalating through Shewchuk's B/C1/C2/D expansion
//! stages only when the sign is in doubt.  `Two_Product` tails use
//! `f64::mul_add` (FMA), which computes `a*b - round(a*b)` exactly.

/// Sign of the determinant | q-p  r-p | — the turn direction p->q->r.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Orientation {
    /// r strictly left of directed line p->q (counter-clockwise turn).
    Left,
    /// r strictly right (clockwise turn).
    Right,
    /// exactly collinear.
    Straight,
}

use super::point::Point;

const EPSILON: f64 = 1.110_223_024_625_156_5e-16; // 2^-53
const RESULTERRBOUND: f64 = (3.0 + 8.0 * EPSILON) * EPSILON;
const CCWERRBOUND_A: f64 = (3.0 + 16.0 * EPSILON) * EPSILON;
const CCWERRBOUND_B: f64 = (2.0 + 12.0 * EPSILON) * EPSILON;
const CCWERRBOUND_C: f64 = (9.0 + 64.0 * EPSILON) * EPSILON * EPSILON;

#[inline]
fn fast_two_sum(a: f64, b: f64) -> (f64, f64) {
    // requires |a| >= |b|
    let x = a + b;
    let bvirt = x - a;
    (x, b - bvirt)
}

#[inline]
fn two_sum(a: f64, b: f64) -> (f64, f64) {
    let x = a + b;
    let bvirt = x - a;
    let avirt = x - bvirt;
    let bround = b - bvirt;
    let around = a - avirt;
    (x, around + bround)
}

#[inline]
fn two_diff(a: f64, b: f64) -> (f64, f64) {
    let x = a - b;
    (x, two_diff_tail(a, b, x))
}

#[inline]
fn two_diff_tail(a: f64, b: f64, x: f64) -> f64 {
    let bvirt = a - x;
    let avirt = x + bvirt;
    let bround = bvirt - b;
    let around = a - avirt;
    around + bround
}

#[inline]
fn two_product(a: f64, b: f64) -> (f64, f64) {
    let x = a * b;
    // FMA: a*b - x is exact.
    (x, a.mul_add(b, -x))
}

/// (a1,a0) - (b1,b0) -> 4-term expansion (x3..x0), increasing magnitude.
#[inline]
fn two_two_diff(a1: f64, a0: f64, b1: f64, b0: f64) -> [f64; 4] {
    // Two_One_Diff(a1, a0, b0) -> (_j, _0, x0)
    let (i0, x0) = two_diff(a0, b0);
    let (j0, r0) = two_sum(a1, i0);
    // Two_One_Diff(j0, r0, b1) -> (x3, x2, x1)
    let (i1, x1) = two_diff(r0, b1);
    let (x3, x2) = two_sum(j0, i1);
    [x0, x1, x2, x3]
}

/// Shewchuk's FAST_EXPANSION_SUM_ZEROELIM.
fn fast_expansion_sum_zeroelim(e: &[f64], f: &[f64], h: &mut [f64]) -> usize {
    let (elen, flen) = (e.len(), f.len());
    let mut enow = e[0];
    let mut fnow = f[0];
    let (mut eindex, mut findex) = (0usize, 0usize);
    let mut q;
    if (fnow > enow) == (fnow > -enow) {
        q = enow;
        eindex += 1;
    } else {
        q = fnow;
        findex += 1;
    }
    let mut hindex = 0usize;
    let mut hh;
    if eindex < elen && findex < flen {
        enow = e[eindex];
        fnow = f[findex];
        let qnew;
        if (fnow > enow) == (fnow > -enow) {
            (qnew, hh) = fast_two_sum(enow, q);
            eindex += 1;
        } else {
            (qnew, hh) = fast_two_sum(fnow, q);
            findex += 1;
        }
        q = qnew;
        if hh != 0.0 {
            h[hindex] = hh;
            hindex += 1;
        }
        while eindex < elen && findex < flen {
            enow = e[eindex];
            fnow = f[findex];
            let qnew;
            if (fnow > enow) == (fnow > -enow) {
                (qnew, hh) = two_sum(q, enow);
                eindex += 1;
            } else {
                (qnew, hh) = two_sum(q, fnow);
                findex += 1;
            }
            q = qnew;
            if hh != 0.0 {
                h[hindex] = hh;
                hindex += 1;
            }
        }
    }
    while eindex < elen {
        let (qnew, hh2) = two_sum(q, e[eindex]);
        eindex += 1;
        q = qnew;
        if hh2 != 0.0 {
            h[hindex] = hh2;
            hindex += 1;
        }
    }
    while findex < flen {
        let (qnew, hh2) = two_sum(q, f[findex]);
        findex += 1;
        q = qnew;
        if hh2 != 0.0 {
            h[hindex] = hh2;
            hindex += 1;
        }
    }
    if q != 0.0 || hindex == 0 {
        h[hindex] = q;
        hindex += 1;
    }
    hindex
}

fn estimate(e: &[f64]) -> f64 {
    e.iter().sum()
}

/// Full-precision fallback: exact sign of det(q-p, r-p).
fn orient2d_adapt(pa: Point, pb: Point, pc: Point, detsum: f64) -> f64 {
    let acx = pa.x - pc.x;
    let bcx = pb.x - pc.x;
    let acy = pa.y - pc.y;
    let bcy = pb.y - pc.y;

    let (detleft, detlefttail) = two_product(acx, bcy);
    let (detright, detrighttail) = two_product(acy, bcx);
    let b = two_two_diff(detleft, detlefttail, detright, detrighttail);
    let mut det = estimate(&b);
    let errbound = CCWERRBOUND_B * detsum;
    if det >= errbound || -det >= errbound {
        return det;
    }

    let acxtail = two_diff_tail(pa.x, pc.x, acx);
    let bcxtail = two_diff_tail(pb.x, pc.x, bcx);
    let acytail = two_diff_tail(pa.y, pc.y, acy);
    let bcytail = two_diff_tail(pb.y, pc.y, bcy);
    if acxtail == 0.0 && acytail == 0.0 && bcxtail == 0.0 && bcytail == 0.0 {
        return det;
    }

    let errbound = CCWERRBOUND_C * detsum + RESULTERRBOUND * det.abs();
    det += (acx * bcytail + bcy * acxtail) - (acy * bcxtail + bcx * acytail);
    if det >= errbound || -det >= errbound {
        return det;
    }

    let mut c1 = [0.0f64; 8];
    let mut c2 = [0.0f64; 12];
    let mut d = [0.0f64; 16];

    let (s1, s0) = two_product(acxtail, bcy);
    let (t1, t0) = two_product(acytail, bcx);
    let u = two_two_diff(s1, s0, t1, t0);
    let c1len = fast_expansion_sum_zeroelim(&b, &u, &mut c1);

    let (s1, s0) = two_product(acx, bcytail);
    let (t1, t0) = two_product(acy, bcxtail);
    let u = two_two_diff(s1, s0, t1, t0);
    let c2len = fast_expansion_sum_zeroelim(&c1[..c1len], &u, &mut c2);

    let (s1, s0) = two_product(acxtail, bcytail);
    let (t1, t0) = two_product(acytail, bcxtail);
    let u = two_two_diff(s1, s0, t1, t0);
    let dlen = fast_expansion_sum_zeroelim(&c2[..c2len], &u, &mut d);

    d[dlen - 1]
}

/// Signed area-ish value whose *sign* is exact: >0 iff pc is strictly left
/// of directed line pa->pb.
pub fn orient2d_value(pa: Point, pb: Point, pc: Point) -> f64 {
    let detleft = (pa.x - pc.x) * (pb.y - pc.y);
    let detright = (pa.y - pc.y) * (pb.x - pc.x);
    let det = detleft - detright;

    let detsum = if detleft > 0.0 {
        if detright <= 0.0 {
            return det;
        }
        detleft + detright
    } else if detleft < 0.0 {
        if detright >= 0.0 {
            return det;
        }
        -detleft - detright
    } else {
        return det;
    };

    let errbound = CCWERRBOUND_A * detsum;
    if det >= errbound || -det >= errbound {
        return det;
    }
    orient2d_adapt(pa, pb, pc, detsum)
}

/// Exact turn classification of p -> q -> r.
pub fn orient2d(p: Point, q: Point, r: Point) -> Orientation {
    let v = orient2d_value(p, q, r);
    if v > 0.0 {
        Orientation::Left
    } else if v < 0.0 {
        Orientation::Right
    } else {
        Orientation::Straight
    }
}

/// Paper's `left_of`: r strictly left of directed segment p->q.
#[inline]
pub fn left_of(p: Point, q: Point, r: Point) -> bool {
    orient2d(p, q, r) == Orientation::Left
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn pt(x: f64, y: f64) -> Point {
        Point::new(x, y)
    }

    #[test]
    fn obvious_cases() {
        assert_eq!(orient2d(pt(0., 0.), pt(1., 0.), pt(0.5, 1.)), Orientation::Left);
        assert_eq!(orient2d(pt(0., 0.), pt(1., 0.), pt(0.5, -1.)), Orientation::Right);
        assert_eq!(orient2d(pt(0., 0.), pt(1., 0.), pt(2., 0.)), Orientation::Straight);
    }

    #[test]
    fn exact_collinear_with_awkward_floats() {
        // three points on the line y = x with coordinates that round
        let a = pt(0.1, 0.1);
        let b = pt(0.2, 0.2);
        let c = pt(0.3, 0.3);
        // naive det is ~1e-18 garbage; exact answer is Straight only when
        // the f64 values are truly collinear — (0.1,0.1),(0.2,0.2),(0.3,0.3)
        // as f64 are NOT exactly collinear, so just demand consistency:
        let o1 = orient2d(a, b, c);
        let o2 = orient2d(b, c, a);
        let o3 = orient2d(c, a, b);
        assert_eq!(o1, o2);
        assert_eq!(o2, o3);
    }

    #[test]
    fn exact_collinear_dyadic() {
        // dyadic rationals: exactly representable, exactly collinear
        let a = pt(0.125, 0.25);
        let b = pt(0.25, 0.5);
        let c = pt(0.5, 1.0);
        assert_eq!(orient2d(a, b, c), Orientation::Straight);
    }

    #[test]
    fn near_degenerate_consistency_vs_i128() {
        // grid points: integer coordinates -> exact i128 determinant oracle
        let mut rng = Rng::new(99);
        for _ in 0..200_000 {
            let c = |r: &mut Rng| r.below(1 << 20) as i64 - (1 << 19);
            let (ax, ay, bx, by, cx, cy) =
                (c(&mut rng), c(&mut rng), c(&mut rng), c(&mut rng), c(&mut rng), c(&mut rng));
            let exact = (bx - ax) as i128 * (cy - ay) as i128
                - (by - ay) as i128 * (cx - ax) as i128;
            let scale = 1.0 / (1u64 << 20) as f64; // push into [0,1]-ish floats
            let o = orient2d(
                pt(ax as f64 * scale, ay as f64 * scale),
                pt(bx as f64 * scale, by as f64 * scale),
                pt(cx as f64 * scale, cy as f64 * scale),
            );
            let want = match exact.signum() {
                1 => Orientation::Left,
                -1 => Orientation::Right,
                _ => Orientation::Straight,
            };
            assert_eq!(o, want, "({ax},{ay}) ({bx},{by}) ({cx},{cy})");
        }
    }

    #[test]
    fn nearly_collinear_tiny_perturbation() {
        // b on segment a-c, then nudge by one ulp: sign must flip exactly
        let a = pt(0.5, 0.5);
        let c = pt(0.75, 0.75);
        let b = pt(0.625, 0.625);
        assert_eq!(orient2d(a, c, b), Orientation::Straight);
        let up = pt(0.625, f64::from_bits(0.625f64.to_bits() + 1));
        let dn = pt(0.625, f64::from_bits(0.625f64.to_bits() - 1));
        assert_eq!(orient2d(a, c, up), Orientation::Left);
        assert_eq!(orient2d(a, c, dn), Orientation::Right);
    }

    #[test]
    fn antisymmetry() {
        let mut rng = Rng::new(5);
        for _ in 0..10_000 {
            let a = pt(rng.f64(), rng.f64());
            let b = pt(rng.f64(), rng.f64());
            let c = pt(rng.f64(), rng.f64());
            let o1 = orient2d(a, b, c);
            let o2 = orient2d(b, a, c);
            match o1 {
                Orientation::Left => assert_eq!(o2, Orientation::Right),
                Orientation::Right => assert_eq!(o2, Orientation::Left),
                Orientation::Straight => assert_eq!(o2, Orientation::Straight),
            }
        }
    }

    #[test]
    fn left_of_matches_orientation() {
        assert!(left_of(pt(0., 0.), pt(1., 0.), pt(0.5, 0.1)));
        assert!(!left_of(pt(0., 0.), pt(1., 0.), pt(0.5, -0.1)));
        assert!(!left_of(pt(0., 0.), pt(1., 0.), pt(0.5, 0.0)));
    }
}
