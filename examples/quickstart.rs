//! Quickstart: compute a convex hull three ways and check they agree.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use wagener_hull::geometry::generators::{generate, Distribution};
use wagener_hull::geometry::hull_check::check_upper_hull;
use wagener_hull::ovl;
use wagener_hull::serial::monotone_chain;
use wagener_hull::wagener;

fn main() {
    // 1. a workload: 1000 random points in the unit square, x-sorted,
    //    f32-quantized (the conventions every backend shares)
    let points = generate(Distribution::UniformSquare, 1000, 42);

    // 2. the paper's algorithm (host pipeline: log n merge stages)
    let (upper, lower) = wagener::full_hull(&points);
    println!("wagener upper hull: {} corners", upper.len());
    println!("wagener lower hull: {} corners", lower.len());

    // 3. the serial baseline the paper compares against
    let serial = monotone_chain::upper_hull(&points);
    assert_eq!(upper, serial, "wagener must equal serial");

    // 4. the paper's §3 optimal-speedup variant (strips + tree merges)
    let run = ovl::optimal_upper_hull(&points, 0);
    assert_eq!(run.hull, serial);
    println!(
        "ovl-optimal: {} strips, {} tangent predicate evals, {} total work units",
        run.stats.strips,
        run.stats.tangent_predicate_evals,
        run.stats.total()
    );

    // 5. independent validity check
    check_upper_hull(&points, &upper).expect("hull invalid?!");
    println!("all implementations agree; hull verified. corners:");
    for p in &upper {
        println!("  {p}");
    }
}
