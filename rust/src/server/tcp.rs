//! Thread-per-connection compatibility shim: one accept loop, one
//! handler thread per connection, all sharing the [`Engine`] facade —
//! one-shot requests are routed to the cheapest coordinator shard,
//! session verbs to their sid's pinned shard.  The readiness-driven
//! event loop (`server::event_loop`) is the default core on unix; this
//! shim is the reference implementation the parity suite measures it
//! against, and the only core on non-unix targets.
//!
//! Handler threads are *tracked*, not detached: `ThreadedHandle` shuts
//! every live connection's socket down and joins the handlers on stop,
//! so nothing races an engine shutdown that follows.  The accept loop is
//! woken by a self-pipe on unix (a loopback connect-poke cannot reach a
//! wildcard bind like `0.0.0.0:0`), with the poke kept as the non-unix
//! fallback.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
#[cfg(unix)]
use std::os::fd::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::coordinator::HullRequest;
use crate::engine::Engine;
use crate::{log_debug, log_info};

#[cfg(unix)]
use super::sys;
use super::proto::{self, ProtoError, Request, Response};
use super::{frame, request_deadline, ServerConfig};

/// Per-connection robustness knobs, copied out of [`ServerConfig`] at
/// startup so handler threads never chase the config.
#[derive(Clone, Copy)]
pub(crate) struct ConnOpts {
    pub(crate) request_timeout_ms: u64,
    pub(crate) max_proto_errors: u32,
}

impl ConnOpts {
    pub(crate) fn from_config(cfg: &ServerConfig) -> Self {
        ConnOpts {
            request_timeout_ms: cfg.request_timeout_ms,
            max_proto_errors: cfg.max_proto_errors,
        }
    }
}

/// A live connection: the handler thread plus a socket handle the accept
/// loop keeps so `stop` can unblock a handler parked in a blocking read.
struct ConnSlot {
    id: u64,
    handle: JoinHandle<()>,
    stream: TcpStream,
}

/// Shared connection registry.  The accept loop holds the mutex across
/// the handler spawn, so a slot is always registered before its handler
/// can look for it; handlers then remove their own slot on exit
/// (dropping the tracked stream clone immediately, so a closed client's
/// socket never lingers in CLOSE_WAIT waiting for the next accept), and
/// `stop` drains and joins whatever is still live.
#[derive(Default)]
struct ConnRegistry {
    conns: Mutex<Vec<ConnSlot>>,
    /// active-connection *gauge*: incremented at accept, decremented when
    /// the handler exits (it used to be a monotonically increasing
    /// counter mislabeled as "connections").
    active: AtomicU64,
    next_id: AtomicU64,
}

/// Handle to a running threaded server (shutdown on drop).
pub(crate) struct ThreadedHandle {
    pub(crate) local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    registry: Arc<ConnRegistry>,
    engine: Arc<Engine>,
    #[cfg(unix)]
    waker: Arc<sys::Waker>,
}

impl ThreadedHandle {
    pub(crate) fn active_connections(&self) -> u64 {
        self.registry.active.load(Ordering::Relaxed)
    }

    pub(crate) fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    fn stop_inner(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // wake the accept loop: self-pipe on unix (works for wildcard
        // binds), loopback connect-poke elsewhere
        #[cfg(unix)]
        self.waker.wake();
        #[cfg(not(unix))]
        let _ = TcpStream::connect(self.local_addr);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
        // unblock handlers parked on reads, then join every one of them:
        // after stop() returns, no handler can race a coordinator shutdown.
        // Read-side only: a handler mid-request still flushes its response
        // (the coordinator drain guarantee) and exits on the next EOF.
        let drained: Vec<ConnSlot> = match self.registry.conns.lock() {
            Ok(mut conns) => conns.drain(..).collect(),
            Err(_) => return,
        };
        for slot in &drained {
            let _ = slot.stream.shutdown(Shutdown::Read);
        }
        for slot in drained {
            let _ = slot.handle.join();
        }
    }
}

impl Drop for ThreadedHandle {
    fn drop(&mut self) {
        self.stop_inner();
    }
}

/// Start the threaded core on `cfg.addr` (non-blocking; returns a handle).
pub(crate) fn serve_threaded(
    engine: Arc<Engine>,
    cfg: &ServerConfig,
) -> std::io::Result<ThreadedHandle> {
    let listener = TcpListener::bind(&cfg.addr)?;
    let local_addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let registry = Arc::new(ConnRegistry::default());
    log_info!(
        "serving on {local_addr} (backend={} shards={} core=threaded)",
        engine.backend_name(),
        engine.shard_count()
    );

    #[cfg(unix)]
    let waker = Arc::new(sys::Waker::new()?);
    #[cfg(unix)]
    let poller = {
        listener.set_nonblocking(true)?;
        let mut p = sys::Poller::new()?;
        p.add(listener.as_raw_fd(), 0, sys::EV_READ)?;
        p.add(waker.fd(), 1, sys::EV_READ)?;
        p
    };

    let stop2 = stop.clone();
    let reg2 = registry.clone();
    let engine2 = engine.clone();
    let opts = ConnOpts::from_config(cfg);
    #[cfg(unix)]
    let waker2 = waker.clone();
    let accept_thread = std::thread::Builder::new()
        .name("hull-accept".into())
        .spawn(move || {
            #[cfg(unix)]
            accept_loop_unix(listener, poller, &waker2, &stop2, &reg2, &engine2, opts);
            #[cfg(not(unix))]
            accept_loop_blocking(listener, &stop2, &reg2, &engine2, opts);
        })?;

    Ok(ThreadedHandle {
        local_addr,
        stop,
        accept_thread: Some(accept_thread),
        registry,
        engine,
        #[cfg(unix)]
        waker,
    })
}

/// Non-blocking accept loop parked in poll over {listener, self-pipe}:
/// a `stop` wakes it without needing a routable loopback connect.
#[cfg(unix)]
fn accept_loop_unix(
    listener: TcpListener,
    mut poller: sys::Poller,
    waker: &sys::Waker,
    stop: &AtomicBool,
    registry: &Arc<ConnRegistry>,
    engine: &Arc<Engine>,
    opts: ConnOpts,
) {
    let mut events = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        if let Err(e) = poller.wait(&mut events, -1) {
            log_info!("accept poll error: {e}");
            break;
        }
        let mut accept_ready = false;
        for ev in &events {
            if ev.token == 1 {
                waker.drain();
            } else {
                accept_ready = true;
            }
        }
        if stop.load(Ordering::SeqCst) {
            break;
        }
        if !accept_ready {
            continue;
        }
        loop {
            match listener.accept() {
                Ok((s, _)) => {
                    // accepted sockets must be blocking regardless of
                    // what the listener's flag was inherited as
                    if s.set_nonblocking(false).is_err() {
                        continue;
                    }
                    accept_one(s, registry, engine, opts);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) => {
                    log_info!("accept error: {e}");
                    break;
                }
            }
        }
    }
}

#[cfg(not(unix))]
fn accept_loop_blocking(
    listener: TcpListener,
    stop: &AtomicBool,
    registry: &Arc<ConnRegistry>,
    engine: &Arc<Engine>,
    opts: ConnOpts,
) {
    for stream in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        match stream {
            Ok(s) => accept_one(s, registry, engine, opts),
            Err(e) => log_info!("accept error: {e}"),
        }
    }
}

/// Track and spawn the handler for one accepted connection.
fn accept_one(s: TcpStream, registry: &Arc<ConnRegistry>, engine: &Arc<Engine>, opts: ConnOpts) {
    let eng = engine.clone();
    let tracked = match s.try_clone() {
        Ok(t) => t,
        Err(_) => return, // dead socket; skip it
    };
    registry.active.fetch_add(1, Ordering::Relaxed);
    let conn_id = registry.next_id.fetch_add(1, Ordering::Relaxed);
    let reg_in = registry.clone();
    // hold the registry lock across the spawn: the slot is pushed before
    // the handler can possibly look for it, so the self-reap below always
    // finds it — an instantly-exiting handler just blocks on the mutex
    // for the push's duration
    let Ok(mut conns) = registry.conns.lock() else {
        // poisoned (a handler panicked mid-reap): tracking is gone;
        // refuse the connection
        registry.active.fetch_sub(1, Ordering::Relaxed);
        return;
    };
    let spawned = std::thread::Builder::new().name("hull-conn".into()).spawn(move || {
        handle_connection(s, eng, &reg_in.active, opts);
        reg_in.active.fetch_sub(1, Ordering::Relaxed);
        // self-reap: drop the tracked stream clone now, not at the next
        // accept — only the coordinator-free tail of this thread outlives
        // the slot, so `stop` loses nothing by not joining it.  Dropping
        // our own JoinHandle merely detaches.
        if let Ok(mut conns) = reg_in.conns.lock() {
            if let Some(i) = conns.iter().position(|c| c.id == conn_id) {
                conns.swap_remove(i);
            }
        }
    });
    match spawned {
        Ok(handle) => {
            conns.push(ConnSlot { id: conn_id, handle, stream: tracked });
        }
        Err(e) => {
            registry.active.fetch_sub(1, Ordering::Relaxed);
            log_info!("spawn error: {e}");
        }
    }
}

fn write_response<W: Write>(w: &mut W, binary: bool, resp: &Response) -> std::io::Result<()> {
    if binary {
        frame::write_response(w, resp)
    } else {
        proto::write_response(w, resp)
    }
}

fn handle_connection(stream: TcpStream, engine: Arc<Engine>, active: &AtomicU64, opts: ConnOpts) {
    let peer = match stream.peer_addr() {
        Ok(p) => p.to_string(),
        Err(_) => "<unknown>".into(),
    };
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = BufWriter::new(stream);
    log_debug!("conn {peer}: connected");

    // Per-connection protocol auto-detection: peek the first octet
    // without consuming it.  `REQ_MAGIC` can never begin a text verb
    // (those are printable ASCII), so one byte decides for the whole
    // connection.
    let binary = match reader.fill_buf() {
        Ok(buf) if !buf.is_empty() => buf[0] == frame::REQ_MAGIC,
        _ => {
            log_debug!("conn {peer}: disconnected before the first byte");
            return;
        }
    };
    log_debug!("conn {peer}: protocol={}", if binary { "binary" } else { "text" });

    let mut frames: u64 = 0;
    let mut proto_errors: u32 = 0;
    loop {
        let read = if binary {
            frame::read_request(&mut reader)
        } else {
            proto::read_request(&mut reader)
        };
        let req = match read {
            Ok(r) => r,
            Err(ProtoError::Eof) => break,
            Err(e) => {
                let _ = write_response(&mut writer, binary, &super::proto_error_response(&e));
                if binary {
                    // a bad binary frame loses framing: always fatal
                    break;
                }
                // text framing is line-oriented: answer and resync on the
                // next line, up to the consecutive-abuse ceiling
                proto_errors += 1;
                if opts.max_proto_errors != 0 && proto_errors >= opts.max_proto_errors {
                    log_info!(
                        "conn {peer}: disconnecting after {proto_errors} \
                         consecutive protocol errors"
                    );
                    break;
                }
                continue;
            }
        };
        frames += 1;
        proto_errors = 0;
        let resp = match req {
            Request::Quit => break,
            Request::Ping => Response::Pong,
            Request::Stats => {
                // merged aggregate + per_shard array, plus the server's
                // connection gauge (engine-global, read exactly once)
                Response::Stats(engine.stats(Some(active.load(Ordering::Relaxed))).0.to_string())
            }
            Request::Hull { id, points, tmo_ms } => {
                let deadline = request_deadline(opts.request_timeout_ms, tmo_ms);
                let reply = engine.submit(HullRequest::new(id, points).with_deadline(deadline));
                match reply.recv() {
                    Ok(result) => super::hull_response(id, result),
                    Err(_) => Response::HullErr { id, message: "coordinator gone".into() },
                }
            }
            Request::SessionOpen { id, restore } => {
                super::session_open_response(&engine, id, restore)
            }
            Request::SessionAdd { sid, points, tmo_ms } => {
                let deadline = request_deadline(opts.request_timeout_ms, tmo_ms);
                super::session_add_response(&engine, sid, &points, deadline)
            }
            Request::SessionHull { sid, epoch } => super::session_hull_response(&engine, sid, epoch),
            Request::SessionClose { sid } => super::session_close_response(&engine, sid),
        };
        if write_response(&mut writer, binary, &resp).is_err() {
            break;
        }
    }
    log_debug!("conn {peer}: disconnected after {frames} frame(s)");
}
