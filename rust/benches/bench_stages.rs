//! E1/E2 — stage anatomy: cost of each merge stage (the paper's kernel
//! launches) and the thread-allocation geometry of Figure 2.
//!
//! Run: `cargo bench --bench bench_stages`

use wagener_hull::benchkit::{black_box, Bencher, Report};
use wagener_hull::geometry::generators::{generate, Distribution};
use wagener_hull::geometry::point::pad_to_hood;
use wagener_hull::serial::hood::oracle_stage;
use wagener_hull::wagener::{self, occupancy};

fn main() {
    let b = Bencher::default();
    let n = 4096;
    let pts = generate(Distribution::Disk, n, 3);

    // prepare the hood state entering each stage
    let mut states = Vec::new();
    let mut hood = pad_to_hood(&pts, n);
    let mut d = 2usize;
    while d < n {
        states.push((d, hood.clone()));
        hood = wagener::stage(&hood, d);
        d *= 2;
    }

    let mut report = Report::new("E2: per-stage merge cost, n = 4096 disk");
    for (d, state) in &states {
        report.add(b.run(&format!("wagener_stage/d{d}"), || {
            black_box(wagener::stage(black_box(state), *d))
        }));
    }
    for (d, state) in &states {
        report.add(b.run(&format!("oracle_stage/d{d}"), || {
            black_box(oracle_stage(black_box(state), *d))
        }));
    }
    // Figure-2 allocation table as notes (machine-readable in BENCH_JSON)
    for row in occupancy::occupancy_table(&pts, n) {
        report.note(format!(
            "occupancy stage={} d={} d1={} d2={} blocks={} threads={} active={} util={:.3}",
            row.stage, row.d, row.d1, row.d2, row.blocks, row.threads,
            row.active_threads, row.utilization()
        ));
    }
    report.finish();

    // whole pipeline vs sum of stages (launch overhead visibility)
    let mut report = Report::new("E2b: full pipeline, n sweep (disk)");
    for &n in &[256usize, 1024, 4096, 16384] {
        let pts = generate(Distribution::Disk, n, 3);
        report.add(b.run(&format!("upper_hood/n{n}"), || {
            black_box(wagener::upper_hood(black_box(&pts), n))
        }));
    }
    report.finish();
}
