#!/usr/bin/env python3
"""Differential simulation of rust/src/engine/placement.rs.

Transliterates mix64, Stripe and the consistent-hash Ring (64 vnodes)
with exact u64 wrapping arithmetic, then property-tests routing:
determinism, totality, stripe equivalence with PR 5's `(sid-1) % N`,
order_for permutation/successor-walk structure, balance, and the
consistent-hash stability guarantee (adding a shard only moves keys TO
the new shard).
"""

import bisect
import random
import sys

MASK = (1 << 64) - 1
VNODES = 64


def mix64(z):
    """SplitMix64 finalizer, bit-for-bit the Rust version."""
    z = (z + 0x9E37_79B9_7F4A_7C15) & MASK
    z = ((z ^ (z >> 30)) * 0xBF58_476D_1CE4_E5B9) & MASK
    z = ((z ^ (z >> 27)) * 0x94D0_49BB_1331_11EB) & MASK
    return (z ^ (z >> 31)) & MASK


class Stripe:
    def __init__(self, shards):
        assert shards > 0
        self.shards = shards

    def shard_for(self, sid):
        # (sid.wrapping_sub(1)) % N — sid 0 wraps to u64::MAX first
        return ((sid - 1) & MASK) % self.shards

    def order_for(self, sid):
        d = self.shard_for(sid)
        return [(d + k) % self.shards for k in range(self.shards)]


class Ring:
    def __init__(self, shards, vnodes=VNODES):
        assert shards > 0 and vnodes > 0
        self.shards = shards
        self.points = sorted(
            (mix64((shard << 32) | v), shard)
            for shard in range(shards)
            for v in range(vnodes)
        )
        self.positions = [p for p, _ in self.points]

    def successor(self, h):
        # Rust: binary_search(&(h, usize::MAX)) — insertion point after
        # every (h, shard), i.e. the first position STRICTLY greater
        i = bisect.bisect_right(self.positions, h)
        return 0 if i == len(self.points) else i

    def shard_for(self, sid):
        return self.points[self.successor(mix64(sid))][1]

    def order_for(self, sid):
        start = self.successor(mix64(sid))
        seen, order = set(), []
        for k in range(len(self.points)):
            shard = self.points[(start + k) % len(self.points)][1]
            if shard not in seen:
                seen.add(shard)
                order.append(shard)
                if len(order) == self.shards:
                    break
        return order


def check(cond, msg):
    if not cond:
        print(f"FAIL: {msg}", file=sys.stderr)
        sys.exit(1)


def main():
    rng = random.Random(0x9E3779B9)

    # mix64 sanity: injective on a large sample and avalanche-y enough
    # that single-bit inputs spread across the full word
    seen = set()
    for z in list(range(10000)) + [rng.randrange(1 << 64) for _ in range(10000)]:
        seen.add(mix64(z))
    check(len(seen) >= 19990, "mix64 collided unexpectedly often")
    check(mix64(0) != 0 and mix64(1) >> 32 != 0, "mix64 degenerate")

    # stripe == PR 5 routing, incl. the sid=0 wrap; order is the rotation
    cases = 0
    for n in range(1, 13):
        s = Stripe(n)
        for sid in list(range(0, 200)) + [rng.randrange(1 << 64) for _ in range(200)]:
            want = ((sid - 1) % 2**64) % n
            check(s.shard_for(sid) == want, f"stripe({n}) sid {sid}")
            order = s.order_for(sid)
            check(sorted(order) == list(range(n)), f"stripe order permutation n={n}")
            check(order[0] == want, "stripe order starts at designated")
            cases += 1

    # ring: deterministic, total, independent rebuilds agree
    for n in range(1, 13):
        a, b = Ring(n), Ring(n)
        for sid in list(range(1, 300)) + [rng.randrange(1, 1 << 64) for _ in range(300)]:
            sa = a.shard_for(sid)
            check(0 <= sa < n, f"ring({n}) out of range")
            check(sa == b.shard_for(sid), f"ring({n}) nondeterministic")
            order = a.order_for(sid)
            check(sorted(order) == list(range(n)), f"ring order permutation n={n}")
            check(order[0] == sa, "ring order starts at designated")
            cases += 1
        if n == 1:
            check(all(a.shard_for(s) == 0 for s in range(1, 65)), "1-shard ring != 0")

    # order_for[1] really is the next distinct shard clockwise — the
    # spill target equals the owner-if-designated-left property
    r = Ring(5)
    for sid in [1, 2, 77, 1234, (1 << 64) - 1] + [rng.randrange(1, 1 << 60) for _ in range(500)]:
        order = r.order_for(sid)
        without = [(p, s) for p, s in r.points if s != order[0]]
        positions = [p for p, _ in without]
        i = bisect.bisect_right(positions, mix64(sid))
        heir = without[0 if i == len(without) else i][1]
        check(order[1] == heir, f"spill target sid {sid}: {order[1]} != heir {heir}")

    # balance: with 64 vnodes every shard's share stays within the loose
    # band the Rust unit test enforces (400..=1800 of 4000 at n=4)
    counts = [0] * 4
    r4 = Ring(4)
    for sid in range(1, 4001):
        counts[r4.shard_for(sid)] += 1
    check(all(400 <= c <= 1800 for c in counts), f"ring(4) balance {counts}")

    # consistent-hash stability: growing n -> n+1 moves keys only TO the
    # new shard, and roughly a 1/(n+1) fraction of them (vnode variance
    # allows a wide band, but never the bulk of the keyspace)
    total = 4000
    for n in range(1, 9):
        small, big = Ring(n), Ring(n + 1)
        moved = 0
        for sid in range(1, total + 1):
            a, b = small.shard_for(sid), big.shard_for(sid)
            if a != b:
                check(b == n, f"grow {n}->{n+1}: sid {sid} moved {a}->{b}, not to new shard")
                moved += 1
        hi = min(0.85, 1.8 / (n + 1)) * total
        check(0 < moved < hi, f"grow {n}->{n+1}: moved {moved}/{total} (bound {hi:.0f})")

    print(f"sim_placement OK: mix64 20000, routing {cases} cases, "
          f"spill-heir 505, balance + stability for n=1..12")


if __name__ == "__main__":
    main()
