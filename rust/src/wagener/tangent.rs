//! The paper's g / f tangent-classification predicates.
//!
//! For a block pair [H(P) | H(Q)] (each half live-left-justified):
//! `g(i, j)` locates corner `q = blk[j]` of H(Q) relative to the corner
//! supporting the tangent from `p = blk[i]`; `f(i, j)` locates `p` on H(P)
//! relative to the tangent from `q`.  Along the respective hood the code
//! sequence is LOW* EQUAL HIGH* (paper Theorem 2.1 uses the f-monotonicity
//! over tangent pairs).  The published listings are partially garbled; these
//! are re-derived from the geometry (DESIGN.md §4.2) and property-tested
//! against the brute-force tangent.

use crate::geometry::point::Point;
use crate::geometry::predicates::left_of;

/// Paper's LOW / EQUAL / HIGH classification.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Code {
    Low,
    Equal,
    High,
}

/// Neighbor of `blk[idx]` in direction `delta` within the hood stored at
/// `blk[lo..hi]`; the synthetic below-point when absent (paper's
/// branch-free `y -= atend` trick).
#[inline]
fn neighbor(blk: &[Point], idx: usize, next: bool, lo: usize, hi: usize) -> Point {
    let pt = blk[idx];
    if next {
        let at_end = idx + 1 >= hi || !blk[idx + 1].is_live();
        if at_end {
            pt.below()
        } else {
            blk[idx + 1]
        }
    } else {
        let at_start = idx <= lo;
        if at_start {
            pt.below()
        } else {
            blk[idx - 1]
        }
    }
}

/// g(i, j): position of H(Q) corner j relative to the tangent-from-p touch
/// corner.  `i` indexes the P half `[0, d)`, `j` the Q half `[d, 2d)`.
/// REMOTE p or q ⇒ High.
#[inline]
pub fn g(blk: &[Point], i: usize, j: usize, d: usize) -> Code {
    debug_assert!(i < d && (d..2 * d).contains(&j));
    let p = blk[i];
    let q = blk[j];
    if p.is_remote() || q.is_remote() {
        return Code::High;
    }
    let q_next = neighbor(blk, j, true, d, 2 * d);
    if left_of(p, q, q_next) {
        return Code::Low;
    }
    let q_prev = neighbor(blk, j, false, d, 2 * d);
    if left_of(p, q, q_prev) {
        Code::High
    } else {
        Code::Equal
    }
}

/// f(i, j): position of H(P) corner i relative to the tangent-from-q touch
/// corner.  REMOTE p or q ⇒ High.
#[inline]
pub fn f(blk: &[Point], i: usize, j: usize, d: usize) -> Code {
    debug_assert!(i < d && (d..2 * d).contains(&j));
    let p = blk[i];
    let q = blk[j];
    if p.is_remote() || q.is_remote() {
        return Code::High;
    }
    let p_next = neighbor(blk, i, true, 0, d);
    if left_of(p, q, p_next) {
        return Code::Low;
    }
    let p_prev = neighbor(blk, i, false, 0, d);
    if left_of(p, q, p_prev) {
        Code::High
    } else {
        Code::Equal
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::generators::{generate, Distribution};
    use crate::geometry::point::{pad_to_hood, Point, REMOTE};
    use crate::serial::monotone_chain;
    use crate::util::rng::Rng;

    /// Build a [H(P) | H(Q)] block pair from two point sets.
    fn block_pair(p: &[Point], q: &[Point], d: usize) -> Vec<Point> {
        let mut blk = pad_to_hood(&monotone_chain::upper_hull(p), d);
        blk.extend(pad_to_hood(&monotone_chain::upper_hull(q), d));
        blk
    }

    fn random_pair(rng: &mut Rng, d: usize) -> Vec<Point> {
        let n = rng.range_usize(1, d + 1);
        let m = rng.range_usize(1, d + 1);
        let mut p: Vec<Point> = (0..n)
            .map(|_| Point::new(rng.f64() * 0.45, rng.f64()).quantize_f32())
            .collect();
        let mut q: Vec<Point> = (0..m)
            .map(|_| Point::new(0.55 + rng.f64() * 0.45, rng.f64()).quantize_f32())
            .collect();
        crate::geometry::point::sort_by_x(&mut p);
        crate::geometry::point::sort_by_x(&mut q);
        p.dedup_by(|a, b| a.x == b.x);
        q.dedup_by(|a, b| a.x == b.x);
        block_pair(&p, &q, d)
    }

    /// Brute-force common tangent of a block pair: the unique live (i, j)
    /// with all other live corners strictly right of line i->j.
    fn brute_tangent(blk: &[Point], d: usize) -> (usize, usize) {
        let live: Vec<usize> = (0..2 * d).filter(|&t| blk[t].is_live()).collect();
        for &i in live.iter().filter(|&&t| t < d) {
            for &j in live.iter().filter(|&&t| t >= d) {
                if live
                    .iter()
                    .all(|&o| o == i || o == j || !left_of(blk[i], blk[j], blk[o]))
                {
                    return (i, j);
                }
            }
        }
        panic!("no tangent");
    }

    #[test]
    fn g_sequence_is_monotone() {
        let mut rng = Rng::new(31);
        for _ in 0..100 {
            let d = 8;
            let blk = random_pair(&mut rng, d);
            let qlive = (d..2 * d).take_while(|&j| blk[j].is_live()).count();
            for i in 0..d {
                if blk[i].is_remote() {
                    continue;
                }
                let codes: Vec<Code> = (d..d + qlive).map(|j| g(&blk, i, j, d)).collect();
                let eq = codes.iter().filter(|&&c| c == Code::Equal).count();
                assert_eq!(eq, 1, "exactly one EQUAL: {codes:?}");
                assert!(codes.windows(2).all(|w| w[0] <= w[1]), "{codes:?}");
            }
        }
    }

    #[test]
    fn f_sequence_is_monotone() {
        let mut rng = Rng::new(37);
        for _ in 0..100 {
            let d = 8;
            let blk = random_pair(&mut rng, d);
            let plive = (0..d).take_while(|&i| blk[i].is_live()).count();
            for j in d..2 * d {
                if blk[j].is_remote() {
                    continue;
                }
                let codes: Vec<Code> = (0..plive).map(|i| f(&blk, i, j, d)).collect();
                let eq = codes.iter().filter(|&&c| c == Code::Equal).count();
                assert_eq!(eq, 1, "exactly one EQUAL: {codes:?}");
                assert!(codes.windows(2).all(|w| w[0] <= w[1]), "{codes:?}");
            }
        }
    }

    #[test]
    fn double_equal_is_exactly_the_common_tangent() {
        let mut rng = Rng::new(41);
        for _ in 0..200 {
            let d = 8;
            let blk = random_pair(&mut rng, d);
            let want = brute_tangent(&blk, d);
            let mut hits = Vec::new();
            for i in 0..d {
                for j in d..2 * d {
                    if blk[i].is_live()
                        && blk[j].is_live()
                        && g(&blk, i, j, d) == Code::Equal
                        && f(&blk, i, j, d) == Code::Equal
                    {
                        hits.push((i, j));
                    }
                }
            }
            assert_eq!(hits, vec![want]);
        }
    }

    #[test]
    fn remote_is_high() {
        let pts = generate(Distribution::UniformSquare, 4, 2);
        let blk = block_pair(&pts[..2], &pts[2..], 4);
        assert_eq!(blk[3], REMOTE);
        assert_eq!(g(&blk, 0, 7, 4), Code::High); // remote q
        assert_eq!(f(&blk, 3, 4, 4), Code::High); // remote p
    }
}
