//! Overmars–van Leeuwen machinery for the paper's §3 optimal-speedup
//! sketch: hull chains in balanced trees, logarithmic-time common-tangent
//! search, and the strip-preprocessed merge pipeline whose *work* (not
//! just time) the paper argues down from O(n log n) to O(n).
//!
//! Experiment E5 measures the predicate-evaluation and data-movement
//! counts of this variant against the standard Wagener pipeline.

pub mod optimal;
pub mod tangent_search;
pub mod treap;

pub use optimal::{optimal_upper_hull, OptimalRun, WorkStats};
pub use tangent_search::{common_tangent, HullChain};
pub use treap::Treap;
