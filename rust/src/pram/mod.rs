//! CREW-PRAM simulator with CUDA-style cost accounting.
//!
//! The paper's machine model is Wagener's CREW PRAM, realised on a CUDA
//! chip whose shared-memory *bank conflicts* made the parallel program
//! "slow by comparison with another serial program" (paper Conclusions).
//! This substrate makes both halves of that statement measurable:
//!
//! * a synchronous shared-memory machine with per-step write-conflict
//!   (CREW) checking — a correctness tool: the Wagener phases must be
//!   exclusive-write, and tests assert zero violations;
//! * a cost model counting PRAM steps, work (PE-operations), and modeled
//!   cycles under a 32-bank / 32-lane-warp serialization model — the
//!   quantity behind experiment E4.

pub mod machine;

pub use machine::{BankModel, Counters, PeCtx, Pram, PramError};
