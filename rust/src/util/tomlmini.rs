//! Minimal TOML subset parser for the launcher config (substitute for the
//! `toml` crate).
//!
//! Supported grammar — everything `config.rs` needs and nothing more:
//!   * `[section]` headers (one level),
//!   * `key = value` with value ∈ {string "..", integer, float, bool},
//!   * `#` comments and blank lines.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// section -> key -> value; keys outside any section land in section "".
pub type Table = BTreeMap<String, BTreeMap<String, Value>>;

#[derive(Debug, Clone, PartialEq)]
pub struct TomlError {
    pub line: usize,
    pub message: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml error on line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for TomlError {}

pub fn parse(input: &str) -> Result<Table, TomlError> {
    let mut table = Table::new();
    let mut section = String::new();
    table.entry(section.clone()).or_default();
    for (lineno, raw) in input.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        let err = |m: &str| TomlError {
            line: lineno + 1,
            message: m.to_string(),
        };
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest.strip_suffix(']').ok_or_else(|| err("unclosed ']'"))?;
            section = name.trim().to_string();
            if section.is_empty() {
                return Err(err("empty section name"));
            }
            table.entry(section.clone()).or_default();
        } else if let Some((k, v)) = line.split_once('=') {
            let key = k.trim();
            if key.is_empty() {
                return Err(err("empty key"));
            }
            let value = parse_value(v.trim()).map_err(|m| err(&m))?;
            table
                .get_mut(&section)
                .unwrap()
                .insert(key.to_string(), value);
        } else {
            return Err(err("expected 'key = value' or '[section]'"));
        }
    }
    Ok(table)
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        // basic escapes only
        let mut out = String::new();
        let mut chars = inner.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    _ => return Err("bad escape".into()),
                }
            } else {
                out.push(c);
            }
        }
        return Ok(Value::Str(out));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value: {s:?}"))
}

/// Convenience: fetch `section.key` with a typed accessor.
pub fn get<'t>(table: &'t Table, section: &str, key: &str) -> Option<&'t Value> {
    table.get(section).and_then(|s| s.get(key))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_typical_config() {
        let src = r#"
# launcher config
[server]
addr = "127.0.0.1:7878"   # bind address
workers = 4

[batcher]
max_batch = 8
flush_us = 500
enabled = true
scale = 1.5
"#;
        let t = parse(src).unwrap();
        assert_eq!(
            get(&t, "server", "addr").unwrap().as_str(),
            Some("127.0.0.1:7878")
        );
        assert_eq!(get(&t, "server", "workers").unwrap().as_int(), Some(4));
        assert_eq!(get(&t, "batcher", "enabled").unwrap().as_bool(), Some(true));
        assert_eq!(get(&t, "batcher", "scale").unwrap().as_float(), Some(1.5));
        assert_eq!(get(&t, "batcher", "max_batch").unwrap().as_float(), Some(8.0));
    }

    #[test]
    fn top_level_keys() {
        let t = parse("x = 1\ny = \"a#b\"").unwrap();
        assert_eq!(get(&t, "", "x").unwrap().as_int(), Some(1));
        assert_eq!(get(&t, "", "y").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn string_escapes() {
        let t = parse(r#"s = "a\nb\"c""#).unwrap();
        assert_eq!(get(&t, "", "s").unwrap().as_str(), Some("a\nb\"c"));
    }

    #[test]
    fn error_reports_line() {
        let e = parse("ok = 1\nbroken line").unwrap_err();
        assert_eq!(e.line, 2);
        for bad in ["[unclosed", "= 1", "k = ", "k = 'single'"] {
            assert!(parse(bad).is_err(), "{bad}");
        }
    }
}
