"""L1 correctness: pallas merge-stage kernel vs the pure-numpy oracle.

The CORE correctness signal of the build path.  Hypothesis sweeps sizes,
liveness patterns and point distributions; every stage output must match
the monotone-chain oracle bit-exactly (same f32 points are selected, only
selection logic differs between implementations).
"""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ref, wagener

# ---------------------------------------------------------------- helpers


def sorted_points(rng: np.random.Generator, m: int) -> np.ndarray:
    pts = rng.random((m, 2)).astype(np.float32)
    return pts[np.argsort(pts[:, 0])]


def make_hood(pts: np.ndarray, n: int) -> np.ndarray:
    """n-slot initial hood: pts live-left-justified, REMOTE padded."""
    hood = np.tile(ref.remote_row(), (n, 1))
    hood[: len(pts)] = pts
    return hood


def run_stages(hood0: np.ndarray, check_each: bool = True) -> np.ndarray:
    """Drive hood0 through all stages, asserting vs oracle per stage."""
    n = hood0.shape[0]
    hw = jnp.asarray(hood0)
    hr = hood0.copy()
    d = 2
    while d < n:
        hr = ref.ref_stage(hr, d)
        hw = wagener.pallas_stage(hw, d)
        if check_each:
            np.testing.assert_array_equal(np.asarray(hw), hr, err_msg=f"d={d}")
        d *= 2
    return np.asarray(hw)


# ------------------------------------------------------------ stage_dims


@pytest.mark.parametrize(
    "d,expect",
    [(2, (2, 1)), (4, (2, 2)), (8, (4, 2)), (16, (4, 4)), (32, (8, 4)),
     (64, (8, 8)), (512, (32, 16))],
)
def test_stage_dims(d, expect):
    assert wagener.stage_dims(d) == expect


def test_stage_dims_rejects_bad():
    for bad in (0, 1, 3, 6, 100):
        with pytest.raises((AssertionError, ValueError)):
            wagener.stage_dims(bad)


# ------------------------------------------------------- predicate checks


def test_g_classification_sequence():
    """g along H(Q) must read LOW* EQUAL HIGH* for every live p in P."""
    rng = np.random.default_rng(7)
    for _ in range(10):
        n = 16
        hood0 = make_hood(sorted_points(rng, n), n)
        # build d=8 hoods via oracle, then inspect one block pair
        hood = hood0.copy()
        for d in (2, 4):
            hood = ref.ref_stage(hood, d)
        blk = jnp.asarray(hood)
        d = 8
        q_live = int(ref.is_live(hood[d : 2 * d]).sum())
        for i in range(int(ref.is_live(hood[:d]).sum())):
            codes = [
                int(wagener._g(blk, jnp.asarray(i), jnp.asarray(d + j), d))
                for j in range(q_live)
            ]
            s = "".join("LEH"[c] for c in codes)
            assert s == "L" * s.count("L") + "E" + "H" * s.count("H"), s


def test_f_matches_bruteforce_tangent():
    """The pair with g == f == EQUAL must be the brute-force tangent."""
    rng = np.random.default_rng(11)
    for _ in range(20):
        d = 8
        p = sorted_points(rng, d)
        q = sorted_points(rng, d)
        q[:, 0] += 1.0  # Q right of P
        q[:, 0] = np.clip(q[:, 0] / 2 + 0.5, None, 1.0)
        p[:, 0] = p[:, 0] / 2.1
        pblk = ref.pad_block(ref.upper_hull(p), d)
        qblk = ref.pad_block(ref.upper_hull(q), d)
        blk = jnp.asarray(np.concatenate([pblk, qblk]))
        pi, qi = ref.ref_tangent(pblk, qblk)
        hits = []
        for a in range(int(ref.is_live(pblk).sum())):
            for b in range(int(ref.is_live(qblk).sum())):
                g = int(wagener._g(blk, jnp.asarray(a), jnp.asarray(d + b), d))
                f = int(wagener._f(blk, jnp.asarray(a), jnp.asarray(d + b), d))
                if g == wagener.EQUAL and f == wagener.EQUAL:
                    hits.append((a, b))
        assert hits == [(pi, qi)]


# ------------------------------------------------------ hypothesis sweeps


@settings(max_examples=25, deadline=None)
@given(
    log_n=st.integers(2, 6),
    m_frac=st.floats(0.05, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_stage_vs_ref_random(log_n, m_frac, seed):
    n = 1 << log_n
    m = max(1, int(round(m_frac * n)))
    rng = np.random.default_rng(seed)
    run_stages(make_hood(sorted_points(rng, m), n))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.sampled_from([16, 64]))
def test_stage_all_on_hull(seed, n):
    """Parabola: every point is an upper-hull corner (max hood sizes)."""
    rng = np.random.default_rng(seed)
    x = np.sort(rng.random(n)).astype(np.float32)
    y = (1.0 - (2 * x - 1) ** 2).astype(np.float32) * 0.5
    out = run_stages(make_hood(np.stack([x, y], 1), n))
    assert int(ref.is_live(out).sum()) == n


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.sampled_from([16, 64]))
def test_stage_two_on_hull(seed, n):
    """Valley: only the two extreme points survive (min hood sizes).

    Exercises the mam6 stale-corner paper-bug fix (far-left p*, far-right
    q*)."""
    rng = np.random.default_rng(seed)
    x = np.sort(rng.random(n) * 0.8 + 0.1).astype(np.float32)
    y = ((2 * x - 1) ** 2).astype(np.float32) * 0.5
    out = run_stages(make_hood(np.stack([x, y], 1), n))
    assert int(ref.is_live(out).sum()) == 2


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.sampled_from([8, 32]),
    split=st.floats(0.1, 0.9),
)
def test_stage_clustered(seed, n, split):
    """Two tight clusters: tangents span a wide gap."""
    rng = np.random.default_rng(seed)
    m1 = max(1, int(n * split))
    a = rng.random((m1, 2)).astype(np.float32) * 0.1
    b = rng.random((n - m1, 2)).astype(np.float32) * 0.1 + 0.85
    pts = np.concatenate([a, b]) if len(b) else a
    pts = pts[np.argsort(pts[:, 0])]
    run_stages(make_hood(pts, n))


# --------------------------------------------------------------- edge cases


def test_single_point():
    out = run_stages(make_hood(np.array([[0.5, 0.5]], np.float32), 8))
    assert int(ref.is_live(out).sum()) == 1


def test_all_remote_blocks_passthrough():
    """A fully-REMOTE pair must pass through unchanged (padding blocks)."""
    hood = make_hood(np.zeros((0, 2), np.float32), 8)
    hood[0] = [0.1, 0.3]  # one live point so the array is not fully dead
    out = run_stages(hood)
    np.testing.assert_array_equal(out[0], np.float32([0.1, 0.3]))
    assert int(ref.is_live(out).sum()) == 1


def test_pallas_equals_jnp_stage():
    """Differential: the two lowerings of merge_block agree exactly."""
    rng = np.random.default_rng(3)
    hood = jnp.asarray(make_hood(sorted_points(rng, 64), 64))
    d = 2
    while d < 64:
        a = wagener.pallas_stage(hood, d)
        b = wagener.jnp_stage(hood, d)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        hood = a
        d *= 2


def test_monotone_x_invariant():
    """Live corners of every intermediate hood are strictly x-increasing."""
    rng = np.random.default_rng(5)
    hood = jnp.asarray(make_hood(sorted_points(rng, 128), 128))
    d = 2
    while d < 128:
        hood = wagener.pallas_stage(hood, d)
        h = np.asarray(hood)
        for b in range(128 // (2 * d)):
            blk = h[b * 2 * d : (b + 1) * 2 * d]
            live = blk[ref.is_live(blk)]
            assert np.all(np.diff(live[:, 0]) > 0)
        d *= 2
