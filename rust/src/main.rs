//! `wagener` — CLI launcher for the hull framework.
//!
//! Subcommands (hand-rolled parser; no argv crates in this environment):
//!   gen        generate a point file in the paper's format
//!   hull       compute a hull from a point file (the paper's main program:
//!              optional per-stage trace, SVG render, backend choice)
//!   serve      run the TCP hull service from a TOML config
//!   client     send a point file to a running server
//!   occupancy  print the Figure-2 thread-allocation table
//!   artifacts  list/verify the AOT artifact registry

use std::collections::HashMap;
use std::io::Read;
use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use wagener_hull::config::Config;
use wagener_hull::coordinator::{BackendKind, Coordinator, CoordinatorConfig, PrefilterMode};
use wagener_hull::engine::{Engine, EngineConfig, PlacementKind};
use wagener_hull::store::{FsStore, SnapshotStore};
use wagener_hull::geometry::generators::{generate, Distribution};
use wagener_hull::geometry::point::{pad_to_hood, Point};
use wagener_hull::pram::ExecMode;
use wagener_hull::runtime::ArtifactRegistry;
use wagener_hull::gateway;
use wagener_hull::server;
use wagener_hull::viz::svg::{render_hull_svg, SvgOptions};
use wagener_hull::viz::trace::TraceWriter;
use wagener_hull::wagener::occupancy::{format_table, occupancy_table};
use wagener_hull::wagener::stage;

const USAGE: &str = "\
usage: wagener <command> [options]

commands:
  gen        --dist <name> --n <count> [--seed <u64>] [--out <file>]
  hull       <points-file> [--trace <file>] [--svg <file>] [--backend <pjrt|native|serial|pram>]
             [--artifacts <dir>] [--exec-mode <fast|audited>]
             [--merge <points-file-2>]   hull both files, then tangent-merge the two hulls
  serve      [--config <file>] [--addr <host:port>] [--backend <kind>] [--artifacts <dir>]
             [--exec-mode <fast|audited>] [--workers <n>] [--shards <n>] [--io-threads <n>]
             [--max-sessions <n>] [--merge-threshold <n>] [--idle-ttl-ms <n>]
             [--request-timeout-ms <n>] [--max-queued <n>] [--breaker-cooldown-ms <n>]
             [--max-proto-errors <n>] [--store-dir <dir>] [--placement <stripe|ring>]
             [--prefilter <host|device|off>]   where the octagon pre-filter runs
             [--device-merge <true|false>]     pjrt session merges on the tangent kernel
             [--http-port <n>]   also serve the HTTP/JSON gateway on this port
  client     --addr <host:port> [--proto <text|binary|auto>] [--tmo <ms>]
             [--connect-retries <n>] <points-file>
  occupancy  --n <count> [--dist <name>] [--seed <u64>]
  artifacts  [--dir <dir>]

distributions: uniform disk circle parabola valley clusters<k> bimodal
point file format (paper §2): first line count, then 'x y' per line, x-sorted in [0,1]
";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        eprintln!();
        eprintln!("{USAGE}");
        std::process::exit(1);
    }
}

/// Split args into positional + --flag value pairs.
fn parse_flags(args: &[String]) -> Result<(Vec<String>, HashMap<String, String>)> {
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        if let Some(name) = a.strip_prefix("--") {
            let v = it.next().ok_or_else(|| anyhow!("--{name} needs a value"))?;
            flags.insert(name.to_string(), v.clone());
        } else {
            pos.push(a.clone());
        }
    }
    Ok((pos, flags))
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else { bail!("no command") };
    let rest = &args[1..];
    match cmd.as_str() {
        "gen" => cmd_gen(rest),
        "hull" => cmd_hull(rest),
        "serve" => cmd_serve(rest),
        "client" => cmd_client(rest),
        "occupancy" => cmd_occupancy(rest),
        "artifacts" => cmd_artifacts(rest),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}"),
    }
}

/// Read the paper's point-file format.
fn read_points_file(path: &str) -> Result<Vec<Point>> {
    let mut text = String::new();
    if path == "-" {
        std::io::stdin().read_to_string(&mut text)?;
    } else {
        text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    }
    let mut tokens = text.split_whitespace();
    let count: usize = tokens
        .next()
        .ok_or_else(|| anyhow!("empty file"))?
        .parse()
        .context("first token must be the point count")?;
    let mut pts = Vec::with_capacity(count);
    for k in 0..count {
        let x: f64 = tokens
            .next()
            .ok_or_else(|| anyhow!("eof at point {k}"))?
            .parse()?;
        let y: f64 = tokens
            .next()
            .ok_or_else(|| anyhow!("eof at point {k}"))?
            .parse()?;
        pts.push(Point::new(x, y));
    }
    Ok(pts)
}

fn write_points(w: &mut impl std::io::Write, pts: &[Point]) -> Result<()> {
    writeln!(w, "{}", pts.len())?;
    for p in pts {
        writeln!(w, "{:.6} {:.6}", p.x, p.y)?;
    }
    Ok(())
}

fn cmd_gen(args: &[String]) -> Result<()> {
    let (_, flags) = parse_flags(args)?;
    let dist = flags
        .get("dist")
        .map(String::as_str)
        .unwrap_or("uniform");
    let dist = Distribution::parse(dist).ok_or_else(|| anyhow!("unknown distribution {dist}"))?;
    let n: usize = flags.get("n").ok_or_else(|| anyhow!("--n required"))?.parse()?;
    let seed: u64 = flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(1);
    let pts = generate(dist, n, seed);
    match flags.get("out") {
        Some(path) => {
            let mut f = std::fs::File::create(path)?;
            write_points(&mut f, &pts)?;
            println!("wrote {n} {} points to {path}", dist.name());
        }
        None => write_points(&mut std::io::stdout(), &pts)?,
    }
    Ok(())
}

/// Parse the optional `--exec-mode <fast|audited>` flag.
fn parse_exec_mode(flags: &HashMap<String, String>) -> Result<Option<ExecMode>> {
    flags
        .get("exec-mode")
        .map(|s| ExecMode::parse(s).ok_or_else(|| anyhow!("unknown exec mode {s}")))
        .transpose()
}

/// Parse the optional `--workers <n>` flag (0 = available parallelism).
fn parse_workers(flags: &HashMap<String, String>) -> Result<Option<usize>> {
    flags
        .get("workers")
        .map(|s| s.parse::<usize>().context("--workers wants a non-negative integer"))
        .transpose()
}

/// `--exec-mode` only changes behaviour on the pram backend (and pjrt
/// under self_check); surface the no-op instead of silently ignoring it.
fn warn_if_exec_mode_noop(mode: Option<ExecMode>, backend: BackendKind, self_check: bool) {
    if let Some(m) = mode {
        let effective = backend == BackendKind::Pram
            || (backend == BackendKind::Pjrt && self_check);
        if !effective {
            eprintln!(
                "warning: --exec-mode {} has no effect on the {} backend \
                 (it selects the pram engine tier)",
                m.name(),
                backend.name()
            );
        }
    }
}

fn cmd_hull(args: &[String]) -> Result<()> {
    let (pos, flags) = parse_flags(args)?;
    let file = pos.first().ok_or_else(|| anyhow!("hull needs a points file"))?;
    let points = read_points_file(file)?;
    let backend = flags
        .get("backend")
        .map(|s| BackendKind::parse(s).ok_or_else(|| anyhow!("unknown backend {s}")))
        .transpose()?
        .unwrap_or(BackendKind::Native);
    let exec_mode = parse_exec_mode(&flags)?;
    if flags.contains_key("merge") && (flags.contains_key("trace") || flags.contains_key("svg")) {
        bail!("--merge outputs combined hull chains only; it cannot be used with --trace/--svg");
    }

    // paper's main: echo the points, then compute
    write_points(&mut std::io::stdout(), &points)?;
    println!();

    // per-stage trace (paper's optional second argument)
    let mut stage_hoods: Vec<Vec<Vec<Point>>> = Vec::new();
    if flags.contains_key("trace") || flags.contains_key("svg") {
        let mut sorted = points.clone();
        wagener_hull::geometry::point::sort_by_x(&mut sorted);
        let slots = sorted.len().next_power_of_two().max(2);
        let mut hood = pad_to_hood(&sorted, slots);
        let mut tw = flags
            .get("trace")
            .map(|p| std::fs::File::create(p).map(TraceWriter::new))
            .transpose()?;
        let mut d = 2;
        while d < slots {
            if let Some(tw) = tw.as_mut() {
                tw.stage(&hood, d)?;
            }
            stage_hoods.push(
                hood.chunks(d)
                    .map(|b| wagener_hull::geometry::point::live_prefix(b).to_vec())
                    .collect(),
            );
            hood = stage(&hood, d);
            d *= 2;
        }
        if let Some(tw) = tw {
            tw.finish()?;
        }
    }

    let mut coord_cfg = CoordinatorConfig {
        backend,
        artifacts_dir: PathBuf::from(
            flags.get("artifacts").cloned().unwrap_or_else(|| "artifacts".into()),
        ),
        // one-shot CLI: a single request is a single one-item batch, so
        // a pool could never help — pin one worker (no --workers here;
        // intra-request parallelism comes from the backend itself)
        workers: 1,
        ..Default::default()
    };
    if let Some(mode) = exec_mode {
        coord_cfg.exec_mode = mode;
    }
    warn_if_exec_mode_noop(exec_mode, coord_cfg.backend, coord_cfg.self_check);
    let coord = Coordinator::start(coord_cfg).map_err(|e| anyhow!(e))?;

    // --merge: hull both files on the backend, then combine the two
    // hulls with the paper's common-tangent machinery (the session
    // subsystem's merge path, exercisable without a server)
    if let Some(file2) = flags.get("merge") {
        let points2 = read_points_file(file2)?;
        let a = coord.compute(points.clone()).map_err(|e| anyhow!("{e}"))?;
        let b = coord.compute(points2).map_err(|e| anyhow!("{e}"))?;
        let ((upper, lower), path) = wagener_hull::wagener::merge_hulls(
            (&a.upper, &a.lower),
            (&b.upper, &b.lower),
        );
        println!("# merge_hulls backend={} path={}", a.backend, path.name());
        println!("# upper hood");
        write_points(&mut std::io::stdout(), &upper)?;
        println!("# lower hood");
        write_points(&mut std::io::stdout(), &lower)?;
        return Ok(());
    }

    let resp = coord
        .compute(points.clone())
        .map_err(|e| anyhow!("{e}"))?;

    println!("# backend={} queue_ns={} exec_ns={}", resp.backend, resp.queue_ns, resp.exec_ns);
    println!("# upper hood");
    write_points(&mut std::io::stdout(), &resp.upper)?;
    println!("# lower hood");
    write_points(&mut std::io::stdout(), &resp.lower)?;

    if let Some(svg_path) = flags.get("svg") {
        let svg = render_hull_svg(
            &points,
            &resp.upper,
            &resp.lower,
            &stage_hoods,
            &SvgOptions::default(),
        );
        std::fs::write(svg_path, svg)?;
        println!("# svg written to {svg_path}");
    }
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let (_, flags) = parse_flags(args)?;
    let mut cfg = match flags.get("config") {
        Some(path) => Config::from_file(std::path::Path::new(path))?,
        None => Config::default(),
    };
    if let Some(addr) = flags.get("addr") {
        cfg.server.addr = addr.clone();
    }
    if let Some(b) = flags.get("backend") {
        cfg.coordinator.backend =
            BackendKind::parse(b).ok_or_else(|| anyhow!("unknown backend {b}"))?;
    }
    if let Some(dir) = flags.get("artifacts") {
        cfg.coordinator.artifacts_dir = PathBuf::from(dir);
    }
    let exec_mode = parse_exec_mode(&flags)?;
    if let Some(mode) = exec_mode {
        cfg.coordinator.exec_mode = mode;
    }
    if let Some(w) = parse_workers(&flags)? {
        cfg.coordinator.workers = w;
    }
    if let Some(v) = flags.get("shards") {
        cfg.engine.shards = v
            .parse::<usize>()
            .context("--shards wants a non-negative integer (0 = auto)")?;
    }
    if let Some(v) = flags.get("io-threads") {
        cfg.server.io_threads = v
            .parse::<usize>()
            .context("--io-threads wants a non-negative integer (0 = auto)")?;
    }
    if let Some(v) = flags.get("max-sessions") {
        cfg.stream.max_sessions =
            v.parse::<usize>().context("--max-sessions wants a positive integer")?.max(1);
    }
    if let Some(v) = flags.get("merge-threshold") {
        cfg.stream.merge_threshold =
            v.parse::<usize>().context("--merge-threshold wants a positive integer")?.max(1);
    }
    if let Some(v) = flags.get("idle-ttl-ms") {
        cfg.stream.idle_ttl_ms =
            v.parse::<u64>().context("--idle-ttl-ms wants a non-negative integer (0 = never)")?;
    }
    if let Some(v) = flags.get("request-timeout-ms") {
        cfg.server.request_timeout_ms = v
            .parse::<u64>()
            .context("--request-timeout-ms wants a non-negative integer (0 = none)")?;
    }
    if let Some(v) = flags.get("max-queued") {
        cfg.engine.max_queued = v
            .parse::<usize>()
            .context("--max-queued wants a non-negative integer (0 = unbounded)")?;
    }
    if let Some(v) = flags.get("breaker-cooldown-ms") {
        cfg.coordinator.breaker_cooldown_ms = v
            .parse::<u64>()
            .context("--breaker-cooldown-ms wants a non-negative integer (0 = disabled)")?;
    }
    if let Some(v) = flags.get("max-proto-errors") {
        cfg.server.max_proto_errors = v
            .parse::<u32>()
            .context("--max-proto-errors wants a non-negative integer (0 = never)")?;
    }
    if let Some(v) = flags.get("placement") {
        cfg.engine.placement =
            PlacementKind::parse(v).ok_or_else(|| anyhow!("unknown placement {v:?}"))?;
    }
    if let Some(v) = flags.get("prefilter") {
        cfg.coordinator.prefilter = PrefilterMode::parse(v)
            .ok_or_else(|| anyhow!("--prefilter wants host, device or off, got {v:?}"))?;
    }
    if let Some(v) = flags.get("device-merge") {
        cfg.coordinator.device_merge = v
            .parse::<bool>()
            .map_err(|_| anyhow!("--device-merge wants true or false, got {v:?}"))?;
    }
    if let Some(v) = flags.get("http-port") {
        cfg.gateway.port = v.parse::<u16>().context("--http-port wants a port (0..=65535)")?;
        cfg.gateway.enabled = true;
    }
    if let Some(v) = flags.get("store-dir") {
        cfg.store.dir = (!v.is_empty()).then(|| PathBuf::from(v));
    }
    warn_if_exec_mode_noop(exec_mode, cfg.coordinator.backend, cfg.coordinator.self_check);

    let store: Option<Arc<dyn SnapshotStore>> = match &cfg.store.dir {
        None => None,
        Some(dir) => Some(Arc::new(
            FsStore::open(dir).with_context(|| format!("opening store {}", dir.display()))?,
        )),
    };
    let engine = Arc::new(
        Engine::start(EngineConfig {
            shards: cfg.engine.shards,
            max_queued: cfg.engine.max_queued,
            coordinator: cfg.coordinator.clone(),
            stream: cfg.stream.clone(),
            placement: cfg.engine.placement,
            store,
        })
        .map_err(|e| anyhow!(e))?,
    );
    if engine.merge_threshold() < cfg.stream.merge_threshold {
        eprintln!(
            "warning: merge_threshold {} exceeds the {} backend's request cap; clamped to {}",
            cfg.stream.merge_threshold,
            engine.backend_name(),
            engine.merge_threshold()
        );
    }
    let handle = server::serve_engine(engine.clone(), &cfg.server)?;
    // both listeners front the same Engine: the gateway handle must
    // outlive the serve loop, so bind it before blocking
    let _gw_handle = if cfg.gateway.enabled {
        let host = cfg.server.addr.rsplit_once(':').map(|(h, _)| h).unwrap_or("127.0.0.1");
        let gw_cfg = gateway::GatewayConfig {
            addr: format!("{host}:{}", cfg.gateway.port),
            io_threads: cfg.server.io_threads,
            request_timeout_ms: cfg.server.request_timeout_ms,
            max_body_bytes: cfg.gateway.max_body_bytes,
            page_limit: cfg.gateway.page_limit,
        };
        let gw = gateway::serve_gateway(engine.clone(), &gw_cfg)?;
        println!("gateway on {} (page_limit={})", gw.local_addr(), cfg.gateway.page_limit);
        Some(gw)
    } else {
        None
    };
    println!(
        "serving on {} backend={} shards={} placement={} workers/shard={} max_sessions={} \
         merge_threshold={} store={} (Ctrl-C to stop)",
        handle.local_addr,
        engine.backend_name(),
        engine.shard_count(),
        engine.placement_kind().name(),
        engine.workers_per_shard(),
        engine.max_sessions(),
        engine.merge_threshold(),
        cfg.store.dir.as_deref().map(|d| d.display().to_string()).unwrap_or_else(|| "off".into()),
    );
    // block forever
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_client(args: &[String]) -> Result<()> {
    let (pos, flags) = parse_flags(args)?;
    let addr = flags.get("addr").ok_or_else(|| anyhow!("--addr required"))?;
    let file = pos.first().ok_or_else(|| anyhow!("client needs a points file"))?;
    let points = read_points_file(file)?;
    // the server auto-detects per connection, so "auto" just means "let
    // the client pick": the compact binary framing
    let proto = match flags.get("proto").map(String::as_str) {
        None | Some("text") => server::WireProto::Text,
        Some("binary") | Some("auto") => server::WireProto::Binary,
        Some(other) => bail!("unknown protocol {other:?} (want text, binary or auto)"),
    };
    let tmo_ms: Option<u32> = flags
        .get("tmo")
        .map(|s| s.parse().context("--tmo wants a millisecond budget"))
        .transpose()?;
    let retries: u32 = flags
        .get("connect-retries")
        .map(|s| s.parse().context("--connect-retries wants a count"))
        .transpose()?
        .unwrap_or(1);
    // connect_with is bounded by DEFAULT_CONNECT_TIMEOUT (and
    // connect_retry layers jittered backoff on top), so an unresponsive
    // host fails fast instead of parking the client forever
    let mut client = server::HullClient::connect_retry(
        addr.as_str(),
        proto,
        retries,
        std::time::Duration::from_millis(200),
    )?;
    let hull = client.hull_deadline(&points, tmo_ms)?;
    println!(
        "# backend={} queue_ns={} exec_ns={}",
        hull.backend, hull.queue_ns, hull.exec_ns
    );
    println!("# upper hood");
    write_points(&mut std::io::stdout(), &hull.upper)?;
    println!("# lower hood");
    write_points(&mut std::io::stdout(), &hull.lower)?;
    println!("# stats: {}", client.stats()?);
    Ok(())
}

fn cmd_occupancy(args: &[String]) -> Result<()> {
    let (_, flags) = parse_flags(args)?;
    let n: usize = flags.get("n").ok_or_else(|| anyhow!("--n required"))?.parse()?;
    let dist = Distribution::parse(flags.get("dist").map(String::as_str).unwrap_or("uniform"))
        .ok_or_else(|| anyhow!("unknown distribution"))?;
    let seed: u64 = flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(1);
    let slots = n.next_power_of_two().max(4);
    let pts = generate(dist, n, seed);
    println!(
        "# thread allocation (paper Fig. 2): n={n} slots={slots} dist={}",
        dist.name()
    );
    print!("{}", format_table(&occupancy_table(&pts, slots)));
    Ok(())
}

fn cmd_artifacts(args: &[String]) -> Result<()> {
    let (_, flags) = parse_flags(args)?;
    let dir = flags.get("dir").cloned().unwrap_or_else(|| "artifacts".into());
    let reg = ArtifactRegistry::load(&dir)?;
    println!("{:<18} {:>6} {:>6} {:>8} {:>12}", "artifact", "n", "batch", "outputs", "bytes");
    for meta in reg.iter() {
        let bytes = std::fs::metadata(&meta.path).map(|m| m.len()).unwrap_or(0);
        println!(
            "{:<18} {:>6} {:>6} {:>8} {:>12}",
            meta.name, meta.n, meta.batch, meta.outputs, bytes
        );
    }
    println!("size classes: {:?}", reg.hull_size_classes());
    Ok(())
}
