//! Gift wrapping (Jarvis march) restricted to the upper chain — O(n·h)
//! baseline; the output-sensitive point of comparison for E4.

use crate::geometry::point::Point;
use crate::geometry::predicates::{orient2d, Orientation};

/// Upper hull of x-sorted, distinct-x points by repeated tangent-finding.
pub fn upper_hull(points: &[Point]) -> Vec<Point> {
    let n = points.len();
    if n <= 2 {
        return points.to_vec();
    }
    let mut hull = vec![points[0]];
    let mut cur = 0usize;
    while cur != n - 1 {
        // the next corner is the point all others lie right of (below)
        let mut cand = n - 1;
        for i in (cur + 1)..n - 1 {
            if orient2d(points[cur], points[cand], points[i]) == Orientation::Left {
                cand = i;
            }
        }
        hull.push(points[cand]);
        cur = cand;
    }
    hull
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::generators::{generate, Distribution};
    use crate::serial::monotone_chain;

    #[test]
    fn matches_monotone_chain() {
        for dist in Distribution::ALL {
            let pts = generate(dist, 96, 11);
            assert_eq!(
                upper_hull(&pts),
                monotone_chain::upper_hull(&pts),
                "{}",
                dist.name()
            );
        }
    }

    #[test]
    fn two_points() {
        let pts = vec![Point::new(0.0, 0.0), Point::new(1.0, 1.0)];
        assert_eq!(upper_hull(&pts), pts);
    }
}
