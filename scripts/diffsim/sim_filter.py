#!/usr/bin/env python3
"""Differential simulation of the octagon prefilter (PR 10).

Mirrors, line for line, BOTH implementations of the interior-point
prefilter:

  * the device kernel's branch-free flagging + prefix-sum compaction
    (python/compile/kernels/filter.py: ``octagon_extremes`` /
    ``octagon_keep`` / ``compact``), including the REMOTE-padded block
    layout, first-occurrence argmax tie-breaking, degenerate-edge
    auto-pass and the scalar passthrough guards;
  * the host filter (rust/src/coordinator/request.rs::octagon_filter):
    one-pass strict-``>`` extremes scan, consecutive + circular corner
    dedup, the "< 3 distinct corners" and consecutive-triple right-turn
    bailouts, and strict-inside retention.

Both are hammered against an EXACT rational oracle (fractions.Fraction):

  P1  hull preservation — the exact strict hull of the kernel's kept set
      equals the exact strict hull of the input;
  P2  same for the host filter's kept set;
  P3  kernel ≡ host — the kernel's keep mask selects exactly the points
      the host filter retains (the bit-identity the rust property tests
      assert through the serving stack);
  P4  block discipline — the kernel output is the kept points, input
      order preserved, left-justified, REMOTE-filled tail;
  P5  boundary safety — a point exactly ON an octagon edge (exact
      orientation 0) is never dropped.

Adversaries lean on the cases float filters get wrong: exact collinear
runs (horizontal / vertical / 45°), duplicate points, directional-key
ties (many points attaining the same extreme), tight clusters, integer
grids, and circle rims — all f32-quantized first, like every request in
the serving path (which is also what makes the f64 determinant sign
exact: differences of f32 values are exact in f64, their products fit in
53 bits, and rounding is monotone).

Why a float determinant can be trusted here but the oracle is still
rational: the oracle pins down STRICTNESS (on-edge vs inside) without
assuming that analysis is right — if it were wrong, P1/P5 would fail.

stdlib only; exits non-zero on the first violation.
"""

import random
import struct
import sys
from fractions import Fraction

PREFILTER_MIN_POINTS = 32
REMOTE = (10.0, 0.0)
LIVE_X_MAX = 1.0


def f32(v):
    """Quantize to f32 — Point::quantize_f32 / the artifact wire type."""
    return struct.unpack("f", struct.pack("f", v))[0]


def qpoint(x, y):
    return (f32(x), f32(y))


# ----------------------------------------------------------- predicates


def det_float(a, b, c):
    """f64 orientation determinant — the kernel's ``_left_of`` operand."""
    return (b[0] - a[0]) * (c[1] - a[1]) - (b[1] - a[1]) * (c[0] - a[0])


def det_exact(a, b, c):
    """Exact rational determinant — the oracle, and the host filter's
    robust ``orient2d`` (whose sign is exact by construction)."""
    ax, ay = Fraction(a[0]), Fraction(a[1])
    return (Fraction(b[0]) - ax) * (Fraction(c[1]) - ay) - (
        Fraction(b[1]) - ay
    ) * (Fraction(c[0]) - ax)


def keys(p):
    """Directional keys, ccw from W — identical list in both impls."""
    x, y = p
    return [-x, -(x + y), -y, x - y, x, x + y, y, -(x - y)]


# ------------------------------------------- kernel transliteration


def kernel_filter_block(block):
    """filter.py's ``filter_block`` over one REMOTE-padded block."""
    n = len(block)
    live = [p[0] <= LIVE_X_MAX for p in block]

    # octagon_extremes: masked argmax, first occurrence wins each tie
    ext = []
    for d in range(8):
        best_i, best_k = None, None
        for i, p in enumerate(block):
            if not live[i]:
                continue  # keys -> -inf: REMOTE slots never win
            k = keys(p)[d]
            if best_k is None or k > best_k:
                best_i, best_k = i, k
        # an all-REMOTE block never reaches the filter in serving; keep
        # the sim total by treating it as passthrough
        if best_i is None:
            return list(block)
        ext.append(block[best_i])

    nxt = ext[1:] + ext[:1]
    same = [ext[i] == nxt[i] for i in range(8)]
    n_distinct = sum(1 for s in same if not s)
    any_right = any(
        not same[i] and det_float(ext[i], nxt[i], ext[j]) < 0
        for i in range(8)
        for j in range(8)
    )
    passthrough = (
        sum(live) < PREFILTER_MIN_POINTS or n_distinct < 3 or any_right
    )

    keep = []
    for i, p in enumerate(block):
        inside = all(
            same[e] or det_float(ext[e], nxt[e], p) > 0 for e in range(8)
        )
        keep.append(live[i] and (passthrough or not inside))

    # compact: prefix-sum scatter, REMOTE tail
    out = [REMOTE] * n
    pos = 0
    for i, p in enumerate(block):
        if keep[i]:
            out[pos] = p
            pos += 1
    return out


# --------------------------------------------- host transliteration


def host_filter(pts):
    """request.rs ``octagon_filter``: returns the retained list."""
    if len(pts) < PREFILTER_MIN_POINTS:
        return list(pts)
    best = [pts[0]] * 8
    best_k = keys(pts[0])
    for p in pts[1:]:
        k = keys(p)
        for d in range(8):
            if k[d] > best_k[d]:
                best_k[d] = k[d]
                best[d] = p
    octagon = []
    for b in best:
        if not octagon or octagon[-1] != b:
            octagon.append(b)
    while len(octagon) > 1 and octagon[0] == octagon[-1]:
        octagon.pop()
    if len(octagon) < 3:
        return list(pts)
    m = len(octagon)
    for i in range(m):
        a, b, c = octagon[i], octagon[(i + 1) % m], octagon[(i + 2) % m]
        if det_exact(a, b, c) < 0:
            return list(pts)

    def strictly_inside(p):
        return all(
            det_exact(octagon[i], octagon[(i + 1) % m], p) > 0
            for i in range(m)
        )

    return [p for p in pts if not strictly_inside(p)]


# --------------------------------------------------------- exact oracle


def exact_hull(pts):
    """Strict full hull (upper ⊕ lower vertex cycle) in exact rationals."""
    uniq = sorted(set(pts))
    if len(uniq) <= 2:
        return uniq

    def chain(points):
        out = []
        for p in points:
            while len(out) >= 2 and det_exact(out[-2], out[-1], p) <= 0:
                out.pop()
            out.append(p)
        return out

    upper = chain(list(reversed(uniq)))
    lower = chain(uniq)
    return lower[:-1] + upper[:-1]


def on_any_octagon_edge(p, pts):
    """Exact test: p lies ON an edge of the (exact-extreme) octagon."""
    ext = []
    for d in range(8):
        best = max(pts, key=lambda q: (keys(q)[d],))
        # first occurrence of the exact max, matching both impls
        for q in pts:
            if keys(q)[d] == keys(best)[d]:
                ext.append(q)
                break
    poly = [v for i, v in enumerate(ext) if v != ext[(i + 1) % 8]]
    if len(poly) < 3:
        return False
    m = len(poly)
    for i in range(m):
        a, b = poly[i], poly[(i + 1) % m]
        if det_exact(a, b, p) == 0:
            lo_x, hi_x = min(a[0], b[0]), max(a[0], b[0])
            lo_y, hi_y = min(a[1], b[1]), max(a[1], b[1])
            if lo_x <= p[0] <= hi_x and lo_y <= p[1] <= hi_y:
                return True
    return False


# ------------------------------------------------------------ adversaries


def gen_cases():
    rng = random.Random(0xF117E5)
    cases = []

    def disk(n, seed):
        r = random.Random(seed)
        pts = []
        while len(pts) < n:
            x, y = r.uniform(-1, 1), r.uniform(-1, 1)
            if x * x + y * y <= 1.0:
                pts.append(qpoint(0.5 + 0.5 * x, 0.5 + 0.5 * y))
        return pts

    # dense disks: the compaction-ratio workhorse
    for n in (64, 512, 4096):
        cases.append(("disk%d" % n, disk(n, n)))

    # collinear runs (exact on the f32 grid): horizontal, vertical, 45°
    xs = [i / 64 for i in range(40)]
    cases.append(("hline", [qpoint(x, 0.25) for x in xs]))
    cases.append(("vline", [qpoint(0.25, x) for x in xs]))
    cases.append(("diag", [qpoint(x, x) for x in xs]))
    cases.append(
        ("diag_dup", [qpoint(x, x) for x in xs] + [qpoint(xs[3], xs[3])] * 5)
    )

    # duplicate-key adversary: a whole face of the octagon tied on x+y
    # (every point of the NE face attains the same max), plus interior
    ne_face = [qpoint(i / 32, 1.0 - i / 32) for i in range(8, 25)]
    inner = disk(40, 77)
    cases.append(("tied_ne_face", sorted(ne_face + inner)))

    # square rim with collinear edge points (boundary-kept adversary)
    rim = (
        [qpoint(i / 16, 0.0) for i in range(17)]
        + [qpoint(i / 16, 1.0) for i in range(17)]
        + [qpoint(0.0, i / 16) for i in range(1, 16)]
        + [qpoint(1.0, i / 16) for i in range(1, 16)]
    )
    cases.append(("square_rim", sorted(rim + disk(30, 5))))

    # tight clusters (pathological ties after f32 quantization)
    clusters = []
    for _ in range(8):
        cx, cy = rng.random(), rng.random()
        for _ in range(16):
            clusters.append(
                qpoint(cx + rng.uniform(-1e-4, 1e-4), cy + rng.uniform(-1e-4, 1e-4))
            )
    cases.append(("clusters", sorted(clusters)))

    # integer grid: everything collinear with everything
    grid = [qpoint(i / 8, j / 8) for i in range(9) for j in range(9)]
    cases.append(("grid", grid))

    # circle rim: every point is a hull vertex — the filter must drop 0
    circ = []
    r = random.Random(9)
    import math

    for k in range(128):
        t = 2 * math.pi * k / 128
        circ.append(qpoint(0.5 + 0.5 * math.cos(t), 0.5 + 0.5 * math.sin(t)))
    cases.append(("circle", sorted(set(circ))))

    # below the gate: filters must be the identity
    cases.append(("tiny", disk(PREFILTER_MIN_POINTS - 1, 3)))

    # random smalls with duplicates
    for s in range(10):
        base = disk(48, 100 + s)
        dups = [base[i % len(base)] for i in range(12)]
        cases.append(("dup%d" % s, sorted(base + dups)))

    return cases


def pad_block(pts):
    n = 1
    while n < max(len(pts), 2):
        n *= 2
    return list(pts) + [REMOTE] * (n - len(pts))


def live_prefix(block):
    out = []
    for p in block:
        if p[0] > LIVE_X_MAX:
            break
        out.append(p)
    return out


def fail(case, prop, msg):
    print("FAIL [%s] %s: %s" % (case, prop, msg))
    sys.exit(1)


def main():
    checks = 0
    for name, pts in gen_cases():
        pts = sorted(pts)  # the serving path x-sorts before filtering
        block = pad_block(pts)
        out_block = kernel_filter_block(block)
        kernel_kept = live_prefix(out_block)
        host_kept = host_filter(pts)

        hull_in = exact_hull(pts)
        if exact_hull(kernel_kept) != hull_in:
            fail(name, "P1", "kernel filter changed the exact hull")
        if exact_hull(host_kept) != hull_in:
            fail(name, "P2", "host filter changed the exact hull")
        if kernel_kept != host_kept:
            fail(
                name,
                "P3",
                "kernel kept %d points, host kept %d — sets differ"
                % (len(kernel_kept), len(host_kept)),
            )
        # P4: survivors left-justified in input order, REMOTE tail
        tail = out_block[len(kernel_kept):]
        if any(p != REMOTE for p in tail):
            fail(name, "P4", "tail not REMOTE-filled")
        it = iter(pts)
        for p in kernel_kept:
            for q in it:
                if q == p:
                    break
            else:
                fail(name, "P4", "kept points out of input order")
        # P5: points exactly on an octagon edge are never dropped
        if len(pts) >= PREFILTER_MIN_POINTS:
            dropped = set(pts) - set(kernel_kept)
            for p in dropped:
                if on_any_octagon_edge(p, pts):
                    fail(name, "P5", "boundary point %r dropped" % (p,))
        checks += 1
        print(
            "ok %-14s n=%-5d kept=%-5d (compaction %.3f)"
            % (
                name,
                len(pts),
                len(kernel_kept),
                len(kernel_kept) / len(pts),
            )
        )
    print("sim_filter: %d cases, all properties hold" % checks)


if __name__ == "__main__":
    main()
