//! E13 — the HTTP edge vs the TCP wire: what does the gateway's
//! HTTP/1.1 framing and JSON encoding cost over the same engine?
//!
//! Two reports:
//!   * E13: one-shot hull round-trips through all four encodings —
//!     TCP text, TCP binary, HTTP JSON, HTTP octet-stream — on one
//!     shared engine, at small and large point counts.  The HTTP
//!     binary row isolates header overhead (same payload bytes as the
//!     TCP binary frame); the JSON rows price float printing/parsing.
//!   * E13b: cursor-paginated session hull reads vs the one-shot form:
//!     page walks re-send headers and re-resolve the epoch-pinned
//!     snapshot per page, so the ratio is the cost of pagination.
//!
//! Run: `cargo bench --bench bench_gateway` (tier1.sh feeds
//! BENCH_gateway.json via WAGENER_BENCH_JSON; WAGENER_BENCH_FAST=1
//! shrinks point counts and the sampling budget).

use std::sync::Arc;
use std::time::Duration;

use wagener_hull::benchkit::{Bencher, Report};
use wagener_hull::coordinator::{BackendKind, BatcherConfig, CoordinatorConfig};
use wagener_hull::engine::{Engine, EngineConfig};
use wagener_hull::gateway::client::HttpClient;
use wagener_hull::gateway::{serve_gateway, GatewayConfig};
use wagener_hull::geometry::generators::{generate, Distribution};
use wagener_hull::geometry::point::Point;
use wagener_hull::server::{serve_engine, HullClient, ServerConfig, WireProto};
use wagener_hull::stream::StreamConfig;

fn start_engine() -> Arc<Engine> {
    Arc::new(
        Engine::start(EngineConfig {
            shards: 1,
            coordinator: CoordinatorConfig {
                backend: BackendKind::Serial,
                batcher: BatcherConfig { max_batch: 4, flush_us: 200, queue_cap: 1024 },
                self_check: false,
                ..Default::default()
            },
            stream: StreamConfig::default(),
            ..Default::default()
        })
        .unwrap(),
    )
}

fn json_body(pts: &[Point]) -> String {
    let pairs: Vec<String> = pts.iter().map(|p| format!("[{},{}]", p.x, p.y)).collect();
    format!("{{\"points\":[{}]}}", pairs.join(","))
}

fn le_body(pts: &[Point]) -> Vec<u8> {
    let mut b = Vec::with_capacity(pts.len() * 16);
    for p in pts {
        b.extend_from_slice(&p.x.to_le_bytes());
        b.extend_from_slice(&p.y.to_le_bytes());
    }
    b
}

fn main() {
    let b = Bencher::default();
    let fast = std::env::var("WAGENER_BENCH_FAST").is_ok();

    let engine = start_engine();
    let tcp = serve_engine(
        engine.clone(),
        &ServerConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
    )
    .unwrap();
    let gw = serve_gateway(
        engine.clone(),
        &GatewayConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
    )
    .unwrap();

    // ------------------------------------------ E13: one-shot encodings
    let sizes: &[usize] = if fast { &[1024] } else { &[4096, 1 << 16] };
    let mut report = Report::new("E13: hull round-trips — HTTP gateway vs TCP wire (one engine)");
    let mut ct = HullClient::connect_with(tcp.local_addr, WireProto::Text).unwrap();
    let mut cb = HullClient::connect_with(tcp.local_addr, WireProto::Binary).unwrap();
    ct.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    cb.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut hc = HttpClient::connect(gw.local_addr()).unwrap();

    for &n in sizes {
        let pts = generate(Distribution::Disk, n, 42);
        let json = json_body(&pts);
        let bin = le_body(&pts);
        report.add(b.run(&format!("hull_n{n}/tcp_text"), || ct.hull(&pts).unwrap().upper.len()));
        report.add(b.run(&format!("hull_n{n}/tcp_binary"), || cb.hull(&pts).unwrap().upper.len()));
        report.add(b.run(&format!("hull_n{n}/http_json"), || {
            let r = hc.post_json("/v1/hull", &json).unwrap();
            assert_eq!(r.status, 200);
            r.body.len()
        }));
        report.add(b.run(&format!("hull_n{n}/http_binary"), || {
            let r = hc.post_bytes("/v1/hull", &bin).unwrap();
            assert_eq!(r.status, 200);
            r.body.len()
        }));
        report.note(format!(
            "n={n}: {} JSON request bytes vs {} octet-stream bytes",
            json.len(),
            bin.len()
        ));
    }
    report.finish();

    // -------------------------------- E13b: paginated vs one-shot reads
    let hull_n = if fast { 512usize } else { 4096 };
    let limit = 512usize;
    let mut report = Report::new(&format!(
        "E13b: session hull reads — one-shot vs cursor pages (circle n={hull_n}, limit={limit})"
    ));
    // circle input: every point is a hull vertex, so the paginated walk
    // really does stream hull_n points through the cursor machinery
    let sid_resp = hc.post_json("/v1/sessions", "").unwrap();
    let sid = sid_resp.json().get("sid").and_then(|v| v.as_f64()).unwrap() as u64;
    let pts = generate(Distribution::Circle, hull_n, 9);
    let r = hc.post_bytes(&format!("/v1/sessions/{sid}/points"), &le_body(&pts)).unwrap();
    assert_eq!(r.status, 200);
    // settle the pending buffer so every read serves the same epoch
    let warm = hc.get(&format!("/v1/sessions/{sid}/hull?limit=1")).unwrap();
    let epoch = warm.json().get("epoch").and_then(|v| v.as_f64()).unwrap() as u64;

    report.add(b.run("read/tcp_one_shot", || ct.session_hull(sid).unwrap().upper.len()));
    report.add(b.run("read/http_one_shot", || {
        let r = hc
            .get(&format!("/v1/sessions/{sid}/hull?epoch={epoch}&limit={hull_n}"))
            .unwrap();
        assert_eq!(r.status, 200);
        r.body.len()
    }));
    report.add(b.run("read/http_paginated", || {
        let mut target = format!("/v1/sessions/{sid}/hull?epoch={epoch}&limit={limit}");
        let (mut pages, mut bytes) = (0usize, 0usize);
        loop {
            let r = hc.get(&target).unwrap();
            assert_eq!(r.status, 200);
            bytes += r.body.len();
            pages += 1;
            let j = r.json();
            match j.get("next_cursor") {
                Some(wagener_hull::util::json::Json::Str(c)) => {
                    target = format!("/v1/sessions/{sid}/hull?cursor={c}&limit={limit}");
                }
                _ => break,
            }
        }
        (pages, bytes)
    }));
    report.note(format!(
        "paginated walk: {} pages of ≤{limit} points each",
        (hull_n + 2).div_ceil(limit)
    ));
    report.finish();

    drop((ct, cb, hc));
    gw.stop();
    tcp.stop();
}
