//! The coordinator: request intake, routing, thread topology, lifecycle.
//!
//! Thread layout (all std threads; this environment vendors no async
//! runtime, and the workload is CPU-bound — see DESIGN.md §Substitutions):
//!
//! ```text
//! callers ──submit()──► [batcher thread] ──batches──► [exec pool: N workers]
//!    ▲  (prepare +              │  size-class queues        │ each worker owns
//!    │   prefilter +            ▼  deadline flushing        ▼ its OWN backend
//!    │   degenerate      bounded channel, shared      replies + metrics
//!    │   fast path)      by all workers (Mutex<Receiver>)
//!    └──────────────────────── per-request reply channel ◄──┘
//! ```
//!
//! The pool is the host-side analogue of multi-SM dispatch: size classes
//! execute concurrently instead of head-of-line blocking behind one
//! thread, and each worker constructs its own backend *on* its thread
//! (PJRT handles are `!Send`, so backends can never migrate).

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::backend::{exact_full_hull, BackendKind, HullBackend};
use super::batcher::{reap_expired, run_batcher, BatchMsg, BatcherConfig, Item};
use super::metrics::{Metrics, MetricsSnapshot};
use super::request::{octagon_filter, prepare, HullReply, HullRequest, HullResponse, RequestError};
use crate::fault::{FaultAction, FaultPlan};
use crate::geometry::hull_check::check_upper_hull;
use crate::geometry::point::Point;
use crate::pram::ExecMode;
use crate::wagener::hull_merge::TangentKernel;

/// Where the octagon interior-point prefilter runs.
///
/// `Host` keeps the exact robust-predicate filter on the submit path
/// (`prepare()`), pre-PR 10 behaviour.  `Device` moves it onto the exec
/// worker's accelerator (the `filter_n*` Pallas artifacts) with silent
/// per-request host fallback — non-pjrt backends, tiny inputs, size-class
/// misses, and device failures all land on the host filter, so the served
/// hull is bit-identical in every mode.  `Off` disables prefiltering.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrefilterMode {
    Host,
    Device,
    Off,
}

impl PrefilterMode {
    pub fn parse(s: &str) -> Option<PrefilterMode> {
        Some(match s {
            "host" => PrefilterMode::Host,
            "device" => PrefilterMode::Device,
            "off" => PrefilterMode::Off,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            PrefilterMode::Host => "host",
            PrefilterMode::Device => "device",
            PrefilterMode::Off => "off",
        }
    }
}

/// Coordinator configuration (see config.rs for the TOML form).
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub backend: BackendKind,
    pub artifacts_dir: PathBuf,
    pub batcher: BatcherConfig,
    /// verify every response against the hull checker, and (pjrt backend)
    /// cross-check PJRT results against the PRAM engine on `exec_mode`
    /// (paranoia mode; divergences land in `RuntimeStats::ref_mismatches`).
    pub self_check: bool,
    /// compile all hull artifacts at startup (pjrt backend only).
    pub preload: bool,
    /// PRAM engine tier for the `pram` backend: the serving path defaults
    /// to `Fast`; `Audited` keeps the CREW/bank-model instrument live.
    pub exec_mode: ExecMode,
    /// exec worker threads, each owning its own backend instance
    /// (0 = one per available hardware thread).
    pub workers: usize,
    /// octagon interior-point pre-filter: large dense inputs shrink
    /// before they reach a hull pipeline (hull-preserving — dropped
    /// points land in the `filtered_points_{host,device}` metrics).
    /// See [`PrefilterMode`] for where it runs.
    pub prefilter: PrefilterMode,
    /// route streaming-session hull ⊕ hull merges through the device
    /// tangent kernel when the backend has one (`pjrt` with `tangent_n*`
    /// artifacts).  Host merges are used whenever the device declines;
    /// results are bit-identical either way.
    pub device_merge: bool,
    /// circuit-breaker cooldown: after repeated consecutive backend
    /// failures the breaker opens and the router stops feeding this
    /// coordinator; the first routing probe after the cooldown half-opens
    /// it.  `0` disables the breaker entirely.
    pub breaker_cooldown_ms: u64,
    /// deterministic fault schedule injected into every exec worker's
    /// dispatch (chaos tests only; `None` in production).
    pub fault_plan: Option<Arc<FaultPlan>>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            backend: BackendKind::Native,
            artifacts_dir: PathBuf::from("artifacts"),
            batcher: BatcherConfig::default(),
            self_check: false,
            preload: false,
            exec_mode: ExecMode::Fast,
            workers: 0,
            prefilter: PrefilterMode::Host,
            device_merge: true,
            breaker_cooldown_ms: 1000,
            fault_plan: None,
        }
    }
}

/// Resolve a `workers` config value (0 = auto).  Auto means one worker
/// per hardware thread for host backends, but a single worker for
/// `pjrt`: every pjrt worker loads the artifact registry and (under
/// `preload`) compiles each artifact, so multiplying executors by core
/// count must be an explicit choice, never a default.
fn effective_workers(cfg: &CoordinatorConfig) -> usize {
    if cfg.workers > 0 {
        cfg.workers
    } else if cfg.backend == BackendKind::Pjrt {
        1
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// Consecutive batch failures before the breaker trips open.
const BREAKER_TRIP: u32 = 3;

/// Per-coordinator circuit breaker.  Exec workers report every batch
/// outcome; the engine's router asks [`Breaker::blocked`] before feeding
/// this shard.  Closed → (BREAKER_TRIP consecutive failures) → open →
/// (cooldown elapses, first router probe) → half-open → one success
/// closes it again, one failure re-opens it.  The current mode is
/// exported as the `breaker_state` gauge (0 closed, 1 open, 2 half-open).
#[derive(Debug)]
pub struct Breaker {
    /// zero = breaker disabled (never blocks, never trips).
    cooldown: Duration,
    state: Mutex<BreakerState>,
    metrics: Arc<Metrics>,
}

#[derive(Debug)]
struct BreakerState {
    mode: u8, // 0 closed, 1 open, 2 half-open
    consecutive: u32,
    opened_at: Option<Instant>,
}

impl Breaker {
    fn new(cooldown_ms: u64, metrics: Arc<Metrics>) -> Breaker {
        Breaker {
            cooldown: Duration::from_millis(cooldown_ms),
            state: Mutex::new(BreakerState { mode: 0, consecutive: 0, opened_at: None }),
            metrics,
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, BreakerState> {
        self.state.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    fn set_mode(&self, st: &mut BreakerState, mode: u8) {
        st.mode = mode;
        self.metrics.breaker_state.store(mode as u64, Ordering::Relaxed);
    }

    /// A batch dispatched cleanly: reset the failure streak; a half-open
    /// probe succeeding closes the breaker.
    pub fn on_success(&self) {
        if self.cooldown.is_zero() {
            return;
        }
        let mut st = self.lock();
        st.consecutive = 0;
        if st.mode != 0 {
            self.set_mode(&mut st, 0);
            st.opened_at = None;
        }
    }

    /// A batch failed (backend error or contained panic).  Trips open on
    /// the BREAKER_TRIP-th consecutive failure; a half-open probe failing
    /// re-opens; failures while already open re-stamp the cooldown.
    pub fn on_failure(&self) {
        if self.cooldown.is_zero() {
            return;
        }
        let mut st = self.lock();
        st.consecutive = st.consecutive.saturating_add(1);
        match st.mode {
            0 if st.consecutive >= BREAKER_TRIP => {
                self.set_mode(&mut st, 1);
                st.opened_at = Some(Instant::now());
            }
            1 | 2 => {
                self.set_mode(&mut st, 1);
                st.opened_at = Some(Instant::now());
            }
            _ => {}
        }
    }

    /// Should the router keep new work away from this coordinator?
    /// While open, the first call after the cooldown flips to half-open
    /// and answers `false` — that caller's request becomes the probe.
    pub fn blocked(&self) -> bool {
        if self.cooldown.is_zero() {
            return false;
        }
        let mut st = self.lock();
        match st.mode {
            0 => false,
            2 => true, // probe already in flight; wait for its verdict
            _ => match st.opened_at {
                Some(t) if t.elapsed() < self.cooldown => true,
                _ => {
                    self.set_mode(&mut st, 2);
                    false
                }
            },
        }
    }

    /// Current mode (0 closed, 1 open, 2 half-open).
    pub fn state(&self) -> u8 {
        self.lock().mode
    }
}

/// Handle to a running coordinator.
pub struct Coordinator {
    submit_tx: Option<mpsc::SyncSender<Item>>,
    batcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    breaker: Arc<Breaker>,
    backend_name: &'static str,
    max_points: usize,
    worker_count: usize,
    prefilter: PrefilterMode,
    /// device-merge worker intake (None = host merges only).  Bounded so
    /// merge jobs serialize through the single device thread.
    tangent_tx: Option<mpsc::SyncSender<TangentJob>>,
    merge_worker: Option<JoinHandle<()>>,
    next_id: AtomicU64,
}

/// One hull ⊕ hull merge shipped to the device-merge worker: the four
/// chains (lower pair already y-mirrored by `hull_merge::device_merge`)
/// and a reply slot.  `None` back means "use the host path".
struct TangentJob {
    upper: [Vec<Point>; 2],
    lower: [Vec<Point>; 2],
    reply: mpsc::Sender<Option<(Vec<Point>, Vec<Point>)>>,
}

/// The dedicated device-merge thread: PJRT handles are `!Send`, so the
/// tangent executor lives on its own thread and jobs come to it.  Built
/// without preload — tangent artifacts compile on first use, off the
/// serving path's critical startup.  If the backend cannot be built the
/// thread answers `None` forever (sessions silently keep host merges).
fn run_merge_worker(
    cfg: CoordinatorConfig,
    metrics: Arc<Metrics>,
    rx: mpsc::Receiver<TangentJob>,
) {
    let backend = match cfg.backend.build(&cfg.artifacts_dir, false, cfg.exec_mode, false) {
        Ok(b) => b,
        Err(_) => {
            for job in rx {
                let _ = job.reply.send(None);
            }
            return;
        }
    };
    for job in rx {
        let out = backend.device_tangent(
            [job.upper[0].as_slice(), job.upper[1].as_slice()],
            [job.lower[0].as_slice(), job.lower[1].as_slice()],
        );
        if out.is_some() {
            Metrics::inc(&metrics.device_tangent_merges);
        }
        let _ = job.reply.send(out);
    }
}

/// One dispatch attempt: scheduled fault injection (chaos tests) and the
/// backend call, both inside panic containment.  A panic escaping
/// compute would otherwise kill the worker silently (pool one thread
/// smaller forever); contain it to a per-batch error instead.  Host
/// backends are stateless and PJRT's RefCell borrows release on unwind,
/// so the backend stays usable.
fn dispatch_batch(
    backend: &dyn HullBackend,
    items: &[Item],
    width: usize,
    fault: Option<&FaultPlan>,
) -> Result<Vec<(Vec<Point>, Vec<Point>)>, String> {
    let reqs: Vec<&[Point]> = items.iter().map(|i| i.prepared.points.as_slice()).collect();
    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        if let Some(plan) = fault {
            match plan.next() {
                Some(FaultAction::Panic) => panic!("fault-plan: injected panic"),
                Some(FaultAction::Error) => return Err("fault-plan: injected error".into()),
                Some(FaultAction::Delay(d)) => std::thread::sleep(d),
                None => {}
            }
        }
        backend.compute(&reqs, width)
    }))
    .unwrap_or_else(|p| {
        let what = p
            .downcast_ref::<&str>()
            .map(|s| s.to_string())
            .or_else(|| p.downcast_ref::<String>().cloned())
            .unwrap_or_else(|| "unknown panic".into());
        Err(format!("backend panicked: {what}"))
    })
}

/// Answer every item of a successfully dispatched batch.
fn deliver_success(
    items: Vec<Item>,
    hulls: Vec<(Vec<Point>, Vec<Point>)>,
    backend_name: &'static str,
    self_check: bool,
    exec_start: Instant,
    exec_ns: u64,
    metrics: &Metrics,
) {
    for (item, (upper, lower)) in items.into_iter().zip(hulls) {
        let queue_ns = (exec_start - item.enqueued).as_nanos() as u64;
        if self_check {
            if let Err(e) = check_upper_hull(&item.prepared.points, &upper) {
                Metrics::inc(&metrics.errors);
                item.reply
                    .send(Err(RequestError::Backend(format!("self-check failed: {e}"))));
                continue;
            }
        }
        Metrics::inc(&metrics.responses);
        Metrics::add(&metrics.hull_points_out, (upper.len() + lower.len()) as u64);
        metrics.e2e_latency.record(item.enqueued.elapsed());
        metrics.queue_latency.record_ns(queue_ns);
        item.reply.send(Ok(HullResponse {
            id: item.prepared.id,
            upper,
            lower,
            backend: backend_name,
            queue_ns,
            exec_ns,
        }));
    }
}

/// Fail every item of a batch whose retries are exhausted.
fn deliver_failure(items: Vec<Item>, e: &str, metrics: &Metrics) {
    for item in items {
        Metrics::inc(&metrics.errors);
        item.reply.send(Err(RequestError::Backend(e.to_string())));
    }
}

/// Jittered failover backoff, deterministic per batch (keyed on the
/// first request id) so chaos runs reproduce: 1–4 ms.
fn retry_backoff(seed: u64) -> Duration {
    let mut z = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    Duration::from_millis(1 + ((z ^ (z >> 31)) & 3))
}

/// One exec worker: builds its own backend, then pulls batches off the
/// shared channel until the batcher sends its shutdown pill (workers
/// hold retry senders into the same channel, so plain disconnection can
/// never happen while the pool lives).  Holding the receiver lock only
/// while *dequeuing* (never while computing) is what lets size classes
/// execute concurrently across the pool.
fn run_exec_worker(
    cfg: CoordinatorConfig,
    metrics: Arc<Metrics>,
    batch_rx: Arc<Mutex<mpsc::Receiver<BatchMsg>>>,
    retry_tx: mpsc::SyncSender<BatchMsg>,
    breaker: Arc<Breaker>,
    ready_tx: mpsc::Sender<Result<(usize, usize, usize), String>>,
    hw_threads: usize,
    busy: Arc<AtomicUsize>,
) {
    let backend = match cfg.backend.build(
        &cfg.artifacts_dir,
        cfg.preload,
        cfg.exec_mode,
        cfg.self_check,
    ) {
        Ok(b) => {
            let _ = ready_tx.send(Ok((
                b.max_points(),
                b.preferred_batch(),
                b.device_filter_capacity(),
            )));
            b
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };
    drop(ready_tx);

    loop {
        let msg = match batch_rx.lock() {
            Ok(guard) => guard.recv(),
            Err(_) => return, // a sibling worker panicked mid-dequeue
        };
        let Ok(BatchMsg { mut items, attempt }) = msg else { return };
        if items.is_empty() {
            return; // shutdown pill — one per worker, sent by the batcher
        }
        // Deadline gate: never spend a dispatch on requests that expired
        // while queued.
        reap_expired(&mut items, &metrics);
        if items.is_empty() {
            continue;
        }
        // Device prefilter: shrink each request on the accelerator before
        // the hull dispatch.  Per-item host fallback (octagon_filter) keeps
        // the served hull bit-identical when the device declines — tiny
        // inputs, size-class misses, non-pjrt backends, or exec errors.
        // Retried batches (attempt > 0) were already filtered first time.
        if cfg.prefilter == PrefilterMode::Device && attempt == 0 {
            for item in items.iter_mut() {
                let pts = &mut item.prepared.points;
                let before = pts.len();
                match backend.device_filter(pts) {
                    Some(kept) => {
                        Metrics::add(&metrics.device_filter_points_in, before as u64);
                        Metrics::add(
                            &metrics.filtered_points_device,
                            (before - kept.len()) as u64,
                        );
                        item.prepared.filtered = before - kept.len();
                        *pts = kept;
                    }
                    None => {
                        let dropped = octagon_filter(pts);
                        Metrics::add(&metrics.filtered_points_host, dropped as u64);
                        item.prepared.filtered = dropped;
                    }
                }
            }
        }
        // Thread budget for this dispatch: an even share of the machine
        // among the dispatches in flight *right now*.  An idle pool hands
        // one big request full hardware width; a saturated pool converges
        // to 1 per worker — never workers × hw threads.  The count is a
        // heuristic (Relaxed races only soften the split), correctness
        // never depends on it.
        let exec_start = Instant::now();
        let in_flight = busy.fetch_add(1, Ordering::Relaxed) + 1;
        let width = (hw_threads / in_flight).max(1);
        let result = dispatch_batch(&*backend, &items, width, cfg.fault_plan.as_deref());
        busy.fetch_sub(1, Ordering::Relaxed);
        let exec_ns = exec_start.elapsed().as_nanos() as u64;
        Metrics::inc(&metrics.batches);
        Metrics::add(&metrics.batched_requests, items.len() as u64);
        metrics.exec_latency.record_ns(exec_ns);
        match result {
            Ok(hulls) => {
                breaker.on_success();
                deliver_success(
                    items,
                    hulls,
                    backend.name(),
                    cfg.self_check,
                    exec_start,
                    exec_ns,
                    &metrics,
                );
            }
            Err(e) => {
                breaker.on_failure();
                if attempt > 0 {
                    deliver_failure(items, &e, &metrics);
                    continue;
                }
                // Bounded failover: back off briefly (jittered), then
                // re-enqueue the batch once so a different worker — with
                // its own backend instance — picks it up.  try_send keeps
                // this deadlock-free: a full queue (or a draining
                // coordinator) falls back to an inline second attempt on
                // this worker instead of blocking it.
                Metrics::inc(&metrics.retries);
                std::thread::sleep(retry_backoff(items[0].prepared.id));
                let mut items = match retry_tx.try_send(BatchMsg { items, attempt: 1 }) {
                    Ok(()) => continue,
                    Err(mpsc::TrySendError::Full(m))
                    | Err(mpsc::TrySendError::Disconnected(m)) => m.items,
                };
                reap_expired(&mut items, &metrics);
                if items.is_empty() {
                    continue;
                }
                let retry_start = Instant::now();
                let in_flight = busy.fetch_add(1, Ordering::Relaxed) + 1;
                let width = (hw_threads / in_flight).max(1);
                let result =
                    dispatch_batch(&*backend, &items, width, cfg.fault_plan.as_deref());
                busy.fetch_sub(1, Ordering::Relaxed);
                let retry_ns = retry_start.elapsed().as_nanos() as u64;
                Metrics::inc(&metrics.batches);
                Metrics::add(&metrics.batched_requests, items.len() as u64);
                metrics.exec_latency.record_ns(retry_ns);
                match result {
                    Ok(hulls) => {
                        breaker.on_success();
                        deliver_success(
                            items,
                            hulls,
                            backend.name(),
                            cfg.self_check,
                            retry_start,
                            retry_ns,
                            &metrics,
                        );
                    }
                    Err(e) => {
                        breaker.on_failure();
                        deliver_failure(items, &e, &metrics);
                    }
                }
            }
        }
    }
}

impl Coordinator {
    /// Spawn the batcher + the exec worker pool; fails if any backend
    /// cannot be constructed (e.g. missing artifacts for `pjrt`).
    pub fn start(cfg: CoordinatorConfig) -> Result<Coordinator, String> {
        let worker_count = effective_workers(&cfg);
        let metrics = Arc::new(Metrics::default());
        let breaker = Arc::new(Breaker::new(cfg.breaker_cooldown_ms, metrics.clone()));
        let (submit_tx, submit_rx) = mpsc::sync_channel::<Item>(cfg.batcher.queue_cap);
        let (batch_tx, batch_rx) = mpsc::sync_channel::<BatchMsg>(cfg.batcher.queue_cap.max(1));
        let batch_rx = Arc::new(Mutex::new(batch_rx));
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(usize, usize, usize), String>>();

        // Shared gauge of dispatches in flight: each worker sizes its
        // intra-batch / intra-request thread budget as hw / in_flight at
        // dispatch time, so a lone request on an idle pool still gets
        // full hardware width while a saturated pool never books
        // workers × hw transient threads.
        let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let busy = Arc::new(AtomicUsize::new(0));

        let mut workers = Vec::with_capacity(worker_count);
        for w in 0..worker_count {
            let cfg = cfg.clone();
            let metrics = metrics.clone();
            let batch_rx = batch_rx.clone();
            let retry_tx = batch_tx.clone();
            let breaker = breaker.clone();
            let ready_tx = ready_tx.clone();
            let busy = busy.clone();
            let handle = std::thread::Builder::new()
                .name(format!("hull-exec-{w}"))
                .spawn(move || {
                    run_exec_worker(cfg, metrics, batch_rx, retry_tx, breaker, ready_tx, hw, busy)
                })
                .map_err(|e| e.to_string())?;
            workers.push(handle);
        }
        drop(ready_tx);

        // wait for every backend construction before declaring ready
        let mut max_points = usize::MAX;
        let mut pref_batch = 1usize;
        let mut filter_cap = usize::MAX;
        let mut ready_ok = 0usize;
        let mut failure: Option<String> = None;
        for _ in 0..worker_count {
            match ready_rx.recv() {
                Ok(Ok((mp, pb, fc))) => {
                    max_points = max_points.min(mp);
                    pref_batch = pref_batch.max(pb);
                    filter_cap = filter_cap.min(fc);
                    ready_ok += 1;
                }
                Ok(Err(e)) => failure = Some(e),
                Err(_) => {
                    failure.get_or_insert_with(|| "exec worker died during startup".to_string());
                    break;
                }
            }
        }
        if let Some(e) = failure {
            // workers hold retry senders, so dropping batch_tx alone can
            // no longer disconnect the channel: send each surviving
            // worker (exactly the ready_ok that built a backend) its
            // shutdown pill, then join everyone.
            for _ in 0..ready_ok {
                let _ = batch_tx.send(BatchMsg { items: Vec::new(), attempt: 0 });
            }
            drop(batch_tx);
            for h in workers {
                let _ = h.join();
            }
            return Err(e);
        }

        // In Device mode the prefilter runs *before* the hull dispatch, so
        // admission can accept anything the filter artifacts can shrink —
        // the hull size cap applies to the post-filter point count.
        if cfg.prefilter == PrefilterMode::Device && filter_cap != usize::MAX {
            max_points = max_points.max(filter_cap);
        }

        // Device-merge worker: one thread owning its own backend (PJRT
        // handles are `!Send`), fed through a bounded job channel.  Only
        // worth spawning when tangent artifacts can exist at all.
        let (tangent_tx, merge_worker) =
            if cfg.backend == BackendKind::Pjrt && cfg.device_merge {
                let (tx, rx) = mpsc::sync_channel::<TangentJob>(1);
                let cfg = cfg.clone();
                let metrics = metrics.clone();
                let handle = std::thread::Builder::new()
                    .name("hull-merge-dev".into())
                    .spawn(move || run_merge_worker(cfg, metrics, rx))
                    .map_err(|e| e.to_string())?;
                (Some(tx), Some(handle))
            } else {
                (None, None)
            };

        let max_batch = if cfg.batcher.max_batch == 0 {
            pref_batch.max(1)
        } else {
            cfg.batcher.max_batch
        };
        let flush_us = cfg.batcher.flush_us;
        let batcher_metrics = metrics.clone();
        let batcher = std::thread::Builder::new()
            .name("hull-batcher".into())
            .spawn(move || {
                run_batcher(submit_rx, batch_tx, max_batch, flush_us, worker_count, batcher_metrics)
            })
            .map_err(|e| e.to_string())?;

        Ok(Coordinator {
            submit_tx: Some(submit_tx),
            batcher: Some(batcher),
            workers,
            metrics,
            breaker,
            backend_name: cfg.backend.name(),
            max_points,
            worker_count,
            prefilter: cfg.prefilter,
            tangent_tx,
            merge_worker,
            next_id: AtomicU64::new(1),
        })
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend_name
    }

    pub fn max_points(&self) -> usize {
        self.max_points
    }

    /// Number of exec workers in the pool.
    pub fn workers(&self) -> usize {
        self.worker_count
    }

    /// This coordinator's circuit breaker (the engine router consults it
    /// before feeding the shard; chaos tests observe its mode).
    pub fn breaker(&self) -> &Breaker {
        &self.breaker
    }

    /// Allocate a request id (for callers that don't track their own).
    pub fn next_id(&self) -> u64 {
        self.next_id.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
    }

    /// Submit asynchronously; the returned channel yields the response.
    pub fn submit(
        &self,
        req: HullRequest,
    ) -> mpsc::Receiver<Result<HullResponse, RequestError>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.submit_with(req, HullReply::Channel(reply_tx));
        reply_rx
    }

    /// Submit with an arbitrary reply destination — the non-blocking
    /// entry for the event-loop server.  A [`HullReply::Sink`] closure
    /// runs on whichever thread finishes the request: this one for early
    /// rejections and the degenerate fast path, an exec worker's after a
    /// batched dispatch.
    pub fn submit_with(&self, req: HullRequest, reply: HullReply) {
        Metrics::inc(&self.metrics.requests);
        Metrics::add(&self.metrics.points_in, req.points.len() as u64);

        let prepared = match prepare(&req, self.prefilter == PrefilterMode::Host) {
            Ok(p) => p,
            Err(e) => {
                Metrics::inc(&self.metrics.errors);
                reply.send(Err(e));
                return;
            }
        };
        if prepared.points.len() > self.max_points {
            Metrics::inc(&self.metrics.errors);
            reply.send(Err(RequestError::TooLarge {
                points: prepared.points.len(),
                max: self.max_points,
            }));
            return;
        }
        // recorded only for requests that will actually be served, so the
        // gauge tracks real filter savings (not work thrown away by a
        // TooLarge rejection)
        Metrics::add(&self.metrics.filtered_points_host, prepared.filtered as u64);
        if prepared.degenerate {
            // exact fast path: general position violated; compute inline.
            // All three latency histograms are recorded, matching the
            // batched path (queue time is genuinely zero here).
            let t0 = Instant::now();
            let (upper, lower) = exact_full_hull(&prepared.points);
            Metrics::inc(&self.metrics.degenerate_fallbacks);
            Metrics::inc(&self.metrics.responses);
            Metrics::add(
                &self.metrics.hull_points_out,
                (upper.len() + lower.len()) as u64,
            );
            let exec_ns = t0.elapsed().as_nanos() as u64;
            self.metrics.exec_latency.record_ns(exec_ns);
            self.metrics.queue_latency.record_ns(0);
            self.metrics.e2e_latency.record_ns(exec_ns);
            reply.send(Ok(HullResponse {
                id: prepared.id,
                upper,
                lower,
                backend: "exact",
                queue_ns: 0,
                exec_ns,
            }));
            return;
        }

        match &self.submit_tx {
            Some(tx) => {
                let item = Item { prepared, enqueued: Instant::now(), reply };
                // a refused send hands the item (and its reply) back
                if let Err(mpsc::SendError(item)) = tx.send(item) {
                    Metrics::inc(&self.metrics.errors);
                    item.reply.send(Err(RequestError::Shutdown));
                }
            }
            None => reply.send(Err(RequestError::Shutdown)),
        }
    }

    /// Synchronous convenience wrapper.
    pub fn compute(&self, points: Vec<Point>) -> Result<HullResponse, RequestError> {
        let req = HullRequest::new(self.next_id(), points);
        self.submit(req)
            .recv()
            .map_err(|_| RequestError::Shutdown)?
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// The device tangent kernel for streaming-session merges, when this
    /// coordinator runs one (`pjrt` backend with `device_merge` on).
    /// `None` keeps sessions on the host merge path.
    pub fn device_merge_kernel(&self) -> Option<&dyn TangentKernel> {
        self.tangent_tx.as_ref().map(|_| self as &dyn TangentKernel)
    }

    /// Graceful shutdown: drain queues, join every worker.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.submit_tx.take(); // closes the batcher's input
        if let Some(h) = self.batcher.take() {
            let _ = h.join(); // batcher drains its queues, then drops batch_tx
        }
        for h in self.workers.drain(..) {
            let _ = h.join(); // each worker drains the shared channel dry
        }
        self.tangent_tx.take(); // closes the merge worker's job intake
        if let Some(h) = self.merge_worker.take() {
            let _ = h.join();
        }
    }
}

/// Sessions call merges from arbitrary threads; the job channel proxies
/// each one to the `hull-merge-dev` thread that owns the PJRT executor.
/// Any channel hiccup (shutdown race, worker death) degrades to `None`,
/// which `merge_hulls_with` treats as "host merge".
impl TangentKernel for Coordinator {
    fn tangent_merge(
        &self,
        upper: [&[Point]; 2],
        lower: [&[Point]; 2],
    ) -> Option<(Vec<Point>, Vec<Point>)> {
        let tx = self.tangent_tx.as_ref()?;
        let (reply_tx, reply_rx) = mpsc::channel();
        tx.send(TangentJob {
            upper: [upper[0].to_vec(), upper[1].to_vec()],
            lower: [lower[0].to_vec(), lower[1].to_vec()],
            reply: reply_tx,
        })
        .ok()?;
        reply_rx.recv().ok().flatten()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::generators::{generate, Distribution};
    use crate::serial::monotone_chain;
    use std::time::Duration;

    fn coord(kind: BackendKind) -> Coordinator {
        Coordinator::start(CoordinatorConfig {
            backend: kind,
            batcher: BatcherConfig { max_batch: 4, flush_us: 200, queue_cap: 64 },
            self_check: true,
            ..Default::default()
        })
        .unwrap()
    }

    fn coord_workers(kind: BackendKind, workers: usize) -> Coordinator {
        Coordinator::start(CoordinatorConfig {
            backend: kind,
            batcher: BatcherConfig { max_batch: 1, flush_us: 100, queue_cap: 256 },
            workers,
            // keep inputs at full size: the head-of-line test needs the
            // big request to actually be big when it reaches the backend
            prefilter: PrefilterMode::Off,
            ..Default::default()
        })
        .unwrap()
    }

    #[test]
    fn native_roundtrip() {
        let c = coord(BackendKind::Native);
        let pts = generate(Distribution::Disk, 100, 1);
        let resp = c.compute(pts.clone()).unwrap();
        let (u, l) = monotone_chain::full_hull(&pts);
        assert_eq!(resp.upper, u);
        assert_eq!(resp.lower, l);
        assert_eq!(resp.backend, "native");
        c.shutdown();
    }

    #[test]
    fn many_concurrent_requests() {
        let c = Arc::new(coord(BackendKind::Native));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                for k in 0..10u64 {
                    let pts =
                        generate(Distribution::ALL[(k % 7) as usize], 20 + k as usize, t * 100 + k);
                    let resp = c.compute(pts.clone()).unwrap();
                    let (u, _) = monotone_chain::full_hull(&pts);
                    assert_eq!(resp.upper, u);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let snap = c.snapshot().0;
        assert_eq!(snap.get("responses").unwrap().as_usize(), Some(40));
        assert_eq!(snap.get("errors").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn pram_backend_serves_on_the_fast_tier_by_default() {
        let c = coord(BackendKind::Pram); // CoordinatorConfig::default => Fast
        let pts = generate(Distribution::Circle, 200, 8);
        let resp = c.compute(pts.clone()).unwrap();
        let (u, l) = monotone_chain::full_hull(&pts);
        assert_eq!(resp.upper, u);
        assert_eq!(resp.lower, l);
        assert_eq!(resp.backend, "pram-fast");
        c.shutdown();
    }

    #[test]
    fn degenerate_goes_exact_and_records_all_latencies() {
        let c = coord(BackendKind::Native);
        let pts = vec![
            Point::new(0.5, 0.1),
            Point::new(0.5, 0.9),
            Point::new(0.1, 0.5),
            Point::new(0.9, 0.5),
        ];
        let resp = c.compute(pts).unwrap();
        assert_eq!(resp.backend, "exact");
        assert_eq!(resp.upper.len(), 3);
        let snap = c.snapshot().0;
        assert_eq!(snap.get("degenerate_fallbacks").unwrap().as_usize(), Some(1));
        // the degenerate fast path must feed every latency histogram
        // (it used to record only e2e, silently undercounting the rest)
        for h in ["e2e_latency", "exec_latency", "queue_latency"] {
            assert_eq!(
                snap.get(h).unwrap().get("count").unwrap().as_usize(),
                Some(1),
                "{h} skipped by the degenerate path"
            );
        }
    }

    #[test]
    fn rejects_invalid() {
        let c = coord(BackendKind::Serial);
        assert!(matches!(c.compute(vec![]), Err(RequestError::Empty)));
        assert!(matches!(
            c.compute(vec![Point::new(7.0, 0.0)]),
            Err(RequestError::OutOfRange(0))
        ));
    }

    #[test]
    fn batching_happens() {
        let c = Arc::new(coord(BackendKind::Native));
        // fire a wave of equal-size requests from multiple threads
        let mut handles = Vec::new();
        for t in 0..8u64 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                let pts = generate(Distribution::UniformSquare, 50, t);
                c.compute(pts).unwrap();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let snap = c.snapshot().0;
        let batches = snap.get("batches").unwrap().as_usize().unwrap();
        assert!(batches < 8, "expected batching, got {batches} batches");
    }

    #[test]
    fn shutdown_then_submit_errors() {
        let mut c = coord(BackendKind::Serial);
        c.shutdown_inner();
        let err = c.compute(generate(Distribution::Disk, 10, 1)).unwrap_err();
        assert_eq!(err, RequestError::Shutdown);
    }

    // ------------------------------------------------------- worker pool

    #[test]
    fn worker_pool_size_resolves() {
        let c = coord_workers(BackendKind::Serial, 3);
        assert_eq!(c.workers(), 3);
        let auto = coord_workers(BackendKind::Serial, 0);
        assert!(auto.workers() >= 1);
    }

    /// N-worker results must be bit-identical to the 1-worker path, on
    /// every host backend (the acceptance parity gate).
    #[test]
    fn n_workers_bit_identical_to_one_worker() {
        for kind in [BackendKind::Native, BackendKind::Serial, BackendKind::Pram] {
            let c1 = coord_workers(kind, 1);
            let c4 = coord_workers(kind, 4);
            let inputs: Vec<Vec<Point>> = (0..12)
                .map(|k| {
                    generate(Distribution::ALL[k % 7], 16 + 37 * (k % 5), 1000 + k as u64)
                })
                .collect();
            for pts in &inputs {
                let a = c1.compute(pts.clone()).unwrap();
                let b = c4.compute(pts.clone()).unwrap();
                assert_eq!(a.upper, b.upper, "{} upper diverged", kind.name());
                assert_eq!(a.lower, b.lower, "{} lower diverged", kind.name());
                assert_eq!(a.backend, b.backend);
            }
            c1.shutdown();
            c4.shutdown();
        }
    }

    /// A small request in its own size class must not queue behind a big
    /// batch when a second worker is idle.
    #[test]
    fn no_head_of_line_blocking_across_size_classes() {
        let big = generate(Distribution::Disk, 1 << 19, 3);
        let small = generate(Distribution::Disk, 64, 4);

        // calibrate: how long does the big request take alone?
        let c = coord_workers(BackendKind::Native, 2);
        let t0 = Instant::now();
        c.compute(big.clone()).unwrap();
        let t_big = t0.elapsed();

        // occupy one worker with the big request, then race the small one
        let big_rx = c.submit(HullRequest::new(c.next_id(), big));
        std::thread::sleep(Duration::from_millis(20)); // let it reach a worker
        let t0 = Instant::now();
        let small_rx = c.submit(HullRequest::new(c.next_id(), small));
        small_rx.recv().unwrap().unwrap();
        let t_small = t0.elapsed();
        big_rx.recv().unwrap().unwrap();

        // only meaningful when the big request is actually slow; on very
        // fast machines the race can't be observed and anything passes
        if t_big > Duration::from_millis(100) {
            assert!(
                t_small < t_big / 2,
                "small request head-of-line blocked: {t_small:?} vs big {t_big:?}"
            );
        }
        c.shutdown();
    }

    /// Shutdown must drain: every in-flight request gets a response, all
    /// workers join, nothing is dropped on the floor.
    #[test]
    fn shutdown_drains_all_workers() {
        let c = coord_workers(BackendKind::Native, 3);
        let mut waits = Vec::new();
        for k in 0..30u64 {
            let pts = generate(Distribution::ALL[(k % 7) as usize], 20 + k as usize, k);
            waits.push(c.submit(HullRequest::new(k + 1, pts)));
        }
        let metrics = c.metrics.clone();
        c.shutdown(); // joins batcher + all workers; queues must drain first
        for w in waits {
            w.recv()
                .expect("reply channel closed without a response")
                .expect("request failed during drain");
        }
        let snap = metrics.snapshot().0;
        assert_eq!(snap.get("responses").unwrap().as_usize(), Some(30));
        assert_eq!(snap.get("errors").unwrap().as_usize(), Some(0));
    }

    // ------------------------------------------------------- robustness

    #[test]
    fn injected_panic_fails_over_to_a_retry_and_succeeds() {
        let c = Coordinator::start(CoordinatorConfig {
            backend: BackendKind::Native,
            batcher: BatcherConfig { max_batch: 1, flush_us: 100, queue_cap: 64 },
            workers: 2,
            // dispatch 0 panics; the failover dispatch (index 1) is clean
            fault_plan: Some(crate::fault::FaultPlan::from_steps(&[(
                0,
                crate::fault::FaultAction::Panic,
            )])),
            ..Default::default()
        })
        .unwrap();
        let pts = generate(Distribution::Disk, 80, 5);
        let resp = c.compute(pts.clone()).unwrap();
        let (u, _) = monotone_chain::full_hull(&pts);
        assert_eq!(resp.upper, u, "failover result must be bit-identical");
        let snap = c.snapshot().0;
        assert_eq!(snap.get("retries_total").unwrap().as_usize(), Some(1));
        assert_eq!(snap.get("responses").unwrap().as_usize(), Some(1));
        assert_eq!(snap.get("errors").unwrap().as_usize(), Some(0));
        c.shutdown();
    }

    #[test]
    fn fault_on_both_attempts_surfaces_backend_error() {
        let c = Coordinator::start(CoordinatorConfig {
            backend: BackendKind::Native,
            batcher: BatcherConfig { max_batch: 1, flush_us: 100, queue_cap: 64 },
            workers: 2,
            fault_plan: Some(crate::fault::FaultPlan::from_steps(&[
                (0, crate::fault::FaultAction::Error),
                (1, crate::fault::FaultAction::Panic),
            ])),
            ..Default::default()
        })
        .unwrap();
        let err = c.compute(generate(Distribution::Disk, 80, 6)).unwrap_err();
        assert!(matches!(err, RequestError::Backend(_)), "got {err:?}");
        let snap = c.snapshot().0;
        assert_eq!(snap.get("retries_total").unwrap().as_usize(), Some(1));
        assert_eq!(snap.get("errors").unwrap().as_usize(), Some(1));
        c.shutdown();
    }

    #[test]
    fn expired_request_answers_deadline_exceeded() {
        let c = coord(BackendKind::Native);
        let pts = generate(Distribution::Disk, 80, 7);
        // deadline already in the past when the batcher dequeues it
        let req = HullRequest::new(1, pts).with_deadline(Some(Instant::now()));
        let err = c.submit(req).recv().unwrap().unwrap_err();
        assert_eq!(err, RequestError::DeadlineExceeded);
        assert_eq!(err.to_string(), "deadline-exceeded");
        let snap = c.snapshot().0;
        assert_eq!(snap.get("deadline_exceeded_total").unwrap().as_usize(), Some(1));
        assert_eq!(snap.get("errors").unwrap().as_usize(), Some(1));
        assert_eq!(snap.get("responses").unwrap().as_usize(), Some(0));
        c.shutdown();
    }

    #[test]
    fn breaker_trips_half_opens_and_recovers() {
        let metrics = Arc::new(Metrics::default());
        let b = Breaker::new(40, metrics.clone());
        assert!(!b.blocked());
        b.on_failure();
        b.on_failure();
        assert!(!b.blocked(), "below the trip threshold");
        b.on_failure(); // third consecutive failure trips it
        assert!(b.blocked());
        assert_eq!(b.state(), 1);
        assert_eq!(metrics.snapshot().0.get("breaker_state").unwrap().as_usize(), Some(1));
        std::thread::sleep(Duration::from_millis(50));
        assert!(!b.blocked(), "cooldown elapsed: first caller is the probe");
        assert_eq!(b.state(), 2, "half-open while the probe is in flight");
        assert!(b.blocked(), "second caller waits for the probe verdict");
        b.on_failure(); // probe failed: re-open
        assert_eq!(b.state(), 1);
        std::thread::sleep(Duration::from_millis(50));
        assert!(!b.blocked());
        b.on_success(); // probe succeeded: close
        assert_eq!(b.state(), 0);
        assert!(!b.blocked());
        assert_eq!(metrics.snapshot().0.get("breaker_state").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn breaker_cooldown_zero_disables() {
        let b = Breaker::new(0, Arc::new(Metrics::default()));
        for _ in 0..10 {
            b.on_failure();
        }
        assert!(!b.blocked(), "disabled breaker never blocks");
        assert_eq!(b.state(), 0);
    }

    #[test]
    fn prefilter_counts_interior_points() {
        let c = Coordinator::start(CoordinatorConfig {
            backend: BackendKind::Native,
            self_check: true,
            prefilter: PrefilterMode::Host,
            ..Default::default()
        })
        .unwrap();
        let pts = generate(Distribution::Disk, 4096, 9);
        let resp = c.compute(pts.clone()).unwrap();
        let (u, l) = monotone_chain::full_hull(&pts);
        assert_eq!(resp.upper, u);
        assert_eq!(resp.lower, l);
        let snap = c.snapshot().0;
        let filtered = snap.get("filtered_points").unwrap().as_usize().unwrap();
        assert!(filtered > 2048, "dense disk should shed most interior points: {filtered}");
        c.shutdown();
    }

    #[test]
    fn prefilter_off_is_honored() {
        let c = Coordinator::start(CoordinatorConfig {
            backend: BackendKind::Native,
            prefilter: PrefilterMode::Off,
            ..Default::default()
        })
        .unwrap();
        c.compute(generate(Distribution::Disk, 4096, 9)).unwrap();
        let snap = c.snapshot().0;
        assert_eq!(snap.get("filtered_points").unwrap().as_usize(), Some(0));
        c.shutdown();
    }

    /// Acceptance gate for PR 10: the served hull must be bit-identical
    /// under `host`, `device`, and `off` prefiltering on every generator
    /// distribution.  On a host backend `device_filter` declines, so the
    /// Device coordinator exercises the per-item worker-side host
    /// fallback — the metrics must show the drops as host-side, with the
    /// device counter untouched.
    #[test]
    fn prefilter_modes_serve_identical_hulls() {
        let mk = |mode: PrefilterMode| {
            Coordinator::start(CoordinatorConfig {
                backend: BackendKind::Native,
                self_check: true,
                prefilter: mode,
                ..Default::default()
            })
            .unwrap()
        };
        let host = mk(PrefilterMode::Host);
        let device = mk(PrefilterMode::Device);
        let off = mk(PrefilterMode::Off);
        for (k, dist) in Distribution::ALL.iter().enumerate() {
            let pts = generate(*dist, 1200 + 71 * k, 4200 + k as u64);
            let a = host.compute(pts.clone()).unwrap();
            let b = device.compute(pts.clone()).unwrap();
            let c = off.compute(pts.clone()).unwrap();
            let (u, l) = monotone_chain::full_hull(&pts);
            for (resp, mode) in [(&a, "host"), (&b, "device"), (&c, "off")] {
                assert_eq!(resp.upper, u, "{} upper diverged on {dist:?}", mode);
                assert_eq!(resp.lower, l, "{} lower diverged on {dist:?}", mode);
            }
        }
        let snap = device.snapshot().0;
        assert_eq!(
            snap.get("filtered_points_device").unwrap().as_usize(),
            Some(0),
            "no device artifacts on a native backend"
        );
        let host_side = snap.get("filtered_points_host").unwrap().as_usize().unwrap();
        assert!(host_side > 0, "worker-side host fallback should shed points");
        assert_eq!(
            snap.get("filtered_points").unwrap().as_usize(),
            Some(host_side),
            "compat key must stay the host+device sum"
        );
        host.shutdown();
        device.shutdown();
        off.shutdown();
    }
}
