//! Randomized property-testing harness (substitute for `proptest`).
//!
//! No shrinking — instead every case is driven by a recorded seed, so a
//! failure message pins the exact reproducer:
//! `WAGENER_PROP_SEED=<seed> cargo test <name>` re-runs just that case.
//! Case counts scale down under `cfg(debug_assertions)`-free CI via
//! `WAGENER_PROP_CASES`.

use super::rng::Rng;

/// Number of cases to run: env override > explicit request.
pub fn case_count(default_cases: usize) -> usize {
    std::env::var("WAGENER_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default_cases)
}

/// Run `prop` over `cases` seeded RNGs; panic with the failing seed.
///
/// The property returns `Err(message)` (or panics) to signal failure.
pub fn check<F>(name: &str, cases: usize, prop: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    if let Ok(s) = std::env::var("WAGENER_PROP_SEED") {
        let seed: u64 = s.parse().expect("WAGENER_PROP_SEED must be a u64");
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed under WAGENER_PROP_SEED={seed}: {msg}");
        }
        return;
    }
    // Base seed derives from the property name so distinct properties do not
    // share case streams, but runs stay deterministic build-to-build.
    let base = fnv1a(name.as_bytes());
    for case in 0..case_count(cases) {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case}: {msg}\n\
                 reproduce with: WAGENER_PROP_SEED={seed} cargo test"
            );
        }
    }
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let counter = std::cell::Cell::new(0);
        check("count", 17, |_| {
            counter.set(counter.get() + 1);
            Ok(())
        });
        assert_eq!(counter.get(), case_count(17));
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn failing_property_panics_with_seed() {
        check("always-false", 5, |_| Err("nope".into()));
    }

    #[test]
    fn seeds_differ_between_cases() {
        let seen = std::cell::RefCell::new(Vec::new());
        check("seed-stream", 10, |rng| {
            seen.borrow_mut().push(rng.next_u64());
            Ok(())
        });
        let v = seen.borrow();
        let mut uniq = v.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), v.len());
    }
}
