//! In-memory [`SnapshotStore`]: tests, crash-restart simulation (drop the
//! engine, keep the store), and rebalance transfers.

use std::collections::HashMap;
use std::sync::{Mutex, MutexGuard};

use super::{ChunkId, SnapshotStore, StoreError};

fn lock_ignore_poison<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

#[derive(Default)]
pub struct MemStore {
    chunks: Mutex<HashMap<ChunkId, Vec<u8>>>,
    manifests: Mutex<HashMap<u64, String>>,
}

impl MemStore {
    pub fn new() -> MemStore {
        MemStore::default()
    }

    /// Test hook: mutate a stored chunk's bytes in place (bit flips,
    /// truncation) to exercise corruption detection.  Returns false if
    /// the chunk does not exist.
    pub fn tamper_chunk(&self, id: ChunkId, f: impl FnOnce(&mut Vec<u8>)) -> bool {
        let mut chunks = lock_ignore_poison(&self.chunks);
        match chunks.get_mut(&id) {
            Some(data) => {
                f(data);
                true
            }
            None => false,
        }
    }

    /// Test hook: drop a chunk entirely (dangling manifest reference).
    pub fn remove_chunk(&self, id: ChunkId) -> bool {
        lock_ignore_poison(&self.chunks).remove(&id).is_some()
    }

    /// Every chunk currently stored (sorted for determinism).
    pub fn chunk_ids(&self) -> Vec<ChunkId> {
        let mut ids: Vec<ChunkId> = lock_ignore_poison(&self.chunks).keys().copied().collect();
        ids.sort();
        ids
    }
}

impl SnapshotStore for MemStore {
    fn put_chunk(&self, data: &[u8]) -> Result<(ChunkId, bool), StoreError> {
        let id = ChunkId::of(data);
        let mut chunks = lock_ignore_poison(&self.chunks);
        let wrote = chunks.insert(id, data.to_vec()).is_none();
        Ok((id, wrote))
    }

    fn get_chunk(&self, id: ChunkId) -> Result<Vec<u8>, StoreError> {
        let chunks = lock_ignore_poison(&self.chunks);
        let data = chunks
            .get(&id)
            .ok_or_else(|| StoreError::Corrupt(format!("missing chunk {id}")))?;
        if ChunkId::of(data) != id {
            return Err(StoreError::Corrupt(format!("chunk {id} fails hash verification")));
        }
        Ok(data.clone())
    }

    fn put_manifest(&self, sid: u64, text: &str) -> Result<(), StoreError> {
        lock_ignore_poison(&self.manifests).insert(sid, text.to_string());
        Ok(())
    }

    fn get_manifest(&self, sid: u64) -> Result<Option<String>, StoreError> {
        Ok(lock_ignore_poison(&self.manifests).get(&sid).cloned())
    }

    fn list_sids(&self) -> Result<Vec<u64>, StoreError> {
        let mut sids: Vec<u64> = lock_ignore_poison(&self.manifests).keys().copied().collect();
        sids.sort_unstable();
        Ok(sids)
    }
}
