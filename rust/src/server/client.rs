//! Blocking client for the hull service (examples, benches, tests, CLI).

use std::io::{BufReader, BufWriter};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::geometry::point::Point;

use super::proto::{self, Request, Response};
use super::frame;

/// Which wire encoding this client speaks.  The server auto-detects per
/// connection from the first byte, so no negotiation round-trip exists:
/// a client just starts talking in its chosen protocol.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireProto {
    /// Line-oriented text (the paper's file format extended with framing).
    Text,
    /// Length-prefixed binary frames with packed little-endian f64 pairs.
    Binary,
}

/// One connection to a hull server.
pub struct HullClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    next_id: u64,
    proto: WireProto,
}

/// A hull result as seen by the client.
#[derive(Clone, Debug)]
pub struct ClientHull {
    pub id: u64,
    pub upper: Vec<Point>,
    pub lower: Vec<Point>,
    pub backend: String,
    pub queue_ns: u64,
    pub exec_ns: u64,
}

/// `SADD` acknowledgment: lifetime absorbed count, current pending
/// buffer size, current epoch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SessionAddReply {
    pub absorbed: u64,
    pub pending: u64,
    pub epoch: u64,
}

/// `SHULL` payload: the authoritative hull and its epoch.
#[derive(Clone, Debug)]
pub struct SessionHullReply {
    pub epoch: u64,
    pub upper: Vec<Point>,
    pub lower: Vec<Point>,
}

impl HullClient {
    pub fn connect(addr: impl std::net::ToSocketAddrs) -> Result<HullClient> {
        Self::connect_with(addr, WireProto::Text)
    }

    /// Connect speaking `proto` — same verbs, same replies, different
    /// encoding on the wire.
    pub fn connect_with(
        addr: impl std::net::ToSocketAddrs,
        proto: WireProto,
    ) -> Result<HullClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(HullClient { reader, writer: BufWriter::new(stream), next_id: 1, proto })
    }

    /// The wire encoding this connection speaks.
    pub fn wire_proto(&self) -> WireProto {
        self.proto
    }

    fn send(&mut self, req: &Request) -> Result<()> {
        match self.proto {
            WireProto::Text => proto::write_request(&mut self.writer, req)?,
            WireProto::Binary => frame::write_request(&mut self.writer, req)?,
        }
        Ok(())
    }

    fn recv(&mut self) -> Result<Response> {
        match self.proto {
            WireProto::Text => proto::read_response(&mut self.reader),
            WireProto::Binary => frame::read_response(&mut self.reader),
        }
        .map_err(|e| anyhow!("{e}"))
    }

    /// Bound every blocking read on this connection (`None` = wait
    /// forever).  Session calls against a loaded server should set one:
    /// a timeout surfaces as an error instead of a parked client.
    pub fn set_read_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        self.reader.get_ref().set_read_timeout(timeout)?;
        Ok(())
    }

    pub fn ping(&mut self) -> Result<()> {
        self.send(&Request::Ping)?;
        match self.recv()? {
            Response::Pong => Ok(()),
            other => bail!("unexpected reply {other:?}"),
        }
    }

    /// Request the hull of `points`; blocks for the response.
    pub fn hull(&mut self, points: &[Point]) -> Result<ClientHull> {
        let id = self.next_id;
        self.next_id += 1;
        self.send(&Request::Hull { id, points: points.to_vec() })?;
        match self.recv()? {
            Response::Hull { id, upper, lower, backend, queue_ns, exec_ns } => {
                Ok(ClientHull { id, upper, lower, backend, queue_ns, exec_ns })
            }
            Response::HullErr { message, .. } => bail!("server: {message}"),
            Response::MalformedErr { message, .. } => bail!("server: malformed frame: {message}"),
            other => bail!("unexpected reply {other:?}"),
        }
    }

    /// Fetch the metrics snapshot (raw JSON string).
    pub fn stats(&mut self) -> Result<String> {
        self.send(&Request::Stats)?;
        match self.recv()? {
            Response::Stats(s) => Ok(s),
            other => bail!("unexpected reply {other:?}"),
        }
    }

    pub fn quit(mut self) -> Result<()> {
        self.send(&Request::Quit)?;
        Ok(())
    }

    // ------------------------------------------------ streaming sessions

    /// `SOPEN`: open a streaming session; returns its token.
    pub fn session_open(&mut self) -> Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        self.send(&Request::SessionOpen { id })?;
        match self.recv()? {
            Response::SessionOpened { sid, .. } => Ok(sid),
            Response::SessionErr { message, .. } => bail!("server: {message}"),
            other => bail!("unexpected reply {other:?}"),
        }
    }

    /// `SADD`: insert a batch into the session.
    pub fn session_add(&mut self, sid: u64, points: &[Point]) -> Result<SessionAddReply> {
        self.send(&Request::SessionAdd { sid, points: points.to_vec() })?;
        match self.recv()? {
            Response::SessionAdded { absorbed, pending, epoch, .. } => {
                Ok(SessionAddReply { absorbed, pending, epoch })
            }
            Response::SessionErr { message, .. } => bail!("server: {message}"),
            other => bail!("unexpected reply {other:?}"),
        }
    }

    /// `SHULL`: the authoritative session hull (server flushes pending
    /// first).
    pub fn session_hull(&mut self, sid: u64) -> Result<SessionHullReply> {
        self.send(&Request::SessionHull { sid })?;
        match self.recv()? {
            Response::SessionHull { epoch, upper, lower, .. } => {
                Ok(SessionHullReply { epoch, upper, lower })
            }
            Response::SessionErr { message, .. } => bail!("server: {message}"),
            other => bail!("unexpected reply {other:?}"),
        }
    }

    /// `SCLOSE`: release the session.
    pub fn session_close(&mut self, sid: u64) -> Result<()> {
        self.send(&Request::SessionClose { sid })?;
        match self.recv()? {
            Response::SessionClosed { .. } => Ok(()),
            Response::SessionErr { message, .. } => bail!("server: {message}"),
            other => bail!("unexpected reply {other:?}"),
        }
    }
}
