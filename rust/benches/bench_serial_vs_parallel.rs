//! E4 — "the parallel program is slow by comparison with another serial
//! program" (paper Conclusions).
//!
//! Regenerates the comparison as a table: four serial baselines, the
//! native Wagener pipeline, and the OvL-optimal variant, across sizes and
//! distributions; plus the PRAM simulator's modeled cycle counts with and
//! without the bank-conflict serialization the paper blames.
//!
//! Run: `cargo bench --bench bench_serial_vs_parallel`
//! (WAGENER_BENCH_FAST=1 for a smoke run)

use wagener_hull::benchkit::{black_box, Bencher, Report};
use wagener_hull::geometry::generators::{generate, Distribution};
use wagener_hull::ovl;
use wagener_hull::serial::{gift_wrapping, graham, monotone_chain, quickhull};
use wagener_hull::wagener;

fn main() {
    let b = Bencher::default();

    // ---- headline: who wins at each n (uniform square, the common case)
    let mut report = Report::new("E4: serial vs parallel, uniform square");
    for &n in &[256usize, 1024, 4096, 16384] {
        let pts = generate(Distribution::UniformSquare, n, 7);
        report.add(b.run(&format!("serial/monotone_chain/n{n}"), || {
            black_box(monotone_chain::upper_hull(black_box(&pts)))
        }));
        report.add(b.run(&format!("serial/quickhull/n{n}"), || {
            black_box(quickhull::upper_hull(black_box(&pts)))
        }));
        report.add(b.run(&format!("serial/graham/n{n}"), || {
            black_box(graham::convex_hull(black_box(&pts)))
        }));
        if n <= 4096 {
            report.add(b.run(&format!("serial/gift_wrapping/n{n}"), || {
                black_box(gift_wrapping::upper_hull(black_box(&pts)))
            }));
        }
        report.add(b.run(&format!("parallel/wagener_native/n{n}"), || {
            black_box(wagener::upper_hull(black_box(&pts)))
        }));
        report.add(b.run(&format!("parallel/ovl_optimal/n{n}"), || {
            black_box(ovl::optimal_upper_hull(black_box(&pts), 0).hull)
        }));
    }

    // ---- the paper's blamed mechanism: bank-conflict serialization
    for &n in &[1024usize, 4096] {
        let pts = generate(Distribution::Disk, n, 7);
        let run = wagener::pram_exec::run_pipeline(&pts, n).unwrap();
        report.note(format!(
            "pram n={n}: steps={} work={} ideal_cycles={} modeled_cycles={} conflict_factor={:.2}",
            run.counters.steps,
            run.counters.work,
            run.counters.ideal_cycles,
            run.counters.modeled_cycles,
            run.counters.conflict_factor()
        ));
    }
    report.note("paper shape: serial < native wagener (parallel pays O(n log n) work)");
    report.finish();

    // ---- distribution sweep at fixed n (hull-size sensitivity)
    let mut report = Report::new("E4b: distribution sweep, n = 4096");
    for dist in Distribution::ALL {
        let pts = generate(dist, 4096, 11);
        report.add(b.run(&format!("serial/{}", dist.name()), || {
            black_box(monotone_chain::upper_hull(black_box(&pts)))
        }));
        report.add(b.run(&format!("wagener/{}", dist.name()), || {
            black_box(wagener::upper_hull(black_box(&pts)))
        }));
    }
    report.finish();
}
