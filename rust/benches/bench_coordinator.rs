//! E6 — coordinator overhead and batching policy: throughput/latency of
//! the serving layer itself (native backend so the backend cost is tiny
//! and the router/batcher dominate), swept over batch size and flush
//! deadline; plus the worker-pool scaling section (E6c) that feeds
//! `BENCH_coordinator.json` via `scripts/tier1.sh`.
//!
//! Run: `cargo bench --bench bench_coordinator`

use std::sync::Arc;

use wagener_hull::benchkit::{Bencher, Report};
use wagener_hull::coordinator::{
    BackendKind, BatcherConfig, Coordinator, CoordinatorConfig, HullRequest, PrefilterMode,
};
use wagener_hull::geometry::generators::{generate, Distribution};

fn coord(max_batch: usize, flush_us: u64, workers: usize) -> Arc<Coordinator> {
    Arc::new(
        Coordinator::start(CoordinatorConfig {
            backend: BackendKind::Native,
            batcher: BatcherConfig { max_batch, flush_us, queue_cap: 4096 },
            self_check: false,
            workers,
            // keep the measured work comparable across PRs: the filter
            // would otherwise shrink the dense inputs before the backend
            prefilter: PrefilterMode::Off,
            ..Default::default()
        })
        .unwrap(),
    )
}

fn main() {
    let b = Bencher::default();
    let pts = generate(Distribution::Disk, 200, 5);

    // direct backend call = the floor (no batcher, no channels);
    // workers=1 keeps E6/E6b measuring router overhead, not the pool
    let mut report = Report::new("E6: coordinator overhead (native backend, 200-pt reqs)");
    report.add(b.run("floor/native_full_hull", || {
        wagener_hull::wagener::full_hull(std::hint::black_box(&pts))
    }));

    for (mb, flush) in [(1usize, 50u64), (4, 200), (8, 200), (8, 1000)] {
        let c = coord(mb, flush, 1);
        let pts2 = pts.clone();
        report.add(b.run(&format!("coordinator/batch{mb}_flush{flush}us"), move || {
            c.compute(pts2.clone()).unwrap()
        }));
    }
    report.finish();

    // concurrent wave throughput at different batching policies
    let mut report = Report::new("E6b: wave throughput (8 threads x 25 reqs)");
    for (mb, flush) in [(1usize, 100u64), (8, 400), (16, 800)] {
        let c = coord(mb, flush, 1);
        report.add(b.run_batched(
            &format!("wave/batch{mb}_flush{flush}us"),
            200,
            || {
                let mut handles = Vec::new();
                for t in 0..8u64 {
                    let c = c.clone();
                    handles.push(std::thread::spawn(move || {
                        let pts = generate(Distribution::Disk, 150, t);
                        let waits: Vec<_> = (0..25)
                            .map(|_| {
                                c.submit(HullRequest::new(c.next_id(), pts.clone()))
                            })
                            .collect();
                        for w in waits {
                            w.recv().unwrap().unwrap();
                        }
                    }));
                }
                for h in handles {
                    h.join().unwrap();
                }
            },
        ));
        let snap = c.snapshot().0;
        report.note(format!(
            "batch{mb}_flush{flush}: mean_batch_size={}",
            snap.get("mean_batch_size").unwrap()
        ));
    }
    report.finish();

    // E6c — the worker pool: 1 exec worker vs N, native backend, n=4096
    // requests each forming their own batch (max_batch=1), fired as a
    // 4-thread wave.  The acceptance gate for the pool PR: the N-worker
    // row must beat the 1-worker row.
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2);
    let n_workers = hw.clamp(2, 8);
    let mut report =
        Report::new(&format!("E6c: worker pool 1 vs {n_workers} workers (native, n=4096)"));
    let inputs: Vec<Vec<_>> = (0..4).map(|t| generate(Distribution::Disk, 4096, t)).collect();
    for workers in [1usize, n_workers] {
        let c = coord(1, 100, workers);
        let inputs = inputs.clone();
        report.add(b.run_batched(&format!("pool/workers{workers}_n4096"), 32, move || {
            let mut handles = Vec::new();
            for pts in inputs.iter().cloned() {
                let c = c.clone();
                handles.push(std::thread::spawn(move || {
                    let waits: Vec<_> = (0..8)
                        .map(|_| {
                            c.submit(HullRequest::new(c.next_id(), pts.clone()))
                        })
                        .collect();
                    for w in waits {
                        w.recv().unwrap().unwrap();
                    }
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
        }));
    }
    report.note(format!("hardware threads: {hw}"));
    report.finish();
}
