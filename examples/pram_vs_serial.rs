//! Experiment E4: the paper's Conclusions claim — "the parallel program is
//! slow by comparison with another serial program", blamed on memory-bank
//! serialization.
//!
//! This driver makes that quantitative, and separates the two costs the
//! simulator can pay: for each n it reports the serial baseline's wall
//! time, the native Wagener wall time, the PRAM engine's wall time on
//! both execution tiers (the audited instrument vs the fast serving
//! tier), and the audited tier's *modeled* execution under the CUDA bank
//! model — ideal cycles (conflict-free CREW PRAM), modeled cycles
//! (32-bank serialization), and the conflict factor between them.
//!
//! ```bash
//! cargo run --release --example pram_vs_serial
//! ```

use std::time::Instant;

use wagener_hull::geometry::generators::{generate, Distribution};
use wagener_hull::pram::ExecMode;
use wagener_hull::serial::monotone_chain;
use wagener_hull::wagener;
use wagener_hull::wagener::pram_exec::run_pipeline_mode;

fn time_ns<T>(f: impl Fn() -> T, iters: usize) -> (f64, T) {
    let mut out = None;
    let t0 = Instant::now();
    for _ in 0..iters {
        out = Some(std::hint::black_box(f()));
    }
    (t0.elapsed().as_nanos() as f64 / iters as f64, out.unwrap())
}

fn main() {
    println!("== E4: serial vs parallel (paper Conclusions) ==");
    println!(
        "{:>7} {:>12} {:>12} {:>8} | {:>12} {:>12} {:>8} | {:>12} {:>9} {:>9}",
        "n",
        "serial",
        "native-wag",
        "ratio",
        "pram-audit",
        "pram-fast",
        "tier-x",
        "modeled-cyc",
        "ideal-cyc",
        "conflict"
    );
    for &n in &[64usize, 256, 1024, 4096, 1 << 16] {
        let pts = generate(Distribution::Disk, n, 99);
        let iters = (200_000 / n).max(3);
        let (serial_ns, hull_s) = time_ns(|| monotone_chain::upper_hull(&pts), iters);
        let (native_ns, hull_w) = time_ns(|| wagener::upper_hull(&pts), iters.min(50));
        assert_eq!(hull_s, hull_w);

        // wall time of the two engine tiers on the same pipeline
        // (the audited instrument at n=2^16 costs seconds per run)
        let sim_iters = (65536 / n.max(1)).clamp(1, 16);
        let (audited_ns, run) = time_ns(
            || run_pipeline_mode(&pts, n, ExecMode::Audited, true).unwrap(),
            sim_iters,
        );
        let (fast_ns, fast_run) = time_ns(
            || run_pipeline_mode(&pts, n, ExecMode::Fast, true).unwrap(),
            sim_iters,
        );
        assert_eq!(run.hood, fast_run.hood); // tiers agree bit-for-bit

        println!(
            "{:>7} {:>10.1}µs {:>10.1}µs {:>7.1}x | {:>10.1}µs {:>10.1}µs {:>7.1}x | {:>12} {:>9} {:>8.2}x",
            n,
            serial_ns / 1e3,
            native_ns / 1e3,
            native_ns / serial_ns,
            audited_ns / 1e3,
            fast_ns / 1e3,
            audited_ns / fast_ns,
            run.counters.modeled_cycles,
            run.counters.ideal_cycles,
            run.counters.conflict_factor(),
        );
    }

    println!("\nper-stage breakdown at n=1024 (disk, audited tier):");
    let pts = generate(Distribution::Disk, 1024, 99);
    let run = wagener::pram_exec::run_pipeline(&pts, 1024).unwrap();
    println!(
        "{:>6} {:>7} {:>8} {:>8} {:>10} {:>10} {:>9}",
        "d", "d1xd2", "blocks", "steps", "reads", "modeled", "conflict"
    );
    for st in &run.per_stage {
        println!(
            "{:>6} {:>4}x{:<3} {:>8} {:>8} {:>10} {:>10} {:>8.2}x",
            st.d,
            st.d1,
            st.d2,
            st.blocks,
            st.steps,
            st.reads,
            st.modeled_cycles,
            st.modeled_cycles as f64 / st.ideal_cycles as f64,
        );
    }
    println!(
        "\npaper's qualitative claim reproduced: the PRAM/CUDA organisation pays a\n\
         {}x bank-serialization penalty on top of its O(n log n) work, while the\n\
         serial chain does O(n) work with sequential access — so the parallel\n\
         program loses on one chip.  The fast tier drops the instrument and is\n\
         what the serving path runs.",
        format_args!("{:.1}", run.counters.conflict_factor())
    );
}
