"""AOT exporter: lower the L2 model to HLO *text* artifacts for rust/PJRT.

Interchange format is HLO text, NOT ``lowered.compile().serialize()`` and
NOT a serialized HloModuleProto: jax >= 0.5 emits protos with 64-bit
instruction ids which the published xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Every artifact is lowered with ``return_tuple=True``; the rust side unwraps
with ``Literal::to_tuple``.  A ``manifest.json`` describes each artifact so
the rust `runtime::ArtifactRegistry` can pick executables by (n, batch)
without hard-coding paths.

Usage (from python/):
    python -m compile.aot --out ../artifacts/model.hlo.txt   # full set
    python -m compile.aot --report                           # + op counts

The default set covers the serving size classes (n = 64..1024) x batch
{1, 8}, single-request hood artifacts for the examples, and the plain-jnp
ablation twin for n = 256 (E7).
"""

from __future__ import annotations

import argparse
import collections
import json
import pathlib
import re

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

HOOD_SIZES = (64, 256, 1024)
HULL_SIZES = (64, 128, 256, 512, 1024)
BATCHES = (1, 8)
# Prefilter size classes: dense inputs only — below the smallest class the
# host filter wins and the rust side never dispatches to the device.
FILTER_SIZES = (4096, 16384, 65536, 262144, 1048576)
# Tangent size classes: block slots 2d; chains longer than n/2 fall back
# to the host tangent path.
TANGENT_SIZES = (128, 512, 2048, 8192)


def to_hlo_text(lowered) -> str:
    """jax Lowered -> XLA HLO text via stablehlo (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape: int):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def artifact_set():
    """name -> (fn, example-arg spec, metadata). Tuple outputs throughout."""
    arts = {}
    for n in HOOD_SIZES:
        arts[f"hood_n{n}"] = (
            lambda p: (model.upper_hood(p),),
            _spec(n, 2),
            {"kind": "hood", "n": n, "batch": 0, "outputs": 1},
        )
    for n in HULL_SIZES:
        for b in BATCHES:
            arts[f"hull_n{n}_b{b}"] = (
                model.batched_full_hull,
                _spec(b, n, 2),
                {"kind": "hull", "n": n, "batch": b, "outputs": 2},
            )
    # ablation twin: plain-jnp (no pallas) pipeline, E7
    arts["hood_jnp_n256"] = (
        lambda p: (model.upper_hood_jnp(p),),
        _spec(256, 2),
        {"kind": "hood_jnp", "n": 256, "batch": 0, "outputs": 1},
    )
    # octagon prefilter (batch 0: one block per dispatch, like hoods)
    for n in FILTER_SIZES:
        arts[f"filter_n{n}"] = (
            lambda p: (model.prefilter(p),),
            _spec(n, 2),
            {"kind": "filter", "n": n, "batch": 0, "outputs": 1},
        )
    # sampled tangent merge (batch 2: upper pair + mirrored lower pair —
    # one streaming-session merge is exactly one upload)
    for n in TANGENT_SIZES:
        arts[f"tangent_n{n}"] = (
            lambda b: (model.tangent_merge(b),),
            _spec(2, n, 2),
            {"kind": "tangent", "n": n, "batch": 2, "outputs": 1},
        )
    return arts


_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*[\w\[\]{}, ]+?\s(\w+)\(")


def op_histogram(hlo_text: str) -> dict[str, int]:
    """Crude instruction histogram from HLO text (perf reporting, E7)."""
    hist: collections.Counter[str] = collections.Counter()
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if m:
            hist[m.group(1)] += 1
    return dict(hist)


def export_all(out_dir: pathlib.Path, report: bool) -> dict:
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest: dict[str, dict] = {}
    reports: dict[str, dict] = {}
    for name, (fn, spec, meta) in artifact_set().items():
        lowered = jax.jit(fn).lower(spec)
        text = to_hlo_text(lowered)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        manifest[name] = {
            "file": path.name,
            "input_shape": list(spec.shape),
            **meta,
        }
        if report:
            hist = op_histogram(text)
            reports[name] = {
                "ops_total": sum(hist.values()),
                "bytes": len(text),
                "top_ops": dict(
                    sorted(hist.items(), key=lambda kv: -kv[1])[:12]
                ),
            }
        print(f"wrote {path} ({len(text)} chars)")
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    if report:
        (out_dir / "report.json").write_text(json.dumps(reports, indent=2))
        print(json.dumps(reports, indent=2))
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--out",
        default="../artifacts/model.hlo.txt",
        help="stamp file path; artifacts land in its directory",
    )
    ap.add_argument(
        "--report", action="store_true", help="write per-artifact op counts"
    )
    args = ap.parse_args()
    model.enable_x64()
    stamp = pathlib.Path(args.out)
    out_dir = stamp.parent
    export_all(out_dir, args.report)
    # Makefile freshness stamp: copy of the mid-size hull artifact.
    stamp.write_text((out_dir / "hull_n256_b1.hlo.txt").read_text())
    print(f"stamp {stamp}")


if __name__ == "__main__":
    main()
