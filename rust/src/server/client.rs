//! Blocking client for the hull service (examples, benches, tests, CLI).

use std::io::{BufReader, BufWriter};
use std::net::TcpStream;

use anyhow::{anyhow, bail, Result};

use crate::geometry::point::Point;

use super::proto::{self, Request, Response};

/// One connection to a hull server.
pub struct HullClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    next_id: u64,
}

/// A hull result as seen by the client.
#[derive(Clone, Debug)]
pub struct ClientHull {
    pub id: u64,
    pub upper: Vec<Point>,
    pub lower: Vec<Point>,
    pub backend: String,
    pub queue_ns: u64,
    pub exec_ns: u64,
}

impl HullClient {
    pub fn connect(addr: impl std::net::ToSocketAddrs) -> Result<HullClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(HullClient { reader, writer: BufWriter::new(stream), next_id: 1 })
    }

    pub fn ping(&mut self) -> Result<()> {
        proto::write_request(&mut self.writer, &Request::Ping)?;
        match proto::read_response(&mut self.reader).map_err(|e| anyhow!("{e}"))? {
            Response::Pong => Ok(()),
            other => bail!("unexpected reply {other:?}"),
        }
    }

    /// Request the hull of `points`; blocks for the response.
    pub fn hull(&mut self, points: &[Point]) -> Result<ClientHull> {
        let id = self.next_id;
        self.next_id += 1;
        proto::write_request(
            &mut self.writer,
            &Request::Hull { id, points: points.to_vec() },
        )?;
        match proto::read_response(&mut self.reader).map_err(|e| anyhow!("{e}"))? {
            Response::Hull { id, upper, lower, backend, queue_ns, exec_ns } => {
                Ok(ClientHull { id, upper, lower, backend, queue_ns, exec_ns })
            }
            Response::HullErr { message, .. } => bail!("server: {message}"),
            Response::MalformedErr { message, .. } => bail!("server: malformed frame: {message}"),
            other => bail!("unexpected reply {other:?}"),
        }
    }

    /// Fetch the metrics snapshot (raw JSON string).
    pub fn stats(&mut self) -> Result<String> {
        proto::write_request(&mut self.writer, &Request::Stats)?;
        match proto::read_response(&mut self.reader).map_err(|e| anyhow!("{e}"))? {
            Response::Stats(s) => Ok(s),
            other => bail!("unexpected reply {other:?}"),
        }
    }

    pub fn quit(mut self) -> Result<()> {
        proto::write_request(&mut self.writer, &Request::Quit)?;
        Ok(())
    }
}
