//! Serving metrics: counters + log-bucketed latency histograms with
//! percentile estimation.  Lock-light: all atomics, safe to share via Arc.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::Json;

const BUCKETS: usize = 48; // log2 ns buckets: covers 1 ns .. ~3 days

/// Log2-bucketed latency histogram (nanoseconds).
#[derive(Debug)]
pub struct Histogram {
    counts: [AtomicU64; BUCKETS],
    sum_ns: AtomicU64,
    count: AtomicU64,
    max_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_ns: AtomicU64::new(0),
            count: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn record_ns(&self, ns: u64) {
        let b = (64 - ns.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1);
        self.counts[b].fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    pub fn record(&self, d: std::time::Duration) {
        self.record_ns(d.as_nanos() as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_ns(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_ns.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns.load(Ordering::Relaxed)
    }

    /// Percentile estimate (upper bucket bound), q in [0, 1].
    pub fn percentile_ns(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * q).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (b, c) in self.counts.iter().enumerate() {
            seen += c.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (b + 1); // bucket upper bound
            }
        }
        self.max_ns()
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::Num(self.count() as f64)),
            ("mean_ns", Json::Num(self.mean_ns())),
            ("p50_ns", Json::Num(self.percentile_ns(0.50) as f64)),
            ("p95_ns", Json::Num(self.percentile_ns(0.95) as f64)),
            ("p99_ns", Json::Num(self.percentile_ns(0.99) as f64)),
            ("max_ns", Json::Num(self.max_ns() as f64)),
        ])
    }
}

/// All coordinator metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub errors: AtomicU64,
    pub degenerate_fallbacks: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    pub points_in: AtomicU64,
    pub hull_points_out: AtomicU64,
    /// points dropped by the octagon interior-point pre-filter.
    pub filtered_points: AtomicU64,
    pub queue_latency: Histogram,
    pub exec_latency: Histogram,
    pub e2e_latency: Histogram,
    // ---- streaming sessions (maintained by stream::SessionRegistry) ----
    /// currently open sessions (gauge).
    pub open_sessions: AtomicU64,
    /// points proven interior and dropped (insert-time rejection + merge
    /// consolidation), lifetime total across sessions.
    pub session_absorbed_points: AtomicU64,
    /// points sitting in pending buffers right now (gauge).
    pub session_pending_points: AtomicU64,
    /// incremental re-hulls performed (threshold or explicit flush).
    pub session_merges: AtomicU64,
    /// sessions reaped by the idle-TTL sweep.
    pub session_evictions: AtomicU64,
    /// wall time of each incremental merge (backend round-trip included).
    pub session_merge_latency: Histogram,
}

/// A point-in-time copy, JSON-serializable for the STATS endpoint.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot(pub Json);

impl Metrics {
    pub fn inc(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(counter: &AtomicU64, v: u64) {
        counter.fetch_add(v, Ordering::Relaxed);
    }

    /// Decrement a gauge (callers pair every `sub` with an earlier `add`,
    /// so this cannot underflow in correct use).
    pub fn sub(counter: &AtomicU64, v: u64) {
        counter.fetch_sub(v, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = |c: &AtomicU64| Json::Num(c.load(Ordering::Relaxed) as f64);
        let batches = self.batches.load(Ordering::Relaxed);
        let breqs = self.batched_requests.load(Ordering::Relaxed);
        MetricsSnapshot(Json::obj(vec![
            ("requests", g(&self.requests)),
            ("responses", g(&self.responses)),
            ("errors", g(&self.errors)),
            ("degenerate_fallbacks", g(&self.degenerate_fallbacks)),
            ("batches", g(&self.batches)),
            ("batched_requests", g(&self.batched_requests)),
            (
                "mean_batch_size",
                Json::Num(if batches == 0 { 0.0 } else { breqs as f64 / batches as f64 }),
            ),
            ("points_in", g(&self.points_in)),
            ("hull_points_out", g(&self.hull_points_out)),
            ("filtered_points", g(&self.filtered_points)),
            ("queue_latency", self.queue_latency.to_json()),
            ("exec_latency", self.exec_latency.to_json()),
            ("e2e_latency", self.e2e_latency.to_json()),
            ("open_sessions", g(&self.open_sessions)),
            ("absorbed_points_total", g(&self.session_absorbed_points)),
            ("pending_points_total", g(&self.session_pending_points)),
            ("merges_total", g(&self.session_merges)),
            ("session_evictions", g(&self.session_evictions)),
            ("session_merge_latency", self.session_merge_latency.to_json()),
        ]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles_ordered() {
        let h = Histogram::default();
        for i in 1..=1000u64 {
            h.record_ns(i * 1000);
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.percentile_ns(0.5);
        let p95 = h.percentile_ns(0.95);
        let p99 = h.percentile_ns(0.99);
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        // p50 of ~uniform 1k..1000k ns should be around 512k..1M bucket
        assert!((100_000..=2_100_000).contains(&p50), "{p50}");
        assert!((h.mean_ns() - 500_500.0 * 1.0).abs() < 100_000.0);
    }

    #[test]
    fn empty_histogram() {
        let h = Histogram::default();
        assert_eq!(h.percentile_ns(0.99), 0);
        assert_eq!(h.mean_ns(), 0.0);
    }

    #[test]
    fn snapshot_is_json() {
        let m = Metrics::default();
        Metrics::inc(&m.requests);
        Metrics::add(&m.points_in, 100);
        m.e2e_latency.record_ns(5000);
        let snap = m.snapshot();
        let s = snap.0.to_string();
        let back = crate::util::json::parse(&s).unwrap();
        assert_eq!(back.get("requests").unwrap().as_usize(), Some(1));
        assert_eq!(back.get("points_in").unwrap().as_usize(), Some(100));
        assert_eq!(
            back.get("e2e_latency").unwrap().get("count").unwrap().as_usize(),
            Some(1)
        );
    }

    #[test]
    fn snapshot_carries_session_gauges() {
        let m = Metrics::default();
        Metrics::add(&m.open_sessions, 3);
        Metrics::sub(&m.open_sessions, 1);
        Metrics::add(&m.session_pending_points, 42);
        Metrics::inc(&m.session_merges);
        m.session_merge_latency.record_ns(1234);
        let snap = crate::util::json::parse(&m.snapshot().0.to_string()).unwrap();
        assert_eq!(snap.get("open_sessions").unwrap().as_usize(), Some(2));
        assert_eq!(snap.get("pending_points_total").unwrap().as_usize(), Some(42));
        assert_eq!(snap.get("merges_total").unwrap().as_usize(), Some(1));
        assert_eq!(snap.get("absorbed_points_total").unwrap().as_usize(), Some(0));
        assert_eq!(
            snap.get("session_merge_latency").unwrap().get("count").unwrap().as_usize(),
            Some(1)
        );
    }

    #[test]
    fn max_tracked() {
        let h = Histogram::default();
        h.record_ns(10);
        h.record_ns(99999);
        h.record_ns(50);
        assert_eq!(h.max_ns(), 99999);
    }
}
