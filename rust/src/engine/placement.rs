//! Pluggable sid → shard placement.
//!
//! PR 5 hard-wired the session routing function into the engine:
//! `(sid - 1) % N` inverts the striped allocation, so a sid names its
//! shard forever.  That coupling blocks two things the durable-session
//! work needs: moving a live session between shards (rebalance) and
//! routing policies whose assignment survives a change in shard count
//! better than striping does.  This module extracts the routing decision
//! behind the [`Placement`] trait:
//!
//! * [`Stripe`] — the PR 5 function, still the default.  Sids are
//!   allocated striped per shard (shard `i` of `N` hands out sids
//!   `≡ i+1 (mod N)`), and `(sid - 1) % N` routes them back.
//! * [`Ring`] — a consistent-hash ring with virtual nodes.  Sids are
//!   allocated from one engine-global counter (1, 2, 3, … — the same
//!   sequence a 1-shard engine produces, which is what keeps the
//!   shards=1 vs shards=N parity gates meaningful under both
//!   placements), and each sid's designated shard is the ring successor
//!   of its hash.  `VNODES` virtual nodes per shard smooth the split.
//!
//! Either way the placement is a *pure function* of the sid — the engine
//! layers an override map on top for sessions moved by
//! [`crate::engine::Engine::rebalance`].

/// Virtual nodes per shard on the [`Ring`]: enough that the largest
/// shard's share of the keyspace stays within a few percent of 1/N.
pub const VNODES: usize = 64;

/// Which placement policy to build (config: `[engine] placement`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PlacementKind {
    #[default]
    Stripe,
    Ring,
}

impl PlacementKind {
    pub fn parse(s: &str) -> Option<PlacementKind> {
        match s.to_ascii_lowercase().as_str() {
            "stripe" => Some(PlacementKind::Stripe),
            "ring" => Some(PlacementKind::Ring),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            PlacementKind::Stripe => "stripe",
            PlacementKind::Ring => "ring",
        }
    }

    /// Placement for tests/tools honoring the `ENGINE_PLACEMENT`
    /// environment variable (tier1 exports `ENGINE_PLACEMENT=ring` to run
    /// the restart suite against ring routing).
    pub fn from_env(default: PlacementKind) -> PlacementKind {
        std::env::var("ENGINE_PLACEMENT")
            .ok()
            .and_then(|s| PlacementKind::parse(&s))
            .unwrap_or(default)
    }

    pub fn build(self, shards: usize) -> Box<dyn Placement> {
        match self {
            PlacementKind::Stripe => Box::new(Stripe::new(shards)),
            PlacementKind::Ring => Box::new(Ring::new(shards, VNODES)),
        }
    }
}

/// A deterministic sid → shard assignment.  Implementations are pure
/// (no interior state), so every caller computes the same answer and the
/// engine's rebalance overrides are the only source of divergence.
pub trait Placement: Send + Sync {
    fn kind(&self) -> PlacementKind;

    /// The designated shard for `sid`, in `0..shards`.
    fn shard_for(&self, sid: u64) -> usize;

    /// Fallback order when the designated shard has no capacity: every
    /// shard exactly once, designated first.  For the ring this walks
    /// successors clockwise, so a full shard spills to its ring
    /// neighbour — the same shard that would own the sid if the full one
    /// left the ring.
    fn order_for(&self, sid: u64) -> Vec<usize>;
}

/// PR 5's striped routing: `(sid - 1) % N`.
pub struct Stripe {
    shards: usize,
}

impl Stripe {
    pub fn new(shards: usize) -> Stripe {
        assert!(shards > 0, "placement over zero shards");
        Stripe { shards }
    }
}

impl Placement for Stripe {
    fn kind(&self) -> PlacementKind {
        PlacementKind::Stripe
    }

    fn shard_for(&self, sid: u64) -> usize {
        (sid.wrapping_sub(1) % self.shards as u64) as usize
    }

    fn order_for(&self, sid: u64) -> Vec<usize> {
        let d = self.shard_for(sid);
        (0..self.shards).map(|k| (d + k) % self.shards).collect()
    }
}

/// SplitMix64 finalizer: cheap, well-mixed, and stable across platforms
/// — the ring layout must be identical in every process that computes it.
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Consistent-hash ring: each shard contributes `vnodes` points at
/// `mix64(shard << 32 | v)`; a sid belongs to the first point clockwise
/// from `mix64(sid)` (wrapping).
pub struct Ring {
    shards: usize,
    /// (position, shard), sorted by position.
    points: Vec<(u64, usize)>,
}

impl Ring {
    pub fn new(shards: usize, vnodes: usize) -> Ring {
        assert!(shards > 0, "placement over zero shards");
        assert!(vnodes > 0, "ring needs at least one vnode per shard");
        let mut points = Vec::with_capacity(shards * vnodes);
        for shard in 0..shards {
            for v in 0..vnodes {
                points.push((mix64((shard as u64) << 32 | v as u64), shard));
            }
        }
        points.sort_unstable();
        Ring { shards, points }
    }

    /// Index into `points` of the successor of hash `h` (wrapping).
    fn successor(&self, h: u64) -> usize {
        match self.points.binary_search(&(h, usize::MAX)) {
            Ok(i) => i,
            Err(i) if i == self.points.len() => 0,
            Err(i) => i,
        }
    }
}

impl Placement for Ring {
    fn kind(&self) -> PlacementKind {
        PlacementKind::Ring
    }

    fn shard_for(&self, sid: u64) -> usize {
        self.points[self.successor(mix64(sid))].1
    }

    fn order_for(&self, sid: u64) -> Vec<usize> {
        let start = self.successor(mix64(sid));
        let mut seen = vec![false; self.shards];
        let mut order = Vec::with_capacity(self.shards);
        for k in 0..self.points.len() {
            let shard = self.points[(start + k) % self.points.len()].1;
            if !seen[shard] {
                seen[shard] = true;
                order.push(shard);
                if order.len() == self.shards {
                    break;
                }
            }
        }
        order
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parses_and_names() {
        assert_eq!(PlacementKind::parse("stripe"), Some(PlacementKind::Stripe));
        assert_eq!(PlacementKind::parse("RING"), Some(PlacementKind::Ring));
        assert_eq!(PlacementKind::parse("hash"), None);
        assert_eq!(PlacementKind::Ring.name(), "ring");
        assert_eq!(PlacementKind::default(), PlacementKind::Stripe);
    }

    #[test]
    fn stripe_matches_pr5_routing() {
        let p = Stripe::new(4);
        for sid in 1..=32u64 {
            assert_eq!(p.shard_for(sid), ((sid - 1) % 4) as usize);
        }
        assert_eq!(p.order_for(6), vec![1, 2, 3, 0]);
        assert_eq!(p.kind(), PlacementKind::Stripe);
    }

    #[test]
    fn ring_is_deterministic_and_total() {
        let a = Ring::new(4, VNODES);
        let b = Ring::new(4, VNODES);
        for sid in 1..=1000u64 {
            let s = a.shard_for(sid);
            assert!(s < 4);
            assert_eq!(s, b.shard_for(sid), "same ring, same answer");
        }
    }

    #[test]
    fn ring_spreads_sids_across_shards() {
        let r = Ring::new(4, VNODES);
        let mut counts = [0usize; 4];
        for sid in 1..=4000u64 {
            counts[r.shard_for(sid)] += 1;
        }
        for (i, c) in counts.iter().enumerate() {
            // perfect split is 1000; vnode smoothing keeps every shard
            // within a loose factor of it
            assert!((400..=1800).contains(c), "shard {i} owns {c} of 4000");
        }
    }

    #[test]
    fn ring_order_visits_every_shard_once_designated_first() {
        let r = Ring::new(5, 16);
        for sid in [1u64, 2, 77, 1234, u64::MAX] {
            let order = r.order_for(sid);
            assert_eq!(order.len(), 5);
            assert_eq!(order[0], r.shard_for(sid));
            let mut sorted = order.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, vec![0, 1, 2, 3, 4], "order {order:?} is a permutation");
        }
    }

    #[test]
    fn single_shard_rings_route_everything_to_zero() {
        let r = Ring::new(1, VNODES);
        for sid in 1..=64u64 {
            assert_eq!(r.shard_for(sid), 0);
            assert_eq!(r.order_for(sid), vec![0]);
        }
    }

    #[test]
    fn ring_assignment_is_stable_when_shards_are_added() {
        // the consistent-hash property: going 4 → 5 shards only moves
        // sids whose successor arc now belongs to the new shard; sids
        // that stay must keep their old assignment
        let small = Ring::new(4, VNODES);
        let big = Ring::new(5, VNODES);
        let mut moved = 0usize;
        let total = 4000u64;
        for sid in 1..=total {
            let (a, b) = (small.shard_for(sid), big.shard_for(sid));
            if a != b {
                assert_eq!(b, 4, "sid {sid} moved to an old shard ({a} -> {b})");
                moved += 1;
            }
        }
        // expected movement is ~1/5 of the keyspace, never the bulk of it
        assert!(moved > 0, "a fifth shard must claim something");
        assert!((moved as f64) < 0.40 * total as f64, "moved {moved} of {total}");
    }
}
