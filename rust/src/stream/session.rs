//! One streaming hull session: the incremental maintenance state machine.
//!
//! A [`Session`] holds the current hull (canonical upper/lower chains), a
//! bounded pending-point buffer, and an epoch counter.  Inserts are
//! interior-rejected against the current hull in O(log h) with the exact
//! orientation predicate — *strictly* interior points can never become
//! hull vertices of any superset, so they are absorbed on the spot;
//! everything else (including points exactly ON the boundary, the same
//! boundary-safety rule as the octagon prefilter) pends.  When the pending
//! buffer reaches the merge threshold, or on an explicit flush, the
//! pending set is hulled by the configured backend (through
//! [`HullService`]) and combined with the current hull by the paper's
//! tangent machinery ([`crate::wagener::hull_merge::merge_hulls`]).
//!
//! Invariant (checked by the integration suite): at every quiescent point,
//! `inserted == absorbed + pending + hull_points`, and the hull chains are
//! bit-identical to a one-shot hull of every point ever inserted.

use std::time::Instant;

use crate::coordinator::request::validate_points;
use crate::coordinator::{Coordinator, RequestError};
use crate::geometry::point::{sort_by_x, Point};
use crate::geometry::predicates::{orient2d, Orientation};
use crate::store::{LedgerEntry, SessionState};
use crate::wagener::hull_merge::{merge_hulls_with, TangentKernel};

/// Anything that can turn a raw point set into canonical hull chains —
/// the session's door into the coordinator's backend pool.  Implemented
/// by [`Coordinator`]; tests substitute a serial implementation.
pub trait HullService {
    fn full_hull(&self, points: Vec<Point>) -> Result<(Vec<Point>, Vec<Point>), RequestError>;

    /// Accelerator tangent kernel for hull ⊕ hull merges, when the
    /// service has one (the coordinator's device-merge worker under
    /// `backend = pjrt` + `device_merge = true`).  `None` keeps every
    /// merge on the host path — results are bit-identical either way.
    fn tangent_kernel(&self) -> Option<&dyn TangentKernel> {
        None
    }
}

impl HullService for Coordinator {
    fn full_hull(&self, points: Vec<Point>) -> Result<(Vec<Point>, Vec<Point>), RequestError> {
        let resp = self.compute(points)?;
        Ok((resp.upper, resp.lower))
    }

    fn tangent_kernel(&self) -> Option<&dyn TangentKernel> {
        self.device_merge_kernel()
    }
}

/// Result of one [`Session::add`] call, echoed on the wire as
/// `SADD <sid> OK <absorbed> <pending> <epoch>`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AddOutcome {
    /// lifetime count of points absorbed (interior-rejected at insert or
    /// swallowed by a merge).
    pub absorbed: u64,
    /// points currently pending (post-call).
    pub pending: usize,
    /// current epoch (increments once per merge).
    pub epoch: u64,
}

/// One client's incremental hull.
#[derive(Debug)]
pub struct Session {
    upper: Vec<Point>,
    lower: Vec<Point>,
    pending: Vec<Point>,
    epoch: u64,
    inserted: u64,
    absorbed: u64,
    /// unique vertex count of the current hull (upper ∪ lower).
    hull_points: u64,
    merge_threshold: usize,
    /// Append-only epoch ledger: `ledger[e-1]` is the delta record of the
    /// merge that produced epoch `e` (the pending survivors it consumed
    /// plus the resulting chains), so every historical hull stays
    /// addressable (`SHULL <sid> <epoch>`) and checkpoints can replay the
    /// full history.  Grows with merge count; content-addressed storage
    /// dedups the chains on disk.
    ledger: Vec<LedgerEntry>,
    /// wall time of merges not yet drained by [`Session::take_merge_samples`]
    /// (buffered here, not in the return value, so completed merges keep
    /// their latency samples even when a later merge in the same call
    /// errors out).
    merge_samples: Vec<u64>,
}

impl Session {
    pub fn new(merge_threshold: usize) -> Session {
        Session {
            upper: Vec::new(),
            lower: Vec::new(),
            pending: Vec::new(),
            epoch: 0,
            inserted: 0,
            absorbed: 0,
            hull_points: 0,
            merge_threshold: merge_threshold.max(1),
            ledger: Vec::new(),
            merge_samples: Vec::new(),
        }
    }

    /// Rebuild a session from a checkpoint — the exact inverse of
    /// [`Session::snapshot_state`], bit-identical down to accounting.
    pub fn from_state(state: SessionState) -> Session {
        let hull_points = unique_vertices(&state.upper, &state.lower);
        Session {
            upper: state.upper,
            lower: state.lower,
            pending: state.pending,
            epoch: state.epoch,
            inserted: state.inserted,
            absorbed: state.absorbed,
            hull_points,
            merge_threshold: state.merge_threshold.max(1),
            ledger: state.ledger,
            merge_samples: Vec::new(),
        }
    }

    /// The complete logical state for checkpointing.  Merge latency
    /// samples are metrics plumbing, not state, and are excluded.
    pub fn snapshot_state(&self) -> SessionState {
        SessionState {
            epoch: self.epoch,
            merge_threshold: self.merge_threshold,
            inserted: self.inserted,
            absorbed: self.absorbed,
            upper: self.upper.clone(),
            lower: self.lower.clone(),
            pending: self.pending.clone(),
            ledger: self.ledger.clone(),
        }
    }

    /// Insert a batch.  Validation is atomic (any bad point rejects the
    /// whole batch before anything mutates); a backend failure mid-merge
    /// leaves already-inserted points pending and is retried by the next
    /// add/flush.
    pub fn add(
        &mut self,
        points: &[Point],
        svc: &dyn HullService,
    ) -> Result<AddOutcome, RequestError> {
        validate_points(points)?;
        for p in points {
            let q = p.quantize_f32();
            self.inserted += 1;
            if strictly_inside(&self.upper, &self.lower, q) {
                self.absorbed += 1;
            } else {
                self.pending.push(q);
                if self.pending.len() >= self.merge_threshold {
                    self.merge(svc)?;
                }
            }
        }
        Ok(AddOutcome {
            absorbed: self.absorbed,
            pending: self.pending.len(),
            epoch: self.epoch,
        })
    }

    /// Fold any pending points into the hull.  Returns whether a merge
    /// actually ran.
    pub fn flush(&mut self, svc: &dyn HullService) -> Result<bool, RequestError> {
        if self.pending.is_empty() {
            return Ok(false);
        }
        self.merge(svc)?;
        Ok(true)
    }

    /// Drain the wall times of merges since the last drain (one sample
    /// per completed merge, kept across a failing call so metrics never
    /// lose a merge that did happen).
    pub fn take_merge_samples(&mut self) -> Vec<u64> {
        std::mem::take(&mut self.merge_samples)
    }

    /// Re-hull `hull ∪ pending`: the pending set goes through the backend
    /// pool, the resulting hull⊕hull pair through the tangent merge.
    fn merge(&mut self, svc: &dyn HullService) -> Result<(), RequestError> {
        debug_assert!(!self.pending.is_empty());
        let t0 = Instant::now();
        let consumed = self.pending.len() as u64;
        // pending stays in place until the backend answers: a Backend
        // error must not lose points
        let (pu, pl) = svc.full_hull(self.pending.clone())?;
        let (upper, lower) = if self.upper.is_empty() {
            (pu, pl)
        } else {
            let ((u, l), _path) = merge_hulls_with(
                svc.tangent_kernel(),
                (&self.upper, &self.lower),
                (&pu, &pl),
            );
            (u, l)
        };
        let old_hull = self.hull_points;
        let new_hull = unique_vertices(&upper, &lower);
        self.ledger.push(LedgerEntry {
            survivors: std::mem::take(&mut self.pending),
            upper: upper.clone(),
            lower: lower.clone(),
        });
        self.upper = upper;
        self.lower = lower;
        self.hull_points = new_hull;
        // every consumed point (and every displaced old vertex) that is
        // not a vertex of the new hull has been proven interior: absorbed
        self.absorbed += old_hull + consumed - new_hull;
        self.epoch += 1;
        debug_assert_eq!(self.ledger.len() as u64, self.epoch);
        self.merge_samples.push(t0.elapsed().as_nanos() as u64);
        Ok(())
    }

    /// Current hull chains (pending points NOT included — flush first for
    /// the authoritative hull).
    pub fn hull(&self) -> (&[Point], &[Point]) {
        (&self.upper, &self.lower)
    }

    /// Time travel: the hull exactly as of `epoch`.  Epoch 0 is the empty
    /// pre-first-merge hull; epoch `self.epoch()` equals [`Session::hull`]
    /// (chains only change at merges).  `None` for epochs never reached.
    pub fn hull_at(&self, epoch: u64) -> Option<(&[Point], &[Point])> {
        if epoch == 0 {
            return Some((&[], &[]));
        }
        if epoch > self.epoch {
            return None;
        }
        let entry = &self.ledger[(epoch - 1) as usize];
        Some((&entry.upper, &entry.lower))
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    pub fn inserted_total(&self) -> u64 {
        self.inserted
    }

    pub fn absorbed_total(&self) -> u64 {
        self.absorbed
    }

    /// Unique vertex count of the current hull.
    pub fn hull_points(&self) -> u64 {
        self.hull_points
    }
}

/// Distinct points across the two chains (they share their extreme-x
/// vertices; degenerate hulls may not — count exactly).
fn unique_vertices(upper: &[Point], lower: &[Point]) -> u64 {
    let mut all: Vec<Point> = upper.iter().chain(lower.iter()).copied().collect();
    sort_by_x(&mut all);
    all.dedup();
    all.len() as u64
}

/// Exact strict-interior test against canonical hull chains: strictly
/// between the extreme x's, strictly below the upper chain, strictly
/// above the lower chain.  Zero-area hulls (segments, single points,
/// vertical degenerate edges) contain nothing strictly — boundary-safe by
/// construction, so absorbing is always hull-preserving bit-for-bit.
pub fn strictly_inside(upper: &[Point], lower: &[Point], p: Point) -> bool {
    if upper.len() < 2 || lower.len() < 2 {
        return false;
    }
    let (xl, xr) = (upper[0].x, upper[upper.len() - 1].x);
    if !(xl < p.x && p.x < xr) {
        return false;
    }
    chain_side(upper, p) == Orientation::Right && chain_side(lower, p) == Orientation::Left
}

/// Orientation of `p` against the chain segment spanning `p.x`
/// (chains are x-sorted with strictly increasing x; caller guarantees
/// `chain[0].x < p.x < chain.last().x`).  O(log h) binary search.
fn chain_side(chain: &[Point], p: Point) -> Orientation {
    let k = chain.partition_point(|v| v.x <= p.x);
    // k >= 1 and k < chain.len() by the caller's range check
    orient2d(chain[k - 1], chain[k], p)
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::coordinator::backend::canonical_full_hull;
    use crate::geometry::generators::{generate, Distribution};

    /// Serial stand-in for the coordinator: identical canonicalization
    /// (quantize, sort, dedup, exact fallback under duplicate x).
    pub(crate) struct SerialService;

    impl HullService for SerialService {
        fn full_hull(
            &self,
            points: Vec<Point>,
        ) -> Result<(Vec<Point>, Vec<Point>), RequestError> {
            if points.is_empty() {
                return Err(RequestError::Empty);
            }
            Ok(canonical_full_hull(&points))
        }
    }

    /// One-shot oracle over a raw insert history.
    pub(crate) fn oracle(points: &[Point]) -> (Vec<Point>, Vec<Point>) {
        canonical_full_hull(points)
    }

    #[test]
    fn strict_interior_rejects_boundary_keeps_inside() {
        let square = vec![
            Point::new(0.0, 0.0),
            Point::new(0.0, 1.0),
            Point::new(1.0, 0.0),
            Point::new(1.0, 1.0),
        ];
        let (u, l) = oracle(&square);
        assert!(strictly_inside(&u, &l, Point::new(0.5, 0.5)));
        assert!(!strictly_inside(&u, &l, Point::new(0.5, 1.0)), "on top edge");
        assert!(!strictly_inside(&u, &l, Point::new(0.0, 0.5)), "on left edge x");
        assert!(!strictly_inside(&u, &l, Point::new(1.0, 0.5)), "on right edge x");
        assert!(!strictly_inside(&u, &l, Point::new(0.5, 0.0)), "on bottom edge");
    }

    #[test]
    fn zero_area_hulls_absorb_nothing() {
        let seg = vec![Point::new(0.1, 0.1), Point::new(0.9, 0.9)];
        let (u, l) = oracle(&seg);
        assert!(!strictly_inside(&u, &l, Point::new(0.5, 0.5)), "on the segment");
        let single = vec![Point::new(0.5, 0.5)];
        let (u, l) = oracle(&single);
        assert!(!strictly_inside(&u, &l, Point::new(0.5, 0.5)));
    }

    #[test]
    fn session_matches_oracle_with_interleaved_merges() {
        let svc = SerialService;
        for dist in Distribution::ALL {
            let pts = generate(dist, 500, 11);
            let mut s = Session::new(64);
            for chunk in pts.chunks(37) {
                s.add(chunk, &svc).unwrap();
            }
            s.flush(&svc).unwrap();
            let (wu, wl) = oracle(&pts);
            let (gu, gl) = s.hull();
            assert_eq!(gu, &wu[..], "{} upper", dist.name());
            assert_eq!(gl, &wl[..], "{} lower", dist.name());
            assert_eq!(
                s.inserted_total(),
                s.absorbed_total() + s.pending_len() as u64 + s.hull_points(),
                "{} accounting",
                dist.name()
            );
            assert_eq!(s.pending_len(), 0);
        }
    }

    #[test]
    fn duplicates_and_boundary_points_stay_exact() {
        let svc = SerialService;
        let pts = generate(Distribution::Disk, 300, 5);
        let mut s = Session::new(50);
        s.add(&pts, &svc).unwrap();
        s.flush(&svc).unwrap();
        // re-insert the whole set (every point now interior or a vertex
        // duplicate), plus exact hull vertices again
        let (hu, _) = s.hull();
        let verts: Vec<Point> = hu.to_vec();
        s.add(&pts, &svc).unwrap();
        s.add(&verts, &svc).unwrap();
        s.flush(&svc).unwrap();
        let (wu, wl) = oracle(&pts);
        let (gu, gl) = s.hull();
        assert_eq!(gu, &wu[..]);
        assert_eq!(gl, &wl[..]);
        assert_eq!(
            s.inserted_total(),
            s.absorbed_total() + s.hull_points(),
            "duplicates must be fully absorbed"
        );
    }

    #[test]
    fn validation_is_atomic() {
        let svc = SerialService;
        let mut s = Session::new(8);
        let bad = vec![Point::new(0.5, 0.5), Point::new(1.5, 0.0)];
        assert!(matches!(s.add(&bad, &svc), Err(RequestError::OutOfRange(1))));
        assert_eq!(s.inserted_total(), 0);
        assert_eq!(s.pending_len(), 0);
        let nan = vec![Point::new(f64::NAN, 0.5)];
        assert!(matches!(s.add(&nan, &svc), Err(RequestError::NonFinite(0))));
    }

    #[test]
    fn threshold_triggers_merges_and_epoch() {
        let svc = SerialService;
        let pts = generate(Distribution::Circle, 64, 3);
        let mut s = Session::new(16);
        let out = s.add(&pts, &svc).unwrap();
        assert!(out.epoch >= 4, "circle points all pend: {} merges", out.epoch);
        // one latency sample per merge, buffered until drained
        assert_eq!(s.take_merge_samples().len() as u64, out.epoch);
        assert!(s.take_merge_samples().is_empty(), "drain must reset");
        assert!(s.pending_len() < 16);
    }

    #[test]
    fn ledger_serves_every_historical_epoch() {
        let svc = SerialService;
        let pts = generate(Distribution::Disk, 400, 9);
        let mut s = Session::new(32);
        // replay the same schedule against a fresh session per epoch to
        // pin what each historical hull must be
        let mut per_epoch: Vec<(Vec<Point>, Vec<Point>)> = Vec::new();
        let mut twin = Session::new(32);
        for chunk in pts.chunks(23) {
            s.add(chunk, &svc).unwrap();
            twin.add(chunk, &svc).unwrap();
            while (per_epoch.len() as u64) < twin.epoch() {
                // twin epochs advance in lockstep with s (same schedule)
                let (u, l) = twin.hull();
                per_epoch.push((u.to_vec(), l.to_vec()));
            }
        }
        s.flush(&svc).unwrap();
        twin.flush(&svc).unwrap();
        while (per_epoch.len() as u64) < twin.epoch() {
            let (u, l) = twin.hull();
            per_epoch.push((u.to_vec(), l.to_vec()));
        }
        assert!(s.epoch() >= 2, "schedule must cross several merges");
        assert_eq!(s.hull_at(0), Some((&[][..], &[][..])));
        assert_eq!(s.hull_at(s.epoch() + 1), None);
        let (cu, cl) = s.hull();
        let (cu, cl) = (cu.to_vec(), cl.to_vec());
        assert_eq!(s.hull_at(s.epoch()), Some((&cu[..], &cl[..])));
        for (i, (u, l)) in per_epoch.iter().enumerate() {
            // NOTE: the hull only changes at merges, so the snapshot taken
            // right after epoch e advanced is exactly hull_at(e+1)... the
            // loop above records one snapshot per epoch increment in order
            let got = s.hull_at(i as u64 + 1).unwrap();
            assert_eq!(got.0, &u[..], "epoch {} upper", i + 1);
            assert_eq!(got.1, &l[..], "epoch {} lower", i + 1);
        }
    }

    #[test]
    fn snapshot_state_roundtrip_is_bit_identical() {
        let svc = SerialService;
        let pts = generate(Distribution::Cluster, 300, 4);
        let mut s = Session::new(48);
        for chunk in pts.chunks(31) {
            s.add(chunk, &svc).unwrap();
        }
        let state = s.snapshot_state();
        let mut restored = Session::from_state(state.clone());
        assert_eq!(restored.snapshot_state(), state, "export(import(x)) == x");
        assert_eq!(restored.epoch(), s.epoch());
        assert_eq!(restored.pending_len(), s.pending_len());
        assert_eq!(restored.hull_points(), s.hull_points());
        assert_eq!(restored.hull(), s.hull());
        for e in 0..=s.epoch() {
            assert_eq!(restored.hull_at(e), s.hull_at(e), "epoch {e}");
        }
        // continuations diverge-free: feed both the same tail
        let tail = generate(Distribution::Circle, 100, 7);
        let a = s.add(&tail, &svc).unwrap();
        let b = restored.add(&tail, &svc).unwrap();
        assert_eq!(a, b);
        s.flush(&svc).unwrap();
        restored.flush(&svc).unwrap();
        assert_eq!(s.hull(), restored.hull());
        assert_eq!(
            restored.inserted_total(),
            restored.absorbed_total() + restored.pending_len() as u64 + restored.hull_points()
        );
    }

    #[test]
    fn flush_on_empty_pending_is_a_noop() {
        let svc = SerialService;
        let mut s = Session::new(8);
        assert!(!s.flush(&svc).unwrap());
        assert_eq!(s.epoch(), 0);
        s.add(&[Point::new(0.2, 0.2)], &svc).unwrap();
        assert!(s.flush(&svc).unwrap());
        assert_eq!(s.epoch(), 1);
        assert!(!s.flush(&svc).unwrap());
        assert_eq!(s.epoch(), 1);
        assert_eq!(s.take_merge_samples().len(), 1);
    }
}
