//! TCP server end-to-end: real sockets, real engine, protocol checks.
//!
//! The servers here are built through the [`Engine`] facade with the
//! shard count taken from `ENGINE_SHARDS` (default 1, the
//! pre-engine-identical configuration); tier1 re-runs this whole suite
//! with `ENGINE_SHARDS=4` so the sharded path is exercised end-to-end in
//! CI.  Every assertion is shard-count independent by design.

use std::sync::Arc;

use wagener_hull::coordinator::{BackendKind, BatcherConfig, Coordinator, CoordinatorConfig};
use wagener_hull::engine::{Engine, EngineConfig};
use wagener_hull::geometry::generators::{generate, Distribution};
use wagener_hull::geometry::point::Point;
use wagener_hull::serial::monotone_chain;
use wagener_hull::server::{serve, serve_engine, HullClient, ServerConfig};
use wagener_hull::stream::StreamConfig;

fn start_engine(kind: BackendKind, stream_cfg: StreamConfig) -> Arc<Engine> {
    Arc::new(
        Engine::start(EngineConfig {
            shards: EngineConfig::shards_from_env(1),
            coordinator: CoordinatorConfig {
                backend: kind,
                batcher: BatcherConfig { max_batch: 4, flush_us: 300, queue_cap: 256 },
                self_check: true,
                ..Default::default()
            },
            stream: stream_cfg,
            ..Default::default()
        })
        .unwrap(),
    )
}

fn loopback_cfg() -> ServerConfig {
    ServerConfig { addr: "127.0.0.1:0".into(), ..Default::default() }
}

fn start_server(kind: BackendKind) -> (Arc<Engine>, wagener_hull::server::ServerHandle) {
    let engine = start_engine(kind, StreamConfig::default());
    let handle = serve_engine(engine.clone(), &loopback_cfg()).unwrap();
    (engine, handle)
}

#[test]
fn ping_hull_stats_roundtrip() {
    let (_coord, handle) = start_server(BackendKind::Native);
    let mut client = HullClient::connect(handle.local_addr).unwrap();
    client.ping().unwrap();

    let pts = generate(Distribution::Disk, 120, 7);
    let hull = client.hull(&pts).unwrap();
    let (u, l) = monotone_chain::full_hull(&pts);
    assert_eq!(hull.upper, u);
    assert_eq!(hull.lower, l);
    assert_eq!(hull.backend, "native");

    let stats = client.stats().unwrap();
    let json = wagener_hull::util::json::parse(&stats).unwrap();
    assert_eq!(json.get("responses").unwrap().as_usize(), Some(1));
    client.quit().unwrap();
    handle.stop();
}

#[test]
fn many_clients_concurrently() {
    let (coord, handle) = start_server(BackendKind::Native);
    let addr = handle.local_addr;
    let mut join = Vec::new();
    for t in 0..6u64 {
        join.push(std::thread::spawn(move || {
            let mut client = HullClient::connect(addr).unwrap();
            for k in 0..5u64 {
                let pts = generate(
                    Distribution::ALL[(t % 7) as usize],
                    30 + (t * 5 + k) as usize,
                    t * 31 + k,
                );
                let hull = client.hull(&pts).unwrap();
                let (u, l) = monotone_chain::full_hull(&pts);
                assert_eq!(hull.upper, u);
                assert_eq!(hull.lower, l);
            }
        }));
    }
    for h in join {
        h.join().unwrap();
    }
    let snap = coord.snapshot().0;
    assert_eq!(snap.get("responses").unwrap().as_usize(), Some(30));
    assert_eq!(snap.get("errors").unwrap().as_usize(), Some(0));
    handle.stop();
}

#[test]
fn server_reports_request_errors() {
    let (_coord, handle) = start_server(BackendKind::Serial);
    let mut client = HullClient::connect(handle.local_addr).unwrap();
    // out-of-range point -> structured error, connection stays usable
    let err = client.hull(&[Point::new(5.0, 5.0)]).unwrap_err();
    assert!(err.to_string().contains("outside"), "{err}");
    client.ping().unwrap();
    handle.stop();
}

#[test]
fn degenerate_input_served_exactly() {
    let (_coord, handle) = start_server(BackendKind::Native);
    let mut client = HullClient::connect(handle.local_addr).unwrap();
    let pts = vec![
        Point::new(0.5, 0.2),
        Point::new(0.5, 0.8),
        Point::new(0.1, 0.5),
        Point::new(0.9, 0.5),
    ];
    let hull = client.hull(&pts).unwrap();
    assert_eq!(hull.backend, "exact");
    // responses are f32-quantized (the artifact wire type)
    let q: Vec<Point> = pts.iter().map(|p| p.quantize_f32()).collect();
    assert_eq!(hull.upper, vec![q[2], q[1], q[3]]);
    assert_eq!(hull.lower, vec![q[2], q[0], q[3]]);
    handle.stop();
}

#[test]
fn malformed_protocol_line_closes_gracefully() {
    use std::io::{BufRead, BufReader, Write};
    let (_coord, handle) = start_server(BackendKind::Serial);
    let mut stream = std::net::TcpStream::connect(handle.local_addr).unwrap();
    stream.write_all(b"GARBAGE\n").unwrap();
    stream.flush().unwrap();
    let mut line = String::new();
    BufReader::new(stream).read_line(&mut line).unwrap();
    assert!(line.contains("ERR"), "{line}");
    handle.stop();
}

#[test]
fn malformed_hull_frame_echoes_request_id() {
    use std::io::{BufRead, BufReader, Write};
    let (_coord, handle) = start_server(BackendKind::Serial);
    let mut stream = std::net::TcpStream::connect(handle.local_addr).unwrap();
    // the id parses, the count does not: the error must carry id 9 so a
    // client correlating replies by request id can match the failure
    stream.write_all(b"HULL 9 zz\n").unwrap();
    stream.flush().unwrap();
    let mut line = String::new();
    BufReader::new(stream).read_line(&mut line).unwrap();
    assert!(line.starts_with("ERR 9 "), "want 'ERR 9 ...', got {line:?}");
    handle.stop();
}

/// Poll a gauge until it reaches `want` (connection teardown is async).
fn wait_gauge(handle: &wagener_hull::server::ServerHandle, want: u64) {
    let t0 = std::time::Instant::now();
    while handle.active_connections() != want {
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(5),
            "gauge stuck at {} (want {want})",
            handle.active_connections()
        );
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
}

#[test]
fn connection_gauge_tracks_active_connections() {
    let (_coord, handle) = start_server(BackendKind::Serial);
    assert_eq!(handle.active_connections(), 0);
    let mut c1 = HullClient::connect(handle.local_addr).unwrap();
    let mut c2 = HullClient::connect(handle.local_addr).unwrap();
    c1.ping().unwrap();
    c2.ping().unwrap();
    wait_gauge(&handle, 2);
    c2.quit().unwrap();
    wait_gauge(&handle, 1); // a gauge, not a lifetime counter
    c1.quit().unwrap();
    wait_gauge(&handle, 0);
    handle.stop();
}

/// The deprecated `serve(coordinator, ..)` wrapper must keep serving
/// exactly as before: it wraps the coordinator as a 1-shard engine, and
/// sessions + one-shots + STATS all work over the same wire bytes.
#[test]
fn deprecated_serve_wrapper_is_a_one_shard_engine() {
    let coord = Arc::new(
        Coordinator::start(CoordinatorConfig {
            backend: BackendKind::Serial,
            self_check: true,
            ..Default::default()
        })
        .unwrap(),
    );
    let handle = serve(coord.clone(), &loopback_cfg()).unwrap();
    assert_eq!(handle.engine().shard_count(), 1);
    let mut client = HullClient::connect(handle.local_addr).unwrap();
    let pts = generate(Distribution::Circle, 90, 5);
    let hull = client.hull(&pts).unwrap();
    let (u, _) = monotone_chain::full_hull(&pts);
    assert_eq!(hull.upper, u);
    let sid = client.session_open().unwrap();
    assert_eq!(sid, 1, "stride-1 sid allocation, exactly the old registry");
    client.session_close(sid).unwrap();
    let stats = client.stats().unwrap();
    let json = wagener_hull::util::json::parse(&stats).unwrap();
    assert_eq!(json.get("shards").unwrap().as_usize(), Some(1));
    assert_eq!(json.get("per_shard").unwrap().as_arr().unwrap().len(), 1);
    handle.stop();
}

// ---------------------------------------------------- streaming sessions

fn start_session_server(
    kind: BackendKind,
    stream_cfg: StreamConfig,
) -> (Arc<Engine>, wagener_hull::server::ServerHandle) {
    let engine = start_engine(kind, stream_cfg);
    let handle = serve_engine(engine.clone(), &loopback_cfg()).unwrap();
    (engine, handle)
}

#[test]
fn session_lifecycle_over_tcp_matches_one_shot() {
    let (_coord, handle) = start_session_server(
        BackendKind::Native,
        StreamConfig { merge_threshold: 64, ..Default::default() },
    );
    let mut client = HullClient::connect(handle.local_addr).unwrap();
    client.set_read_timeout(Some(std::time::Duration::from_secs(10))).unwrap();

    let sid = client.session_open().unwrap();
    let pts = generate(Distribution::Disk, 500, 13);
    let mut last_epoch = 0;
    for chunk in pts.chunks(125) {
        let ack = client.session_add(sid, chunk).unwrap();
        assert!(ack.epoch >= last_epoch);
        last_epoch = ack.epoch;
    }
    let hull = client.session_hull(sid).unwrap();
    let (u, l) = monotone_chain::full_hull(&pts);
    assert_eq!(hull.upper, u);
    assert_eq!(hull.lower, l);
    assert!(hull.epoch >= 1);

    // one-shot HULL on the same connection agrees bit-for-bit
    let oneshot = client.hull(&pts).unwrap();
    assert_eq!(oneshot.upper, hull.upper);
    assert_eq!(oneshot.lower, hull.lower);

    // STATS carries the session gauges
    let stats = client.stats().unwrap();
    let json = wagener_hull::util::json::parse(&stats).unwrap();
    assert_eq!(json.get("open_sessions").unwrap().as_usize(), Some(1));
    assert_eq!(json.get("pending_points_total").unwrap().as_usize(), Some(0));
    assert!(json.get("merges_total").unwrap().as_usize().unwrap() >= 1);
    assert!(json.get("absorbed_points_total").unwrap().as_usize().is_some());

    client.session_close(sid).unwrap();
    let stats = client.stats().unwrap();
    let json = wagener_hull::util::json::parse(&stats).unwrap();
    assert_eq!(json.get("open_sessions").unwrap().as_usize(), Some(0));
    // closed session: distinct unknown-session error, connection usable
    let err = client.session_add(sid, &pts[..1]).unwrap_err();
    assert!(err.to_string().contains("unknown-session"), "{err}");
    client.ping().unwrap();
    handle.stop();
}

#[test]
fn unknown_session_error_echoes_sid_on_the_wire() {
    use std::io::{BufRead, BufReader, Write};
    let (_coord, handle) = start_session_server(BackendKind::Serial, StreamConfig::default());
    let mut stream = std::net::TcpStream::connect(handle.local_addr).unwrap();
    stream.write_all(b"SADD 777 1\n0.5 0.5\n").unwrap();
    stream.flush().unwrap();
    let mut line = String::new();
    BufReader::new(stream).read_line(&mut line).unwrap();
    assert_eq!(line.trim_end(), "SADD 777 ERR unknown-session", "{line:?}");
    handle.stop();
}

#[test]
fn malformed_session_frame_echoes_sid() {
    use std::io::{BufRead, BufReader, Write};
    let (_coord, handle) = start_session_server(BackendKind::Serial, StreamConfig::default());
    let mut stream = std::net::TcpStream::connect(handle.local_addr).unwrap();
    // sid parses, count does not: the id-echo rule extends to SADD
    stream.write_all(b"SADD 9 zz\n").unwrap();
    stream.flush().unwrap();
    let mut line = String::new();
    BufReader::new(stream).read_line(&mut line).unwrap();
    assert!(line.starts_with("ERR 9 "), "want 'ERR 9 ...', got {line:?}");
    handle.stop();
}

#[test]
fn session_capacity_cap_over_tcp() {
    let (_coord, handle) = start_session_server(
        BackendKind::Serial,
        StreamConfig { max_sessions: 1, idle_ttl_ms: 0, ..Default::default() },
    );
    let mut client = HullClient::connect(handle.local_addr).unwrap();
    let sid = client.session_open().unwrap();
    let err = client.session_open().unwrap_err();
    assert!(err.to_string().contains("capacity"), "{err}");
    client.session_close(sid).unwrap();
    client.session_open().unwrap();
    handle.stop();
}

#[test]
fn idle_sessions_evicted_over_tcp() {
    let (_coord, handle) = start_session_server(
        BackendKind::Serial,
        StreamConfig { idle_ttl_ms: 40, ..Default::default() },
    );
    let mut client = HullClient::connect(handle.local_addr).unwrap();
    let sid = client.session_open().unwrap();
    client.session_add(sid, &[Point::new(0.5, 0.5)]).unwrap();
    std::thread::sleep(std::time::Duration::from_millis(80));
    handle.engine().sweep_now(); // sweeps every shard (the sid's included)
    let err = client.session_add(sid, &[Point::new(0.2, 0.2)]).unwrap_err();
    assert!(err.to_string().contains("unknown-session"), "{err}");
    handle.stop();
}

/// `stop` must join handler threads even when a client still holds its
/// connection open mid-read (the server shuts the socket down to unblock
/// the handler); a hang here would fail the test by timeout.
#[test]
fn stop_joins_open_connections() {
    let (_coord, handle) = start_server(BackendKind::Serial);
    let mut client = HullClient::connect(handle.local_addr).unwrap();
    client.ping().unwrap();
    wait_gauge(&handle, 1);
    // client neither quits nor drops: the handler is parked in read_line
    handle.stop();
    // handle consumed; the handler was joined and decremented the gauge
    drop(client);
}
