//! The shared typed-error vocabulary.
//!
//! The serving stack exposes a small set of *typed* errors — failures a
//! client is expected to match on and handle programmatically, as opposed
//! to free-form diagnostics.  Their wire tokens used to live as string
//! literals scattered across `coordinator/request.rs`,
//! `stream/registry.rs` and `store/mod.rs`; this module is now the single
//! source of truth: the `Display` impls of [`RequestError`],
//! [`SessionError`] and [`StoreError`] delegate their typed arms to
//! [`TypedError::wire_token`], and the HTTP gateway maps the same enum to
//! stable statuses via [`TypedError::http_status`].  The TCP wire strings
//! are pinned by the parity suites — changing a token here is a protocol
//! break, not a refactor.

use crate::coordinator::RequestError;
use crate::store::StoreError;
use crate::stream::SessionError;

/// Every machine-parseable error token the system emits, with its one
/// wire spelling and its one HTTP status.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TypedError {
    /// Load shed: every candidate shard sat at its admission ceiling.
    Overloaded,
    /// The request's deadline passed before a worker could answer.
    DeadlineExceeded,
    /// sid never existed, was closed, or was evicted.
    UnknownSession,
    /// Epoch time-travel to an epoch the session never reached.
    UnknownEpoch,
    /// Snapshot bytes or manifest failed verification.
    SnapshotCorrupt,
    /// Snapshot store I/O failed.
    SnapshotIo,
}

impl TypedError {
    pub const ALL: [TypedError; 6] = [
        TypedError::Overloaded,
        TypedError::DeadlineExceeded,
        TypedError::UnknownSession,
        TypedError::UnknownEpoch,
        TypedError::SnapshotCorrupt,
        TypedError::SnapshotIo,
    ];

    /// The exact token the TCP text/binary error payload starts with.
    pub const fn wire_token(self) -> &'static str {
        match self {
            TypedError::Overloaded => "overloaded",
            TypedError::DeadlineExceeded => "deadline-exceeded",
            TypedError::UnknownSession => "unknown-session",
            TypedError::UnknownEpoch => "unknown-epoch",
            TypedError::SnapshotCorrupt => "snapshot-corrupt",
            TypedError::SnapshotIo => "snapshot-io",
        }
    }

    /// The stable HTTP status the gateway answers with.
    pub const fn http_status(self) -> u16 {
        match self {
            TypedError::Overloaded => 503,
            TypedError::DeadlineExceeded => 504,
            TypedError::UnknownSession => 404,
            TypedError::UnknownEpoch => 404,
            TypedError::SnapshotCorrupt => 500,
            TypedError::SnapshotIo => 500,
        }
    }

    /// The typed classification of a request-level failure, if it has one.
    pub fn of_request(e: &RequestError) -> Option<TypedError> {
        match e {
            RequestError::Overloaded => Some(TypedError::Overloaded),
            RequestError::DeadlineExceeded => Some(TypedError::DeadlineExceeded),
            _ => None,
        }
    }

    /// Store failures are always typed.
    pub fn of_store(e: &StoreError) -> TypedError {
        match e {
            StoreError::Corrupt(_) => TypedError::SnapshotCorrupt,
            StoreError::Io(_) => TypedError::SnapshotIo,
        }
    }

    /// The typed classification of a session-level failure, if it has one.
    pub fn of_session(e: &SessionError) -> Option<TypedError> {
        match e {
            SessionError::UnknownSession => Some(TypedError::UnknownSession),
            SessionError::UnknownEpoch => Some(TypedError::UnknownEpoch),
            SessionError::Snapshot(s) => Some(TypedError::of_store(s)),
            SessionError::Request(r) => TypedError::of_request(r),
            _ => None,
        }
    }
}

impl std::fmt::Display for TypedError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.wire_token())
    }
}

/// HTTP status for any request-level failure: the typed mapping when one
/// applies, else 400 for caller mistakes and 5xx for server-side loss.
pub fn http_status_of_request(e: &RequestError) -> u16 {
    match TypedError::of_request(e) {
        Some(t) => t.http_status(),
        None => match e {
            RequestError::Backend(_) => 502,
            RequestError::Shutdown => 503,
            _ => 400,
        },
    }
}

/// JSON error-body `code` for any request-level failure.
pub fn code_of_request(e: &RequestError) -> &'static str {
    match TypedError::of_request(e) {
        Some(t) => t.wire_token(),
        None => match e {
            RequestError::Backend(_) => "backend-failure",
            RequestError::Shutdown => "shutting-down",
            _ => "bad-request",
        },
    }
}

/// HTTP status for any session-level failure.
pub fn http_status_of_session(e: &SessionError) -> u16 {
    match TypedError::of_session(e) {
        Some(t) => t.http_status(),
        None => match e {
            SessionError::Capacity { .. } => 503,
            SessionError::AlreadyOpen => 409,
            SessionError::Request(r) => http_status_of_request(r),
            _ => 500,
        },
    }
}

/// JSON error-body `code` for any session-level failure.
pub fn code_of_session(e: &SessionError) -> &'static str {
    match TypedError::of_session(e) {
        Some(t) => t.wire_token(),
        None => match e {
            SessionError::Capacity { .. } => "session-capacity",
            SessionError::AlreadyOpen => "session-already-open",
            SessionError::Request(r) => code_of_request(r),
            _ => "internal",
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_tokens_are_pinned() {
        // these strings are protocol: clients match on them (README
        // robustness vocabulary), and the parity suites compare them
        // byte-for-byte across cores and wire formats
        let want = [
            "overloaded",
            "deadline-exceeded",
            "unknown-session",
            "unknown-epoch",
            "snapshot-corrupt",
            "snapshot-io",
        ];
        for (t, w) in TypedError::ALL.iter().zip(want) {
            assert_eq!(t.wire_token(), w);
            assert_eq!(t.to_string(), w);
        }
    }

    #[test]
    fn display_impls_delegate_to_the_table() {
        assert_eq!(RequestError::Overloaded.to_string(), "overloaded");
        assert_eq!(RequestError::DeadlineExceeded.to_string(), "deadline-exceeded");
        assert_eq!(SessionError::UnknownSession.to_string(), "unknown-session");
        assert_eq!(SessionError::UnknownEpoch.to_string(), "unknown-epoch");
        assert_eq!(
            StoreError::Corrupt("x".into()).to_string(),
            "snapshot-corrupt: x"
        );
        assert_eq!(StoreError::Io("y".into()).to_string(), "snapshot-io: y");
        assert_eq!(
            SessionError::Snapshot(StoreError::Corrupt("m".into())).to_string(),
            "snapshot-corrupt: m"
        );
    }

    #[test]
    fn http_mapping_is_stable() {
        assert_eq!(TypedError::Overloaded.http_status(), 503);
        assert_eq!(TypedError::DeadlineExceeded.http_status(), 504);
        assert_eq!(TypedError::UnknownSession.http_status(), 404);
        assert_eq!(TypedError::UnknownEpoch.http_status(), 404);
        assert_eq!(TypedError::SnapshotCorrupt.http_status(), 500);
        assert_eq!(TypedError::SnapshotIo.http_status(), 500);
        assert_eq!(http_status_of_request(&RequestError::Empty), 400);
        assert_eq!(http_status_of_request(&RequestError::Backend("b".into())), 502);
        assert_eq!(http_status_of_session(&SessionError::Capacity { max: 4 }), 503);
        assert_eq!(http_status_of_session(&SessionError::AlreadyOpen), 409);
        assert_eq!(code_of_session(&SessionError::Request(RequestError::Overloaded)), "overloaded");
    }
}
