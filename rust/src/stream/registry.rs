//! The session registry: long-lived per-client hull state behind the
//! stateless serving pipeline.
//!
//! Concurrency protocol (the part PR 3's id-echo bugfix list cares
//! about):
//!
//! * every session sits behind its own mutex, so `SADD`s from one client
//!   serialize while distinct sessions ride different pool workers;
//! * the eviction sweep takes that per-session lock (`try_lock` — a
//!   session busy in an `SADD`/merge is by definition not idle) *before*
//!   deciding, marks the slot `evicted` under the lock, and only then
//!   removes the map entry.  An operation that raced the sweep and still
//!   holds an `Arc` to the slot observes the `evicted` flag after
//!   acquiring the lock and reports `unknown-session` instead of
//!   mutating a ghost;
//! * lock order is strictly slot-then-map for the sweeper and
//!   map-without-slot for operations (ops only clone the `Arc` under the
//!   map lock), so no cycle exists;
//! * `close` removes the map entry first (no new operation can find the
//!   session), then waits on the slot lock so an in-flight `SADD`
//!   completes before the gauges are settled;
//! * the sweeper thread *parks* (no timeout) while zero sessions are
//!   open — an idle server does no periodic work.  The open count lives
//!   under the sweeper condvar's own mutex so the first `SOPEN` can never
//!   be a lost wakeup, and the sweeper re-parks whenever the map empties.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::{Metrics, RequestError};
use crate::geometry::point::Point;
use crate::log_warn;
use crate::store::{self, SessionState, SnapshotStore, StoreError};

use super::session::{AddOutcome, HullService, Session};

/// Streaming-session knobs (config file: `[stream]`).
#[derive(Clone, Debug)]
pub struct StreamConfig {
    /// open-session cap; `SOPEN` beyond it fails (after an eviction
    /// sweep gets a chance to free idle slots).
    pub max_sessions: usize,
    /// pending-buffer bound: a session re-hulls when this many points
    /// pend (min 1).
    pub merge_threshold: usize,
    /// idle eviction TTL in milliseconds; 0 disables eviction.
    pub idle_ttl_ms: u64,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig { max_sessions: 1024, merge_threshold: 4096, idle_ttl_ms: 60_000 }
    }
}

impl StreamConfig {
    /// Cap the merge threshold at the serving backend's per-request
    /// limit.  A threshold above `max_points` could never merge: every
    /// re-hull of the pending set would be rejected as TooLarge, the
    /// session would brick, and the "bounded pending buffer" guarantee
    /// would silently become unbounded growth.
    pub fn clamp_threshold_to(mut self, max_points: usize) -> StreamConfig {
        self.merge_threshold = self.merge_threshold.min(max_points.max(1));
        self
    }
}

/// Session-level failures (distinct from request-level [`RequestError`]).
#[derive(Clone, Debug, PartialEq)]
pub enum SessionError {
    /// sid never existed, was closed, or was evicted.
    UnknownSession,
    /// `SHULL <sid> <epoch>` for an epoch the session never reached.
    UnknownEpoch,
    /// registry is at `max_sessions`.
    Capacity { max: usize },
    /// install/restore target sid is already live on this registry.
    AlreadyOpen,
    /// snapshot store failure (typed: the wire message starts with
    /// `snapshot-corrupt` / `snapshot-io`).
    Snapshot(StoreError),
    /// the insert/merge failed at the request layer.
    Request(RequestError),
}

impl std::fmt::Display for SessionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            // typed tokens come from the shared table in `crate::errors`
            SessionError::UnknownSession => {
                f.write_str(crate::errors::TypedError::UnknownSession.wire_token())
            }
            SessionError::UnknownEpoch => {
                f.write_str(crate::errors::TypedError::UnknownEpoch.wire_token())
            }
            SessionError::Capacity { max } => write!(f, "session capacity {max} reached"),
            SessionError::AlreadyOpen => write!(f, "session already open"),
            SessionError::Snapshot(e) => write!(f, "{e}"),
            SessionError::Request(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for SessionError {}

/// `SHULL` payload: the authoritative hull (pending flushed) plus the
/// epoch that produced it.
#[derive(Clone, Debug, PartialEq)]
pub struct SessionHullSnapshot {
    pub epoch: u64,
    pub upper: Vec<Point>,
    pub lower: Vec<Point>,
}

struct SlotState {
    session: Session,
    last_used: Instant,
    evicted: bool,
}

struct Slot {
    state: Mutex<SlotState>,
}

/// Sweeper wake state.  `open` mirrors the session-map size *under the
/// condvar's own mutex*: the sweeper's "anything to watch?" check and its
/// wait are atomic against open/close, so a session opened between the
/// two can never be a lost wakeup.  (The map mutex cannot play this role —
/// waiting on a condvar releases only the mutex it is paired with.)
struct SweepState {
    stopped: bool,
    open: usize,
}

struct Inner {
    sessions: Mutex<HashMap<u64, Arc<Slot>>>,
    next_sid: AtomicU64,
    /// sid allocation stride: a registry embedded as engine shard `i` of
    /// `N` hands out sids ≡ i+1 (mod N), so `(sid - 1) % N` routes any
    /// sid back to the shard that owns it for the session's lifetime.
    sid_stride: u64,
    sid_base: u64,
    cfg: StreamConfig,
    metrics: Arc<Metrics>,
    /// Snapshot store: when present, sessions checkpoint on merge, on
    /// close, on idle eviction, and on registry drop (clean shutdown).
    store: Option<Arc<dyn SnapshotStore>>,
    wake: Arc<(Mutex<SweepState>, Condvar)>,
}

impl Inner {
    /// Best-effort checkpoint of a locked session.  A write failure is
    /// logged and counted nowhere — the in-memory session stays
    /// authoritative and the next merge retries (callers that NEED the
    /// write to succeed, e.g. eviction, use [`Inner::checkpoint_strict`]).
    fn checkpoint(&self, sid: u64, session: &Session) {
        if let Err(e) = self.checkpoint_strict(sid, session) {
            log_warn!("session {sid}: checkpoint failed: {e}");
        }
    }

    /// Checkpoint and surface the failure.  No-op without a store.
    fn checkpoint_strict(&self, sid: u64, session: &Session) -> Result<(), StoreError> {
        let Some(st) = &self.store else {
            return Ok(());
        };
        let report = store::write_snapshot(st.as_ref(), sid, &session.snapshot_state())?;
        Metrics::inc(&self.metrics.snapshots_written);
        Metrics::add(&self.metrics.snapshot_bytes, report.bytes_written);
        Ok(())
    }
}

impl Inner {
    /// Track a map-size transition and (on 0 → 1) unpark the sweeper.
    /// Lock order is map/slot → wake, never the reverse: callers may hold
    /// the map lock (insert MUST, so the +1 lands before the sid is
    /// visible to a racing close), while the sweeper drops the wake mutex
    /// before touching map or slot locks — so the order stays acyclic.
    fn shift_open(&self, delta: isize) {
        let (lock, cv) = &*self.wake;
        let mut st = lock_ignore_poison(lock);
        let was = st.open;
        st.open = st.open.checked_add_signed(delta).expect("open-session underflow");
        if was == 0 && st.open > 0 {
            cv.notify_all();
        }
    }
}

/// Shared registry of open sessions (wrap in `Arc` to share with the
/// server).  Owns the idle-eviction sweeper thread; dropping the registry
/// stops and joins it.
pub struct SessionRegistry {
    inner: Arc<Inner>,
    sweeper: Option<JoinHandle<()>>,
}

fn lock_ignore_poison<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    // registry critical sections are short and panic-free; a poisoned
    // mutex (panic elsewhere on the thread) must not wedge serving
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

impl SessionRegistry {
    /// Build a registry sharing the coordinator's metrics sink (the
    /// session gauges ride the same STATS snapshot).
    pub fn new(cfg: StreamConfig, metrics: Arc<Metrics>) -> SessionRegistry {
        Self::new_striped(cfg, metrics, 1, 1)
    }

    /// [`SessionRegistry::new`] for an engine shard: sids start at
    /// `sid_base` and step by `sid_stride`, so shard `i` of `N`
    /// (`sid_base = i + 1`, `sid_stride = N`) allocates exactly the sids
    /// that `(sid - 1) % N == i` routes back to it.  `(1, 1)` is the
    /// standalone registry (every sid, stride one — today's behaviour).
    pub fn new_striped(
        cfg: StreamConfig,
        metrics: Arc<Metrics>,
        sid_base: u64,
        sid_stride: u64,
    ) -> SessionRegistry {
        Self::new_striped_with_store(cfg, metrics, sid_base, sid_stride, None)
    }

    /// [`SessionRegistry::new_striped`] plus a snapshot store: sessions
    /// checkpoint on merge/close/evict/shutdown and can be restored or
    /// adopted at explicit sids ([`SessionRegistry::install`]).
    pub fn new_striped_with_store(
        cfg: StreamConfig,
        metrics: Arc<Metrics>,
        sid_base: u64,
        sid_stride: u64,
        store: Option<Arc<dyn SnapshotStore>>,
    ) -> SessionRegistry {
        assert!(sid_base >= 1 && sid_stride >= 1, "sid striping must start at 1");
        let inner = Arc::new(Inner {
            sessions: Mutex::new(HashMap::new()),
            next_sid: AtomicU64::new(sid_base),
            sid_stride,
            sid_base,
            cfg,
            metrics,
            store,
            wake: Arc::new((
                Mutex::new(SweepState { stopped: false, open: 0 }),
                Condvar::new(),
            )),
        });
        let sweeper = if inner.cfg.idle_ttl_ms > 0 {
            let inner2 = inner.clone();
            let wake = inner.wake.clone();
            let interval =
                Duration::from_millis((inner.cfg.idle_ttl_ms / 4).clamp(10, 1000));
            Some(
                std::thread::Builder::new()
                    .name("hull-session-sweep".into())
                    .spawn(move || {
                        let (lock, cv) = &*wake;
                        let mut st = lock_ignore_poison(lock);
                        loop {
                            // park (no timeout) while zero sessions are
                            // open: an idle server does no periodic work.
                            // shift_open's 0→1 notify unparks us; the
                            // check and the wait share `lock`, so the
                            // wakeup cannot be lost.
                            while !st.stopped && st.open == 0 {
                                st = cv.wait(st).unwrap_or_else(PoisonError::into_inner);
                            }
                            if st.stopped {
                                return;
                            }
                            let (guard, _) = cv
                                .wait_timeout(st, interval)
                                .unwrap_or_else(PoisonError::into_inner);
                            st = guard;
                            if st.stopped {
                                return;
                            }
                            drop(st); // sweep takes map/slot locks: never under `lock`
                            sweep(&inner2);
                            st = lock_ignore_poison(lock);
                            // loop: if the sweep (or closes) emptied the
                            // map, the condition above re-parks us
                        }
                    })
                    .expect("spawn session sweeper"),
            )
        } else {
            None
        };
        SessionRegistry { inner, sweeper }
    }

    /// Open a session; returns its token.  At capacity an eviction sweep
    /// runs first — only genuinely live sessions can exhaust the cap.
    pub fn open(&self) -> Result<u64, SessionError> {
        {
            let map = lock_ignore_poison(&self.inner.sessions);
            if map.len() < self.inner.cfg.max_sessions {
                return Ok(self.insert_session(map));
            }
        }
        sweep(&self.inner); // a second chance: reap idle slots now
        let map = lock_ignore_poison(&self.inner.sessions);
        if map.len() < self.inner.cfg.max_sessions {
            Ok(self.insert_session(map))
        } else {
            Err(SessionError::Capacity { max: self.inner.cfg.max_sessions })
        }
    }

    fn insert_session(&self, mut map: MutexGuard<'_, HashMap<u64, Arc<Slot>>>) -> u64 {
        let sid = self.inner.next_sid.fetch_add(self.inner.sid_stride, Ordering::Relaxed);
        map.insert(
            sid,
            Arc::new(Slot {
                state: Mutex::new(SlotState {
                    session: Session::new(self.inner.cfg.merge_threshold),
                    last_used: Instant::now(),
                    evicted: false,
                }),
            }),
        );
        // count the open BEFORE the sid becomes visible (the map guard is
        // still held): a racer guessing the striped sid and closing it
        // immediately must find the +1 already applied, or its -1 would
        // underflow.  Taking the wake mutex under the map lock is safe —
        // the sweeper never takes the map lock while holding it.
        Metrics::inc(&self.inner.metrics.open_sessions);
        self.inner.shift_open(1);
        drop(map);
        sid
    }

    /// Run `f` under the session's lock, refreshing its idle clock.
    fn with_session<R>(
        &self,
        sid: u64,
        f: impl FnOnce(&mut Session) -> Result<R, SessionError>,
    ) -> Result<R, SessionError> {
        let slot = lock_ignore_poison(&self.inner.sessions)
            .get(&sid)
            .cloned()
            .ok_or(SessionError::UnknownSession)?;
        let mut st = lock_ignore_poison(&slot.state);
        if st.evicted {
            return Err(SessionError::UnknownSession);
        }
        let r = f(&mut st.session);
        st.last_used = Instant::now();
        r
    }

    /// `SADD`: validate, interior-reject, pend, merge on threshold.
    /// Every completed merge checkpoints (when a store is configured) —
    /// epoch advances are the durability points.
    pub fn add(
        &self,
        sid: u64,
        points: &[Point],
        svc: &dyn HullService,
    ) -> Result<AddOutcome, SessionError> {
        let m = &self.inner.metrics;
        let inner = &self.inner;
        self.with_session(sid, |s| {
            let (pend0, abs0, epoch0) = (s.pending_len() as u64, s.absorbed_total(), s.epoch());
            let result = s.add(points, svc);
            record_session_deltas(m, s, pend0, abs0);
            if s.epoch() != epoch0 {
                inner.checkpoint(sid, s);
            }
            result.map_err(SessionError::Request)
        })
    }

    /// `SHULL`: flush pending, return the authoritative hull + epoch.
    pub fn hull(
        &self,
        sid: u64,
        svc: &dyn HullService,
    ) -> Result<SessionHullSnapshot, SessionError> {
        let m = &self.inner.metrics;
        let inner = &self.inner;
        self.with_session(sid, |s| {
            let (pend0, abs0, epoch0) = (s.pending_len() as u64, s.absorbed_total(), s.epoch());
            let result = s.flush(svc);
            record_session_deltas(m, s, pend0, abs0);
            if s.epoch() != epoch0 {
                inner.checkpoint(sid, s);
            }
            result.map_err(SessionError::Request)?;
            let (u, l) = s.hull();
            Ok(SessionHullSnapshot {
                epoch: s.epoch(),
                upper: u.to_vec(),
                lower: l.to_vec(),
            })
        })
    }

    /// `SHULL <sid> <epoch>`: time-travel read from the epoch ledger.  No
    /// flush — a historical hull is immutable by definition; the epoch
    /// echoed back is the requested one.
    pub fn hull_at(&self, sid: u64, epoch: u64) -> Result<SessionHullSnapshot, SessionError> {
        self.with_session(sid, |s| match s.hull_at(epoch) {
            None => Err(SessionError::UnknownEpoch),
            Some((u, l)) => Ok(SessionHullSnapshot {
                epoch,
                upper: u.to_vec(),
                lower: l.to_vec(),
            }),
        })
    }

    /// `SCLOSE`: flush (final merge — buffered pending points must not
    /// silently vanish; the flush counts in `merges_total` like any
    /// other), checkpoint, then unregister.  A flush failure still closes
    /// the session: with a store the checkpoint retains the un-merged
    /// pending points, so nothing is lost durably; without one this
    /// degrades to the historical drop-pending behaviour.  Waits for an
    /// in-flight operation to finish.
    pub fn close(&self, sid: u64, svc: &dyn HullService) -> Result<(), SessionError> {
        let slot = lock_ignore_poison(&self.inner.sessions)
            .remove(&sid)
            .ok_or(SessionError::UnknownSession)?;
        self.inner.shift_open(-1); // sweeper re-parks once the map empties
        let mut st = lock_ignore_poison(&slot.state);
        st.evicted = true; // a racer still holding the Arc sees a tombstone
        let m = &self.inner.metrics;
        let (pend0, abs0) = (st.session.pending_len() as u64, st.session.absorbed_total());
        if st.session.flush(svc).is_err() {
            log_warn!("session {sid}: final flush failed; closing with pending buffered");
        }
        record_session_deltas(m, &mut st.session, pend0, abs0);
        self.inner.checkpoint(sid, &st.session);
        Metrics::sub(&m.open_sessions, 1);
        Metrics::sub(&m.session_pending_points, st.session.pending_len() as u64);
        Ok(())
    }

    /// Install a session at an explicit sid: snapshot restore
    /// ([`crate::store::read_snapshot`] -> [`Session::from_state`]) and
    /// rebalance adoption both land here.  Fails `AlreadyOpen` if the sid
    /// is live and `Capacity` when full (after an eviction sweep).  When
    /// the sid lies on this registry's stripe, the sid allocator is
    /// bumped past it so a later `SOPEN` can never re-issue it.
    pub fn install(&self, sid: u64, state: SessionState) -> Result<(), SessionError> {
        let mut map = lock_ignore_poison(&self.inner.sessions);
        if map.len() >= self.inner.cfg.max_sessions {
            drop(map);
            sweep(&self.inner);
            map = lock_ignore_poison(&self.inner.sessions);
            if map.len() >= self.inner.cfg.max_sessions {
                return Err(SessionError::Capacity { max: self.inner.cfg.max_sessions });
            }
        }
        if map.contains_key(&sid) {
            return Err(SessionError::AlreadyOpen);
        }
        let stride = self.inner.sid_stride;
        if sid % stride == self.inner.sid_base % stride {
            // aligned: next_sid steps in this residue class, sid + stride
            // is the next member past sid.  (Engine-allocated sids under
            // ring placement may be off-stripe; the registry allocator is
            // unused then and must not be knocked off its residue.)
            self.inner.next_sid.fetch_max(sid + stride, Ordering::Relaxed);
        }
        let session = Session::from_state(state);
        let pending = session.pending_len() as u64;
        map.insert(
            sid,
            Arc::new(Slot {
                state: Mutex::new(SlotState {
                    session,
                    last_used: Instant::now(),
                    evicted: false,
                }),
            }),
        );
        let m = &self.inner.metrics;
        Metrics::inc(&m.open_sessions);
        Metrics::add(&m.session_pending_points, pending);
        self.inner.shift_open(1);
        drop(map);
        Ok(())
    }

    /// Remove a live session and hand back its checkpoint state (the
    /// rebalance donor half; the recipient shard `install`s it).  Waits
    /// for an in-flight operation, exactly like close, but writes no
    /// final snapshot and counts no eviction — the session is moving, not
    /// ending.
    pub fn detach(&self, sid: u64) -> Result<SessionState, SessionError> {
        let slot = lock_ignore_poison(&self.inner.sessions)
            .remove(&sid)
            .ok_or(SessionError::UnknownSession)?;
        self.inner.shift_open(-1);
        let mut st = lock_ignore_poison(&slot.state);
        st.evicted = true; // racers re-route via the engine's override map
        let m = &self.inner.metrics;
        Metrics::sub(&m.open_sessions, 1);
        Metrics::sub(&m.session_pending_points, st.session.pending_len() as u64);
        Ok(st.session.snapshot_state())
    }

    /// Checkpoint every open session (clean shutdown).  Blocks on each
    /// session's lock so in-flight merges land in their snapshot.
    pub fn checkpoint_all(&self) {
        if self.inner.store.is_none() {
            return;
        }
        let snapshot: Vec<(u64, Arc<Slot>)> = lock_ignore_poison(&self.inner.sessions)
            .iter()
            .map(|(sid, slot)| (*sid, slot.clone()))
            .collect();
        for (sid, slot) in snapshot {
            let st = lock_ignore_poison(&slot.state);
            if !st.evicted {
                self.inner.checkpoint(sid, &st.session);
            }
        }
    }

    /// Currently open sessions.
    pub fn open_sessions(&self) -> usize {
        lock_ignore_poison(&self.inner.sessions).len()
    }

    /// This registry's open-session cap (an engine shard's slice of the
    /// global `max_sessions`).
    pub fn max_sessions(&self) -> usize {
        self.inner.cfg.max_sessions
    }

    /// The (possibly clamped) merge threshold sessions are built with.
    pub fn merge_threshold(&self) -> usize {
        self.inner.cfg.merge_threshold
    }

    /// The snapshot store sessions checkpoint to, if any (the engine
    /// facade borrows it for `SOPEN <sid>` restores and rebalance).
    pub fn store(&self) -> Option<Arc<dyn SnapshotStore>> {
        self.inner.store.clone()
    }

    /// Run one eviction sweep synchronously (tests; the sweeper thread
    /// calls the same routine on its interval).
    pub fn sweep_now(&self) {
        sweep(&self.inner);
    }
}

impl Drop for SessionRegistry {
    fn drop(&mut self) {
        {
            let (lock, cv) = &*self.inner.wake;
            lock_ignore_poison(lock).stopped = true;
            cv.notify_all();
        }
        if let Some(h) = self.sweeper.take() {
            let _ = h.join();
        }
        // clean-shutdown checkpoint: every open session's latest state
        // (including an un-merged pending tail) survives the restart
        self.checkpoint_all();
    }
}

/// Record the metric deltas of one session operation (shared by `add`
/// and `hull`).  Runs even when the operation failed mid-way: a backend
/// error can interrupt after points pended and merges ran, so the gauges
/// must track the session's actual state (or a later close/evict would
/// underflow them) and completed merges keep their counter + latency
/// sample (drained from the session, not a possibly-discarded return
/// value).
fn record_session_deltas(m: &Metrics, s: &mut Session, pend0: u64, abs0: u64) {
    Metrics::add(&m.session_absorbed_points, s.absorbed_total() - abs0);
    gauge_shift(&m.session_pending_points, pend0, s.pending_len() as u64);
    for ns in s.take_merge_samples() {
        Metrics::inc(&m.session_merges);
        m.session_merge_latency.record_ns(ns);
    }
}

/// Move a gauge from `before` to `after` without ever underflowing.
fn gauge_shift(gauge: &AtomicU64, before: u64, after: u64) {
    if after >= before {
        Metrics::add(gauge, after - before);
    } else {
        Metrics::sub(gauge, before - after);
    }
}

/// One eviction pass.  Slot lock first (`try_lock`: busy == not idle),
/// decision + tombstone under the lock, map removal after.
fn sweep(inner: &Inner) {
    if inner.cfg.idle_ttl_ms == 0 {
        return;
    }
    let ttl = Duration::from_millis(inner.cfg.idle_ttl_ms);
    let snapshot: Vec<(u64, Arc<Slot>)> = lock_ignore_poison(&inner.sessions)
        .iter()
        .map(|(sid, slot)| (*sid, slot.clone()))
        .collect();
    for (sid, slot) in snapshot {
        let Ok(mut st) = slot.state.try_lock() else {
            continue; // in-flight SADD/SHULL: the session is live
        };
        if st.evicted || st.last_used.elapsed() < ttl {
            continue;
        }
        // write the final snapshot BEFORE tombstoning: eviction must not
        // destroy session state when a store is configured.  If the write
        // fails the session is kept (retried next sweep) — an eviction
        // that loses data is worse than a missed TTL.
        if let Err(e) = inner.checkpoint_strict(sid, &st.session) {
            log_warn!("session {sid}: eviction checkpoint failed, keeping session: {e}");
            continue;
        }
        st.evicted = true;
        let pending = st.session.pending_len() as u64;
        drop(st);
        let mut map = lock_ignore_poison(&inner.sessions);
        if map.get(&sid).is_some_and(|s| Arc::ptr_eq(s, &slot)) {
            map.remove(&sid);
            drop(map);
            inner.shift_open(-1);
            Metrics::sub(&inner.metrics.open_sessions, 1);
            Metrics::sub(&inner.metrics.session_pending_points, pending);
            Metrics::inc(&inner.metrics.session_evictions);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::generators::{generate, Distribution};
    use crate::stream::session::tests::{oracle, SerialService};

    fn registry(cfg: StreamConfig) -> SessionRegistry {
        SessionRegistry::new(cfg, Arc::new(Metrics::default()))
    }

    #[test]
    fn open_add_hull_close_lifecycle() {
        let reg = registry(StreamConfig { merge_threshold: 32, ..Default::default() });
        let svc = SerialService;
        let sid = reg.open().unwrap();
        let pts = generate(Distribution::Disk, 200, 4);
        for chunk in pts.chunks(50) {
            reg.add(sid, chunk, &svc).unwrap();
        }
        let snap = reg.hull(sid, &svc).unwrap();
        let (wu, wl) = oracle(&pts);
        assert_eq!(snap.upper, wu);
        assert_eq!(snap.lower, wl);
        assert!(snap.epoch >= 1);
        reg.close(sid, &svc).unwrap();
        assert_eq!(reg.open_sessions(), 0);
        assert_eq!(reg.close(sid, &svc), Err(SessionError::UnknownSession));
        assert!(matches!(
            reg.add(sid, &pts[..1], &svc),
            Err(SessionError::UnknownSession)
        ));
    }

    #[test]
    fn capacity_cap_enforced() {
        let reg = registry(StreamConfig { max_sessions: 2, idle_ttl_ms: 0, ..Default::default() });
        let a = reg.open().unwrap();
        let _b = reg.open().unwrap();
        assert_eq!(reg.open(), Err(SessionError::Capacity { max: 2 }));
        reg.close(a, &SerialService).unwrap();
        reg.open().unwrap();
    }

    #[test]
    fn idle_sessions_evicted_after_ttl() {
        // sweeper disabled-ish (long interval via big ttl? no — drive
        // sweep_now by hand with a tiny ttl)
        let reg = registry(StreamConfig { idle_ttl_ms: 30, ..Default::default() });
        let svc = SerialService;
        let sid = reg.open().unwrap();
        reg.add(sid, &[Point::new(0.5, 0.5)], &svc).unwrap();
        std::thread::sleep(Duration::from_millis(60));
        reg.sweep_now();
        assert_eq!(reg.open_sessions(), 0);
        assert!(matches!(
            reg.add(sid, &[Point::new(0.1, 0.1)], &svc),
            Err(SessionError::UnknownSession)
        ));
    }

    #[test]
    fn metrics_track_sessions_and_pending() {
        let metrics = Arc::new(Metrics::default());
        let reg = SessionRegistry::new(
            StreamConfig { merge_threshold: 1000, idle_ttl_ms: 0, ..Default::default() },
            metrics.clone(),
        );
        let svc = SerialService;
        let sid = reg.open().unwrap();
        assert_eq!(metrics.open_sessions.load(Ordering::Relaxed), 1);
        let pts = generate(Distribution::Circle, 40, 2);
        reg.add(sid, &pts, &svc).unwrap();
        assert_eq!(metrics.session_pending_points.load(Ordering::Relaxed), 40);
        reg.hull(sid, &svc).unwrap(); // flush
        assert_eq!(metrics.session_pending_points.load(Ordering::Relaxed), 0);
        assert_eq!(metrics.session_merges.load(Ordering::Relaxed), 1);
        assert!(metrics.session_merge_latency.count() == 1);
        reg.close(sid, &svc).unwrap();
        assert_eq!(metrics.open_sessions.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn close_flushes_pending_and_counts_the_merge() {
        let metrics = Arc::new(Metrics::default());
        let reg = SessionRegistry::new(
            StreamConfig { merge_threshold: 1000, idle_ttl_ms: 0, ..Default::default() },
            metrics.clone(),
        );
        let svc = SerialService;
        let sid = reg.open().unwrap();
        let pts = generate(Distribution::Disk, 60, 8);
        reg.add(sid, &pts, &svc).unwrap(); // threshold never reached: all pend
        assert_eq!(metrics.session_merges.load(Ordering::Relaxed), 0);
        reg.close(sid, &svc).unwrap();
        // the final flush merged the buffered points and was counted
        assert_eq!(metrics.session_merges.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.session_pending_points.load(Ordering::Relaxed), 0);
        assert_eq!(metrics.session_merge_latency.count(), 1);
    }

    #[test]
    fn checkpoints_on_merge_close_and_evict() {
        use crate::store::{read_snapshot, MemStore};
        let store = Arc::new(MemStore::new());
        let metrics = Arc::new(Metrics::default());
        let reg = SessionRegistry::new_striped_with_store(
            StreamConfig { merge_threshold: 16, idle_ttl_ms: 0, ..Default::default() },
            metrics.clone(),
            1,
            1,
            Some(store.clone()),
        );
        let svc = SerialService;
        let pts = generate(Distribution::Circle, 40, 3);

        // merge-triggered checkpoint
        let sid = reg.open().unwrap();
        reg.add(sid, &pts, &svc).unwrap(); // 40 circle points: >=2 merges
        let written_after_add = metrics.snapshots_written.load(Ordering::Relaxed);
        assert!(written_after_add >= 2, "one checkpoint per merge");
        assert!(metrics.snapshot_bytes.load(Ordering::Relaxed) > 0);
        let snap = read_snapshot(store.as_ref(), sid).unwrap().unwrap();
        assert!(snap.epoch >= 2);

        // close writes the post-flush checkpoint (true hull, no pending)
        reg.close(sid, &svc).unwrap();
        let snap = read_snapshot(store.as_ref(), sid).unwrap().unwrap();
        assert!(snap.pending.is_empty(), "close flushed before checkpointing");
        assert_eq!(
            snap.inserted,
            snap.absorbed + Session::from_state(snap.clone()).hull_points()
        );

        // eviction writes a final snapshot before tombstoning (fresh
        // registry + store with a real TTL; sweep driven by hand)
        let store2 = Arc::new(MemStore::new());
        let reg2 = SessionRegistry::new_striped_with_store(
            StreamConfig { merge_threshold: 16, idle_ttl_ms: 25, ..Default::default() },
            Arc::new(Metrics::default()),
            1,
            1,
            Some(store2.clone()),
        );
        let sid2 = reg2.open().unwrap();
        reg2.add(sid2, &pts[..5], &svc).unwrap(); // pending only, no merge yet
        std::thread::sleep(Duration::from_millis(50));
        reg2.sweep_now();
        assert_eq!(reg2.open_sessions(), 0, "idle session evicted");
        let snap2 = read_snapshot(store2.as_ref(), sid2).unwrap().unwrap();
        assert_eq!(snap2.pending.len(), 5, "evict snapshot keeps un-merged pending");
        assert_eq!(snap2.inserted, 5);
    }

    #[test]
    fn install_restores_and_guards_sid_allocation() {
        let svc = SerialService;
        let reg = registry(StreamConfig { merge_threshold: 8, idle_ttl_ms: 0, ..Default::default() });
        let sid = reg.open().unwrap();
        let pts = generate(Distribution::Disk, 30, 6);
        reg.add(sid, &pts, &svc).unwrap();
        let state = reg.detach(sid).unwrap();
        assert_eq!(reg.open_sessions(), 0);
        assert!(matches!(reg.add(sid, &pts[..1], &svc), Err(SessionError::UnknownSession)));

        // install far ahead of the allocator, then confirm open() skips it
        reg.install(77, state.clone()).unwrap();
        assert_eq!(reg.install(77, state), Err(SessionError::AlreadyOpen));
        let snap = reg.hull(77, &svc).unwrap();
        let (wu, wl) = oracle(&pts);
        assert_eq!(snap.upper, wu);
        assert_eq!(snap.lower, wl);
        let fresh = reg.open().unwrap();
        assert!(fresh > 77, "allocator bumped past the installed sid, got {fresh}");
    }

    /// Striped allocation (engine shard 2 of 4): sids 3, 7, 11, … — every
    /// one routes back to this shard under `(sid - 1) % 4 == 2`.
    #[test]
    fn striped_sids_stay_on_their_residue_class() {
        let reg = SessionRegistry::new_striped(
            StreamConfig { idle_ttl_ms: 0, ..Default::default() },
            Arc::new(Metrics::default()),
            3,
            4,
        );
        let sids: Vec<u64> = (0..5).map(|_| reg.open().unwrap()).collect();
        assert_eq!(sids, vec![3, 7, 11, 15, 19]);
        for sid in sids {
            assert_eq!((sid - 1) % 4, 2);
        }
    }

    /// The parked-sweeper satellite: the sweeper thread itself (not a
    /// manual `sweep_now`) must evict an idle session after the first
    /// `SOPEN` unparks it, and a park → unpark → evict → re-park → unpark
    /// cycle must keep working (the second open lands after the map
    /// emptied and the sweeper went back to its no-timeout wait).
    #[test]
    fn sweeper_thread_unparks_on_first_open_and_reparks_when_empty() {
        let reg = registry(StreamConfig { idle_ttl_ms: 30, ..Default::default() });
        let svc = SerialService;
        let wait_evicted = |reg: &SessionRegistry| {
            let t0 = Instant::now();
            while reg.open_sessions() != 0 {
                assert!(
                    t0.elapsed() < Duration::from_secs(5),
                    "sweeper never evicted the idle session"
                );
                std::thread::sleep(Duration::from_millis(10));
            }
        };
        let sid = reg.open().unwrap();
        reg.add(sid, &[Point::new(0.5, 0.5)], &svc).unwrap();
        wait_evicted(&reg); // round 1: the open unparked the sweeper
        let sid2 = reg.open().unwrap();
        assert_ne!(sid, sid2);
        wait_evicted(&reg); // round 2: re-park then re-unpark still works
    }

    /// The satellite bugfix: an eviction sweep must never tear a session
    /// out from under an in-flight SADD.  The slow service pins the
    /// session lock across the merge while sweeps hammer the registry.
    #[test]
    fn eviction_never_races_an_inflight_add() {
        struct SlowService;
        impl HullService for SlowService {
            fn full_hull(
                &self,
                points: Vec<Point>,
            ) -> Result<(Vec<Point>, Vec<Point>), RequestError> {
                std::thread::sleep(Duration::from_millis(200));
                SerialService.full_hull(points)
            }
        }
        let reg = Arc::new(registry(StreamConfig {
            merge_threshold: 4,
            idle_ttl_ms: 150,
            ..Default::default()
        }));
        let sid = reg.open().unwrap();
        // the add's merges hold the session lock for ~400 ms — far past
        // the 150 ms TTL, so the session *looks* idle-expired (stale
        // last_used) exactly while an operation is in flight
        let reg2 = reg.clone();
        let worker = std::thread::spawn(move || {
            let pts = generate(Distribution::Disk, 8, 1);
            reg2.add(sid, &pts, &SlowService)
        });
        // sweeps during the in-flight add must skip the busy session
        for _ in 0..20 {
            reg.sweep_now();
            std::thread::sleep(Duration::from_millis(20));
        }
        let outcome = worker.join().unwrap();
        assert!(outcome.is_ok(), "in-flight SADD evicted: {outcome:?}");
        // the add refreshed the idle clock: the session is still live
        assert_eq!(reg.open_sessions(), 1);
        let snap = reg.hull(sid, &SerialService).unwrap();
        assert!(!snap.upper.is_empty());
        // ...and once genuinely idle again, eviction proceeds
        std::thread::sleep(Duration::from_millis(250));
        reg.sweep_now();
        assert_eq!(reg.open_sessions(), 0);
    }
}
