"""L2 correctness: full pipeline, full hull, batching."""

from __future__ import annotations

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model
from compile.kernels import ref

from .test_kernel import make_hood, sorted_points


@settings(max_examples=15, deadline=None)
@given(
    log_n=st.integers(1, 6),
    m_frac=st.floats(0.05, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_upper_hood_pipeline(log_n, m_frac, seed):
    n = 1 << log_n
    m = max(1, int(round(m_frac * n)))
    rng = np.random.default_rng(seed)
    hood0 = make_hood(sorted_points(rng, m), n)
    out = np.asarray(model.upper_hood(jnp.asarray(hood0)))
    np.testing.assert_array_equal(out, ref.ref_hood(hood0))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_full_hull(seed):
    n = 32
    rng = np.random.default_rng(seed)
    hood0 = make_hood(sorted_points(rng, n), n)
    up, lo = model.full_hull(jnp.asarray(hood0))
    np.testing.assert_array_equal(np.asarray(up), ref.ref_hood(hood0))
    np.testing.assert_array_equal(np.asarray(lo), ref.ref_lower_hood(hood0))


def test_full_hull_extremes_shared():
    """Leftmost/rightmost live points appear in both hoods."""
    rng = np.random.default_rng(9)
    hood0 = make_hood(sorted_points(rng, 64), 64)
    up, lo = (np.asarray(a) for a in model.full_hull(jnp.asarray(hood0)))
    upl, lol = up[ref.is_live(up)], lo[ref.is_live(lo)]
    np.testing.assert_array_equal(upl[0], lol[0])
    np.testing.assert_array_equal(upl[-1], lol[-1])


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), b=st.sampled_from([1, 3, 8]))
def test_batched_full_hull(seed, b):
    n = 16
    rng = np.random.default_rng(seed)
    batch = np.stack(
        [
            make_hood(sorted_points(rng, int(rng.integers(1, n + 1))), n)
            for _ in range(b)
        ]
    )
    up, lo = (np.asarray(a) for a in model.batched_full_hull(jnp.asarray(batch)))
    assert up.shape == lo.shape == (b, n, 2)
    for k in range(b):
        np.testing.assert_array_equal(up[k], ref.ref_hood(batch[k]))
        np.testing.assert_array_equal(lo[k], ref.ref_lower_hood(batch[k]))


def test_jnp_twin_matches_pallas_pipeline():
    rng = np.random.default_rng(13)
    hood0 = jnp.asarray(make_hood(sorted_points(rng, 256), 256))
    a = np.asarray(model.upper_hood(hood0))
    b = np.asarray(model.upper_hood_jnp(hood0))
    np.testing.assert_array_equal(a, b)


def test_hull_closed_polygon_orientation():
    """Upper + reversed lower forms a simple CCW-closed polygon boundary."""
    rng = np.random.default_rng(21)
    hood0 = make_hood(sorted_points(rng, 64), 64)
    up, lo = (np.asarray(a) for a in model.full_hull(jnp.asarray(hood0)))
    upl, lol = up[ref.is_live(up)], lo[ref.is_live(lo)]
    # boundary: lower left->right then upper right->left (CCW)
    poly = np.concatenate([lol, upl[::-1][1:-1]])
    x, y = poly[:, 0], poly[:, 1]
    area2 = float(np.sum(x * np.roll(y, -1) - np.roll(x, -1) * y))
    assert area2 > 0  # CCW
    # all input points inside or on hull: test via y-range at each x
    assert len(poly) >= 3 or len(upl) <= 2
