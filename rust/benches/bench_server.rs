//! E10 — connection cores and wire formats: the readiness-driven event
//! loop vs the thread-per-connection shim, and text vs binary framing.
//!
//! Two reports:
//!   * E10: request round-trips through a server holding N idle
//!     connections while M adder threads pound streaming sessions —
//!     the threaded baseline pays a parked thread per idle connection,
//!     the event loop (io_threads = 1/2/4) multiplexes them;
//!   * E10b: frame-decode and encode micro rows, text vs binary, where
//!     the packed little-endian format skips all float parsing.
//!
//! Run: `cargo bench --bench bench_server` (tier1.sh feeds
//! BENCH_server.json via WAGENER_BENCH_JSON; WAGENER_BENCH_FAST=1
//! shrinks the fleet and the sampling budget).

use std::io::{BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use wagener_hull::benchkit::{black_box, Bencher, Report};
use wagener_hull::coordinator::{BackendKind, BatcherConfig, CoordinatorConfig};
use wagener_hull::engine::{Engine, EngineConfig};
use wagener_hull::geometry::generators::{generate, Distribution};
use wagener_hull::server::{
    frame, proto, serve_engine, serve_engine_threaded, HullClient, Request, Response,
    ServerConfig, ServerHandle, WireProto,
};
use wagener_hull::stream::StreamConfig;

fn start_engine() -> Arc<Engine> {
    Arc::new(
        Engine::start(EngineConfig {
            shards: 1,
            coordinator: CoordinatorConfig {
                backend: BackendKind::Serial,
                batcher: BatcherConfig { max_batch: 4, flush_us: 200, queue_cap: 1024 },
                self_check: false,
                ..Default::default()
            },
            stream: StreamConfig::default(),
            ..Default::default()
        })
        .unwrap(),
    )
}

fn main() {
    let b = Bencher::default();
    let fast = std::env::var("WAGENER_BENCH_FAST").is_ok();
    let idle_target: usize = if fast { 64 } else { 1024 };
    let adders: usize = if fast { 2 } else { 4 };
    // idle fleet + adders + bench clients all live in this process: make
    // sure the fd budget holds both socket ends, or shrink the fleet
    #[cfg(unix)]
    let idle_target = {
        let got = wagener_hull::server::raise_nofile_limit((idle_target as u64) * 2 + 512);
        idle_target.min((got.saturating_sub(512) / 2) as usize)
    };

    let mut report = Report::new(&format!(
        "E10: connection cores — {idle_target} idle conns + {adders} session adders (serial backend)"
    ));

    // (label, threaded-shim?, io_threads)
    let cores: &[(&str, bool, usize)] = &[
        ("threaded", true, 0),
        ("event_io1", false, 1),
        ("event_io2", false, 2),
        ("event_io4", false, 4),
    ];
    for &(label, threaded, io_threads) in cores {
        let cfg = ServerConfig { addr: "127.0.0.1:0".into(), io_threads, ..Default::default() };
        let handle: ServerHandle = if threaded {
            serve_engine_threaded(start_engine(), &cfg).unwrap()
        } else {
            serve_engine(start_engine(), &cfg).unwrap()
        };
        let addr = handle.local_addr;

        // park the idle fleet (the threaded shim pays a thread each)
        let mut idle: Vec<TcpStream> = Vec::with_capacity(idle_target);
        for _ in 0..idle_target {
            match TcpStream::connect(addr) {
                Ok(s) => idle.push(s),
                Err(_) => break,
            }
        }

        // M adder threads keep streaming sessions hot in the background
        let stop = Arc::new(AtomicBool::new(false));
        let mut adder_threads = Vec::with_capacity(adders);
        for t in 0..adders {
            let stop = stop.clone();
            adder_threads.push(std::thread::spawn(move || {
                let mut c = HullClient::connect_with(addr, WireProto::Binary).unwrap();
                c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
                let sid = c.session_open().unwrap();
                let pts = generate(Distribution::Disk, 64, 900 + t as u64);
                while !stop.load(Ordering::Relaxed) {
                    if c.session_add(sid, &pts).is_err() {
                        break;
                    }
                }
                let _ = c.session_close(sid);
            }));
        }

        // measured client: round-trip latency through the crowd
        let mut ct = HullClient::connect_with(addr, WireProto::Text).unwrap();
        let mut cb = HullClient::connect_with(addr, WireProto::Binary).unwrap();
        ct.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        cb.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let pts = generate(Distribution::Disk, 64, 42);

        report.add(b.run(&format!("server/{label}/ping_rtt"), || ct.ping().unwrap()));
        report.add(b.run(&format!("server/{label}/hull64_text_rtt"), || {
            ct.hull(&pts).unwrap().upper.len()
        }));
        report.add(
            b.run(&format!("server/{label}/hull64_binary_rtt"), || {
                cb.hull(&pts).unwrap().upper.len()
            }),
        );

        // pipelined binary pings: per-frame cost once syscalls amortize
        let mut batch = Vec::new();
        for _ in 0..64 {
            frame::encode_request(&mut batch, &Request::Ping);
        }
        let pipe = TcpStream::connect(addr).unwrap();
        pipe.set_nodelay(true).unwrap();
        pipe.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut pipe_reader = BufReader::new(pipe.try_clone().unwrap());
        let mut pipe_writer = pipe;
        report.add(b.run_batched(&format!("server/{label}/pipelined_ping_x64"), 64, || {
            pipe_writer.write_all(&batch).unwrap();
            for _ in 0..64 {
                frame::read_response(&mut pipe_reader).unwrap();
            }
        }));

        report.note(format!(
            "{label}: gauge held {} connections during the run",
            handle.active_connections()
        ));

        stop.store(true, Ordering::SeqCst);
        for t in adder_threads {
            let _ = t.join();
        }
        drop((ct, cb, pipe_writer, pipe_reader, idle));
        handle.stop();
    }
    report.finish();

    // ---------------------------------------------- E10b: frame micro
    let mut report = Report::new("E10b: frame decode/encode — text vs binary");
    for n in [16usize, 1024] {
        let req = Request::Hull { id: 1, points: generate(Distribution::Disk, n, 7), tmo_ms: None };
        let mut bin = Vec::new();
        frame::encode_request(&mut bin, &req);
        let mut txt = Vec::new();
        proto::write_request(&mut txt, &req).unwrap();
        report.add(b.run(&format!("decode/text_hull_n{n}"), || {
            match proto::decode_text_request(black_box(&txt)) {
                Ok(proto::Decoded::Frame(r, _)) => r,
                other => panic!("{other:?}"),
            }
        }));
        report.add(b.run(&format!("decode/binary_hull_n{n}"), || {
            match frame::decode_request(black_box(&bin)) {
                Ok(proto::Decoded::Frame(r, _)) => r,
                other => panic!("{other:?}"),
            }
        }));
        report.note(format!("n={n}: {} text bytes vs {} binary bytes", txt.len(), bin.len()));
    }
    {
        let pts = generate(Distribution::Circle, 256, 9);
        let resp = Response::Hull {
            id: 1,
            upper: pts[..128].to_vec(),
            lower: pts[128..].to_vec(),
            backend: "serial".into(),
            queue_ns: 1234,
            exec_ns: 5678,
        };
        report.add(b.run("encode/text_hull_resp_k256", || {
            let mut v = Vec::new();
            proto::write_response(&mut v, &resp).unwrap();
            v.len()
        }));
        report.add(b.run("encode/binary_hull_resp_k256", || {
            let mut v = Vec::new();
            frame::encode_response(&mut v, &resp);
            v.len()
        }));
    }
    report.finish();
}
