//! Streaming-session subsystem end-to-end: incremental ≡ batch on every
//! host backend, exact accounting, and the eviction/capacity rules —
//! the acceptance gates of the streaming PR.
//!
//! Reproduce any property failure with WAGENER_PROP_SEED=<seed>.

use std::sync::Arc;

use wagener_hull::coordinator::{BackendKind, Coordinator, CoordinatorConfig};
use wagener_hull::geometry::generators::{generate, Distribution};
use wagener_hull::geometry::point::{sort_by_x, Point};
use wagener_hull::prop_assert;
use wagener_hull::serial::monotone_chain;
use wagener_hull::stream::{SessionError, SessionRegistry, StreamConfig};
use wagener_hull::util::property::check;
use wagener_hull::util::rng::Rng;

fn coord(kind: BackendKind) -> Arc<Coordinator> {
    Arc::new(
        Coordinator::start(CoordinatorConfig { backend: kind, ..Default::default() }).unwrap(),
    )
}

fn registry(coord: &Coordinator, threshold: usize) -> SessionRegistry {
    SessionRegistry::new(
        StreamConfig { merge_threshold: threshold, idle_ttl_ms: 0, ..Default::default() },
        coord.metrics.clone(),
    )
}

// One-shot oracle over the raw insert history (quantize + sort + dedup +
// exact hull, the coordinator's canonicalization); cross-checked against
// the plain monotone chain in the acceptance test below.
use wagener_hull::coordinator::backend::canonical_full_hull as mc_oracle;

/// THE acceptance gate: a session fed 2^16 points in 64 batches returns
/// a hull bit-identical to a one-shot HULL of the same points, on every
/// host backend, with `absorbed + pending + hull` accounting exact.
#[test]
fn acceptance_2e16_in_64_batches_bit_identical_on_every_host_backend() {
    let n = 1usize << 16;
    let pts = generate(Distribution::Disk, n, 42);
    for kind in [BackendKind::Native, BackendKind::Serial, BackendKind::Pram] {
        let c = coord(kind);
        let reg = registry(&c, 4096);
        let sid = reg.open().unwrap();
        let batch = pts.len() / 64;
        let mut last = None;
        for chunk in pts.chunks(batch) {
            last = Some(reg.add(sid, chunk, &*c).unwrap());
        }
        let outcome = last.unwrap();
        let snap = reg.hull(sid, &*c).unwrap();

        // bit-identity against the one-shot path on the same backend
        let oneshot = c.compute(pts.clone()).unwrap();
        assert_eq!(snap.upper, oneshot.upper, "{} upper diverged", kind.name());
        assert_eq!(snap.lower, oneshot.lower, "{} lower diverged", kind.name());
        // ...and against the serial oracle (which itself must agree with
        // the plain monotone chain on the generator's distinct-x set)
        let (wu, wl) = mc_oracle(&pts);
        assert_eq!((wu.clone(), wl.clone()), monotone_chain::full_hull(&pts));
        assert_eq!(snap.upper, wu, "{} upper vs oracle", kind.name());
        assert_eq!(snap.lower, wl, "{} lower vs oracle", kind.name());

        // exact accounting: every inserted point is absorbed, pending, or
        // a hull vertex — in the session ledger AND the shared metrics
        let mut verts: Vec<Point> =
            snap.upper.iter().chain(snap.lower.iter()).copied().collect();
        sort_by_x(&mut verts);
        verts.dedup();
        let m = c.snapshot().0;
        let absorbed = m.get("absorbed_points_total").unwrap().as_usize().unwrap();
        let pending = m.get("pending_points_total").unwrap().as_usize().unwrap();
        assert_eq!(pending, 0, "{}: SHULL flushed", kind.name());
        assert_eq!(
            absorbed + pending + verts.len(),
            n,
            "{}: absorbed+pending+hull accounting",
            kind.name()
        );
        assert!(outcome.absorbed as usize <= absorbed);
        assert!(
            m.get("merges_total").unwrap().as_usize().unwrap() >= 1,
            "{}: merges recorded",
            kind.name()
        );
        assert_eq!(m.get("open_sessions").unwrap().as_usize(), Some(1));
        reg.close(sid, &*c).unwrap();
        assert_eq!(c.snapshot().0.get("open_sessions").unwrap().as_usize(), Some(0));
    }
}

/// incremental ≡ batch under random insert schedules: every generator
/// distribution (incl. the collinear-heavy ones), random batch sizes,
/// random merge thresholds, and re-inserted duplicates.
#[test]
fn prop_incremental_equals_batch() {
    let c = coord(BackendKind::Native);
    check("stream-incremental-vs-batch", 40, |rng: &mut Rng| {
        let dist = Distribution::ALL[rng.range_usize(0, Distribution::ALL.len())];
        let n = rng.range_usize(1, 1500);
        let pts = generate(dist, n, rng.next_u64());
        let threshold = rng.range_usize(1, 400);
        let reg = registry(&c, threshold);
        let sid = reg.open().map_err(|e| e.to_string())?;
        let mut fed: Vec<Point> = Vec::new();
        let mut rest = &pts[..];
        while !rest.is_empty() {
            let take = rng.range_usize(1, rest.len() + 1);
            reg.add(sid, &rest[..take], &*c).map_err(|e| e.to_string())?;
            fed.extend_from_slice(&rest[..take]);
            // sometimes re-feed an earlier slice: duplicates must be
            // absorbed without disturbing the hull
            if rng.chance(0.3) && !fed.is_empty() {
                let k = rng.range_usize(0, fed.len());
                let dup: Vec<Point> = fed[k..].iter().copied().take(20).collect();
                reg.add(sid, &dup, &*c).map_err(|e| e.to_string())?;
                fed.extend(dup);
            }
            rest = &rest[take..];
        }
        let snap = reg.hull(sid, &*c).map_err(|e| e.to_string())?;
        let (wu, wl) = mc_oracle(&fed);
        prop_assert!(
            snap.upper == wu,
            "{} n={n} threshold={threshold}: upper diverged",
            dist.name()
        );
        prop_assert!(
            snap.lower == wl,
            "{} n={n} threshold={threshold}: lower diverged",
            dist.name()
        );
        reg.close(sid, &*c).map_err(|e| e.to_string())?;
        Ok(())
    });
}

/// Interleaved sessions stay isolated: two sessions fed different sets
/// through the same registry/coordinator never contaminate each other.
#[test]
fn sessions_are_isolated() {
    let c = coord(BackendKind::Native);
    let reg = registry(&c, 64);
    let a_pts = generate(Distribution::Circle, 400, 7);
    let b_pts = generate(Distribution::Valley, 400, 8);
    let a = reg.open().unwrap();
    let b = reg.open().unwrap();
    for (ca, cb) in a_pts.chunks(100).zip(b_pts.chunks(100)) {
        reg.add(a, ca, &*c).unwrap();
        reg.add(b, cb, &*c).unwrap();
    }
    let sa = reg.hull(a, &*c).unwrap();
    let sb = reg.hull(b, &*c).unwrap();
    let (wa_u, wa_l) = mc_oracle(&a_pts);
    let (wb_u, wb_l) = mc_oracle(&b_pts);
    assert_eq!((sa.upper, sa.lower), (wa_u, wa_l));
    assert_eq!((sb.upper, sb.lower), (wb_u, wb_l));
}

/// Epochs advance once per merge and SHULL reports the epoch that
/// produced the hull it returns.
#[test]
fn epochs_are_coherent() {
    let c = coord(BackendKind::Native);
    let reg = registry(&c, 100);
    let sid = reg.open().unwrap();
    let pts = generate(Distribution::Circle, 250, 3);
    let out = reg.add(sid, &pts, &*c).unwrap();
    assert_eq!(out.epoch, 2, "250 circle points / threshold 100 = 2 merges");
    let snap = reg.hull(sid, &*c).unwrap(); // flush = merge #3
    assert_eq!(snap.epoch, 3);
    let again = reg.hull(sid, &*c).unwrap(); // nothing pending: no epoch bump
    assert_eq!(again.epoch, 3);
    assert_eq!(again.upper, snap.upper);
}

/// Invalid points are rejected atomically with the request-level error,
/// and the session keeps serving afterwards.
#[test]
fn invalid_points_reject_without_corrupting_the_session() {
    let c = coord(BackendKind::Native);
    let reg = registry(&c, 64);
    let sid = reg.open().unwrap();
    reg.add(sid, &[Point::new(0.3, 0.3)], &*c).unwrap();
    let err = reg.add(sid, &[Point::new(0.4, 0.4), Point::new(7.0, 0.0)], &*c);
    assert!(matches!(err, Err(SessionError::Request(_))), "{err:?}");
    reg.add(sid, &[Point::new(0.9, 0.9)], &*c).unwrap();
    let snap = reg.hull(sid, &*c).unwrap();
    let (wu, _) = mc_oracle(&[Point::new(0.3, 0.3), Point::new(0.9, 0.9)]);
    assert_eq!(snap.upper, wu, "rejected batch must leave no residue");
}
