//! The paper's trace format, byte-compatible with `show_current_hoods`:
//!
//! ```text
//! <number of hoods (count/d)>
//! <hood size>
//! <x y>            (hood-size lines)
//! <blank line>
//! ...repeated per hood...
//! ```
//!
//! A full trace is one such section per stage, terminated by a `0` line
//! (the paper writes `fprintf(trace, "0\n")` at the end).

use std::fmt::Write as _;

use crate::geometry::point::{live_prefix, Point};

/// Format one stage's hoods (the body of `show_current_hoods(outfile, d)`).
pub fn format_hoods(hood: &[Point], d: usize) -> String {
    assert_eq!(hood.len() % d, 0);
    let mut out = String::new();
    writeln!(out, "{}", hood.len() / d).unwrap();
    for blk in hood.chunks(d) {
        let live = live_prefix(blk);
        writeln!(out, "{}", live.len()).unwrap();
        for p in live {
            writeln!(out, "{:.6} {:.6}", p.x, p.y).unwrap();
        }
        out.push('\n');
    }
    out
}

/// Incremental trace writer mirroring the paper's main loop.
pub struct TraceWriter<W: std::io::Write> {
    sink: W,
}

impl<W: std::io::Write> TraceWriter<W> {
    pub fn new(sink: W) -> Self {
        TraceWriter { sink }
    }

    /// Call before each stage with the current hood array and block size.
    pub fn stage(&mut self, hood: &[Point], d: usize) -> std::io::Result<()> {
        self.sink.write_all(format_hoods(hood, d).as_bytes())
    }

    /// Terminate the trace (the paper's trailing "0").
    pub fn finish(mut self) -> std::io::Result<()> {
        self.sink.write_all(b"0\n")
    }
}

/// One parsed stage: hoods as point lists.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceStage {
    pub hoods: Vec<Vec<Point>>,
}

/// Parse a full trace file back into stages (round-trip testing, tooling).
pub fn parse_trace(text: &str) -> Result<Vec<TraceStage>, String> {
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    let mut stages = Vec::new();
    loop {
        let count: usize = match lines.next() {
            None => return Err("missing terminating 0".into()),
            Some(l) => l.trim().parse().map_err(|_| format!("bad hood count {l:?}"))?,
        };
        if count == 0 {
            return Ok(stages);
        }
        let mut hoods = Vec::with_capacity(count);
        for _ in 0..count {
            let size: usize = lines
                .next()
                .ok_or("eof in hood header")?
                .trim()
                .parse()
                .map_err(|e| format!("bad hood size: {e}"))?;
            let mut pts = Vec::with_capacity(size);
            for _ in 0..size {
                let l = lines.next().ok_or("eof in hood points")?;
                let mut c = l.split_whitespace();
                let x: f64 = c
                    .next()
                    .and_then(|s| s.parse().ok())
                    .ok_or_else(|| format!("bad point line {l:?}"))?;
                let y: f64 = c
                    .next()
                    .and_then(|s| s.parse().ok())
                    .ok_or_else(|| format!("bad point line {l:?}"))?;
                pts.push(Point::new(x, y));
            }
            hoods.push(pts);
        }
        stages.push(TraceStage { hoods });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::generators::{generate, Distribution};
    use crate::geometry::point::pad_to_hood;
    use crate::wagener::stage;

    #[test]
    fn format_matches_paper_shape() {
        let pts = generate(Distribution::UniformSquare, 8, 1);
        let hood = pad_to_hood(&pts, 8);
        let txt = format_hoods(&hood, 2);
        let mut lines = txt.lines();
        assert_eq!(lines.next(), Some("4")); // count/d hoods
        assert_eq!(lines.next(), Some("2")); // first hood size
    }

    #[test]
    fn trace_roundtrip_through_pipeline() {
        let n = 32;
        let pts = generate(Distribution::Disk, n, 5);
        let mut hood = pad_to_hood(&pts, n);
        let mut buf = Vec::new();
        {
            let mut tw = TraceWriter::new(&mut buf);
            let mut d = 2;
            while d < n {
                tw.stage(&hood, d).unwrap();
                hood = stage(&hood, d);
                d *= 2;
            }
            tw.finish().unwrap();
        }
        let text = String::from_utf8(buf).unwrap();
        let stages = parse_trace(&text).unwrap();
        assert_eq!(stages.len(), 4); // d = 2, 4, 8, 16
        assert_eq!(stages[0].hoods.len(), 16);
        assert_eq!(stages[3].hoods.len(), 2);
        // live counts match the real pipeline state at each stage
        for st in &stages {
            for h in &st.hoods {
                assert!(!h.is_empty() || st.hoods.len() > 2);
            }
        }
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_trace("").is_err());
        assert!(parse_trace("1\nbogus").is_err());
        assert!(parse_trace("1\n1\n0.5").is_err());
        // valid empty trace
        assert_eq!(parse_trace("0\n").unwrap().len(), 0);
    }
}
