//! Workload generators: the controlled hull-size regimes the benches sweep.
//!
//! The paper's dataset (Figure 4) is not published; these distributions
//! span the behaviours that matter for hull algorithms: expected hull size
//! O(log n) (uniform square), O(n^(1/3)) (disk), Θ(n) (circle/parabola,
//! the adversarial case for the merge phases), and 2 (valley — exercises
//! the mam6 stale-corner paper-bug fix).  All outputs are x-sorted,
//! x-deduplicated, coordinates in [0, 1], f32-quantized so every backend
//! (rust native, PRAM sim, PJRT f32 artifacts) sees identical inputs.

use super::point::{dedup_x, sort_by_x, Point};
use crate::util::rng::Rng;

/// Point distribution families.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Distribution {
    /// iid uniform on the unit square — expected upper-hull size O(log n).
    UniformSquare,
    /// uniform in a disk — expected hull size O(n^(1/3)).
    Disk,
    /// on a circle — every point is a hull corner (upper half kept live).
    Circle,
    /// on a downward parabola — every point is an UPPER hull corner.
    Parabola,
    /// on an upward parabola — upper hull is exactly the two extremes.
    Valley,
    /// k tight gaussian clusters spread across the square.
    Clusters(u8),
    /// two distant clumps — wide-gap tangents (stress for sampling phases).
    Bimodal,
}

impl Distribution {
    pub const ALL: [Distribution; 7] = [
        Distribution::UniformSquare,
        Distribution::Disk,
        Distribution::Circle,
        Distribution::Parabola,
        Distribution::Valley,
        Distribution::Clusters(5),
        Distribution::Bimodal,
    ];

    pub fn name(&self) -> String {
        match self {
            Distribution::UniformSquare => "uniform".into(),
            Distribution::Disk => "disk".into(),
            Distribution::Circle => "circle".into(),
            Distribution::Parabola => "parabola".into(),
            Distribution::Valley => "valley".into(),
            Distribution::Clusters(k) => format!("clusters{k}"),
            Distribution::Bimodal => "bimodal".into(),
        }
    }

    /// Parse a CLI name ("uniform", "clusters5", ...).
    pub fn parse(s: &str) -> Option<Distribution> {
        Some(match s {
            "uniform" => Distribution::UniformSquare,
            "disk" => Distribution::Disk,
            "circle" => Distribution::Circle,
            "parabola" => Distribution::Parabola,
            "valley" => Distribution::Valley,
            "bimodal" => Distribution::Bimodal,
            _ => {
                let k = s.strip_prefix("clusters")?.parse().ok()?;
                Distribution::Clusters(k)
            }
        })
    }
}

/// Deterministic y-jitter for points on smooth curves.
///
/// f32 quantization flattens low-curvature stretches (a parabola apex has
/// Δy below one ulp) into *exactly collinear* runs, violating the paper's
/// no-3-collinear assumption and creating tangent ties.  A jitter of 1e-4
/// (≫ f32 ulp ≈ 6e-8, ≪ feature scale) restores general position with
/// overwhelming probability while keeping the distribution's character.
const CURVE_JITTER: f64 = 1e-4;

fn jitter(y: f64, rng: &mut Rng) -> f64 {
    (y + (rng.f64() - 0.5) * 2.0 * CURVE_JITTER).clamp(0.0, 1.0)
}

fn raw_points(dist: Distribution, n: usize, rng: &mut Rng) -> Vec<Point> {
    let mut pts = Vec::with_capacity(n);
    match dist {
        Distribution::UniformSquare => {
            for _ in 0..n {
                pts.push(Point::new(rng.f64(), rng.f64()));
            }
        }
        Distribution::Disk => {
            while pts.len() < n {
                let x = rng.f64() * 2.0 - 1.0;
                let y = rng.f64() * 2.0 - 1.0;
                if x * x + y * y <= 1.0 {
                    pts.push(Point::new(0.5 + x / 2.0, 0.5 + y / 2.0));
                }
            }
        }
        Distribution::Circle => {
            for _ in 0..n {
                let t = rng.f64() * std::f64::consts::TAU;
                let (x, y) = (0.5 + t.cos() * 0.45, 0.5 + t.sin() * 0.45);
                let y = jitter(y, rng);
                pts.push(Point::new(x, y));
            }
        }
        Distribution::Parabola => {
            for _ in 0..n {
                let x = rng.f64();
                let y = 0.1 + 0.8 * (1.0 - (2.0 * x - 1.0) * (2.0 * x - 1.0));
                pts.push(Point::new(x, jitter(y, rng)));
            }
        }
        Distribution::Valley => {
            for _ in 0..n {
                let x = rng.f64();
                let y = 0.1 + 0.8 * (2.0 * x - 1.0) * (2.0 * x - 1.0);
                pts.push(Point::new(x, jitter(y, rng)));
            }
        }
        Distribution::Clusters(k) => {
            let k = k.max(1) as usize;
            let centers: Vec<Point> = (0..k)
                .map(|_| Point::new(rng.range_f64(0.15, 0.85), rng.range_f64(0.15, 0.85)))
                .collect();
            for i in 0..n {
                let c = centers[i % k];
                pts.push(Point::new(
                    (c.x + rng.gaussian() * 0.03).clamp(0.0, 1.0),
                    (c.y + rng.gaussian() * 0.03).clamp(0.0, 1.0),
                ));
            }
        }
        Distribution::Bimodal => {
            for i in 0..n {
                let (cx, cy) = if i % 2 == 0 { (0.08, 0.2) } else { (0.92, 0.75) };
                pts.push(Point::new(
                    (cx + rng.gaussian() * 0.04).clamp(0.0, 1.0),
                    (cy + rng.gaussian() * 0.04).clamp(0.0, 1.0),
                ));
            }
        }
    }
    pts
}

/// Generate `n` points: x-sorted, distinct x, f32-quantized, in [0,1]².
///
/// Distinct-x is the paper's general-position assumption; duplicates after
/// f32 quantization are resampled deterministically.
pub fn generate(dist: Distribution, n: usize, seed: u64) -> Vec<Point> {
    let mut rng = Rng::new(seed ^ 0xD15_7B17);
    let mut pts: Vec<Point> = raw_points(dist, n, &mut rng)
        .into_iter()
        .map(|p| p.quantize_f32())
        .collect();
    sort_by_x(&mut pts);
    pts = dedup_x(&pts, true);
    // resample until we have n distinct-x points (duplicates are rare)
    let mut guard = 0;
    while pts.len() < n && guard < 64 {
        let extra = raw_points(dist, n - pts.len() + 8, &mut rng);
        pts.extend(extra.into_iter().map(|p| p.quantize_f32()));
        sort_by_x(&mut pts);
        pts = dedup_x(&pts, true);
        guard += 1;
    }
    pts.truncate(n);
    assert_eq!(pts.len(), n, "generator could not reach {n} distinct-x points");
    pts
}

/// Affinely rescale a cloud's x-coordinates into `[lo, hi]`
/// (f32-quantized like all generator output).  Shapes x-disjoint vs
/// x-overlapping workloads for the hull ⊕ hull merge paths.  The map is
/// order-preserving, but quantization can collide neighboring x's —
/// callers feeding chains that require distinct x must dedup afterwards.
pub fn squeeze_x(points: &[Point], lo: f64, hi: f64) -> Vec<Point> {
    points
        .iter()
        .map(|p| Point::new(lo + p.x * (hi - lo), p.y).quantize_f32())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_sorted_distinct_x_in_range() {
        for dist in Distribution::ALL {
            let pts = generate(dist, 256, 7);
            assert_eq!(pts.len(), 256, "{}", dist.name());
            for w in pts.windows(2) {
                assert!(w[0].x < w[1].x, "{}", dist.name());
            }
            for p in &pts {
                assert!((0.0..=1.0).contains(&p.x), "{} {p}", dist.name());
                assert!((0.0..=1.0).contains(&p.y), "{} {p}", dist.name());
                // f32-quantized
                assert_eq!(p.x, p.x as f32 as f64);
                assert_eq!(p.y, p.y as f32 as f64);
            }
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(Distribution::UniformSquare, 100, 42);
        let b = generate(Distribution::UniformSquare, 100, 42);
        let c = generate(Distribution::UniformSquare, 100, 43);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn parse_roundtrip() {
        for dist in Distribution::ALL {
            assert_eq!(Distribution::parse(&dist.name()), Some(dist));
        }
        assert_eq!(Distribution::parse("nope"), None);
    }

    #[test]
    fn parabola_mostly_on_hull() {
        use crate::geometry::hull_check::brute_force_upper_hull;
        let pts = generate(Distribution::Parabola, 48, 3);
        let hull = brute_force_upper_hull(&pts);
        // f32 quantization may flatten a couple of near-collinear corners
        assert!(hull.len() >= 44, "hull {}", hull.len());
    }

    #[test]
    fn valley_hull_is_two_points() {
        use crate::geometry::hull_check::brute_force_upper_hull;
        let pts = generate(Distribution::Valley, 64, 3);
        let hull = brute_force_upper_hull(&pts);
        assert!(hull.len() <= 3, "hull {}", hull.len());
    }
}
