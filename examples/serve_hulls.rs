//! End-to-end serving driver (experiment E6): the full three-layer system
//! on a real workload.
//!
//! Starts the coordinator on the PJRT backend (AOT Pallas/JAX artifacts),
//! serves it over TCP, then drives it with concurrent client threads
//! sending mixed-size hull requests.  Reports throughput and latency
//! percentiles and verifies every response against the serial oracle.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_hulls [backend] [n_requests]
//! ```

use std::sync::Arc;
use std::time::Instant;

use wagener_hull::coordinator::{
    BackendKind, BatcherConfig, Coordinator, CoordinatorConfig,
};
use wagener_hull::geometry::generators::{generate, Distribution};
use wagener_hull::serial::monotone_chain;
use wagener_hull::server::{serve, HullClient, ServerConfig};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let backend = args
        .first()
        .map(|s| BackendKind::parse(s).expect("backend: pjrt|native|serial|pram"))
        .unwrap_or(BackendKind::Pjrt);
    let total_requests: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(400);
    let clients = 8usize;
    let per_client = total_requests / clients;

    println!("== serve_hulls: backend={} requests={total_requests} clients={clients}", backend.name());

    let coord = Arc::new(
        Coordinator::start(CoordinatorConfig {
            backend,
            artifacts_dir: "artifacts".into(),
            batcher: BatcherConfig { max_batch: 8, flush_us: 400, queue_cap: 1024 },
            self_check: false,
            preload: backend == BackendKind::Pjrt,
            ..Default::default()
        })
        .expect("coordinator start (run `make artifacts` for pjrt)"),
    );
    let cfg = ServerConfig { addr: "127.0.0.1:0".into(), ..Default::default() };
    let handle = serve(coord.clone(), &cfg).unwrap();
    let addr = handle.local_addr;
    println!("server on {addr}");

    // warm the compile cache so steady-state numbers are clean
    {
        let mut c = HullClient::connect(addr).unwrap();
        for n in [120usize, 200] {
            let pts = generate(Distribution::Disk, n, 7777 + n as u64);
            c.hull(&pts).unwrap();
        }
    }

    let t0 = Instant::now();
    let mut joins = Vec::new();
    for t in 0..clients as u64 {
        joins.push(std::thread::spawn(move || {
            let mut client = HullClient::connect(addr).unwrap();
            let mut lat_ns: Vec<u64> = Vec::with_capacity(per_client);
            for k in 0..per_client as u64 {
                let dist = Distribution::ALL[(k % 7) as usize];
                // two size classes so the batcher can actually group
                // concurrent requests (mixed round-robin defeats batching;
                // see EXPERIMENTS.md E6)
                let n = [120usize, 200][(k % 2) as usize];
                let pts = generate(dist, n, t * 100_000 + k);
                let s = Instant::now();
                let hull = client.hull(&pts).unwrap();
                lat_ns.push(s.elapsed().as_nanos() as u64);
                // verify against the serial oracle
                let (u, l) = monotone_chain::full_hull(&pts);
                assert_eq!(hull.upper, u, "client {t} req {k}");
                assert_eq!(hull.lower, l, "client {t} req {k}");
            }
            lat_ns
        }));
    }
    let mut lat: Vec<u64> = joins.into_iter().flat_map(|j| j.join().unwrap()).collect();
    let wall = t0.elapsed();
    lat.sort_unstable();

    let pct = |q: f64| lat[((lat.len() as f64 * q) as usize).min(lat.len() - 1)] as f64 / 1e6;
    let done = lat.len();
    println!("\n== results ({} backend) ==", backend.name());
    println!("requests:    {done} (all verified against serial oracle)");
    println!("wall time:   {:.2} s", wall.as_secs_f64());
    println!("throughput:  {:.1} req/s", done as f64 / wall.as_secs_f64());
    println!("latency p50: {:.2} ms", pct(0.50));
    println!("latency p95: {:.2} ms", pct(0.95));
    println!("latency p99: {:.2} ms", pct(0.99));
    println!("latency max: {:.2} ms", *lat.last().unwrap() as f64 / 1e6);
    println!("\ncoordinator metrics: {}", coord.snapshot().0.to_string_pretty());

    handle.stop();
}
