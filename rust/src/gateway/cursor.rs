//! Opaque pagination cursors for hull reads.
//!
//! A hull snapshot is two monotone chains (`upper`, `lower`) pinned to an
//! epoch; a cursor names a resume position inside that snapshot:
//! `(epoch, chain, offset)`.  The epoch rides inside the cursor, so every
//! follow-up page re-reads the *same immutable ledger entry*
//! ([`Engine::session_hull_at`] with `Some(epoch)`) no matter how many
//! `SADD`s land between pages — that is what makes pages reassemble
//! bit-identically to a one-shot `SHULL`, and what makes a cursor from an
//! evicted-and-restored session answer the typed `unknown-epoch` instead
//! of silently paginating a different hull.
//!
//! The wire form is hex over a fixed little-endian layout plus an xor
//! checksum byte — opaque to clients (the contract is "echo it back"),
//! while tampering or truncation decodes to `None` → 400 `bad-cursor`.

use crate::geometry::point::Point;

/// Resume position inside one epoch-pinned hull snapshot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Cursor {
    pub epoch: u64,
    /// 0 = upper chain, 1 = lower chain.
    pub chain: u8,
    /// Point offset within that chain.
    pub offset: u64,
}

const VERSION: u8 = 1;
/// version + epoch + chain + offset + checksum.
const RAW_LEN: usize = 1 + 8 + 1 + 8 + 1;

fn checksum(raw: &[u8]) -> u8 {
    raw.iter().fold(0x5Au8, |a, b| a ^ b.rotate_left(3))
}

/// Encode to the opaque wire string (38 lowercase hex chars).
pub fn encode(c: &Cursor) -> String {
    let mut raw = [0u8; RAW_LEN];
    raw[0] = VERSION;
    raw[1..9].copy_from_slice(&c.epoch.to_le_bytes());
    raw[9] = c.chain;
    raw[10..18].copy_from_slice(&c.offset.to_le_bytes());
    raw[18] = checksum(&raw[..18]);
    let mut out = String::with_capacity(RAW_LEN * 2);
    for b in raw {
        out.push_str(&format!("{b:02x}"));
    }
    out
}

/// Decode; `None` for anything that is not a verbatim [`encode`] output.
pub fn decode(s: &str) -> Option<Cursor> {
    if s.len() != RAW_LEN * 2 || !s.bytes().all(|b| b.is_ascii_hexdigit()) {
        return None;
    }
    let mut raw = [0u8; RAW_LEN];
    for (i, r) in raw.iter_mut().enumerate() {
        *r = u8::from_str_radix(&s[i * 2..i * 2 + 2], 16).ok()?;
    }
    if raw[0] != VERSION || raw[18] != checksum(&raw[..18]) {
        return None;
    }
    let chain = raw[9];
    if chain > 1 {
        return None;
    }
    Some(Cursor {
        epoch: u64::from_le_bytes(raw[1..9].try_into().unwrap()),
        chain,
        offset: u64::from_le_bytes(raw[10..18].try_into().unwrap()),
    })
}

/// One page of a snapshot.
#[derive(Debug)]
pub struct Page {
    pub upper: Vec<Point>,
    pub lower: Vec<Point>,
    /// Resume cursor; `None` when both chains are exhausted.
    pub next: Option<Cursor>,
}

/// Slice up to `limit` points out of `(upper, lower)` starting at `at`,
/// upper chain first.  Offsets past a chain's end are treated as
/// exhausted (a clamped resume, not an error), so a cursor is always safe
/// to retry.  Concatenating the pages of any limit schedule yields
/// exactly `upper ++ lower` — the pagination-parity property the
/// integration suite and the diffsim ledger both pin.
pub fn page(upper: &[Point], lower: &[Point], at: Cursor, limit: usize) -> Page {
    debug_assert!(limit > 0);
    let mut out_upper = Vec::new();
    let mut out_lower = Vec::new();
    let mut chain = at.chain;
    let mut offset = at.offset as usize;
    let mut room = limit;
    if chain == 0 {
        let start = offset.min(upper.len());
        let take = room.min(upper.len() - start);
        out_upper.extend_from_slice(&upper[start..start + take]);
        room -= take;
        if start + take < upper.len() {
            return Page {
                upper: out_upper,
                lower: out_lower,
                next: Some(Cursor { epoch: at.epoch, chain: 0, offset: (start + take) as u64 }),
            };
        }
        chain = 1;
        offset = 0;
    }
    debug_assert_eq!(chain, 1);
    let start = offset.min(lower.len());
    let take = room.min(lower.len() - start);
    out_lower.extend_from_slice(&lower[start..start + take]);
    let next = (start + take < lower.len())
        .then(|| Cursor { epoch: at.epoch, chain: 1, offset: (start + take) as u64 });
    Page { upper: out_upper, lower: out_lower, next }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pts(n: usize, base: f64) -> Vec<Point> {
        (0..n).map(|i| Point { x: base + i as f64, y: base - i as f64 }).collect()
    }

    #[test]
    fn cursor_roundtrips() {
        for c in [
            Cursor { epoch: 0, chain: 0, offset: 0 },
            Cursor { epoch: 7, chain: 1, offset: 12345 },
            Cursor { epoch: u64::MAX, chain: 0, offset: u64::MAX },
        ] {
            let s = encode(&c);
            assert_eq!(s.len(), 38);
            assert_eq!(decode(&s), Some(c), "{s}");
        }
    }

    #[test]
    fn tampering_and_garbage_decode_to_none() {
        let s = encode(&Cursor { epoch: 9, chain: 1, offset: 4 });
        assert!(decode(&s[..s.len() - 2]).is_none(), "truncated");
        assert!(decode(&format!("{s}aa")).is_none(), "extended");
        for i in 0..s.len() {
            let mut t: Vec<u8> = s.bytes().collect();
            t[i] = if t[i] == b'0' { b'1' } else { b'0' };
            let t = String::from_utf8(t).unwrap();
            if t != s {
                assert!(decode(&t).is_none(), "flip at {i}: {t}");
            }
        }
        assert!(decode("").is_none());
        assert!(decode("not-a-cursor").is_none());
        assert!(decode(&"zz".repeat(19)).is_none());
    }

    #[test]
    fn pages_reassemble_exactly() {
        let upper = pts(7, 100.0);
        let lower = pts(5, 200.0);
        for limit in 1..=13 {
            let mut got_u = Vec::new();
            let mut got_l = Vec::new();
            let mut at = Cursor { epoch: 3, chain: 0, offset: 0 };
            let mut hops = 0;
            loop {
                let p = page(&upper, &lower, at, limit);
                got_u.extend(p.upper);
                got_l.extend(p.lower);
                match p.next {
                    Some(n) => {
                        assert_eq!(n.epoch, 3);
                        at = n;
                    }
                    None => break,
                }
                hops += 1;
                assert!(hops <= 13, "cursor chain does not terminate");
            }
            assert_eq!(got_u, upper, "limit={limit}");
            assert_eq!(got_l, lower, "limit={limit}");
        }
    }

    #[test]
    fn one_page_when_limit_covers_everything() {
        let upper = pts(3, 0.0);
        let lower = pts(2, 50.0);
        let p = page(&upper, &lower, Cursor { epoch: 1, chain: 0, offset: 0 }, 5);
        assert_eq!(p.upper, upper);
        assert_eq!(p.lower, lower);
        assert!(p.next.is_none());
    }

    #[test]
    fn out_of_range_offsets_are_exhausted_not_errors() {
        let upper = pts(2, 0.0);
        let lower = pts(2, 9.0);
        let p = page(&upper, &lower, Cursor { epoch: 1, chain: 1, offset: 99 }, 4);
        assert!(p.upper.is_empty() && p.lower.is_empty());
        assert!(p.next.is_none());
        let p = page(&[], &[], Cursor { epoch: 1, chain: 0, offset: 0 }, 4);
        assert!(p.upper.is_empty() && p.lower.is_empty());
        assert!(p.next.is_none());
    }
}
