//! Randomized property tests (in-tree harness; see util::property) over
//! the invariants that matter: hull semantics across all implementations,
//! batching/routing behaviour of the coordinator, protocol round-trips,
//! and the PRAM machine's CREW discipline.
//!
//! Reproduce any failure with WAGENER_PROP_SEED=<seed> cargo test <name>.

use wagener_hull::coordinator::{
    backend::exact_full_hull, BackendKind, BatcherConfig, Coordinator, CoordinatorConfig,
};
use wagener_hull::geometry::generators::{generate, Distribution};
use wagener_hull::geometry::hull_check::check_upper_hull;
use wagener_hull::geometry::point::{live_prefix, sort_by_x, Point};
use wagener_hull::ovl;
use wagener_hull::prop_assert;
use wagener_hull::serial::monotone_chain;
use wagener_hull::util::property::check;
use wagener_hull::util::rng::Rng;
use wagener_hull::wagener;

fn random_dist(rng: &mut Rng) -> Distribution {
    Distribution::ALL[rng.range_usize(0, Distribution::ALL.len())]
}

/// Arbitrary *raw* point clouds (not via generators): duplicates, shared
/// x, tiny clusters — everything a client might send.
fn raw_points(rng: &mut Rng, max: usize) -> Vec<Point> {
    let n = rng.range_usize(1, max);
    let grid = rng.chance(0.3); // 30%: quantize to a coarse grid (forces duplicates)
    (0..n)
        .map(|_| {
            let (mut x, mut y) = (rng.f64(), rng.f64());
            if grid {
                x = (x * 8.0).round() / 8.0;
                y = (y * 8.0).round() / 8.0;
            }
            Point::new(x, y)
        })
        .collect()
}

#[test]
fn prop_wagener_equals_serial() {
    check("wagener-vs-serial", 60, |rng| {
        let dist = random_dist(rng);
        let n = rng.range_usize(1, 300);
        let pts = generate(dist, n, rng.next_u64());
        let want = monotone_chain::upper_hull(&pts);
        let got = wagener::upper_hull(&pts);
        prop_assert!(got == want, "{} n={n}: wagener != serial", dist.name());
        Ok(())
    });
}

#[test]
fn prop_hull_is_valid_hull() {
    check("hull-validity", 60, |rng| {
        let dist = random_dist(rng);
        let n = rng.range_usize(3, 400);
        let pts = generate(dist, n, rng.next_u64());
        let hull = wagener::upper_hull(&pts);
        check_upper_hull(&pts, &hull).map_err(|e| format!("{}: {e}", dist.name()))
    });
}

#[test]
fn prop_pram_is_crew_and_matches() {
    check("pram-crew", 25, |rng| {
        let dist = random_dist(rng);
        let slots = 1usize << rng.range_usize(1, 8);
        let m = rng.range_usize(1, slots + 1);
        let pts = generate(dist, m, rng.next_u64());
        let run = wagener::pram_exec::run_pipeline(&pts, slots)
            .map_err(|e| format!("CREW violation: {e}"))?;
        prop_assert!(run.counters.write_conflicts == 0, "write conflicts");
        let want = monotone_chain::upper_hull(&pts);
        prop_assert!(
            live_prefix(&run.hood) == &want[..],
            "{} m={m} slots={slots}: pram mismatch",
            dist.name()
        );
        Ok(())
    });
}

/// TIER invariant (the two-tier engine's contract): the fast serving
/// tier and the audited instrument produce bit-identical hoods — and
/// identical final device memory — on every CREW-clean input, across
/// disc/circle/degenerate generators and n in {8..4096}.
#[test]
fn prop_fast_tier_bit_identical_to_audited() {
    use wagener_hull::pram::ExecMode;
    use wagener_hull::wagener::pram_exec::run_pipeline_mode;
    check("fast-vs-audited", 30, |rng| {
        let dist = random_dist(rng);
        let slots = 1usize << rng.range_usize(3, 13); // 8 .. 4096
        let m = rng.range_usize(1, slots + 1);
        let pts = generate(dist, m, rng.next_u64());
        let audited = run_pipeline_mode(&pts, slots, ExecMode::Audited, true)
            .map_err(|e| format!("audited: {e}"))?;
        let fast = run_pipeline_mode(&pts, slots, ExecMode::Fast, true)
            .map_err(|e| format!("fast: {e}"))?;
        // `hood` is the full padded device memory readback, REMOTE slots
        // included, so equality here is final-mem equality
        prop_assert!(
            audited.hood == fast.hood,
            "{} m={m} slots={slots}: tiers diverge",
            dist.name()
        );
        prop_assert!(
            audited.counters.steps == fast.counters.steps
                && audited.counters.work == fast.counters.work,
            "tier step/work accounting diverges"
        );
        let want = monotone_chain::upper_hull(&pts);
        prop_assert!(
            live_prefix(&fast.hood) == &want[..],
            "{} m={m} slots={slots}: fast tier wrong hull",
            dist.name()
        );
        Ok(())
    });
}

#[test]
fn prop_ovl_matches_any_strip() {
    check("ovl-strips", 40, |rng| {
        let dist = random_dist(rng);
        let n = rng.range_usize(1, 500);
        let strip = rng.range_usize(1, n + 2);
        let pts = generate(dist, n, rng.next_u64());
        let want = monotone_chain::upper_hull(&pts);
        let got = ovl::optimal_upper_hull(&pts, strip).hull;
        prop_assert!(got == want, "{} n={n} strip={strip}", dist.name());
        Ok(())
    });
}

#[test]
fn prop_exact_fallback_handles_anything() {
    check("exact-fallback", 60, |rng| {
        let mut pts = raw_points(rng, 80);
        pts = pts.iter().map(|p| p.quantize_f32()).collect();
        sort_by_x(&mut pts);
        pts.dedup();
        let (upper, lower) = exact_full_hull(&pts);
        prop_assert!(!upper.is_empty() && !lower.is_empty(), "empty hull");
        // chains strictly x-increasing, extremes shared
        for w in upper.windows(2) {
            prop_assert!(w[0].x < w[1].x, "upper x-order");
        }
        for w in lower.windows(2) {
            prop_assert!(w[0].x < w[1].x, "lower x-order");
        }
        // every input point is on-or-below upper and on-or-above lower
        use wagener_hull::geometry::predicates::{orient2d, Orientation};
        for p in &pts {
            for (chain, dir) in [(&upper, Orientation::Left), (&lower, Orientation::Right)] {
                let seg = chain.partition_point(|h| h.x < p.x);
                if seg == 0 || seg >= chain.len() {
                    continue;
                }
                let o = orient2d(chain[seg - 1], chain[seg], *p);
                prop_assert!(o != dir, "point outside hull: {p}");
            }
        }
        Ok(())
    });
}

/// ROUTING invariant: every submitted request gets exactly one response
/// with its own id and its own hull, no matter how requests interleave.
#[test]
fn prop_coordinator_routing_preserves_identity() {
    let coord = Coordinator::start(CoordinatorConfig {
        backend: BackendKind::Native,
        batcher: BatcherConfig { max_batch: 5, flush_us: 100, queue_cap: 512 },
        self_check: false,
        ..Default::default()
    })
    .unwrap();
    check("routing-identity", 10, |rng| {
        // a wave of requests with mixed sizes, submitted before any recv
        let k = rng.range_usize(2, 20);
        let mut waits = Vec::new();
        let mut wants = Vec::new();
        for _ in 0..k {
            let dist = random_dist(rng);
            let n = rng.range_usize(1, 120);
            let pts = generate(dist, n, rng.next_u64());
            let id = coord.next_id();
            wants.push((id, monotone_chain::full_hull(&pts)));
            waits.push(coord.submit(wagener_hull::coordinator::HullRequest::new(id, pts)));
        }
        for (rx, (id, (u, l))) in waits.into_iter().zip(wants) {
            let resp = rx.recv().map_err(|_| "dropped")?.map_err(|e| e.to_string())?;
            prop_assert!(resp.id == id, "response id mismatch");
            prop_assert!(resp.upper == u && resp.lower == l, "hull mismatch for id {id}");
        }
        Ok(())
    });
}

/// BATCHING invariant: batching must be invisible — the same requests
/// answered identically at batch 1 and batch 8.
#[test]
fn prop_batching_is_transparent() {
    let mk = |max_batch| {
        Coordinator::start(CoordinatorConfig {
            backend: BackendKind::Native,
            batcher: BatcherConfig { max_batch, flush_us: 100, queue_cap: 512 },
            self_check: false,
            ..Default::default()
        })
        .unwrap()
    };
    let c1 = mk(1);
    let c8 = mk(8);
    check("batching-transparent", 10, |rng| {
        let k = rng.range_usize(2, 12);
        let reqs: Vec<Vec<Point>> = (0..k)
            .map(|_| generate(random_dist(rng), rng.range_usize(1, 100), rng.next_u64()))
            .collect();
        let a: Vec<_> = reqs
            .iter()
            .map(|p| c1.compute(p.clone()).map_err(|e| e.to_string()))
            .collect::<Result<_, _>>()?;
        // submit all to the batched coordinator concurrently
        let waits: Vec<_> = reqs
            .iter()
            .map(|p| {
                c8.submit(wagener_hull::coordinator::HullRequest::new(c8.next_id(), p.clone()))
            })
            .collect();
        for (rx, want) in waits.into_iter().zip(a) {
            let resp = rx.recv().map_err(|_| "dropped")?.map_err(|e| e.to_string())?;
            prop_assert!(
                resp.upper == want.upper && resp.lower == want.lower,
                "batched result differs from unbatched"
            );
        }
        Ok(())
    });
}

/// STATE invariant: metrics add up — responses + errors == requests.
#[test]
fn prop_metrics_conservation() {
    check("metrics-conservation", 8, |rng| {
        let coord = Coordinator::start(CoordinatorConfig {
            backend: BackendKind::Serial,
            batcher: BatcherConfig { max_batch: 3, flush_us: 50, queue_cap: 64 },
            self_check: false,
            ..Default::default()
        })
        .unwrap();
        let k = rng.range_usize(1, 15);
        let mut ok = 0usize;
        let mut err = 0usize;
        for _ in 0..k {
            if rng.chance(0.25) {
                // invalid request
                let bad = vec![Point::new(5.0, 5.0)];
                let _ = coord.compute(bad).is_err();
                err += 1;
            } else {
                let pts = raw_points(rng, 60);
                match coord.compute(pts) {
                    Ok(_) => ok += 1,
                    Err(_) => err += 1,
                }
            }
        }
        let snap = coord.snapshot().0;
        let resp = snap.get("responses").unwrap().as_usize().unwrap();
        let errs = snap.get("errors").unwrap().as_usize().unwrap();
        let reqs = snap.get("requests").unwrap().as_usize().unwrap();
        prop_assert!(resp == ok, "responses {resp} != ok {ok}");
        prop_assert!(errs == err, "errors {errs} != {err}");
        prop_assert!(reqs == k, "requests {reqs} != {k}");
        Ok(())
    });
}

#[test]
fn prop_protocol_roundtrip() {
    use std::io::BufReader;
    use wagener_hull::server::proto::{
        read_request, read_response, write_request, write_response, Request, Response,
    };
    check("proto-roundtrip", 50, |rng| {
        let pts = raw_points(rng, 50);
        let req = Request::Hull { id: rng.next_u64(), points: pts.clone(), tmo_ms: None };
        let mut buf = Vec::new();
        write_request(&mut buf, &req).unwrap();
        let back = read_request(&mut BufReader::new(&buf[..])).map_err(|e| e.to_string())?;
        prop_assert!(back == req, "request roundtrip");

        let k = rng.range_usize(0, pts.len() + 1);
        let resp = Response::Hull {
            id: rng.next_u64(),
            upper: pts[..k].to_vec(),
            lower: pts[k..].to_vec(),
            backend: "pjrt".into(),
            queue_ns: rng.next_u64() >> 20,
            exec_ns: rng.next_u64() >> 20,
        };
        let mut buf = Vec::new();
        write_response(&mut buf, &resp).unwrap();
        let back = read_response(&mut BufReader::new(&buf[..])).map_err(|e| e.to_string())?;
        prop_assert!(back == resp, "response roundtrip");

        // session verbs ride the same framing
        let sid = rng.next_u64();
        let sreq = Request::SessionAdd { sid, points: pts.clone(), tmo_ms: None };
        let mut buf = Vec::new();
        write_request(&mut buf, &sreq).unwrap();
        let back = read_request(&mut BufReader::new(&buf[..])).map_err(|e| e.to_string())?;
        prop_assert!(back == sreq, "SADD roundtrip");

        let k = rng.range_usize(0, pts.len() + 1);
        let sresp = Response::SessionHull {
            sid,
            epoch: rng.next_u64() >> 8,
            upper: pts[..k].to_vec(),
            lower: pts[k..].to_vec(),
        };
        let mut buf = Vec::new();
        write_response(&mut buf, &sresp).unwrap();
        let back = read_response(&mut BufReader::new(&buf[..])).map_err(|e| e.to_string())?;
        prop_assert!(back == sresp, "SHULL roundtrip");
        Ok(())
    });
}

/// merge_hulls(hull(A), hull(B)) must be bit-identical to the exact
/// one-shot hull of A ∪ B — on arbitrary raw clouds (duplicates, shared
/// x), generator distributions, and forced x-disjoint splits.
#[test]
fn prop_merge_hulls_matches_union_oracle() {
    use wagener_hull::coordinator::backend::canonical_full_hull as canonical;
    use wagener_hull::wagener::hull_merge::merge_hulls;

    check("merge-hulls-vs-union", 80, |rng| {
        let (a, b) = if rng.chance(0.5) {
            // raw clouds: duplicates and duplicate-x welcome
            (raw_points(rng, 200), raw_points(rng, 200))
        } else {
            (
                generate(random_dist(rng), rng.range_usize(1, 250), rng.next_u64()),
                generate(random_dist(rng), rng.range_usize(1, 250), rng.next_u64()),
            )
        };
        // 50%: squeeze into disjoint x-bands to force the tangent path
        let (a, b) = if rng.chance(0.5) {
            use wagener_hull::geometry::generators::squeeze_x;
            (squeeze_x(&a, 0.0, 0.45), squeeze_x(&b, 0.55, 1.0))
        } else {
            (a, b)
        };
        let (au, al) = canonical(&a);
        let (bu, bl) = canonical(&b);
        let ((mu, ml), path) = merge_hulls((&au, &al), (&bu, &bl));
        let union: Vec<Point> = a.iter().chain(b.iter()).copied().collect();
        let (wu, wl) = canonical(&union);
        prop_assert!(mu == wu, "upper diverged on {} path", path.name());
        prop_assert!(ml == wl, "lower diverged on {} path", path.name());
        Ok(())
    });
}

#[test]
fn prop_trace_roundtrip() {
    use wagener_hull::geometry::point::pad_to_hood;
    use wagener_hull::viz::trace::{format_hoods, parse_trace};
    check("trace-roundtrip", 30, |rng| {
        let slots = 1usize << rng.range_usize(1, 8);
        let m = rng.range_usize(1, slots + 1);
        let pts = generate(random_dist(rng), m, rng.next_u64());
        let hood = pad_to_hood(&pts, slots);
        let d = 1usize << rng.range_usize(0, slots.trailing_zeros() as usize + 1);
        let mut text = format_hoods(&hood, d);
        text.push_str("0\n");
        let stages = parse_trace(&text).map_err(|e| e)?;
        prop_assert!(stages.len() == 1, "one stage");
        prop_assert!(stages[0].hoods.len() == slots / d, "hood count");
        let total: usize = stages[0].hoods.iter().map(Vec::len).sum();
        prop_assert!(total == m, "live points preserved: {total} != {m}");
        Ok(())
    });
}
