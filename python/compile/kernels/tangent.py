"""L1: the sampled common-tangent search as a fixed-shape Pallas program.

A streaming session's merge combines its current hull with the hull of
the pending buffer via the paper's common tangent (`wagener::hull_merge`).
Until now that ran on the host (`find_tangent`, the mam1..mam5 lattice in
rust).  This kernel moves it on-device: one program consumes one padded
``[H(L) | H(R)]`` block (two d-slot live-left-justified halves, x-disjoint
left-to-right chains) and emits the merged 2d-slot block — the tangent
lattice *and* the mam6 shift-copy in a single dispatch.

The device contract is batch = 2: row 0 is the upper-chain pair, row 1 the
y-negated lower-chain pair (the lower hull is the upper hull of mirrored
points, same convention as model.full_hull).  A full hull ⊕ hull merge is
therefore exactly ONE upload and one download; the rust side re-scans the
two returned live prefixes with the exact monotone chain, which
canonicalizes cross-hull collinearity precisely like the host path's
rescan — so the device path is bit-identical to the host path and falls
back to it when no artifact size class fits.

The kernel body is wagener.merge_block verbatim: the tangent search IS one
match-and-merge stage, just launched on an adversarially-padded block pair
instead of a pipeline stage's hoods.

Kernels MUST be lowered with interpret=True (see wagener.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import wagener


def _tangent_kernel(blocks_ref, out_ref, *, d1: int, d2: int):
    """Pallas body: one program = one [H(L) | H(R)] block merge."""
    out_ref[...] = wagener.merge_block(blocks_ref[0], d1, d2)[None]


@jax.jit
def pallas_tangent(blocks: jnp.ndarray) -> jnp.ndarray:
    """Merge a batch of [H(L) | H(R)] block pairs via pallas_call.

    blocks: (b, 2d, 2) float32 — grid = b programs, one per pair (the
    serving artifact uses b = 2: upper pair + mirrored lower pair)."""
    b, n2, _ = blocks.shape
    d = n2 // 2
    d1, d2 = wagener.stage_dims(d)
    spec = pl.BlockSpec((1, n2, 2), lambda i: (i, 0, 0))
    return pl.pallas_call(
        functools.partial(_tangent_kernel, d1=d1, d2=d2),
        grid=(b,),
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(blocks.shape, blocks.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(blocks)


@jax.jit
def jnp_tangent(blocks: jnp.ndarray) -> jnp.ndarray:
    """Plain-jnp twin of :func:`pallas_tangent` (vmap over pairs)."""
    _, n2, _ = blocks.shape
    d1, d2 = wagener.stage_dims(n2 // 2)
    return jax.vmap(lambda blk: wagener.merge_block(blk, d1, d2))(blocks)


# re-export for tests/aot
enable_x64 = wagener.enable_x64
