//! Figures 1 & 4 reproduction (experiments E1, E3): run the paper's
//! 1024-point sample scenario end-to-end, write the per-stage trace in the
//! paper's `show_current_hoods` format, render the hood2ps-style SVG, and
//! cross-check all three execution paths (host, PRAM sim, PJRT artifact).
//!
//! ```bash
//! cargo run --release --example figure4           # uses artifacts/ if built
//! ```
//! Outputs: target/figure4.trace, target/figure4.svg, plus the Figure-2
//! occupancy table on stdout.

use wagener_hull::geometry::generators::{generate, Distribution};
use wagener_hull::geometry::point::{live_prefix, pad_to_hood};
use wagener_hull::runtime::{ArtifactRegistry, HullExecutor};
use wagener_hull::serial::monotone_chain;
use wagener_hull::viz::svg::{render_hull_svg, SvgOptions};
use wagener_hull::viz::trace::TraceWriter;
use wagener_hull::wagener;

fn main() {
    let n = 1024;
    // the paper's Figure 4 shows a disk-like scatter of 1024 points
    let points = generate(Distribution::Disk, n, 2012);

    // --- host pipeline with trace (E1: the Figure-1 layout across stages)
    let trace_path = "target/figure4.trace";
    let mut hood = pad_to_hood(&points, n);
    let mut stage_hoods = Vec::new();
    {
        let file = std::fs::File::create(trace_path).unwrap();
        let mut tw = TraceWriter::new(file);
        let mut d = 2;
        while d < n {
            tw.stage(&hood, d).unwrap();
            stage_hoods.push(
                hood.chunks(d)
                    .map(|b| live_prefix(b).to_vec())
                    .collect::<Vec<_>>(),
            );
            hood = wagener::stage(&hood, d);
            d *= 2;
        }
        tw.finish().unwrap();
    }
    let upper = live_prefix(&hood).to_vec();
    println!("host pipeline: upper hull has {} corners", upper.len());
    println!("trace (paper format) -> {trace_path}");

    // --- cross-checks (E3)
    let serial = monotone_chain::upper_hull(&points);
    assert_eq!(upper, serial, "host == serial");
    let pram = wagener::pram_exec::run_pipeline(&points, n).unwrap();
    assert_eq!(live_prefix(&pram.hood), &serial[..], "pram == serial");
    println!(
        "pram run: {} steps, {} work, conflict factor {:.2}",
        pram.counters.steps,
        pram.counters.work,
        pram.counters.conflict_factor()
    );

    let lower = monotone_chain::lower_hull(&points);
    match ArtifactRegistry::load("artifacts").and_then(HullExecutor::new) {
        Ok(exe) => {
            let meta = exe.registry().select_hull(n, 1).unwrap().clone();
            let out = exe.run_hull(&meta, &[points.clone()]).unwrap();
            assert_eq!(out[0].0, serial, "pjrt == serial");
            assert_eq!(out[0].1, lower, "pjrt lower == serial");
            println!("pjrt artifact {}: matches serial exactly", meta.name);
        }
        Err(e) => println!("(pjrt check skipped: {e:#})"),
    }

    // --- Figure 2: thread allocation table
    let occ = wagener::occupancy::occupancy_table(&points, n);
    println!("\nthread allocation (paper Figure 2):");
    print!("{}", wagener::occupancy::format_table(&occ));

    // --- Figure 4: the picture
    let svg = render_hull_svg(
        &points,
        &upper,
        &lower,
        &stage_hoods,
        &SvgOptions::default(),
    );
    std::fs::write("target/figure4.svg", svg).unwrap();
    println!("\nsvg (hood2ps equivalent) -> target/figure4.svg");
}
