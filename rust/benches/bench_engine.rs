//! E9 — the sharded engine: coordinator pools behind one facade.
//!
//! Two workloads over n = 2^16 disk points at shards = 1 / 2 / 4, each
//! shard pinned to a single exec worker so the shard count is the only
//! variable (auto workers would hand every topology the whole machine):
//!   * one-shot throughput — a 16-request wave routed cheapest-queue;
//!     with one shard every request funnels through one batcher thread +
//!     one shared exec channel, with N shards the wave spreads over N
//!     independent batcher/pool/metrics pipelines;
//!   * merge-heavy sessions — 4 concurrent session lifecycles (threshold
//!     1024, so the tangent-merge path and backend round-trips dominate),
//!     sid-affine routed, one shard's registry lock per session instead
//!     of one global lock.
//!
//! Run: `cargo bench --bench bench_engine` (tier1.sh feeds
//! BENCH_engine.json via WAGENER_BENCH_JSON).

use std::sync::Arc;

use wagener_hull::benchkit::{black_box, Bencher, Report};
use wagener_hull::coordinator::{BackendKind, CoordinatorConfig, HullRequest};
use wagener_hull::engine::{Engine, EngineConfig};
use wagener_hull::geometry::generators::{generate, Distribution};
use wagener_hull::stream::StreamConfig;

fn engine(shards: usize, merge_threshold: usize) -> Arc<Engine> {
    Arc::new(
        Engine::start(EngineConfig {
            shards,
            coordinator: CoordinatorConfig {
                backend: BackendKind::Native,
                workers: 1, // fixed width per shard: shards are the variable
                ..Default::default()
            },
            stream: StreamConfig { merge_threshold, idle_ttl_ms: 0, ..Default::default() },
            ..Default::default()
        })
        .unwrap(),
    )
}

fn main() {
    let b = Bencher::default();
    let n = 1usize << 16;
    let pts = generate(Distribution::Disk, n, 33);

    let mut report = Report::new("E9: sharded engine (native, 1 worker/shard, n=2^16)");

    // one-shot throughput: a 16 x 4096-point wave through the router
    let wave: Vec<Vec<wagener_hull::geometry::point::Point>> =
        pts.chunks(n / 16).map(|c| c.to_vec()).collect();
    for shards in [1usize, 2, 4] {
        let e = engine(shards, 4096);
        let wave2 = wave.clone();
        report.add(b.run(&format!("engine/oneshot_wave16x4096_shards{shards}"), move || {
            let mut ids = 0u64;
            let replies: Vec<_> = wave2
                .iter()
                .map(|pts| {
                    ids += 1;
                    e.submit(HullRequest::new(ids, pts.clone()))
                })
                .collect();
            let mut verts = 0usize;
            for r in replies {
                verts += r.recv().unwrap().unwrap().upper.len();
            }
            black_box(verts)
        }));
    }

    // merge-heavy sessions: 4 CONCURRENT lifecycles (one client thread
    // each, like real connections), low threshold, sid-affine — with one
    // shard all four contend on one registry + one backend pool, with
    // four shards each session owns its slice
    for shards in [1usize, 2, 4] {
        let e = engine(shards, 1024);
        let pts2 = pts.clone();
        report.add(b.run(&format!("engine/sessions4_merge_heavy_shards{shards}"), move || {
            let sids: Vec<u64> = (0..4).map(|_| e.session_open().unwrap()).collect();
            let quarter = pts2.len() / 4;
            let verts: usize = std::thread::scope(|s| {
                let handles: Vec<_> = sids
                    .iter()
                    .enumerate()
                    .map(|(k, &sid)| {
                        let (e, pts2) = (&e, &pts2);
                        s.spawn(move || {
                            for chunk in pts2[k * quarter..(k + 1) * quarter].chunks(1024) {
                                e.session_add(sid, chunk).unwrap();
                            }
                            e.session_hull(sid).unwrap().upper.len()
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).sum()
            });
            for sid in sids {
                e.session_close(sid).unwrap();
            }
            black_box(verts)
        }));
    }
    report.note(
        "one-shot wave spreads across N batcher/pool pipelines; sessions \
         pin to their sid's shard (per-shard registry lock + metrics sink)"
            .to_string(),
    );
    report.finish();
}
