//! Readiness-driven connection core: a small pool of I/O event-loop
//! threads drives every connection through non-blocking sockets and
//! level-triggered readiness (`sys::Poller` — epoll on Linux), so 10k+
//! mostly-idle connections multiplex onto a handful of threads instead
//! of 10k parked handler stacks.
//!
//! Shape per connection: read buffer -> frame decoder (text or binary,
//! auto-detected on the first byte) -> engine dispatch -> write buffer
//! with high/low-watermark backpressure.  Cheap verbs (`PING`, `STATS`)
//! are answered inline on the I/O thread; everything that can block
//! (session verbs take registry locks, one-shot `HULL` preprocessing is
//! CPU-bound) is bounced to a small dispatch pool, and one-shot hulls
//! complete through [`Engine::submit_into`] — the exec worker's
//! completion callback posts the encoded response back to the owning
//! loop through its completion queue and self-pipe waker, so no thread
//! ever parks waiting for a batch.
//!
//! Responses stay in request order because a connection stops decoding
//! while a dispatched request is in flight (`busy`); pipelined frames
//! wait in the read buffer, exactly like the thread-per-connection shim
//! that serves one request at a time.  Both cores build responses with
//! the shared helpers in `server::mod`, so their wire output is
//! identical by construction.

use std::collections::{HashMap, VecDeque};
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::fd::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::{HullRequest, IoMetrics, Metrics};
use crate::engine::Engine;
use crate::{log_debug, log_info};

use super::frame;
use super::proto::{self, Decoded, Request, Response};
use super::sys::{self, EV_READ, EV_WRITE};
use super::tcp::ConnOpts;
use super::{request_deadline, ServerConfig};

const TOKEN_LISTENER: u64 = 0;
const TOKEN_WAKER: u64 = 1;
const FIRST_CONN_TOKEN: u64 = 2;

/// Pause decoding new requests once this much response data is queued
/// unsent; the client must drain before we produce more.
pub(crate) const HIGH_WATER: usize = 1 << 20;
/// Resume below this.
pub(crate) const LOW_WATER: usize = 256 * 1024;
/// Per-readiness-event read budget: a firehose sender cannot starve the
/// other connections on this loop (level-triggering re-arms us).
pub(crate) const READ_BUDGET: usize = 256 * 1024;
pub(crate) const READ_CHUNK: usize = 16 * 1024;
/// Compact the write buffer once this much has been consumed.
pub(crate) const COMPACT_AT: usize = 64 * 1024;
/// Bound on the stop-time drain of in-flight requests and unsent bytes.
pub(crate) const DRAIN_MS: u64 = 2000;

/// Pick the loop count: explicit if configured, else `cores/4` in 1..=4.
pub(crate) fn effective_io_threads(configured: usize) -> usize {
    if configured != 0 {
        return configured.clamp(1, 64);
    }
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    (hw / 4).clamp(1, 4)
}

/// An encoded response ready to be appended to a connection's write
/// buffer, posted by a dispatch-pool or exec-worker thread.
struct Completion {
    token: u64,
    bytes: Vec<u8>,
}

/// The cross-thread face of one event loop: new connections and
/// finished responses land here; the waker gets the loop's attention.
struct LoopShared {
    waker: sys::Waker,
    inbox: Mutex<Vec<TcpStream>>,
    completions: Mutex<Vec<Completion>>,
}

/// A request bounced off the I/O thread to the dispatch pool.
struct Job {
    shared: Arc<LoopShared>,
    token: u64,
    binary: bool,
    req: Request,
    /// Effective deadline, stamped at frame arrival on the I/O thread so
    /// pool queueing time counts against the budget.
    deadline: Option<Instant>,
}

struct PoolShared {
    jobs: Mutex<VecDeque<Job>>,
    cv: Condvar,
    stop: AtomicBool,
}

impl PoolShared {
    fn submit(&self, job: Job) {
        if let Ok(mut q) = self.jobs.lock() {
            q.push_back(job);
            self.cv.notify_one();
        }
    }
}

/// Fixed pool of worker threads for the verbs an I/O thread must not run
/// inline.  Bounded concurrency replaces thread-per-connection: the pool
/// is the only place session locks are taken and hull preprocessing runs.
struct DispatchPool {
    shared: Arc<PoolShared>,
    threads: Vec<JoinHandle<()>>,
}

impl DispatchPool {
    fn start(engine: Arc<Engine>, workers: usize) -> std::io::Result<DispatchPool> {
        let shared = Arc::new(PoolShared {
            jobs: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            stop: AtomicBool::new(false),
        });
        let mut threads = Vec::with_capacity(workers);
        for i in 0..workers {
            let sh = shared.clone();
            let eng = engine.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("hull-dispatch-{i}"))
                    .spawn(move || run_worker(&eng, &sh))?,
            );
        }
        Ok(DispatchPool { shared, threads })
    }

    /// Finish queued jobs, then join the workers.
    fn stop(self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for t in self.threads {
            let _ = t.join();
        }
    }
}

fn run_worker(engine: &Engine, shared: &PoolShared) {
    loop {
        let job = {
            let Ok(mut q) = shared.jobs.lock() else { return };
            loop {
                if let Some(j) = q.pop_front() {
                    break j;
                }
                if shared.stop.load(Ordering::SeqCst) {
                    return;
                }
                q = match shared.cv.wait(q) {
                    Ok(guard) => guard,
                    Err(_) => return,
                };
            }
        };
        run_job(engine, job);
    }
}

fn run_job(engine: &Engine, job: Job) {
    let Job { shared, token, binary, req, deadline } = job;
    match req {
        Request::Hull { id, points, .. } => {
            // Preprocessing runs here (inside submit), the batch on an
            // exec worker; the callback fires wherever the request
            // finishes and never parks this thread.
            let req = HullRequest::new(id, points).with_deadline(deadline);
            engine.submit_into(req, move |result| {
                deliver(&shared, token, binary, &super::hull_response(id, result));
            });
        }
        Request::SessionOpen { id, restore } => {
            let resp = super::session_open_response(engine, id, restore);
            deliver(&shared, token, binary, &resp);
        }
        Request::SessionAdd { sid, points, .. } => {
            let resp = super::session_add_response(engine, sid, &points, deadline);
            deliver(&shared, token, binary, &resp);
        }
        Request::SessionHull { sid, epoch } => {
            let resp = super::session_hull_response(engine, sid, epoch);
            deliver(&shared, token, binary, &resp);
        }
        Request::SessionClose { sid } => {
            let resp = super::session_close_response(engine, sid);
            deliver(&shared, token, binary, &resp);
        }
        Request::Ping | Request::Stats | Request::Quit => {
            unreachable!("inline verbs are answered on the I/O thread")
        }
    }
}

/// Encode `resp` in the connection's protocol and post it to the owning
/// loop.  A loop that already exited just never drains the queue.
fn deliver(shared: &LoopShared, token: u64, binary: bool, resp: &Response) {
    let mut bytes = Vec::new();
    if binary {
        frame::encode_response(&mut bytes, resp);
    } else {
        let _ = proto::write_response(&mut bytes, resp);
    }
    if let Ok(mut c) = shared.completions.lock() {
        c.push(Completion { token, bytes });
    }
    shared.waker.wake();
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum Proto {
    Unknown,
    Text,
    Binary,
}

/// Per-connection state machine.
struct Conn {
    stream: TcpStream,
    peer: String,
    proto: Proto,
    /// Unconsumed input; complete frames are decoded out of the front.
    rbuf: Vec<u8>,
    /// Encoded, unsent responses; `woff` is the flushed prefix.
    wbuf: Vec<u8>,
    woff: usize,
    /// Currently registered poller interest (valid while `registered`).
    interest: u32,
    registered: bool,
    /// A dispatched request is in flight: decoding is paused so the
    /// response order matches the request order.
    busy: bool,
    /// Write buffer crossed `HIGH_WATER`: reads are paused until the
    /// client drains below `LOW_WATER`.
    paused: bool,
    /// Flush what is queued, then close (after `QUIT`, a protocol error,
    /// or EOF).
    closing: bool,
    /// Peer half-closed its sending side; buffered frames still run.
    read_closed: bool,
    frames: u64,
    /// Consecutive recoverable protocol errors (text only; reset by any
    /// well-formed frame).  At `max_proto_errors` the connection is cut.
    proto_errors: u32,
}

struct EventLoop {
    index: usize,
    poller: sys::Poller,
    conns: HashMap<u64, Conn>,
    shared: Arc<LoopShared>,
    /// Every loop's shared face, for round-robin accept handoff.
    peers: Vec<Arc<LoopShared>>,
    rr: usize,
    /// Loop 0 owns the listener.
    listener: Option<TcpListener>,
    engine: Arc<Engine>,
    io: Arc<IoMetrics>,
    pool: Arc<PoolShared>,
    stop: Arc<AtomicBool>,
    next_token: Arc<AtomicU64>,
    opts: ConnOpts,
    draining: bool,
}

impl EventLoop {
    fn run(mut self) {
        let mut events: Vec<sys::Event> = Vec::new();
        let mut deadline: Option<Instant> = None;
        loop {
            if self.stop.load(Ordering::SeqCst) && !self.draining {
                self.begin_drain();
                deadline = Some(Instant::now() + Duration::from_millis(DRAIN_MS));
            }
            if self.draining {
                if self.conns.is_empty() {
                    break;
                }
                if let Some(d) = deadline {
                    if Instant::now() >= d {
                        break;
                    }
                }
            }
            let timeout = if self.draining { 25 } else { -1 };
            if let Err(e) = self.poller.wait(&mut events, timeout) {
                log_info!("io loop {}: poll error: {e}", self.index);
                break;
            }
            for ev in events.iter().copied() {
                match ev.token {
                    TOKEN_LISTENER => self.accept_burst(),
                    TOKEN_WAKER => self.shared.waker.drain(),
                    token => self.conn_event(token, ev),
                }
            }
            self.apply_completions();
            if !self.draining {
                self.adopt_inbox();
            }
        }
        let leftover: Vec<u64> = self.conns.keys().copied().collect();
        for token in leftover {
            self.close_conn(token);
        }
    }

    /// Stop accepting and reading; flush what is queued, let in-flight
    /// requests land, close everything that is already settled.
    fn begin_drain(&mut self) {
        self.draining = true;
        if let Some(l) = self.listener.take() {
            let _ = self.poller.delete(l.as_raw_fd());
        }
        let tokens: Vec<u64> = self.conns.keys().copied().collect();
        for token in tokens {
            let settled = match self.conns.get(&token) {
                Some(c) => !c.busy && c.woff == c.wbuf.len(),
                None => continue,
            };
            if settled {
                self.close_conn(token);
            } else {
                self.update_interest(token);
            }
        }
    }

    fn accept_burst(&mut self) {
        loop {
            let accepted = match &self.listener {
                Some(l) => l.accept(),
                None => return,
            };
            match accepted {
                Ok((stream, _)) => {
                    Metrics::inc(&self.io.accepted);
                    let idx = self.rr % self.peers.len();
                    self.rr = self.rr.wrapping_add(1);
                    if idx == self.index {
                        self.adopt(stream);
                    } else {
                        if let Ok(mut inbox) = self.peers[idx].inbox.lock() {
                            inbox.push(stream);
                        }
                        self.peers[idx].waker.wake();
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return,
                Err(e) => {
                    log_info!("accept error: {e}");
                    return;
                }
            }
        }
    }

    fn adopt_inbox(&mut self) {
        let incoming: Vec<TcpStream> = match self.shared.inbox.lock() {
            Ok(mut inbox) => {
                if inbox.is_empty() {
                    return;
                }
                inbox.drain(..).collect()
            }
            Err(_) => return,
        };
        for stream in incoming {
            self.adopt(stream);
        }
    }

    /// Take ownership of an accepted connection on this loop.
    fn adopt(&mut self, stream: TcpStream) {
        let _ = stream.set_nodelay(true);
        if stream.set_nonblocking(true).is_err() {
            return;
        }
        let token = self.next_token.fetch_add(1, Ordering::Relaxed);
        if self.poller.add(stream.as_raw_fd(), token, EV_READ).is_err() {
            return;
        }
        let peer = match stream.peer_addr() {
            Ok(p) => p.to_string(),
            Err(_) => "<unknown>".into(),
        };
        log_debug!("conn {peer}: connected (loop {})", self.index);
        Metrics::inc(&self.io.loops[self.index].open_connections);
        self.conns.insert(
            token,
            Conn {
                stream,
                peer,
                proto: Proto::Unknown,
                rbuf: Vec::new(),
                wbuf: Vec::new(),
                woff: 0,
                interest: EV_READ,
                registered: true,
                busy: false,
                paused: false,
                closing: false,
                read_closed: false,
                frames: 0,
                proto_errors: 0,
            },
        );
    }

    fn close_conn(&mut self, token: u64) {
        if let Some(conn) = self.conns.remove(&token) {
            if conn.registered {
                let _ = self.poller.delete(conn.stream.as_raw_fd());
            }
            Metrics::sub(&self.io.loops[self.index].open_connections, 1);
            let proto = match conn.proto {
                Proto::Unknown => "undetected",
                Proto::Text => "text",
                Proto::Binary => "binary",
            };
            log_debug!(
                "conn {}: disconnected after {} frame(s) ({proto}, loop {})",
                conn.peer,
                conn.frames,
                self.index
            );
        }
    }

    fn conn_event(&mut self, token: u64, ev: sys::Event) {
        let Some(conn) = self.conns.get(&token) else {
            return; // stale event for a connection closed this iteration
        };
        let skip_read = conn.read_closed || self.draining;
        if ev.writable && !self.flush_conn(token) {
            self.close_conn(token);
            return;
        }
        if ev.readable && !skip_read && !self.read_conn(token) {
            self.close_conn(token);
            return;
        }
        self.post_io(token);
    }

    /// Decode what is decodable, flush what is flushable, then settle the
    /// connection's fate and poller interest.
    fn post_io(&mut self, token: u64) {
        self.decode_conn(token);
        if !self.flush_conn(token) {
            self.close_conn(token);
            return;
        }
        let Some(conn) = self.conns.get_mut(&token) else { return };
        if conn.read_closed && !conn.busy {
            // the decoder ran dry and nothing more can arrive
            conn.closing = true;
        }
        if conn.closing && !conn.busy && conn.woff == conn.wbuf.len() {
            self.close_conn(token);
            return;
        }
        self.update_interest(token);
    }

    /// Drain the socket into the read buffer (bounded per event).
    /// Returns false when the connection is dead.
    fn read_conn(&mut self, token: u64) -> bool {
        let Some(conn) = self.conns.get_mut(&token) else { return true };
        let mut chunk = [0u8; READ_CHUNK];
        let budget = conn.rbuf.len() + READ_BUDGET;
        loop {
            match conn.stream.read(&mut chunk) {
                Ok(0) => {
                    conn.read_closed = true;
                    return true;
                }
                Ok(n) => {
                    conn.rbuf.extend_from_slice(&chunk[..n]);
                    Metrics::add(&self.io.loops[self.index].bytes_in, n as u64);
                    if n < chunk.len() || conn.rbuf.len() >= budget {
                        return true;
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return true,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => return false,
            }
        }
    }

    /// Decode and dispatch frames until the buffer runs dry, a dispatched
    /// request pauses the connection, or a protocol error ends it.
    fn decode_conn(&mut self, token: u64) {
        enum Step {
            Wait,
            Frame(Request, bool),
            /// A protocol error: the response, plus the bad prefix to
            /// discard to resync (0 = unrecoverable, cut the connection).
            Fail(Response, usize),
        }
        let max_proto_errors = self.opts.max_proto_errors;
        loop {
            let step = {
                let Some(conn) = self.conns.get_mut(&token) else { return };
                if conn.busy || conn.closing || conn.rbuf.is_empty() {
                    return;
                }
                if conn.proto == Proto::Unknown {
                    conn.proto = if conn.rbuf[0] == frame::REQ_MAGIC {
                        Proto::Binary
                    } else {
                        Proto::Text
                    };
                    log_debug!(
                        "conn {}: protocol={}",
                        conn.peer,
                        if conn.proto == Proto::Binary { "binary" } else { "text" }
                    );
                }
                let binary = conn.proto == Proto::Binary;
                let started = Instant::now();
                let decoded = if binary {
                    // a bad binary frame loses framing: resync 0, fatal
                    frame::decode_request(&conn.rbuf).map_err(|e| (e, 0))
                } else {
                    proto::decode_text_request_resync(&conn.rbuf)
                };
                match decoded {
                    Ok(Decoded::Need(_)) => Step::Wait,
                    Ok(Decoded::Frame(req, used)) => {
                        self.io.decode_latency.record(started.elapsed());
                        Metrics::inc(if binary {
                            &self.io.frames_binary
                        } else {
                            &self.io.frames_text
                        });
                        conn.rbuf.drain(..used);
                        conn.frames += 1;
                        conn.proto_errors = 0;
                        Step::Frame(req, binary)
                    }
                    Err((e, resync)) => Step::Fail(super::proto_error_response(&e), resync),
                }
            };
            match step {
                Step::Wait => return,
                Step::Frame(req, binary) => self.handle_request(token, binary, req),
                Step::Fail(resp, resync) => {
                    // answer (echoing the id when the header parsed);
                    // text connections resync on the next line up to the
                    // consecutive-abuse ceiling, binary ends immediately
                    self.enqueue(token, &resp);
                    let Some(conn) = self.conns.get_mut(&token) else { return };
                    conn.proto_errors += 1;
                    let over = max_proto_errors != 0 && conn.proto_errors >= max_proto_errors;
                    if resync == 0 || over {
                        if over {
                            log_info!(
                                "conn {}: disconnecting after {} consecutive protocol errors",
                                conn.peer,
                                conn.proto_errors
                            );
                        }
                        conn.closing = true;
                        return;
                    }
                    conn.rbuf.drain(..resync);
                }
            }
        }
    }

    fn handle_request(&mut self, token: u64, binary: bool, req: Request) {
        match req {
            Request::Ping => self.enqueue(token, &Response::Pong),
            Request::Stats => {
                // merged aggregate + per_shard array + the I/O core's
                // gauges; cheap (atomics only), so answered inline
                let active = self.io.open_connections();
                let snap = self.engine.stats_io(Some(active), Some(&self.io)).0.to_string();
                self.enqueue(token, &Response::Stats(snap));
            }
            Request::Quit => {
                if let Some(conn) = self.conns.get_mut(&token) {
                    conn.closing = true;
                }
            }
            req => {
                let deadline = match &req {
                    Request::Hull { tmo_ms, .. } | Request::SessionAdd { tmo_ms, .. } => {
                        request_deadline(self.opts.request_timeout_ms, *tmo_ms)
                    }
                    _ => None,
                };
                if let Some(conn) = self.conns.get_mut(&token) {
                    conn.busy = true;
                    self.pool.submit(Job {
                        shared: self.shared.clone(),
                        token,
                        binary,
                        req,
                        deadline,
                    });
                }
            }
        }
    }

    /// Append an inline response to the write buffer in the connection's
    /// protocol.
    fn enqueue(&mut self, token: u64, resp: &Response) {
        let Some(conn) = self.conns.get_mut(&token) else { return };
        if conn.proto == Proto::Binary {
            frame::encode_response(&mut conn.wbuf, resp);
        } else {
            let _ = proto::write_response(&mut conn.wbuf, resp);
        }
        if !conn.paused && conn.wbuf.len() - conn.woff >= HIGH_WATER {
            conn.paused = true;
            Metrics::inc(&self.io.backpressure_stalls);
        }
    }

    /// Write as much of the buffered output as the socket accepts.
    /// Returns false when the connection is dead.
    fn flush_conn(&mut self, token: u64) -> bool {
        let Some(conn) = self.conns.get_mut(&token) else { return true };
        while conn.woff < conn.wbuf.len() {
            match conn.stream.write(&conn.wbuf[conn.woff..]) {
                Ok(0) => return false,
                Ok(n) => {
                    conn.woff += n;
                    Metrics::add(&self.io.loops[self.index].bytes_out, n as u64);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => return false,
            }
        }
        if conn.woff == conn.wbuf.len() {
            conn.wbuf.clear();
            conn.woff = 0;
        } else if conn.woff >= COMPACT_AT {
            conn.wbuf.drain(..conn.woff);
            conn.woff = 0;
        }
        if conn.paused && conn.wbuf.len() - conn.woff < LOW_WATER {
            conn.paused = false;
        }
        true
    }

    /// Pull finished responses posted by dispatch/exec threads into
    /// their connections' write buffers.
    fn apply_completions(&mut self) {
        let done: Vec<Completion> = match self.shared.completions.lock() {
            Ok(mut c) => {
                if c.is_empty() {
                    return;
                }
                c.drain(..).collect()
            }
            Err(_) => return,
        };
        for c in done {
            let Some(conn) = self.conns.get_mut(&c.token) else {
                continue; // connection died while its request ran
            };
            conn.busy = false;
            conn.wbuf.extend_from_slice(&c.bytes);
            if !conn.paused && conn.wbuf.len() - conn.woff >= HIGH_WATER {
                conn.paused = true;
                Metrics::inc(&self.io.backpressure_stalls);
            }
            // resume: decode any pipelined frames, flush, re-arm
            self.post_io(c.token);
        }
    }

    /// Register exactly the interest the state machine needs; a
    /// connection needing neither (in-flight request, nothing to write)
    /// is deregistered entirely so hangup storms cannot spin the loop.
    fn update_interest(&mut self, token: u64) {
        let draining = self.draining;
        let Some(conn) = self.conns.get_mut(&token) else { return };
        let mut want = 0u32;
        if !conn.closing && !conn.busy && !conn.paused && !conn.read_closed && !draining {
            want |= EV_READ;
        }
        if conn.woff < conn.wbuf.len() {
            want |= EV_WRITE;
        }
        let fd = conn.stream.as_raw_fd();
        if want == 0 {
            if conn.registered {
                let _ = self.poller.delete(fd);
                conn.registered = false;
            }
        } else if !conn.registered {
            if self.poller.add(fd, token, want).is_ok() {
                conn.registered = true;
                conn.interest = want;
            }
        } else if want != conn.interest && self.poller.modify(fd, token, want).is_ok() {
            conn.interest = want;
        }
    }
}

/// Handle to a running event-loop server (shutdown on drop).
pub(crate) struct EventHandle {
    pub(crate) local_addr: std::net::SocketAddr,
    engine: Arc<Engine>,
    io: Arc<IoMetrics>,
    stop: Arc<AtomicBool>,
    loops: Vec<Arc<LoopShared>>,
    threads: Vec<JoinHandle<()>>,
    pool: Option<DispatchPool>,
}

impl EventHandle {
    pub(crate) fn active_connections(&self) -> u64 {
        self.io.open_connections()
    }

    pub(crate) fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    fn stop_inner(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        for shared in &self.loops {
            shared.waker.wake();
        }
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        if let Some(pool) = self.pool.take() {
            pool.stop();
        }
    }
}

impl Drop for EventHandle {
    fn drop(&mut self) {
        self.stop_inner();
    }
}

/// Start the event-loop core on `cfg.addr` (non-blocking; returns a
/// handle).
pub(crate) fn serve_event(engine: Arc<Engine>, cfg: &ServerConfig) -> std::io::Result<EventHandle> {
    let listener = TcpListener::bind(&cfg.addr)?;
    listener.set_nonblocking(true)?;
    let local_addr = listener.local_addr()?;
    // best-effort FD headroom: 10k+ connections need more than the
    // common 1024 soft default
    sys::raise_nofile_limit(1 << 16);

    let io_threads = effective_io_threads(cfg.io_threads);
    let io = Arc::new(IoMetrics::new(io_threads));
    let stop = Arc::new(AtomicBool::new(false));
    let next_token = Arc::new(AtomicU64::new(FIRST_CONN_TOKEN));
    log_info!(
        "serving on {local_addr} (backend={} shards={} core=event io_threads={io_threads})",
        engine.backend_name(),
        engine.shard_count()
    );

    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let pool = DispatchPool::start(engine.clone(), hw.clamp(4, 16))?;

    let mut shareds = Vec::with_capacity(io_threads);
    for _ in 0..io_threads {
        shareds.push(Arc::new(LoopShared {
            waker: sys::Waker::new()?,
            inbox: Mutex::new(Vec::new()),
            completions: Mutex::new(Vec::new()),
        }));
    }

    let mut listener = Some(listener);
    let mut threads = Vec::with_capacity(io_threads);
    for (i, shared) in shareds.iter().enumerate() {
        let mut poller = sys::Poller::new()?;
        poller.add(shared.waker.fd(), TOKEN_WAKER, EV_READ)?;
        let own_listener = if i == 0 {
            let l = listener.take().expect("loop 0 takes the listener");
            poller.add(l.as_raw_fd(), TOKEN_LISTENER, EV_READ)?;
            Some(l)
        } else {
            None
        };
        let lp = EventLoop {
            index: i,
            poller,
            conns: HashMap::new(),
            shared: shared.clone(),
            peers: shareds.clone(),
            rr: i,
            listener: own_listener,
            engine: engine.clone(),
            io: io.clone(),
            pool: pool.shared.clone(),
            stop: stop.clone(),
            next_token: next_token.clone(),
            opts: ConnOpts::from_config(cfg),
            draining: false,
        };
        threads.push(
            std::thread::Builder::new().name(format!("hull-io-{i}")).spawn(move || lp.run())?,
        );
    }

    Ok(EventHandle { local_addr, engine, io, stop, loops: shareds, threads, pool: Some(pool) })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn io_thread_auto_sizing_is_clamped() {
        assert_eq!(effective_io_threads(3), 3);
        assert_eq!(effective_io_threads(999), 64);
        let auto = effective_io_threads(0);
        assert!((1..=4).contains(&auto), "auto = {auto}");
    }

    #[test]
    fn watermarks_leave_room_to_resume() {
        assert!(LOW_WATER < HIGH_WATER);
        assert!(COMPACT_AT <= LOW_WATER);
    }
}
