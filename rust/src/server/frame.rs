//! Binary wire format: length-prefixed frames with packed little-endian
//! f64 point pairs — the zero-copy alternative to the text protocol (no
//! per-coordinate float formatting/parsing on the hot path).
//!
//! ```text
//! request  = C7 01 <verb u8> <id u64 LE> <count u32 LE> [tmo u32 LE] count×(x f64 LE, y f64 LE)
//!   verbs: 1 HULL  2 SOPEN  3 SADD  4 SHULL  5 SCLOSE  6 STATS  7 PING  8 QUIT
//!   `id` carries the request id (HULL/SOPEN), the sid (SADD/SHULL/SCLOSE),
//!   or 0 (STATS/PING/QUIT); `count` is nonzero only for HULL/SADD.
//!   The verb byte's high bit (0x80) flags a per-request deadline: when
//!   set on HULL/SADD, a `u32` deadline budget in milliseconds follows the
//!   fixed header (before the point payload).  The flag is invalid on
//!   payload-less verbs.  Decoders that predate the flag see an unknown
//!   verb and answer `Malformed` — never a silently misparsed frame.
//!   Bit 0x40 flags a `u64 LE` operand extension after the fixed header,
//!   valid only on SOPEN (the sid of a snapshotted session to restore)
//!   and SHULL (the epoch of a historical hull to read) — the binary form
//!   of the text protocol's optional second operand.  It is rejected on
//!   every other verb under the same no-silent-misparse rule.
//!
//! response = C8 01 <kind u8> <flag u8> <id u64 LE> <plen u32 LE> plen payload bytes
//!   kinds: 1 HullOk   [queue_ns u64][exec_ns u64][k_up u32][k_lo u32]
//!                     (k_up+k_lo)×16 point bytes, backend utf8 = rest
//!          2 HullErr  message utf8 = payload
//!          3 Malformed  flag=1 when the failed frame's id was recovered
//!                     (id field echoes it), message utf8 = payload
//!          4 SOpened  [sid u64]
//!          5 SAdded   [absorbed u64][pending u64][epoch u64]
//!          6 SHullOk  [epoch u64][k_up u32][k_lo u32] + point bytes
//!          7 SClosed  (empty)
//!          8 SErr     flag = session verb (1 SOPEN 2 SADD 3 SHULL 4 SCLOSE),
//!                     message utf8 = payload
//!          9 Stats    JSON utf8 = payload
//!         10 Pong     (empty)
//! ```
//!
//! A connection's first byte selects the protocol: `0xC7` means binary,
//! anything else (text verbs start with ASCII `H`/`S`/`P`/`Q`) falls back
//! to the line protocol.  Decoders are incremental ([`Decoded::Need`]
//! reports the total bytes required), reject oversized counts *before*
//! any payload is buffered (the same `MAX_REQUEST_POINTS` DoS guard as
//! the text path, with the same id-echo rules), and never allocate more
//! than a small multiple of the bytes actually received.

use std::io::{Read, Write};

use crate::geometry::point::Point;

use super::proto::{Decoded, ProtoError, Request, Response, SessionVerb, MAX_REQUEST_POINTS};

/// First byte of every binary request frame (the auto-detection octet).
pub const REQ_MAGIC: u8 = 0xC7;
/// First byte of every binary response frame.
pub const RESP_MAGIC: u8 = 0xC8;
/// Wire format version.
pub const VERSION: u8 = 0x01;

const REQ_HEADER: usize = 15; // magic + ver + verb + id + count
/// Verb-byte flag: a u32 deadline (ms) follows the fixed request header.
const F_DEADLINE: u8 = 0x80;
/// Verb-byte flag: a u64 operand (restore sid for SOPEN, epoch for
/// SHULL) follows the fixed request header.
const F_ARG: u8 = 0x40;
const RESP_HEADER: usize = 16; // magic + ver + kind + flag + id + plen

const V_HULL: u8 = 1;
const V_SOPEN: u8 = 2;
const V_SADD: u8 = 3;
const V_SHULL: u8 = 4;
const V_SCLOSE: u8 = 5;
const V_STATS: u8 = 6;
const V_PING: u8 = 7;
const V_QUIT: u8 = 8;

const K_HULL_OK: u8 = 1;
const K_HULL_ERR: u8 = 2;
const K_MALFORMED: u8 = 3;
const K_SOPENED: u8 = 4;
const K_SADDED: u8 = 5;
const K_SHULL_OK: u8 = 6;
const K_SCLOSED: u8 = 7;
const K_SERR: u8 = 8;
const K_STATS: u8 = 9;
const K_PONG: u8 = 10;

/// Largest acceptable response payload: two full chains of the largest
/// request plus generous header/JSON slack.  Anything bigger is a corrupt
/// length prefix, rejected before allocation.
const MAX_RESPONSE_PAYLOAD: usize = MAX_REQUEST_POINTS * 32 + (1 << 20);

fn malformed(detail: impl Into<String>) -> ProtoError {
    ProtoError::Malformed { id: None, detail: detail.into() }
}

fn verb_code(v: SessionVerb) -> u8 {
    match v {
        SessionVerb::Open => 1,
        SessionVerb::Add => 2,
        SessionVerb::Hull => 3,
        SessionVerb::Close => 4,
    }
}

fn verb_from_code(c: u8) -> Option<SessionVerb> {
    Some(match c {
        1 => SessionVerb::Open,
        2 => SessionVerb::Add,
        3 => SessionVerb::Hull,
        4 => SessionVerb::Close,
        _ => return None,
    })
}

// ------------------------------------------------------------- encoding

fn push_points(out: &mut Vec<u8>, pts: &[Point]) {
    out.reserve(pts.len() * 16);
    for p in pts {
        out.extend_from_slice(&p.x.to_le_bytes());
        out.extend_from_slice(&p.y.to_le_bytes());
    }
}

fn req_header(out: &mut Vec<u8>, verb: u8, id: u64, count: u32) {
    out.push(REQ_MAGIC);
    out.push(VERSION);
    out.push(verb);
    out.extend_from_slice(&id.to_le_bytes());
    out.extend_from_slice(&count.to_le_bytes());
}

fn req_header_tmo(out: &mut Vec<u8>, verb: u8, id: u64, count: u32, tmo_ms: Option<u32>) {
    match tmo_ms {
        Some(ms) => {
            req_header(out, verb | F_DEADLINE, id, count);
            out.extend_from_slice(&ms.to_le_bytes());
        }
        None => req_header(out, verb, id, count),
    }
}

/// Serialize a request into `out` (appends; does not clear).
pub fn encode_request(out: &mut Vec<u8>, req: &Request) {
    match req {
        Request::Hull { id, points, tmo_ms } => {
            req_header_tmo(out, V_HULL, *id, points.len() as u32, *tmo_ms);
            push_points(out, points);
        }
        Request::SessionOpen { id, restore } => match restore {
            Some(sid) => {
                req_header(out, V_SOPEN | F_ARG, *id, 0);
                out.extend_from_slice(&sid.to_le_bytes());
            }
            None => req_header(out, V_SOPEN, *id, 0),
        },
        Request::SessionAdd { sid, points, tmo_ms } => {
            req_header_tmo(out, V_SADD, *sid, points.len() as u32, *tmo_ms);
            push_points(out, points);
        }
        Request::SessionHull { sid, epoch } => match epoch {
            Some(e) => {
                req_header(out, V_SHULL | F_ARG, *sid, 0);
                out.extend_from_slice(&e.to_le_bytes());
            }
            None => req_header(out, V_SHULL, *sid, 0),
        },
        Request::SessionClose { sid } => req_header(out, V_SCLOSE, *sid, 0),
        Request::Stats => req_header(out, V_STATS, 0, 0),
        Request::Ping => req_header(out, V_PING, 0, 0),
        Request::Quit => req_header(out, V_QUIT, 0, 0),
    }
}

fn resp_header(out: &mut Vec<u8>, kind: u8, flag: u8, id: u64, plen: usize) {
    out.push(RESP_MAGIC);
    out.push(VERSION);
    out.push(kind);
    out.push(flag);
    out.extend_from_slice(&id.to_le_bytes());
    out.extend_from_slice(&(plen as u32).to_le_bytes());
}

/// Serialize a response into `out` (appends; does not clear).
pub fn encode_response(out: &mut Vec<u8>, resp: &Response) {
    match resp {
        Response::Hull { id, upper, lower, backend, queue_ns, exec_ns } => {
            let npts = upper.len() + lower.len();
            resp_header(out, K_HULL_OK, 0, *id, 24 + npts * 16 + backend.len());
            out.extend_from_slice(&queue_ns.to_le_bytes());
            out.extend_from_slice(&exec_ns.to_le_bytes());
            out.extend_from_slice(&(upper.len() as u32).to_le_bytes());
            out.extend_from_slice(&(lower.len() as u32).to_le_bytes());
            push_points(out, upper);
            push_points(out, lower);
            out.extend_from_slice(backend.as_bytes());
        }
        Response::HullErr { id, message } => {
            resp_header(out, K_HULL_ERR, 0, *id, message.len());
            out.extend_from_slice(message.as_bytes());
        }
        Response::MalformedErr { id, message } => {
            resp_header(out, K_MALFORMED, u8::from(id.is_some()), id.unwrap_or(0), message.len());
            out.extend_from_slice(message.as_bytes());
        }
        Response::SessionOpened { id, sid } => {
            resp_header(out, K_SOPENED, 0, *id, 8);
            out.extend_from_slice(&sid.to_le_bytes());
        }
        Response::SessionAdded { sid, absorbed, pending, epoch } => {
            resp_header(out, K_SADDED, 0, *sid, 24);
            out.extend_from_slice(&absorbed.to_le_bytes());
            out.extend_from_slice(&pending.to_le_bytes());
            out.extend_from_slice(&epoch.to_le_bytes());
        }
        Response::SessionHull { sid, epoch, upper, lower } => {
            let npts = upper.len() + lower.len();
            resp_header(out, K_SHULL_OK, 0, *sid, 16 + npts * 16);
            out.extend_from_slice(&epoch.to_le_bytes());
            out.extend_from_slice(&(upper.len() as u32).to_le_bytes());
            out.extend_from_slice(&(lower.len() as u32).to_le_bytes());
            push_points(out, upper);
            push_points(out, lower);
        }
        Response::SessionClosed { sid } => resp_header(out, K_SCLOSED, 0, *sid, 0),
        Response::SessionErr { verb, id, message } => {
            resp_header(out, K_SERR, verb_code(*verb), *id, message.len());
            out.extend_from_slice(message.as_bytes());
        }
        Response::Stats(json) => {
            resp_header(out, K_STATS, 0, 0, json.len());
            out.extend_from_slice(json.as_bytes());
        }
        Response::Pong => resp_header(out, K_PONG, 0, 0, 0),
    }
}

// ------------------------------------------------------------- decoding

pub(crate) fn read_points(bytes: &[u8], count: usize) -> Vec<Point> {
    debug_assert_eq!(bytes.len(), count * 16);
    let mut pts = Vec::with_capacity(count);
    for pair in bytes.chunks_exact(16) {
        let x = f64::from_le_bytes(pair[..8].try_into().unwrap());
        let y = f64::from_le_bytes(pair[8..].try_into().unwrap());
        pts.push(Point::new(x, y));
    }
    pts
}

/// Decode one request frame from the front of `buf`.  `Need(n)` means the
/// caller must supply `n` total bytes before retrying; errors follow the
/// text protocol's id-echo rules (the id is echoed whenever the fixed
/// header parsed).
pub fn decode_request(buf: &[u8]) -> Result<Decoded<Request>, ProtoError> {
    if buf.len() < REQ_HEADER {
        return Ok(Decoded::Need(REQ_HEADER));
    }
    if buf[0] != REQ_MAGIC {
        return Err(malformed(format!("bad frame magic 0x{:02X}", buf[0])));
    }
    if buf[1] != VERSION {
        return Err(malformed(format!("unsupported frame version {}", buf[1])));
    }
    let has_tmo = buf[2] & F_DEADLINE != 0;
    let has_arg = buf[2] & F_ARG != 0;
    let verb = buf[2] & !(F_DEADLINE | F_ARG);
    let id = u64::from_le_bytes(buf[3..11].try_into().unwrap());
    let count = u32::from_le_bytes(buf[11..15].try_into().unwrap()) as usize;
    match verb {
        V_HULL | V_SADD => {
            if has_arg {
                return Err(ProtoError::Malformed {
                    id: Some(id),
                    detail: format!("verb {verb} carries no operand extension"),
                });
            }
            if count > MAX_REQUEST_POINTS {
                return Err(ProtoError::TooManyPoints {
                    id,
                    points: count,
                    session: verb == V_SADD,
                });
            }
            let body = REQ_HEADER + if has_tmo { 4 } else { 0 };
            let need = body + count * 16;
            if buf.len() < need {
                return Ok(Decoded::Need(need));
            }
            let tmo_ms = has_tmo
                .then(|| u32::from_le_bytes(buf[REQ_HEADER..body].try_into().unwrap()));
            let points = read_points(&buf[body..need], count);
            let req = if verb == V_HULL {
                Request::Hull { id, points, tmo_ms }
            } else {
                Request::SessionAdd { sid: id, points, tmo_ms }
            };
            Ok(Decoded::Frame(req, need))
        }
        V_SOPEN | V_SHULL | V_SCLOSE | V_STATS | V_PING | V_QUIT => {
            if has_tmo {
                return Err(ProtoError::Malformed {
                    id: Some(id),
                    detail: format!("verb {verb} does not carry a deadline"),
                });
            }
            if count != 0 {
                return Err(ProtoError::Malformed {
                    id: Some(id),
                    detail: format!("verb {verb} carries no point payload (count {count})"),
                });
            }
            if has_arg && verb != V_SOPEN && verb != V_SHULL {
                return Err(ProtoError::Malformed {
                    id: Some(id),
                    detail: format!("verb {verb} carries no operand extension"),
                });
            }
            let (arg, need) = if has_arg {
                let need = REQ_HEADER + 8;
                if buf.len() < need {
                    return Ok(Decoded::Need(need));
                }
                let arg =
                    u64::from_le_bytes(buf[REQ_HEADER..need].try_into().unwrap());
                (Some(arg), need)
            } else {
                (None, REQ_HEADER)
            };
            let req = match verb {
                V_SOPEN => Request::SessionOpen { id, restore: arg },
                V_SHULL => Request::SessionHull { sid: id, epoch: arg },
                V_SCLOSE => Request::SessionClose { sid: id },
                V_STATS => Request::Stats,
                V_PING => Request::Ping,
                _ => Request::Quit,
            };
            Ok(Decoded::Frame(req, need))
        }
        other => Err(ProtoError::Malformed {
            id: Some(id),
            detail: format!("unknown verb {other}"),
        }),
    }
}

/// Bounds-checked little cursor over a response payload.
struct Cur<'a> {
    b: &'a [u8],
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], ProtoError> {
        if self.b.len() < n {
            return Err(malformed("truncated response payload"));
        }
        let (head, tail) = self.b.split_at(n);
        self.b = tail;
        Ok(head)
    }

    fn u32(&mut self) -> Result<u32, ProtoError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, ProtoError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn points(&mut self, count: usize) -> Result<Vec<Point>, ProtoError> {
        let bytes = self.take(count * 16)?;
        Ok(read_points(bytes, count))
    }

    fn rest_utf8(self) -> String {
        String::from_utf8_lossy(self.b).into_owned()
    }
}

/// Decode one response frame from the front of `buf` (client side).
pub fn decode_response(buf: &[u8]) -> Result<Decoded<Response>, ProtoError> {
    if buf.len() < RESP_HEADER {
        return Ok(Decoded::Need(RESP_HEADER));
    }
    if buf[0] != RESP_MAGIC {
        return Err(malformed(format!("bad response magic 0x{:02X}", buf[0])));
    }
    if buf[1] != VERSION {
        return Err(malformed(format!("unsupported frame version {}", buf[1])));
    }
    let kind = buf[2];
    let flag = buf[3];
    let id = u64::from_le_bytes(buf[4..12].try_into().unwrap());
    let plen = u32::from_le_bytes(buf[12..16].try_into().unwrap()) as usize;
    if plen > MAX_RESPONSE_PAYLOAD {
        return Err(malformed(format!("response payload {plen} over limit")));
    }
    let need = RESP_HEADER + plen;
    if buf.len() < need {
        return Ok(Decoded::Need(need));
    }
    let mut cur = Cur { b: &buf[RESP_HEADER..need] };
    let resp = match kind {
        K_HULL_OK => {
            let queue_ns = cur.u64()?;
            let exec_ns = cur.u64()?;
            let k_up = cur.u32()? as usize;
            let k_lo = cur.u32()? as usize;
            let upper = cur.points(k_up)?;
            let lower = cur.points(k_lo)?;
            Response::Hull { id, upper, lower, backend: cur.rest_utf8(), queue_ns, exec_ns }
        }
        K_HULL_ERR => Response::HullErr { id, message: cur.rest_utf8() },
        K_MALFORMED => Response::MalformedErr {
            id: (flag == 1).then_some(id),
            message: cur.rest_utf8(),
        },
        K_SOPENED => Response::SessionOpened { id, sid: cur.u64()? },
        K_SADDED => Response::SessionAdded {
            sid: id,
            absorbed: cur.u64()?,
            pending: cur.u64()?,
            epoch: cur.u64()?,
        },
        K_SHULL_OK => {
            let epoch = cur.u64()?;
            let k_up = cur.u32()? as usize;
            let k_lo = cur.u32()? as usize;
            let upper = cur.points(k_up)?;
            let lower = cur.points(k_lo)?;
            Response::SessionHull { sid: id, epoch, upper, lower }
        }
        K_SCLOSED => Response::SessionClosed { sid: id },
        K_SERR => Response::SessionErr {
            verb: verb_from_code(flag)
                .ok_or_else(|| malformed(format!("unknown session verb code {flag}")))?,
            id,
            message: cur.rest_utf8(),
        },
        K_STATS => Response::Stats(cur.rest_utf8()),
        K_PONG => Response::Pong,
        other => return Err(malformed(format!("unknown response kind {other}"))),
    };
    Ok(Decoded::Frame(resp, need))
}

// ------------------------------------------------------ blocking shims

/// Drive an incremental decoder over a blocking reader: grow the buffer
/// to exactly what `Need` reports, never over-reading past the frame (the
/// next frame's bytes stay in the stream).  EOF before the first byte —
/// or mid-frame, matching the text reader — surfaces as [`ProtoError::Eof`].
fn read_frame<T, R: Read>(
    r: &mut R,
    decode: fn(&[u8]) -> Result<Decoded<T>, ProtoError>,
) -> Result<T, ProtoError> {
    let mut buf: Vec<u8> = Vec::new();
    loop {
        match decode(&buf)? {
            Decoded::Frame(t, _) => return Ok(t),
            Decoded::Need(n) => {
                let old = buf.len();
                debug_assert!(n > old, "decoder must make progress");
                buf.resize(n, 0);
                if let Err(e) = r.read_exact(&mut buf[old..]) {
                    return Err(match e.kind() {
                        std::io::ErrorKind::UnexpectedEof => ProtoError::Eof,
                        _ => malformed(e.to_string()),
                    });
                }
            }
        }
    }
}

/// Read one binary request off a blocking stream (threaded shim).
pub fn read_request<R: Read>(r: &mut R) -> Result<Request, ProtoError> {
    read_frame(r, decode_request)
}

/// Read one binary response off a blocking stream (client side).
pub fn read_response<R: Read>(r: &mut R) -> Result<Response, ProtoError> {
    read_frame(r, decode_response)
}

/// Serialize + flush a request (client side).
pub fn write_request<W: Write>(w: &mut W, req: &Request) -> std::io::Result<()> {
    let mut buf = Vec::new();
    encode_request(&mut buf, req);
    w.write_all(&buf)?;
    w.flush()
}

/// Serialize + flush a response (server side).
pub fn write_response<W: Write>(w: &mut W, resp: &Response) -> std::io::Result<()> {
    let mut buf = Vec::new();
    encode_response(&mut buf, resp);
    w.write_all(&buf)?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pts(v: &[(f64, f64)]) -> Vec<Point> {
        v.iter().map(|&(x, y)| Point::new(x, y)).collect()
    }

    fn roundtrip_req(req: Request) -> Request {
        let mut buf = Vec::new();
        encode_request(&mut buf, &req);
        match decode_request(&buf).unwrap() {
            Decoded::Frame(r, used) => {
                assert_eq!(used, buf.len(), "frame must consume exactly its bytes");
                r
            }
            Decoded::Need(n) => panic!("complete frame reported Need({n})"),
        }
    }

    fn roundtrip_resp(resp: Response) -> Response {
        let mut buf = Vec::new();
        encode_response(&mut buf, &resp);
        match decode_response(&buf).unwrap() {
            Decoded::Frame(r, used) => {
                assert_eq!(used, buf.len());
                r
            }
            Decoded::Need(n) => panic!("complete frame reported Need({n})"),
        }
    }

    #[test]
    fn requests_roundtrip_bit_exact() {
        for req in [
            Request::Hull { id: 42, points: pts(&[(0.125, 0.25), (0.5, 0.75)]), tmo_ms: None },
            Request::Hull { id: 0, points: vec![], tmo_ms: None },
            Request::Hull {
                id: u64::MAX,
                points: pts(&[(0.1234567890123, 0.000001)]),
                tmo_ms: Some(250),
            },
            Request::SessionOpen { id: 3, restore: None },
            Request::SessionOpen { id: 4, restore: Some(u64::MAX) },
            Request::SessionAdd {
                sid: 17,
                points: pts(&[(0.0, 1.0), (1.0, 0.0)]),
                tmo_ms: Some(u32::MAX),
            },
            Request::SessionAdd { sid: 18, points: vec![], tmo_ms: None },
            Request::SessionHull { sid: 17, epoch: None },
            Request::SessionHull { sid: 17, epoch: Some(0) },
            Request::SessionHull { sid: 17, epoch: Some(12) },
            Request::SessionClose { sid: 17 },
            Request::Stats,
            Request::Ping,
            Request::Quit,
        ] {
            assert_eq!(roundtrip_req(req.clone()), req);
        }
    }

    #[test]
    fn responses_roundtrip_bit_exact() {
        for resp in [
            Response::Hull {
                id: 7,
                upper: pts(&[(0.0, 0.0), (1.0, 1.0)]),
                lower: pts(&[(0.0, 0.0), (0.5, 0.0), (1.0, 1.0)]),
                backend: "pjrt".into(),
                queue_ns: 123,
                exec_ns: 456,
            },
            Response::Hull {
                id: 1,
                upper: vec![],
                lower: vec![],
                backend: String::new(),
                queue_ns: 0,
                exec_ns: 0,
            },
            Response::HullErr { id: 9, message: "empty point set".into() },
            Response::MalformedErr { id: Some(31), message: "bad frame".into() },
            Response::MalformedErr { id: None, message: "bad frame".into() },
            Response::SessionOpened { id: 3, sid: 42 },
            Response::SessionAdded { sid: 42, absorbed: 7, pending: 11, epoch: 2 },
            Response::SessionHull {
                sid: 42,
                epoch: 5,
                upper: pts(&[(0.0, 0.0), (1.0, 1.0)]),
                lower: pts(&[(0.0, 0.0), (0.5, 0.0), (1.0, 1.0)]),
            },
            Response::SessionHull { sid: 1, epoch: 0, upper: vec![], lower: vec![] },
            Response::SessionClosed { sid: 42 },
            Response::SessionErr { verb: SessionVerb::Add, id: 42, message: "nope".into() },
            Response::SessionErr { verb: SessionVerb::Open, id: 9, message: "full".into() },
            Response::SessionErr { verb: SessionVerb::Hull, id: 2, message: "x".into() },
            Response::SessionErr { verb: SessionVerb::Close, id: 2, message: "x".into() },
            Response::Stats(r#"{"requests":1}"#.into()),
            Response::Pong,
        ] {
            assert_eq!(roundtrip_resp(resp.clone()), resp);
        }
    }

    #[test]
    fn nan_and_infinity_coordinates_survive_the_wire() {
        // the decoder is transport, not validation: non-finite values ride
        // through bit-exactly and are rejected by the engine, exactly like
        // the text protocol (Rust's f64 parser accepts "NaN"/"inf" too)
        let mut buf = Vec::new();
        encode_request(
            &mut buf,
            &Request::Hull { id: 1, points: pts(&[(f64::NAN, f64::INFINITY)]), tmo_ms: None },
        );
        match decode_request(&buf).unwrap() {
            Decoded::Frame(Request::Hull { points, .. }, _) => {
                assert!(points[0].x.is_nan());
                assert_eq!(points[0].y, f64::INFINITY);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn incremental_need_is_exact() {
        let req = Request::Hull { id: 5, points: pts(&[(0.1, 0.2), (0.3, 0.4)]), tmo_ms: None };
        let mut buf = Vec::new();
        encode_request(&mut buf, &req);
        assert_eq!(buf.len(), 15 + 32);
        // empty: need the header
        assert!(matches!(decode_request(&[]).unwrap(), Decoded::Need(15)));
        // header only: need the full frame
        assert!(matches!(decode_request(&buf[..15]).unwrap(), Decoded::Need(n) if n == 47));
        // one byte short
        assert!(matches!(decode_request(&buf[..46]).unwrap(), Decoded::Need(47)));
        // trailing bytes of the next frame are not consumed
        let mut two = buf.clone();
        encode_request(&mut two, &Request::Ping);
        match decode_request(&two).unwrap() {
            Decoded::Frame(r, used) => {
                assert_eq!(r, req);
                assert_eq!(used, 47);
            }
            other => panic!("{other:?}"),
        }
        match decode_request(&two[47..]).unwrap() {
            Decoded::Frame(Request::Ping, 15) => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn deadline_flag_extends_the_frame_exactly() {
        let req =
            Request::Hull { id: 5, points: pts(&[(0.1, 0.2), (0.3, 0.4)]), tmo_ms: Some(750) };
        let mut buf = Vec::new();
        encode_request(&mut buf, &req);
        // header + 4-byte deadline + 2×16 points, flag in the verb byte
        assert_eq!(buf.len(), 15 + 4 + 32);
        assert_eq!(buf[2], 1 | 0x80);
        assert_eq!(u32::from_le_bytes(buf[15..19].try_into().unwrap()), 750);
        // header alone reports the deadline-inclusive total
        assert!(matches!(decode_request(&buf[..15]).unwrap(), Decoded::Need(51)));
        assert!(matches!(decode_request(&buf[..50]).unwrap(), Decoded::Need(51)));
        assert_eq!(roundtrip_req(req.clone()), req);
        // the flag is rejected on payload-less verbs, id echoed
        let mut bad = Vec::new();
        req_header(&mut bad, V_PING | F_DEADLINE, 9, 0);
        assert_eq!(decode_request(&bad).unwrap_err().frame_id(), Some(9));
    }

    #[test]
    fn arg_flag_extends_the_frame_exactly() {
        let req = Request::SessionHull { sid: 17, epoch: Some(5) };
        let mut buf = Vec::new();
        encode_request(&mut buf, &req);
        // header + 8-byte epoch, flag in the verb byte
        assert_eq!(buf.len(), 15 + 8);
        assert_eq!(buf[2], V_SHULL | F_ARG);
        assert_eq!(u64::from_le_bytes(buf[15..23].try_into().unwrap()), 5);
        // header alone reports the operand-inclusive total
        assert!(matches!(decode_request(&buf[..15]).unwrap(), Decoded::Need(23)));
        assert!(matches!(decode_request(&buf[..22]).unwrap(), Decoded::Need(23)));
        assert_eq!(roundtrip_req(req.clone()), req);
        // SOPEN restore rides the same extension
        let req = Request::SessionOpen { id: 2, restore: Some(17) };
        let mut buf = Vec::new();
        encode_request(&mut buf, &req);
        assert_eq!(buf[2], V_SOPEN | F_ARG);
        assert_eq!(roundtrip_req(req.clone()), req);
        // the flag is rejected on every other verb, id echoed
        for verb in [V_HULL, V_SADD, V_SCLOSE, V_STATS, V_PING, V_QUIT] {
            let mut bad = Vec::new();
            req_header(&mut bad, verb | F_ARG, 9, 0);
            bad.extend_from_slice(&0u64.to_le_bytes());
            assert_eq!(
                decode_request(&bad).unwrap_err().frame_id(),
                Some(9),
                "verb {verb} must reject the operand flag"
            );
        }
        // flagless frames keep the 15-byte extent (wire compat)
        let mut buf = Vec::new();
        encode_request(&mut buf, &Request::SessionHull { sid: 17, epoch: None });
        assert_eq!(buf.len(), 15);
    }

    #[test]
    fn bad_magic_and_version_rejected() {
        let e = decode_request(&[0x00; 15]).unwrap_err();
        assert_eq!(e.frame_id(), None);
        let mut buf = Vec::new();
        encode_request(&mut buf, &Request::Ping);
        buf[1] = 9; // bogus version
        assert!(decode_request(&buf).is_err());
        let e = decode_response(&[0x00; 16]).unwrap_err();
        assert_eq!(e.frame_id(), None);
    }

    #[test]
    fn oversized_count_rejected_before_payload() {
        // header claims MAX+1 points with zero payload bytes present: the
        // guard must fire from the header alone (no Need, no allocation)
        let mut buf = Vec::new();
        req_header(&mut buf, V_HULL, 1, (MAX_REQUEST_POINTS + 1) as u32);
        assert_eq!(
            decode_request(&buf),
            Err(ProtoError::TooManyPoints {
                id: 1,
                points: MAX_REQUEST_POINTS + 1,
                session: false
            })
        );
        let mut buf = Vec::new();
        req_header(&mut buf, V_SADD, 9, (MAX_REQUEST_POINTS + 1) as u32);
        assert_eq!(
            decode_request(&buf),
            Err(ProtoError::TooManyPoints {
                id: 9,
                points: MAX_REQUEST_POINTS + 1,
                session: true
            })
        );
    }

    #[test]
    fn malformed_binary_frames_echo_the_id_when_parseable() {
        // unknown verb: header parsed, id echoes
        let mut buf = Vec::new();
        req_header(&mut buf, 200, 77, 0);
        assert_eq!(decode_request(&buf).unwrap_err().frame_id(), Some(77));
        // payload on a payload-less verb: id echoes
        let mut buf = Vec::new();
        req_header(&mut buf, V_PING, 5, 3);
        assert_eq!(decode_request(&buf).unwrap_err().frame_id(), Some(5));
        // bad magic: nothing to echo
        assert_eq!(decode_request(&[0xFF; 15]).unwrap_err().frame_id(), None);
    }

    #[test]
    fn corrupt_response_length_rejected() {
        let mut buf = Vec::new();
        resp_header(&mut buf, K_STATS, 0, 0, MAX_RESPONSE_PAYLOAD + 1);
        assert!(decode_response(&buf).is_err());
        // truncated payload inside a declared-valid length
        let mut buf = Vec::new();
        resp_header(&mut buf, K_SOPENED, 0, 1, 4); // SOpened needs 8
        buf.extend_from_slice(&[0u8; 4]);
        assert!(decode_response(&buf).is_err());
    }

    #[test]
    fn blocking_reader_matches_decoder_and_reports_eof() {
        let req = Request::SessionAdd { sid: 6, points: pts(&[(0.5, 0.5)]), tmo_ms: None };
        let mut buf = Vec::new();
        write_request(&mut buf, &req).unwrap();
        assert_eq!(read_request(&mut &buf[..]).unwrap(), req);
        // empty stream: Eof
        assert_eq!(read_request(&mut &b""[..]).unwrap_err(), ProtoError::Eof);
        // mid-frame truncation: Eof passthrough, like the text reader
        assert_eq!(read_request(&mut &buf[..10]).unwrap_err(), ProtoError::Eof);
        assert_eq!(read_request(&mut &buf[..20]).unwrap_err(), ProtoError::Eof);
        let resp = Response::Pong;
        let mut rbuf = Vec::new();
        write_response(&mut rbuf, &resp).unwrap();
        assert_eq!(read_response(&mut &rbuf[..]).unwrap(), resp);
    }

    #[test]
    fn auto_detection_octet_is_unambiguous() {
        // no text verb starts with the binary magic
        for first in [b'H', b'S', b'P', b'Q', b'E'] {
            assert_ne!(first, REQ_MAGIC);
        }
        let mut buf = Vec::new();
        encode_request(&mut buf, &Request::Quit);
        assert_eq!(buf[0], REQ_MAGIC);
    }
}
