//! Coordinator over the PJRT backend: the full three-layer serving path.
//! Requires `make artifacts`; tests SKIP (pass vacuously, with a stderr
//! note) when the artifacts or the PJRT runtime are absent, so the tier-1
//! suite stays green on build hosts without the AOT toolchain.

use std::sync::Arc;

use wagener_hull::coordinator::{
    BackendKind, BatcherConfig, Coordinator, CoordinatorConfig,
};
use wagener_hull::geometry::generators::{generate, Distribution};
use wagener_hull::serial::monotone_chain;

fn pjrt_coord(max_batch: usize, flush_us: u64) -> Option<Coordinator> {
    match Coordinator::start(CoordinatorConfig {
        backend: BackendKind::Pjrt,
        artifacts_dir: format!("{}/artifacts", env!("CARGO_MANIFEST_DIR")).into(),
        batcher: BatcherConfig { max_batch, flush_us, queue_cap: 256 },
        self_check: true,
        ..Default::default()
    }) {
        Ok(c) => Some(c),
        Err(e) => {
            eprintln!("SKIP (pjrt unavailable — run `make artifacts`): {e}");
            None
        }
    }
}

#[test]
fn pjrt_single_request() {
    let Some(c) = pjrt_coord(1, 200) else { return };
    let pts = generate(Distribution::Circle, 200, 11);
    let resp = c.compute(pts.clone()).unwrap();
    let (u, l) = monotone_chain::full_hull(&pts);
    assert_eq!(resp.upper, u);
    assert_eq!(resp.lower, l);
    assert_eq!(resp.backend, "pjrt");
}

#[test]
fn pjrt_batched_wave() {
    let Some(c) = pjrt_coord(8, 2000) else { return };
    let c = Arc::new(c);
    let mut handles = Vec::new();
    for t in 0..8u64 {
        let c = c.clone();
        handles.push(std::thread::spawn(move || {
            // same size class so the batcher can group them
            let pts = generate(Distribution::UniformSquare, 60, 100 + t);
            let resp = c.compute(pts.clone()).unwrap();
            let (u, l) = monotone_chain::full_hull(&pts);
            assert_eq!(resp.upper, u);
            assert_eq!(resp.lower, l);
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let snap = c.snapshot().0;
    assert_eq!(snap.get("responses").unwrap().as_usize(), Some(8));
    let batches = snap.get("batches").unwrap().as_usize().unwrap();
    assert!(batches < 8, "requests were not batched: {batches} batches");
}

#[test]
fn pjrt_mixed_size_classes() {
    let Some(c) = pjrt_coord(4, 300) else { return };
    for (n, seed) in [(10usize, 1u64), (100, 2), (300, 3), (900, 4)] {
        let pts = generate(Distribution::Disk, n, seed);
        let resp = c.compute(pts.clone()).unwrap();
        let (u, l) = monotone_chain::full_hull(&pts);
        assert_eq!(resp.upper, u, "n={n}");
        assert_eq!(resp.lower, l, "n={n}");
    }
}

#[test]
fn pjrt_worker_pool_parity() {
    // each pjrt worker owns its own executor; N workers must still be
    // bit-identical to one
    let mk = |workers: usize| {
        Coordinator::start(CoordinatorConfig {
            backend: BackendKind::Pjrt,
            artifacts_dir: format!("{}/artifacts", env!("CARGO_MANIFEST_DIR")).into(),
            batcher: BatcherConfig { max_batch: 1, flush_us: 100, queue_cap: 64 },
            self_check: true,
            workers,
            ..Default::default()
        })
    };
    let c1 = match mk(1) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("SKIP (pjrt unavailable — run `make artifacts`): {e}");
            return;
        }
    };
    let c3 = mk(3).unwrap();
    for (n, seed) in [(50usize, 1u64), (200, 2), (800, 3)] {
        let pts = generate(Distribution::Disk, n, seed);
        let a = c1.compute(pts.clone()).unwrap();
        let b = c3.compute(pts).unwrap();
        assert_eq!(a.upper, b.upper, "n={n}");
        assert_eq!(a.lower, b.lower, "n={n}");
    }
}

#[test]
fn pjrt_rejects_oversized() {
    let Some(c) = pjrt_coord(1, 100) else { return };
    let max = c.max_points();
    assert!(max >= 1024);
    let pts = generate(Distribution::UniformSquare, max + 1, 5);
    let err = c.compute(pts).unwrap_err();
    assert!(err.to_string().contains("size class"), "{err}");
}

#[test]
fn pjrt_start_fails_cleanly_without_artifacts() {
    // failure injection: missing artifact dir must fail at startup with a
    // useful message, not at first request
    let err = match Coordinator::start(CoordinatorConfig {
        backend: BackendKind::Pjrt,
        artifacts_dir: "/nonexistent/artifacts".into(),
        batcher: BatcherConfig::default(),
        self_check: false,
        ..Default::default()
    }) {
        Ok(_) => panic!("started without artifacts?!"),
        Err(e) => e,
    };
    assert!(err.contains("make artifacts"), "{err}");
}
