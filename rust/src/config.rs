//! Launcher configuration: TOML file + programmatic defaults.
//!
//! ```toml
//! [server]
//! addr = "127.0.0.1:7878"
//! io_threads = 0             # event-loop threads; 0 = auto (cores/4, 1..=4)
//! request_timeout_ms = 0     # default per-request deadline; 0 = none
//! max_proto_errors = 8       # consecutive text protocol errors before
//!                            # disconnect; 0 = never
//!
//! [backend]
//! kind = "pjrt"              # pjrt | native | serial | pram
//! artifacts_dir = "artifacts"
//! self_check = false
//! exec_mode = "fast"         # fast | audited  (pram backend tier)
//!
//! [batcher]
//! max_batch = 8              # 0 = backend preference
//! flush_us = 500
//! queue_cap = 1024
//!
//! [coordinator]
//! workers = 0                # exec worker threads; 0 = hardware threads
//! prefilter = "host"         # octagon pre-filter: host | device | off
//!                            # (bool accepted: true = host, false = off)
//! device_merge = true        # pjrt: session merges via the device
//!                            # tangent kernel (host fallback built in)
//! breaker_cooldown_ms = 1000 # circuit-breaker open -> half-open probe
//!                            # delay after repeated backend failures;
//!                            # 0 disables the breaker
//!
//! [engine]
//! shards = 1                 # coordinator pools; 0 = auto (pjrt -> 1)
//! max_queued = 0             # per-shard in-flight ceiling before new
//!                            # one-shots/SADDs shed with "overloaded";
//!                            # 0 = unbounded
//! placement = "stripe"       # session -> shard map: stripe | ring
//!
//! [stream]
//! max_sessions = 1024        # open streaming-session cap
//! merge_threshold = 4096     # pending points that trigger a re-hull
//! idle_ttl_ms = 60000        # idle session eviction; 0 = never
//!
//! [store]
//! dir = ""                   # snapshot-store directory; "" = durability off
//!
//! [gateway]
//! enabled = false            # HTTP/JSON edge listener (shares the engine
//!                            # with the TCP protocol listener)
//! port = 8080                # bound on the [server] addr's host
//! max_body_bytes = 67108864  # HTTP request-body cap (413 past it);
//!                            # default fits the binary frame point cap
//! page_limit = 4096          # max (and default) hull points per page on
//!                            # GET /v1/sessions/{sid}/hull
//! ```

use std::path::PathBuf;

use anyhow::{anyhow, Result};

use crate::coordinator::{BackendKind, CoordinatorConfig, PrefilterMode};
use crate::engine::PlacementKind;
use crate::pram::ExecMode;
use crate::server::ServerConfig;
use crate::stream::StreamConfig;
use crate::util::tomlmini::{self, Table};

/// `[engine]` section: the shard topology above the coordinator.
#[derive(Clone, Debug)]
pub struct EngineSection {
    /// coordinator-shard count; 0 = auto (pjrt resolves to 1, host
    /// backends to `clamp(hw/4, 1, 8)` — see `engine::EngineConfig`).
    pub shards: usize,
    /// per-shard in-flight ceiling: past it new one-shot requests and
    /// `SADD`s answer the typed error `overloaded` (cheapest-sibling
    /// routing is tried first).  0 = unbounded.
    pub max_queued: usize,
    /// session -> shard map: `stripe` (PR 5's `(sid-1) % N`) or `ring`
    /// (consistent hashing — stable under shard-count changes).
    pub placement: PlacementKind,
}

impl Default for EngineSection {
    fn default() -> Self {
        EngineSection { shards: 1, max_queued: 0, placement: PlacementKind::Stripe }
    }
}

/// `[store]` section: the durable snapshot store.
#[derive(Clone, Debug, Default)]
pub struct StoreSection {
    /// Snapshot-store directory.  `None` (or `""` in TOML) runs without
    /// durability: sessions live and die with the process, pre-PR 8.
    pub dir: Option<PathBuf>,
}

/// `[gateway]` section: the HTTP/JSON edge listener.
#[derive(Clone, Debug)]
pub struct GatewaySection {
    /// Serve HTTP alongside the TCP protocol (both share one engine).
    pub enabled: bool,
    /// HTTP port, bound on the `[server]` addr's host.
    pub port: u16,
    /// Request-body ceiling; larger bodies answer 413.  The default fits
    /// the binary wire format's point cap (`MAX_REQUEST_POINTS` × 16 B).
    pub max_body_bytes: usize,
    /// Max (and default) hull points per page on paginated hull reads.
    pub page_limit: usize,
}

impl Default for GatewaySection {
    fn default() -> Self {
        GatewaySection {
            enabled: false,
            port: 8080,
            max_body_bytes: 1 << 26,
            page_limit: 4096,
        }
    }
}

/// Full launcher configuration.
#[derive(Clone, Debug, Default)]
pub struct Config {
    pub server: ServerConfig,
    pub coordinator: CoordinatorConfig,
    pub engine: EngineSection,
    pub stream: StreamConfig,
    pub store: StoreSection,
    pub gateway: GatewaySection,
}

impl Config {
    /// Parse from TOML text (unknown keys rejected to catch typos).
    pub fn from_toml(text: &str) -> Result<Config> {
        let table: Table = tomlmini::parse(text).map_err(|e| anyhow!("{e}"))?;
        let mut cfg = Config::default();

        for (section, entries) in &table {
            for (key, value) in entries {
                let path = format!("{section}.{key}");
                match path.as_str() {
                    "server.addr" => {
                        cfg.server.addr = value
                            .as_str()
                            .ok_or_else(|| anyhow!("{path}: want string"))?
                            .to_string();
                    }
                    "server.io_threads" => {
                        cfg.server.io_threads = as_usize(value, &path)?;
                    }
                    "server.request_timeout_ms" => {
                        cfg.server.request_timeout_ms = as_usize(value, &path)? as u64;
                    }
                    "server.max_proto_errors" => {
                        cfg.server.max_proto_errors = as_usize(value, &path)? as u32;
                    }
                    "backend.kind" => {
                        let s = value.as_str().ok_or_else(|| anyhow!("{path}: want string"))?;
                        cfg.coordinator.backend = BackendKind::parse(s)
                            .ok_or_else(|| anyhow!("{path}: unknown backend {s:?}"))?;
                    }
                    "backend.artifacts_dir" => {
                        cfg.coordinator.artifacts_dir = PathBuf::from(
                            value.as_str().ok_or_else(|| anyhow!("{path}: want string"))?,
                        );
                    }
                    "backend.self_check" => {
                        cfg.coordinator.self_check =
                            value.as_bool().ok_or_else(|| anyhow!("{path}: want bool"))?;
                    }
                    "backend.exec_mode" => {
                        let s = value.as_str().ok_or_else(|| anyhow!("{path}: want string"))?;
                        cfg.coordinator.exec_mode = ExecMode::parse(s)
                            .ok_or_else(|| anyhow!("{path}: unknown exec mode {s:?}"))?;
                    }
                    "backend.preload" => {
                        cfg.coordinator.preload =
                            value.as_bool().ok_or_else(|| anyhow!("{path}: want bool"))?;
                    }
                    "batcher.max_batch" => {
                        cfg.coordinator.batcher.max_batch = as_usize(value, &path)?;
                    }
                    "batcher.flush_us" => {
                        cfg.coordinator.batcher.flush_us = as_usize(value, &path)? as u64;
                    }
                    "batcher.queue_cap" => {
                        cfg.coordinator.batcher.queue_cap = as_usize(value, &path)?.max(1);
                    }
                    "coordinator.workers" => {
                        cfg.coordinator.workers = as_usize(value, &path)?;
                    }
                    "coordinator.prefilter" => {
                        // historical form: a bool (true = host filter, false
                        // = off).  The string form names where it runs.
                        cfg.coordinator.prefilter = if let Some(b) = value.as_bool() {
                            if b { PrefilterMode::Host } else { PrefilterMode::Off }
                        } else {
                            let s = value
                                .as_str()
                                .ok_or_else(|| anyhow!("{path}: want bool or string"))?;
                            PrefilterMode::parse(s).ok_or_else(|| {
                                anyhow!("{path}: want host | device | off, got {s:?}")
                            })?
                        };
                    }
                    "coordinator.device_merge" => {
                        cfg.coordinator.device_merge =
                            value.as_bool().ok_or_else(|| anyhow!("{path}: want bool"))?;
                    }
                    "coordinator.breaker_cooldown_ms" => {
                        cfg.coordinator.breaker_cooldown_ms = as_usize(value, &path)? as u64;
                    }
                    "engine.shards" => {
                        cfg.engine.shards = as_usize(value, &path)?;
                    }
                    "engine.max_queued" => {
                        cfg.engine.max_queued = as_usize(value, &path)?;
                    }
                    "engine.placement" => {
                        let s = value.as_str().ok_or_else(|| anyhow!("{path}: want string"))?;
                        cfg.engine.placement = PlacementKind::parse(s)
                            .ok_or_else(|| anyhow!("{path}: unknown placement {s:?}"))?;
                    }
                    "store.dir" => {
                        let s = value.as_str().ok_or_else(|| anyhow!("{path}: want string"))?;
                        cfg.store.dir = (!s.is_empty()).then(|| PathBuf::from(s));
                    }
                    "gateway.enabled" => {
                        cfg.gateway.enabled =
                            value.as_bool().ok_or_else(|| anyhow!("{path}: want bool"))?;
                    }
                    "gateway.port" => {
                        cfg.gateway.port = as_usize(value, &path)?
                            .try_into()
                            .map_err(|_| anyhow!("{path}: want a port (0..=65535)"))?;
                    }
                    "gateway.max_body_bytes" => {
                        cfg.gateway.max_body_bytes = as_usize(value, &path)?.max(1);
                    }
                    "gateway.page_limit" => {
                        cfg.gateway.page_limit = as_usize(value, &path)?.max(1);
                    }
                    "stream.max_sessions" => {
                        cfg.stream.max_sessions = as_usize(value, &path)?.max(1);
                    }
                    "stream.merge_threshold" => {
                        cfg.stream.merge_threshold = as_usize(value, &path)?.max(1);
                    }
                    "stream.idle_ttl_ms" => {
                        cfg.stream.idle_ttl_ms = as_usize(value, &path)? as u64;
                    }
                    _ => return Err(anyhow!("unknown config key: {path}")),
                }
            }
        }
        Ok(cfg)
    }

    pub fn from_file(path: &std::path::Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        Self::from_toml(&text)
    }
}

fn as_usize(v: &tomlmini::Value, path: &str) -> Result<usize> {
    v.as_int()
        .and_then(|i| usize::try_from(i).ok())
        .ok_or_else(|| anyhow!("{path}: want a non-negative integer"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let cfg = Config::from_toml(
            r#"
[server]
addr = "0.0.0.0:9000"
io_threads = 2
request_timeout_ms = 750
max_proto_errors = 3
[backend]
kind = "serial"
artifacts_dir = "/tmp/arts"
self_check = true
exec_mode = "audited"
[batcher]
max_batch = 16
flush_us = 250
queue_cap = 99
[coordinator]
workers = 6
prefilter = false
device_merge = false
breaker_cooldown_ms = 125
[engine]
shards = 3
max_queued = 64
placement = "ring"
[stream]
max_sessions = 9
merge_threshold = 128
idle_ttl_ms = 2500
[store]
dir = "/tmp/snaps"
[gateway]
enabled = true
port = 8088
max_body_bytes = 1048576
page_limit = 512
"#,
        )
        .unwrap();
        assert_eq!(cfg.server.addr, "0.0.0.0:9000");
        assert_eq!(cfg.server.io_threads, 2);
        assert_eq!(cfg.server.request_timeout_ms, 750);
        assert_eq!(cfg.server.max_proto_errors, 3);
        assert_eq!(cfg.coordinator.backend, BackendKind::Serial);
        assert_eq!(cfg.coordinator.artifacts_dir, PathBuf::from("/tmp/arts"));
        assert!(cfg.coordinator.self_check);
        assert_eq!(cfg.coordinator.exec_mode, ExecMode::Audited);
        assert_eq!(cfg.coordinator.batcher.max_batch, 16);
        assert_eq!(cfg.coordinator.batcher.flush_us, 250);
        assert_eq!(cfg.coordinator.batcher.queue_cap, 99);
        assert_eq!(cfg.coordinator.workers, 6);
        assert_eq!(cfg.coordinator.prefilter, PrefilterMode::Off);
        assert!(!cfg.coordinator.device_merge);
        assert_eq!(cfg.coordinator.breaker_cooldown_ms, 125);
        assert_eq!(cfg.engine.shards, 3);
        assert_eq!(cfg.engine.max_queued, 64);
        assert_eq!(cfg.engine.placement, PlacementKind::Ring);
        assert_eq!(cfg.store.dir, Some(PathBuf::from("/tmp/snaps")));
        assert_eq!(cfg.stream.max_sessions, 9);
        assert_eq!(cfg.stream.merge_threshold, 128);
        assert_eq!(cfg.stream.idle_ttl_ms, 2500);
        assert!(cfg.gateway.enabled);
        assert_eq!(cfg.gateway.port, 8088);
        assert_eq!(cfg.gateway.max_body_bytes, 1 << 20);
        assert_eq!(cfg.gateway.page_limit, 512);
    }

    #[test]
    fn defaults_when_empty() {
        let cfg = Config::from_toml("").unwrap();
        assert_eq!(cfg.coordinator.backend, BackendKind::Native);
        assert_eq!(cfg.coordinator.exec_mode, ExecMode::Fast);
        assert_eq!(cfg.server.addr, "127.0.0.1:7878");
        assert_eq!(cfg.server.io_threads, 0); // 0 = auto-sized event loop pool
        assert_eq!(cfg.coordinator.workers, 0); // 0 = available parallelism
        assert_eq!(cfg.coordinator.prefilter, PrefilterMode::Host);
        assert!(cfg.coordinator.device_merge);
        assert_eq!(cfg.engine.shards, 1); // sharding is opt-in (0 = auto)
        assert_eq!(cfg.engine.max_queued, 0); // shedding is opt-in
        assert_eq!(cfg.server.request_timeout_ms, 0); // deadlines are opt-in
        assert_eq!(cfg.server.max_proto_errors, 8);
        assert_eq!(cfg.coordinator.breaker_cooldown_ms, 1000);
        assert_eq!(cfg.stream.max_sessions, 1024);
        assert_eq!(cfg.stream.merge_threshold, 4096);
        assert_eq!(cfg.stream.idle_ttl_ms, 60_000);
        assert_eq!(cfg.engine.placement, PlacementKind::Stripe); // ring is opt-in
        assert_eq!(cfg.store.dir, None); // durability is opt-in
        assert!(!cfg.gateway.enabled); // HTTP is opt-in
        assert_eq!(cfg.gateway.port, 8080);
        assert_eq!(cfg.gateway.max_body_bytes, 1 << 26);
        assert_eq!(cfg.gateway.page_limit, 4096);
    }

    #[test]
    fn rejects_unknown_keys_and_bad_types() {
        assert!(Config::from_toml("[server]\nport = 1").is_err());
        assert!(Config::from_toml("[server]\nio_threads = -1").is_err());
        assert!(Config::from_toml("[server]\nio_threads = \"all\"").is_err());
        assert!(Config::from_toml("[backend]\nkind = \"cuda\"").is_err());
        assert!(Config::from_toml("[backend]\nexec_mode = \"warp\"").is_err());
        assert!(Config::from_toml("[batcher]\nmax_batch = \"lots\"").is_err());
        assert!(Config::from_toml("[batcher]\nmax_batch = -3").is_err());
        assert!(Config::from_toml("[coordinator]\nworkers = -1").is_err());
        assert!(Config::from_toml("[coordinator]\nprefilter = 3").is_err());
        assert!(Config::from_toml("[coordinator]\nprefilter = \"gpu\"").is_err());
        assert!(Config::from_toml("[coordinator]\ndevice_merge = 3").is_err());
        assert!(Config::from_toml("[coordinator]\nthreads = 4").is_err());
        // the string form names where the prefilter runs
        let cfg = Config::from_toml("[coordinator]\nprefilter = \"device\"").unwrap();
        assert_eq!(cfg.coordinator.prefilter, PrefilterMode::Device);
        assert!(Config::from_toml("[engine]\nshards = -2").is_err());
        assert!(Config::from_toml("[engine]\npools = 4").is_err());
        assert!(Config::from_toml("[engine]\nplacement = \"rendezvous\"").is_err());
        assert!(Config::from_toml("[store]\ndir = 7").is_err());
        assert!(Config::from_toml("[store]\npath = \"x\"").is_err());
        assert!(Config::from_toml("[gateway]\nenabled = \"yes\"").is_err());
        assert!(Config::from_toml("[gateway]\nport = 70000").is_err());
        assert!(Config::from_toml("[gateway]\nport = -1").is_err());
        assert!(Config::from_toml("[gateway]\nlisten = \"x\"").is_err());
        // empty dir string means "durability off", not a cwd store
        let cfg = Config::from_toml("[store]\ndir = \"\"").unwrap();
        assert_eq!(cfg.store.dir, None);
        assert!(Config::from_toml("[stream]\nmax_sessions = \"many\"").is_err());
        assert!(Config::from_toml("[stream]\nttl = 5").is_err());
        // 0 is clamped to 1 (a session must merge eventually), ttl 0 = off
        let cfg = Config::from_toml("[stream]\nmerge_threshold = 0\nidle_ttl_ms = 0").unwrap();
        assert_eq!(cfg.stream.merge_threshold, 1);
        assert_eq!(cfg.stream.idle_ttl_ms, 0);
    }
}
