//! Minimal, fully-offline stand-in for the `anyhow` crate.
//!
//! This build environment cannot fetch registry crates, so the subset of
//! the `anyhow` API this repository uses is reimplemented here as a path
//! dependency: `Error`, `Result<T>`, the `anyhow!` / `bail!` macros, and
//! the `Context` extension trait for `Result` and `Option`.  Swapping in
//! the real crate is a one-line change in the root Cargo.toml.
//!
//! Semantics match where it matters:
//! * `Error` is NOT `std::error::Error` (so the blanket
//!   `From<E: std::error::Error>` impl is coherent, exactly as in the
//!   real crate);
//! * `{}` displays the outermost message, `{:#}` the full
//!   colon-separated context chain, `{:?}` the chain with "Caused by:";
//! * context wraps outside-in.

use std::fmt;

/// A context-carrying error: `chain[0]` is the outermost message.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with another layer of context (outermost first).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The error chain, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        for cause in self.chain.iter().skip(1) {
            write!(f, "\n\nCaused by:\n    {cause}")?;
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (and missing `Option` values).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// `return Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = io_err().into();
        let e = e.context("reading manifest").context("starting up");
        assert_eq!(format!("{e}"), "starting up");
        assert_eq!(format!("{e:#}"), "starting up: reading manifest: gone");
        assert_eq!(e.root_cause(), "gone");
    }

    #[test]
    fn macros_and_context_trait() {
        fn inner() -> Result<()> {
            bail!("bad {}", 7)
        }
        let e = inner().unwrap_err();
        assert_eq!(format!("{e}"), "bad 7");

        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("ctx").unwrap_err();
        assert_eq!(format!("{e:#}"), "ctx: gone");

        let o: Option<u32> = None;
        let e = o.with_context(|| "missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");

        let from_string = anyhow!(String::from("already a message"));
        assert_eq!(format!("{from_string}"), "already a message");
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(format!("{}", f().unwrap_err()), "gone");
    }
}
