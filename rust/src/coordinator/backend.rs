//! Pluggable hull backends.
//!
//! The default production backend is PJRT (AOT artifacts from the Pallas/
//! JAX layers); `native` (host Wagener), `serial` (monotone chain) and
//! `pram` (cost-accounting simulator) exist for baselines and experiments.
//! PJRT handles are not Send, so backends are constructed *on* the worker
//! thread via [`BackendKind::build`].
//!
//! The `native` and `serial` backends fan the requests of a multi-item
//! batch out across scoped threads ([`par_map_batch`]) — the host-side
//! analogue of the GPU executing batch lanes concurrently.  The `pram`
//! fast tier instead parallelizes *inside* each request (across PEs),
//! so its batch items run in sequence, each with the dispatch's whole
//! thread budget; the audited `pram` tier stays serial throughout: it
//! is the deterministic cost instrument, not a serving path.

use std::path::PathBuf;

use crate::geometry::point::{dedup_x, sort_by_x, Point, REMOTE};
use crate::pram::ExecMode;
use crate::runtime::{ArtifactKind, ArtifactRegistry, HullExecutor};
use crate::serial::monotone_chain;
use crate::wagener;

use super::request::PREFILTER_MIN_POINTS;

/// Which backend the coordinator runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// AOT HLO artifacts on the PJRT CPU client (the three-layer path).
    Pjrt,
    /// rust-native Wagener pipeline.
    Native,
    /// serial monotone chain (the paper's serial comparator).
    Serial,
    /// Wagener on the CREW-PRAM simulator (slow; experiments only).
    Pram,
}

impl BackendKind {
    pub fn parse(s: &str) -> Option<BackendKind> {
        Some(match s {
            "pjrt" => BackendKind::Pjrt,
            "native" => BackendKind::Native,
            "serial" => BackendKind::Serial,
            "pram" => BackendKind::Pram,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Pjrt => "pjrt",
            BackendKind::Native => "native",
            BackendKind::Serial => "serial",
            BackendKind::Pram => "pram",
        }
    }

    /// Construct the backend (call on the thread that will own it).
    /// `preload` compiles every hull artifact up front (server warm start;
    /// §Perf P4 — lazy compilation showed up as 10²-second tail latencies).
    /// `exec_mode` selects the PRAM engine tier: the `pram` backend runs
    /// on it directly, and under `self_check` the `pjrt` backend
    /// cross-checks every PJRT result against the PRAM engine on that
    /// tier ([`HullExecutor::set_reference_check`]).
    pub fn build(
        &self,
        artifacts_dir: &PathBuf,
        preload: bool,
        exec_mode: ExecMode,
        self_check: bool,
    ) -> Result<Box<dyn HullBackend>, String> {
        Ok(match self {
            BackendKind::Pjrt => {
                let reg = ArtifactRegistry::load(artifacts_dir).map_err(|e| e.to_string())?;
                let mut exe = HullExecutor::new(reg).map_err(|e| e.to_string())?;
                if self_check {
                    exe.set_reference_check(Some(exec_mode));
                }
                if preload {
                    let names: Vec<String> = exe
                        .registry()
                        .iter()
                        .filter(|m| {
                            matches!(
                                m.kind,
                                ArtifactKind::Hull | ArtifactKind::Filter | ArtifactKind::Tangent
                            )
                        })
                        .map(|m| m.name.clone())
                        .collect();
                    for name in names {
                        exe.ensure_compiled(&name).map_err(|e| e.to_string())?;
                    }
                }
                Box::new(PjrtBackend { exe })
            }
            BackendKind::Native => Box::new(NativeBackend),
            BackendKind::Serial => Box::new(SerialBackend),
            BackendKind::Pram => Box::new(PramBackend { mode: exec_mode }),
        })
    }
}

/// A batch-capable full-hull computer over preprocessed (x-sorted,
/// distinct-x, f32-quantized) point sets.
pub trait HullBackend {
    fn name(&self) -> &'static str;
    /// largest batch worth grouping (the batcher's flush threshold).
    fn preferred_batch(&self) -> usize;
    /// largest request size this backend accepts.
    fn max_points(&self) -> usize;
    /// compute (upper, lower) chains per request (borrowed slices — the
    /// dispatch path must not copy point data it already owns).
    /// `threads` is the caller's thread budget for intra-batch /
    /// intra-request parallelism at this moment (1 = fully serial; an
    /// idle worker pool hands one dispatch the whole machine, a
    /// saturated pool hands each dispatch 1).  Results are bit-identical
    /// at any budget; `pjrt` ignores it (its handles are `!Send`).
    fn compute(
        &self,
        batch: &[&[Point]],
        threads: usize,
    ) -> Result<Vec<(Vec<Point>, Vec<Point>)>, String>;
    /// Accelerator-resident octagon prefilter: the survivors of `pts`
    /// (input order and bits preserved), or `None` to keep the host path
    /// — non-device backends, inputs below the kernel's size gate, a
    /// size-class miss, or a device failure.  Falling back is always
    /// silent and lossless; the device kernel is hull-preserving under
    /// the same strict-inside rule as the host filter.
    fn device_filter(&self, _pts: &[Point]) -> Option<Vec<Point>> {
        None
    }
    /// Largest point block the device prefilter accepts (0 = none).
    /// Under `prefilter = "device"` admission can ceiling on this instead
    /// of the hull size classes: oversized dense requests shrink on the
    /// accelerator before they ever meet a hull artifact.
    fn device_filter_capacity(&self) -> usize {
        0
    }
    /// Accelerator-resident common-tangent merge of two x-disjoint hulls.
    /// `upper` holds the upper chains `[left, right]`; `lower` the
    /// y-MIRRORED lower chains `[left, right]` (a mirrored lower chain is
    /// a valid upper-convex chain, so one artifact serves both rows — the
    /// whole hull ⊕ hull merge is exactly ONE upload).  Returns the
    /// merged upper chain and the merged still-mirrored lower chain, or
    /// `None` for host fallback (no artifact, chains too long, failure).
    /// Outputs may carry collinear middles; callers canonicalize with a
    /// strict-turn rescan (see `wagener::hull_merge::merge_hulls_with`).
    fn device_tangent(
        &self,
        _upper: [&[Point]; 2],
        _lower: [&[Point]; 2],
    ) -> Option<(Vec<Point>, Vec<Point>)> {
        None
    }
}

/// Below this many total points in a batch, scoped-thread spawns cost
/// more than the hull work they would parallelize.
const PAR_BATCH_MIN_POINTS: usize = 1 << 13;

/// Fan the items of a batch out across up to `pool` scoped threads
/// (contiguous chunks; results come back in input order).  Single-item
/// batches, `pool <= 1`, and batches whose total point count is below
/// [`PAR_BATCH_MIN_POINTS`] run on the calling thread — scoped spawns
/// don't pay for themselves there.
fn par_map_batch<F>(
    batch: &[&[Point]],
    pool: usize,
    f: F,
) -> Result<Vec<(Vec<Point>, Vec<Point>)>, String>
where
    F: Fn(&[Point]) -> Result<(Vec<Point>, Vec<Point>), String> + Sync,
{
    let threads = pool.min(batch.len());
    let total_points: usize = batch.iter().map(|pts| pts.len()).sum();
    if threads <= 1 || total_points < PAR_BATCH_MIN_POINTS {
        return batch.iter().map(|pts| f(pts)).collect();
    }
    let chunk = batch.len().div_ceil(threads);
    let mut slots: Vec<Option<Result<(Vec<Point>, Vec<Point>), String>>> =
        (0..batch.len()).map(|_| None).collect();
    let f = &f;
    std::thread::scope(|scope| {
        let mut chunks = batch.chunks(chunk).zip(slots.chunks_mut(chunk));
        // the calling thread takes the first chunk itself — the budget
        // is `threads` running threads, not `threads` spawns plus an
        // idle dispatcher
        let first = chunks.next();
        for (in_chunk, out_chunk) in chunks {
            scope.spawn(move || {
                for (pts, slot) in in_chunk.iter().zip(out_chunk.iter_mut()) {
                    *slot = Some(f(pts));
                }
            });
        }
        if let Some((in_chunk, out_chunk)) = first {
            for (pts, slot) in in_chunk.iter().zip(out_chunk.iter_mut()) {
                *slot = Some(f(pts));
            }
        }
    });
    slots
        .into_iter()
        .map(|s| s.expect("par_map_batch: a chunk thread died before filling its slot"))
        .collect()
}

// ------------------------------------------------------------------ pjrt

struct PjrtBackend {
    exe: HullExecutor,
}

impl HullBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn preferred_batch(&self) -> usize {
        let classes = self.exe.registry().hull_size_classes();
        classes
            .first()
            .map(|&n| self.exe.registry().hull_batches(n).into_iter().max().unwrap_or(1))
            .unwrap_or(1)
    }

    fn max_points(&self) -> usize {
        self.exe.registry().hull_size_classes().into_iter().max().unwrap_or(0)
    }

    fn compute(
        &self,
        batch: &[&[Point]],
        _threads: usize,
    ) -> Result<Vec<(Vec<Point>, Vec<Point>)>, String> {
        let m = batch.iter().map(|v| v.len()).max().unwrap_or(0);
        let n = self
            .exe
            .registry()
            .hull_size_classes()
            .into_iter()
            .find(|&n| n >= m.max(2))
            .ok_or_else(|| format!("no size class >= {m}"))?;
        let caps = self.exe.registry().hull_batches(n);
        let mut out = Vec::with_capacity(batch.len());
        let mut rest = batch;
        while !rest.is_empty() {
            // smallest capable batch artifact for the remaining chunk
            let b = caps
                .iter()
                .copied()
                .find(|&b| b >= rest.len())
                .unwrap_or_else(|| caps.iter().copied().max().unwrap_or(1));
            let take = rest.len().min(b);
            let meta = self
                .exe
                .registry()
                .select_hull(n, b)
                .map_err(|e| e.to_string())?
                .clone();
            let chunk = self
                .exe
                .run_hull(&meta, &rest[..take])
                .map_err(|e| e.to_string())?;
            out.extend(chunk);
            rest = &rest[take..];
        }
        Ok(out)
    }

    fn device_filter(&self, pts: &[Point]) -> Option<Vec<Point>> {
        // the kernel passes tiny inputs through verbatim — dispatching
        // them would be a pure round-trip tax
        if pts.len() < PREFILTER_MIN_POINTS {
            return None;
        }
        let meta = self.exe.registry().select_filter(pts.len())?.clone();
        self.exe.run_filter(&meta, pts).ok()
    }

    fn device_filter_capacity(&self) -> usize {
        self.exe.registry().max_filter_points()
    }

    fn device_tangent(
        &self,
        upper: [&[Point]; 2],
        lower: [&[Point]; 2],
    ) -> Option<(Vec<Point>, Vec<Point>)> {
        let len = upper.iter().chain(lower.iter()).map(|c| c.len()).max()?;
        let meta = self.exe.registry().select_tangent(len)?.clone();
        let d = meta.n / 2;
        // [H(L) | H(R)] block layout: each half REMOTE-padded to d slots
        let block = |pair: [&[Point]; 2]| {
            let mut blk = vec![REMOTE; meta.n];
            blk[..pair[0].len()].copy_from_slice(pair[0]);
            blk[d..d + pair[1].len()].copy_from_slice(pair[1]);
            blk
        };
        self.exe.run_tangent(&meta, &block(upper), &block(lower)).ok()
    }
}

// ---------------------------------------------------------------- native

struct NativeBackend;

impl HullBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }
    fn preferred_batch(&self) -> usize {
        8
    }
    fn max_points(&self) -> usize {
        1 << 22
    }
    fn compute(
        &self,
        batch: &[&[Point]],
        threads: usize,
    ) -> Result<Vec<(Vec<Point>, Vec<Point>)>, String> {
        par_map_batch(batch, threads, |pts| Ok(wagener::full_hull(pts)))
    }
}

// ---------------------------------------------------------------- serial

struct SerialBackend;

impl HullBackend for SerialBackend {
    fn name(&self) -> &'static str {
        "serial"
    }
    fn preferred_batch(&self) -> usize {
        1
    }
    fn max_points(&self) -> usize {
        1 << 24
    }
    fn compute(
        &self,
        batch: &[&[Point]],
        threads: usize,
    ) -> Result<Vec<(Vec<Point>, Vec<Point>)>, String> {
        par_map_batch(batch, threads, |pts| Ok(monotone_chain::full_hull(pts)))
    }
}

// ------------------------------------------------------------------ pram

struct PramBackend {
    /// `Fast` for serving (parallel, unaudited), `Audited` for the
    /// cost-model instrument.
    mode: ExecMode,
}

impl PramBackend {
    fn one(
        mode: ExecMode,
        fast_threads: usize,
        pts: &[Point],
    ) -> Result<(Vec<Point>, Vec<Point>), String> {
        let slots = pts.len().next_power_of_two().max(2);
        let up = wagener::pram_exec::run_pipeline_mode_threads(pts, slots, mode, true, fast_threads)
            .map_err(|e| e.to_string())?;
        let neg: Vec<Point> = pts.iter().map(|p| Point::new(p.x, -p.y)).collect();
        let lo =
            wagener::pram_exec::run_pipeline_mode_threads(&neg, slots, mode, true, fast_threads)
                .map_err(|e| e.to_string())?;
        let upper = crate::geometry::point::live_prefix(&up.hood).to_vec();
        let lower: Vec<Point> = crate::geometry::point::live_prefix(&lo.hood)
            .iter()
            .map(|p| Point::new(p.x, -p.y))
            .collect();
        Ok((upper, lower))
    }
}

impl HullBackend for PramBackend {
    fn name(&self) -> &'static str {
        match self.mode {
            ExecMode::Fast => "pram-fast",
            ExecMode::Audited => "pram",
        }
    }
    fn preferred_batch(&self) -> usize {
        1
    }
    fn max_points(&self) -> usize {
        // the unaudited tier can serve far larger requests for the same
        // latency budget than the instrument can
        match self.mode {
            ExecMode::Fast => 1 << 18,
            ExecMode::Audited => 1 << 14,
        }
    }
    fn compute(
        &self,
        batch: &[&[Point]],
        threads: usize,
    ) -> Result<Vec<(Vec<Point>, Vec<Point>)>, String> {
        // The fast tier already parallelizes internally across PEs, so
        // batch items run in sequence and each gets the whole budget —
        // fanning requests out on top of the PE pool would double-book
        // it.  The audited instrument is serial by construction either
        // way (its counters stay a deterministic trace).
        batch.iter().map(|pts| Self::one(self.mode, threads, pts)).collect()
    }
}

// ------------------------------------------------------ degenerate exact

/// Exact full hull for inputs violating general position (duplicate x):
/// per x-class only the extreme-y points can be hull corners, so dedup to
/// the max-y (resp. min-y) representative and run the serial chain.
pub fn exact_full_hull(sorted_pts: &[Point]) -> (Vec<Point>, Vec<Point>) {
    let upper = monotone_chain::upper_hull(&dedup_x(sorted_pts, true));
    let lower = monotone_chain::lower_hull(&dedup_x(sorted_pts, false));
    (upper, lower)
}

/// Canonical one-shot hull of *raw* client points: quantize + sort +
/// dedup + exact hull — the semantics every backend's served output is
/// equivalent to (the prefilter is hull-preserving and so omitted).
/// This is the oracle the streaming/merge suites compare against.
pub fn canonical_full_hull(raw: &[Point]) -> (Vec<Point>, Vec<Point>) {
    let mut pts: Vec<Point> = raw.iter().map(|p| p.quantize_f32()).collect();
    sort_by_x(&mut pts);
    pts.dedup();
    exact_full_hull(&pts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::generators::{generate, Distribution};

    #[test]
    fn kind_parse_roundtrip() {
        for k in [BackendKind::Pjrt, BackendKind::Native, BackendKind::Serial, BackendKind::Pram] {
            assert_eq!(BackendKind::parse(k.name()), Some(k));
        }
        assert_eq!(BackendKind::parse("gpu"), None);
    }

    #[test]
    fn native_serial_pram_agree() {
        let native = BackendKind::Native
            .build(&PathBuf::new(), false, ExecMode::Fast, false)
            .unwrap();
        let serial = BackendKind::Serial
            .build(&PathBuf::new(), false, ExecMode::Fast, false)
            .unwrap();
        let pram = BackendKind::Pram
            .build(&PathBuf::new(), false, ExecMode::Audited, false)
            .unwrap();
        let pram_fast = BackendKind::Pram
            .build(&PathBuf::new(), false, ExecMode::Fast, false)
            .unwrap();
        let batch: Vec<Vec<Point>> = (0..3)
            .map(|k| generate(Distribution::ALL[k], 50 + k, k as u64))
            .collect();
        let views: Vec<&[Point]> = batch.iter().map(Vec::as_slice).collect();
        let a = native.compute(&views, 1).unwrap();
        let b = serial.compute(&views, 1).unwrap();
        let c = pram.compute(&views, 1).unwrap();
        let d = pram_fast.compute(&views, 1).unwrap();
        assert_eq!(a, b);
        assert_eq!(b, c);
        assert_eq!(c, d);
    }

    #[test]
    fn intra_batch_fanout_matches_serial_order_and_values() {
        // a batch bigger than the thread budget and heavy enough to
        // clear the PAR_BATCH_MIN_POINTS gate: chunked fan-out must
        // return the same hulls in the same order as the serial path
        let batch: Vec<Vec<Point>> = (0..13)
            .map(|k| generate(Distribution::ALL[k % 7], 700 + 111 * k, 500 + k as u64))
            .collect();
        let views: Vec<&[Point]> = batch.iter().map(Vec::as_slice).collect();
        assert!(views.iter().map(|v| v.len()).sum::<usize>() >= PAR_BATCH_MIN_POINTS);
        for kind in [BackendKind::Native, BackendKind::Serial, BackendKind::Pram] {
            let backend = kind
                .build(&PathBuf::new(), false, ExecMode::Fast, false)
                .unwrap();
            assert_eq!(
                backend.compute(&views, 1).unwrap(),
                backend.compute(&views, 4).unwrap(),
                "{} fan-out diverged",
                kind.name()
            );
        }
    }

    /// The "bit-identical at any thread budget" claim, on the code path
    /// it actually rests on: 9000 points → 16384 slots → 8192 PEs, which
    /// clears `fast_parallel_threshold` (4096), so budget 4 engages the
    /// fast tier's parallel PE dispatch and per-worker write-buffer merge
    /// while budget 1 runs the serial branch.  (Smaller inputs never
    /// leave the serial branch and would test nothing.)
    #[test]
    fn pram_fast_parallel_pe_dispatch_matches_serial() {
        let pts = generate(Distribution::Disk, 9000, 42);
        let views: Vec<&[Point]> = vec![pts.as_slice()];
        let backend = BackendKind::Pram
            .build(&PathBuf::new(), false, ExecMode::Fast, false)
            .unwrap();
        let serial = backend.compute(&views, 1).unwrap();
        let parallel = backend.compute(&views, 4).unwrap();
        assert_eq!(serial, parallel, "parallel PE dispatch diverged from serial");
        // and both must agree with the reference backend
        let reference = BackendKind::Serial
            .build(&PathBuf::new(), false, ExecMode::Fast, false)
            .unwrap()
            .compute(&views, 1)
            .unwrap();
        assert_eq!(serial, reference);
    }

    #[test]
    fn pram_tiers_report_distinct_names_and_limits() {
        let audited = BackendKind::Pram
            .build(&PathBuf::new(), false, ExecMode::Audited, false)
            .unwrap();
        let fast = BackendKind::Pram
            .build(&PathBuf::new(), false, ExecMode::Fast, false)
            .unwrap();
        assert_eq!(audited.name(), "pram");
        assert_eq!(fast.name(), "pram-fast");
        assert!(fast.max_points() > audited.max_points());
    }

    #[test]
    fn exact_full_hull_handles_duplicate_x() {
        // a vertical segment of three points plus flanks
        let pts = vec![
            Point::new(0.1, 0.5),
            Point::new(0.5, 0.1),
            Point::new(0.5, 0.5),
            Point::new(0.5, 0.9),
            Point::new(0.9, 0.5),
        ];
        let (up, lo) = exact_full_hull(&pts);
        assert_eq!(up, vec![pts[0], pts[3], pts[4]]);
        assert_eq!(lo, vec![pts[0], pts[1], pts[4]]);
    }

    #[test]
    fn exact_matches_serial_on_general_position() {
        let pts = generate(Distribution::Disk, 128, 3);
        let (u, l) = exact_full_hull(&pts);
        let (su, sl) = monotone_chain::full_hull(&pts);
        assert_eq!(u, su);
        assert_eq!(l, sl);
    }
}
