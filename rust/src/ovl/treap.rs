//! Rank-indexed treap over hull corners — the "balanced trees of size
//! <= log n" in the paper's optimal-speedup sketch.
//!
//! Supports O(log n) rank access, split-at-rank and join, which is exactly
//! what an Overmars–van Leeuwen hull merge needs: after the tangent
//! (pi, qi) is found, the merged chain is
//! `left.split(pi+1).0  ++  right.split(qi).1` — two splits and a join,
//! no element copying (the paper's CUDA version pays O(d) moves instead;
//! E5 reports that difference as `data_moves`).

use crate::geometry::point::Point;
use crate::util::rng::Rng;

struct Node {
    pt: Point,
    pri: u64,
    size: usize,
    left: Option<Box<Node>>,
    right: Option<Box<Node>>,
}

fn size(n: &Option<Box<Node>>) -> usize {
    n.as_ref().map_or(0, |b| b.size)
}

fn update(n: &mut Box<Node>) {
    n.size = 1 + size(&n.left) + size(&n.right);
}

fn split(node: Option<Box<Node>>, k: usize) -> (Option<Box<Node>>, Option<Box<Node>>) {
    // left gets the first k elements
    match node {
        None => (None, None),
        Some(mut n) => {
            let ls = size(&n.left);
            if k <= ls {
                let (a, b) = split(n.left.take(), k);
                n.left = b;
                update(&mut n);
                (a, Some(n))
            } else {
                let (a, b) = split(n.right.take(), k - ls - 1);
                n.right = a;
                update(&mut n);
                (Some(n), b)
            }
        }
    }
}

fn join(a: Option<Box<Node>>, b: Option<Box<Node>>) -> Option<Box<Node>> {
    match (a, b) {
        (None, b) => b,
        (a, None) => a,
        (Some(mut x), Some(mut y)) => {
            if x.pri >= y.pri {
                x.right = join(x.right.take(), Some(y));
                update(&mut x);
                Some(x)
            } else {
                y.left = join(Some(x), y.left.take());
                update(&mut y);
                Some(y)
            }
        }
    }
}

/// Balanced (expected) search tree over an x-ordered point sequence.
pub struct Treap {
    root: Option<Box<Node>>,
    rng: Rng,
}

impl Treap {
    pub fn new(seed: u64) -> Treap {
        Treap { root: None, rng: Rng::new(seed) }
    }

    /// Build from an x-ordered slice (O(n log n) expected; strips are tiny).
    pub fn from_slice(pts: &[Point], seed: u64) -> Treap {
        let mut t = Treap::new(seed);
        for &p in pts {
            t.push_back(p);
        }
        t
    }

    pub fn len(&self) -> usize {
        size(&self.root)
    }

    pub fn is_empty(&self) -> bool {
        self.root.is_none()
    }

    /// Append a point (must be x-after the current last).
    pub fn push_back(&mut self, p: Point) {
        let pri = self.rng.next_u64();
        let node = Some(Box::new(Node { pt: p, pri, size: 1, left: None, right: None }));
        self.root = join(self.root.take(), node);
    }

    /// O(log n) access by rank.
    pub fn get(&self, mut rank: usize) -> Point {
        assert!(rank < self.len(), "rank {rank} >= len {}", self.len());
        let mut cur = self.root.as_ref().unwrap();
        loop {
            let ls = size(&cur.left);
            if rank < ls {
                cur = cur.left.as_ref().unwrap();
            } else if rank == ls {
                return cur.pt;
            } else {
                rank -= ls + 1;
                cur = cur.right.as_ref().unwrap();
            }
        }
    }

    /// Split into (first k, rest); self is consumed.
    pub fn split_at(mut self, k: usize) -> (Treap, Treap) {
        let (a, b) = split(self.root.take(), k);
        let seed_a = self.rng.next_u64();
        let seed_b = self.rng.next_u64();
        (
            Treap { root: a, rng: Rng::new(seed_a) },
            Treap { root: b, rng: Rng::new(seed_b) },
        )
    }

    /// Concatenate (all of self x-before all of other).
    pub fn concat(mut self, mut other: Treap) -> Treap {
        let root = join(self.root.take(), other.root.take());
        Treap { root, rng: self.rng }
    }

    /// In-order traversal to a Vec (O(n); used only at pipeline exit).
    pub fn to_vec(&self) -> Vec<Point> {
        fn walk(n: &Option<Box<Node>>, out: &mut Vec<Point>) {
            if let Some(b) = n {
                walk(&b.left, out);
                out.push(b.pt);
                walk(&b.right, out);
            }
        }
        let mut out = Vec::with_capacity(self.len());
        walk(&self.root, &mut out);
        out
    }

    /// Expected-balance sanity: tree height (test helper).
    pub fn height(&self) -> usize {
        fn h(n: &Option<Box<Node>>) -> usize {
            n.as_ref().map_or(0, |b| 1 + h(&b.left).max(h(&b.right)))
        }
        h(&self.root)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pts(k: usize) -> Vec<Point> {
        (0..k).map(|i| Point::new(i as f64 / k as f64, (i * i % 17) as f64)).collect()
    }

    #[test]
    fn build_get_roundtrip() {
        let v = pts(100);
        let t = Treap::from_slice(&v, 1);
        assert_eq!(t.len(), 100);
        for (i, &p) in v.iter().enumerate() {
            assert_eq!(t.get(i), p);
        }
        assert_eq!(t.to_vec(), v);
    }

    #[test]
    fn split_and_concat() {
        let v = pts(37);
        for k in [0usize, 1, 17, 36, 37] {
            let t = Treap::from_slice(&v, 2);
            let (a, b) = t.split_at(k);
            assert_eq!(a.to_vec(), &v[..k]);
            assert_eq!(b.to_vec(), &v[k..]);
            let joined = a.concat(b);
            assert_eq!(joined.to_vec(), v);
        }
    }

    #[test]
    fn ovl_merge_shape() {
        // merged = left[..=pi] ++ right[qi..] with two splits and a join
        let left = pts(20);
        let right: Vec<Point> =
            pts(20).iter().map(|p| Point::new(p.x + 1.5, p.y)).collect();
        let (pi, qi) = (7usize, 13usize);
        let (keep_l, _) = Treap::from_slice(&left, 3).split_at(pi + 1);
        let (_, keep_r) = Treap::from_slice(&right, 4).split_at(qi);
        let merged = keep_l.concat(keep_r);
        let mut want = left[..=pi].to_vec();
        want.extend_from_slice(&right[qi..]);
        assert_eq!(merged.to_vec(), want);
    }

    #[test]
    fn expected_logarithmic_height() {
        let t = Treap::from_slice(&pts(4096), 5);
        // expected height ~ 3 log2 n ≈ 36; allow slack
        assert!(t.height() < 60, "height {}", t.height());
    }

    #[test]
    fn empty_and_singleton() {
        let t = Treap::new(1);
        assert!(t.is_empty());
        let mut t = Treap::new(1);
        t.push_back(Point::new(0.5, 0.5));
        assert_eq!(t.len(), 1);
        assert_eq!(t.get(0), Point::new(0.5, 0.5));
    }
}
