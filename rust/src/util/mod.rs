//! In-tree substrates that a framework would normally pull from crates.io.
//!
//! This build environment vendors only the `xla` PJRT bindings and `anyhow`,
//! so the usual ecosystem crates (rand, serde_json, toml, proptest, tracing)
//! are re-implemented here as small, tested modules (DESIGN.md
//! §Substitutions).  Each is scoped to exactly what this project needs.

pub mod json;
pub mod logging;
pub mod property;
pub mod rng;
pub mod tomlmini;
