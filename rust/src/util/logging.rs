//! Leveled stderr logger (substitute for `tracing`).
//!
//! Level comes from `WAGENER_LOG` (error|warn|info|debug|trace), default
//! `info`.  Macros live at crate root: `log_info!`, `log_warn!`, ...

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
    fn from_str(s: &str) -> Option<Level> {
        Some(match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "info" => Level::Info,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => return None,
        })
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialized

fn init_from_env() -> u8 {
    let lvl = std::env::var("WAGENER_LOG")
        .ok()
        .and_then(|s| Level::from_str(&s))
        .unwrap_or(Level::Info) as u8;
    MAX_LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// Current maximum enabled level.
pub fn max_level() -> Level {
    let raw = MAX_LEVEL.load(Ordering::Relaxed);
    let raw = if raw == 255 { init_from_env() } else { raw };
    match raw {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Override the level programmatically (tests, CLI flags).
pub fn set_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    level <= max_level()
}

/// Monotonic start-of-process instant for relative timestamps.
pub fn start_instant() -> Instant {
    use std::sync::OnceLock;
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

/// Log a preformatted record (used by the macros).
pub fn log_record(level: Level, target: &str, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t = start_instant().elapsed();
    eprintln!(
        "[{:>9.4}s {:5} {}] {}",
        t.as_secs_f64(),
        level.name(),
        target,
        args
    );
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)+) => { $crate::util::logging::log_record(
        $crate::util::logging::Level::Error, module_path!(), format_args!($($arg)+)) };
}
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)+) => { $crate::util::logging::log_record(
        $crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)+)) };
}
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)+) => { $crate::util::logging::log_record(
        $crate::util::logging::Level::Info, module_path!(), format_args!($($arg)+)) };
}
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)+) => { $crate::util::logging::log_record(
        $crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)+)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Trace);
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }

    #[test]
    fn names() {
        assert_eq!(Level::Debug.name(), "DEBUG");
        assert_eq!(Level::from_str("TRACE"), Some(Level::Trace));
        assert_eq!(Level::from_str("bogus"), None);
    }
}
