//! E7 — runtime execution cost, two halves:
//!
//! 1. **PRAM engine tiers** (always runs): audited instrument vs fast
//!    serving tier at n = 4096 and n = 2^16 (uniform disc).  The fast/
//!    audited speedup is the PR-over-PR perf trajectory recorded in
//!    BENCH_pram.json (`scripts/tier1.sh` sets WAGENER_BENCH_JSON).
//! 2. **PJRT artifact execution** (compile excluded; compile times
//!    reported as notes) and the pallas-vs-plain-jnp ablation twin.
//!    Requires `make artifacts`; prints a skip note otherwise.
//!
//! Run: `cargo bench --bench bench_runtime`

use std::time::Duration;

use wagener_hull::benchkit::{Bencher, Report};
use wagener_hull::geometry::generators::{generate, Distribution};
use wagener_hull::pram::ExecMode;
use wagener_hull::runtime::{ArtifactRegistry, HullExecutor};
use wagener_hull::wagener::pram_exec::run_pipeline_mode;

fn main() {
    pram_tiers();
    pjrt_artifacts();
}

/// Audited vs fast tier on the full Wagener pipeline.
fn pram_tiers() {
    let mut report = Report::new("E7a: PRAM engine tiers (audited vs fast)");
    // the audited tier at n=2^16 takes whole seconds per run; cap the
    // sample budget instead of inheriting the default 1 s target
    let b = Bencher {
        warmup: Duration::from_millis(10),
        target: Duration::from_millis(
            if std::env::var("WAGENER_BENCH_FAST").is_ok() { 50 } else { 400 },
        ),
        min_iters: 2,
        max_iters: 10_000,
    };
    for &n in &[4096usize, 1 << 16] {
        let pts = generate(Distribution::Disk, n, 99);
        let audited = b.run(&format!("pram/audited/disk_n{n}"), || {
            run_pipeline_mode(&pts, n, ExecMode::Audited, true).unwrap()
        });
        let fast = b.run(&format!("pram/fast/disk_n{n}"), || {
            run_pipeline_mode(&pts, n, ExecMode::Fast, true).unwrap()
        });
        report.note(format!(
            "n={n}: fast tier speedup {:.1}x over the audited instrument",
            audited.median_ns / fast.median_ns
        ));
        report.add(audited);
        report.add(fast);
    }
    report.finish();
}

/// PJRT execute cost per artifact + the native comparison.
fn pjrt_artifacts() {
    let b = Bencher::default();
    let mut report = Report::new("E7b: PJRT artifact execution");
    let reg = match ArtifactRegistry::load("artifacts") {
        Ok(r) => r,
        Err(e) => {
            report.note(format!("SKIPPED: {e:#} (run `make artifacts`)"));
            report.finish();
            return;
        }
    };
    let exe = match HullExecutor::new(reg) {
        Ok(e) => e,
        Err(e) => {
            report.note(format!("SKIPPED: {e:#}"));
            report.finish();
            return;
        }
    };

    // hood artifacts (single request, upper hull only)
    for name in ["hood_n64", "hood_n256", "hood_jnp_n256"] {
        let meta = exe.registry().get(name).unwrap().clone();
        let pts = generate(Distribution::Disk, meta.n, 5);
        exe.run_hood(&meta, &pts).unwrap(); // compile once
        report.add(b.run(&format!("pjrt/{name}"), || {
            exe.run_hood(&meta, &pts).unwrap()
        }));
    }
    report.note("hood_n256 vs hood_jnp_n256 = pallas kernel vs plain-jnp ablation (E7)");

    // batched hull artifacts: per-request cost vs batch size
    for (name, b_reqs) in [("hull_n64_b1", 1usize), ("hull_n64_b8", 8)] {
        let meta = exe.registry().get(name).unwrap().clone();
        let reqs: Vec<Vec<_>> = (0..b_reqs)
            .map(|k| generate(Distribution::Disk, 60, k as u64))
            .collect();
        exe.run_hull(&meta, &reqs).unwrap();
        report.add(b.run_batched(&format!("pjrt/{name}/per_request"), b_reqs, || {
            exe.run_hull(&meta, &reqs).unwrap()
        }));
    }

    // native comparison at the same sizes
    for n in [64usize, 256] {
        let pts = generate(Distribution::Disk, n, 5);
        report.add(b.run(&format!("native/wagener_n{n}"), || {
            wagener_hull::wagener::full_hull(std::hint::black_box(&pts))
        }));
    }

    let stats = exe.stats();
    report.note(format!(
        "compiles={} total_compile_ms={:.0} executions={} ref_checks={} ref_mismatches={}",
        stats.compiles,
        stats.compile_ns as f64 / 1e6,
        stats.executions,
        stats.ref_checks,
        stats.ref_mismatches,
    ));
    report.finish();
}
