//! Logarithmic common-tangent search between two x-separated upper-hull
//! chains — the paper's "balanced search" of Overmars & van Leeuwen,
//! expressed with the same LOW/EQUAL/HIGH codes as the CUDA kernel.
//!
//! Inner search: for a fixed left-chain corner p, the g-codes along the
//! right chain read LOW* EQUAL HIGH*, so the touch corner is the largest
//! rank with code <= EQUAL — one binary search, O(log q) probes.
//! Outer search: by the paper's Theorem 2.1 the f-codes of (p_i, touch(p_i))
//! along the left chain are again LOW* EQUAL HIGH*, so p* is the largest
//! rank with code <= EQUAL — a second binary search whose probes each run
//! an inner search: O(log p · log q) predicate evaluations total.

use crate::geometry::point::Point;
use crate::geometry::predicates::left_of;
use crate::wagener::tangent::Code;

use super::treap::Treap;

/// Rank-indexed read access to a hull chain (array or balanced tree).
pub trait HullChain {
    fn len(&self) -> usize;
    fn get(&self, rank: usize) -> Point;
}

impl HullChain for &[Point] {
    fn len(&self) -> usize {
        (**self).len()
    }
    fn get(&self, rank: usize) -> Point {
        self[rank]
    }
}

impl HullChain for Treap {
    fn len(&self) -> usize {
        Treap::len(self)
    }
    fn get(&self, rank: usize) -> Point {
        Treap::get(self, rank)
    }
}

/// Probe counter: predicate (left_of) evaluations, chain accesses.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SearchCost {
    pub predicate_evals: u64,
    pub chain_accesses: u64,
}

impl std::ops::AddAssign for SearchCost {
    fn add_assign(&mut self, o: SearchCost) {
        self.predicate_evals += o.predicate_evals;
        self.chain_accesses += o.chain_accesses;
    }
}

fn neighbor<C: HullChain>(c: &C, rank: usize, next: bool, cost: &mut SearchCost) -> Point {
    let pt = c.get(rank);
    if next {
        if rank + 1 < c.len() {
            cost.chain_accesses += 1;
            c.get(rank + 1)
        } else {
            pt.below()
        }
    } else if rank > 0 {
        cost.chain_accesses += 1;
        c.get(rank - 1)
    } else {
        pt.below()
    }
}

/// g-code of right-chain corner `j` w.r.t. the tangent from point `p`.
fn g_code<C: HullChain>(p: Point, q_chain: &C, j: usize, cost: &mut SearchCost) -> Code {
    cost.chain_accesses += 1;
    let q = q_chain.get(j);
    let q_next = neighbor(q_chain, j, true, cost);
    cost.predicate_evals += 1;
    if left_of(p, q, q_next) {
        return Code::Low;
    }
    let q_prev = neighbor(q_chain, j, false, cost);
    cost.predicate_evals += 1;
    if left_of(p, q, q_prev) {
        Code::High
    } else {
        Code::Equal
    }
}

/// f-code of left-chain corner `i` w.r.t. the tangent from point `q`.
fn f_code<C: HullChain>(p_chain: &C, i: usize, q: Point, cost: &mut SearchCost) -> Code {
    cost.chain_accesses += 1;
    let p = p_chain.get(i);
    let p_next = neighbor(p_chain, i, true, cost);
    cost.predicate_evals += 1;
    if left_of(p, q, p_next) {
        return Code::Low;
    }
    let p_prev = neighbor(p_chain, i, false, cost);
    cost.predicate_evals += 1;
    if left_of(p, q, p_prev) {
        Code::High
    } else {
        Code::Equal
    }
}

/// Largest rank in [0, len) with code <= EQUAL (codes are LOW* EQ HIGH*).
/// Rank 0 is never HIGH (its prev is the synthetic below-point).
fn last_not_high<F: FnMut(usize) -> Code>(len: usize, mut code: F) -> usize {
    let (mut lo, mut hi) = (0usize, len - 1);
    while lo < hi {
        let mid = (lo + hi + 1) / 2;
        if code(mid) <= Code::Equal {
            lo = mid;
        } else {
            hi = mid - 1;
        }
    }
    lo
}

/// Touch corner on `q_chain` of the tangent from external left point `p`.
pub fn tangent_from_point<C: HullChain>(p: Point, q_chain: &C, cost: &mut SearchCost) -> usize {
    debug_assert!(q_chain.len() > 0);
    last_not_high(q_chain.len(), |j| g_code(p, q_chain, j, cost))
}

/// Common upper tangent (pi, qi) between an x-separated chain pair.
/// O(log p · log q) predicate evaluations.
pub fn common_tangent<A: HullChain, B: HullChain>(
    p_chain: &A,
    q_chain: &B,
    cost: &mut SearchCost,
) -> (usize, usize) {
    debug_assert!(p_chain.len() > 0 && q_chain.len() > 0);
    let pi = last_not_high(p_chain.len(), |i| {
        let p = {
            let mut c = SearchCost::default();
            let p = p_chain.get(i);
            c.chain_accesses += 1;
            *cost += c;
            p
        };
        let qi = tangent_from_point(p, q_chain, cost);
        f_code(p_chain, i, q_chain.get(qi), cost)
    });
    let qi = tangent_from_point(p_chain.get(pi), q_chain, cost);
    (pi, qi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::point::sort_by_x;
    use crate::serial::monotone_chain;
    use crate::util::rng::Rng;

    fn random_chains(rng: &mut Rng, np: usize, nq: usize) -> (Vec<Point>, Vec<Point>) {
        let mut p: Vec<Point> = (0..np)
            .map(|_| Point::new(rng.f64() * 0.45, rng.f64()).quantize_f32())
            .collect();
        let mut q: Vec<Point> = (0..nq)
            .map(|_| Point::new(0.55 + rng.f64() * 0.45, rng.f64()).quantize_f32())
            .collect();
        sort_by_x(&mut p);
        sort_by_x(&mut q);
        p.dedup_by(|a, b| a.x == b.x);
        q.dedup_by(|a, b| a.x == b.x);
        (monotone_chain::upper_hull(&p), monotone_chain::upper_hull(&q))
    }

    fn brute(p: &[Point], q: &[Point]) -> (usize, usize) {
        for i in 0..p.len() {
            for j in 0..q.len() {
                let all_below = p
                    .iter()
                    .chain(q.iter())
                    .all(|&o| o == p[i] || o == q[j] || !left_of(p[i], q[j], o));
                if all_below {
                    return (i, j);
                }
            }
        }
        panic!("no tangent")
    }

    #[test]
    fn matches_brute_force() {
        let mut rng = Rng::new(71);
        for _ in 0..300 {
            let np = rng.range_usize(1, 40);
            let nq = rng.range_usize(1, 40);
            let (p, q) = random_chains(&mut rng, np, nq);
            let mut cost = SearchCost::default();
            let got = common_tangent(&p.as_slice(), &q.as_slice(), &mut cost);
            assert_eq!(got, brute(&p, &q), "p={p:?} q={q:?}");
        }
    }

    #[test]
    fn works_on_treaps() {
        let mut rng = Rng::new(73);
        for _ in 0..50 {
            let (p, q) = random_chains(&mut rng, 30, 30);
            let tp = Treap::from_slice(&p, 1);
            let tq = Treap::from_slice(&q, 2);
            let mut cost = SearchCost::default();
            let got = common_tangent(&tp, &tq, &mut cost);
            assert_eq!(got, brute(&p, &q));
        }
    }

    #[test]
    fn cost_is_polylogarithmic() {
        // chains of 2^k parabola corners: evals must grow ~ log^2, far
        // below linear
        let mut rng = Rng::new(79);
        let mut prev = 0u64;
        for k in [6usize, 8, 10, 12] {
            let n = 1 << k;
            let mk = |off: f64, rng: &mut Rng| -> Vec<Point> {
                let mut v: Vec<Point> = (0..n)
                    .map(|_| {
                        let x = rng.f64() * 0.45;
                        Point::new(off + x, 0.8 - (x - 0.22) * (x - 0.22)).quantize_f32()
                    })
                    .collect();
                sort_by_x(&mut v);
                v.dedup_by(|a, b| a.x == b.x);
                monotone_chain::upper_hull(&v)
            };
            let p = mk(0.0, &mut rng);
            let q = mk(0.55, &mut rng);
            assert!(p.len() > n / 2 && q.len() > n / 2, "need big hulls");
            let mut cost = SearchCost::default();
            common_tangent(&p.as_slice(), &q.as_slice(), &mut cost);
            assert!(
                cost.predicate_evals <= 4 * ((k + 1) * (k + 1)) as u64,
                "k={k}: {} evals",
                cost.predicate_evals
            );
            assert!(cost.predicate_evals >= prev / 4, "not degenerate");
            prev = cost.predicate_evals;
        }
    }

    #[test]
    fn singleton_chains() {
        let p = vec![Point::new(0.2, 0.5)];
        let q = vec![Point::new(0.8, 0.3)];
        let mut cost = SearchCost::default();
        assert_eq!(common_tangent(&p.as_slice(), &q.as_slice(), &mut cost), (0, 0));
    }
}
