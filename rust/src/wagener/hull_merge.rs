//! `merge_hulls` — the paper's common-tangent machinery applied to two
//! *precomputed* convex hulls (hull ⊕ hull inputs, not leaf merges).
//!
//! The streaming-session subsystem re-hulls `current hull ∪ pending` on
//! every merge; re-sorting the union and running a full pipeline would
//! throw away the structure both sides already have.  Instead:
//!
//! * **x-disjoint chains** (one hull entirely left of the other): the
//!   block-pair tangent search from `merge.rs` (`find_tangent`, the
//!   paper's mam1..mam5 sampled phases) locates the common tangent in
//!   O(√h · …) predicate evaluations, and the merged chain is a pair of
//!   slice copies.  This is exactly the [H(P) | H(Q)] merge the paper
//!   runs at every pipeline stage, now exposed as a standalone entry
//!   point.
//! * **x-overlapping chains** (the common streaming case): the two
//!   vertex sequences are interleaved by a linear two-pointer merge
//!   (both are already x-sorted — nothing is re-sorted), x-classes are
//!   collapsed to their extreme-y representative, and one strict-turn
//!   scan over the ≤ h₁+h₂ vertices rebuilds the chain.
//!
//! Both paths finish with (or consist of) a strict-turn monotone scan,
//! so the output is *canonical*: bit-identical to the chain a one-shot
//! hull of the union of the two vertex sets would produce, including
//! under cross-hull collinearity and duplicate x (exact predicates
//! throughout).  Correctness does not depend on which touch corner the
//! sampled phases return when the tangent passes through a collinear
//! run: every mutually-supporting pair lies on the same support line
//! (convexity makes local support global), and the trailing scan drops
//! the collinear middles.

use super::merge::find_tangent;
use super::stage::stage_dims;
use crate::geometry::point::{dedup_x, pad_to_hood, Point};
use crate::serial::monotone_chain;

/// Which strategy merged a chain pair (exposed for tests, the CLI, and
/// benches — the tangent path is the one the paper's machinery serves).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MergePath {
    /// One side empty: the other chain verbatim.
    Trivial,
    /// x-disjoint chains: sampled common-tangent search (mam1..mam5).
    Tangent,
    /// x-disjoint chains merged by an accelerator tangent kernel (one
    /// upload for the whole hull ⊕ hull merge) + host strict-turn rescan.
    DeviceTangent,
    /// x-overlapping chains: linear interleave + strict-turn rescan.
    Interleave,
}

impl MergePath {
    pub fn name(&self) -> &'static str {
        match self {
            MergePath::Trivial => "trivial",
            MergePath::Tangent => "tangent",
            MergePath::DeviceTangent => "device-tangent",
            MergePath::Interleave => "interleave",
        }
    }
}

/// An accelerator-resident common-tangent merge (the PJRT tangent
/// artifacts, reached through the coordinator's device-merge worker).
/// `upper` carries the upper chains `[left, right]` of two x-disjoint
/// hulls, `lower` their y-MIRRORED lower chains — one batched upload
/// merges the full hull pair.  Implementations return the merged upper
/// chain and the merged still-mirrored lower chain, or `None` to fall
/// back to the host tangent path (no artifact, size-class miss, device
/// failure).  Outputs need not be canonical: [`merge_hulls_with`]
/// finishes with a strict-turn rescan, which also erases any divergence
/// in *which* valid tangent the device picked under collinearity (every
/// choice keeps a chain whose strict hull equals the union's).
pub trait TangentKernel {
    fn tangent_merge(
        &self,
        upper: [&[Point]; 2],
        lower: [&[Point]; 2],
    ) -> Option<(Vec<Point>, Vec<Point>)>;
}

/// Merge two *upper-hull* chains (each canonical: x-strictly-increasing,
/// strict turns only, as every backend produces).  Returns the canonical
/// upper chain of the union of the two vertex sets and the path taken.
pub fn merge_upper_hulls(a: &[Point], b: &[Point]) -> (Vec<Point>, MergePath) {
    if a.is_empty() {
        return (b.to_vec(), MergePath::Trivial);
    }
    if b.is_empty() {
        return (a.to_vec(), MergePath::Trivial);
    }
    // strict inequality: a shared boundary x needs the dedup of the
    // interleave path, not the tangent's general-position block
    let (l, r) = if a[a.len() - 1].x < b[0].x {
        (a, b)
    } else if b[b.len() - 1].x < a[0].x {
        (b, a)
    } else {
        return (interleave_upper(a, b), MergePath::Interleave);
    };
    (tangent_merge_upper(l, r), MergePath::Tangent)
}

/// Merge two *lower-hull* chains.  Mirrors y and reuses the upper
/// machinery: negation is exact in f64, so the result stays canonical.
pub fn merge_lower_hulls(a: &[Point], b: &[Point]) -> (Vec<Point>, MergePath) {
    fn mirror(chain: &[Point]) -> Vec<Point> {
        chain.iter().map(|p| Point::new(p.x, -p.y)).collect()
    }
    let (merged, path) = merge_upper_hulls(&mirror(a), &mirror(b));
    (mirror(&merged), path)
}

/// Merge two full hulls, each given as `(upper, lower)` chains.  The two
/// chains of one hull share their x-range, so upper and lower always take
/// the same path; it is returned once.
pub fn merge_hulls(
    a: (&[Point], &[Point]),
    b: (&[Point], &[Point]),
) -> ((Vec<Point>, Vec<Point>), MergePath) {
    let (upper, path) = merge_upper_hulls(a.0, b.0);
    let (lower, _) = merge_lower_hulls(a.1, b.1);
    ((upper, lower), path)
}

/// [`merge_hulls`] with an optional accelerator tangent kernel.  The
/// device path serves exactly the case the host tangent path serves —
/// strictly x-disjoint hull pairs — and canonicalizes the kernel's output
/// with the same strict-turn rescan, so the result is bit-identical to
/// the host merge whichever path runs.  Everything else (empty sides,
/// x-overlap, kernel refusal) falls through to [`merge_hulls`].
pub fn merge_hulls_with(
    kernel: Option<&dyn TangentKernel>,
    a: (&[Point], &[Point]),
    b: (&[Point], &[Point]),
) -> ((Vec<Point>, Vec<Point>), MergePath) {
    if let Some(k) = kernel {
        if let Some(out) = device_merge(k, a, b) {
            return (out, MergePath::DeviceTangent);
        }
    }
    merge_hulls(a, b)
}

fn mirror(chain: &[Point]) -> Vec<Point> {
    chain.iter().map(|p| Point::new(p.x, -p.y)).collect()
}

/// Try the device tangent on a hull pair: orient into (left, right) by
/// strict x-disjointness (the chains of one hull share their extreme xs,
/// so checking the uppers covers the lowers), mirror the lower chains,
/// run the kernel, rescan both rows.
fn device_merge(
    kernel: &dyn TangentKernel,
    a: (&[Point], &[Point]),
    b: (&[Point], &[Point]),
) -> Option<(Vec<Point>, Vec<Point>)> {
    if a.0.is_empty() || b.0.is_empty() {
        return None; // trivial path is cheaper than any upload
    }
    let (l, r) = if a.0[a.0.len() - 1].x < b.0[0].x {
        (a, b)
    } else if b.0[b.0.len() - 1].x < a.0[0].x {
        (b, a)
    } else {
        return None; // x-overlap: the interleave path owns this case
    };
    let (llo, rlo) = (mirror(l.1), mirror(r.1));
    let (up, lo_m) = kernel.tangent_merge([l.0, r.0], [&llo, &rlo])?;
    Some((
        monotone_chain::upper_hull(&up),
        mirror(&monotone_chain::upper_hull(&lo_m)),
    ))
}

/// x-disjoint case: the paper's sampled tangent phases over a block pair
/// [H(L) | H(R)], then two slice copies and a canonicalizing scan.
fn tangent_merge_upper(l: &[Point], r: &[Point]) -> Vec<Point> {
    let d = l.len().max(r.len()).next_power_of_two().max(2);
    let (d1, d2) = stage_dims(d);
    let mut blk = pad_to_hood(l, d);
    blk.extend(pad_to_hood(r, d));
    let t = find_tangent(&blk, d1, d2);
    // mam6 without the REMOTE fill: the chain is materialized compactly
    let mut chain = Vec::with_capacity(t.pidx + 1 + (2 * d - t.qidx));
    chain.extend_from_slice(&l[..=t.pidx]);
    chain.extend_from_slice(&r[t.qidx - d..]);
    // the tangent can pass through corners of BOTH chains (cross-hull
    // collinearity); the strict-turn rescan of the ≤ h₁+h₂ survivors
    // drops the middles, making the output canonical
    monotone_chain::upper_hull(&chain)
}

/// x-overlapping case: linear interleave of two x-sorted chains (no
/// re-sort), extreme-y per x-class, strict-turn scan.
fn interleave_upper(a: &[Point], b: &[Point]) -> Vec<Point> {
    let mut merged = Vec::with_capacity(a.len() + b.len());
    let (mut i, mut j) = (0usize, 0usize);
    while i < a.len() && j < b.len() {
        let take_a =
            a[i].x < b[j].x || (a[i].x == b[j].x && a[i].y <= b[j].y);
        if take_a {
            merged.push(a[i]);
            i += 1;
        } else {
            merged.push(b[j]);
            j += 1;
        }
    }
    merged.extend_from_slice(&a[i..]);
    merged.extend_from_slice(&b[j..]);
    // duplicate x across the chains: only the max-y representative can
    // sit on the upper chain (same rule as the exact degenerate path)
    let merged = dedup_x(&merged, true);
    monotone_chain::upper_hull(&merged)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::canonical_full_hull as oracle;
    use crate::geometry::generators::{generate, squeeze_x, Distribution};
    use crate::util::rng::Rng;

    #[test]
    fn empty_sides_are_trivial() {
        let pts = generate(Distribution::Disk, 40, 3);
        let (u, l) = oracle(&pts);
        let ((mu, ml), path) = merge_hulls((&u, &l), (&[], &[]));
        assert_eq!(path, MergePath::Trivial);
        assert_eq!((mu, ml), (u.clone(), l.clone()));
        let ((mu, ml), path) = merge_hulls((&[], &[]), (&u, &l));
        assert_eq!(path, MergePath::Trivial);
        assert_eq!((mu, ml), (u, l));
    }

    #[test]
    fn disjoint_pairs_take_the_tangent_path_and_match_oracle() {
        let mut rng = Rng::new(71);
        for case in 0..200 {
            let da = Distribution::ALL[case % 7];
            let db = Distribution::ALL[(case + 3) % 7];
            let a = squeeze_x(&generate(da, rng.range_usize(1, 200), rng.next_u64()), 0.0, 0.47);
            let b = squeeze_x(&generate(db, rng.range_usize(1, 200), rng.next_u64()), 0.53, 1.0);
            let (au, al) = oracle(&a);
            let (bu, bl) = oracle(&b);
            let ((mu, ml), path) = merge_hulls((&au, &al), (&bu, &bl));
            assert_eq!(path, MergePath::Tangent, "case {case}");
            let union: Vec<Point> = a.iter().chain(b.iter()).copied().collect();
            let (wu, wl) = oracle(&union);
            assert_eq!(mu, wu, "case {case} upper ({} ∪ {})", da.name(), db.name());
            assert_eq!(ml, wl, "case {case} lower ({} ∪ {})", da.name(), db.name());
        }
    }

    #[test]
    fn overlapping_pairs_interleave_and_match_oracle() {
        let mut rng = Rng::new(73);
        for case in 0..200 {
            let da = Distribution::ALL[case % 7];
            let db = Distribution::ALL[(case + 5) % 7];
            let a = generate(da, rng.range_usize(1, 300), rng.next_u64());
            let b = generate(db, rng.range_usize(1, 300), rng.next_u64());
            let (au, al) = oracle(&a);
            let (bu, bl) = oracle(&b);
            let ((mu, ml), _path) = merge_hulls((&au, &al), (&bu, &bl));
            let union: Vec<Point> = a.iter().chain(b.iter()).copied().collect();
            let (wu, wl) = oracle(&union);
            assert_eq!(mu, wu, "case {case} upper ({} ∪ {})", da.name(), db.name());
            assert_eq!(ml, wl, "case {case} lower ({} ∪ {})", da.name(), db.name());
        }
    }

    #[test]
    fn duplicate_x_across_hulls_is_exact() {
        // both hulls own vertices at x = 0.5 with different y: the merged
        // chain must keep only the extreme-y representative, exactly like
        // the one-shot degenerate path
        let a = vec![
            Point::new(0.1, 0.4),
            Point::new(0.5, 0.9),
            Point::new(0.5, 0.1),
            Point::new(0.8, 0.4),
        ];
        let b = vec![
            Point::new(0.3, 0.3),
            Point::new(0.5, 0.95),
            Point::new(0.5, 0.05),
            Point::new(0.9, 0.5),
        ];
        let (au, al) = oracle(&a);
        let (bu, bl) = oracle(&b);
        let ((mu, ml), path) = merge_hulls((&au, &al), (&bu, &bl));
        assert_eq!(path, MergePath::Interleave);
        let union: Vec<Point> = a.iter().chain(b.iter()).copied().collect();
        let (wu, wl) = oracle(&union);
        assert_eq!(mu, wu);
        assert_eq!(ml, wl);
    }

    #[test]
    fn cross_hull_collinearity_is_canonicalized() {
        // the common tangent passes through two corners of EACH chain:
        // only the outermost pair survives (collinear middles dropped),
        // matching the strict-turn oracle bit-for-bit
        // exact collinearity on dyadic coordinates:
        let a = vec![
            Point::new(0.0, 0.25),
            Point::new(0.125, 0.375),
            Point::new(0.25, 0.5),
            Point::new(0.3125, 0.0625),
        ];
        let b = vec![
            Point::new(0.5, 0.75),
            Point::new(0.625, 0.875),
            Point::new(0.75, 0.5),
        ];
        // (0.125,0.375),(0.25,0.5),(0.5,0.75),(0.625,0.875) all on y = x + 0.25
        let (au, al) = oracle(&a);
        let (bu, bl) = oracle(&b);
        let ((mu, ml), path) = merge_hulls((&au, &al), (&bu, &bl));
        assert_eq!(path, MergePath::Tangent);
        let union: Vec<Point> = a.iter().chain(b.iter()).copied().collect();
        let (wu, wl) = oracle(&union);
        assert_eq!(mu, wu, "collinear tangent upper");
        assert_eq!(ml, wl, "collinear tangent lower");
    }

    // ---------------------------------------------- device tangent path

    use crate::geometry::point::live_prefix;

    /// Host stand-in for the PJRT tangent artifacts, honoring the exact
    /// device contract: pad each chain pair into a `[H(L) | H(R)]` block
    /// of 2d slots, merge with the rust-native twin of the pallas kernel
    /// body, hand back the live prefixes (possibly non-canonical — the
    /// caller's rescan must cope).  `max_d` mimics a registry's largest
    /// size class so refusal/fallback is exercised too.
    struct BlockKernel {
        max_d: usize,
    }

    impl TangentKernel for BlockKernel {
        fn tangent_merge(
            &self,
            upper: [&[Point]; 2],
            lower: [&[Point]; 2],
        ) -> Option<(Vec<Point>, Vec<Point>)> {
            let len = upper.iter().chain(lower.iter()).map(|c| c.len()).max()?;
            let d = len.next_power_of_two().max(2);
            if d > self.max_d {
                return None;
            }
            let row = |pair: [&[Point]; 2]| {
                let mut blk = pad_to_hood(pair[0], d);
                blk.extend(pad_to_hood(pair[1], d));
                super::super::merge::merge_block_d(&blk, d)
            };
            let up = row(upper);
            let lo = row(lower);
            Some((live_prefix(&up).to_vec(), live_prefix(&lo).to_vec()))
        }
    }

    #[test]
    fn device_tangent_parity_on_forced_disjoint_pairs() {
        // the acceptance gate: device-merged hulls must be bit-identical
        // to the host tangent path (and hence to the one-shot oracle) on
        // x-disjoint pairs across every generator distribution
        let kernel = BlockKernel { max_d: 1 << 9 };
        let mut rng = Rng::new(77);
        for case in 0..200 {
            let da = Distribution::ALL[case % 7];
            let db = Distribution::ALL[(case + 2) % 7];
            let a = squeeze_x(&generate(da, rng.range_usize(1, 220), rng.next_u64()), 0.0, 0.46);
            let b = squeeze_x(&generate(db, rng.range_usize(1, 220), rng.next_u64()), 0.54, 1.0);
            let (au, al) = oracle(&a);
            let (bu, bl) = oracle(&b);
            let (host, host_path) = merge_hulls((&au, &al), (&bu, &bl));
            let (dev, dev_path) =
                merge_hulls_with(Some(&kernel), (&au, &al), (&bu, &bl));
            assert_eq!(host_path, MergePath::Tangent, "case {case}");
            assert_eq!(dev_path, MergePath::DeviceTangent, "case {case}");
            assert_eq!(dev, host, "case {case} ({} ∪ {})", da.name(), db.name());
        }
    }

    #[test]
    fn device_kernel_refusal_falls_back_to_host_tangent() {
        let kernel = BlockKernel { max_d: 2 }; // every real pair overflows
        let a = squeeze_x(&generate(Distribution::Circle, 64, 21), 0.0, 0.45);
        let b = squeeze_x(&generate(Distribution::Circle, 64, 22), 0.55, 1.0);
        let (au, al) = oracle(&a);
        let (bu, bl) = oracle(&b);
        let (host, _) = merge_hulls((&au, &al), (&bu, &bl));
        let (dev, path) = merge_hulls_with(Some(&kernel), (&au, &al), (&bu, &bl));
        assert_eq!(path, MergePath::Tangent, "refusal must fall back");
        assert_eq!(dev, host);
    }

    #[test]
    fn device_path_skips_overlap_and_empty_sides() {
        let kernel = BlockKernel { max_d: 1 << 9 };
        let a = generate(Distribution::Disk, 80, 31);
        let b = generate(Distribution::Cluster, 80, 32);
        let (au, al) = oracle(&a);
        let (bu, bl) = oracle(&b);
        let (host, _) = merge_hulls((&au, &al), (&bu, &bl));
        let (dev, path) = merge_hulls_with(Some(&kernel), (&au, &al), (&bu, &bl));
        assert_eq!(path, MergePath::Interleave);
        assert_eq!(dev, host);
        let (dev, path) = merge_hulls_with(Some(&kernel), (&au, &al), (&[], &[]));
        assert_eq!(path, MergePath::Trivial);
        assert_eq!(dev, (au.clone(), al.clone()));
    }

    #[test]
    fn device_cross_hull_collinearity_is_canonicalized() {
        // same dyadic collinear construction as the host test: whatever
        // tangent corner the kernel samples, the rescan must produce the
        // canonical chain
        let kernel = BlockKernel { max_d: 1 << 9 };
        let a = vec![
            Point::new(0.0, 0.25),
            Point::new(0.125, 0.375),
            Point::new(0.25, 0.5),
            Point::new(0.3125, 0.0625),
        ];
        let b = vec![
            Point::new(0.5, 0.75),
            Point::new(0.625, 0.875),
            Point::new(0.75, 0.5),
        ];
        let (au, al) = oracle(&a);
        let (bu, bl) = oracle(&b);
        let ((mu, ml), path) = merge_hulls_with(Some(&kernel), (&au, &al), (&bu, &bl));
        assert_eq!(path, MergePath::DeviceTangent);
        let union: Vec<Point> = a.iter().chain(b.iter()).copied().collect();
        let (wu, wl) = oracle(&union);
        assert_eq!(mu, wu);
        assert_eq!(ml, wl);
    }

    #[test]
    fn single_point_hulls_merge() {
        let a = vec![Point::new(0.2, 0.3)];
        let b = vec![Point::new(0.7, 0.6)];
        let ((mu, ml), path) = merge_hulls((&a, &a), (&b, &b));
        assert_eq!(path, MergePath::Tangent);
        assert_eq!(mu, vec![a[0], b[0]]);
        assert_eq!(ml, vec![a[0], b[0]]);
    }

    #[test]
    fn one_hull_swallowing_the_other() {
        // b strictly inside a: the merge must return a unchanged
        let a = generate(Distribution::Circle, 64, 9);
        let mut b = squeeze_x(&generate(Distribution::Disk, 64, 10), 0.4, 0.6);
        for p in b.iter_mut() {
            *p = Point::new(p.x, 0.4 + p.y * 0.2).quantize_f32();
        }
        let (au, al) = oracle(&a);
        let (bu, bl) = oracle(&b);
        let ((mu, ml), _) = merge_hulls((&au, &al), (&bu, &bl));
        let union: Vec<Point> = a.iter().chain(b.iter()).copied().collect();
        let (wu, wl) = oracle(&union);
        assert_eq!(mu, wu);
        assert_eq!(ml, wl);
    }
}
