//! Andrew's monotone chain — the canonical O(n) serial hull for x-sorted
//! input and the primary baseline for experiment E4.

use crate::geometry::point::Point;
use crate::geometry::predicates::{orient2d, Orientation};

/// Upper hull of x-sorted, distinct-x points (strict turns: collinear
/// middle points are dropped, matching the Wagener pipeline's output under
/// the paper's general-position assumption).
pub fn upper_hull(points: &[Point]) -> Vec<Point> {
    hull_half(points, Orientation::Left)
}

/// Lower hull of x-sorted, distinct-x points.
pub fn lower_hull(points: &[Point]) -> Vec<Point> {
    hull_half(points, Orientation::Right)
}

fn hull_half(points: &[Point], keep: Orientation) -> Vec<Point> {
    let mut stack: Vec<Point> = Vec::with_capacity(16);
    for &p in points {
        while stack.len() >= 2
            && orient2d(stack[stack.len() - 2], p, stack[stack.len() - 1]) != keep
        {
            stack.pop();
        }
        stack.push(p);
    }
    stack
}

/// Full convex hull as (upper, lower) chains, both left-to-right.
pub fn full_hull(points: &[Point]) -> (Vec<Point>, Vec<Point>) {
    (upper_hull(points), lower_hull(points))
}

/// Closed CCW boundary from the two chains (shared extremes deduplicated).
pub fn closed_boundary(upper: &[Point], lower: &[Point]) -> Vec<Point> {
    let mut poly: Vec<Point> = lower.to_vec();
    for &p in upper.iter().rev().skip(1) {
        if poly.first() != Some(&p) {
            poly.push(p);
        }
    }
    poly
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::generators::{generate, Distribution};
    use crate::geometry::hull_check::{brute_force_upper_hull, check_upper_hull, polygon_area2};

    #[test]
    fn simple_peak() {
        let pts: Vec<Point> = [(0.0, 0.0), (0.3, 0.8), (0.6, 0.2), (1.0, 0.4)]
            .iter()
            .map(|&(x, y)| Point::new(x, y))
            .collect();
        assert_eq!(
            upper_hull(&pts),
            vec![pts[0], pts[1], pts[3]],
        );
        assert_eq!(lower_hull(&pts), vec![pts[0], pts[2], pts[3]]);
    }

    #[test]
    fn matches_brute_force_on_all_distributions() {
        for dist in Distribution::ALL {
            for seed in 0..5 {
                let pts = generate(dist, 40, seed);
                let got = upper_hull(&pts);
                check_upper_hull(&pts, &got).unwrap_or_else(|e| {
                    panic!("{} seed {seed}: {e}", dist.name())
                });
                let want = brute_force_upper_hull(&pts);
                assert_eq!(got, want, "{} seed {seed}", dist.name());
            }
        }
    }

    #[test]
    fn hull_of_small_inputs() {
        let p = Point::new(0.5, 0.5);
        assert_eq!(upper_hull(&[p]), vec![p]);
        let q = Point::new(0.7, 0.1);
        assert_eq!(upper_hull(&[p, q]), vec![p, q]);
        assert_eq!(lower_hull(&[p, q]), vec![p, q]);
    }

    #[test]
    fn closed_boundary_is_ccw_simple() {
        let pts = generate(Distribution::Disk, 200, 9);
        let (u, l) = full_hull(&pts);
        let poly = closed_boundary(&u, &l);
        assert!(polygon_area2(&poly) > 0.0);
        // first/last extremes shared exactly once
        assert_eq!(poly.iter().filter(|&&p| p == u[0]).count(), 1);
        let right = *u.last().unwrap();
        assert_eq!(poly.iter().filter(|&&p| p == right).count(), 1);
    }

    #[test]
    fn collinear_middles_dropped() {
        // exactly-representable collinear triple
        let pts = vec![
            Point::new(0.0, 0.0),
            Point::new(0.25, 0.25),
            Point::new(0.5, 0.5),
        ];
        assert_eq!(upper_hull(&pts), vec![pts[0], pts[2]]);
        assert_eq!(lower_hull(&pts), vec![pts[0], pts[2]]);
    }
}
