//! Artifact registry: discover and describe the AOT-exported HLO modules.
//!
//! `python -m compile.aot` writes `artifacts/manifest.json` mapping
//! artifact names to files and shapes; this module parses it (with the
//! in-tree JSON parser) and answers "which executable serves a request of
//! m points at batch b?".

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

/// What a compiled module computes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    /// upper hood only: (n,2) -> 1-tuple (n,2).
    Hood,
    /// batched full hull: (b,n,2) -> 2-tuple ((b,n,2), (b,n,2)).
    Hull,
    /// plain-jnp ablation twin of Hood.
    HoodJnp,
    /// octagon interior-point prefilter: (n,2) -> 1-tuple (n,2).
    Filter,
    /// sampled common-tangent merge of [H(L)|H(R)] block pairs:
    /// (2,n,2) -> 1-tuple (2,n,2), n = 2d slots per pair.
    Tangent,
}

impl ArtifactKind {
    fn parse(s: &str) -> Result<ArtifactKind> {
        Ok(match s {
            "hood" => ArtifactKind::Hood,
            "hull" => ArtifactKind::Hull,
            "hood_jnp" => ArtifactKind::HoodJnp,
            "filter" => ArtifactKind::Filter,
            "tangent" => ArtifactKind::Tangent,
            other => bail!("unknown artifact kind {other:?}"),
        })
    }
}

/// One manifest entry.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub path: PathBuf,
    pub kind: ArtifactKind,
    /// hood slots (power of two).
    pub n: usize,
    /// batch dimension; 0 for unbatched hood artifacts.
    pub batch: usize,
    /// tuple arity of the output.
    pub outputs: usize,
    pub input_shape: Vec<usize>,
}

/// The set of available artifacts.
#[derive(Clone, Debug)]
pub struct ArtifactRegistry {
    pub dir: PathBuf,
    entries: BTreeMap<String, ArtifactMeta>,
}

impl ArtifactRegistry {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<ArtifactRegistry> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!(
                "cannot read {} — run `make artifacts` first",
                manifest_path.display()
            )
        })?;
        Self::from_manifest_json(dir, &text)
    }

    /// Parse a manifest document (separated out for tests).
    pub fn from_manifest_json(dir: PathBuf, text: &str) -> Result<ArtifactRegistry> {
        let doc = json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let obj = doc.as_obj().ok_or_else(|| anyhow!("manifest: not an object"))?;
        let mut entries = BTreeMap::new();
        for (name, meta) in obj {
            let field = |k: &str| {
                meta.get(k)
                    .ok_or_else(|| anyhow!("manifest entry {name}: missing {k}"))
            };
            let kind = ArtifactKind::parse(
                field("kind")?.as_str().ok_or_else(|| anyhow!("{name}: kind"))?,
            )?;
            let entry = ArtifactMeta {
                name: name.clone(),
                path: dir.join(
                    field("file")?.as_str().ok_or_else(|| anyhow!("{name}: file"))?,
                ),
                kind,
                n: field("n")?.as_usize().ok_or_else(|| anyhow!("{name}: n"))?,
                batch: field("batch")?
                    .as_usize()
                    .ok_or_else(|| anyhow!("{name}: batch"))?,
                outputs: field("outputs")?
                    .as_usize()
                    .ok_or_else(|| anyhow!("{name}: outputs"))?,
                input_shape: field("input_shape")?
                    .as_arr()
                    .ok_or_else(|| anyhow!("{name}: input_shape"))?
                    .iter()
                    .filter_map(Json::as_usize)
                    .collect(),
            };
            entries.insert(name.clone(), entry);
        }
        if entries.is_empty() {
            bail!("manifest is empty");
        }
        Ok(ArtifactRegistry { dir, entries })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.entries.get(name)
    }

    pub fn iter(&self) -> impl Iterator<Item = &ArtifactMeta> {
        self.entries.values()
    }

    /// Hull size classes available (sorted n of batched hull artifacts).
    pub fn hull_size_classes(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .entries
            .values()
            .filter(|m| m.kind == ArtifactKind::Hull)
            .map(|m| m.n)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Batch sizes available for hull artifacts of `n` slots (sorted).
    pub fn hull_batches(&self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .entries
            .values()
            .filter(|m| m.kind == ArtifactKind::Hull && m.n == n)
            .map(|m| m.batch)
            .collect();
        v.sort_unstable();
        v
    }

    /// Pick the hull artifact for `m` live points at batch `b`:
    /// smallest size class with n >= max(m, 2), exact batch match required.
    pub fn select_hull(&self, m: usize, b: usize) -> Result<&ArtifactMeta> {
        let n = self
            .hull_size_classes()
            .into_iter()
            .find(|&n| n >= m.max(2))
            .ok_or_else(|| anyhow!("no hull artifact can hold {m} points"))?;
        self.entries
            .values()
            .find(|meta| meta.kind == ArtifactKind::Hull && meta.n == n && meta.batch == b)
            .ok_or_else(|| anyhow!("no hull artifact for n={n} batch={b}"))
    }

    /// Smallest artifact of `kind` whose block holds >= `m` slots, or None
    /// (callers fall back to the host path on a size-class miss).
    fn select_smallest(&self, kind: ArtifactKind, m: usize) -> Option<&ArtifactMeta> {
        self.entries
            .values()
            .filter(|meta| meta.kind == kind && meta.n >= m)
            .min_by_key(|meta| meta.n)
    }

    /// Pick the prefilter artifact for `m` points (smallest class n >= m).
    pub fn select_filter(&self, m: usize) -> Option<&ArtifactMeta> {
        self.select_smallest(ArtifactKind::Filter, m)
    }

    /// Pick the tangent-merge artifact for chains of up to `len` corners
    /// per side: block = 2d slots with d >= len, so smallest n >= 2*len.
    pub fn select_tangent(&self, len: usize) -> Option<&ArtifactMeta> {
        self.select_smallest(ArtifactKind::Tangent, 2 * len.max(1))
    }

    /// The largest prefilter block available (0 when no filter artifact
    /// exists) — the device-mode admission ceiling.
    pub fn max_filter_points(&self) -> usize {
        self.entries
            .values()
            .filter(|m| m.kind == ArtifactKind::Filter)
            .map(|m| m.n)
            .max()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "hull_n64_b1": {"file": "hull_n64_b1.hlo.txt", "kind": "hull",
        "n": 64, "batch": 1, "outputs": 2, "input_shape": [1, 64, 2]},
      "hull_n64_b8": {"file": "hull_n64_b8.hlo.txt", "kind": "hull",
        "n": 64, "batch": 8, "outputs": 2, "input_shape": [8, 64, 2]},
      "hull_n256_b1": {"file": "hull_n256_b1.hlo.txt", "kind": "hull",
        "n": 256, "batch": 1, "outputs": 2, "input_shape": [1, 256, 2]},
      "hood_n64": {"file": "hood_n64.hlo.txt", "kind": "hood",
        "n": 64, "batch": 0, "outputs": 1, "input_shape": [64, 2]},
      "filter_n4096": {"file": "filter_n4096.hlo.txt", "kind": "filter",
        "n": 4096, "batch": 0, "outputs": 1, "input_shape": [4096, 2]},
      "filter_n65536": {"file": "filter_n65536.hlo.txt", "kind": "filter",
        "n": 65536, "batch": 0, "outputs": 1, "input_shape": [65536, 2]},
      "tangent_n128": {"file": "tangent_n128.hlo.txt", "kind": "tangent",
        "n": 128, "batch": 2, "outputs": 1, "input_shape": [2, 128, 2]},
      "tangent_n512": {"file": "tangent_n512.hlo.txt", "kind": "tangent",
        "n": 512, "batch": 2, "outputs": 1, "input_shape": [2, 512, 2]}
    }"#;

    fn reg() -> ArtifactRegistry {
        ArtifactRegistry::from_manifest_json(PathBuf::from("/x"), SAMPLE).unwrap()
    }

    #[test]
    fn parses_entries() {
        let r = reg();
        let m = r.get("hull_n64_b8").unwrap();
        assert_eq!(m.kind, ArtifactKind::Hull);
        assert_eq!((m.n, m.batch, m.outputs), (64, 8, 2));
        assert_eq!(m.input_shape, vec![8, 64, 2]);
        assert_eq!(m.path, PathBuf::from("/x/hull_n64_b8.hlo.txt"));
    }

    #[test]
    fn size_classes_and_selection() {
        let r = reg();
        assert_eq!(r.hull_size_classes(), vec![64, 256]);
        assert_eq!(r.hull_batches(64), vec![1, 8]);
        assert_eq!(r.select_hull(10, 1).unwrap().name, "hull_n64_b1");
        assert_eq!(r.select_hull(64, 8).unwrap().name, "hull_n64_b8");
        assert_eq!(r.select_hull(65, 1).unwrap().name, "hull_n256_b1");
        assert!(r.select_hull(257, 1).is_err());
        assert!(r.select_hull(64, 3).is_err());
    }

    #[test]
    fn filter_and_tangent_selection() {
        let r = reg();
        assert_eq!(r.select_filter(100).unwrap().name, "filter_n4096");
        assert_eq!(r.select_filter(4096).unwrap().name, "filter_n4096");
        assert_eq!(r.select_filter(4097).unwrap().name, "filter_n65536");
        assert!(r.select_filter(65537).is_none());
        assert_eq!(r.max_filter_points(), 65536);
        // chains of up to n/2 corners per side fit a tangent block
        assert_eq!(r.select_tangent(1).unwrap().name, "tangent_n128");
        assert_eq!(r.select_tangent(64).unwrap().name, "tangent_n128");
        assert_eq!(r.select_tangent(65).unwrap().name, "tangent_n512");
        assert_eq!(r.select_tangent(256).unwrap().name, "tangent_n512");
        assert!(r.select_tangent(257).is_none());
    }

    #[test]
    fn rejects_bad_manifests() {
        for bad in [
            "{}",
            r#"{"a": {"file": "f", "kind": "hull"}}"#,
            r#"{"a": {"file": "f", "kind": "mystery", "n": 1, "batch": 0,
                 "outputs": 1, "input_shape": []}}"#,
            "[1,2]",
        ] {
            assert!(
                ArtifactRegistry::from_manifest_json(PathBuf::from("/x"), bad).is_err(),
                "{bad}"
            );
        }
    }

    #[test]
    fn loads_real_manifest_if_built() {
        // integration sanity: only runs when `make artifacts` has been run
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if std::path::Path::new(dir).join("manifest.json").exists() {
            let r = ArtifactRegistry::load(dir).unwrap();
            assert!(r.hull_size_classes().contains(&256));
            for m in r.iter() {
                assert!(m.path.exists(), "{} missing", m.path.display());
            }
        }
    }
}
