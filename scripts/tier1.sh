#!/usr/bin/env bash
# Tier-1 gate + perf baseline.
#
#   scripts/tier1.sh            # lint, build, test, smoke-bench
#
# Gates: `cargo fmt --check` and `cargo clippy -D warnings` (when the
# components are installed), then `cargo build --release && cargo test -q`
# (the ROADMAP tier-1 verify), then the socket-facing suites once more
# with ENGINE_SHARDS=4 (the sharded engine path on real sockets), then
# the restart suite once more under ring placement, then fast smoke runs
# of bench_runtime, bench_coordinator, bench_stream, bench_engine,
# bench_server, bench_robustness, bench_gateway, bench_store and
# bench_accel with WAGENER_BENCH_JSON
# pointed at BENCH_pram.json / BENCH_coordinator.json / BENCH_stream.json /
# BENCH_engine.json / BENCH_server.json / BENCH_robustness.json /
# BENCH_gateway.json / BENCH_store.json / BENCH_accel.json, so every PR leaves machine-readable perf records
# (PRAM tier timings, router/worker-pool throughput, streaming-session
# schedules, shard scaling, connection-core and wire-format costs,
# overload shed/latency contrasts, snapshot write/restore latency) for
# the next PR to compare against.  Every promised
# BENCH_*.json is then ASSERTED to hold at least one report (a bench that
# skips a backend must still emit its JSON trailer — an empty trajectory
# file means the harness regressed).
set -euo pipefail

ROOT="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"
cd "$ROOT"

if ! command -v cargo >/dev/null 2>&1; then
    echo "tier1: cargo not found on PATH; install a Rust toolchain" >&2
    exit 1
fi

if cargo fmt --version >/dev/null 2>&1; then
    echo "== tier1: cargo fmt --check =="
    cargo fmt --check
else
    echo "tier1: rustfmt not installed; skipping fmt gate" >&2
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "== tier1: cargo clippy -D warnings =="
    cargo clippy --all-targets -- -D warnings
else
    echo "tier1: clippy not installed; skipping clippy gate" >&2
fi

echo "== tier1: cargo build --release =="
cargo build --release

echo "== tier1: cargo test -q =="
cargo test -q

# Kernel twin parity: the Pallas filter/tangent kernels and their plain-jnp
# twins are property-tested in python (hull preservation, boundary points
# kept, pallas≡jnp bit-identity).  Guarded on the toolchain: containers
# without jax/pytest skip this step (the committed diffsim ledger and the
# rust-side parity tests still cover the transliteration).
if python3 -c "import jax, pytest" >/dev/null 2>&1; then
    echo "== tier1: python kernel tests =="
    (cd "$ROOT/python" && python3 -m pytest -q tests/test_filter_kernel.py)
else
    echo "tier1: jax/pytest not importable; skipping python kernel tests" >&2
fi

# The socket-facing suites run once more against a 4-shard engine: the
# sharded routing/registry/metrics paths must hold on real sockets in
# CI, not just in unit tests (shard-parity itself lives in
# engine_integration, which the main test run covers).  proto_parity and
# event_loop_integration join server_integration here so both connection
# cores and both wire formats are exercised on the sharded path too.
# chaos_integration joins so the deterministic fault harness proves the
# same seed → same outcomes property against a sharded engine as well.
# restart_integration joins so durability (crash-restart, SHULL time
# travel, corrupt snapshots, eviction restore) holds on the sharded path.
# gateway_integration joins so HTTP/TCP parity (hull bits, sessions,
# epoch time travel, cursor pagination) holds against a sharded engine.
echo "== tier1: server suites @ ENGINE_SHARDS=4 =="
ENGINE_SHARDS=4 cargo test -q --test server_integration \
    --test proto_parity --test event_loop_integration \
    --test chaos_integration --test restart_integration \
    --test gateway_integration

# And once more with ring placement: snapshots, restores and epoch time
# travel must be placement-independent — a session's durability cannot
# depend on which shard the consistent-hash ring routed it to.
echo "== tier1: restart suite @ ENGINE_SHARDS=4 ENGINE_PLACEMENT=ring =="
ENGINE_SHARDS=4 ENGINE_PLACEMENT=ring cargo test -q --test restart_integration

# A promised bench trajectory that ends up empty is a silent regression
# (a skipping backend must still write its report); fail loudly instead.
assert_bench_written() {
    if ! grep -q '"title"' "$1" 2>/dev/null; then
        echo "tier1: FAIL — $1 is empty; the bench emitted no JSON report" >&2
        exit 1
    fi
}

echo "== tier1: smoke bench -> BENCH_pram.json =="
: > "$ROOT/BENCH_pram.json"
WAGENER_BENCH_FAST=1 WAGENER_BENCH_JSON="$ROOT/BENCH_pram.json" \
    cargo bench --bench bench_runtime
assert_bench_written "$ROOT/BENCH_pram.json"

echo "== tier1: smoke bench -> BENCH_coordinator.json =="
: > "$ROOT/BENCH_coordinator.json"
WAGENER_BENCH_FAST=1 WAGENER_BENCH_JSON="$ROOT/BENCH_coordinator.json" \
    cargo bench --bench bench_coordinator
assert_bench_written "$ROOT/BENCH_coordinator.json"

echo "== tier1: smoke bench -> BENCH_stream.json =="
: > "$ROOT/BENCH_stream.json"
WAGENER_BENCH_FAST=1 WAGENER_BENCH_JSON="$ROOT/BENCH_stream.json" \
    cargo bench --bench bench_stream
assert_bench_written "$ROOT/BENCH_stream.json"

echo "== tier1: smoke bench -> BENCH_engine.json =="
: > "$ROOT/BENCH_engine.json"
WAGENER_BENCH_FAST=1 WAGENER_BENCH_JSON="$ROOT/BENCH_engine.json" \
    cargo bench --bench bench_engine
assert_bench_written "$ROOT/BENCH_engine.json"

echo "== tier1: smoke bench -> BENCH_server.json =="
: > "$ROOT/BENCH_server.json"
WAGENER_BENCH_FAST=1 WAGENER_BENCH_JSON="$ROOT/BENCH_server.json" \
    cargo bench --bench bench_server
assert_bench_written "$ROOT/BENCH_server.json"

echo "== tier1: smoke bench -> BENCH_robustness.json =="
: > "$ROOT/BENCH_robustness.json"
WAGENER_BENCH_FAST=1 WAGENER_BENCH_JSON="$ROOT/BENCH_robustness.json" \
    cargo bench --bench bench_robustness
assert_bench_written "$ROOT/BENCH_robustness.json"

echo "== tier1: smoke bench -> BENCH_gateway.json =="
: > "$ROOT/BENCH_gateway.json"
WAGENER_BENCH_FAST=1 WAGENER_BENCH_JSON="$ROOT/BENCH_gateway.json" \
    cargo bench --bench bench_gateway
assert_bench_written "$ROOT/BENCH_gateway.json"

echo "== tier1: smoke bench -> BENCH_store.json =="
: > "$ROOT/BENCH_store.json"
WAGENER_BENCH_FAST=1 WAGENER_BENCH_JSON="$ROOT/BENCH_store.json" \
    cargo bench --bench bench_store
assert_bench_written "$ROOT/BENCH_store.json"

echo "== tier1: smoke bench -> BENCH_accel.json =="
: > "$ROOT/BENCH_accel.json"
WAGENER_BENCH_FAST=1 WAGENER_BENCH_JSON="$ROOT/BENCH_accel.json" \
    cargo bench --bench bench_accel
assert_bench_written "$ROOT/BENCH_accel.json"

echo "tier1 OK — bench rows:"
cat "$ROOT/BENCH_pram.json" "$ROOT/BENCH_coordinator.json" "$ROOT/BENCH_stream.json" \
    "$ROOT/BENCH_engine.json" "$ROOT/BENCH_server.json" "$ROOT/BENCH_robustness.json" \
    "$ROOT/BENCH_gateway.json" "$ROOT/BENCH_store.json" "$ROOT/BENCH_accel.json"
