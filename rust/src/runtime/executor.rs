//! PJRT executor: compile-once / execute-many wrapper over the `xla` crate.
//!
//! Pattern from /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` (HLO *text*: xla_extension 0.5.1
//! rejects jax ≥ 0.5 serialized protos) → `XlaComputation::from_proto` →
//! `client.compile` → `execute`.
//!
//! The executor is deliberately **not** Send: PJRT handles live on the
//! backend thread that created them; the coordinator routes work to that
//! thread over channels (see coordinator::backend).

use std::cell::RefCell;
use std::collections::HashMap;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use super::artifacts::{ArtifactKind, ArtifactMeta, ArtifactRegistry};
use crate::geometry::point::{live_prefix, Point, REMOTE};
use crate::pram::ExecMode;

/// Cumulative execution statistics (scraped by coordinator metrics).
#[derive(Clone, Debug, Default)]
pub struct RuntimeStats {
    pub compiles: u64,
    pub executions: u64,
    pub requests: u64,
    pub compile_ns: u64,
    pub execute_ns: u64,
    /// PJRT results cross-checked against the PRAM engine (see
    /// [`HullExecutor::set_reference_check`]).
    pub ref_checks: u64,
    pub ref_mismatches: u64,
    /// device prefilter dispatches and the points they shed.
    pub filter_runs: u64,
    pub filter_dropped: u64,
    /// device tangent merges; each is exactly one upload + one download.
    pub tangent_merges: u64,
}

/// Compile-cache + execution front-end for hull/hood artifacts.
pub struct HullExecutor {
    registry: ArtifactRegistry,
    client: xla::PjRtClient,
    cache: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
    stats: RefCell<RuntimeStats>,
    /// when set, every PJRT result is recomputed on the given PRAM engine
    /// tier and compared; mismatches are counted, not fatal.
    ref_check: Option<ExecMode>,
}

impl HullExecutor {
    /// Create a CPU PJRT client over the given artifact registry.
    pub fn new(registry: ArtifactRegistry) -> Result<HullExecutor> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(HullExecutor {
            registry,
            client,
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
            ref_check: None,
        })
    }

    /// Cross-check every PJRT result against the PRAM engine running on
    /// `mode` (`Fast` for a cheap shadow oracle, `Audited` to also keep
    /// the cost model in the loop).  `None` disables the check.  All
    /// three paths are bit-identical on f32-quantized general-position
    /// inputs, so any divergence is a real artifact/runtime bug; it is
    /// counted in [`RuntimeStats::ref_mismatches`], never fatal.
    pub fn set_reference_check(&mut self, mode: Option<ExecMode>) {
        self.ref_check = mode;
    }

    pub fn registry(&self) -> &ArtifactRegistry {
        &self.registry
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the named artifact.
    pub fn ensure_compiled(&self, name: &str) -> Result<()> {
        if self.cache.borrow().contains_key(name) {
            return Ok(());
        }
        let meta = self
            .registry
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?}"))?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&meta.path)
            .with_context(|| format!("parsing HLO text {}", meta.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        let mut stats = self.stats.borrow_mut();
        stats.compiles += 1;
        stats.compile_ns += t0.elapsed().as_nanos() as u64;
        self.cache.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    /// Compile every artifact up front (server warm start).
    pub fn preload_all(&self) -> Result<()> {
        let names: Vec<String> = self.registry.iter().map(|m| m.name.clone()).collect();
        for name in names {
            self.ensure_compiled(&name)?;
        }
        Ok(())
    }

    /// Flatten and REMOTE-pad request point sets into an f32 literal of
    /// shape [b, n, 2].
    fn batch_literal<S: AsRef<[Point]>>(meta: &ArtifactMeta, batch: &[S]) -> Result<xla::Literal> {
        let (b, n) = (meta.batch.max(1), meta.n);
        if batch.len() > b {
            bail!("batch of {} > artifact batch {}", batch.len(), b);
        }
        let mut flat = Vec::with_capacity(b * n * 2);
        for req in batch {
            let req = req.as_ref();
            if req.len() > n {
                bail!("request of {} points > artifact n {}", req.len(), n);
            }
            for p in req {
                let (x, y) = p.to_f32_pair();
                flat.push(x);
                flat.push(y);
            }
            for _ in req.len()..n {
                flat.push(REMOTE.x as f32);
                flat.push(REMOTE.y as f32);
            }
        }
        // pad unused batch rows with fully-REMOTE requests
        flat.resize(b * n * 2, 0.0);
        for row in batch.len()..b {
            for s in 0..n {
                flat[(row * n + s) * 2] = REMOTE.x as f32;
                flat[(row * n + s) * 2 + 1] = REMOTE.y as f32;
            }
        }
        let lit = xla::Literal::vec1(&flat);
        Ok(if meta.batch == 0 {
            lit.reshape(&[n as i64, 2])?
        } else {
            lit.reshape(&[b as i64, n as i64, 2])?
        })
    }

    fn literal_to_hoods(lit: &xla::Literal, b: usize, n: usize) -> Result<Vec<Vec<Point>>> {
        let flat = lit.to_vec::<f32>()?;
        if flat.len() != b * n * 2 {
            bail!("unexpected output size {} != {}", flat.len(), b * n * 2);
        }
        Ok((0..b)
            .map(|row| {
                (0..n)
                    .map(|s| {
                        Point::from_f32_pair(flat[(row * n + s) * 2], flat[(row * n + s) * 2 + 1])
                    })
                    .collect()
            })
            .collect())
    }

    /// Execute a batched full-hull artifact over up to `meta.batch`
    /// requests; returns per-request (upper, lower) hull corners.
    pub fn run_hull<S: AsRef<[Point]>>(
        &self,
        meta: &ArtifactMeta,
        batch: &[S],
    ) -> Result<Vec<(Vec<Point>, Vec<Point>)>> {
        if meta.kind != ArtifactKind::Hull {
            bail!("{} is not a hull artifact", meta.name);
        }
        self.ensure_compiled(&meta.name)?;
        let input = Self::batch_literal(meta, batch)?;
        let t0 = Instant::now();
        let cache = self.cache.borrow();
        let exe = cache.get(&meta.name).unwrap();
        let result = exe.execute::<xla::Literal>(&[input])?[0][0].to_literal_sync()?;
        let (up_lit, lo_lit) = result.to_tuple2()?;
        {
            let mut stats = self.stats.borrow_mut();
            stats.executions += 1;
            stats.requests += batch.len() as u64;
            stats.execute_ns += t0.elapsed().as_nanos() as u64;
        }
        let b = meta.batch.max(1);
        let ups = Self::literal_to_hoods(&up_lit, b, meta.n)?;
        let los = Self::literal_to_hoods(&lo_lit, b, meta.n)?;
        let out: Vec<(Vec<Point>, Vec<Point>)> = ups
            .into_iter()
            .zip(los)
            .take(batch.len())
            .map(|(u, l)| {
                (
                    live_prefix(&u).to_vec(),
                    live_prefix(&l).to_vec(),
                )
            })
            .collect();
        if let Some(mode) = self.ref_check {
            let mut stats = self.stats.borrow_mut();
            for (req, got) in batch.iter().zip(&out) {
                stats.ref_checks += 1;
                match Self::reference_full_hull(mode, req.as_ref()) {
                    Some(want) if want == *got => {}
                    _ => stats.ref_mismatches += 1,
                }
            }
        }
        Ok(out)
    }

    /// (upper, lower) from the PRAM engine — the reference oracle for
    /// [`HullExecutor::set_reference_check`].  Non-strict: inputs outside
    /// general position yield `None`-free best-effort hulls upstream, so
    /// the oracle never panics the serving path.
    fn reference_full_hull(mode: ExecMode, pts: &[Point]) -> Option<(Vec<Point>, Vec<Point>)> {
        let slots = pts.len().next_power_of_two().max(2);
        let up = crate::wagener::pram_exec::run_pipeline_mode(pts, slots, mode, false).ok()?;
        let neg: Vec<Point> = pts.iter().map(|p| Point::new(p.x, -p.y)).collect();
        let lo = crate::wagener::pram_exec::run_pipeline_mode(&neg, slots, mode, false).ok()?;
        Some((
            live_prefix(&up.hood).to_vec(),
            live_prefix(&lo.hood).iter().map(|p| Point::new(p.x, -p.y)).collect(),
        ))
    }

    /// Execute an unbatched hood artifact (upper hull only).
    pub fn run_hood(&self, meta: &ArtifactMeta, points: &[Point]) -> Result<Vec<Point>> {
        if meta.batch != 0 {
            bail!("{} is not an unbatched hood artifact", meta.name);
        }
        self.ensure_compiled(&meta.name)?;
        let input = Self::batch_literal(meta, std::slice::from_ref(&points.to_vec()))?;
        let t0 = Instant::now();
        let cache = self.cache.borrow();
        let exe = cache.get(&meta.name).unwrap();
        let result = exe.execute::<xla::Literal>(&[input])?[0][0].to_literal_sync()?;
        let hood = result.to_tuple1()?;
        {
            let mut stats = self.stats.borrow_mut();
            stats.executions += 1;
            stats.requests += 1;
            stats.execute_ns += t0.elapsed().as_nanos() as u64;
        }
        let rows = Self::literal_to_hoods(&hood, 1, meta.n)?;
        let got = live_prefix(&rows[0]).to_vec();
        if let Some(mode) = self.ref_check {
            let mut stats = self.stats.borrow_mut();
            stats.ref_checks += 1;
            let slots = points.len().next_power_of_two().max(2);
            let want = crate::wagener::pram_exec::run_pipeline_mode(points, slots, mode, false)
                .ok()
                .map(|r| live_prefix(&r.hood).to_vec());
            if want.as_deref() != Some(&got[..]) {
                stats.ref_mismatches += 1;
            }
        }
        Ok(got)
    }

    /// Execute a prefilter artifact over one point set: survivors of the
    /// octagon interior-point filter, in input order.  The kernel is
    /// hull-preserving under the same strict-inside rule as the host
    /// filter (boundary points kept), so callers may substitute the
    /// result for `points` wherever only the hull matters.
    pub fn run_filter(&self, meta: &ArtifactMeta, points: &[Point]) -> Result<Vec<Point>> {
        if meta.kind != ArtifactKind::Filter {
            bail!("{} is not a filter artifact", meta.name);
        }
        self.ensure_compiled(&meta.name)?;
        let input = Self::batch_literal(meta, &[points])?;
        let t0 = Instant::now();
        let cache = self.cache.borrow();
        let exe = cache.get(&meta.name).unwrap();
        let result = exe.execute::<xla::Literal>(&[input])?[0][0].to_literal_sync()?;
        let block = result.to_tuple1()?;
        let rows = Self::literal_to_hoods(&block, 1, meta.n)?;
        let got = live_prefix(&rows[0]).to_vec();
        let mut stats = self.stats.borrow_mut();
        stats.executions += 1;
        stats.requests += 1;
        stats.execute_ns += t0.elapsed().as_nanos() as u64;
        stats.filter_runs += 1;
        stats.filter_dropped += (points.len() - got.len()) as u64;
        Ok(got)
    }

    /// Execute a tangent-merge artifact over one hull ⊕ hull merge: row 0
    /// is the upper [H(L)|H(R)] block, row 1 the y-negated lower pair —
    /// exactly ONE upload and one download per merge.  Returns the two
    /// merged chains (live prefixes; row 1 still mirrored).
    pub fn run_tangent(
        &self,
        meta: &ArtifactMeta,
        upper_blk: &[Point],
        lower_blk: &[Point],
    ) -> Result<(Vec<Point>, Vec<Point>)> {
        if meta.kind != ArtifactKind::Tangent {
            bail!("{} is not a tangent artifact", meta.name);
        }
        if upper_blk.len() != meta.n || lower_blk.len() != meta.n {
            bail!(
                "tangent block of {}/{} slots != artifact n {}",
                upper_blk.len(),
                lower_blk.len(),
                meta.n
            );
        }
        self.ensure_compiled(&meta.name)?;
        let input = Self::batch_literal(meta, &[upper_blk, lower_blk])?;
        let t0 = Instant::now();
        let cache = self.cache.borrow();
        let exe = cache.get(&meta.name).unwrap();
        let result = exe.execute::<xla::Literal>(&[input])?[0][0].to_literal_sync()?;
        let block = result.to_tuple1()?;
        let rows = Self::literal_to_hoods(&block, 2, meta.n)?;
        let mut stats = self.stats.borrow_mut();
        stats.executions += 1;
        stats.requests += 1;
        stats.execute_ns += t0.elapsed().as_nanos() as u64;
        stats.tangent_merges += 1;
        Ok((
            live_prefix(&rows[0]).to_vec(),
            live_prefix(&rows[1]).to_vec(),
        ))
    }

    /// Convenience: route m-point requests to the right artifact and run.
    pub fn hull_auto(
        &self,
        batch: &[Vec<Point>],
    ) -> Result<Vec<(Vec<Point>, Vec<Point>)>> {
        let m = batch.iter().map(Vec::len).max().unwrap_or(0);
        // prefer an exact-batch artifact, else the batch-capable one
        let b = *self
            .registry
            .hull_batches(self.registry.select_hull(m, 1).map(|a| a.n).unwrap_or(0))
            .iter()
            .filter(|&&cap| cap >= batch.len())
            .min()
            .ok_or_else(|| anyhow!("no artifact batch >= {}", batch.len()))?;
        let meta = self.registry.select_hull(m, b)?.clone();
        self.run_hull(&meta, batch)
    }
}
