//! # wagener-hull
//!
//! Production-grade reproduction of Ó Dúnlaing's *"CUDA implementation of
//! Wagener's 2D convex hull PRAM algorithm"* (arXiv CS.DC 2012) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **L1** — the match-and-merge tangent search as a Pallas kernel
//!   (`python/compile/kernels/wagener.py`), AOT-lowered to HLO text;
//! * **L2** — the staged hood pipeline as a JAX computation
//!   (`python/compile/model.py`);
//! * **L3** — this crate: a hull-serving coordinator (router, batcher,
//!   PJRT executor) plus every substrate the paper depends on: robust
//!   geometric predicates, serial baselines, a cost-accounting PRAM
//!   simulator, the Overmars–van Leeuwen optimal-speedup variant,
//!   visualisation, and a benchmark harness.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured record.

pub mod benchkit;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod errors;
pub mod fault;
pub mod gateway;
pub mod geometry;
pub mod ovl;
pub mod pram;
pub mod runtime;
pub mod serial;
pub mod server;
pub mod store;
pub mod stream;
pub mod util;
pub mod viz;
pub mod wagener;
