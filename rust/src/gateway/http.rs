//! Incremental HTTP/1.1 request decoder + response encoder, in the style
//! of `server/proto.rs`: pure functions over a byte buffer returning
//! [`Decoded::Frame`] (a complete request plus the bytes it consumed) or
//! [`Decoded::Need`] (a lower bound on the bytes required), so the
//! readiness-driven gateway loop can feed it partial reads and never
//! blocks on a slow sender.
//!
//! Deliberately small surface: request-line + headers (CRLF or bare-LF
//! line endings), `Content-Length` and `chunked` bodies, keep-alive
//! negotiation.  Anything outside that — header obs-folding, a
//! `Transfer-Encoding` next to a `Content-Length` (the classic request
//! smuggling vector), conflicting duplicate lengths — is a *fatal*
//! [`HttpError`]: the response goes out with `Connection: close` and the
//! connection is torn down, because framing can no longer be trusted.
//! Every error carries a stable status + machine-parseable code.

use crate::server::proto::Decoded;
use crate::util::json::Json;

/// Cap on the request line + headers (including the blank-line
/// terminator).  Past this with no terminator in sight the request is
/// rejected with 431 — the `Need` lower bound can never grow unbounded.
pub const MAX_HEAD_BYTES: usize = 16 * 1024;
/// Header-count cap (64 is far beyond any legitimate client here).
pub const MAX_HEADERS: usize = 64;

/// Request methods the router matches on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Get,
    Post,
    Delete,
    /// Parsed fine but not something any route serves.
    Other,
}

impl Method {
    fn parse(s: &str) -> Method {
        match s {
            "GET" => Method::Get,
            "POST" => Method::Post,
            "DELETE" => Method::Delete,
            _ => Method::Other,
        }
    }

    pub const fn word(self) -> &'static str {
        match self {
            Method::Get => "GET",
            Method::Post => "POST",
            Method::Delete => "DELETE",
            Method::Other => "OTHER",
        }
    }
}

/// One decoded HTTP request.
#[derive(Debug)]
pub struct HttpRequest {
    pub method: Method,
    /// Decoded path, query string stripped (e.g. `/v1/sessions/7/hull`).
    pub path: String,
    /// Percent-decoded `key=value` pairs from the query string, in order.
    pub query: Vec<(String, String)>,
    /// Headers with ascii-lowercased names, in arrival order.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    /// HTTP/1.1 defaults to keep-alive, 1.0 to close; a `Connection`
    /// header overrides either way.
    pub keep_alive: bool,
}

impl HttpRequest {
    /// First header with this (lowercase) name.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(n, _)| n == name).map(|(_, v)| v.as_str())
    }

    /// First query parameter with this name.
    pub fn query(&self, name: &str) -> Option<&str> {
        self.query.iter().find(|(n, _)| n == name).map(|(_, v)| v.as_str())
    }
}

/// Fatal framing failures.  All of them end the connection after the
/// error response flushes — see the module docs for why.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum HttpError {
    /// Request line or header syntax is broken.
    Malformed(&'static str),
    /// No blank line within [`MAX_HEAD_BYTES`], or > [`MAX_HEADERS`].
    HeadTooLarge,
    /// Declared or accumulated body past the configured cap.
    BodyTooLarge { max: usize },
    /// Not HTTP/1.0 or HTTP/1.1.
    UnsupportedVersion,
    /// `Transfer-Encoding` + `Content-Length`, duplicate conflicting
    /// lengths, or obs-folding — the request-smuggling vectors.
    Smuggling(&'static str),
    /// Broken `chunked` framing.
    BadChunk(&'static str),
}

impl HttpError {
    pub const fn status(&self) -> u16 {
        match self {
            HttpError::Malformed(_) => 400,
            HttpError::HeadTooLarge => 431,
            HttpError::BodyTooLarge { .. } => 413,
            HttpError::UnsupportedVersion => 505,
            HttpError::Smuggling(_) => 400,
            HttpError::BadChunk(_) => 400,
        }
    }

    pub const fn code(&self) -> &'static str {
        match self {
            HttpError::Malformed(_) => "malformed-request",
            HttpError::HeadTooLarge => "headers-too-large",
            HttpError::BodyTooLarge { .. } => "body-too-large",
            HttpError::UnsupportedVersion => "unsupported-version",
            HttpError::Smuggling(_) => "ambiguous-framing",
            HttpError::BadChunk(_) => "bad-chunk",
        }
    }
}

impl std::fmt::Display for HttpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HttpError::Malformed(d) => write!(f, "malformed request: {d}"),
            HttpError::HeadTooLarge => write!(f, "request head exceeds {MAX_HEAD_BYTES} bytes"),
            HttpError::BodyTooLarge { max } => write!(f, "request body exceeds {max} bytes"),
            HttpError::UnsupportedVersion => write!(f, "only HTTP/1.0 and HTTP/1.1 are served"),
            HttpError::Smuggling(d) => write!(f, "ambiguous framing: {d}"),
            HttpError::BadChunk(d) => write!(f, "bad chunked framing: {d}"),
        }
    }
}

/// Find the end of the head: the byte index just past the first blank
/// line (`\r\n\r\n` or `\n\n`, mixed endings included).
fn head_end(buf: &[u8]) -> Option<usize> {
    let mut i = 0;
    while i < buf.len() {
        if buf[i] == b'\n' {
            let rest = &buf[i + 1..];
            if rest.first() == Some(&b'\n') {
                return Some(i + 2);
            }
            if rest.len() >= 2 && rest[0] == b'\r' && rest[1] == b'\n' {
                return Some(i + 3);
            }
        }
        i += 1;
    }
    None
}

fn percent_decode(s: &str) -> String {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'%' if i + 2 < bytes.len() => {
                let hex = |b: u8| (b as char).to_digit(16);
                match (hex(bytes[i + 1]), hex(bytes[i + 2])) {
                    (Some(h), Some(l)) => {
                        out.push((h * 16 + l) as u8);
                        i += 3;
                    }
                    _ => {
                        out.push(b'%');
                        i += 1;
                    }
                }
            }
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            b => {
                out.push(b);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

fn parse_query(qs: &str) -> Vec<(String, String)> {
    qs.split('&')
        .filter(|p| !p.is_empty())
        .map(|pair| match pair.split_once('=') {
            Some((k, v)) => (percent_decode(k), percent_decode(v)),
            None => (percent_decode(pair), String::new()),
        })
        .collect()
}

/// Decode one request from the front of `buf`.  `max_body` caps both
/// declared `Content-Length` and accumulated chunked bodies.  `Need(n)`
/// always satisfies `n > buf.len()` and
/// `n <= max(buf.len(), MAX_HEAD_BYTES) + max_body + 2` — bounded
/// progress (the left term covers chunk-framing overhead already
/// buffered; the fuzz suite pins both properties).
pub fn decode_request(buf: &[u8], max_body: usize) -> Result<Decoded<HttpRequest>, HttpError> {
    let Some(head_len) = head_end(buf) else {
        if buf.len() >= MAX_HEAD_BYTES {
            return Err(HttpError::HeadTooLarge);
        }
        return Ok(Decoded::Need(buf.len() + 1));
    };
    if head_len > MAX_HEAD_BYTES {
        return Err(HttpError::HeadTooLarge);
    }
    let head = std::str::from_utf8(&buf[..head_len])
        .map_err(|_| HttpError::Malformed("head is not utf-8"))?;
    let mut lines = head.split('\n').map(|l| l.strip_suffix('\r').unwrap_or(l));

    // ---- request line
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split(' ').filter(|p| !p.is_empty());
    let (Some(m), Some(target), Some(version)) = (parts.next(), parts.next(), parts.next())
    else {
        return Err(HttpError::Malformed("request line wants METHOD TARGET VERSION"));
    };
    if parts.next().is_some() {
        return Err(HttpError::Malformed("request line has trailing tokens"));
    }
    let http11 = match version {
        "HTTP/1.1" => true,
        "HTTP/1.0" => false,
        _ => return Err(HttpError::UnsupportedVersion),
    };
    let method = Method::parse(m);
    if !target.starts_with('/') {
        return Err(HttpError::Malformed("target must be origin-form (start with /)"));
    }
    let (raw_path, raw_query) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };

    // ---- headers
    let mut headers: Vec<(String, String)> = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue; // the blank terminator (and the slack after it)
        }
        if headers.len() >= MAX_HEADERS {
            return Err(HttpError::HeadTooLarge);
        }
        if line.starts_with(' ') || line.starts_with('\t') {
            // obs-folding: deprecated, and a smuggling vector when two
            // parsers disagree about it — reject outright
            return Err(HttpError::Smuggling("obs-folded header"));
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(HttpError::Malformed("header line without ':'"));
        };
        if name.is_empty() || name.contains(' ') || name.contains('\t') {
            return Err(HttpError::Malformed("bad header name"));
        }
        headers.push((name.to_ascii_lowercase(), value.trim().to_string()));
    }

    // ---- body framing
    let te: Vec<&str> = headers
        .iter()
        .filter(|(n, _)| n == "transfer-encoding")
        .map(|(_, v)| v.as_str())
        .collect();
    let cl: Vec<&str> = headers
        .iter()
        .filter(|(n, _)| n == "content-length")
        .map(|(_, v)| v.as_str())
        .collect();
    if !te.is_empty() && !cl.is_empty() {
        return Err(HttpError::Smuggling("both Transfer-Encoding and Content-Length"));
    }
    if cl.len() > 1 && cl.iter().any(|v| *v != cl[0]) {
        return Err(HttpError::Smuggling("conflicting Content-Length values"));
    }

    let (body, used) = if !te.is_empty() {
        if te.len() > 1 || !te[0].eq_ignore_ascii_case("chunked") {
            return Err(HttpError::Smuggling("unsupported Transfer-Encoding"));
        }
        match decode_chunked(&buf[head_len..], max_body)? {
            Decoded::Need(n) => return Ok(Decoded::Need(head_len + n)),
            Decoded::Frame(body, n) => (body, head_len + n),
        }
    } else if let Some(v) = cl.first() {
        let n: usize = v
            .parse()
            .map_err(|_| HttpError::Malformed("Content-Length is not a number"))?;
        if n > max_body {
            return Err(HttpError::BodyTooLarge { max: max_body });
        }
        if buf.len() < head_len + n {
            return Ok(Decoded::Need(head_len + n));
        }
        (buf[head_len..head_len + n].to_vec(), head_len + n)
    } else {
        (Vec::new(), head_len)
    };

    let keep_alive = match headers
        .iter()
        .find(|(n, _)| n == "connection")
        .map(|(_, v)| v.to_ascii_lowercase())
    {
        Some(v) if v.split(',').any(|t| t.trim() == "close") => false,
        Some(v) if v.split(',').any(|t| t.trim() == "keep-alive") => true,
        _ => http11,
    };

    Ok(Decoded::Frame(
        HttpRequest {
            method,
            path: percent_decode(raw_path),
            query: parse_query(raw_query),
            headers,
            body,
            keep_alive,
        },
        used,
    ))
}

/// Incrementally decode a `chunked` body from `buf` (which starts right
/// after the head).  Returns the assembled body + bytes consumed.
fn decode_chunked(buf: &[u8], max_body: usize) -> Result<Decoded<Vec<u8>>, HttpError> {
    let mut body = Vec::new();
    let mut off = 0;
    loop {
        // chunk-size line
        let Some(nl) = buf[off..].iter().position(|&b| b == b'\n') else {
            if buf.len() - off > 18 {
                // a chunk-size line is a short hex number (+ extensions we
                // reject); a long prefix with no newline is garbage
                return Err(HttpError::BadChunk("unterminated chunk size"));
            }
            return Ok(Decoded::Need(buf.len() + 1));
        };
        let line = std::str::from_utf8(&buf[off..off + nl])
            .map_err(|_| HttpError::BadChunk("chunk size is not utf-8"))?
            .trim_end_matches('\r');
        let size_hex = line.split(';').next().unwrap_or("").trim();
        if size_hex.is_empty() || size_hex.len() > 8 {
            return Err(HttpError::BadChunk("bad chunk size"));
        }
        let size = usize::from_str_radix(size_hex, 16)
            .map_err(|_| HttpError::BadChunk("chunk size is not hex"))?;
        off += nl + 1;
        if size == 0 {
            // no trailer support: the terminator must follow immediately
            let rest = &buf[off..];
            if rest.is_empty() || (rest[0] == b'\r' && rest.len() < 2) {
                return Ok(Decoded::Need(buf.len() + 1));
            }
            return if rest[0] == b'\n' {
                Ok(Decoded::Frame(body, off + 1))
            } else if rest[0] == b'\r' && rest[1] == b'\n' {
                Ok(Decoded::Frame(body, off + 2))
            } else {
                Err(HttpError::BadChunk("trailers are not supported"))
            };
        }
        if body.len() + size > max_body {
            return Err(HttpError::BodyTooLarge { max: max_body });
        }
        // chunk data + its trailing CRLF
        if buf.len() < off + size + 1 {
            return Ok(Decoded::Need(off + size + 1));
        }
        body.extend_from_slice(&buf[off..off + size]);
        off += size;
        match buf[off] {
            b'\n' => off += 1,
            b'\r' => {
                if buf.len() < off + 2 {
                    return Ok(Decoded::Need(off + 2));
                }
                if buf[off + 1] != b'\n' {
                    return Err(HttpError::BadChunk("chunk data not newline-terminated"));
                }
                off += 2;
            }
            _ => return Err(HttpError::BadChunk("chunk data not newline-terminated")),
        }
    }
}

/// One response ready to encode.
#[derive(Debug)]
pub struct HttpResponse {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
}

impl HttpResponse {
    pub fn json(status: u16, body: Json) -> HttpResponse {
        HttpResponse {
            status,
            content_type: "application/json",
            body: body.to_string().into_bytes(),
        }
    }

    /// The uniform JSON error body: `{"error":{"code":...,"message":...}}`.
    pub fn error(status: u16, code: &str, message: &str) -> HttpResponse {
        HttpResponse::json(
            status,
            Json::obj(vec![(
                "error",
                Json::obj(vec![
                    ("code", Json::Str(code.to_string())),
                    ("message", Json::Str(message.to_string())),
                ]),
            )]),
        )
    }

    /// Append the wire form.  Responses always carry `Content-Length`
    /// (never chunked) so the client-side decoder stays trivial.
    pub fn encode(&self, out: &mut Vec<u8>, keep_alive: bool) {
        out.extend_from_slice(
            format!(
                "HTTP/1.1 {} {}\r\ncontent-type: {}\r\ncontent-length: {}\r\nconnection: {}\r\n\r\n",
                self.status,
                reason(self.status),
                self.content_type,
                self.body.len(),
                if keep_alive { "keep-alive" } else { "close" },
            )
            .as_bytes(),
        );
        out.extend_from_slice(&self.body);
    }
}

/// Reason phrases for every status the gateway emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        413 => "Payload Too Large",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        502 => "Bad Gateway",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        505 => "HTTP Version Not Supported",
        _ => "Error",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(buf: &[u8]) -> (HttpRequest, usize) {
        match decode_request(buf, 1 << 20).unwrap() {
            Decoded::Frame(r, n) => (r, n),
            Decoded::Need(n) => panic!("want frame, got Need({n})"),
        }
    }

    #[test]
    fn decodes_a_simple_get() {
        let wire = b"GET /v1/stats?pretty=1 HTTP/1.1\r\nHost: x\r\n\r\n";
        let (r, used) = frame(wire);
        assert_eq!(r.method, Method::Get);
        assert_eq!(r.path, "/v1/stats");
        assert_eq!(r.query("pretty"), Some("1"));
        assert_eq!(r.header("host"), Some("x"));
        assert!(r.body.is_empty());
        assert!(r.keep_alive);
        assert_eq!(used, wire.len());
    }

    #[test]
    fn bare_lf_line_endings_are_accepted() {
        let wire = b"POST /v1/hull HTTP/1.1\ncontent-length: 2\n\nhi";
        let (r, used) = frame(wire);
        assert_eq!(r.body, b"hi");
        assert_eq!(used, wire.len());
    }

    #[test]
    fn incremental_need_makes_progress() {
        let full = b"POST /v1/hull HTTP/1.1\r\ncontent-length: 5\r\n\r\nabcde";
        for cut in 0..full.len() {
            match decode_request(&full[..cut], 1 << 20).unwrap() {
                Decoded::Need(n) => assert!(n > cut, "cut={cut} need={n}"),
                Decoded::Frame(_, _) => panic!("frame before all {} bytes (cut={cut})", full.len()),
            }
        }
        let (r, used) = frame(full);
        assert_eq!(used, full.len());
        assert_eq!(r.body, b"abcde");
    }

    #[test]
    fn chunked_bodies_reassemble() {
        let wire = b"POST /x HTTP/1.1\r\ntransfer-encoding: chunked\r\n\r\n3\r\nabc\r\n2\r\nde\r\n0\r\n\r\n";
        let (r, used) = frame(wire);
        assert_eq!(r.body, b"abcde");
        assert_eq!(used, wire.len());
        // byte-by-byte: only Need until the terminator
        for cut in 0..wire.len() {
            match decode_request(&wire[..cut], 1 << 20).unwrap() {
                Decoded::Need(n) => assert!(n > cut),
                Decoded::Frame(_, _) => panic!("early frame at {cut}"),
            }
        }
    }

    #[test]
    fn oversized_content_length_is_fatal_not_need() {
        let e = decode_request(b"POST /x HTTP/1.1\r\ncontent-length: 999\r\n\r\n", 100)
            .unwrap_err();
        assert_eq!(e, HttpError::BodyTooLarge { max: 100 });
        assert_eq!(e.status(), 413);
    }

    #[test]
    fn smuggling_vectors_are_fatal() {
        let e = decode_request(
            b"POST /x HTTP/1.1\r\ncontent-length: 3\r\ntransfer-encoding: chunked\r\n\r\n",
            1 << 20,
        )
        .unwrap_err();
        assert!(matches!(e, HttpError::Smuggling(_)));
        let e = decode_request(
            b"POST /x HTTP/1.1\r\ncontent-length: 3\r\ncontent-length: 4\r\n\r\n",
            1 << 20,
        )
        .unwrap_err();
        assert!(matches!(e, HttpError::Smuggling(_)));
        // identical duplicates are tolerated
        let (r, _) =
            frame(b"POST /x HTTP/1.1\r\ncontent-length: 2\r\ncontent-length: 2\r\n\r\nok");
        assert_eq!(r.body, b"ok");
        let e = decode_request(b"GET /x HTTP/1.1\r\na: 1\r\n b: 2\r\n\r\n", 1 << 20).unwrap_err();
        assert!(matches!(e, HttpError::Smuggling(_)));
    }

    #[test]
    fn unbounded_head_is_rejected() {
        let mut buf = b"GET / HTTP/1.1\r\n".to_vec();
        while buf.len() < MAX_HEAD_BYTES {
            buf.extend_from_slice(b"x-filler: yyyyyyyyyyyyyyyyyyyyyyyyyyyyyyyyyyyy\r\n");
        }
        let e = decode_request(&buf, 1 << 20).unwrap_err();
        assert_eq!(e, HttpError::HeadTooLarge);
    }

    #[test]
    fn http10_defaults_to_close() {
        let (r, _) = frame(b"GET / HTTP/1.0\r\n\r\n");
        assert!(!r.keep_alive);
        let (r, _) = frame(b"GET / HTTP/1.0\r\nconnection: keep-alive\r\n\r\n");
        assert!(r.keep_alive);
        let (r, _) = frame(b"GET / HTTP/1.1\r\nconnection: close\r\n\r\n");
        assert!(!r.keep_alive);
        assert!(matches!(
            decode_request(b"GET / HTTP/2\r\n\r\n", 4).unwrap_err(),
            HttpError::UnsupportedVersion
        ));
    }

    #[test]
    fn responses_encode_with_content_length() {
        let mut out = Vec::new();
        HttpResponse::json(200, Json::obj(vec![("ok", Json::Bool(true))]))
            .encode(&mut out, true);
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"), "{s}");
        assert!(s.contains("content-length: 11"), "{s}");
        assert!(s.contains("connection: keep-alive"), "{s}");
        assert!(s.ends_with("{\"ok\":true}"), "{s}");
    }
}
