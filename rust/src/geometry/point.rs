//! Planar points and the paper's hood-slot conventions.
//!
//! The paper stores points as CUDA `float2` with x-coordinates in [0, 1];
//! any slot with x > 1 is "remote" (dead padding), and the canonical remote
//! value is REMOTE = (10, 0).  We keep f64 in the rust core (the PJRT
//! boundary converts to/from f32) and reuse the same conventions.

use std::fmt;

/// Liveness threshold: a slot is live iff `x <= LIVE_X_MAX`.
pub const LIVE_X_MAX: f64 = 1.0;

/// A point in the plane (f64; constructed from f32 at the wire/PJRT edge).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Point {
    pub x: f64,
    pub y: f64,
}

/// The paper's padding value for dead hood slots.
pub const REMOTE: Point = Point { x: 10.0, y: 0.0 };

impl Point {
    pub const fn new(x: f64, y: f64) -> Point {
        Point { x, y }
    }

    /// Paper convention: slot is live iff x <= 1.
    pub fn is_live(&self) -> bool {
        self.x <= LIVE_X_MAX
    }

    pub fn is_remote(&self) -> bool {
        !self.is_live()
    }

    /// The synthetic point directly below `self` (paper's `y -= 1` trick
    /// for branch-free neighbor handling at hood ends).
    pub fn below(&self) -> Point {
        Point::new(self.x, self.y - 1.0)
    }

    /// Round-trip through f32 (what the PJRT artifacts compute on).
    pub fn to_f32_pair(&self) -> (f32, f32) {
        (self.x as f32, self.y as f32)
    }

    pub fn from_f32_pair(x: f32, y: f32) -> Point {
        Point::new(x as f64, y as f64)
    }

    /// Quantize to f32 grid: makes rust-native and PJRT backends compute on
    /// identical coordinates.
    pub fn quantize_f32(&self) -> Point {
        Point::new(self.x as f32 as f64, self.y as f32 as f64)
    }
}

impl fmt::Display for Point {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "({:.6}, {:.6})", self.x, self.y)
    }
}

/// Sort points by (x, y); the pipeline requires strictly increasing x.
pub fn sort_by_x(points: &mut [Point]) {
    points.sort_by(|a, b| {
        a.x.partial_cmp(&b.x)
            .unwrap()
            .then(a.y.partial_cmp(&b.y).unwrap())
    });
}

/// Drop points sharing an x-coordinate, keeping the one with extreme y.
///
/// The paper assumes general position (distinct x, no 3 collinear).  For
/// the *upper* hood only the max-y point of an x-class can be a corner, so
/// `keep_max_y = true` preserves the upper hull; callers computing lower
/// hoods pass `false`.  Input must be sorted by (x, y).
pub fn dedup_x(points: &[Point], keep_max_y: bool) -> Vec<Point> {
    let mut out: Vec<Point> = Vec::with_capacity(points.len());
    for &p in points {
        match out.last_mut() {
            Some(last) if last.x == p.x => {
                // sorted by (x, y): p.y >= last.y
                if keep_max_y {
                    *last = p;
                }
            }
            _ => out.push(p),
        }
    }
    out
}

/// Affine map normalizing arbitrary input into the paper's [0,1] x-range
/// (and a sane y-range), remembering how to undo it.
#[derive(Clone, Copy, Debug)]
pub struct Normalizer {
    pub x_off: f64,
    pub x_scale: f64,
    pub y_off: f64,
    pub y_scale: f64,
}

impl Normalizer {
    /// Fit to the bounding box of `points` (must be non-empty, finite).
    pub fn fit(points: &[Point]) -> Normalizer {
        assert!(!points.is_empty(), "cannot normalize an empty point set");
        let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
        let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
        for p in points {
            x0 = x0.min(p.x);
            x1 = x1.max(p.x);
            y0 = y0.min(p.y);
            y1 = y1.max(p.y);
        }
        let xs = if x1 > x0 { x1 - x0 } else { 1.0 };
        let ys = if y1 > y0 { y1 - y0 } else { 1.0 };
        Normalizer {
            x_off: x0,
            x_scale: xs,
            y_off: y0,
            y_scale: ys,
        }
    }

    pub fn apply(&self, p: Point) -> Point {
        Point::new((p.x - self.x_off) / self.x_scale, (p.y - self.y_off) / self.y_scale)
    }

    pub fn invert(&self, p: Point) -> Point {
        Point::new(p.x * self.x_scale + self.x_off, p.y * self.y_scale + self.y_off)
    }
}

/// Next power of two >= n (n >= 1).
pub fn next_pow2(n: usize) -> usize {
    n.next_power_of_two()
}

/// Build an initial hood block: points live-left-justified, REMOTE-padded
/// to `slots` (slots must be a power of two >= points.len()).
pub fn pad_to_hood(points: &[Point], slots: usize) -> Vec<Point> {
    assert!(slots.is_power_of_two(), "hood size must be a power of two");
    assert!(points.len() <= slots, "{} points > {} slots", points.len(), slots);
    let mut hood = Vec::with_capacity(slots);
    hood.extend_from_slice(points);
    hood.resize(slots, REMOTE);
    hood
}

/// Extract the live prefix of a hood block.
pub fn live_prefix(hood: &[Point]) -> &[Point] {
    let k = hood.iter().take_while(|p| p.is_live()).count();
    &hood[..k]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn remote_is_dead() {
        assert!(REMOTE.is_remote());
        assert!(!Point::new(1.0, 0.5).is_remote());
        assert!(Point::new(1.0000001, 0.0).is_remote());
    }

    #[test]
    fn below_shifts_y() {
        let p = Point::new(0.25, 0.5).below();
        assert_eq!(p, Point::new(0.25, -0.5));
    }

    #[test]
    fn sorting_and_dedup() {
        let mut pts = vec![
            Point::new(0.5, 0.1),
            Point::new(0.1, 0.9),
            Point::new(0.5, 0.7),
            Point::new(0.1, 0.2),
        ];
        sort_by_x(&mut pts);
        assert_eq!(pts[0], Point::new(0.1, 0.2));
        let up = dedup_x(&pts, true);
        assert_eq!(up, vec![Point::new(0.1, 0.9), Point::new(0.5, 0.7)]);
        let lo = dedup_x(&pts, false);
        assert_eq!(lo, vec![Point::new(0.1, 0.2), Point::new(0.5, 0.1)]);
    }

    #[test]
    fn normalizer_roundtrip() {
        let pts = vec![Point::new(-3.0, 7.0), Point::new(9.0, -2.0), Point::new(1.0, 1.0)];
        let nm = Normalizer::fit(&pts);
        for &p in &pts {
            let q = nm.apply(p);
            assert!((0.0..=1.0).contains(&q.x), "{q}");
            assert!((0.0..=1.0).contains(&q.y), "{q}");
            let r = nm.invert(q);
            assert!((r.x - p.x).abs() < 1e-12 && (r.y - p.y).abs() < 1e-12);
        }
    }

    #[test]
    fn normalizer_degenerate_box() {
        let pts = vec![Point::new(2.0, 5.0), Point::new(2.0, 5.0)];
        let nm = Normalizer::fit(&pts);
        let q = nm.apply(pts[0]);
        assert!(q.x.is_finite() && q.y.is_finite());
    }

    #[test]
    fn hood_padding() {
        let pts = vec![Point::new(0.1, 0.1), Point::new(0.2, 0.2)];
        let hood = pad_to_hood(&pts, 8);
        assert_eq!(hood.len(), 8);
        assert_eq!(live_prefix(&hood).len(), 2);
        assert_eq!(hood[7], REMOTE);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn pad_requires_pow2() {
        pad_to_hood(&[Point::new(0.0, 0.0)], 6);
    }

    #[test]
    fn next_pow2_values() {
        for (n, want) in [(1, 1), (2, 2), (3, 4), (5, 8), (64, 64), (65, 128)] {
            assert_eq!(next_pow2(n), want);
        }
    }
}
