//! Blocking client for the hull service (examples, benches, tests, CLI).

use std::io::{BufReader, BufWriter};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::{Duration, SystemTime, UNIX_EPOCH};

use anyhow::{anyhow, bail, Result};

use crate::geometry::point::Point;

use super::proto::{self, Request, Response};
use super::frame;

/// Which wire encoding this client speaks.  The server auto-detects per
/// connection from the first byte, so no negotiation round-trip exists:
/// a client just starts talking in its chosen protocol.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireProto {
    /// Line-oriented text (the paper's file format extended with framing).
    Text,
    /// Length-prefixed binary frames with packed little-endian f64 pairs.
    Binary,
}

/// One connection to a hull server.
pub struct HullClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    next_id: u64,
    proto: WireProto,
}

/// A hull result as seen by the client.
#[derive(Clone, Debug)]
pub struct ClientHull {
    pub id: u64,
    pub upper: Vec<Point>,
    pub lower: Vec<Point>,
    pub backend: String,
    pub queue_ns: u64,
    pub exec_ns: u64,
}

/// `SADD` acknowledgment: lifetime absorbed count, current pending
/// buffer size, current epoch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SessionAddReply {
    pub absorbed: u64,
    pub pending: u64,
    pub epoch: u64,
}

/// `SHULL` payload: the authoritative hull and its epoch.
#[derive(Clone, Debug)]
pub struct SessionHullReply {
    pub epoch: u64,
    pub upper: Vec<Point>,
    pub lower: Vec<Point>,
}

/// Default bound on connection establishment: a dead or unroutable host
/// surfaces as an error instead of a client parked in `connect(2)`.
pub const DEFAULT_CONNECT_TIMEOUT: Duration = Duration::from_secs(5);

impl HullClient {
    pub fn connect(addr: impl ToSocketAddrs) -> Result<HullClient> {
        Self::connect_with(addr, WireProto::Text)
    }

    /// Connect speaking `proto` — same verbs, same replies, different
    /// encoding on the wire.  Bounded by [`DEFAULT_CONNECT_TIMEOUT`];
    /// use [`HullClient::connect_with_timeout`] to choose the bound (or
    /// wait forever).
    pub fn connect_with(addr: impl ToSocketAddrs, proto: WireProto) -> Result<HullClient> {
        Self::connect_with_timeout(addr, proto, Some(DEFAULT_CONNECT_TIMEOUT))
    }

    /// [`HullClient::connect_with`] with an explicit connect timeout
    /// (`None` = the OS default, potentially minutes).  Every resolved
    /// address is tried before giving up.
    pub fn connect_with_timeout(
        addr: impl ToSocketAddrs,
        proto: WireProto,
        timeout: Option<Duration>,
    ) -> Result<HullClient> {
        let stream = match timeout {
            None => TcpStream::connect(addr)?,
            Some(t) => {
                let mut last: Option<std::io::Error> = None;
                let mut stream = None;
                for sa in addr.to_socket_addrs()? {
                    match TcpStream::connect_timeout(&sa, t) {
                        Ok(s) => {
                            stream = Some(s);
                            break;
                        }
                        Err(e) => last = Some(e),
                    }
                }
                match stream {
                    Some(s) => s,
                    None => {
                        return Err(match last {
                            Some(e) => e.into(),
                            None => anyhow!("address resolved to nothing"),
                        })
                    }
                }
            }
        };
        stream.set_nodelay(true)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(HullClient { reader, writer: BufWriter::new(stream), next_id: 1, proto })
    }

    /// Connect with bounded retry: up to `attempts` tries, sleeping a
    /// jittered exponential backoff (`backoff`, `2*backoff`, `4*backoff`,
    /// …, each plus up to 25% jitter) between failures.  For scripts and
    /// tests racing a server that is still binding its listener.
    pub fn connect_retry(
        addr: impl ToSocketAddrs + Clone,
        proto: WireProto,
        attempts: u32,
        backoff: Duration,
    ) -> Result<HullClient> {
        let attempts = attempts.max(1);
        let mut last = None;
        for i in 0..attempts {
            match Self::connect_with(addr.clone(), proto) {
                Ok(c) => return Ok(c),
                Err(e) => last = Some(e),
            }
            if i + 1 < attempts {
                let exp = backoff.saturating_mul(1u32 << i.min(16));
                // wall-clock nanos as a jitter source: no rand dependency,
                // and reproducibility across retries is worthless anyway
                let nanos = SystemTime::now()
                    .duration_since(UNIX_EPOCH)
                    .map(|d| d.subsec_nanos())
                    .unwrap_or(0) as u64;
                let jitter = (exp.as_millis() as u64 / 4).saturating_add(1);
                std::thread::sleep(exp + Duration::from_millis(nanos % jitter));
            }
        }
        Err(last.unwrap_or_else(|| anyhow!("no connect attempts made")))
    }

    /// The wire encoding this connection speaks.
    pub fn wire_proto(&self) -> WireProto {
        self.proto
    }

    fn send(&mut self, req: &Request) -> Result<()> {
        match self.proto {
            WireProto::Text => proto::write_request(&mut self.writer, req)?,
            WireProto::Binary => frame::write_request(&mut self.writer, req)?,
        }
        Ok(())
    }

    fn recv(&mut self) -> Result<Response> {
        match self.proto {
            WireProto::Text => proto::read_response(&mut self.reader),
            WireProto::Binary => frame::read_response(&mut self.reader),
        }
        .map_err(|e| anyhow!("{e}"))
    }

    /// Bound every blocking read on this connection (`None` = wait
    /// forever).  Session calls against a loaded server should set one:
    /// a timeout surfaces as an error instead of a parked client.
    pub fn set_read_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        self.reader.get_ref().set_read_timeout(timeout)?;
        Ok(())
    }

    pub fn ping(&mut self) -> Result<()> {
        self.send(&Request::Ping)?;
        match self.recv()? {
            Response::Pong => Ok(()),
            other => bail!("unexpected reply {other:?}"),
        }
    }

    /// Request the hull of `points`; blocks for the response.
    pub fn hull(&mut self, points: &[Point]) -> Result<ClientHull> {
        self.hull_deadline(points, None)
    }

    /// [`HullClient::hull`] with a per-request deadline budget in
    /// milliseconds (`TMO=` token / binary deadline header).  The server
    /// answers `deadline-exceeded` instead of computing a hull it cannot
    /// deliver in time; the budget can only tighten the server's
    /// configured default.
    pub fn hull_deadline(&mut self, points: &[Point], tmo_ms: Option<u32>) -> Result<ClientHull> {
        let id = self.next_id;
        self.next_id += 1;
        self.send(&Request::Hull { id, points: points.to_vec(), tmo_ms })?;
        match self.recv()? {
            Response::Hull { id, upper, lower, backend, queue_ns, exec_ns } => {
                Ok(ClientHull { id, upper, lower, backend, queue_ns, exec_ns })
            }
            Response::HullErr { message, .. } => bail!("server: {message}"),
            Response::MalformedErr { message, .. } => bail!("server: malformed frame: {message}"),
            other => bail!("unexpected reply {other:?}"),
        }
    }

    /// Fetch the metrics snapshot (raw JSON string).
    pub fn stats(&mut self) -> Result<String> {
        self.send(&Request::Stats)?;
        match self.recv()? {
            Response::Stats(s) => Ok(s),
            other => bail!("unexpected reply {other:?}"),
        }
    }

    pub fn quit(mut self) -> Result<()> {
        self.send(&Request::Quit)?;
        Ok(())
    }

    // ------------------------------------------------ streaming sessions

    /// `SOPEN`: open a streaming session; returns its token.
    pub fn session_open(&mut self) -> Result<u64> {
        self.session_open_inner(None)
    }

    /// `SOPEN <id> <sid>`: restore session `sid` from the server's
    /// snapshot store (its last checkpoint).  Fails with
    /// `unknown-session` when no snapshot exists, `session already open`
    /// when the sid is live, and `snapshot-corrupt`/`snapshot-io` when
    /// the stored bytes don't verify.
    pub fn session_restore(&mut self, sid: u64) -> Result<u64> {
        self.session_open_inner(Some(sid))
    }

    fn session_open_inner(&mut self, restore: Option<u64>) -> Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        self.send(&Request::SessionOpen { id, restore })?;
        match self.recv()? {
            Response::SessionOpened { sid, .. } => Ok(sid),
            Response::SessionErr { message, .. } => bail!("server: {message}"),
            other => bail!("unexpected reply {other:?}"),
        }
    }

    /// `SADD`: insert a batch into the session.
    pub fn session_add(&mut self, sid: u64, points: &[Point]) -> Result<SessionAddReply> {
        self.session_add_deadline(sid, points, None)
    }

    /// [`HullClient::session_add`] with a per-request deadline budget in
    /// milliseconds (see [`HullClient::hull_deadline`]).
    pub fn session_add_deadline(
        &mut self,
        sid: u64,
        points: &[Point],
        tmo_ms: Option<u32>,
    ) -> Result<SessionAddReply> {
        self.send(&Request::SessionAdd { sid, points: points.to_vec(), tmo_ms })?;
        match self.recv()? {
            Response::SessionAdded { absorbed, pending, epoch, .. } => {
                Ok(SessionAddReply { absorbed, pending, epoch })
            }
            Response::SessionErr { message, .. } => bail!("server: {message}"),
            other => bail!("unexpected reply {other:?}"),
        }
    }

    /// `SHULL`: the authoritative session hull (server flushes pending
    /// first).
    pub fn session_hull(&mut self, sid: u64) -> Result<SessionHullReply> {
        self.session_hull_inner(sid, None)
    }

    /// `SHULL <sid> <epoch>`: the hull exactly as it stood at a
    /// historical epoch (0 = empty, current epoch = live hull; pending
    /// points are *not* flushed).  `unknown-epoch` when the epoch is
    /// beyond the session's current one.
    pub fn session_hull_at(&mut self, sid: u64, epoch: u64) -> Result<SessionHullReply> {
        self.session_hull_inner(sid, Some(epoch))
    }

    fn session_hull_inner(&mut self, sid: u64, epoch: Option<u64>) -> Result<SessionHullReply> {
        self.send(&Request::SessionHull { sid, epoch })?;
        match self.recv()? {
            Response::SessionHull { epoch, upper, lower, .. } => {
                Ok(SessionHullReply { epoch, upper, lower })
            }
            Response::SessionErr { message, .. } => bail!("server: {message}"),
            other => bail!("unexpected reply {other:?}"),
        }
    }

    /// `SCLOSE`: release the session.
    pub fn session_close(&mut self, sid: u64) -> Result<()> {
        self.send(&Request::SessionClose { sid })?;
        match self.recv()? {
            Response::SessionClosed { .. } => Ok(()),
            Response::SessionErr { message, .. } => bail!("server: {message}"),
            other => bail!("unexpected reply {other:?}"),
        }
    }
}
