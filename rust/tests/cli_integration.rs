//! CLI launcher smoke tests: the paper's own workflow end-to-end through
//! the installed binary (gen -> hull -> trace/svg, occupancy, artifacts).

use std::process::Command;

fn wagener() -> Command {
    Command::new(env!("CARGO_BIN_EXE_wagener"))
}

#[test]
fn gen_then_hull_with_trace_and_svg() {
    let dir = std::env::temp_dir().join(format!("wagener-cli-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let pts = dir.join("pts.txt");
    let trace = dir.join("trace.txt");
    let svg = dir.join("hull.svg");

    let out = wagener()
        .args(["gen", "--dist", "disk", "--n", "64", "--seed", "9", "--out"])
        .arg(&pts)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    let out = wagener()
        .arg("hull")
        .arg(&pts)
        .arg("--trace")
        .arg(&trace)
        .arg("--svg")
        .arg(&svg)
        .arg("--backend")
        .arg("native")
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8(out.stdout).unwrap();
    assert!(stdout.contains("# upper hood"), "{stdout}");
    assert!(stdout.contains("# lower hood"));

    // trace parses in the paper's format
    let trace_text = std::fs::read_to_string(&trace).unwrap();
    let stages = wagener_hull::viz::trace::parse_trace(&trace_text).unwrap();
    assert_eq!(stages.len(), 5); // 64 slots -> d = 2..32
    // svg is well-formed
    let svg_text = std::fs::read_to_string(&svg).unwrap();
    assert!(svg_text.starts_with("<svg"));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn hull_runs_on_both_pram_tiers() {
    let dir = std::env::temp_dir().join(format!("wagener-cli-tiers-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let pts = dir.join("pts.txt");
    let out = wagener()
        .args(["gen", "--dist", "circle", "--n", "48", "--seed", "3", "--out"])
        .arg(&pts)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    let mut hulls = Vec::new();
    for mode in ["fast", "audited"] {
        let out = wagener()
            .arg("hull")
            .arg(&pts)
            .args(["--backend", "pram", "--exec-mode", mode])
            .output()
            .unwrap();
        assert!(out.status.success(), "{mode}: {}", String::from_utf8_lossy(&out.stderr));
        let stdout = String::from_utf8(out.stdout).unwrap();
        assert!(
            stdout.contains(if mode == "fast" { "backend=pram-fast" } else { "backend=pram" }),
            "{mode}: {stdout}"
        );
        // keep everything from the hull report on (tiers must agree)
        hulls.push(stdout[stdout.find("# upper hood").unwrap()..].to_string());
    }
    assert_eq!(hulls[0], hulls[1], "tiers disagree on the served hull");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn hull_merge_combines_two_files() {
    let dir = std::env::temp_dir().join(format!("wagener-cli-merge-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let (a, b) = (dir.join("a.txt"), dir.join("b.txt"));
    // two x-disjoint clouds, hand-written in the paper's point format:
    // the merge must take the tangent path and keep only outer corners
    std::fs::write(&a, "3\n0.1 0.2\n0.2 0.8\n0.3 0.3\n").unwrap();
    std::fs::write(&b, "3\n0.7 0.4\n0.8 0.9\n0.9 0.1\n").unwrap();
    let out = wagener()
        .arg("hull")
        .arg(&a)
        .arg("--merge")
        .arg(&b)
        .args(["--backend", "serial"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8(out.stdout).unwrap();
    assert!(stdout.contains("path=tangent"), "{stdout}");
    assert!(stdout.contains("# upper hood"), "{stdout}");
    // the merged upper hull of the six points: (0.1,0.2) (0.2,0.8)
    // (0.8,0.9) (0.9,0.1) — interior corners swallowed by the tangent
    let upper = stdout.split("# upper hood").nth(1).unwrap();
    assert!(upper.trim_start().starts_with('4'), "{upper}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn occupancy_table_prints() {
    let out = wagener()
        .args(["occupancy", "--n", "128", "--dist", "parabola"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let stdout = String::from_utf8(out.stdout).unwrap();
    assert!(stdout.contains("stage"), "{stdout}");
    assert!(stdout.lines().count() >= 7);
}

#[test]
fn unknown_command_usage() {
    let out = wagener().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("usage"));
}

#[test]
fn hull_rejects_missing_file() {
    let out = wagener().args(["hull", "/no/such/file"]).output().unwrap();
    assert!(!out.status.success());
}
