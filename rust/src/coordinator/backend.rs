//! Pluggable hull backends.
//!
//! The default production backend is PJRT (AOT artifacts from the Pallas/
//! JAX layers); `native` (host Wagener), `serial` (monotone chain) and
//! `pram` (cost-accounting simulator) exist for baselines and experiments.
//! PJRT handles are not Send, so backends are constructed *on* the worker
//! thread via [`BackendKind::build`].

use std::path::PathBuf;

use crate::geometry::point::{dedup_x, Point};
use crate::pram::ExecMode;
use crate::runtime::{ArtifactRegistry, HullExecutor};
use crate::serial::monotone_chain;
use crate::wagener;

/// Which backend the coordinator runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// AOT HLO artifacts on the PJRT CPU client (the three-layer path).
    Pjrt,
    /// rust-native Wagener pipeline.
    Native,
    /// serial monotone chain (the paper's serial comparator).
    Serial,
    /// Wagener on the CREW-PRAM simulator (slow; experiments only).
    Pram,
}

impl BackendKind {
    pub fn parse(s: &str) -> Option<BackendKind> {
        Some(match s {
            "pjrt" => BackendKind::Pjrt,
            "native" => BackendKind::Native,
            "serial" => BackendKind::Serial,
            "pram" => BackendKind::Pram,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Pjrt => "pjrt",
            BackendKind::Native => "native",
            BackendKind::Serial => "serial",
            BackendKind::Pram => "pram",
        }
    }

    /// Construct the backend (call on the thread that will own it).
    /// `preload` compiles every hull artifact up front (server warm start;
    /// §Perf P4 — lazy compilation showed up as 10²-second tail latencies).
    /// `exec_mode` selects the PRAM engine tier: the `pram` backend runs
    /// on it directly, and under `self_check` the `pjrt` backend
    /// cross-checks every PJRT result against the PRAM engine on that
    /// tier ([`HullExecutor::set_reference_check`]).
    pub fn build(
        &self,
        artifacts_dir: &PathBuf,
        preload: bool,
        exec_mode: ExecMode,
        self_check: bool,
    ) -> Result<Box<dyn HullBackend>, String> {
        Ok(match self {
            BackendKind::Pjrt => {
                let reg = ArtifactRegistry::load(artifacts_dir).map_err(|e| e.to_string())?;
                let mut exe = HullExecutor::new(reg).map_err(|e| e.to_string())?;
                if self_check {
                    exe.set_reference_check(Some(exec_mode));
                }
                if preload {
                    let names: Vec<String> = exe
                        .registry()
                        .iter()
                        .filter(|m| m.kind == crate::runtime::ArtifactKind::Hull)
                        .map(|m| m.name.clone())
                        .collect();
                    for name in names {
                        exe.ensure_compiled(&name).map_err(|e| e.to_string())?;
                    }
                }
                Box::new(PjrtBackend { exe })
            }
            BackendKind::Native => Box::new(NativeBackend),
            BackendKind::Serial => Box::new(SerialBackend),
            BackendKind::Pram => Box::new(PramBackend { mode: exec_mode }),
        })
    }
}

/// A batch-capable full-hull computer over preprocessed (x-sorted,
/// distinct-x, f32-quantized) point sets.
pub trait HullBackend {
    fn name(&self) -> &'static str;
    /// largest batch worth grouping (the batcher's flush threshold).
    fn preferred_batch(&self) -> usize;
    /// largest request size this backend accepts.
    fn max_points(&self) -> usize;
    /// compute (upper, lower) chains per request.
    fn compute(&self, batch: &[Vec<Point>]) -> Result<Vec<(Vec<Point>, Vec<Point>)>, String>;
}

// ------------------------------------------------------------------ pjrt

struct PjrtBackend {
    exe: HullExecutor,
}

impl HullBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn preferred_batch(&self) -> usize {
        let classes = self.exe.registry().hull_size_classes();
        classes
            .first()
            .map(|&n| self.exe.registry().hull_batches(n).into_iter().max().unwrap_or(1))
            .unwrap_or(1)
    }

    fn max_points(&self) -> usize {
        self.exe.registry().hull_size_classes().into_iter().max().unwrap_or(0)
    }

    fn compute(&self, batch: &[Vec<Point>]) -> Result<Vec<(Vec<Point>, Vec<Point>)>, String> {
        let m = batch.iter().map(Vec::len).max().unwrap_or(0);
        let n = self
            .exe
            .registry()
            .hull_size_classes()
            .into_iter()
            .find(|&n| n >= m.max(2))
            .ok_or_else(|| format!("no size class >= {m}"))?;
        let caps = self.exe.registry().hull_batches(n);
        let mut out = Vec::with_capacity(batch.len());
        let mut rest = batch;
        while !rest.is_empty() {
            // smallest capable batch artifact for the remaining chunk
            let b = caps
                .iter()
                .copied()
                .find(|&b| b >= rest.len())
                .unwrap_or_else(|| caps.iter().copied().max().unwrap_or(1));
            let take = rest.len().min(b);
            let meta = self
                .exe
                .registry()
                .select_hull(n, b)
                .map_err(|e| e.to_string())?
                .clone();
            let chunk = self
                .exe
                .run_hull(&meta, &rest[..take])
                .map_err(|e| e.to_string())?;
            out.extend(chunk);
            rest = &rest[take..];
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------- native

struct NativeBackend;

impl HullBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }
    fn preferred_batch(&self) -> usize {
        8
    }
    fn max_points(&self) -> usize {
        1 << 22
    }
    fn compute(&self, batch: &[Vec<Point>]) -> Result<Vec<(Vec<Point>, Vec<Point>)>, String> {
        Ok(batch.iter().map(|pts| wagener::full_hull(pts)).collect())
    }
}

// ---------------------------------------------------------------- serial

struct SerialBackend;

impl HullBackend for SerialBackend {
    fn name(&self) -> &'static str {
        "serial"
    }
    fn preferred_batch(&self) -> usize {
        1
    }
    fn max_points(&self) -> usize {
        1 << 24
    }
    fn compute(&self, batch: &[Vec<Point>]) -> Result<Vec<(Vec<Point>, Vec<Point>)>, String> {
        Ok(batch.iter().map(|pts| monotone_chain::full_hull(pts)).collect())
    }
}

// ------------------------------------------------------------------ pram

struct PramBackend {
    /// `Fast` for serving (parallel, unaudited), `Audited` for the
    /// cost-model instrument.
    mode: ExecMode,
}

impl HullBackend for PramBackend {
    fn name(&self) -> &'static str {
        match self.mode {
            ExecMode::Fast => "pram-fast",
            ExecMode::Audited => "pram",
        }
    }
    fn preferred_batch(&self) -> usize {
        1
    }
    fn max_points(&self) -> usize {
        // the unaudited tier can serve far larger requests for the same
        // latency budget than the instrument can
        match self.mode {
            ExecMode::Fast => 1 << 18,
            ExecMode::Audited => 1 << 14,
        }
    }
    fn compute(&self, batch: &[Vec<Point>]) -> Result<Vec<(Vec<Point>, Vec<Point>)>, String> {
        batch
            .iter()
            .map(|pts| {
                let slots = pts.len().next_power_of_two().max(2);
                let up = wagener::pram_exec::run_pipeline_mode(pts, slots, self.mode, true)
                    .map_err(|e| e.to_string())?;
                let neg: Vec<Point> = pts.iter().map(|p| Point::new(p.x, -p.y)).collect();
                let lo = wagener::pram_exec::run_pipeline_mode(&neg, slots, self.mode, true)
                    .map_err(|e| e.to_string())?;
                let upper = crate::geometry::point::live_prefix(&up.hood).to_vec();
                let lower: Vec<Point> = crate::geometry::point::live_prefix(&lo.hood)
                    .iter()
                    .map(|p| Point::new(p.x, -p.y))
                    .collect();
                Ok((upper, lower))
            })
            .collect()
    }
}

// ------------------------------------------------------ degenerate exact

/// Exact full hull for inputs violating general position (duplicate x):
/// per x-class only the extreme-y points can be hull corners, so dedup to
/// the max-y (resp. min-y) representative and run the serial chain.
pub fn exact_full_hull(sorted_pts: &[Point]) -> (Vec<Point>, Vec<Point>) {
    let upper = monotone_chain::upper_hull(&dedup_x(sorted_pts, true));
    let lower = monotone_chain::lower_hull(&dedup_x(sorted_pts, false));
    (upper, lower)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::generators::{generate, Distribution};

    #[test]
    fn kind_parse_roundtrip() {
        for k in [BackendKind::Pjrt, BackendKind::Native, BackendKind::Serial, BackendKind::Pram] {
            assert_eq!(BackendKind::parse(k.name()), Some(k));
        }
        assert_eq!(BackendKind::parse("gpu"), None);
    }

    #[test]
    fn native_serial_pram_agree() {
        let native = BackendKind::Native
            .build(&PathBuf::new(), false, ExecMode::Fast, false)
            .unwrap();
        let serial = BackendKind::Serial
            .build(&PathBuf::new(), false, ExecMode::Fast, false)
            .unwrap();
        let pram = BackendKind::Pram
            .build(&PathBuf::new(), false, ExecMode::Audited, false)
            .unwrap();
        let pram_fast = BackendKind::Pram
            .build(&PathBuf::new(), false, ExecMode::Fast, false)
            .unwrap();
        let batch: Vec<Vec<Point>> = (0..3)
            .map(|k| generate(Distribution::ALL[k], 50 + k, k as u64))
            .collect();
        let a = native.compute(&batch).unwrap();
        let b = serial.compute(&batch).unwrap();
        let c = pram.compute(&batch).unwrap();
        let d = pram_fast.compute(&batch).unwrap();
        assert_eq!(a, b);
        assert_eq!(b, c);
        assert_eq!(c, d);
    }

    #[test]
    fn pram_tiers_report_distinct_names_and_limits() {
        let audited = BackendKind::Pram
            .build(&PathBuf::new(), false, ExecMode::Audited, false)
            .unwrap();
        let fast = BackendKind::Pram
            .build(&PathBuf::new(), false, ExecMode::Fast, false)
            .unwrap();
        assert_eq!(audited.name(), "pram");
        assert_eq!(fast.name(), "pram-fast");
        assert!(fast.max_points() > audited.max_points());
    }

    #[test]
    fn exact_full_hull_handles_duplicate_x() {
        // a vertical segment of three points plus flanks
        let pts = vec![
            Point::new(0.1, 0.5),
            Point::new(0.5, 0.1),
            Point::new(0.5, 0.5),
            Point::new(0.5, 0.9),
            Point::new(0.9, 0.5),
        ];
        let (up, lo) = exact_full_hull(&pts);
        assert_eq!(up, vec![pts[0], pts[3], pts[4]]);
        assert_eq!(lo, vec![pts[0], pts[1], pts[4]]);
    }

    #[test]
    fn exact_matches_serial_on_general_position() {
        let pts = generate(Distribution::Disk, 128, 3);
        let (u, l) = exact_full_hull(&pts);
        let (su, sl) = monotone_chain::full_hull(&pts);
        assert_eq!(u, su);
        assert_eq!(l, sl);
    }
}
